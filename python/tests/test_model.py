"""L2 correctness: model shapes, gradient flow, and that a few epochs of
the scanned train_epoch actually reduce loss on learnable synthetic data.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def synth_data(rng, nb, bs, num_classes):
    """Class-conditional Gaussian-blob images: genuinely learnable."""
    protos = rng.normal(0, 1, (num_classes, 32, 32, 3)).astype(np.float32)
    ys = rng.integers(0, num_classes, (nb, bs)).astype(np.int32)
    xs = protos[ys] + 0.3 * rng.normal(0, 1, (nb, bs, 32, 32, 3)).astype(np.float32)
    return jnp.asarray(xs), jnp.asarray(ys)


@pytest.mark.parametrize("name", ["micro_resnet", "micro_inception"])
class TestModel:
    def test_forward_shapes(self, name):
        params = M.MODELS[name](jax.random.PRNGKey(0), 10)
        x = jnp.zeros((4, 32, 32, 3))
        logits = M.FORWARDS[name](params, x)
        assert logits.shape == (4, 10)

    def test_param_order_matches_layer_names(self, name):
        params = M.MODELS[name](jax.random.PRNGKey(0), 10)
        names = M.layer_names(name)
        assert len(params) == len(names)
        for p, n in zip(params, names):
            if n.endswith(".bias"):
                assert p.ndim == 1, f"{n}: {p.shape}"
            elif n == "fc":
                assert p.ndim == 2
            else:
                assert p.ndim == 4, f"{n}: {p.shape}"

    def test_train_epoch_reduces_loss(self, name):
        rng = np.random.default_rng(0)
        xs, ys = synth_data(rng, 8, 32, 10)
        params = M.MODELS[name](jax.random.PRNGKey(1), 10)
        train = jax.jit(M.make_train_epoch(name, 10))
        first_loss = None
        for _ in range(5):
            out = train(params, xs, ys, jnp.float32(0.05))
            params, loss = list(out[:-1]), out[-1]
            if first_loss is None:
                first_loss = float(loss)
        assert float(loss) < first_loss * 0.9, (first_loss, float(loss))

    def test_eval_counts_correct(self, name):
        rng = np.random.default_rng(1)
        xs, ys = synth_data(rng, 1, 64, 10)
        params = M.MODELS[name](jax.random.PRNGKey(2), 10)
        ev = jax.jit(M.make_eval(name, 10))
        loss, correct = ev(params, xs[0], ys[0])
        assert 0 <= float(correct) <= 64
        assert np.isfinite(float(loss))

    def test_grads_nonzero_everywhere(self, name):
        rng = np.random.default_rng(2)
        xs, ys = synth_data(rng, 1, 16, 10)
        params = M.MODELS[name](jax.random.PRNGKey(3), 10)

        def loss_fn(p):
            logits = M.FORWARDS[name](p, xs[0])
            onehot = jax.nn.one_hot(ys[0], 10)
            return -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), -1))

        grads = jax.grad(loss_fn)(params)
        for g, n in zip(grads, M.layer_names(name)):
            assert float(jnp.abs(g).max()) > 0, f"zero grad in {n}"
