"""L1 correctness: Pallas kernel vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes/β/Δ/value scales; the kernel must match the
reference *exactly* (same f32 op order), which is what guarantees the
Rust native path and the HLO path agree at FL time.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.predict_quantize import predict_quantize
from compile.kernels.ref import predict_quantize_ref

jax.config.update("jax_platform_name", "cpu")


def make_inputs(rng, n, scale, beta, two_delta):
    prev_abs = np.abs(rng.normal(0, scale, n)).astype(np.float32)
    memory = rng.normal(0, 1, n).astype(np.float32)
    signs = rng.choice([-1.0, 0.0, 1.0], n).astype(np.float32)
    grad = rng.normal(0, scale, n).astype(np.float32)
    abs_grad = np.abs(grad)
    scalars = np.array(
        [beta, abs_grad.mean(), abs_grad.std(),
         prev_abs.mean(), prev_abs.std(), two_delta, 0.0, 0.0],
        dtype=np.float32,
    )
    return prev_abs, memory, signs, grad, scalars


#: The ref is jitted so XLA makes the same FMA-fusion decisions for both
#: graphs — eager jnp differs from compiled by ~1 ulp on fused mul-adds.
_ref_jit = jax.jit(predict_quantize_ref)


def run_both(inputs, tile):
    k_codes, k_ghat, k_mem = predict_quantize(*[jnp.asarray(a) for a in inputs], tile=tile)
    r_codes, r_ghat, r_mem = _ref_jit(*[jnp.asarray(a) for a in inputs])
    return (k_codes, k_ghat, k_mem), (r_codes, r_ghat, r_mem)


class TestKernelVsRef:
    def test_exact_match_basic(self):
        rng = np.random.default_rng(0)
        inputs = make_inputs(rng, 4096, 1.0, 0.9, 0.01)
        (kc, kg, km), (rc, rg, rm) = run_both(inputs, 4096)
        np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))
        np.testing.assert_array_equal(np.asarray(kg), np.asarray(rg))
        np.testing.assert_array_equal(np.asarray(km), np.asarray(rm))

    def test_multi_tile_grid(self):
        rng = np.random.default_rng(1)
        inputs = make_inputs(rng, 8192, 0.1, 0.5, 0.002)
        (kc, kg, km), (rc, rg, rm) = run_both(inputs, 2048)  # grid of 4
        np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))
        np.testing.assert_array_equal(np.asarray(kg), np.asarray(rg))
        np.testing.assert_array_equal(np.asarray(km), np.asarray(rm))

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        tiles=st.integers(1, 4),
        log_scale=st.floats(-4, 2),
        beta=st.floats(0.0, 0.999),
        log_delta=st.floats(-5, -1),
    )
    def test_hypothesis_sweep(self, seed, tiles, log_scale, beta, log_delta):
        rng = np.random.default_rng(seed)
        tile = 512
        n = tile * tiles
        scale = 10.0 ** log_scale
        two_delta = 2 * 10.0 ** log_delta * scale
        inputs = make_inputs(rng, n, scale, beta, np.float32(two_delta))
        (kc, kg, km), (rc, rg, rm) = run_both(inputs, tile)
        np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))
        np.testing.assert_array_equal(np.asarray(kg), np.asarray(rg))
        np.testing.assert_array_equal(np.asarray(km), np.asarray(rm))

    def test_zero_sigma_prev_stable(self):
        rng = np.random.default_rng(2)
        inputs = list(make_inputs(rng, 512, 1.0, 0.9, 0.01))
        inputs[0] = np.full(512, 0.25, np.float32)     # constant prev_abs
        inputs[4][3] = 0.25                            # mu_prev
        inputs[4][4] = 0.0                             # sigma_prev = 0
        (kc, kg, km), (rc, rg, rm) = run_both(tuple(inputs), 512)
        assert np.isfinite(np.asarray(kg)).all()
        np.testing.assert_array_equal(np.asarray(kg), np.asarray(rg))
        np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))
        assert np.isfinite(np.asarray(km)).all()

    def test_accurate_prediction_small_codes(self):
        # If signs/magnitude match the gradient, codes concentrate at 0.
        n = 1024
        rng = np.random.default_rng(3)
        a = np.abs(rng.normal(0.5, 0.1, n)).astype(np.float32)
        grad = a.copy()  # positive gradient equal to prev magnitude
        signs = np.ones(n, np.float32)
        memory = ((a - a.mean()) / a.std()).astype(np.float32)  # converged EMA
        scalars = np.array(
            [1.0, a.mean(), a.std(), a.mean(), a.std(), 0.05, 0, 0],
            np.float32,
        )
        codes, _, _ = predict_quantize(
            jnp.asarray(a), jnp.asarray(memory), jnp.asarray(signs),
            jnp.asarray(grad), jnp.asarray(scalars), tile=512)
        zero_frac = float((np.asarray(codes) == 0).mean())
        assert zero_frac > 0.95, zero_frac


class TestKernelRejectsBadShapes:
    def test_non_multiple_tile_asserts(self):
        rng = np.random.default_rng(4)
        inputs = make_inputs(rng, 1000, 1.0, 0.9, 0.01)
        with pytest.raises(AssertionError):
            predict_quantize(*[jnp.asarray(a) for a in inputs], tile=512)
