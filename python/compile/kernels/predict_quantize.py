"""L1 Pallas kernel: the fused predict+quantize hot-spot.

One elementwise pass fusing Alg. 1 magnitude prediction (normalize → EMA →
de-normalize), sign application, residual formation and error-bounded
quantization to bin codes. Entropy coding stays on the host (Rust), exactly
as cuSZP keeps bit-packing CPU-assisted.

TPU mapping (DESIGN.md §7): a 1-D grid of VMEM-sized tiles. With
TILE = 64k f32 elements the six live buffers (4 inputs + 3 outputs share
tiles) occupy ~1.75 MB of VMEM — far under the ~16 MB budget, leaving the
grid pipeline free to double-buffer HBM↔VMEM transfers. All math is
VPU-friendly f32 elementwise; no MXU involvement. The kernel is memory
bound: 4 f32 reads + 3 f32 writes = 28 B/element.

MUST be lowered with interpret=True here: real TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SIGMA_EPS = 1e-12

# Default tile: 64k elements = 256 KiB per f32 buffer in VMEM.
TILE = 65536


def _kernel(scalar_ref, prev_abs_ref, memory_ref, signs_ref, grad_ref,
            codes_ref, ghat_ref, newmem_ref):
    beta = scalar_ref[0]
    mu_curr = scalar_ref[1]
    sigma_curr = scalar_ref[2]
    mu_prev = scalar_ref[3]
    sigma_prev = scalar_ref[4]
    two_delta = scalar_ref[5]

    prev_abs = prev_abs_ref[...]
    memory = memory_ref[...]
    signs = signs_ref[...]
    grad = grad_ref[...]

    inv_sigma_prev = 1.0 / jnp.maximum(sigma_prev, SIGMA_EPS)
    z = (prev_abs - mu_prev) * inv_sigma_prev
    new_memory = beta * memory + (1.0 - beta) * z
    a_hat = jnp.maximum(new_memory * sigma_curr + mu_curr, 0.0)
    g_hat = signs * a_hat
    inv_two_delta = 1.0 / two_delta
    codes = jnp.floor((grad - g_hat) * inv_two_delta + 0.5)

    codes_ref[...] = codes
    ghat_ref[...] = g_hat
    newmem_ref[...] = new_memory


@functools.partial(jax.jit, static_argnames=("tile",))
def predict_quantize(prev_abs, memory, signs, grad, scalars, *, tile=TILE):
    """Fused predict+quantize over an n-element (n % tile == 0) buffer.

    scalars: f32[8] = [beta, mu_curr, sigma_curr, mu_prev, sigma_prev,
    two_delta, pad, pad]. Returns (codes f32[n], g_hat f32[n],
    new_memory f32[n]).
    """
    n = prev_abs.shape[0]
    assert n % tile == 0, f"n={n} not a multiple of tile={tile}"
    grid = (n // tile,)
    tiled = pl.BlockSpec((tile,), lambda i: (i,))
    # Scalars are broadcast to every tile.
    scalar_spec = pl.BlockSpec((8,), lambda i: (0,))
    out_shape = [jax.ShapeDtypeStruct((n,), jnp.float32)] * 3
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[scalar_spec, tiled, tiled, tiled, tiled],
        out_specs=[tiled, tiled, tiled],
        out_shape=out_shape,
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(scalars, prev_abs, memory, signs, grad)
