"""Pure-jnp oracle for the predict_quantize kernel.

This is the L1 correctness contract: the Pallas kernel
(`predict_quantize.py`) and the Rust native fused path
(`rust/src/compress/fused.rs`) must both match this math exactly
(identical f32 op order; round-half-up via floor(x+0.5)).
"""

import jax.numpy as jnp

SIGMA_EPS = 1e-12


def predict_quantize_ref(prev_abs, memory, signs, grad, scalars):
    """Reference predict+quantize.

    scalars: [beta, mu_curr, sigma_curr, mu_prev, sigma_prev, two_delta,
              0, 0]  (padded to 8 for a fixed kernel signature)

    Returns (codes_f32, g_hat, new_memory). The caller (Rust) applies
    escape handling; the kernel only produces raw codes and predictions.
    """
    beta = scalars[0]
    mu_curr = scalars[1]
    sigma_curr = scalars[2]
    mu_prev = scalars[3]
    sigma_prev = scalars[4]
    two_delta = scalars[5]

    inv_sigma_prev = 1.0 / jnp.maximum(sigma_prev, SIGMA_EPS)
    z = (prev_abs - mu_prev) * inv_sigma_prev
    new_memory = beta * memory + (1.0 - beta) * z
    a_hat = jnp.maximum(new_memory * sigma_curr + mu_curr, 0.0)
    g_hat = signs * a_hat
    inv_two_delta = 1.0 / two_delta
    codes = jnp.floor((grad - g_hat) * inv_two_delta + 0.5)
    return codes, g_hat, new_memory


def magnitude_predict_ref(prev_abs, memory, beta, mu_curr, sigma_curr):
    """Alg. 1 in isolation (used by model-level tests)."""
    mu_prev = jnp.mean(prev_abs)
    sigma_prev = jnp.std(prev_abs)
    z = (prev_abs - mu_prev) / jnp.maximum(sigma_prev, SIGMA_EPS)
    new_memory = beta * memory + (1.0 - beta) * z
    a_hat = jnp.maximum(new_memory * sigma_curr + mu_curr, 0.0)
    return a_hat, new_memory
