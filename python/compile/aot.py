"""AOT lowering: JAX/Pallas -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT serialized protos) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Emits, per model in {micro_resnet, micro_inception} x classes {10, 101}:
    train_epoch_<model>_c<classes>.hlo.txt
    eval_<model>_c<classes>.hlo.txt
plus the fused Pallas kernel at two block sizes:
    predict_quantize_4096.hlo.txt
    predict_quantize_65536.hlo.txt
and a manifest.json describing every artifact's shapes so the Rust side
needs no hard-coded protocol.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# Fixed AOT shapes (documented in the manifest).
BATCHES_PER_EPOCH = 8
BATCH_SIZE = 32
EVAL_N = 256
IMG = (32, 32, 3)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_train_epoch(name, num_classes):
    params = M.MODELS[name](jax.random.PRNGKey(0), num_classes)
    n_params = len(params)
    train = M.make_train_epoch(name, num_classes)

    def flat(*args):
        p = list(args[:n_params])
        xs, ys, lr = args[n_params:]
        return train(p, xs, ys, lr)

    arg_specs = [spec(p.shape) for p in params] + [
        spec((BATCHES_PER_EPOCH, BATCH_SIZE) + IMG),
        spec((BATCHES_PER_EPOCH, BATCH_SIZE), jnp.int32),
        spec(()),
    ]
    return jax.jit(flat).lower(*arg_specs), [list(p.shape) for p in params]


def lower_eval(name, num_classes):
    params = M.MODELS[name](jax.random.PRNGKey(0), num_classes)
    n_params = len(params)
    ev = M.make_eval(name, num_classes)

    def flat(*args):
        p = list(args[:n_params])
        x, y = args[n_params:]
        return ev(p, x, y)

    arg_specs = [spec(p.shape) for p in params] + [
        spec((EVAL_N,) + IMG),
        spec((EVAL_N,), jnp.int32),
    ]
    return jax.jit(flat).lower(*arg_specs)


def lower_predict_quantize(n, tile):
    fn = M.make_predict_quantize(n, tile)
    s = spec((n,))
    return jax.jit(fn).lower(s, s, s, s, spec((8,)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--skip-train", action="store_true",
                    help="only emit the predict_quantize kernels")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "batches_per_epoch": BATCHES_PER_EPOCH,
        "batch_size": BATCH_SIZE,
        "eval_n": EVAL_N,
        "img": list(IMG),
        "models": {},
        "kernels": {},
    }

    for n, tile in [(4096, 4096), (65536, 8192)]:
        path = f"predict_quantize_{n}.hlo.txt"
        text = to_hlo_text(lower_predict_quantize(n, tile))
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        manifest["kernels"][str(n)] = {"file": path, "n": n, "tile": tile}
        print(f"wrote {path} ({len(text)} chars)")

    if not args.skip_train:
        for name in ["micro_resnet", "micro_inception"]:
            for classes in [10, 101]:
                lowered, shapes = lower_train_epoch(name, classes)
                tpath = f"train_epoch_{name}_c{classes}.hlo.txt"
                with open(os.path.join(args.out_dir, tpath), "w") as f:
                    f.write(to_hlo_text(lowered))
                epath = f"eval_{name}_c{classes}.hlo.txt"
                with open(os.path.join(args.out_dir, epath), "w") as f:
                    f.write(to_hlo_text(lower_eval(name, classes)))
                manifest["models"][f"{name}_c{classes}"] = {
                    "train": tpath,
                    "eval": epath,
                    "layer_names": M.layer_names(name),
                    "param_shapes": shapes,
                    "num_classes": classes,
                }
                print(f"wrote {tpath}, {epath}")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
