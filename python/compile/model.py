"""L2: JAX micro-CNN models (train_epoch / eval graphs) + the compression
graph that calls the L1 Pallas kernel.

These mirror `rust/src/tensor/model_zoo.rs::{micro_resnet,micro_inception}`
layer-for-layer: the Rust coordinator owns the parameter tensors (flat
list, in this module's `layer_names()` order) and feeds them through the
AOT-lowered HLO. Python never runs at FL time.

Input convention: synthetic datasets are [B, 32, 32, 3] f32 (NHWC), labels
int32 [B]. Fashion-MNIST-like data is grayscale replicated to 3 channels
(see DESIGN.md §5 substitutions).
"""

import jax
import jax.numpy as jnp

from .kernels.predict_quantize import predict_quantize


# ---------------------------------------------------------------------------
# Parameter initialization (He-normal conv / LeCun dense).


def _conv(key, out_ch, in_ch, kh, kw):
    fan_in = in_ch * kh * kw
    w = jax.random.normal(key, (out_ch, in_ch, kh, kw), jnp.float32)
    return w * jnp.sqrt(2.0 / fan_in)


def _dense(key, out, inp):
    w = jax.random.normal(key, (out, inp), jnp.float32)
    return w * jnp.sqrt(1.0 / inp)


def init_micro_resnet(key, num_classes=10):
    """Params in model_zoo::micro_resnet order."""
    ks = jax.random.split(key, 8)
    return [
        _conv(ks[0], 16, 3, 3, 3), jnp.zeros((16,)),          # stem
        _conv(ks[1], 16, 16, 3, 3), jnp.zeros((16,)),          # block0.a
        _conv(ks[2], 16, 16, 3, 3), jnp.zeros((16,)),          # block0.b
        _conv(ks[3], 32, 16, 3, 3), jnp.zeros((32,)),          # block1.a
        _conv(ks[4], 32, 32, 3, 3), jnp.zeros((32,)),          # block1.b
        _conv(ks[5], 32, 16, 1, 1), jnp.zeros((32,)),          # block1.down
        _dense(ks[6], num_classes, 32 * 8 * 8), jnp.zeros((num_classes,)),
    ]


def init_micro_inception(key, num_classes=10):
    """Params in model_zoo::micro_inception order."""
    ks = jax.random.split(key, 8)
    return [
        _conv(ks[0], 16, 3, 3, 3), jnp.zeros((16,)),           # stem
        _conv(ks[1], 8, 16, 1, 1), jnp.zeros((8,)),            # mix0.b1
        _conv(ks[2], 16, 16, 3, 3), jnp.zeros((16,)),          # mix0.b3
        _conv(ks[3], 8, 16, 5, 5), jnp.zeros((8,)),            # mix0.b5
        _conv(ks[4], 8, 32, 1, 1), jnp.zeros((8,)),            # mix1.b1
        _conv(ks[5], 16, 32, 3, 3), jnp.zeros((16,)),          # mix1.b3
        _conv(ks[6], 8, 32, 5, 5), jnp.zeros((8,)),            # mix1.b5
        _dense(ks[7], num_classes, 32 * 8 * 8), jnp.zeros((num_classes,)),
    ]


MODELS = {
    "micro_resnet": init_micro_resnet,
    "micro_inception": init_micro_inception,
}


# ---------------------------------------------------------------------------
# Forward passes.


def _conv2d(x, w, b, stride=1):
    """NHWC x OIHW conv, SAME padding."""
    y = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "OIHW", "NHWC"),
    )
    return y + b


def _avg_pool(x, k):
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, k, k, 1), (1, k, k, 1), "VALID"
    ) / (k * k)


def forward_micro_resnet(params, x):
    (sw, sb, a0w, a0b, b0w, b0b, a1w, a1b, b1w, b1b, dw, db, fw, fb) = params
    h = jax.nn.relu(_conv2d(x, sw, sb))                       # 32x32x16
    # block0 (identity residual)
    r = h
    h = jax.nn.relu(_conv2d(h, a0w, a0b))
    h = _conv2d(h, b0w, b0b)
    h = jax.nn.relu(h + r)                                    # 32x32x16
    # block1 (stride-2 + 1x1 projection)
    r = _conv2d(h, dw, db, stride=2)                          # 16x16x32
    h = jax.nn.relu(_conv2d(h, a1w, a1b, stride=2))
    h = _conv2d(h, b1w, b1b)
    h = jax.nn.relu(h + r)                                    # 16x16x32
    h = _avg_pool(h, 2)                                       # 8x8x32
    h = h.reshape(h.shape[0], -1)
    return h @ fw.T + fb


def forward_micro_inception(params, x):
    (sw, sb, c1w, c1b, c3w, c3b, c5w, c5b,
     d1w, d1b, d3w, d3b, d5w, d5b, fw, fb) = params
    h = jax.nn.relu(_conv2d(x, sw, sb))                       # 32x32x16
    h = _avg_pool(h, 2)                                       # 16x16x16
    h = jnp.concatenate([
        jax.nn.relu(_conv2d(h, c1w, c1b)),
        jax.nn.relu(_conv2d(h, c3w, c3b)),
        jax.nn.relu(_conv2d(h, c5w, c5b)),
    ], axis=-1)                                               # 16x16x32
    h = _avg_pool(h, 2)                                       # 8x8x32
    h = jnp.concatenate([
        jax.nn.relu(_conv2d(h, d1w, d1b)),
        jax.nn.relu(_conv2d(h, d3w, d3b)),
        jax.nn.relu(_conv2d(h, d5w, d5b)),
    ], axis=-1)                                               # 8x8x32
    h = h.reshape(h.shape[0], -1)
    return h @ fw.T + fb


FORWARDS = {
    "micro_resnet": forward_micro_resnet,
    "micro_inception": forward_micro_inception,
}


def _loss_fn(forward, params, x, y, num_classes):
    logits = forward(params, x)
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(y, num_classes, dtype=jnp.float32)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def make_train_epoch(name, num_classes):
    """One local FL epoch: scan of minibatch SGD steps.

    Signature (after jit): (params..., X[nb,bs,32,32,3], Y[nb,bs] i32,
    lr f32[]) -> (new_params..., mean_loss). The scan keeps the HLO small
    regardless of batch count and lets XLA donate the parameter buffers.
    """
    forward = FORWARDS[name]

    def train_epoch(params, xs, ys, lr):
        def step(p, batch):
            x, y = batch
            loss, grads = jax.value_and_grad(
                lambda q: _loss_fn(forward, q, x, y, num_classes))(p)
            new_p = [w - lr * g for w, g in zip(p, grads)]
            return new_p, loss

        new_params, losses = jax.lax.scan(step, list(params), (xs, ys))
        return tuple(new_params) + (jnp.mean(losses),)

    return train_epoch


def make_eval(name, num_classes):
    """Eval graph: (params..., X[n,32,32,3], Y[n]) -> (loss, n_correct)."""
    forward = FORWARDS[name]

    def evaluate(params, x, y):
        logits = forward(params, x)
        logp = jax.nn.log_softmax(logits)
        onehot = jax.nn.one_hot(y, num_classes, dtype=jnp.float32)
        loss = -jnp.mean(jnp.sum(onehot * logp, axis=-1))
        correct = jnp.sum((jnp.argmax(logits, -1) == y).astype(jnp.float32))
        return loss, correct

    return evaluate


def make_predict_quantize(n, tile):
    """The L2 wrapper around the L1 kernel for AOT lowering."""

    def fn(prev_abs, memory, signs, grad, scalars):
        return predict_quantize(prev_abs, memory, signs, grad, scalars,
                                tile=tile)

    return fn


def layer_names(name):
    """Flat parameter order, matching rust model_zoo metas."""
    if name == "micro_resnet":
        return [
            "stem.conv", "stem.bias",
            "block0.a.conv", "block0.a.bias",
            "block0.b.conv", "block0.b.bias",
            "block1.a.conv", "block1.a.bias",
            "block1.b.conv", "block1.b.bias",
            "block1.down.conv", "block1.down.bias",
            "fc", "fc.bias",
        ]
    if name == "micro_inception":
        return [
            "stem.conv", "stem.bias",
            "mix0.b1.conv", "mix0.b1.bias",
            "mix0.b3.conv", "mix0.b3.bias",
            "mix0.b5.conv", "mix0.b5.bias",
            "mix1.b1.conv", "mix1.b1.bias",
            "mix1.b3.conv", "mix1.b3.bias",
            "mix1.b5.conv", "mix1.b5.bias",
            "fc", "fc.bias",
        ]
    raise ValueError(name)
