//! Multi-process-style federation over real TCP sockets with throttled
//! uplinks: spawns a parameter server and N client threads speaking the
//! wire protocol, each client behind a simulated 20 Mbps link.
//!
//! ```bash
//! cargo run --release --offline --example tcp_federation
//! ```
//! (The same protocol runs across machines via `fedgec serve` /
//! `fedgec client`.)

use std::net::TcpListener;

use fedgec::compress::spec::{CodecSpec, SpecDefaults};
use fedgec::coordinator::native_trainer::NativeTrainer;
use fedgec::fl::client::Client;
use fedgec::fl::server::Server;
use fedgec::fl::transport::bandwidth::LinkSpec;
use fedgec::fl::transport::tcp::{accept_n, TcpChannel};
use fedgec::fl::transport::Channel;
use fedgec::train::data::{DatasetSpec, SynthDataset};
use fedgec::train::native::NativeNet;
use fedgec::util::rng::Rng;

fn main() -> fedgec::Result<()> {
    let n_clients = 4;
    let rounds = 6;
    let eb = 1e-2;
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    println!("server on {addr}; {n_clients} clients over throttled 20 Mbps TCP uplinks\n");

    let link = LinkSpec {
        bits_per_sec: 20e6,
        down_bits_per_sec: 80e6,
        latency: std::time::Duration::from_millis(5),
    };
    let handles: Vec<_> = (0..n_clients)
        .map(|id| {
            let addr = addr.clone();
            std::thread::spawn(move || -> fedgec::Result<()> {
                let mut ch = TcpChannel::connect(&addr, Some(link))?;
                let ds = SynthDataset::new(DatasetSpec::Cifar10, 21);
                let mut rng = Rng::new(1000 + id as u64);
                let slice = ds.sample(&mut rng, 96, 0.4);
                let trainer = NativeTrainer::new(10, slice, 0.2, 3);
                let codec =
                    CodecSpec::parse_with("fedgec", &SpecDefaults::with_rel_eb(eb))?.build();
                // Clients stream per-layer frames by default, so each
                // throttled send overlaps with the next layer's encode.
                Client::new(id as u32, Box::new(trainer), codec).run(&mut ch)
            })
        })
        .collect();

    let chans = accept_n(&listener, n_clients, None)?;
    let mut channels: Vec<Box<dyn Channel>> =
        chans.into_iter().map(|c| Box::new(c) as _).collect();
    let proto = NativeNet::new(10, 3);
    let init =
        vec![proto.conv_w.clone(), proto.conv_b.clone(), proto.fc_w.clone(), proto.fc_b.clone()];
    // One stateless decode engine for the whole federation; per-client
    // predictor state lives in the server's keyed state store.
    let spec = CodecSpec::parse_with("fedgec", &SpecDefaults::with_rel_eb(eb))?;
    let mut server = Server::with_engine(init, proto.layer_metas(), 0.2, spec.build_engine());
    server.wait_hellos(&mut channels)?;
    for r in 0..rounds {
        let t0 = std::time::Instant::now();
        let stats = server.run_round(&mut channels)?;
        println!(
            "round {r}: loss {:.4} | CR {:.2} | payload {:>6.1} KB | states {} ({:.0} KB) | wall {}",
            stats.mean_loss,
            stats.ratio(),
            stats.payload_bytes as f64 / 1e3,
            stats.store_clients,
            stats.store_bytes as f64 / 1e3,
            fedgec::metrics::fmt_duration(t0.elapsed()),
        );
    }
    server.shutdown(&mut channels)?;
    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("client panicked"))??;
    }
    let ds = SynthDataset::new(DatasetSpec::Cifar10, 21);
    let mut rng = Rng::new(9999);
    let eval = ds.sample(&mut rng, 256, 0.0);
    let (loss, acc) = NativeTrainer::eval_params(10, &server.params, &eval);
    println!("\nfinal global model: eval loss {loss:.4}, accuracy {acc:.3}");
    Ok(())
}
