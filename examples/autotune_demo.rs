//! Auto-tuning demo — the paper's §6 future-work item in action: FedGEC
//! with fixed defaults vs the τ/β auto-tuner, on a gradient stream whose
//! statistics shift mid-run (a new "task phase" with noisier signs). The
//! controller re-targets the ~10% mismatch operating point with zero
//! extra communication.
//!
//! ```bash
//! cargo run --release --offline --example autotune_demo
//! ```

use fedgec::compress::pipeline::{FedgecCodec, FedgecConfig};
use fedgec::compress::quant::ErrorBound;
use fedgec::compress::GradientCodec;
use fedgec::metrics::Table;
use fedgec::tensor::model_zoo::ModelArch;
use fedgec::train::data::DatasetSpec;
use fedgec::train::gradgen::{GradGen, GradGenConfig};

fn run(autotune: bool) -> Vec<(usize, f64, f64)> {
    let metas = ModelArch::MicroResNet.layers(10);
    let cfg = FedgecConfig {
        error_bound: ErrorBound::Rel(3e-2),
        autotune,
        ..Default::default()
    };
    let mut client = FedgecCodec::new(cfg.clone());
    let mut server = FedgecCodec::new(cfg);
    let mut out = Vec::new();
    // Phase 1: clean CIFAR-like statistics; phase 2: Caltech-like chaos.
    let phases =
        [(DatasetSpec::Cifar10, 8usize), (DatasetSpec::Caltech101, 8), (DatasetSpec::Cifar10, 8)];
    let mut round = 0usize;
    for (spec, rounds) in phases {
        let mut gen = GradGen::new(metas.clone(), GradGenConfig::for_dataset(spec), 5);
        for _ in 0..rounds {
            let g = gen.next_round();
            let (payload, report) = client.compress_with_report(&g).unwrap();
            server
                .decompress(&payload, &metas.iter().cloned().collect::<Vec<_>>())
                .unwrap();
            let cr = g.byte_size() as f64 / payload.len() as f64;
            // Aggregate mismatch across conv layers (unified report).
            let (mut mm, mut el) = (0usize, 0usize);
            for rep in &report.layers {
                mm += rep.sign_stats.sign_mismatches;
                el += rep.sign_stats.elements_predicted;
            }
            let mismatch = if el > 0 { mm as f64 / el as f64 } else { 0.0 };
            out.push((round, cr, mismatch));
            round += 1;
        }
    }
    assert_eq!(client.state.fingerprint(), server.state.fingerprint());
    out
}

fn main() {
    println!("Auto-tuning demo: statistics shift at rounds 8 and 16 (cifar -> caltech -> cifar)\n");
    let fixed = run(false);
    let tuned = run(true);
    let mut table = Table::new(
        "fixed (tau=0.5, beta=0.9) vs auto-tuned",
        &["round", "CR fixed", "CR tuned", "mismatch fixed", "mismatch tuned"],
    );
    for ((r, cf, mf), (_, ct, mt)) in fixed.iter().zip(&tuned) {
        table.row(vec![
            r.to_string(),
            format!("{cf:.2}"),
            format!("{ct:.2}"),
            format!("{:.1}%", mf * 100.0),
            format!("{:.1}%", mt * 100.0),
        ]);
    }
    table.print();
    let mean = |v: &[(usize, f64, f64)]| {
        v.iter().map(|x| x.1).sum::<f64>() / v.len() as f64
    };
    println!(
        "mean CR: fixed {:.2} vs tuned {:.2} (client/server stayed synchronized — \n\
         tau is client-local, beta derives deterministically from reconstructed history)",
        mean(&fixed),
        mean(&tuned)
    );
}
