//! End-to-end FL driver — the full three-layer system on a real workload:
//! a micro-CNN (JAX → HLO → PJRT, real gradients — or the native trainer
//! when no artifacts are built) trained by federated averaging over
//! synthetic CIFAR-10-shaped clients under **partial participation**
//! (half the fleet per round by default), with every upload compressed
//! by FedGEC, logging the loss curve, accuracy, compression ratio, the
//! server state-store occupancy trajectory, and the simulated
//! communication time vs the uncompressed baseline at 10 Mbps.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example fl_e2e
//! # knobs: FEDGEC_ROUNDS, FEDGEC_CODEC, FEDGEC_EB, FEDGEC_ENGINE=hlo,
//! #        FEDGEC_MODEL, FEDGEC_CLIENTS, FEDGEC_PARTICIPATION,
//! #        FEDGEC_STORE_BUDGET_MB, FEDGEC_DOWN, FEDGEC_DOWN_EB,
//! #        FEDGEC_AGG=binsum, FEDGEC_THREADED=1, FEDGEC_SHARDS=4,
//! #        FEDGEC_TIER=edge:8, FEDGEC_JOURNAL=path.jsonl,
//! #        FEDGEC_EBC=plateau
//! ```
//!
//! Emits `results/BENCH_fl_e2e_state_memory.json` — the per-round
//! state-memory trajectory — `results/BENCH_fl_e2e_downlink.json` —
//! the per-round up/down byte and comm-time split — and
//! `results/BENCH_fl_e2e_agg.json` — the server decode/aggregation CPU
//! and binsum-vs-exact route counts — all captured by the CI
//! bench-smoke job. Set `FEDGEC_DOWN=fedgec` to compress the broadcast
//! as a global-model delta (encode-once fan-out); set
//! `FEDGEC_AGG=binsum` (with a state-free abs-eb codec spec) for
//! compressed-domain aggregation that dequantizes once per round.
//!
//! Every run also streams the telemetry **round journal** (JSONL,
//! DESIGN.md §14) next to the panels — `results/fl_e2e_journal
//! <suffix>.jsonl`, path overridable via `FEDGEC_JOURNAL` — and then
//! folds it back with [`fedgec::telemetry::journal::fold_journal`],
//! asserting the folded per-round totals equal the runner's own
//! `RoundStats` **exactly**. `FEDGEC_THREADED=1`, `FEDGEC_SHARDS=N`, or
//! `FEDGEC_TIER=edge:K` switch the run to the threaded in-proc fleet
//! (full participation, native trainer) so the sharded and hierarchical
//! merge paths get the same exactness check in CI.
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use fedgec::config::{EngineKind, RunConfig};
use fedgec::coordinator::{print_summary, run_local, run_threaded};
use fedgec::fl::transport::bandwidth::LinkSpec;
use fedgec::telemetry::journal;
use fedgec::train::data::DatasetSpec;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// `FEDGEC_PANEL_SUFFIX` appended to every emitted panel name, so CI
/// runs with different configs (e.g. `agg=binsum`) land in their own
/// `BENCH_fl_e2e_*<suffix>.json` files instead of overwriting each
/// other's.
fn panel(name: &str) -> String {
    let suffix: String = env_or("FEDGEC_PANEL_SUFFIX", String::new());
    format!("{name}{suffix}")
}

fn main() -> fedgec::Result<()> {
    let rounds: usize = env_or("FEDGEC_ROUNDS", 20);
    let codec: String = env_or("FEDGEC_CODEC", "fedgec".to_string());
    let eb: f64 = env_or("FEDGEC_EB", 3e-2);
    let engine = match std::env::var("FEDGEC_ENGINE").as_deref() {
        Ok("hlo") => EngineKind::Hlo,
        _ => EngineKind::Native,
    };
    // Threaded topology knobs: any of them selects the in-proc fleet
    // (run_threaded), which requires the native trainer and the full
    // fleet participating every round.
    let shards: usize = env_or("FEDGEC_SHARDS", 1);
    let tier: String = env_or("FEDGEC_TIER", "flat".to_string());
    let threaded = env_or("FEDGEC_THREADED", 0usize) == 1 || shards > 1 || tier != "flat";
    // HLO artifacts are a build step; fall back to the native trainer
    // when they are absent (e.g. the CI bench-smoke job).
    let have_artifacts =
        fedgec::runtime::Runtime::default_dir().join("manifest.json").exists();
    let default_model =
        if have_artifacts && !threaded { "micro_resnet" } else { "native" };
    let model: String = env_or("FEDGEC_MODEL", default_model.to_string());
    let cfg = RunConfig {
        model: model.clone(),
        dataset: DatasetSpec::Cifar10,
        n_clients: env_or("FEDGEC_CLIENTS", 8),
        rounds,
        local_lr: 0.05,
        server_lr: 0.05, // == local_lr ⇒ exact FedAvg (see config.rs)
        codec: codec.clone(),
        rel_error_bound: eb,
        engine,
        eval_every: 5,
        seed: 42,
        class_skew: 0.5,
        // Partial participation: half the clients train per round; the
        // rest keep their mirror state parked in the server's store.
        // (Threaded mode drives every connected channel — full fleet.)
        participation: env_or("FEDGEC_PARTICIPATION", if threaded { 1.0 } else { 0.5 }),
        shards,
        tier: tier.clone(),
        store_budget_mb: env_or("FEDGEC_STORE_BUDGET_MB", 0.0),
        // Downlink broadcast codec: `raw` keeps the f32 fan-out,
        // `fedgec` streams the global delta (tight bound — the delta
        // lands in every client's model).
        down: env_or("FEDGEC_DOWN", "raw".to_string()),
        down_eb: env_or("FEDGEC_DOWN_EB", 1e-3),
        // Aggregation route: `exact` decodes everything to f32;
        // `binsum` aggregates eligible layers in the integer-code
        // domain and dequantizes once per round.
        agg: env_or("FEDGEC_AGG", "exact".to_string()),
        // Error-bound controller (DESIGN.md §15): `fixed` keeps eb
        // static; `plateau`/`schedule:*`/`layerwise` let the server
        // retune the bound each round and broadcast it as an EbPlan.
        ebc: env_or("FEDGEC_EBC", "fixed".to_string()),
        // Asymmetric access link: broadcasts ride a faster downlink.
        link: LinkSpec::asym_mbps(10.0, 40.0),
        ..Default::default()
    };
    println!(
        "FL E2E: {} on synthetic CIFAR-10, {} clients x {} rounds ({}% participating), \
         codec={} eb={} engine={:?}",
        cfg.model,
        cfg.n_clients,
        cfg.rounds,
        (cfg.participation * 100.0) as u32,
        cfg.codec,
        eb,
        engine
    );
    if model != "native" {
        println!(
            "(gradients are REAL: JAX train_epoch lowered to HLO, executed via PJRT from Rust)"
        );
    }
    if threaded {
        println!("(threaded in-proc fleet: shards={shards}, tier={tier})");
    }
    println!();

    // Round journal: attach for the run, then fold it back and check it
    // against the runner's own RoundStats — the telemetry subsystem's
    // end-to-end exactness contract.
    let journal_path = match std::env::var("FEDGEC_JOURNAL") {
        Ok(p) => std::path::PathBuf::from(p),
        Err(_) => {
            fedgec::metrics::results_dir().join(format!("{}.jsonl", panel("fl_e2e_journal")))
        }
    };
    if let Some(dir) = journal_path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    journal::attach(&journal_path)?;
    let summary = if threaded { run_threaded(&cfg) } else { run_local(&cfg) };
    journal::detach(); // flush even when the run failed
    let summary = summary?;
    print_summary(&cfg, &summary);

    let folded = journal::fold_journal(&std::fs::read_to_string(&journal_path)?)?;
    anyhow::ensure!(
        folded.len() == summary.rounds.len(),
        "journal folded {} rounds, runner reported {}",
        folded.len(),
        summary.rounds.len()
    );
    for (f, r) in folded.iter().zip(&summary.rounds) {
        anyhow::ensure!(
            &f.folded == r,
            "journal fold diverges from RoundStats at round {}:\nfolded   {:?}\nreported {:?}",
            f.round,
            f.folded,
            r
        );
        let rep = f.reported.as_ref().ok_or_else(|| {
            anyhow::anyhow!("journal round {} has no round_end record", f.round)
        })?;
        anyhow::ensure!(rep == r, "round_end record diverges at round {}", f.round);
    }
    println!(
        "journal: {} rounds folded from {} match RoundStats exactly\n",
        folded.len(),
        journal_path.display()
    );

    // State-memory trajectory: how many mirror states the server store
    // holds (and their bytes) as partial participation churns through
    // the fleet — saved as a BENCH_*.json artifact for CI.
    let mut mem = fedgec::metrics::Table::new(
        "server state-store occupancy per round (partial participation)",
        &["round", "participants", "resyncs", "store clients", "store KB", "CR"],
    );
    for r in &summary.rounds {
        mem.row(vec![
            r.round.to_string(),
            r.participants.to_string(),
            r.resyncs.to_string(),
            r.store_clients.to_string(),
            format!("{:.1}", r.store_bytes as f64 / 1e3),
            format!("{:.2}", r.ratio()),
        ]);
    }
    mem.print();
    mem.save_json(&panel("fl_e2e_state_memory"))?;
    let peak = summary.rounds.iter().map(|r| r.store_bytes).max().unwrap_or(0);
    println!(
        "peak store occupancy {:.1} KB across {} clients (budget: {})",
        peak as f64 / 1e3,
        cfg.n_clients,
        if cfg.store_budget_mb > 0.0 {
            format!("{} MB", cfg.store_budget_mb)
        } else {
            "unbounded".into()
        }
    );

    // Downlink panel: per-round up/down bytes and the comm-time split
    // (Eq. 1 over both directions) — saved as a BENCH_*.json artifact.
    let mut dl = fedgec::metrics::Table::new(
        &format!(
            "downlink broadcast (down={}, {:.0} Mbps down / {:.0} Mbps up)",
            cfg.down,
            cfg.link.down_bits_per_sec / 1e6,
            cfg.link.bits_per_sec / 1e6
        ),
        &[
            "round", "up KB", "up raw KB", "down KB", "down raw KB", "down CR", "full syncs",
            "comp", "tx up", "decomp", "down codec", "tx down",
        ],
    );
    for r in &summary.rounds {
        dl.row(vec![
            r.round.to_string(),
            format!("{:.1}", r.payload_bytes as f64 / 1e3),
            format!("{:.1}", r.raw_bytes as f64 / 1e3),
            format!("{:.1}", r.downlink_bytes as f64 / 1e3),
            format!("{:.1}", r.downlink_raw_bytes as f64 / 1e3),
            format!("{:.2}", r.down_ratio()),
            r.full_syncs.to_string(),
            fedgec::metrics::fmt_duration(r.comp_time),
            fedgec::metrics::fmt_duration(r.transmit_time),
            fedgec::metrics::fmt_duration(r.decomp_time),
            fedgec::metrics::fmt_duration(r.down_codec_time),
            fedgec::metrics::fmt_duration(r.down_transmit_time),
        ]);
    }
    dl.print();
    dl.save_json(&panel("fl_e2e_downlink"))?;

    // Aggregation panel: server decode CPU per round plus the
    // binsum/exact route split — the `agg=binsum` headline numbers,
    // saved as a BENCH_*.json artifact.
    let mut ag = fedgec::metrics::Table::new(
        &format!("server aggregation (agg={})", cfg.agg),
        &["round", "decode ms", "agg ms", "binsum layers", "exact layers", "dequant passes"],
    );
    for r in &summary.rounds {
        ag.row(vec![
            r.round.to_string(),
            format!("{:.2}", r.server_decode_time.as_secs_f64() * 1e3),
            format!("{:.2}", r.agg_time.as_secs_f64() * 1e3),
            r.binsum_layers.to_string(),
            r.exact_layers.to_string(),
            r.dequant_passes.to_string(),
        ]);
    }
    ag.print();
    ag.save_json(&panel("fl_e2e_agg"))?;

    // Error-bound controller panel: the per-round bound the controller
    // broadcast (journal `eb_plan` records, DESIGN.md §15). Saved
    // without the suffix helper — the CI step already isolates this
    // run via FEDGEC_PANEL_SUFFIX, and the gate keys on the fixed name.
    if cfg.ebc != "fixed" {
        let mut ebt = fedgec::metrics::Table::new(
            &format!("error-bound controller (ebc={})", cfg.ebc),
            &["round", "eb", "up KB", "loss"],
        );
        for r in &summary.rounds {
            ebt.row(vec![
                r.round.to_string(),
                r.round_eb.map(|e| format!("{e:.3e}")).unwrap_or_else(|| "-".into()),
                format!("{:.1}", r.payload_bytes as f64 / 1e3),
                format!("{:.4}", r.mean_loss),
            ]);
        }
        ebt.print();
        ebt.save_json("fl_e2e_ebc")?;
        let planned = summary.rounds.iter().filter(|r| r.round_eb.is_some()).count();
        anyhow::ensure!(planned > 0, "ebc={} emitted no eb plans", cfg.ebc);
    }
    println!(
        "server decode CPU {} | aggregation CPU {} (agg={})",
        fedgec::metrics::fmt_duration(summary.total_server_decode_time()),
        fedgec::metrics::fmt_duration(summary.total_agg_time()),
        cfg.agg
    );

    // Communication-time comparison vs uncompressed at the same link —
    // both directions (Eq. 1: the broadcast pull + the update push).
    let uncompressed: std::time::Duration =
        summary.rounds.iter().map(|r| r.uncompressed_time(&cfg.link)).sum();
    let ours = summary.total_comm_time();
    println!(
        "\nround-trip at {:.0}/{:.0} Mbps: uncompressed {} vs {} with codec={} down={} (−{:.1}%)",
        cfg.link.bits_per_sec / 1e6,
        cfg.link.down_bits_per_sec / 1e6,
        fedgec::metrics::fmt_duration(uncompressed),
        fedgec::metrics::fmt_duration(ours),
        cfg.codec,
        cfg.down,
        100.0 * (1.0 - ours.as_secs_f64() / uncompressed.as_secs_f64())
    );
    // Loss curve for EXPERIMENTS.md.
    let curve: Vec<String> =
        summary.loss_curve().iter().map(|l| format!("{l:.4}")).collect();
    println!("loss curve: [{}]", curve.join(", "));
    Ok(())
}
