//! End-to-end FL driver — the full three-layer system on a real workload:
//! a micro-CNN (JAX → HLO → PJRT, real gradients) trained by federated
//! averaging over synthetic CIFAR-10-shaped clients, with every upload
//! compressed by FedGEC, logging the loss curve, accuracy, compression
//! ratio, and the simulated communication time vs the uncompressed and
//! SZ3 baselines at 10 Mbps.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example fl_e2e
//! # knobs: FEDGEC_ROUNDS, FEDGEC_CODEC, FEDGEC_EB, FEDGEC_ENGINE=hlo
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use fedgec::config::{EngineKind, RunConfig};
use fedgec::coordinator::{print_summary, run_local};
use fedgec::fl::transport::bandwidth::LinkSpec;
use fedgec::train::data::DatasetSpec;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> fedgec::Result<()> {
    let rounds: usize = env_or("FEDGEC_ROUNDS", 20);
    let codec: String = env_or("FEDGEC_CODEC", "fedgec".to_string());
    let eb: f64 = env_or("FEDGEC_EB", 3e-2);
    let engine = match std::env::var("FEDGEC_ENGINE").as_deref() {
        Ok("hlo") => EngineKind::Hlo,
        _ => EngineKind::Native,
    };
    let cfg = RunConfig {
        model: "micro_resnet".into(),
        dataset: DatasetSpec::Cifar10,
        n_clients: 4,
        rounds,
        local_lr: 0.05,
        server_lr: 0.05, // == local_lr ⇒ exact FedAvg (see config.rs)
        codec: codec.clone(),
        rel_error_bound: eb,
        link: LinkSpec::mbps(10.0),
        engine,
        eval_every: 5,
        seed: 42,
        class_skew: 0.5,
        ..Default::default()
    };
    println!(
        "FL E2E: micro_resnet on synthetic CIFAR-10, {} clients x {} rounds, codec={} eb={} engine={:?}",
        cfg.n_clients, cfg.rounds, cfg.codec, eb, engine
    );
    println!("(gradients are REAL: JAX train_epoch lowered to HLO, executed via PJRT from Rust)\n");
    let summary = run_local(&cfg)?;
    print_summary(&cfg, &summary);

    // Communication-time comparison vs uncompressed at the same link.
    let total_raw = summary.total_raw();
    let uncompressed = cfg.link.transmit_time(total_raw);
    let ours = summary.total_comm_time();
    println!(
        "\nuplink 10 Mbps: uncompressed transfer {} vs {} with {} (−{:.1}%)",
        fedgec::metrics::fmt_duration(uncompressed),
        fedgec::metrics::fmt_duration(ours),
        cfg.codec,
        100.0 * (1.0 - ours.as_secs_f64() / uncompressed.as_secs_f64())
    );
    // Loss curve for EXPERIMENTS.md.
    let curve: Vec<String> =
        summary.loss_curve().iter().map(|l| format!("{l:.4}")).collect();
    println!("loss curve: [{}]", curve.join(", "));
    Ok(())
}
