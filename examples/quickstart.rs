//! Quickstart: compress one round of gradients with FedGEC and the
//! baselines, print compression ratios and verify the error bound.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use fedgec::compress::spec::{CodecSpec, SpecDefaults};
use fedgec::metrics::Table;
use fedgec::tensor::model_zoo::ModelArch;
use fedgec::train::gradgen::{GradGen, GradGenConfig};
use fedgec::util::stats;

fn main() -> fedgec::Result<()> {
    // ResNet-18-shaped gradient stream (true architecture shapes; values
    // synthesized with the paper's documented statistics — DESIGN.md §5).
    let metas = ModelArch::ResNet18.layers(10);
    let eb = 3e-2; // the paper's sweet-spot REL bound (§5.3)
    println!(
        "Compressing 3 rounds of ResNet-18 gradients ({:.1} MB/round) at REL eb = {eb}\n",
        metas.iter().map(|m| m.numel).sum::<usize>() as f64 * 4.0 / 1e6
    );

    let mut table = Table::new(
        "Quickstart: compression ratio at REL 3e-2",
        &["codec", "CR", "compress MB/s", "max |err| / range"],
    );
    for name in ["fedgec", "sz3", "qsgd", "topk"] {
        let mut gen = GradGen::new(metas.clone(), GradGenConfig::default(), 1);
        let spec = CodecSpec::parse_with(name, &SpecDefaults::with_rel_eb(eb))?;
        println!("  {name} -> spec '{spec}'");
        let mut client = spec.build();
        let mut server = spec.build();
        let (mut raw, mut comp) = (0usize, 0usize);
        let mut worst_rel_err = 0.0f64;
        let mut secs = 0.0f64;
        for _ in 0..3 {
            let grads = gen.next_round();
            raw += grads.byte_size();
            let t0 = std::time::Instant::now();
            let payload = client.compress(&grads)?;
            secs += t0.elapsed().as_secs_f64();
            comp += payload.len();
            let recon = server.decompress(&payload, &metas)?;
            for (r, g) in recon.layers.iter().zip(&grads.layers) {
                let (lo, hi) = stats::finite_min_max(&g.data);
                let range = (hi - lo).max(f32::MIN_POSITIVE) as f64;
                for (a, b) in r.data.iter().zip(&g.data) {
                    worst_rel_err = worst_rel_err.max((a - b).abs() as f64 / range);
                }
            }
        }
        table.row(vec![
            name.to_string(),
            format!("{:.2}", raw as f64 / comp as f64),
            format!("{:.0}", raw as f64 / 1e6 / secs),
            format!("{:.4}", worst_rel_err),
        ]);
    }
    table.print();
    println!(
        "fedgec & sz3 are error-bounded: max relative error ≤ {eb}.\n\
         qsgd/topk have no per-element bound (see §7.1 of the paper)."
    );
    Ok(())
}
