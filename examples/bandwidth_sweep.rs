//! Bandwidth sweep (the paper's Fig. 11 scenario as a runnable example):
//! fix the error bound at 3e-2 and sweep the uplink from 1 Mbps to
//! 1 Gbps, reporting end-to-end communication time per codec and the
//! break-even bandwidth where compression stops paying.
//!
//! ```bash
//! cargo run --release --offline --example bandwidth_sweep
//! ```

use std::time::Duration;

use fedgec::compress::spec::{CodecSpec, SpecDefaults};
use fedgec::fl::transport::bandwidth::LinkSpec;
use fedgec::metrics::{fmt_duration, Table};
use fedgec::tensor::model_zoo::ModelArch;
use fedgec::train::gradgen::{GradGen, GradGenConfig};

fn main() -> fedgec::Result<()> {
    let metas = ModelArch::ResNet18.layers(10);
    let eb = 3e-2;
    let rounds = 3;
    println!("Bandwidth sweep: ResNet-18 gradients, REL eb = {eb}, {rounds} rounds/point\n");

    // Measure codec cost + payload once per codec (bandwidth-independent).
    struct CodecCost {
        name: &'static str,
        payload: usize,
        raw: usize,
        codec_time: Duration,
    }
    let mut costs = Vec::new();
    for name in ["fedgec", "sz3"] {
        let mut gen = GradGen::new(metas.clone(), GradGenConfig::default(), 7);
        let spec = CodecSpec::parse_with(name, &SpecDefaults::with_rel_eb(eb))?;
        let mut client = spec.build();
        let mut server = spec.build();
        let (mut payload, mut raw) = (0usize, 0usize);
        let mut codec_time = Duration::ZERO;
        for _ in 0..rounds {
            let g = gen.next_round();
            raw += g.byte_size();
            let t0 = std::time::Instant::now();
            let p = client.compress(&g)?;
            let mid = std::time::Instant::now();
            server.decompress(&p, &metas)?;
            codec_time += mid - t0 + mid.elapsed();
            payload += p.len();
        }
        costs.push(CodecCost { name, payload, raw, codec_time });
    }

    let mbps_points = [1.0, 5.0, 10.0, 50.0, 100.0, 500.0, 1000.0];
    let mut table = Table::new(
        "End-to-end communication time vs bandwidth (3 rounds)",
        &["bandwidth", "uncompressed", "fedgec", "sz3", "fedgec gain"],
    );
    for &mbps in &mbps_points {
        let link = LinkSpec::sym(mbps * 1e6, Duration::ZERO);
        let unc = link.transmit_time(costs[0].raw);
        let times: Vec<Duration> =
            costs.iter().map(|c| c.codec_time + link.transmit_time(c.payload)).collect();
        table.row(vec![
            format!("{mbps:.0} Mbps"),
            fmt_duration(unc),
            fmt_duration(times[0]),
            fmt_duration(times[1]),
            format!("{:+.1}%", 100.0 * (1.0 - times[0].as_secs_f64() / unc.as_secs_f64())),
        ]);
    }
    table.print();

    // Break-even: bandwidth where codec overhead equals transfer savings
    // (paper: stars around ~620 Mbps for eb=3e-2).
    let c = &costs[0];
    let saved_bytes = (c.raw - c.payload) as f64 * 8.0;
    let breakeven = saved_bytes / c.codec_time.as_secs_f64() / 1e6;
    println!("fedgec break-even bandwidth ≈ {breakeven:.0} Mbps (compression pays below this)");

    // ── Round-trip sweep over an asymmetric link (down = 4x up, the
    // typical access-network shape): the broadcast pull now counts too.
    // The downlink ships the global-model delta, encoded once on the
    // server and fanned out to every client. ──
    let fan_out = 8usize;
    let (raw_down, delta_bytes, enc_time) = fedgec::train::gradgen::measure_downlink_delta(
        &metas,
        GradGenConfig::default(),
        11,
        1e-3,
        fan_out,
        rounds,
    )?;
    let up = &costs[0]; // fedgec uplink measured above
    let mut rt = Table::new(
        &format!(
            "Round trip on an asymmetric link (down = 4x up, {rounds} rounds, \
             downlink delta CR {:.2})",
            (raw_down * rounds) as f64 / delta_bytes as f64
        ),
        &["up bandwidth", "raw both ways", "up-only compressed", "both compressed"],
    );
    for &mbps in &mbps_points {
        let link = LinkSpec::asym_mbps(mbps, 4.0 * mbps);
        let raw_rt = link.transmit_time(up.raw) + link.downlink_time(raw_down * rounds);
        let up_only =
            up.codec_time + link.transmit_time(up.payload) + link.downlink_time(raw_down * rounds);
        let both = up.codec_time
            + link.transmit_time(up.payload)
            + link.downlink_time(delta_bytes)
            + enc_time / fan_out as u32; // encode once, amortized over the fan-out
        rt.row(vec![
            format!("{mbps:.0} Mbps"),
            fmt_duration(raw_rt),
            fmt_duration(up_only),
            fmt_duration(both),
        ]);
    }
    rt.print();
    Ok(())
}
