//! Downlink broadcast compression: end-to-end acceptance tests.
//!
//! * Bit-consistency invariant: after N rounds with `down=fedgec(...)`
//!   every persistent client's reconstructed model is **bit-identical**
//!   to the server's tracked reference, and a client that cold-joins at
//!   round k via `FullSync` converges to the same bytes.
//! * Compression: on the model-zoo CNN at eb=1e-3 the warm delta
//!   broadcast shrinks ≥ 2x vs the raw f32 broadcast.
//! * Fig. 9-style envelope: training through the lossy broadcast tracks
//!   the raw-broadcast loss trajectory.
//! * The wire protocol path (threaded runtime + TCP-style channels)
//!   carries delta/full-sync rounds end to end.

use fedgec::compress::downlink::{DownlinkCodec, DownlinkMirror};
use fedgec::compress::spec::{CodecSpec, SpecDefaults};
use fedgec::config::RunConfig;
use fedgec::coordinator::{run_local, run_threaded};
use fedgec::fl::transport::bandwidth::LinkSpec;
use fedgec::tensor::model_zoo::ModelArch;
use fedgec::tensor::LayerMeta;
use fedgec::train::data::DatasetSpec;
use fedgec::train::gradgen::{GradGen, GradGenConfig};

fn down_spec(eb: f64) -> CodecSpec {
    CodecSpec::parse_with("fedgec", &SpecDefaults::with_rel_eb(eb)).unwrap()
}

fn bits_eq(a: &[Vec<f32>], b: &[Vec<f32>]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.len() == y.len() && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
        })
}

/// A synthetic training trajectory: the global model walks one
/// aggregated-SGD step per round over structured gradients, so the
/// broadcast delta has the cross-round smoothness the predictor exploits.
struct Trajectory {
    params: Vec<Vec<f32>>,
    gen: GradGen,
}

impl Trajectory {
    fn new(metas: &[LayerMeta], seed: u64) -> Self {
        let mut rng = fedgec::util::rng::Rng::new(seed);
        let params = metas
            .iter()
            .map(|m| (0..m.numel).map(|_| rng.normal_f32(0.0, 0.3)).collect())
            .collect();
        let gen = GradGen::new(
            metas.to_vec(),
            GradGenConfig::for_dataset(DatasetSpec::Cifar10),
            seed,
        );
        Trajectory { params, gen }
    }

    fn step(&mut self) {
        self.gen.sgd_step(&mut self.params, 0.05);
    }
}

/// Deliver one encoded round to a mirror exactly as the wire protocol
/// would: `FullSync` for cold participants, the shared delta otherwise.
fn deliver(
    down: &DownlinkCodec,
    bc: &fedgec::compress::downlink::RoundBroadcast,
    id: u32,
    mirror: &mut DownlinkMirror,
) {
    if bc.cold.contains(&id) {
        mirror.full_sync(down.reference().unwrap().to_vec()).unwrap();
    } else {
        let d = bc.delta.as_ref().expect("warm participant needs a delta");
        mirror.apply_delta(d.reset, &d.frames).unwrap();
    }
}

#[test]
fn bit_identity_over_rounds_with_cold_join_and_dropout() {
    let metas = ModelArch::MicroResNet.layers(10);
    let spec = down_spec(1e-3);
    let mut traj = Trajectory::new(&metas, 7);
    let mut down = DownlinkCodec::new(&spec, metas.clone());
    let mut a = DownlinkMirror::new(&spec, metas.clone()); // persistent
    let mut b = DownlinkMirror::new(&spec, metas.clone()); // persistent
    let mut c = DownlinkMirror::new(&spec, metas.clone()); // joins at round 4
    let mut d = DownlinkMirror::new(&spec, metas.clone()); // drops round 6, rejoins 8
    let mut delta_rounds = 0;
    for round in 0..10usize {
        let mut ids: Vec<u32> = vec![0, 1];
        if round >= 4 {
            ids.push(2);
        }
        if round != 6 && round != 7 {
            ids.push(3);
        }
        let bc = down.encode_round(&traj.params, &ids).unwrap();
        if bc.delta.is_some() {
            delta_rounds += 1;
        }
        // Deliver per participant (mirrors indexed by id).
        for &id in &ids {
            let mirror = match id {
                0 => &mut a,
                1 => &mut b,
                2 => &mut c,
                _ => &mut d,
            };
            deliver(&down, &bc, id, mirror);
        }
        // Every participant is bit-identical to the server's reference.
        let reference = down.reference().unwrap();
        for &id in &ids {
            let mirror = match id {
                0 => &a,
                1 => &b,
                2 => &c,
                _ => &d,
            };
            assert!(
                bits_eq(mirror.params().unwrap(), reference),
                "round {round}: client {id} diverged from the reference"
            );
        }
        traj.step();
    }
    // The stream really ran compressed deltas (not full-sync every round).
    assert!(delta_rounds >= 8, "only {delta_rounds} delta rounds");
}

#[test]
fn warm_delta_broadcast_shrinks_2x_on_model_zoo_cnn() {
    // Acceptance: downlink bytes shrink >= 2x vs the raw broadcast on
    // the model-zoo CNN at eb=1e-3 once the stream is warm.
    let metas = ModelArch::MicroInception.layers(10);
    let raw_bytes: usize = metas.iter().map(|m| m.numel * 4).sum();
    let spec = down_spec(1e-3);
    let mut traj = Trajectory::new(&metas, 3);
    let mut down = DownlinkCodec::new(&spec, metas.clone());
    let ids: Vec<u32> = (0..4).collect();
    let rounds = 12usize;
    let (delta_bytes, _) = fedgec::compress::downlink::measure_delta_stream(
        &mut down,
        &mut traj.params,
        &ids,
        rounds,
        |p| traj.gen.sgd_step(p, 0.05),
    )
    .unwrap();
    let cr = (raw_bytes * rounds) as f64 / delta_bytes as f64;
    assert!(cr >= 2.0, "downlink delta CR {cr:.2} < 2x at eb=1e-3");
    // Including the one-time full-sync bootstrap, the whole run still
    // beats raw broadcasting comfortably.
    let total_cr = (raw_bytes * (rounds + 1)) as f64 / (raw_bytes + delta_bytes) as f64;
    assert!(total_cr > 1.5, "total downlink CR {total_cr:.2} with bootstrap");
}

fn base_cfg() -> RunConfig {
    RunConfig {
        model: "native".into(),
        dataset: DatasetSpec::Cifar10,
        n_clients: 3,
        rounds: 6,
        samples_per_client: 64,
        local_lr: 0.2,
        server_lr: 0.2,
        codec: "fedgec".into(),
        rel_error_bound: 1e-2,
        link: LinkSpec::infinite(),
        eval_every: 0,
        seed: 11,
        class_skew: 0.3,
        ..Default::default()
    }
}

#[test]
fn lossy_broadcast_tracks_raw_broadcast_training() {
    // Fig. 9-style envelope: training through the compressed downlink
    // must track the raw-broadcast loss trajectory.
    let mut cfg = base_cfg();
    let clean = run_local(&cfg).unwrap();
    cfg.down = "fedgec".into();
    cfg.down_eb = 1e-3;
    let lossy = run_local(&cfg).unwrap();
    let lc = clean.loss_curve();
    let ld = lossy.loss_curve();
    let final_gap = (lc.last().unwrap() - ld.last().unwrap()).abs();
    assert!(final_gap < 0.35, "loss gap {final_gap}: raw {lc:?} vs lossy-down {ld:?}");
    // Byte accounting: round 0 bootstraps every client, later rounds
    // stream deltas; both directions are recorded.
    assert_eq!(lossy.rounds[0].full_syncs, 3);
    assert!(lossy.rounds.iter().skip(1).all(|r| r.full_syncs == 0));
    assert!(lossy.rounds.iter().all(|r| r.downlink_bytes > 0));
    assert!(lossy.rounds.iter().skip(1).all(|r| r.downlink_bytes < r.downlink_raw_bytes));
    // The raw-broadcast run accounts the downlink too (at CR 1).
    assert!(clean.rounds.iter().all(|r| r.downlink_bytes == r.downlink_raw_bytes));
    assert!(clean.rounds[0].downlink_raw_bytes > 0);
}

#[test]
fn partial_participation_triggers_full_sync_churn() {
    // Clients that miss a broadcast fall off the delta stream and
    // re-bootstrap on rejoin — the run must stay correct and converge.
    let mut cfg = base_cfg();
    cfg.n_clients = 8;
    cfg.rounds = 8;
    cfg.participation = 0.5;
    cfg.down = "fedgec".into();
    let summary = run_local(&cfg).unwrap();
    let total_syncs: usize = summary.rounds.iter().map(|r| r.full_syncs).sum();
    assert!(total_syncs > summary.rounds[0].participants, "churn should re-bootstrap");
    let losses = summary.loss_curve();
    assert!(losses.last().unwrap() < losses.first().unwrap(), "{losses:?}");
}

#[test]
fn threaded_runtime_runs_compressed_downlink() {
    // The wire-protocol path: DeltaBegin/DeltaFrame/FullSync over live
    // channels, encode-once fan-out on the server.
    let mut cfg = base_cfg();
    cfg.rounds = 4;
    cfg.n_clients = 4;
    cfg.down = "fedgec".into();
    cfg.down_eb = 1e-3;
    let summary = run_threaded(&cfg).expect("threaded downlink run");
    assert_eq!(summary.rounds.len(), 4);
    // Round 0 bootstraps everyone; the stable fleet then streams deltas
    // with no further bootstraps and no stream resets.
    assert_eq!(summary.rounds[0].full_syncs, 4);
    for r in summary.rounds.iter().skip(1) {
        assert_eq!(r.full_syncs, 0, "round {}", r.round);
        assert!(r.downlink_bytes > 0);
        assert!(
            r.downlink_bytes < r.downlink_raw_bytes,
            "round {}: delta broadcast should beat raw ({} vs {})",
            r.round,
            r.downlink_bytes,
            r.downlink_raw_bytes
        );
    }
    assert!(summary.final_accuracy.is_some());
    let losses = summary.loss_curve();
    assert!(losses.last().unwrap() < losses.first().unwrap(), "{losses:?}");
}

#[test]
fn run_local_reference_equals_client_decode() {
    // The simulation hands every participant the server's tracked
    // reference; verify against an independently decoding mirror that
    // the reference IS what a wire client would reconstruct.
    let metas = ModelArch::MicroResNet.layers(10);
    let spec = down_spec(1e-3);
    let mut traj = Trajectory::new(&metas, 13);
    let mut down = DownlinkCodec::new(&spec, metas.clone());
    let mut wire_client = DownlinkMirror::new(&spec, metas.clone());
    for _ in 0..6 {
        let bc = down.encode_round(&traj.params, &[0]).unwrap();
        deliver(&down, &bc, 0, &mut wire_client);
        assert!(bits_eq(wire_client.params().unwrap(), down.reference().unwrap()));
        // The reference stays within a tight envelope of the true model
        // (drift-free: the error does not accumulate across rounds).
        for (p, r) in traj.params.iter().zip(down.reference().unwrap()) {
            for (x, y) in p.iter().zip(r) {
                assert!((x - y).abs() < 0.05, "reference drifted: {x} vs {y}");
            }
        }
        traj.step();
    }
}
