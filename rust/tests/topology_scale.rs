//! Topology-tier acceptance tests: the sharded round runner and the
//! edge-aggregator tier must (a) survive a simulated million-client
//! round in bounded memory and bounded wall-clock, and (b) produce the
//! same aggregate as the flat single-thread loop — bit-identical for
//! binsum-routed layers (i64 bin sums are exact and order-independent),
//! within 1e-5 relative for dense f64 merges.
//!
//! Scale knob: `FEDGEC_SCALE_CLIENTS` overrides the fleet size (CI's
//! release `topology_scale` job sets 1_000_000); the in-tree defaults
//! keep debug `cargo test` quick.

use std::time::Instant;

use fedgec::compress::pipeline::{FedgecCodec, FedgecConfig, FedgecEngine};
use fedgec::compress::predictor::magnitude::MagnitudeSel;
use fedgec::compress::predictor::sign::SignSel;
use fedgec::compress::predictor::PredictorSpec;
use fedgec::compress::quant::ErrorBound;
use fedgec::compress::store::ShardedMemStore;
use fedgec::compress::GradientCodec;
use fedgec::fl::aggregate::AggMode;
use fedgec::fl::client::{Client, LocalTrainer};
use fedgec::fl::protocol::Msg;
use fedgec::fl::server::Server;
use fedgec::fl::topology::edge::{run_round_root, EdgeAggregator};
use fedgec::fl::topology::sharded::ShardedRunner;
use fedgec::fl::topology::synth::SynthFleet;
use fedgec::fl::transport::{inproc, Channel};
use fedgec::tensor::{LayerGrad, LayerMeta, ModelGrad};
use fedgec::util::rng::Rng;

const SHARDS: usize = 8;

fn scale_clients() -> usize {
    if let Ok(v) = std::env::var("FEDGEC_SCALE_CLIENTS") {
        return v.parse().expect("FEDGEC_SCALE_CLIENTS must be an integer");
    }
    if cfg!(debug_assertions) {
        5_000
    } else {
        50_000
    }
}

fn metas() -> Vec<LayerMeta> {
    // One bin-routed layer (numel > t_lossy = 1024) plus a small dense
    // one, so every test exercises both merge paths.
    vec![LayerMeta::dense("fc", 2048, 1), LayerMeta::other("bias", 32)]
}

/// State-free spec: fresh codec per round is the same codec, payloads
/// are replayable across clients, and bounded values under an absolute
/// bound stay escape-free (the precondition for binsum bit-identity).
fn state_free_cfg() -> FedgecConfig {
    FedgecConfig {
        error_bound: ErrorBound::Abs(5e-3),
        predictor: PredictorSpec { mag: MagnitudeSel::Zero, sign: SignSel::None },
        ..Default::default()
    }
}

fn server(metas: &[LayerMeta], mode: AggMode) -> Server {
    let params: Vec<Vec<f32>> = metas.iter().map(|m| vec![0.01; m.numel]).collect();
    Server::with_engine(
        params,
        metas.to_vec(),
        0.1,
        Box::new(FedgecEngine::new(state_free_cfg())),
    )
    .with_agg_mode(mode)
}

fn engines(n: usize) -> Vec<Box<dyn fedgec::compress::engine::CodecEngine>> {
    (0..n)
        .map(|_| {
            Box::new(FedgecEngine::new(state_free_cfg()))
                as Box<dyn fedgec::compress::engine::CodecEngine>
        })
        .collect()
}

/// Per-layer twin-path comparison: the bin-routed `fc` layer must match
/// **bitwise** (exact integer sums), the dense `bias` layer within 1e-5
/// relative (f64 reassociation).
fn assert_twin(flat: &[Vec<f32>], sharded: &[Vec<f32>], ctx: &str) {
    assert_eq!(flat.len(), sharded.len());
    assert_eq!(flat[0], sharded[0], "{ctx}: binsum fc layer must be bit-identical");
    for (i, (a, b)) in flat[1].iter().zip(&sharded[1]).enumerate() {
        assert!(
            (a - b).abs() <= 1e-5 * a.abs().max(1e-3),
            "{ctx}: bias[{i}] {a} vs {b}"
        );
    }
}

#[test]
fn bounded_memory_scale_round() {
    let t0 = Instant::now();
    let n = scale_clients();
    let metas = metas();
    let fleet = SynthFleet::new(&state_free_cfg(), &metas, n, 64, 11).unwrap();
    let mut srv = server(&metas, AggMode::Binsum);
    srv.admit_all();
    let init = srv.params.clone();
    let raw_model_bytes = srv.raw_model_bytes();
    let mut runner = ShardedRunner::new(&srv, engines(SHARDS)).unwrap();
    for round in 0..2 {
        let stats = runner
            .run_round_direct(&mut srv, |shard| fleet.shard_iter(SHARDS, shard))
            .unwrap();
        assert_eq!(stats.participants, n, "round {round}");
        assert_eq!(stats.dropped, 0, "round {round}");
        assert_eq!(stats.shards, SHARDS);
        assert!((stats.mean_loss - 0.25).abs() < 1e-9, "round {round}");
        // Aggregate memory is O(shards × model), never O(clients).
        assert!(
            runner.last_agg_resident_bytes <= SHARDS * 10 * raw_model_bytes,
            "round {round}: {} B of partial aggregates",
            runner.last_agg_resident_bytes
        );
    }
    // The stateless engine never touches the store: per-client server
    // memory is exactly zero.
    assert_eq!(srv.store_stats().resident_clients, 0);
    assert!(srv.params.iter().flatten().zip(init.iter().flatten()).any(|(a, b)| a != b));
    // Wall-clock guard: decode cost must stay linear in clients. The
    // budget is deliberately loose (CI machines vary) but rules out
    // anything superlinear at the million-client point.
    let per_client = if cfg!(debug_assertions) { 4e-3 } else { 0.4e-3 };
    let budget = 30.0 + n as f64 * per_client;
    let took = t0.elapsed().as_secs_f64();
    assert!(took < budget, "2 rounds × {n} clients took {took:.1}s (budget {budget:.0}s)");
}

#[test]
fn sharded_direct_matches_flat_binsum_bitwise() {
    let n: usize = 2000;
    let metas = metas();
    let fleet = SynthFleet::new(&state_free_cfg(), &metas, n, 16, 23).unwrap();
    // Mixed integral weights and a deterministic dropout pattern,
    // applied identically on both paths.
    let weight = |id: u32| (1 + id % 5) as f64;
    let dropout = |id: u32| id % 17 == 3;

    let mut flat = server(&metas, AggMode::Binsum);
    flat.admit_all();
    let mut agg = flat.new_round_agg();
    for id in 0..n as u32 {
        if dropout(id) {
            continue;
        }
        let c = fleet.contribution(id);
        flat.absorb_payload(id, &c.payload, weight(id), &mut agg).unwrap();
    }
    flat.finish_round(agg);

    let mut sharded = server(&metas, AggMode::Binsum);
    sharded.admit_all();
    let mut runner = ShardedRunner::new(&sharded, engines(SHARDS)).unwrap();
    let stats = runner
        .run_round_direct(&mut sharded, |shard| {
            fleet.shard_iter(SHARDS, shard).filter(|c| !dropout(c.client)).map(|mut c| {
                c.weight = weight(c.client);
                c
            })
        })
        .unwrap();
    assert_eq!(stats.dropped, 0);
    assert_eq!(stats.participants, (0..n as u32).filter(|&id| !dropout(id)).count());
    assert_twin(&flat.params, &sharded.params, "binsum twin");
}

#[test]
fn sharded_direct_matches_flat_exact_mode() {
    let n: usize = 600;
    let metas = metas();
    let fleet = SynthFleet::new(&state_free_cfg(), &metas, n, 8, 31).unwrap();
    // Non-integral weights: the exact route sums f64, so both layers
    // compare within the reassociation envelope.
    let weight = |id: u32| 0.5 + (id % 7) as f64 * 0.25;

    let mut flat = server(&metas, AggMode::Exact);
    flat.admit_all();
    let mut agg = flat.new_round_agg();
    for id in 0..n as u32 {
        let c = fleet.contribution(id);
        flat.absorb_payload(id, &c.payload, weight(id), &mut agg).unwrap();
    }
    flat.finish_round(agg);

    let mut sharded = server(&metas, AggMode::Exact);
    sharded.admit_all();
    let mut runner = ShardedRunner::new(&sharded, engines(5)).unwrap();
    let stats = runner
        .run_round_direct(&mut sharded, |shard| {
            fleet.shard_iter(5, shard).map(|mut c| {
                c.weight = weight(c.client);
                c
            })
        })
        .unwrap();
    assert_eq!(stats.dropped, 0);
    for (li, (fa, sh)) in flat.params.iter().zip(&sharded.params).enumerate() {
        for (i, (a, b)) in fa.iter().zip(sh).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5 * a.abs().max(1e-3),
                "exact twin: layer {li}[{i}] {a} vs {b}"
            );
        }
    }
}

/// Deterministic params-independent trainer: the gradient stream
/// depends only on (seed, round), so flat and edge-tier runs see
/// byte-identical uplinks regardless of tiny param drift.
struct ReplayTrainer {
    metas: Vec<LayerMeta>,
    seed: u64,
    round: u64,
}

impl LocalTrainer for ReplayTrainer {
    fn train_round(&mut self, _params: &[Vec<f32>]) -> fedgec::Result<(ModelGrad, f32)> {
        let mut rng = Rng::new(self.seed ^ self.round.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        self.round += 1;
        let grads = ModelGrad {
            layers: self
                .metas
                .iter()
                .map(|m| {
                    let data: Vec<f32> =
                        (0..m.numel).map(|_| rng.normal_f32(0.0, 0.1)).collect();
                    LayerGrad::new(m.clone(), data)
                })
                .collect(),
        };
        Ok((grads, 0.5))
    }

    fn layer_metas(&self) -> Vec<LayerMeta> {
        self.metas.clone()
    }

    fn n_samples(&self) -> usize {
        8
    }
}

/// Spawn `n` protocol-complete client threads (mixed monolithic and
/// frame-streamed uploads); returns their server-side channel ends and
/// join handles.
fn spawn_replay_clients(
    n: u32,
    metas: &[LayerMeta],
) -> (Vec<Box<dyn Channel>>, Vec<std::thread::JoinHandle<fedgec::Result<()>>>) {
    let mut chans: Vec<Box<dyn Channel>> = Vec::new();
    let mut handles = Vec::new();
    for id in 0..n {
        let (srv_end, cli_end) = inproc::pair(None);
        chans.push(Box::new(srv_end));
        let trainer = ReplayTrainer { metas: metas.to_vec(), seed: 1000 + id as u64, round: 0 };
        let mut client = Client::new(
            id,
            Box::new(trainer),
            Box::new(FedgecCodec::new(state_free_cfg())),
        )
        .with_streaming(id % 2 == 0);
        handles.push(std::thread::spawn(move || {
            let mut ch = cli_end;
            client.run(&mut ch)
        }));
    }
    (chans, handles)
}

#[test]
fn edge_tier_matches_flat_run() {
    const N: u32 = 12;
    const FANOUT: usize = 4;
    const ROUNDS: usize = 3;
    let metas = metas();

    // Flat reference run.
    let (mut flat_chans, flat_handles) = spawn_replay_clients(N, &metas);
    let mut flat = server(&metas, AggMode::Binsum);
    flat.wait_hellos(&mut flat_chans).unwrap();
    for _ in 0..ROUNDS {
        let stats = flat.run_round(&mut flat_chans).unwrap();
        assert_eq!(stats.dropped, 0);
    }
    flat.shutdown(&mut flat_chans).unwrap();
    for h in flat_handles {
        h.join().unwrap().unwrap();
    }

    // Edge-tier run over identical clients: 12 clients / fanout 4 ⇒ 3
    // edge aggregators, each forwarding one merged AggPush per round.
    let (mut client_chans, edge_client_handles) = spawn_replay_clients(N, &metas);
    let mut edge_chans: Vec<Box<dyn Channel>> = Vec::new();
    let mut edge_handles = Vec::new();
    let mut idx = 0u32;
    while !client_chans.is_empty() {
        let take = FANOUT.min(client_chans.len());
        let mut subtree: Vec<Box<dyn Channel>> = client_chans.drain(..take).collect();
        let (root_end, edge_end) = inproc::pair(None);
        edge_chans.push(Box::new(root_end));
        let mut edge = EdgeAggregator::new(
            idx,
            Box::new(FedgecEngine::new(state_free_cfg())),
            Box::new(ShardedMemStore::new(4, None)),
            metas.clone(),
            AggMode::Binsum,
        );
        edge_handles.push(std::thread::spawn(move || {
            let mut up: Box<dyn Channel> = Box::new(edge_end);
            edge.run(up.as_mut(), &mut subtree)
        }));
        idx += 1;
    }
    let mut root = server(&metas, AggMode::Binsum);
    root.wait_hellos(&mut edge_chans).unwrap();
    for round in 0..ROUNDS {
        let stats = run_round_root(&mut root, &mut edge_chans).unwrap();
        assert_eq!(stats.participants, N as usize, "round {round}");
        assert_eq!(stats.dropped, 0, "round {round}");
        assert_eq!(stats.shards, 3, "round {round}");
        assert!((stats.mean_loss - 0.5).abs() < 1e-9, "round {round}");
        assert_eq!(stats.resyncs, 0, "state-free fleet never resyncs");
    }
    root.shutdown(&mut edge_chans).unwrap();
    for h in edge_handles {
        h.join().unwrap().unwrap();
    }
    for h in edge_client_handles {
        h.join().unwrap().unwrap();
    }

    assert_twin(&flat.params, &root.params, "edge twin");
}

#[test]
fn sharded_channels_drop_dead_clients_per_round() {
    let metas = metas();
    let cfg = state_free_cfg();
    // Six manual-protocol clients over live channels; client 4 hangs up
    // after the first broadcast.
    let mut chans: Vec<Box<dyn Channel>> = Vec::new();
    let mut handles = Vec::new();
    for id in 0..6u32 {
        let (srv_end, mut c) = inproc::pair(None);
        chans.push(Box::new(srv_end));
        let cfg = cfg.clone();
        let metas = metas.clone();
        handles.push(std::thread::spawn(move || {
            c.send(&Msg::Hello { client_id: id }).unwrap();
            for round in 0..2u32 {
                match c.recv().unwrap() {
                    Msg::GlobalParams { .. } => {}
                    other => panic!("client {id}: unexpected {other:?}"),
                }
                if id == 4 {
                    return;
                }
                c.send(&Msg::StateCheck { client_id: id, rounds: 0, fingerprint: 0 })
                    .unwrap();
                match c.recv().unwrap() {
                    Msg::StateResync { .. } => {}
                    other => panic!("client {id}: unexpected {other:?}"),
                }
                let mut rng = Rng::new(77 + (id + 10 * round) as u64);
                let grads = ModelGrad {
                    layers: metas
                        .iter()
                        .map(|m| {
                            let data: Vec<f32> =
                                (0..m.numel).map(|_| rng.normal_f32(0.0, 0.1)).collect();
                            LayerGrad::new(m.clone(), data)
                        })
                        .collect(),
                };
                let payload = FedgecCodec::new(cfg.clone()).compress(&grads).unwrap();
                c.send(&Msg::Update {
                    client_id: id,
                    round,
                    payload,
                    train_loss: 0.5,
                    n_samples: 8,
                })
                .unwrap();
            }
            loop {
                match c.recv() {
                    Ok(Msg::Shutdown) | Err(_) => return,
                    Ok(_) => {}
                }
            }
        }));
    }
    let mut srv = server(&metas, AggMode::Binsum);
    srv.wait_hellos(&mut chans).unwrap();
    let mut runner = ShardedRunner::new(&srv, engines(3)).unwrap();
    for round in 0..2 {
        let stats = runner.run_round(&mut srv, &mut chans).unwrap();
        assert_eq!(stats.participants, 6, "round {round}");
        assert_eq!(stats.dropped, 1, "round {round}: the hung-up client");
        assert_eq!(stats.shards, 3, "round {round}");
        assert!((stats.mean_loss - 0.5).abs() < 1e-9, "round {round}");
    }
    srv.shutdown(&mut chans).unwrap();
    for h in handles {
        h.join().unwrap();
    }
    // Broadcast fan-out sharing survives the shard split: every
    // contribution was decodable (5 served per round ⇒ params moved).
    assert!(srv.params.iter().flatten().any(|&p| p != 0.01));
}
