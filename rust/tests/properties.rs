//! Cross-module property tests (via the util::prop mini-harness):
//! codec-level invariants over randomized gradient tensors, bounds,
//! layer mixes, and adversarial payload corruption.

use fedgec::compress::frame::Frame;
use fedgec::compress::kernels;
use fedgec::compress::pipeline::{FedgecCodec, FedgecConfig};
use fedgec::compress::predictor::magnitude::MagnitudeSel;
use fedgec::compress::predictor::sign::SignSel;
use fedgec::compress::quant::ErrorBound;
use fedgec::compress::session::{DecodeSession, EncodeSession};
use fedgec::compress::spec::{CodecSpec, SpecDefaults};
use fedgec::compress::GradientCodec;
use fedgec::tensor::{LayerGrad, LayerMeta, ModelGrad};
use fedgec::util::prop;
use fedgec::util::rng::Rng;
use fedgec::util::stats;

/// Build a random model-update with a mix of conv/dense/bias layers.
fn arb_model(rng: &mut Rng) -> ModelGrad {
    let n_layers = 1 + rng.next_below(4);
    let mut layers = Vec::new();
    for li in 0..n_layers {
        match rng.next_below(3) {
            0 => {
                let t = [1usize, 4, 9, 25][rng.next_below(4)];
                let k = 4 + rng.next_below(300);
                let (kh, kw) = match t {
                    1 => (1, 1),
                    4 => (2, 2),
                    9 => (3, 3),
                    _ => (5, 5),
                };
                let data = prop::arb_gradient(rng, k * t);
                layers.push(LayerGrad::new(
                    LayerMeta::conv(&format!("conv{li}"), k, 1, kh, kw),
                    data,
                ));
            }
            1 => {
                let n = 8 + rng.next_below(4000);
                let data = prop::arb_gradient(rng, n);
                layers.push(LayerGrad::new(LayerMeta::dense(&format!("fc{li}"), n, 1), data));
            }
            _ => {
                let n = 1 + rng.next_below(64);
                let data = prop::arb_gradient(rng, n);
                layers.push(LayerGrad::new(LayerMeta::other(&format!("b{li}"), n), data));
            }
        }
    }
    ModelGrad { layers }
}

fn metas(g: &ModelGrad) -> Vec<LayerMeta> {
    g.layers.iter().map(|l| l.meta.clone()).collect()
}

#[test]
fn prop_fedgec_error_bound_holds_over_rounds() {
    prop::check("fedgec bound over rounds", 40, |rng| {
        let eb = prop::arb_error_bound(rng);
        let cfg = FedgecConfig {
            error_bound: ErrorBound::Rel(eb),
            full_batch: rng.chance(0.3),
            tau: rng.uniform(0.2, 0.9),
            beta: rng.uniform(0.3, 0.99) as f32,
            ..Default::default()
        };
        let mut client = FedgecCodec::new(cfg.clone());
        let mut server = FedgecCodec::new(cfg);
        let base = arb_model(rng);
        let ms = metas(&base);
        for round in 0..3 {
            // Evolve the tensors a bit each round (temporal correlation).
            let mut g = base.clone();
            for l in &mut g.layers {
                for v in &mut l.data {
                    *v *= 1.0 + 0.1 * rng.gauss() as f32 * round as f32;
                }
            }
            let payload = client.compress(&g).map_err(|e| e.to_string())?;
            let recon = server.decompress(&payload, &ms).map_err(|e| e.to_string())?;
            for (r, o) in recon.layers.iter().zip(&g.layers) {
                let (lo, hi) = stats::finite_min_max(&o.data);
                let delta = ErrorBound::Rel(eb).resolve(lo, hi) as f32;
                for (a, b) in r.data.iter().zip(&o.data) {
                    if b.is_finite() && (a - b).abs() > delta * 1.001 {
                        return Err(format!(
                            "round {round} layer {}: |{a}-{b}| > {delta}",
                            o.meta.name
                        ));
                    }
                    if !b.is_finite() && a.to_bits() != b.to_bits() {
                        return Err("non-finite not preserved".into());
                    }
                }
            }
            if client.state.fingerprint() != server.state.fingerprint() {
                return Err(format!("state divergence at round {round}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_all_codecs_total_on_random_input() {
    // No codec may panic or corrupt shapes on arbitrary (finite or not)
    // input.
    prop::check("codecs total", 30, |rng| {
        let g = arb_model(rng);
        let ms = metas(&g);
        for name in ["fedgec", "sz3", "qsgd", "topk", "none"] {
            let eb = prop::arb_error_bound(rng);
            let mut codec = CodecSpec::parse_with(name, &SpecDefaults::with_rel_eb(eb))
                .map_err(|e| e.to_string())?
                .build();
            let payload = codec.compress(&g).map_err(|e| format!("{name}: {e}"))?;
            let recon = codec.decompress(&payload, &ms).map_err(|e| format!("{name}: {e}"))?;
            if recon.numel() != g.numel() {
                return Err(format!("{name}: numel changed"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_every_registry_spec_roundtrips_through_frames() {
    // Drive every registered CodecSpec family through the per-layer frame
    // API (encode session -> wire -> decode session) on randomized
    // multi-layer models: EBLC codecs must respect their bound, raw must
    // reconstruct exactly, and every codec must preserve shapes.
    prop::check("registry frame roundtrip", 25, |rng| {
        let eb = prop::arb_error_bound(rng);
        let d = SpecDefaults::with_rel_eb(eb);
        let base = arb_model(rng);
        let ms = metas(&base);
        for spec in CodecSpec::registry_specs(&d) {
            let mut client = spec.build();
            let mut server = spec.build();
            for round in 0..2 {
                // Evolve tensors across rounds (stateful codecs need it).
                let mut g = base.clone();
                for l in &mut g.layers {
                    for v in &mut l.data {
                        *v *= 1.0 + 0.05 * round as f32;
                    }
                }
                let mut enc = EncodeSession::new(client.as_mut(), g.layers.len())
                    .map_err(|e| format!("{spec}: {e}"))?;
                let mut dec = DecodeSession::new(server.as_mut(), g.layers.len())
                    .map_err(|e| format!("{spec}: {e}"))?;
                for (layer, meta) in g.layers.iter().zip(&ms) {
                    let frame = enc.encode_layer(layer).map_err(|e| format!("{spec}: {e}"))?;
                    // Frames survive the wire form (self-delimiting).
                    let frame = Frame::from_wire(&frame.to_wire())
                        .map_err(|e| format!("{spec}: {e}"))?;
                    let back =
                        dec.decode_frame(&frame, meta).map_err(|e| format!("{spec}: {e}"))?;
                    if back.data.len() != layer.data.len() {
                        return Err(format!("{spec}: layer {} shape", meta.name));
                    }
                    if spec == CodecSpec::Raw {
                        for (a, b) in back.data.iter().zip(&layer.data) {
                            if a.to_bits() != b.to_bits() {
                                return Err(format!("raw not exact: {a} vs {b}"));
                            }
                        }
                    } else if spec.error_bounded() {
                        let (lo, hi) = stats::finite_min_max(&layer.data);
                        let delta = ErrorBound::Rel(eb).resolve(lo, hi) as f32;
                        for (a, b) in back.data.iter().zip(&layer.data) {
                            if b.is_finite() && (a - b).abs() > delta * 1.001 {
                                return Err(format!(
                                    "{spec} layer {}: |{a}-{b}| > {delta}",
                                    meta.name
                                ));
                            }
                        }
                    }
                }
                let creport = enc.finish().map_err(|e| e.to_string())?;
                let sreport = dec.finish().map_err(|e| e.to_string())?;
                // Unified reports agree layer-by-layer on both sides of
                // the pipe (byte accounting is part of the codec contract).
                if creport.layers.len() != g.layers.len() {
                    return Err(format!("{spec}: report layer count"));
                }
                for (cl, sl) in creport.layers.iter().zip(&sreport.layers) {
                    if cl.raw_bytes != sl.raw_bytes
                        || cl.compressed_bytes != sl.compressed_bytes
                        || cl.side_info_bytes != sl.side_info_bytes
                        || cl.entropy_bytes != sl.entropy_bytes
                    {
                        return Err(format!(
                            "{spec} layer {}: encode report {:?}/{:?}/{:?}/{:?} \
                             != decode {:?}/{:?}/{:?}/{:?}",
                            cl.name,
                            cl.raw_bytes,
                            cl.compressed_bytes,
                            cl.side_info_bytes,
                            cl.entropy_bytes,
                            sl.raw_bytes,
                            sl.compressed_bytes,
                            sl.side_info_bytes,
                            sl.entropy_bytes
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_scalar_and_fast_kernels_produce_identical_frames_registry_wide() {
    // The twin-pair contract end to end: for every registered codec
    // family, the payload bytes produced under the bounds-checked
    // scalar kernels are byte-identical to the default fast-kernel
    // path, and both decodes reconstruct bit-identical tensors. (In a
    // `--features scalar-kernels` build both sides run scalar, so the
    // identity is tautological there; the default CI build is where
    // this property bites.)
    prop::check("scalar == fast frames", 12, |rng| {
        let eb = prop::arb_error_bound(rng);
        let d = SpecDefaults::with_rel_eb(eb);
        let base = arb_model(rng);
        let ms = metas(&base);
        for spec in CodecSpec::registry_specs(&d) {
            let mut c_fast = spec.build();
            let mut c_scalar = spec.build();
            let mut s_fast = spec.build();
            let mut s_scalar = spec.build();
            for round in 0..2 {
                let mut g = base.clone();
                for l in &mut g.layers {
                    for v in &mut l.data {
                        *v *= 1.0 + 0.05 * round as f32;
                    }
                }
                let p_fast = c_fast.compress(&g).map_err(|e| format!("{spec}: {e}"))?;
                let p_scalar = kernels::with_scalar_kernels(|| c_scalar.compress(&g))
                    .map_err(|e| format!("{spec}: {e}"))?;
                if p_fast != p_scalar {
                    return Err(format!("{spec} round {round}: payload bytes differ"));
                }
                let r_fast =
                    s_fast.decompress(&p_fast, &ms).map_err(|e| format!("{spec}: {e}"))?;
                let r_scalar = kernels::with_scalar_kernels(|| s_scalar.decompress(&p_fast, &ms))
                    .map_err(|e| format!("{spec}: {e}"))?;
                for (a, b) in r_fast.layers.iter().zip(&r_scalar.layers) {
                    for (x, y) in a.data.iter().zip(&b.data) {
                        if x.to_bits() != y.to_bits() {
                            return Err(format!(
                                "{spec} round {round}: decode drift {x} vs {y}"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pred_sign_grammar_roundtrips_registry_wide() {
    // Every CodecSpec carrying pred=/sign= keys — the full selector grid
    // crossed with the entropy coders, plus randomized β/τ/eb — must
    // survive parse → Display → parse exactly, and so must every spec
    // the registry enumerates.
    prop::check("pred/sign grammar roundtrip", 20, |rng| {
        let eb = prop::arb_error_bound(rng);
        let beta = rng.uniform(0.05, 0.99);
        let tau = rng.uniform(0.1, 0.95);
        for pred in MagnitudeSel::ALL {
            for sign in SignSel::ALL {
                for ec in ["huff", "rans"] {
                    let text = format!(
                        "fedgec:eb=rel{eb},beta={beta},tau={tau},pred={},sign={},ec={ec}",
                        pred.name(),
                        sign.name()
                    );
                    let spec = CodecSpec::parse(&text).map_err(|e| format!("{text}: {e}"))?;
                    let back = CodecSpec::parse(&spec.to_string())
                        .map_err(|e| format!("reparse {spec}: {e}"))?;
                    if back != spec {
                        return Err(format!("'{text}' -> '{spec}' -> '{back}'"));
                    }
                    match &spec {
                        CodecSpec::Fedgec { pred: p, sign: s, .. } => {
                            if *p != pred || *s != sign {
                                return Err(format!("{text}: selector lost"));
                            }
                        }
                        other => return Err(format!("{other}: wrong family")),
                    }
                }
            }
        }
        for spec in CodecSpec::registry_specs(&SpecDefaults::with_rel_eb(eb)) {
            let back = CodecSpec::parse(&spec.to_string()).map_err(|e| e.to_string())?;
            if back != spec {
                return Err(format!("registry spec '{spec}' did not roundtrip"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_frame_pred_tag_matches_decode_registry_wide() {
    // Self-describing frames: for every magnitude selector, the
    // predictor tag the encoder stamps on each lossy frame is exactly
    // the tag the decoder reports back — and for the fixed selectors it
    // is the selector itself. Randomized multi-layer models, two rounds
    // (cold + warm) per case.
    prop::check("frame pred-tag agreement", 15, |rng| {
        let eb = prop::arb_error_bound(rng);
        let d = SpecDefaults::with_rel_eb(eb);
        let base = arb_model(rng);
        let ms = metas(&base);
        for pred in MagnitudeSel::ALL {
            let spec = CodecSpec::parse_with(&format!("fedgec:pred={}", pred.name()), &d)
                .map_err(|e| e.to_string())?;
            let mut client = spec.build();
            let mut server = spec.build();
            for round in 0..2 {
                let mut g = base.clone();
                for l in &mut g.layers {
                    for v in &mut l.data {
                        *v *= 1.0 + 0.05 * round as f32;
                    }
                }
                let (payload, cr) =
                    client.compress_with_report(&g).map_err(|e| format!("{spec}: {e}"))?;
                let (_, sr) = server
                    .decompress_with_report(&payload, &ms)
                    .map_err(|e| format!("{spec}: {e}"))?;
                for (cl, sl) in cr.layers.iter().zip(&sr.layers) {
                    if cl.pred_tag != sl.pred_tag {
                        return Err(format!(
                            "{spec} layer {}: encode tag '{}' != decode tag '{}'",
                            cl.name, cl.pred_tag, sl.pred_tag
                        ));
                    }
                    if !cl.lossy {
                        if !cl.pred_tag.is_empty() {
                            return Err(format!("{spec}: lossless layer carries a tag"));
                        }
                        continue;
                    }
                    match pred {
                        MagnitudeSel::Ema | MagnitudeSel::Last | MagnitudeSel::Zero => {
                            if cl.pred_tag != pred.name() {
                                return Err(format!(
                                    "{spec} layer {}: tag '{}' != selector",
                                    cl.name, cl.pred_tag
                                ));
                            }
                        }
                        MagnitudeSel::Auto => {
                            if MagnitudeSel::from_name(&cl.pred_tag).is_none() {
                                return Err(format!(
                                    "{spec}: race winner '{}' unknown",
                                    cl.pred_tag
                                ));
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rans_and_huffman_specs_decode_identically() {
    // Registry-wide rANS↔Huffman agreement: for every entropy-coded
    // family, the `ec=rans` twin must reconstruct bit-identically to the
    // `ec=huff` twin — the entropy stage is lossless, so any divergence
    // is a coder bug. Adversarial shapes (constant layers → single-symbol
    // streams, huge outliers → escape-heavy streams) ride in through
    // arb_model/arb_gradient.
    prop::check("rans/huff spec agreement", 25, |rng| {
        let eb = prop::arb_error_bound(rng);
        let d = SpecDefaults::with_rel_eb(eb);
        let base = arb_model(rng);
        let ms = metas(&base);
        for family in ["fedgec", "sz3"] {
            let mut c_h = CodecSpec::parse_with(&format!("{family}:ec=huff"), &d)
                .map_err(|e| e.to_string())?
                .build();
            let mut c_r = CodecSpec::parse_with(&format!("{family}:ec=rans"), &d)
                .map_err(|e| e.to_string())?
                .build();
            let mut s_h = CodecSpec::parse_with(&format!("{family}:ec=huff"), &d)
                .map_err(|e| e.to_string())?
                .build();
            let mut s_r = CodecSpec::parse_with(&format!("{family}:ec=rans"), &d)
                .map_err(|e| e.to_string())?
                .build();
            for round in 0..2 {
                let mut g = base.clone();
                for l in &mut g.layers {
                    for v in &mut l.data {
                        *v *= 1.0 + 0.05 * round as f32;
                    }
                }
                let (ph, rep_h) =
                    c_h.compress_with_report(&g).map_err(|e| format!("{family} huff: {e}"))?;
                let (pr, rep_r) =
                    c_r.compress_with_report(&g).map_err(|e| format!("{family} rans: {e}"))?;
                let rh = s_h.decompress(&ph, &ms).map_err(|e| format!("{family} huff: {e}"))?;
                let rr = s_r.decompress(&pr, &ms).map_err(|e| format!("{family} rans: {e}"))?;
                for (a, b) in rh.layers.iter().zip(&rr.layers) {
                    for (x, y) in a.data.iter().zip(&b.data) {
                        if x.to_bits() != y.to_bits() {
                            return Err(format!(
                                "{family} round {round} layer {}: {x} != {y}",
                                a.meta.name
                            ));
                        }
                    }
                }
                // The size-checked rANS selector never loses a byte to
                // Huffman at the entropy stage, on any layer.
                for (h, r) in rep_h.layers.iter().zip(&rep_r.layers) {
                    if r.entropy_bytes > h.entropy_bytes {
                        return Err(format!(
                            "{family} layer {}: rans {} B > huff {} B",
                            h.name, r.entropy_bytes, h.entropy_bytes
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_corrupted_payloads_never_panic() {
    prop::check("corruption safety", 40, |rng| {
        let g = arb_model(rng);
        let ms = metas(&g);
        let mut codec = FedgecCodec::new(FedgecConfig::default());
        let mut payload = codec.compress(&g).map_err(|e| e.to_string())?;
        // Flip a few random bytes / truncate.
        match rng.next_below(3) {
            0 => {
                for _ in 0..3 {
                    let i = rng.next_below(payload.len());
                    payload[i] ^= 1 << rng.next_below(8);
                }
            }
            1 => {
                let keep = rng.next_below(payload.len());
                payload.truncate(keep);
            }
            _ => {
                payload.extend_from_slice(&[0xAB; 7]);
            }
        }
        let mut server = FedgecCodec::new(FedgecConfig::default());
        let _ = server.decompress(&payload, &ms); // Err is fine, panic is not
        Ok(())
    });
}

#[test]
fn prop_compression_deterministic() {
    // Same state + same input => identical payload (required for
    // client/server mirroring and for reproducible experiments).
    prop::check("determinism", 20, |rng| {
        let g = arb_model(rng);
        let mut a = FedgecCodec::new(FedgecConfig::default());
        let mut b = FedgecCodec::new(FedgecConfig::default());
        let pa = a.compress(&g).map_err(|e| e.to_string())?;
        let pb = b.compress(&g).map_err(|e| e.to_string())?;
        if pa != pb {
            return Err("nondeterministic payload".into());
        }
        Ok(())
    });
}

#[test]
fn prop_zero_and_constant_layers_roundtrip() {
    prop::check("degenerate layers", 30, |rng| {
        let n = 1025 + rng.next_below(2000);
        let c = rng.normal_f32(0.0, 1.0);
        let g = ModelGrad {
            layers: vec![
                LayerGrad::new(LayerMeta::other("zeros", n), vec![0.0; n]),
                LayerGrad::new(LayerMeta::other("const", n), vec![c; n]),
            ],
        };
        let ms = metas(&g);
        let mut codec = FedgecCodec::new(FedgecConfig::default());
        let payload = codec.compress(&g).map_err(|e| e.to_string())?;
        let recon = codec.decompress(&payload, &ms).map_err(|e| e.to_string())?;
        // Degenerate layers must reconstruct near-exactly and compress well.
        for (r, o) in recon.layers.iter().zip(&g.layers) {
            for (a, b) in r.data.iter().zip(&o.data) {
                if (a - b).abs() > 1e-6 * (1.0 + b.abs()) {
                    return Err(format!("{}: {a} vs {b}", o.meta.name));
                }
            }
        }
        if payload.len() * 10 > g.byte_size() {
            return Err(format!("constant data compressed poorly: {}", payload.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_state_store_evict_reload_roundtrips_exactly() {
    // The satellite invariant of the externalized-state redesign: a
    // LayerState that leaves the hot tier and comes back — through the
    // spill record codec, the disk store's evict→reload path, or the
    // memory store's take→put cycle — must be *fingerprint-identical*,
    // or the client/server mirrors would silently diverge.
    use fedgec::compress::state::{ClientState, StateEpoch};
    use fedgec::compress::store::{
        decode_client_state, encode_client_state, DiskSpillStore, ShardedMemStore, StateStore,
    };

    let dir = std::env::temp_dir().join(format!("fedgec_prop_spill_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    prop::check("state store evict→reload", 30, |rng| {
        // A random warm state: 1..4 layers, each having absorbed 1..3
        // rounds of adversarial gradients (arb_gradient mixes scales,
        // zeros, and non-finite escapes), some with EMA memory.
        let mut cs = ClientState::cold();
        let n_layers = 1 + rng.next_below(4);
        cs.codec.ensure(n_layers);
        for li in 0..n_layers {
            if li > 0 && rng.chance(0.2) {
                continue; // leave some layers cold (never absorbed)
            }
            let n = 8 + prop::arb_len(rng, 1500);
            for _ in 0..1 + rng.next_below(3) {
                let recon: Vec<f32> = prop::arb_gradient(rng, n)
                    .into_iter()
                    .map(|x| if x.is_finite() { x } else { 0.0 })
                    .collect();
                cs.codec.layers[li].absorb(&recon);
            }
            if rng.chance(0.7) {
                cs.codec.layers[li].memory = prop::arb_gradient(rng, n);
            }
        }
        cs.epoch = StateEpoch {
            rounds: 1 + rng.next_below(50) as u32,
            fingerprint: cs.codec.fingerprint(),
        };
        let want = cs.codec.fingerprint();

        // 1. The spill record codec alone.
        let rec = encode_client_state(&cs, Default::default()).map_err(|e| e.to_string())?;
        let back = decode_client_state(&rec).map_err(|e| e.to_string())?;
        if back.codec.fingerprint() != want || back.epoch != cs.epoch {
            return Err("spill record codec not exact".into());
        }

        // 2. Memory backend: take→put cycle.
        let mem = ShardedMemStore::new(2, None);
        mem.put(11, cs.clone()).map_err(|e| e.to_string())?;
        let got = mem.take(11).map_err(|e| e.to_string())?.ok_or("mem take lost state")?;
        if got.codec.fingerprint() != want {
            return Err("mem store round-trip not exact".into());
        }

        // 3. Disk backend: a 1-byte hot budget forces every second put to
        // evict-to-disk; the reload must be exact.
        let disk = DiskSpillStore::new(&dir, 1, 1).map_err(|e| e.to_string())?;
        disk.put(1, cs.clone()).map_err(|e| e.to_string())?;
        disk.put(2, ClientState::cold()).map_err(|e| e.to_string())?; // evicts client 1
        if disk.stats().spilled_clients == 0 {
            return Err("expected a spill".into());
        }
        let got = disk.take(1).map_err(|e| e.to_string())?.ok_or("disk take lost state")?;
        if got.codec.fingerprint() != want || got.epoch != cs.epoch {
            return Err("disk evict→reload not exact".into());
        }
        disk.remove(2).map_err(|e| e.to_string())?;
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn prop_shard_fold_matches_flat_run() {
    use fedgec::fl::round::{RoundStats, ShardStats};
    use std::time::Duration;
    // The telemetry journal replays per-shard records through
    // `fold_into`; the fold is only trustworthy if partitioning a round
    // across any number of shards reproduces the flat single-shard
    // tallies. Integers and Durations must match exactly; only the f64
    // loss sum may differ by summation order.
    prop::check("shard fold == flat fold", 300, |rng| {
        let n_clients = 1 + rng.next_below(64);
        let n_shards = 1 + rng.next_below(8);
        let mut flat = ShardStats::default();
        let mut shards = vec![ShardStats::default(); n_shards];
        for _ in 0..n_clients {
            let mut one = ShardStats::default();
            if rng.chance(0.1) {
                one.dropped = 1;
            } else {
                one.served = 1;
                one.payload_bytes = rng.next_below(1 << 20);
                one.raw_bytes = one.payload_bytes * (1 + rng.next_below(30));
                one.loss_sum = rng.uniform(0.0, 10.0);
                one.decode_time = Duration::from_nanos(rng.next_u64() % 1_000_000_000);
                one.agg_time = Duration::from_nanos(rng.next_u64() % 1_000_000_000);
                if rng.chance(0.2) {
                    one.resyncs = 1;
                }
            }
            flat.absorb(&one);
            shards[rng.next_below(n_shards)].absorb(&one);
        }
        let mut total = ShardStats::default();
        for s in &shards {
            total.absorb(s);
        }
        for (name, a, b) in [
            ("served", total.served, flat.served),
            ("dropped", total.dropped, flat.dropped),
            ("resyncs", total.resyncs, flat.resyncs),
            ("payload_bytes", total.payload_bytes, flat.payload_bytes),
            ("raw_bytes", total.raw_bytes, flat.raw_bytes),
        ] {
            if a != b {
                return Err(format!("{name}: sharded {a} != flat {b}"));
            }
        }
        if total.decode_time != flat.decode_time || total.agg_time != flat.agg_time {
            return Err("Duration tallies diverged across the partition".into());
        }
        let mut from_shards = RoundStats::default();
        total.fold_into(&mut from_shards);
        let mut from_flat = RoundStats::default();
        flat.fold_into(&mut from_flat);
        // mean_loss holds the raw f64 loss sum at this point: tolerate
        // reassociation, nothing more.
        let rel = (from_shards.mean_loss - from_flat.mean_loss).abs()
            / from_flat.mean_loss.abs().max(1e-12);
        if rel > 1e-9 {
            return Err(format!(
                "loss sum: sharded {} vs flat {} (rel {rel:e})",
                from_shards.mean_loss, from_flat.mean_loss
            ));
        }
        from_shards.mean_loss = 0.0;
        from_flat.mean_loss = 0.0;
        if from_shards != from_flat {
            return Err(format!("folded stats diverge:\n{from_shards:?}\nvs\n{from_flat:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_decode_is_eb_agnostic_registry_wide() {
    // Δ is self-described on the wire (DESIGN.md §15): every lossy
    // section carries the delta it was quantized with, so a decoder
    // configured with a *different* error bound — or with a controller
    // plan it never received — reconstructs bit-identically. This is
    // what lets the eb controller retune the bound every round with
    // zero out-of-band config on the decode path.
    prop::check("decode eb-agnostic", 15, |rng| {
        let eb_a = prop::arb_error_bound(rng);
        let eb_b = eb_a * rng.uniform(2.5, 12.0); // deliberately wrong
        let da = SpecDefaults::with_rel_eb(eb_a);
        let db = SpecDefaults::with_rel_eb(eb_b);
        let base = arb_model(rng);
        let ms = metas(&base);
        for (spec_a, spec_b) in
            CodecSpec::registry_specs(&da).into_iter().zip(CodecSpec::registry_specs(&db))
        {
            let mut enc = spec_a.build();
            let mut matched = spec_a.build();
            let mut mismatched = spec_b.build();
            for round in 0..3 {
                let mut g = base.clone();
                for l in &mut g.layers {
                    for v in &mut l.data {
                        *v *= 1.0 + 0.07 * round as f32;
                    }
                }
                let payload = enc.compress(&g).map_err(|e| format!("{spec_a}: {e}"))?;
                let want =
                    matched.decompress(&payload, &ms).map_err(|e| format!("{spec_a}: {e}"))?;
                let got = mismatched
                    .decompress(&payload, &ms)
                    .map_err(|e| format!("{spec_a} at eb {eb_b}: {e}"))?;
                for (li, (a, b)) in want.layers.iter().zip(&got.layers).enumerate() {
                    for (x, y) in a.data.iter().zip(&b.data) {
                        if x.to_bits() != y.to_bits() {
                            return Err(format!(
                                "{spec_a} round {round} layer {li}: decoder configured \
                                 at eb {eb_b} diverged ({x} vs {y}) — eb leaked out of \
                                 band into decode"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_eb_plan_steers_encode_only() {
    // A controller plan applied on the encode side (uniform or
    // per-layer) changes the quantizer — but a decoder that never saw
    // the plan still reconstructs bit-identically to one that did: the
    // plan is encode-side steering plus a mirror fingerprint tag, never
    // part of the decode contract.
    use fedgec::compress::control::EbPlan;
    prop::check("eb plan encode-only", 15, |rng| {
        let eb = prop::arb_error_bound(rng);
        let cfg = FedgecConfig { error_bound: ErrorBound::Rel(eb), ..Default::default() };
        let base = arb_model(rng);
        let ms = metas(&base);
        let mut enc = FedgecCodec::new(cfg.clone());
        let mut planned = FedgecCodec::new(cfg.clone());
        let mut unplanned = FedgecCodec::new(cfg);
        for round in 0..3 {
            let factor = [1.0f32, 0.5, 0.25][round];
            let plan = if rng.chance(0.5) {
                EbPlan::uniform(eb as f32 * factor)
            } else {
                EbPlan {
                    round_eb: eb as f32 * factor,
                    per_layer: Some(
                        (0..ms.len()).map(|i| eb as f32 * factor * (1.0 + i as f32)).collect(),
                    ),
                }
            };
            enc.apply_eb_plan(&plan);
            planned.apply_eb_plan(&plan);
            let mut g = base.clone();
            for l in &mut g.layers {
                for v in &mut l.data {
                    *v *= 1.0 + 0.07 * round as f32;
                }
            }
            let payload = enc.compress(&g).map_err(|e| e.to_string())?;
            let want = planned.decompress(&payload, &ms).map_err(|e| e.to_string())?;
            let got = unplanned.decompress(&payload, &ms).map_err(|e| e.to_string())?;
            for (li, (a, b)) in want.layers.iter().zip(&got.layers).enumerate() {
                for (x, y) in a.data.iter().zip(&b.data) {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!(
                            "round {round} layer {li}: plan-blind decoder diverged \
                             ({x} vs {y})"
                        ));
                    }
                }
            }
            // The fingerprint tag, by contrast, *does* see the plan:
            // that is how eb drift shows up in the state handshake.
            if planned.state.fingerprint() != enc.state.fingerprint() {
                return Err(format!("round {round}: planned mirror fingerprint diverged"));
            }
        }
        Ok(())
    });
}
