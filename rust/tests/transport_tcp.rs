//! Real-TCP FL integration: a server and several client threads speak the
//! full protocol over loopback sockets, with live bandwidth throttling.

use std::net::TcpListener;

use fedgec::compress::spec::{CodecSpec, SpecDefaults};
use fedgec::coordinator::native_trainer::NativeTrainer;
use fedgec::fl::client::Client;
use fedgec::fl::server::Server;
use fedgec::fl::transport::bandwidth::LinkSpec;
use fedgec::fl::transport::tcp::{accept_n, TcpChannel};
use fedgec::fl::transport::Channel;
use fedgec::train::data::{DatasetSpec, SynthDataset};
use fedgec::train::native::NativeNet;
use fedgec::util::rng::Rng;

fn fedgec_codec() -> Box<dyn fedgec::compress::GradientCodec> {
    CodecSpec::parse_with("fedgec", &SpecDefaults::with_rel_eb(1e-2)).unwrap().build()
}

fn fedgec_engine() -> Box<dyn fedgec::compress::CodecEngine> {
    CodecSpec::parse_with("fedgec", &SpecDefaults::with_rel_eb(1e-2)).unwrap().build_engine()
}

fn spawn_client(
    addr: String,
    id: u32,
    link: Option<LinkSpec>,
    stream: bool,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let mut ch = TcpChannel::connect(&addr, link).expect("connect");
        let ds = SynthDataset::new(DatasetSpec::Cifar10, 9);
        let mut rng = Rng::new(100 + id as u64);
        let slice = ds.sample(&mut rng, 48, 0.0);
        let trainer = NativeTrainer::new(10, slice, 0.2, 5);
        let codec = fedgec_codec();
        let mut client = Client::new(id, Box::new(trainer), codec).with_streaming(stream);
        client.run(&mut ch).expect("client loop");
    })
}

#[test]
fn tcp_federation_trains() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let n_clients = 3;
    // Mix streamed and monolithic clients: the server must handle both.
    let handles: Vec<_> =
        (0..n_clients).map(|i| spawn_client(addr.clone(), i as u32, None, i % 2 == 0)).collect();
    let chans = accept_n(&listener, n_clients, None).unwrap();
    let mut channels: Vec<Box<dyn Channel>> =
        chans.into_iter().map(|c| Box::new(c) as _).collect();
    let proto = NativeNet::new(10, 5);
    let init =
        vec![proto.conv_w.clone(), proto.conv_b.clone(), proto.fc_w.clone(), proto.fc_b.clone()];
    let mut server = Server::with_engine(init, proto.layer_metas(), 0.2, fedgec_engine());
    server.wait_hellos(&mut channels).unwrap();
    let mut losses = Vec::new();
    for round in 0..4 {
        let stats = server.run_round(&mut channels).unwrap();
        assert!(stats.ratio() > 1.5, "CR {}", stats.ratio());
        // The handshake never resets in a stable federation, and the
        // store holds exactly one mirror state per client.
        assert_eq!(stats.resyncs, 0, "round {round}");
        assert_eq!(stats.store_clients, n_clients);
        losses.push(stats.mean_loss);
    }
    server.shutdown(&mut channels).unwrap();
    for h in handles {
        h.join().unwrap();
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "tcp training should reduce loss: {losses:?}"
    );
}

#[test]
fn tcp_throttled_link_slows_uploads() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    // Throttle the client's uplink to ~4 Mbps with zero latency.
    let link = LinkSpec::sym(4e6, std::time::Duration::ZERO);
    let handle = spawn_client(addr.clone(), 0, Some(link), true);
    let chans = accept_n(&listener, 1, None).unwrap();
    let mut channels: Vec<Box<dyn Channel>> =
        chans.into_iter().map(|c| Box::new(c) as _).collect();
    let proto = NativeNet::new(10, 5);
    let init =
        vec![proto.conv_w.clone(), proto.conv_b.clone(), proto.fc_w.clone(), proto.fc_b.clone()];
    let mut server = Server::with_engine(init, proto.layer_metas(), 0.2, fedgec_engine());
    server.wait_hellos(&mut channels).unwrap();
    let t0 = std::time::Instant::now();
    let stats = server.run_round(&mut channels).unwrap();
    let elapsed = t0.elapsed();
    server.shutdown(&mut channels).unwrap();
    handle.join().unwrap();
    // payload ~tens of KB at 4 Mbps -> at least payload*8/4e6 seconds.
    let floor = stats.payload_bytes as f64 * 8.0 / 4e6;
    assert!(
        elapsed.as_secs_f64() >= floor * 0.8,
        "elapsed {elapsed:?} vs floor {floor}"
    );
}
