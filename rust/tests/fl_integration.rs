//! End-to-end FL runtime integration (native trainer, no artifacts
//! needed): single-threaded simulation and the threaded in-proc runtime,
//! across codecs — training must converge and compression must not hurt
//! accuracy at a moderate bound.

use fedgec::config::RunConfig;
use fedgec::coordinator::{run_local, run_threaded};
use fedgec::fl::transport::bandwidth::LinkSpec;
use fedgec::train::data::DatasetSpec;

fn base_cfg() -> RunConfig {
    RunConfig {
        model: "native".into(),
        dataset: DatasetSpec::Cifar10,
        n_clients: 3,
        rounds: 6,
        samples_per_client: 64,
        local_lr: 0.2,
        server_lr: 0.2,
        codec: "fedgec".into(),
        rel_error_bound: 1e-2,
        link: LinkSpec::infinite(),
        eval_every: 0,
        seed: 11,
        class_skew: 0.3,
        ..Default::default()
    }
}

#[test]
fn local_sim_converges_with_fedgec() {
    let cfg = base_cfg();
    let summary = run_local(&cfg).expect("run");
    assert_eq!(summary.rounds.len(), cfg.rounds);
    let losses = summary.loss_curve();
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss should drop: {losses:?}"
    );
    assert!(summary.mean_ratio() > 2.0, "CR {}", summary.mean_ratio());
    let acc = summary.final_accuracy.unwrap();
    assert!(acc > 0.15, "acc {acc}");
}

#[test]
fn compression_tracks_uncompressed_training() {
    // At eb=1e-2 the compressed run should match the uncompressed loss
    // trajectory closely (the paper's Fig. 9 claim).
    let mut cfg = base_cfg();
    cfg.codec = "none".into();
    let clean = run_local(&cfg).unwrap();
    cfg.codec = "fedgec".into();
    let ours = run_local(&cfg).unwrap();
    let lc = clean.loss_curve();
    let lo = ours.loss_curve();
    let final_gap = (lc.last().unwrap() - lo.last().unwrap()).abs();
    assert!(final_gap < 0.35, "loss gap {final_gap}: clean {lc:?} vs ours {lo:?}");
}

#[test]
fn all_codecs_run_the_fl_loop() {
    for codec in ["fedgec", "sz3", "qsgd", "topk", "none"] {
        let mut cfg = base_cfg();
        cfg.codec = codec.into();
        cfg.rounds = 3;
        let summary = run_local(&cfg).unwrap_or_else(|e| panic!("{codec}: {e}"));
        assert_eq!(summary.rounds.len(), 3, "{codec}");
        assert!(summary.rounds.iter().all(|r| r.payload_bytes > 0), "{codec}");
    }
}

#[test]
fn threaded_runtime_matches_protocol() {
    let mut cfg = base_cfg();
    cfg.rounds = 3;
    cfg.n_clients = 4;
    let summary = run_threaded(&cfg).expect("threaded run");
    assert_eq!(summary.rounds.len(), 3);
    assert!(summary.mean_ratio() > 1.5);
    assert!(summary.final_accuracy.is_some());
}

#[test]
fn partial_participation_trains_and_tracks_store() {
    let mut cfg = base_cfg();
    cfg.n_clients = 8;
    cfg.rounds = 8;
    cfg.participation = 0.5;
    let summary = run_local(&cfg).expect("partial run");
    assert_eq!(summary.rounds.len(), 8);
    // Participation actually varies below the full fleet.
    assert!(summary.rounds.iter().all(|r| r.participants >= 1 && r.participants <= 8));
    assert!(
        summary.rounds.iter().any(|r| r.participants < 8),
        "participation=0.5 should skip clients some rounds"
    );
    // Store occupancy only grows as new clients first participate, and
    // never exceeds the fleet; no resyncs happen without eviction/churn.
    let mut seen = 0usize;
    for r in &summary.rounds {
        assert!(r.store_clients <= 8);
        assert!(r.store_clients >= seen.min(8));
        seen = seen.max(r.store_clients);
        assert_eq!(r.resyncs, 0);
        assert!(r.store_bytes > 0);
    }
    // Training still converges on the participating subsets.
    let losses = summary.loss_curve();
    assert!(losses.last().unwrap() < losses.first().unwrap(), "{losses:?}");
}

#[test]
fn budgeted_store_evicts_and_recovers_mid_training() {
    // A store budget far below 16 full states: eviction + resync runs
    // inside a real training loop and the run still completes/learns.
    // (One native-model mirror state is ~100 KB — only the 5120-element
    // fc layer is lossy; 0.2 MB across 8 shards keeps roughly one state
    // per shard resident.)
    let mut cfg = base_cfg();
    cfg.n_clients = 16;
    cfg.rounds = 4;
    cfg.samples_per_client = 32;
    cfg.store_budget_mb = 0.2;
    let summary = run_local(&cfg).expect("budgeted run");
    assert_eq!(summary.rounds.len(), 4);
    let total_resyncs: usize = summary.rounds.iter().map(|r| r.resyncs).sum();
    assert!(total_resyncs > 0, "budget should force evictions + resyncs");
    // Far fewer resident states than clients (each of the 8 shards keeps
    // at least one, evicting the rest).
    assert!(
        summary.rounds.iter().all(|r| r.store_clients <= 8),
        "store must stay well under 16 states: {:?}",
        summary.rounds.iter().map(|r| r.store_clients).collect::<Vec<_>>()
    );
    let losses = summary.loss_curve();
    assert!(losses.last().unwrap() < losses.first().unwrap(), "{losses:?}");
}

#[test]
fn virtual_link_accounting_scales_with_bandwidth() {
    // Zero latency so only the bandwidth term is compared.
    let mut slow = base_cfg();
    slow.rounds = 2;
    slow.link = LinkSpec::sym(1e6, std::time::Duration::ZERO);
    let mut fast = slow.clone();
    fast.link = LinkSpec::sym(100e6, std::time::Duration::ZERO);
    let s = run_local(&slow).unwrap();
    let f = run_local(&fast).unwrap();
    let ts = s.rounds.iter().map(|r| r.transmit_time).sum::<std::time::Duration>();
    let tf = f.rounds.iter().map(|r| r.transmit_time).sum::<std::time::Duration>();
    assert!(
        ts.as_secs_f64() > tf.as_secs_f64() * 20.0,
        "slow {ts:?} vs fast {tf:?}"
    );
}
