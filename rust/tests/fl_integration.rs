//! End-to-end FL runtime integration (native trainer, no artifacts
//! needed): single-threaded simulation and the threaded in-proc runtime,
//! across codecs — training must converge and compression must not hurt
//! accuracy at a moderate bound.

use fedgec::config::RunConfig;
use fedgec::coordinator::{run_local, run_threaded};
use fedgec::fl::transport::bandwidth::LinkSpec;
use fedgec::train::data::DatasetSpec;

fn base_cfg() -> RunConfig {
    RunConfig {
        model: "native".into(),
        dataset: DatasetSpec::Cifar10,
        n_clients: 3,
        rounds: 6,
        samples_per_client: 64,
        local_lr: 0.2,
        server_lr: 0.2,
        codec: "fedgec".into(),
        rel_error_bound: 1e-2,
        link: LinkSpec::infinite(),
        eval_every: 0,
        seed: 11,
        class_skew: 0.3,
        ..Default::default()
    }
}

#[test]
fn local_sim_converges_with_fedgec() {
    let cfg = base_cfg();
    let summary = run_local(&cfg).expect("run");
    assert_eq!(summary.rounds.len(), cfg.rounds);
    let losses = summary.loss_curve();
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss should drop: {losses:?}"
    );
    assert!(summary.mean_ratio() > 2.0, "CR {}", summary.mean_ratio());
    let acc = summary.final_accuracy.unwrap();
    assert!(acc > 0.15, "acc {acc}");
}

#[test]
fn compression_tracks_uncompressed_training() {
    // At eb=1e-2 the compressed run should match the uncompressed loss
    // trajectory closely (the paper's Fig. 9 claim).
    let mut cfg = base_cfg();
    cfg.codec = "none".into();
    let clean = run_local(&cfg).unwrap();
    cfg.codec = "fedgec".into();
    let ours = run_local(&cfg).unwrap();
    let lc = clean.loss_curve();
    let lo = ours.loss_curve();
    let final_gap = (lc.last().unwrap() - lo.last().unwrap()).abs();
    assert!(final_gap < 0.35, "loss gap {final_gap}: clean {lc:?} vs ours {lo:?}");
}

#[test]
fn all_codecs_run_the_fl_loop() {
    for codec in ["fedgec", "sz3", "qsgd", "topk", "none"] {
        let mut cfg = base_cfg();
        cfg.codec = codec.into();
        cfg.rounds = 3;
        let summary = run_local(&cfg).unwrap_or_else(|e| panic!("{codec}: {e}"));
        assert_eq!(summary.rounds.len(), 3, "{codec}");
        assert!(summary.rounds.iter().all(|r| r.payload_bytes > 0), "{codec}");
    }
}

#[test]
fn threaded_runtime_matches_protocol() {
    let mut cfg = base_cfg();
    cfg.rounds = 3;
    cfg.n_clients = 4;
    let summary = run_threaded(&cfg).expect("threaded run");
    assert_eq!(summary.rounds.len(), 3);
    assert!(summary.mean_ratio() > 1.5);
    assert!(summary.final_accuracy.is_some());
}

#[test]
fn virtual_link_accounting_scales_with_bandwidth() {
    // Zero latency so only the bandwidth term is compared.
    let mut slow = base_cfg();
    slow.rounds = 2;
    slow.link = LinkSpec { bits_per_sec: 1e6, latency: std::time::Duration::ZERO };
    let mut fast = slow.clone();
    fast.link = LinkSpec { bits_per_sec: 100e6, latency: std::time::Duration::ZERO };
    let s = run_local(&slow).unwrap();
    let f = run_local(&fast).unwrap();
    let ts = s.rounds.iter().map(|r| r.transmit_time).sum::<std::time::Duration>();
    let tf = f.rounds.iter().map(|r| r.transmit_time).sum::<std::time::Duration>();
    assert!(
        ts.as_secs_f64() > tf.as_secs_f64() * 20.0,
        "slow {ts:?} vs fast {tf:?}"
    );
}
