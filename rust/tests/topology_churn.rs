//! Fleet-churn soak for the **sharded** round runner: thousands of
//! distinct stateful clients churn through a budgeted state store with
//! joins, dropouts, and forced evictions every round, all decoded by
//! concurrent shard workers over the one shared store. Every resync
//! ordered by the handshake must converge (the client's next uplink
//! decodes, and the mirror fingerprints agree wherever the server still
//! holds the state), and no client may be dropped.
//!
//! `FEDGEC_CHURN_CLIENTS` overrides the fleet size (CI's release
//! topology job runs the 10k default).

use std::sync::Arc;
use std::time::Instant;

use fedgec::compress::pipeline::{FedgecCodec, FedgecConfig, FedgecEngine};
use fedgec::compress::state::StateEpoch;
use fedgec::compress::store::ShardedMemStore;
use fedgec::compress::GradientCodec;
use fedgec::fl::server::Server;
use fedgec::fl::topology::sharded::{Contribution, ShardedRunner};
use fedgec::tensor::{LayerGrad, LayerMeta, ModelGrad};
use fedgec::util::rng::Rng;

const WAVES: usize = 4;
const STICKY: u32 = 64;
const SHARDS: usize = 8;

fn churn_clients() -> u32 {
    if let Ok(v) = std::env::var("FEDGEC_CHURN_CLIENTS") {
        return v.parse().expect("FEDGEC_CHURN_CLIENTS must be an integer");
    }
    if cfg!(debug_assertions) {
        2_500
    } else {
        10_000
    }
}

fn metas() -> Vec<LayerMeta> {
    // One lossy layer (numel > t_lossy=1024 ⇒ carries predictor state)
    // plus a small lossless one.
    vec![LayerMeta::dense("fc", 1280, 1), LayerMeta::other("bias", 64)]
}

fn grads(metas: &[LayerMeta], rng: &mut Rng) -> ModelGrad {
    ModelGrad {
        layers: metas
            .iter()
            .map(|m| {
                let data: Vec<f32> = (0..m.numel).map(|_| rng.normal_f32(0.0, 0.3)).collect();
                LayerGrad::new(m.clone(), data)
            })
            .collect(),
    }
}

/// Run one client's uplink prep on the driver thread: state handshake
/// (resetting the local codec if ordered), compress, advance the local
/// epoch mirror. Returns the contribution for the shard queues plus
/// whether a reset happened.
fn prep(
    id: u32,
    codec: &mut FedgecCodec,
    epoch: &mut StateEpoch,
    server: &mut Server,
    rng: &mut Rng,
    metas: &[LayerMeta],
) -> (Contribution, bool) {
    let reset = server.check_state(id, *epoch).unwrap();
    if reset {
        codec.reset();
        *epoch = StateEpoch::cold();
    }
    let payload: Arc<[u8]> = codec.compress(&grads(metas, rng)).unwrap().into();
    epoch.advance(codec.state_fingerprint());
    (Contribution { client: id, payload, weight: 1.0, loss: 0.5 }, reset)
}

/// Post-round mirror check: wherever the server still holds a client's
/// state, its fingerprint must equal the client's. (`None` means the
/// budgeted store evicted it after the decode — legal; the next
/// handshake resolves it with a reset.)
fn assert_mirrors(server: &Server, expected: &[(u32, StateEpoch)]) -> usize {
    let mut evicted = 0usize;
    for &(id, epoch) in expected {
        match server.state_epoch(id).unwrap() {
            Some(held) => {
                assert_eq!(held, epoch, "client {id}: mirror fingerprints diverged")
            }
            None => evicted += 1,
        }
    }
    evicted
}

#[test]
fn churning_fleet_converges_through_sharded_runner() {
    let t0 = Instant::now();
    let n = churn_clients();
    assert!(n > STICKY * 2, "fleet too small for the churn pattern");
    let metas = metas();
    // One warm state ≈ 1280 × 4 B × 5 buffers ≈ 26 KB; budget ~256
    // states, far below the fleet, so churn waves force evictions.
    let budget = 256 * (1280 * 4 * 5);
    let params: Vec<Vec<f32>> = metas.iter().map(|m| vec![0.0; m.numel]).collect();
    let mut server = Server::new(
        params,
        metas.clone(),
        0.1,
        Box::new(FedgecEngine::new(FedgecConfig::default())),
        Box::new(ShardedMemStore::new(8, Some(budget))),
    );
    server.admit_all();
    let engines = (0..SHARDS)
        .map(|_| {
            Box::new(FedgecEngine::new(FedgecConfig::default()))
                as Box<dyn fedgec::compress::engine::CodecEngine>
        })
        .collect();
    let mut runner = ShardedRunner::new(&server, engines).unwrap();

    // Sticky clients persist across waves (their codecs live on); the
    // rest of the fleet churns through once each.
    let mut sticky: Vec<(FedgecCodec, StateEpoch)> = (0..STICKY)
        .map(|_| (FedgecCodec::new(FedgecConfig::default()), StateEpoch::cold()))
        .collect();
    let mut rng = Rng::new(0x50AB_C0DE);
    let per_wave = (n - STICKY) as usize / WAVES;
    let mut sticky_resets = 0usize;
    for wave in 0..WAVES {
        let mut queues: Vec<Vec<Contribution>> = (0..SHARDS).map(|_| Vec::new()).collect();
        let mut expected: Vec<(u32, StateEpoch)> = Vec::new();
        let lo = STICKY + (wave * per_wave) as u32;
        for id in lo..lo + per_wave as u32 {
            // Transient join: fresh cold codec, participates once, then
            // the device drops out forever.
            let mut codec = FedgecCodec::new(FedgecConfig::default());
            let mut epoch = StateEpoch::cold();
            let (c, reset) = prep(id, &mut codec, &mut epoch, &mut server, &mut rng, &metas);
            assert!(!reset, "first-contact client {id} must not need a reset");
            queues[id as usize % SHARDS].push(c);
            expected.push((id, epoch));
        }
        for (i, (codec, epoch)) in sticky.iter_mut().enumerate() {
            let (c, reset) = prep(i as u32, codec, epoch, &mut server, &mut rng, &metas);
            if reset {
                sticky_resets += 1;
            }
            queues[i % SHARDS].push(c);
            expected.push((i as u32, *epoch));
        }
        let stats = runner
            .run_round_direct(&mut server, |shard| queues[shard].iter().cloned())
            .unwrap();
        assert_eq!(stats.dropped, 0, "wave {wave}: churn must never drop an uplink");
        assert_eq!(stats.participants, per_wave + STICKY as usize, "wave {wave}");
        assert_eq!(stats.shards, SHARDS);
        assert!((stats.mean_loss - 0.5).abs() < 1e-9, "wave {wave}");
        assert!(stats.resyncs == 0, "resyncs are the driver's, not the workers'");
        assert_mirrors(&server, &expected);
        let occ = server.store_stats();
        assert!(
            occ.resident_bytes <= budget,
            "wave {wave}: resident {} over budget {budget}",
            occ.resident_bytes
        );
    }
    let occ = server.store_stats();
    assert!(
        occ.resident_clients < n as usize / 10,
        "store must hold a small fraction of the fleet, got {}",
        occ.resident_clients
    );
    assert!(occ.evictions > 100, "churn at this scale must evict, got {}", occ.evictions);
    assert!(
        sticky_resets > 0,
        "sticky clients drowned by churn must have been evicted + resynced"
    );

    // Quiet phase: only the sticky fleet participates. The first quiet
    // round re-seats evicted states; 64 states fit the budget, so the
    // second must be reset-free with every mirror intact.
    for quiet in 0..2 {
        let mut queues: Vec<Vec<Contribution>> = (0..SHARDS).map(|_| Vec::new()).collect();
        let mut expected: Vec<(u32, StateEpoch)> = Vec::new();
        let mut resets = 0usize;
        for (i, (codec, epoch)) in sticky.iter_mut().enumerate() {
            let (c, reset) = prep(i as u32, codec, epoch, &mut server, &mut rng, &metas);
            if reset {
                resets += 1;
            }
            queues[i % SHARDS].push(c);
            expected.push((i as u32, *epoch));
        }
        let stats = runner
            .run_round_direct(&mut server, |shard| queues[shard].iter().cloned())
            .unwrap();
        assert_eq!(stats.dropped, 0, "quiet {quiet}");
        if quiet == 1 {
            assert_eq!(resets, 0, "warm sticky fleet must stay warm");
            assert_eq!(assert_mirrors(&server, &expected), 0, "no evictions at rest");
        }
    }

    // Wall-clock guard: churn + eviction through 8 workers must stay far
    // from quadratic; a store lock convoy blows straight past this.
    let elapsed = t0.elapsed();
    assert!(
        elapsed.as_secs_f64() < 120.0,
        "{n}-client churn took {elapsed:?} — sharded eviction path too slow"
    );
    println!(
        "{n} clients, {WAVES} waves via {SHARDS} shards: {:?} wall, {} evictions, {} resident",
        elapsed, occ.evictions, occ.resident_clients
    );
}
