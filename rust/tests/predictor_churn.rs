//! Churn test for the pluggable-predictor redesign: `pred=auto` mixes
//! per-layer predictors (the race picks different winners for different
//! layers/rounds), and the encode/decode pipe must stay **bit-identical**
//! through the externalized-state machinery — disk evict→reload of the
//! `FGS3` records (which carry the predictor tag and eb bits) and a mid-run
//! cold-start resync.

use fedgec::compress::engine::CodecEngine;
use fedgec::compress::pipeline::{FedgecCodec, FedgecConfig, FedgecEngine};
use fedgec::compress::predictor::{MagnitudeSel, PredictorSpec, SignSel};
use fedgec::compress::store::{DiskSpillStore, StateStore};
use fedgec::compress::{ClientState, GradientCodec};
use fedgec::tensor::model_zoo::ModelArch;
use fedgec::tensor::{LayerGrad, LayerMeta, ModelGrad};
use fedgec::util::rng::Rng;

fn auto_cfg() -> FedgecConfig {
    FedgecConfig {
        predictor: PredictorSpec { mag: MagnitudeSel::Auto, sign: SignSel::Auto },
        ..Default::default()
    }
}

/// Near-stationary per-layer patterns with mild decay + small jitter —
/// the regime where the race demonstrably promotes a cross-round
/// predictor on conv layers (dominant-sign kernels, few flips) while
/// sign-less layers keep falling to `zero`, i.e. genuinely **mixed**
/// per-layer predictors. (Heavy per-round noise would let `zero` win
/// everywhere, which is a valid race outcome but proves less.)
struct Stream {
    metas: Vec<LayerMeta>,
    patterns: Vec<Vec<f32>>,
    rng: Rng,
    round: usize,
}

impl Stream {
    fn new(metas: Vec<LayerMeta>, seed: u64) -> Stream {
        let mut rng = Rng::new(seed);
        let patterns = metas
            .iter()
            .map(|m| match m.kind.kernel_size() {
                Some(t) => {
                    let mut v = Vec::with_capacity(m.numel);
                    for _ in 0..m.numel.div_ceil(t) {
                        let dom: f32 = if rng.chance(0.5) { 1.0 } else { -1.0 };
                        for _ in 0..t {
                            let flip = rng.chance(0.05);
                            v.push(dom * if flip { -1.0 } else { 1.0 } * (0.2 + rng.next_f32()));
                        }
                    }
                    v.truncate(m.numel);
                    v
                }
                None => (0..m.numel).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            })
            .collect();
        Stream { metas, patterns, rng, round: 0 }
    }

    fn next_round(&mut self) -> ModelGrad {
        let scale = 1.0 / (1.0 + self.round as f32 * 0.05);
        self.round += 1;
        let layers = self
            .metas
            .iter()
            .zip(&self.patterns)
            .map(|(m, p)| {
                let data =
                    p.iter().map(|&x| x * scale * (1.0 + 0.02 * self.rng.gauss() as f32)).collect();
                LayerGrad::new(m.clone(), data)
            })
            .collect();
        ModelGrad { layers }
    }
}

/// One simulated client: an auto-racing codec over its own correlated
/// gradient stream.
struct SimClient {
    codec: FedgecCodec,
    gen: Stream,
}

impl SimClient {
    fn new(metas: Vec<LayerMeta>, seed: u64) -> SimClient {
        SimClient { codec: FedgecCodec::new(auto_cfg()), gen: Stream::new(metas, seed) }
    }
}

#[test]
fn auto_predictors_bit_identical_through_evict_reload_and_resync() {
    let metas = ModelArch::MicroInception.layers(10);
    let n_clients = 2u32;
    let mut clients: Vec<SimClient> =
        (0..n_clients).map(|i| SimClient::new(metas.clone(), 70 + i as u64)).collect();

    // One stateless engine + a disk store whose 1-byte hot tier spills
    // every checked-in state, so each round decodes through a full
    // FGS3 evict→reload cycle.
    let dir = std::env::temp_dir().join(format!("fedgec_pred_churn_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = DiskSpillStore::new(&dir, 1, 1).unwrap();
    let mut engine = FedgecEngine::new(auto_cfg());

    let rounds = 8usize;
    let mut seen_tags = std::collections::BTreeSet::new();
    for round in 0..rounds {
        for id in 0..n_clients {
            let client = &mut clients[id as usize];
            // Mid-run device churn for client 1: its local state is lost,
            // the server drops its mirror (the StateCheck/StateResync
            // outcome), and both sides cold-start in lock-step.
            if round == 4 && id == 1 {
                client.codec.reset();
                store.remove(id).unwrap();
            }
            let grads = client.gen.next_round();
            let (payload, cr) = client.codec.compress_with_report(&grads).unwrap();
            let mut state = store.take(id).unwrap().unwrap_or_else(ClientState::cold);
            let (recon, sr) =
                engine.decode_payload(&payload, &metas, &mut state.codec).unwrap();

            // Bit-identity: the server reconstruction equals the client's
            // own mirror, layer by layer, element by element.
            for (li, layer) in recon.layers.iter().enumerate() {
                if let Some(mirror) = client.codec.state.layers[li].prev_recon.as_deref() {
                    assert_eq!(
                        layer.data.len(),
                        mirror.len(),
                        "round {round} client {id} layer {li}"
                    );
                    for (a, b) in layer.data.iter().zip(mirror) {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "round {round} client {id} layer {li}"
                        );
                    }
                } else {
                    // Small layers bypass the predictor: exact store.
                    assert_eq!(layer.data, grads.layers[li].data);
                }
            }
            assert_eq!(
                state.codec.fingerprint(),
                client.codec.state_fingerprint(),
                "round {round} client {id}: mirror fingerprints diverged"
            );
            // Frame tags agree across the pipe and feed the mixed-
            // predictor evidence.
            for (cl, sl) in cr.layers.iter().zip(&sr.layers) {
                assert_eq!(cl.pred_tag, sl.pred_tag, "round {round} client {id}");
                if cl.lossy {
                    seen_tags.insert(cl.pred_tag.clone());
                }
            }
            state.epoch.advance(state.codec.fingerprint());
            store.put(id, state).unwrap();
        }
    }
    // The run actually exercised mixed per-layer predictors (round 1
    // deterministically falls to `zero`; the warm correlated stream
    // promotes a real predictor somewhere), and the 1-byte hot tier
    // really forced spill reloads.
    assert!(seen_tags.len() >= 2, "expected mixed predictor tags, saw {seen_tags:?}");
    assert!(store.stats().spill_loads > 0, "expected FGS3 evict→reload traffic");
    let _ = std::fs::remove_dir_all(&dir);
}
