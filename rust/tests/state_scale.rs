//! Scale test for the externalized-state server (run in release by CI):
//! 10 000 distinct clients churn through a parameter server whose state
//! store is budgeted far below 10 000 full mirror states. The run must
//! complete, stay inside the budget, keep every participating client's
//! state fingerprint equal to the server's copy, and finish fast enough
//! that the eviction path is clearly not quadratic (wall-clock guard).

use std::time::Instant;

use fedgec::compress::pipeline::{FedgecCodec, FedgecConfig, FedgecEngine};
use fedgec::compress::state::StateEpoch;
use fedgec::compress::store::ShardedMemStore;
use fedgec::compress::GradientCodec;
use fedgec::fl::aggregate::RoundAgg;
use fedgec::fl::server::Server;
use fedgec::tensor::{LayerGrad, LayerMeta, ModelGrad};
use fedgec::util::rng::Rng;

const N_CLIENTS: u32 = 10_000;
const WAVES: usize = 4;
const STICKY: u32 = 64;

fn metas() -> Vec<LayerMeta> {
    // One lossy layer (numel > t_lossy=1024 ⇒ carries predictor state)
    // plus a small lossless one.
    vec![LayerMeta::dense("fc", 1280, 1), LayerMeta::other("bias", 64)]
}

fn grads(metas: &[LayerMeta], rng: &mut Rng) -> ModelGrad {
    ModelGrad {
        layers: metas
            .iter()
            .map(|m| {
                let data: Vec<f32> = (0..m.numel).map(|_| rng.normal_f32(0.0, 0.3)).collect();
                LayerGrad::new(m.clone(), data)
            })
            .collect(),
    }
}

/// One participated round for a client codec; asserts the mirror
/// invariant (client fingerprint == server-held fingerprint) afterwards.
fn participate(
    id: u32,
    codec: &mut FedgecCodec,
    epoch: &mut StateEpoch,
    server: &mut Server,
    agg: &mut RoundAgg,
    rng: &mut Rng,
    metas: &[LayerMeta],
) -> bool {
    let reset = server.check_state(id, *epoch).unwrap();
    if reset {
        codec.reset();
        *epoch = StateEpoch::cold();
    }
    let payload = codec.compress(&grads(metas, rng)).unwrap();
    server.absorb_payload(id, &payload, 1.0, agg).unwrap();
    epoch.advance(codec.state_fingerprint());
    assert_eq!(
        server.state_epoch(id).unwrap(),
        Some(*epoch),
        "client {id}: mirror fingerprints diverged"
    );
    reset
}

#[test]
fn ten_thousand_clients_under_small_store_budget() {
    let t0 = Instant::now();
    let metas = metas();
    // One warm state ≈ 1280 elements × 4 B × 5 buffers ≈ 26 KB. Budget
    // ~256 states ≈ 6.5 MB — 40× smaller than 10k full states.
    let one_state_bytes = 1280 * 4 * 5;
    let budget = 256 * one_state_bytes;
    let params: Vec<Vec<f32>> = metas.iter().map(|m| vec![0.0; m.numel]).collect();
    let mut server = Server::new(
        params,
        metas.clone(),
        0.1,
        Box::new(FedgecEngine::new(FedgecConfig::default())),
        Box::new(ShardedMemStore::new(8, Some(budget))),
    );
    for id in 0..N_CLIENTS {
        server.admit(id);
    }

    // Sticky clients persist across waves (their codecs live on); the
    // rest of the fleet churns through once each — the device-churn
    // regime where eviction + the resync handshake carry the load.
    let mut sticky: Vec<(FedgecCodec, StateEpoch)> = (0..STICKY)
        .map(|_| (FedgecCodec::new(FedgecConfig::default()), StateEpoch::cold()))
        .collect();
    let mut rng = Rng::new(0x5CA1E);
    let per_wave = (N_CLIENTS - STICKY) as usize / WAVES;
    let mut sticky_resets = 0usize;
    for wave in 0..WAVES {
        let mut agg = server.new_round_agg();
        let lo = STICKY + (wave * per_wave) as u32;
        for id in lo..lo + per_wave as u32 {
            // Transient client: fresh (cold) codec, participates once.
            let mut codec = FedgecCodec::new(FedgecConfig::default());
            let mut epoch = StateEpoch::cold();
            let reset =
                participate(id, &mut codec, &mut epoch, &mut server, &mut agg, &mut rng, &metas);
            assert!(!reset, "first-contact client {id} must not need a reset");
        }
        for (i, (codec, epoch)) in sticky.iter_mut().enumerate() {
            if participate(i as u32, codec, epoch, &mut server, &mut agg, &mut rng, &metas) {
                sticky_resets += 1;
            }
        }
        server.finish_round(agg);
        let occ = server.store_stats();
        assert!(
            occ.resident_bytes <= budget,
            "wave {wave}: resident {} over budget {budget}",
            occ.resident_bytes
        );
    }
    let occ = server.store_stats();
    assert!(
        occ.resident_clients < N_CLIENTS as usize / 10,
        "store must hold a small fraction of the fleet, got {}",
        occ.resident_clients
    );
    assert!(occ.evictions > 1000, "churn at this scale must evict, got {}", occ.evictions);
    assert!(
        sticky_resets > 0,
        "sticky clients drowned by churn must have been evicted + resynced"
    );

    // Quiet phase: only the sticky clients participate. The first quiet
    // round re-seats any evicted state; from then on the fleet-of-64
    // fits the budget, so the second quiet round must be reset-free.
    for quiet in 0..2 {
        let mut agg = server.new_round_agg();
        let mut resets = 0usize;
        for (i, (codec, epoch)) in sticky.iter_mut().enumerate() {
            if participate(i as u32, codec, epoch, &mut server, &mut agg, &mut rng, &metas) {
                resets += 1;
            }
        }
        server.finish_round(agg);
        if quiet == 1 {
            assert_eq!(resets, 0, "warm sticky fleet must stay warm");
        }
    }

    // Wall-clock guard: ~10k cold-start decodes plus eviction churn must
    // stay comfortably sub-linear-ish; a quadratic eviction scan or a
    // store lock convoy blows straight past this.
    let elapsed = t0.elapsed();
    assert!(
        elapsed.as_secs_f64() < 90.0,
        "10k-client run took {elapsed:?} — eviction path too slow"
    );
    println!(
        "10k clients, {WAVES} waves: {:?} wall, {} evictions, {} resident ({} KB) under {} KB budget",
        elapsed,
        occ.evictions,
        occ.resident_clients,
        occ.resident_bytes / 1000,
        budget / 1000
    );
}
