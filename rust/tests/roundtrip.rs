//! Integration: every codec roundtrips full-model gradients across rounds
//! with its promised guarantees (error bound for EBLCs, exactness of kept
//! values for TopK, sign preservation for QSGD).

use fedgec::compress::quant::ErrorBound;
use fedgec::compress::spec::{CodecSpec, SpecDefaults};
use fedgec::compress::GradientCodec;
use fedgec::tensor::model_zoo::ModelArch;
use fedgec::tensor::LayerMeta;
use fedgec::train::gradgen::{GradGen, GradGenConfig};
use fedgec::util::stats;

fn micro_model_metas() -> Vec<LayerMeta> {
    ModelArch::MicroResNet.layers(10)
}

fn build(name: &str, eb: f64) -> Box<dyn GradientCodec> {
    CodecSpec::parse_with(name, &SpecDefaults::with_rel_eb(eb)).unwrap().build()
}

#[test]
fn all_codecs_roundtrip_micro_model_gradients() {
    let metas = micro_model_metas();
    for codec_name in ["fedgec", "sz3", "qsgd", "topk", "none"] {
        let mut gen = GradGen::new(metas.clone(), GradGenConfig::default(), 1);
        let eb = 1e-2;
        let mut client = build(codec_name, eb);
        let mut server = build(codec_name, eb);
        for round in 0..4 {
            let grads = gen.next_round();
            let payload = client.compress(&grads).unwrap_or_else(|e| {
                panic!("{codec_name} round {round} compress: {e}");
            });
            let recon = server
                .decompress(&payload, &metas)
                .unwrap_or_else(|e| panic!("{codec_name} round {round} decompress: {e}"));
            assert_eq!(recon.layers.len(), grads.layers.len(), "{codec_name}");
            for (r, g) in recon.layers.iter().zip(&grads.layers) {
                assert_eq!(r.data.len(), g.data.len(), "{codec_name} layer {}", g.meta.name);
            }
        }
    }
}

#[test]
fn eblc_codecs_respect_rel_bound_on_every_layer() {
    let metas = micro_model_metas();
    for codec_name in ["fedgec", "sz3"] {
        for eb in [1e-3, 1e-2, 3e-2, 5e-2] {
            let mut gen = GradGen::new(metas.clone(), GradGenConfig::default(), 2);
            // NOTE: a codec instance is ONE side of the pipe — compressing
            // and decompressing must use separate (mirrored) instances.
            let mut client = build(codec_name, eb);
            let mut server = build(codec_name, eb);
            for _ in 0..3 {
                let grads = gen.next_round();
                let payload = client.compress(&grads).unwrap();
                let recon = server.decompress(&payload, &metas).unwrap();
                for (r, g) in recon.layers.iter().zip(&grads.layers) {
                    let (lo, hi) = stats::finite_min_max(&g.data);
                    let delta = ErrorBound::Rel(eb).resolve(lo, hi) as f32;
                    for (rv, gv) in r.data.iter().zip(&g.data) {
                        assert!(
                            (rv - gv).abs() <= delta * 1.0001,
                            "{codec_name} eb {eb} layer {}: |{rv}-{gv}| > {delta}",
                            g.meta.name
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn fedgec_beats_sz3_on_structured_gradients() {
    // The paper's core claim (Table 4): on gradient tensors with temporal
    // magnitude structure and kernel sign consistency, FedGEC > SZ3 > QSGD
    // in compression ratio at the same bound.
    let metas = ModelArch::ResNet18.layers(10);
    let eb = 3e-2;
    let mut ratios = std::collections::HashMap::new();
    for codec_name in ["fedgec", "sz3", "qsgd"] {
        let mut gen = GradGen::new(metas.clone(), GradGenConfig::default(), 3);
        let mut codec = build(codec_name, eb);
        let mut raw = 0usize;
        let mut comp = 0usize;
        for _ in 0..3 {
            let grads = gen.next_round();
            let payload = codec.compress(&grads).unwrap();
            raw += grads.byte_size();
            comp += payload.len();
        }
        ratios.insert(codec_name, raw as f64 / comp as f64);
    }
    let ours = ratios["fedgec"];
    let sz3 = ratios["sz3"];
    let qsgd = ratios["qsgd"];
    assert!(ours > sz3, "fedgec {ours:.2} should beat sz3 {sz3:.2}");
    assert!(sz3 > qsgd * 0.8, "sz3 {sz3:.2} vs qsgd {qsgd:.2}");
    println!("CR @ eb={eb}: ours {ours:.2} sz3 {sz3:.2} qsgd {qsgd:.2}");
}

#[test]
fn payload_smaller_at_larger_bounds() {
    let metas = micro_model_metas();
    let mut sizes = Vec::new();
    for eb in [1e-3, 1e-2, 5e-2] {
        let mut gen = GradGen::new(metas.clone(), GradGenConfig::default(), 4);
        let mut codec = build("fedgec", eb);
        let mut total = 0usize;
        for _ in 0..3 {
            total += codec.compress(&gen.next_round()).unwrap().len();
        }
        sizes.push(total);
    }
    assert!(sizes[0] > sizes[1] && sizes[1] > sizes[2], "{sizes:?}");
}
