//! The paper's synchronization invariant (§4.1): client and server
//! predictor states must remain bit-identical across many rounds using
//! only the transmitted payload — including under full-batch mode, codec
//! resets, and mixed layer types.

use fedgec::compress::pipeline::{FedgecCodec, FedgecConfig};
use fedgec::compress::quant::ErrorBound;
use fedgec::compress::GradientCodec;
use fedgec::tensor::model_zoo::ModelArch;
use fedgec::tensor::LayerMeta;
use fedgec::train::gradgen::{GradGen, GradGenConfig};

fn metas() -> Vec<LayerMeta> {
    ModelArch::MicroInception.layers(10)
}

fn run_rounds(
    cfg: FedgecConfig,
    gen_cfg: GradGenConfig,
    rounds: usize,
    seed: u64,
) -> (FedgecCodec, FedgecCodec) {
    let metas = metas();
    let mut client = FedgecCodec::new(cfg.clone());
    let mut server = FedgecCodec::new(cfg);
    let mut gen = GradGen::new(metas.clone(), gen_cfg, seed);
    for round in 0..rounds {
        let grads = gen.next_round();
        let payload = client.compress(&grads).unwrap();
        let recon = server.decompress(&payload, &metas).unwrap();
        // Reconstruction on the server == reconstruction stored client-side.
        for (idx, layer) in recon.layers.iter().enumerate() {
            let client_recon = client.state.layers[idx].prev_recon.as_deref();
            let server_recon = server.state.layers[idx].prev_recon.as_deref();
            assert_eq!(client_recon, server_recon, "round {round} layer {idx}");
            if layer.data.len() > 1024 {
                assert_eq!(Some(layer.data.as_slice()), server_recon);
            }
        }
        assert_eq!(
            client.state.fingerprint(),
            server.state.fingerprint(),
            "fingerprint divergence at round {round}"
        );
    }
    (client, server)
}

#[test]
fn sync_over_many_rounds_minibatch() {
    run_rounds(FedgecConfig::default(), GradGenConfig::default(), 10, 1);
}

#[test]
fn sync_full_batch_mode() {
    let cfg = FedgecConfig { full_batch: true, ..Default::default() };
    let gen = GradGenConfig { full_batch: true, ..Default::default() };
    run_rounds(cfg, gen, 10, 2);
}

#[test]
fn sync_across_error_bounds() {
    for eb in [1e-3, 3e-2, 1e-1] {
        let cfg = FedgecConfig { error_bound: ErrorBound::Rel(eb), ..Default::default() };
        run_rounds(cfg, GradGenConfig::default(), 5, 3);
    }
}

#[test]
fn reset_resynchronizes_both_sides() {
    let (mut client, mut server) = run_rounds(FedgecConfig::default(), GradGenConfig::default(), 4, 4);
    client.reset();
    server.reset();
    assert_eq!(client.state.fingerprint(), server.state.fingerprint());
    // And they work again after reset.
    let metas = metas();
    let mut gen = GradGen::new(metas.clone(), GradGenConfig::default(), 5);
    let grads = gen.next_round();
    let payload = client.compress(&grads).unwrap();
    server.decompress(&payload, &metas).unwrap();
    assert_eq!(client.state.fingerprint(), server.state.fingerprint());
}

#[test]
fn divergent_server_state_detected_by_fingerprint() {
    // Negative control: if the server used different data, fingerprints
    // must differ — i.e. the fingerprint actually has discriminating power.
    let metas = metas();
    let mut client = FedgecCodec::new(FedgecConfig::default());
    let mut server = FedgecCodec::new(FedgecConfig::default());
    let mut gen_a = GradGen::new(metas.clone(), GradGenConfig::default(), 6);
    let mut gen_b = GradGen::new(metas.clone(), GradGenConfig::default(), 7);
    let ga = gen_a.next_round();
    let gb = gen_b.next_round();
    let pa = client.compress(&ga).unwrap();
    let _ = client.compress(&ga).unwrap(); // client advances with A again
    let _ = server.decompress(&pa, &metas).unwrap();
    // Server decompresses a payload from different data for round 2.
    let mut other = FedgecCodec::new(FedgecConfig::default());
    let pb = other.compress(&gb).unwrap();
    let _ = server.decompress(&pb, &metas).unwrap();
    assert_ne!(client.state.fingerprint(), server.state.fingerprint());
}
