//! The paper's synchronization invariant (§4.1): client and server
//! predictor states must remain bit-identical across many rounds using
//! only the transmitted payload — including under full-batch mode, codec
//! resets, and mixed layer types.

use fedgec::compress::pipeline::{FedgecCodec, FedgecConfig, FedgecEngine};
use fedgec::compress::quant::ErrorBound;
use fedgec::compress::state::StateEpoch;
use fedgec::compress::store::ShardedMemStore;
use fedgec::compress::GradientCodec;
use fedgec::fl::aggregate::RoundAgg;
use fedgec::fl::server::Server;
use fedgec::tensor::model_zoo::ModelArch;
use fedgec::tensor::LayerMeta;
use fedgec::train::gradgen::{GradGen, GradGenConfig};

fn metas() -> Vec<LayerMeta> {
    ModelArch::MicroInception.layers(10)
}

fn run_rounds(
    cfg: FedgecConfig,
    gen_cfg: GradGenConfig,
    rounds: usize,
    seed: u64,
) -> (FedgecCodec, FedgecCodec) {
    let metas = metas();
    let mut client = FedgecCodec::new(cfg.clone());
    let mut server = FedgecCodec::new(cfg);
    let mut gen = GradGen::new(metas.clone(), gen_cfg, seed);
    for round in 0..rounds {
        let grads = gen.next_round();
        let payload = client.compress(&grads).unwrap();
        let recon = server.decompress(&payload, &metas).unwrap();
        // Reconstruction on the server == reconstruction stored client-side.
        for (idx, layer) in recon.layers.iter().enumerate() {
            let client_recon = client.state.layers[idx].prev_recon.as_deref();
            let server_recon = server.state.layers[idx].prev_recon.as_deref();
            assert_eq!(client_recon, server_recon, "round {round} layer {idx}");
            if layer.data.len() > 1024 {
                assert_eq!(Some(layer.data.as_slice()), server_recon);
            }
        }
        assert_eq!(
            client.state.fingerprint(),
            server.state.fingerprint(),
            "fingerprint divergence at round {round}"
        );
    }
    (client, server)
}

#[test]
fn sync_over_many_rounds_minibatch() {
    run_rounds(FedgecConfig::default(), GradGenConfig::default(), 10, 1);
}

#[test]
fn sync_full_batch_mode() {
    let cfg = FedgecConfig { full_batch: true, ..Default::default() };
    let gen = GradGenConfig { full_batch: true, ..Default::default() };
    run_rounds(cfg, gen, 10, 2);
}

#[test]
fn sync_across_error_bounds() {
    for eb in [1e-3, 3e-2, 1e-1] {
        let cfg = FedgecConfig { error_bound: ErrorBound::Rel(eb), ..Default::default() };
        run_rounds(cfg, GradGenConfig::default(), 5, 3);
    }
}

#[test]
fn reset_resynchronizes_both_sides() {
    let (mut client, mut server) =
        run_rounds(FedgecConfig::default(), GradGenConfig::default(), 4, 4);
    client.reset();
    server.reset();
    assert_eq!(client.state.fingerprint(), server.state.fingerprint());
    // And they work again after reset.
    let metas = metas();
    let mut gen = GradGen::new(metas.clone(), GradGenConfig::default(), 5);
    let grads = gen.next_round();
    let payload = client.compress(&grads).unwrap();
    server.decompress(&payload, &metas).unwrap();
    assert_eq!(client.state.fingerprint(), server.state.fingerprint());
}

/// One simulated federated client against the engine+store server.
struct SimClient {
    codec: FedgecCodec,
    gen: GradGen,
    epoch: StateEpoch,
}

impl SimClient {
    fn new(metas: Vec<LayerMeta>, seed: u64) -> SimClient {
        SimClient {
            codec: FedgecCodec::new(FedgecConfig::default()),
            gen: GradGen::new(metas, GradGenConfig::default(), seed),
            epoch: StateEpoch::cold(),
        }
    }

    /// One participated round: handshake, compress, upload. Returns
    /// whether the server ordered a cold-start reset.
    fn round(&mut self, id: u32, server: &mut Server, agg: &mut RoundAgg) -> bool {
        let reset = server.check_state(id, self.epoch).unwrap();
        if reset {
            self.codec.reset();
            self.epoch = StateEpoch::cold();
        }
        let grads = self.gen.next_round();
        let payload = self.codec.compress(&grads).unwrap();
        server.absorb_payload(id, &payload, 1.0, agg).unwrap();
        self.epoch.advance(self.codec.state_fingerprint());
        // The synchronization invariant, restated in epoch terms: after
        // every participated round the server-held epoch (rounds AND
        // state fingerprint) is bit-identical to the client's.
        assert_eq!(server.state_epoch(id).unwrap(), Some(self.epoch), "client {id}");
        reset
    }
}

fn engine_server(metas: &[LayerMeta]) -> Server {
    let params: Vec<Vec<f32>> = metas.iter().map(|m| vec![0.0; m.numel]).collect();
    Server::with_engine(
        params,
        metas.to_vec(),
        0.1,
        Box::new(FedgecEngine::new(FedgecConfig::default())),
    )
}

#[test]
fn dropout_rejoin_resyncs_via_state_check() {
    // Three clients against one engine + store:
    //   0 — participates every round (control: never reset);
    //   1 — drops out rounds 2..=4 with its state INTACT, rejoins at 5:
    //       the epoch handshake recognizes it and keeps it warm;
    //   2 — drops at round 3 and LOSES its local state (device churn),
    //       rejoins at 4: the handshake mismatches, both sides cold-start,
    //       and the fingerprints re-converge bit-identically.
    let metas = metas();
    let mut server = engine_server(&metas);
    for id in 0..3 {
        server.admit(id);
    }
    let mut clients: Vec<SimClient> =
        (0..3).map(|i| SimClient::new(metas.clone(), 50 + i)).collect();
    for round in 0..8usize {
        let mut agg = server.new_round_agg();
        let reset0 = clients[0].round(0, &mut server, &mut agg);
        assert!(!reset0, "persistent client reset at round {round}");
        if !(2..=4).contains(&round) {
            let reset1 = clients[1].round(1, &mut server, &mut agg);
            assert!(!reset1, "intact-state rejoin must stay warm (round {round})");
        }
        if round == 3 {
            // Device churn: client 2 loses everything it knew.
            clients[2] = SimClient::new(metas.clone(), 999);
        } else {
            let reset2 = clients[2].round(2, &mut server, &mut agg);
            // The one cold rejoin is detected; every other round is warm.
            assert_eq!(reset2, round == 4, "client 2 round {round}");
        }
        server.finish_round(agg);
    }
    // All three mirrors ended in sync and resident.
    assert_eq!(server.store_stats().resident_clients, 3);
    for (id, c) in clients.iter().enumerate() {
        assert_eq!(server.state_epoch(id as u32).unwrap(), Some(c.epoch));
    }
}

#[test]
fn eviction_detected_and_recovered_by_resync() {
    // A store budgeted for ~2 states serving 4 clients: whoever is
    // evicted gets a cold-start order on its next round instead of a
    // silent divergence, and re-converges immediately.
    let metas = metas();
    let params: Vec<Vec<f32>> = metas.iter().map(|m| vec![0.0; m.numel]).collect();
    let mut probe = SimClient::new(metas.clone(), 7);
    let mut sizing_server = engine_server(&metas);
    let mut probe_agg = sizing_server.new_round_agg();
    sizing_server.admit(0);
    probe.round(0, &mut sizing_server, &mut probe_agg);
    let one_state = sizing_server.store_stats().resident_bytes;
    assert!(one_state > 0);

    let mut server = Server::new(
        params,
        metas.clone(),
        0.1,
        Box::new(FedgecEngine::new(FedgecConfig::default())),
        Box::new(ShardedMemStore::new(1, Some(one_state * 2 + one_state / 2))),
    );
    let n = 4u32;
    let mut clients: Vec<SimClient> =
        (0..n).map(|i| SimClient::new(metas.clone(), 100 + i as u64)).collect();
    for id in 0..n {
        server.admit(id);
    }
    let mut resets = 0;
    for _round in 0..3 {
        let mut agg = server.new_round_agg();
        for id in 0..n {
            if clients[id as usize].round(id, &mut server, &mut agg) {
                resets += 1;
            }
        }
        server.finish_round(agg);
    }
    let stats = server.store_stats();
    assert!(stats.evictions > 0, "budget must have forced evictions");
    assert!(resets > 0, "evicted clients must have been reset via the handshake");
    assert!(
        stats.resident_bytes <= one_state * 3,
        "resident {} vs budget {}",
        stats.resident_bytes,
        one_state * 2 + one_state / 2
    );
}

#[test]
fn divergent_server_state_detected_by_fingerprint() {
    // Negative control: if the server used different data, fingerprints
    // must differ — i.e. the fingerprint actually has discriminating power.
    let metas = metas();
    let mut client = FedgecCodec::new(FedgecConfig::default());
    let mut server = FedgecCodec::new(FedgecConfig::default());
    let mut gen_a = GradGen::new(metas.clone(), GradGenConfig::default(), 6);
    let mut gen_b = GradGen::new(metas.clone(), GradGenConfig::default(), 7);
    let ga = gen_a.next_round();
    let gb = gen_b.next_round();
    let pa = client.compress(&ga).unwrap();
    let _ = client.compress(&ga).unwrap(); // client advances with A again
    let _ = server.decompress(&pa, &metas).unwrap();
    // Server decompresses a payload from different data for round 2.
    let mut other = FedgecCodec::new(FedgecConfig::default());
    let pb = other.compress(&gb).unwrap();
    let _ = server.decompress(&pb, &metas).unwrap();
    assert_ne!(client.state.fingerprint(), server.state.fingerprint());
}
