//! Compressed-domain aggregation (`agg=binsum`): the integer-bin route
//! must be indistinguishable from decode-then-FedAvg — registry-wide on
//! random fleets, and end-to-end on a model-zoo CNN over a 20-round
//! half-participation run with exactly one dequantize pass per bin
//! layer per round. Ineligible layers (rel-eb, stateful predictors,
//! mixed per-client Δ) must fall back deterministically.

use fedgec::compress::agg::{BinAggregator, BinFrame};
use fedgec::compress::engine::CodecEngine;
use fedgec::compress::spec::{CodecSpec, SpecDefaults};
use fedgec::compress::state::CodecState;
use fedgec::compress::GradientCodec;
use fedgec::config::RunConfig;
use fedgec::coordinator::run_local;
use fedgec::fl::aggregate::{AggMode, FedAvg};
use fedgec::fl::hetero::sample_participants;
use fedgec::fl::server::Server;
use fedgec::fl::transport::bandwidth::LinkSpec;
use fedgec::tensor::model_zoo::ModelArch;
use fedgec::tensor::{LayerGrad, LayerMeta, ModelGrad};
use fedgec::train::data::DatasetSpec;
use fedgec::train::gradgen::{GradGen, GradGenConfig};
use fedgec::util::prop;
use fedgec::util::rng::Rng;

/// The eligible configuration: state-free predictors + abs-eb.
const BINS_SPEC: &str = "fedgec:eb=abs1e-3,pred=zero,sign=none";

/// Random fleet model: one lossy-sized layer (optionally salted with
/// escapes — outliers and non-finite values) plus an optional small
/// lossless layer.
fn arb_fleet_model(rng: &mut Rng) -> ModelGrad {
    let n_big = 1200 + rng.next_below(1200);
    let mut big = prop::arb_gradient(rng, n_big);
    if rng.chance(0.5) {
        for _ in 0..1 + rng.next_below(8) {
            let i = rng.next_below(n_big);
            big[i] = if rng.chance(0.3) { f32::NAN } else { 1e30 };
        }
    }
    let mut layers = vec![LayerGrad::new(LayerMeta::dense("fc", n_big, 1), big)];
    if rng.chance(0.7) {
        let n = 4 + rng.next_below(64);
        layers.push(LayerGrad::new(LayerMeta::other("bias", n), prop::arb_gradient(rng, n)));
    }
    ModelGrad { layers }
}

fn assert_close(a: f32, b: f32, ctx: &str) -> Result<(), String> {
    if !a.is_finite() || !b.is_finite() {
        if a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan()) {
            return Ok(());
        }
        return Err(format!("{ctx}: non-finite mismatch {a} vs {b}"));
    }
    let tol = 1e-5 * a.abs().max(b.abs()).max(1e-3);
    if (a - b).abs() > tol {
        return Err(format!("{ctx}: {a} vs {b} (tol {tol})"));
    }
    Ok(())
}

#[test]
fn prop_binsum_matches_dense_fedavg_registry_wide() {
    // Twin decode paths over identical payload streams: for every
    // registered codec family (plus the eligible state-free fedgec
    // spec), a fleet with mixed weights, dropouts, and corrupt
    // contributions must aggregate to the same mean on the bins route
    // as on the dense route, within 1e-5 relative.
    prop::check("binsum == dense FedAvg", 8, |rng| {
        let d = SpecDefaults::with_rel_eb(prop::arb_error_bound(rng));
        let mut specs = CodecSpec::registry_specs(&d);
        specs.push(CodecSpec::parse(BINS_SPEC).map_err(|e| e.to_string())?);
        let eligible = specs.len() - 1;
        let base = arb_fleet_model(rng);
        let metas: Vec<LayerMeta> = base.layers.iter().map(|l| l.meta.clone()).collect();
        for (si, spec) in specs.iter().enumerate() {
            let n_clients = 2 + rng.next_below(3);
            let mut codecs: Vec<Box<dyn GradientCodec>> =
                (0..n_clients).map(|_| spec.build()).collect();
            // One engine + one state per client per path, so stateful
            // families evolve their mirrors identically on both routes.
            let mut eng_dense = spec.build_engine();
            let mut eng_bins = spec.build_engine();
            let mut st_dense: Vec<CodecState> =
                (0..n_clients).map(|_| CodecState::default()).collect();
            let mut st_bins: Vec<CodecState> =
                (0..n_clients).map(|_| CodecState::default()).collect();
            let mut saw_bins = false;
            for round in 0..2 {
                let mut reference = FedAvg::new();
                let mut bins = BinAggregator::new();
                for ci in 0..n_clients {
                    if rng.chance(0.2) {
                        continue; // dropout: client skips the round
                    }
                    let mut g = base.clone();
                    for l in &mut g.layers {
                        for v in &mut l.data {
                            *v *= 1.0 + 0.05 * (round as f32 + ci as f32 * 0.3);
                        }
                    }
                    let mut payload =
                        codecs[ci].compress(&g).map_err(|e| format!("{spec}: {e}"))?;
                    if rng.chance(0.15) {
                        let i = rng.next_below(payload.len());
                        payload[i] ^= 1 << rng.next_below(8);
                    }
                    let w = if rng.chance(0.5) {
                        (1 + rng.next_below(64)) as f64
                    } else {
                        rng.uniform(0.1, 4.0)
                    };
                    let dense = eng_dense.decode_payload(&payload, &metas, &mut st_dense[ci]);
                    let binned = eng_bins.decode_payload_to_bins(
                        &payload,
                        &metas,
                        &mut st_bins[ci],
                    );
                    match (dense, binned) {
                        (Ok((grads, _)), Ok((frames, _))) => {
                            saw_bins |=
                                frames.iter().any(|f| matches!(f, BinFrame::Bins { .. }));
                            reference
                                .add(&grads, w)
                                .map_err(|e| format!("{spec}: {e}"))?;
                            bins.add(&frames, w).map_err(|e| format!("{spec}: {e}"))?;
                        }
                        _ => {
                            // A (likely corrupted) contribution failed on
                            // either route: drop it from both and reset
                            // both mirrors so the paths stay twinned.
                            st_dense[ci] = CodecState::default();
                            st_bins[ci] = CodecState::default();
                        }
                    }
                }
                let want = reference.mean();
                let (got, _report) = bins.finish();
                if want.len() != got.len() {
                    return Err(format!("{spec}: layer count {} vs {}", want.len(), got.len()));
                }
                for (li, (wl, gl)) in want.iter().zip(&got).enumerate() {
                    if wl.len() != gl.len() {
                        return Err(format!("{spec}: layer {li} numel"));
                    }
                    for (a, b) in wl.iter().zip(gl) {
                        assert_close(*a, *b, &format!("{spec} round {round} layer {li}"))?;
                    }
                }
            }
            if si == eligible && !saw_bins {
                return Err(format!("{spec}: eligible spec never took the bins route"));
            }
        }
        Ok(())
    });
}

#[test]
fn fallback_routes_for_rel_eb_and_stateful_specs() {
    // The validity analysis, as routes: rel-eb (per-client
    // data-dependent Δ) and stateful predictors must arrive as dense
    // frames tagged `exact`; only the state-free abs-eb config bins.
    let metas = vec![LayerMeta::dense("fc", 1500, 1), LayerMeta::other("bias", 8)];
    let mut rng = Rng::new(0xFA11);
    let grads = ModelGrad {
        layers: metas
            .iter()
            .map(|m| {
                let data: Vec<f32> =
                    (0..m.numel).map(|_| rng.normal_f32(0.0, 0.3)).collect();
                LayerGrad::new(m.clone(), data)
            })
            .collect(),
    };
    let cases = [
        ("fedgec:eb=rel1e-2,pred=zero,sign=none", false), // rel-eb
        ("fedgec:eb=abs1e-3", false),                     // stateful EMA
        (BINS_SPEC, true),
    ];
    for (text, expect_bins) in cases {
        let spec = CodecSpec::parse(text).unwrap();
        let mut codec = spec.build();
        let payload = codec.compress(&grads).unwrap();
        let mut engine = spec.build_engine();
        let mut state = CodecState::default();
        let (frames, report) =
            engine.decode_payload_to_bins(&payload, &metas, &mut state).unwrap();
        let fc_bins = matches!(frames[0], BinFrame::Bins { .. });
        assert_eq!(fc_bins, expect_bins, "{text}: fc route");
        assert_eq!(
            report.layers[0].agg_route,
            if expect_bins { "binsum" } else { "exact" },
            "{text}"
        );
        // The small lossless layer always falls back to dense.
        assert!(matches!(frames[1], BinFrame::Dense(_)), "{text}: bias route");
        assert_eq!(report.layers[1].agg_route, "exact", "{text}");
    }
}

#[test]
fn mixed_delta_fleet_demotes_to_mixed_route_and_still_matches() {
    // Two state-free clients with different abs bounds: both frames
    // arrive as bins, but their Δs differ, so the aggregator demotes
    // the layer mid-round and the result must still equal dense FedAvg.
    let metas = vec![LayerMeta::dense("fc", 1500, 1)];
    let mut rng = Rng::new(0xD317A);
    let grads = ModelGrad {
        layers: vec![LayerGrad::new(
            metas[0].clone(),
            (0..1500).map(|_| rng.normal_f32(0.0, 0.3)).collect(),
        )],
    };
    let mut reference = FedAvg::new();
    let mut bins = BinAggregator::new();
    for (ci, text) in
        ["fedgec:eb=abs1e-3,pred=zero,sign=none", "fedgec:eb=abs2e-3,pred=zero,sign=none"]
            .iter()
            .enumerate()
    {
        let spec = CodecSpec::parse(text).unwrap();
        let mut codec = spec.build();
        let payload = codec.compress(&grads).unwrap();
        let mut engine = spec.build_engine();
        let mut st_a = CodecState::default();
        let mut st_b = CodecState::default();
        let (dense, _) = engine.decode_payload(&payload, &metas, &mut st_a).unwrap();
        let (frames, _) =
            engine.decode_payload_to_bins(&payload, &metas, &mut st_b).unwrap();
        assert!(matches!(frames[0], BinFrame::Bins { .. }), "client {ci} should bin");
        let w = 1.0 + ci as f64;
        reference.add(&dense, w).unwrap();
        bins.add(&frames, w).unwrap();
    }
    let want = reference.mean();
    let (got, report) = bins.finish();
    assert_eq!(report.mixed_layers, 1, "Δ mismatch must demote the layer");
    assert_eq!(report.binsum_layers, 0);
    for (a, b) in want[0].iter().zip(&got[0]) {
        assert_close(*a, *b, "mixed-Δ layer").unwrap();
    }
}

#[test]
fn binsum_matches_exact_on_model_zoo_cnn_over_20_rounds() {
    // The acceptance run: paired servers on identical payload streams
    // from a model-zoo CNN, 20 rounds at half participation. The binsum
    // server must track the exact server within 1e-5 relative, perform
    // exactly one dequantize pass per bin layer per round, and leave
    // every client mirror cold (bit-identical, never touched).
    let metas = ModelArch::MicroInception.layers(10);
    let spec = CodecSpec::parse("fedgec:eb=abs2e-3,pred=zero,sign=none").unwrap();
    let params: Vec<Vec<f32>> = metas.iter().map(|m| vec![0.01; m.numel]).collect();
    let mut srv_exact =
        Server::with_engine(params.clone(), metas.clone(), 0.1, spec.build_engine());
    let mut srv_bins = Server::with_engine(params, metas.clone(), 0.1, spec.build_engine())
        .with_agg_mode(AggMode::Binsum);
    let n = 8usize;
    let mut codecs: Vec<Box<dyn GradientCodec>> = (0..n).map(|_| spec.build()).collect();
    let cold_fp = codecs[0].state_fingerprint();
    let mut gens: Vec<GradGen> = (0..n)
        .map(|i| GradGen::new(metas.clone(), GradGenConfig::default(), 70 + i as u64))
        .collect();
    for id in 0..n {
        srv_exact.admit(id as u32);
        srv_bins.admit(id as u32);
    }
    let mut rng = Rng::new(0xACC);
    let mut total_binsum = 0usize;
    for round in 0..20 {
        let parts = sample_participants(n, 0.5, &mut rng);
        let mut agg_exact = srv_exact.new_round_agg();
        let mut agg_bins = srv_bins.new_round_agg();
        for &ci in &parts {
            let g = gens[ci].next_round();
            let payload = codecs[ci].compress(&g).unwrap();
            let w = (ci + 1) as f64;
            srv_exact.absorb_payload(ci as u32, &payload, w, &mut agg_exact).unwrap();
            srv_bins.absorb_payload(ci as u32, &payload, w, &mut agg_bins).unwrap();
        }
        let re = srv_exact.finish_round(agg_exact);
        let rb = srv_bins.finish_round(agg_bins);
        assert_eq!(re.binsum_layers, 0, "exact server must never bin");
        if !parts.is_empty() {
            assert!(rb.binsum_layers > 0, "round {round}: no layer binned");
            assert_eq!(
                rb.dequant_passes, rb.binsum_layers,
                "round {round}: exactly one dequantize pass per bin layer"
            );
        }
        total_binsum += rb.binsum_layers;
        for (li, (le, lb)) in srv_exact.params.iter().zip(&srv_bins.params).enumerate() {
            for (a, b) in le.iter().zip(lb) {
                // Rounding-order differences accumulate additively over
                // 20 rounds (the payload stream is identical, so there
                // is no feedback), staying well inside 1e-5 relative
                // with a 1e-2 absolute floor.
                let tol = 1e-5 * a.abs().max(b.abs()).max(1e-2);
                assert!(
                    (a - b).abs() <= tol,
                    "round {round} layer {li}: {a} vs {b} (tol {tol})"
                );
            }
        }
    }
    assert!(total_binsum >= 20, "bins route under-used: {total_binsum}");
    // Bit-identical client mirrors: the state-free mode never warmed
    // any client codec.
    for (ci, c) in codecs.iter().enumerate() {
        assert_eq!(c.state_fingerprint(), cold_fp, "client {ci} mirror touched");
    }
}

#[test]
fn run_local_binsum_smoke() {
    // Closed loop: the config key drives the coordinator end-to-end and
    // the per-round stats record the route.
    let cfg = RunConfig {
        model: "native".into(),
        dataset: DatasetSpec::Cifar10,
        n_clients: 3,
        rounds: 4,
        samples_per_client: 64,
        local_lr: 0.2,
        server_lr: 0.2,
        codec: "fedgec:eb=abs5e-3,pred=zero,sign=none".into(),
        link: LinkSpec::infinite(),
        eval_every: 0,
        seed: 11,
        class_skew: 0.3,
        agg: "binsum".into(),
        ..Default::default()
    };
    let summary = run_local(&cfg).expect("binsum run");
    assert_eq!(summary.rounds.len(), 4);
    for r in &summary.rounds {
        assert!(r.payload_bytes > 0);
        assert!(r.binsum_layers >= 1, "round {}: nothing binned", r.round);
        assert_eq!(r.dequant_passes, r.binsum_layers, "round {}", r.round);
    }
    let losses = summary.loss_curve();
    assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
}
