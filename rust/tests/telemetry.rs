//! Telemetry acceptance tests: the round journal's fold must reproduce
//! every runner's own `RoundStats` **exactly** (flat local simulation,
//! sharded threaded fleet, hierarchical edge tier), and the `/metrics`
//! listener must serve well-formed Prometheus text over real HTTP.
//!
//! The journal is process-global, so the tests that attach one are
//! serialized behind a lock.

#![cfg(not(feature = "telemetry-off"))]

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Mutex;

use fedgec::config::RunConfig;
use fedgec::coordinator::{run_local, run_threaded};
use fedgec::fl::round::RunSummary;
use fedgec::fl::transport::bandwidth::LinkSpec;
use fedgec::telemetry::journal;
use fedgec::telemetry::MetricsServer;
use fedgec::train::data::DatasetSpec;

static JOURNAL_LOCK: Mutex<()> = Mutex::new(());

fn base_cfg() -> RunConfig {
    RunConfig {
        model: "native".into(),
        dataset: DatasetSpec::Cifar10,
        n_clients: 8,
        rounds: 3,
        samples_per_client: 32,
        local_lr: 0.2,
        server_lr: 0.2,
        codec: "fedgec".into(),
        rel_error_bound: 1e-2,
        link: LinkSpec::infinite(),
        eval_every: 0,
        seed: 17,
        class_skew: 0.3,
        participation: 1.0,
        ..Default::default()
    }
}

/// Run `f` with the journal attached to a scratch file, then fold the
/// file and assert each round's fold AND its `round_end` self-report
/// equal the runner's `RoundStats` exactly.
fn assert_fold_exact(tag: &str, f: impl FnOnce() -> fedgec::Result<RunSummary>) {
    let _guard = JOURNAL_LOCK.lock().unwrap();
    let name = format!("fedgec_journal_{tag}_{}.jsonl", std::process::id());
    let path = std::env::temp_dir().join(name);
    journal::attach(&path).unwrap();
    let summary = f();
    journal::detach();
    let summary = summary.unwrap_or_else(|e| panic!("{tag}: run failed: {e:#}"));
    let text = std::fs::read_to_string(&path).unwrap();
    let folded = journal::fold_journal(&text).unwrap_or_else(|e| panic!("{tag}: fold: {e:#}"));
    assert_eq!(folded.len(), summary.rounds.len(), "{tag}: round count");
    for (fr, want) in folded.iter().zip(&summary.rounds) {
        assert_eq!(
            &fr.folded, want,
            "{tag}: fold diverges from RoundStats at round {}",
            fr.round
        );
        let rep = fr.reported.as_ref().unwrap_or_else(|| {
            panic!("{tag}: round {} has no round_end record", fr.round)
        });
        assert_eq!(rep, want, "{tag}: round_end record diverges at round {}", fr.round);
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn journal_fold_is_exact_for_local_simulation() {
    // Partial participation + compressed downlink + eval rounds: the
    // richest record mix the local runner emits.
    let mut cfg = base_cfg();
    cfg.participation = 0.5;
    cfg.down = "fedgec".into();
    cfg.down_eb = 1e-3;
    cfg.eval_every = 2;
    cfg.rounds = 4;
    assert_fold_exact("local", || run_local(&cfg));
}

#[test]
fn journal_fold_is_exact_for_sharded_threaded_fleet() {
    let mut cfg = base_cfg();
    cfg.shards = 4;
    assert_fold_exact("sharded", || run_threaded(&cfg));
}

#[test]
fn journal_fold_is_exact_for_edge_tier() {
    let mut cfg = base_cfg();
    cfg.tier = "edge:4".into(); // 8 clients -> 2 edge aggregators
    assert_fold_exact("edge", || run_threaded(&cfg));
}

#[test]
fn metrics_endpoint_serves_prometheus_text_over_http() {
    let mut srv = MetricsServer::bind("127.0.0.1:0").unwrap();
    let get = |path: &str| -> String {
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    };

    let resp = get("/metrics");
    assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
    assert!(resp.contains("text/plain; version=0.0.4"), "{resp}");
    let body = resp.split("\r\n\r\n").nth(1).expect("response body");
    // The acceptance surface: rounds, bytes both directions, CPU time
    // splits, store traffic, resyncs, drops — all present with HELP and
    // TYPE lines and numeric samples.
    for name in [
        "fedgec_rounds_total",
        "fedgec_uplink_bytes_total",
        "fedgec_downlink_bytes_total",
        "fedgec_decode_seconds_total",
        "fedgec_agg_seconds_total",
        "fedgec_merge_seconds_total",
        "fedgec_store_hits_total",
        "fedgec_store_misses_total",
        "fedgec_store_evictions_total",
        "fedgec_resyncs_total",
        "fedgec_clients_dropped_total",
    ] {
        assert!(body.contains(&format!("# HELP {name} ")), "missing HELP for {name}");
        assert!(body.contains(&format!("# TYPE {name} ")), "missing TYPE for {name}");
        let sample = body
            .lines()
            .find(|l| !l.starts_with('#') && l.starts_with(name))
            .unwrap_or_else(|| panic!("no sample line for {name}"));
        let val = sample.rsplit(' ').next().unwrap();
        assert!(val.parse::<f64>().is_ok(), "non-numeric sample {sample:?}");
    }

    // Anything else 404s.
    let resp = get("/");
    assert!(resp.starts_with("HTTP/1.1 404"), "{resp}");

    srv.shutdown();
    // Shutdown is idempotent and the port is released.
    srv.shutdown();
    assert!(TcpStream::connect(srv.addr()).is_err() || get_is_dead(srv.addr()));
}

/// After shutdown the OS may briefly accept on the dead socket's
/// backlog; "dead" means no HTTP response comes back.
fn get_is_dead(addr: std::net::SocketAddr) -> bool {
    let Ok(mut s) = TcpStream::connect(addr) else { return true };
    let _ = write!(s, "GET /metrics HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n");
    let _ = s.set_read_timeout(Some(std::time::Duration::from_millis(500)));
    let mut out = String::new();
    s.read_to_string(&mut out).is_err() || out.is_empty()
}
