//! Controller-determinism churn test (DESIGN.md §15): under
//! `ebc=plateau` the round's error bound changes mid-run, and the
//! encode/decode pipe must stay **bit-identical** through dropout,
//! rejoin, a forced server-side eviction, and disk evict→reload of the
//! FGS3 spill records (which fold the eb bits into the fingerprint).
//! A resynced client adopts the *current* round's eb — never its
//! pre-dropout one.

use fedgec::compress::control::{EbSignals, EbcSpec};
use fedgec::compress::engine::CodecEngine;
use fedgec::compress::pipeline::{FedgecCodec, FedgecConfig, FedgecEngine};
use fedgec::compress::predictor::{MagnitudeSel, PredictorSpec, SignSel};
use fedgec::compress::store::{DiskSpillStore, StateStore};
use fedgec::compress::{ClientState, GradientCodec};
use fedgec::tensor::model_zoo::ModelArch;
use fedgec::tensor::{LayerGrad, LayerMeta, ModelGrad};
use fedgec::util::rng::Rng;

fn cfg() -> FedgecConfig {
    FedgecConfig {
        predictor: PredictorSpec { mag: MagnitudeSel::Ema, sign: SignSel::None },
        ..Default::default()
    }
}

struct SimClient {
    codec: FedgecCodec,
    rng: Rng,
}

impl SimClient {
    fn next_round(&mut self, metas: &[LayerMeta], round: usize) -> ModelGrad {
        let scale = 1.0 / (1.0 + round as f32 * 0.1);
        let layers = metas
            .iter()
            .map(|m| {
                let data = (0..m.numel).map(|_| self.rng.normal_f32(0.0, scale)).collect();
                LayerGrad::new(m.clone(), data)
            })
            .collect();
        ModelGrad { layers }
    }
}

#[test]
fn plateau_controller_bit_identical_through_dropout_rejoin_and_eviction() {
    let metas = ModelArch::MicroInception.layers(10);
    let n_clients = 3u32;
    let mut clients: Vec<SimClient> = (0..n_clients)
        .map(|i| SimClient { codec: FedgecCodec::new(cfg()), rng: Rng::new(40 + i as u64) })
        .collect();

    // 1-byte hot tier: every checked-in mirror spills, so each decode
    // runs a full FGS3 evict→reload cycle under a changing eb.
    let dir = std::env::temp_dir().join(format!("fedgec_ebc_churn_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = DiskSpillStore::new(&dir, 1, 1).unwrap();
    let mut engine = FedgecEngine::new(cfg());

    // patience=1 + flat losses: the bound halves every round until the
    // factor^4 clamp — the run genuinely spans multiple eb values.
    let mut ctl = EbcSpec::parse("plateau:1,0.5").unwrap().build(1e-2);

    let rounds = 10usize;
    let mut ebs_seen = std::collections::BTreeSet::new();
    let mut pre_dropout_eb = 0f32;
    for round in 0..rounds {
        let plan = ctl.plan(round as u32).expect("plateau always plans");
        ebs_seen.insert(plan.round_eb.to_bits());
        engine.apply_eb_plan(&plan);

        // Client 1 drops out for rounds 3..=5 (keeps its stale plan);
        // client 2 loses its device state at round 4 and cold-resyncs.
        let participants: Vec<u32> = (0..n_clients)
            .filter(|&id| !(id == 1 && (3..=5).contains(&round)))
            .collect();
        if round == 2 {
            pre_dropout_eb = plan.round_eb;
        }
        if round == 4 {
            let c2 = &mut clients[2];
            c2.codec.reset();
            // The round-scoped plan is config, not state: it survives
            // the cold reset (the client keeps the current broadcast).
            assert!(c2.codec.plan.is_some(), "reset must not clear the eb plan");
            store.remove(2).unwrap();
        }
        if round == 6 {
            // Rejoin: before this round's broadcast the client still
            // holds the eb it heard before dropping out...
            let stale = clients[1].codec.plan.as_ref().unwrap().round_eb;
            assert_eq!(stale.to_bits(), pre_dropout_eb.to_bits());
            assert_ne!(stale.to_bits(), plan.round_eb.to_bits(), "eb must have moved");
        }

        for &id in &participants {
            let client = &mut clients[id as usize];
            // The broadcast plan reaches every participant of the round.
            client.codec.apply_eb_plan(&plan);
            let grads = client.next_round(&metas, round);
            let payload = client.codec.compress(&grads).unwrap();
            let mut state = store.take(id).unwrap().unwrap_or_else(ClientState::cold);
            let (recon, _) = engine.decode_payload(&payload, &metas, &mut state.codec).unwrap();
            for (li, layer) in recon.layers.iter().enumerate() {
                if let Some(mirror) = client.codec.state.layers[li].prev_recon.as_deref() {
                    for (a, b) in layer.data.iter().zip(mirror) {
                        assert_eq!(a.to_bits(), b.to_bits(), "round {round} client {id} layer {li}");
                    }
                } else {
                    // Small layers bypass the predictor: exact store.
                    assert_eq!(layer.data, grads.layers[li].data);
                }
            }
            assert_eq!(
                state.codec.fingerprint(),
                client.codec.state_fingerprint(),
                "round {round} client {id}: mirror fingerprints diverged (eb {})",
                plan.round_eb
            );
            state.epoch.advance(state.codec.fingerprint());
            store.put(id, state).unwrap();
        }
        if round == 6 {
            // ...and after the round it has adopted the current eb.
            let now = clients[1].codec.plan.as_ref().unwrap().round_eb;
            assert_eq!(now.to_bits(), plan.round_eb.to_bits());
        }
        // Flat losses: the plateau controller keeps tightening.
        ctl.observe(&EbSignals {
            round: round as u32,
            train_loss: 1.0,
            eval: None,
            layer_bytes: vec![],
        });
    }
    assert!(ebs_seen.len() >= 3, "expected the bound to move, saw {} values", ebs_seen.len());
    assert!(store.stats().spill_loads > 0, "expected FGS3 evict→reload traffic");
    let _ = std::fs::remove_dir_all(&dir);
}
