//! Validation of the DESIGN.md §5 substitution: the synthetic gradient
//! generator must exhibit the same statistical structure as *real*
//! gradients from the pure-Rust trainer (and, when artifacts exist, the
//! HLO micro-models): kernel sign consistency above random, temporal
//! magnitude correlation, and decaying magnitudes.

use fedgec::tensor::sign_consistency;
use fedgec::train::data::{DatasetSpec, SynthDataset};
use fedgec::train::gradgen::{GradGen, GradGenConfig};
use fedgec::train::native::NativeNet;
use fedgec::util::rng::Rng;
use fedgec::util::stats;

/// Mean sign consistency of all conv kernels in a gradient tensor.
fn mean_consistency(kernels: impl Iterator<Item = Vec<f32>>) -> f64 {
    let mut sum = 0.0;
    let mut n = 0usize;
    for k in kernels {
        sum += sign_consistency(&k);
        n += 1;
    }
    sum / n.max(1) as f64
}

/// Random-kernel baseline for T=9 (paper Fig. 7(b)).
fn random_baseline(rng: &mut Rng) -> f64 {
    mean_consistency((0..2000).map(|_| (0..9).map(|_| rng.normal_f32(0.0, 1.0)).collect()))
}

#[test]
fn real_gradients_show_kernel_sign_structure_above_random() {
    // Train the native net briefly, then measure consistency of real conv
    // gradients vs random kernels — the paper's Fig. 7(a) vs (b) contrast.
    let ds = SynthDataset::new(DatasetSpec::Cifar10, 3);
    let mut rng = Rng::new(4);
    let batch = ds.sample(&mut rng, 64, 0.0);
    let mut net = NativeNet::new(10, 5);
    // A few steps so gradients reflect a training trajectory.
    for _ in 0..5 {
        let (_, _, g) = net.grad_batch(&batch);
        net.apply(&g, 0.3);
    }
    let (_, _, g) = net.grad_batch(&batch);
    let mg = net.grads_to_model(&g);
    let conv = &mg.layers[0];
    let real = mean_consistency(conv.kernels().unwrap().map(|k| k.to_vec()));
    let baseline = random_baseline(&mut rng);
    assert!(
        real > baseline + 0.08,
        "real consistency {real:.3} should exceed random {baseline:.3}"
    );
}

#[test]
fn gradgen_matches_real_gradient_statistics() {
    // 1) Kernel sign consistency of the generator falls in the same band
    //    as real conv gradients (well above random).
    let metas = vec![fedgec::tensor::LayerMeta::conv("c", 128, 8, 3, 3)];
    let mut gen = GradGen::new(metas, GradGenConfig::default(), 9);
    let g = gen.next_round();
    let synth = mean_consistency(g.layers[0].kernels().unwrap().map(|k| k.to_vec()));
    let mut rng = Rng::new(10);
    let baseline = random_baseline(&mut rng);
    assert!(synth > baseline + 0.15, "synth {synth:.3} vs random {baseline:.3}");

    // 2) Temporal |g| correlation in a realistic band (real SGD gradients
    //    correlate across adjacent epochs but far from perfectly).
    let metas = vec![fedgec::tensor::LayerMeta::conv("c", 128, 8, 3, 3)];
    let mut gen = GradGen::new(metas, GradGenConfig::default(), 11);
    let a: Vec<f32> = gen.next_round().layers[0].data.iter().map(|x| x.abs()).collect();
    let b: Vec<f32> = gen.next_round().layers[0].data.iter().map(|x| x.abs()).collect();
    let corr = stats::pearson(&a, &b);
    assert!((0.2..0.95).contains(&corr), "temporal corr {corr}");
}

#[test]
fn real_native_gradients_have_temporal_magnitude_correlation() {
    let ds = SynthDataset::new(DatasetSpec::Cifar10, 6);
    let mut rng = Rng::new(7);
    let batch = ds.sample(&mut rng, 64, 0.0);
    let mut net = NativeNet::new(10, 8);
    let (_, _, g1) = net.grad_batch(&batch);
    net.apply(&g1, 0.1);
    let (_, _, g2) = net.grad_batch(&batch);
    let a: Vec<f32> = g1.conv_w.iter().map(|x| x.abs()).collect();
    let b: Vec<f32> = g2.conv_w.iter().map(|x| x.abs()).collect();
    let corr = stats::pearson(&a, &b);
    assert!(corr > 0.25, "adjacent-step |g| correlation {corr}");
}

#[test]
fn dataset_complexity_ordering_preserved() {
    // Harder datasets => lower compressibility. Check via residual-entropy
    // proxy: FedGEC CR ordering fmnist >= cifar >= caltech on generator
    // output (the paper's observed trend).
    use fedgec::compress::spec::{CodecSpec, SpecDefaults};
    let metas = fedgec::tensor::model_zoo::ModelArch::MicroResNet.layers(10);
    let mut ratios = Vec::new();
    for spec in [DatasetSpec::Fmnist, DatasetSpec::Cifar10, DatasetSpec::Caltech101] {
        let mut gen = GradGen::new(metas.clone(), GradGenConfig::for_dataset(spec), 12);
        let mut codec =
            CodecSpec::parse_with("fedgec", &SpecDefaults::with_rel_eb(3e-2)).unwrap().build();
        let mut raw = 0;
        let mut comp = 0;
        for _ in 0..3 {
            let g = gen.next_round();
            raw += g.byte_size();
            comp += codec.compress(&g).unwrap().len();
        }
        ratios.push(raw as f64 / comp as f64);
    }
    assert!(
        ratios[0] > ratios[2],
        "fmnist CR {:.2} should exceed caltech CR {:.2} (all: {ratios:?})",
        ratios[0],
        ratios[2]
    );
}
