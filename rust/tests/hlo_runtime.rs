//! Cross-layer integration: the PJRT-loaded HLO artifacts (L1 Pallas
//! kernel + L2 JAX model) against the native Rust implementations.
//!
//! Requires `make artifacts`; every test self-skips when the artifacts
//! directory is absent so `cargo test` stays green pre-build.

use std::cell::RefCell;
use std::rc::Rc;

use fedgec::compress::fused::{fused_encode, FusedEncodeOut, FusedParams};
use fedgec::compress::pipeline::PredictBackend;
use fedgec::runtime::engine::HloPredictEngine;
use fedgec::runtime::manifest::Manifest;
use fedgec::runtime::trainer::HloTrainer;
use fedgec::runtime::Runtime;
use fedgec::train::data::{DatasetSpec, SynthDataset};
use fedgec::util::rng::Rng;
use fedgec::util::stats;

fn runtime() -> Option<Rc<RefCell<Runtime>>> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {dir:?}");
        return None;
    }
    Some(Rc::new(RefCell::new(Runtime::new(dir).expect("create PJRT runtime"))))
}

/// The HLO predict engine must agree with the native fused path: ghat to
/// ~1 ulp (XLA may fuse mul+add into FMA) and the EMA memory likewise.
#[test]
fn hlo_engine_matches_native_predict() {
    let Some(rt) = runtime() else { return };
    let mut engine = HloPredictEngine::new(rt, 4096).expect("load kernel artifact");
    let mut rng = Rng::new(11);
    for &n in &[4096usize, 5000, 12288] {
        let prev_abs: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let grad: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let signs: Vec<f32> = (0..n)
            .map(|_| match rng.next_below(3) {
                0 => -1.0,
                1 => 0.0,
                _ => 1.0,
            })
            .collect();
        let abs: Vec<f32> = grad.iter().map(|x| x.abs()).collect();
        let (mu_curr, sigma_curr) = stats::mean_std(&abs);
        let (mu_prev, sigma_prev) = stats::mean_std(&prev_abs);
        let p = FusedParams {
            beta: 0.9,
            mu_curr,
            sigma_curr,
            mu_prev,
            sigma_prev,
            two_delta: 0.01,
            delta: 0.005,
        };
        // Native path memory evolution.
        let mut mem_native = vec![0.1f32; n];
        let mut out = FusedEncodeOut::default();
        fused_encode(&grad, &prev_abs, &mut mem_native, &signs, &p, &mut out);
        // Engine path.
        let mut mem_hlo = vec![0.1f32; n];
        let ghat = engine.predict(&prev_abs, &mut mem_hlo, &signs, &p).expect("engine predict");
        assert_eq!(ghat.len(), n);
        for i in 0..n {
            let m_err = (mem_hlo[i] - mem_native[i]).abs();
            let tol = 1e-5f32.max(mem_native[i].abs() * 1e-5);
            assert!(m_err <= tol, "n={n} i={i}: mem {} vs {}", mem_hlo[i], mem_native[i]);
        }
        // Spot-check ghat against the native formula.
        let inv_sigma_prev = 1.0 / sigma_prev.max(1e-12);
        for i in (0..n).step_by(97) {
            let z = (prev_abs[i] - mu_prev) * inv_sigma_prev;
            let m = 0.9f32 * 0.1 + 0.1 * z;
            let a = (m * sigma_curr + mu_curr).max(0.0);
            let want = signs[i] * a;
            let tol = 1e-5f32.max(want.abs() * 1e-5);
            assert!((ghat[i] - want).abs() <= tol, "i={i}: {} vs {want}", ghat[i]);
        }
    }
}

/// Full-pipeline equivalence: a FedGEC codec with the HLO engine on both
/// sides stays synchronized and within the error bound over rounds.
#[test]
fn hlo_engine_roundtrips_through_codec() {
    use fedgec::compress::pipeline::{FedgecCodec, FedgecConfig};
    use fedgec::compress::quant::ErrorBound;
    use fedgec::compress::GradientCodec;
    use fedgec::tensor::{LayerGrad, LayerMeta, ModelGrad};

    let Some(rt) = runtime() else { return };
    let cfg = FedgecConfig { error_bound: ErrorBound::Rel(1e-2), ..Default::default() };
    let mk = |rt: &Rc<RefCell<Runtime>>| {
        let engine = HloPredictEngine::new(rt.clone(), 4096).unwrap();
        FedgecCodec::with_engine(cfg.clone(), Box::new(engine))
    };
    let mut client = mk(&rt);
    let mut server = mk(&rt);
    let mut rng = Rng::new(5);
    let n_kernels = 600; // > 1 block with T=9
    let t = 9;
    let metas = vec![LayerMeta::conv("c", n_kernels, 1, 3, 3)];
    for round in 0..3 {
        let mut data = Vec::with_capacity(n_kernels * t);
        for _ in 0..n_kernels {
            let dom: f32 = if rng.chance(0.5) { 1.0 } else { -1.0 };
            for _ in 0..t {
                let flip = rng.chance(0.1);
                data.push(dom * if flip { -1.0 } else { 1.0 } * (0.1 + rng.next_f32()));
            }
        }
        let grads = ModelGrad { layers: vec![LayerGrad::new(metas[0].clone(), data)] };
        let payload = client.compress(&grads).expect("compress");
        let recon = server.decompress(&payload, &metas).expect("decompress");
        let (lo, hi) = stats::finite_min_max(&grads.layers[0].data);
        let delta = cfg.error_bound.resolve(lo, hi) as f32;
        for (r, g) in recon.layers[0].data.iter().zip(&grads.layers[0].data) {
            assert!((r - g).abs() <= delta * 1.0001, "round {round}");
        }
        assert_eq!(
            client.state.fingerprint(),
            server.state.fingerprint(),
            "state divergence at round {round}"
        );
    }
}

/// The L2 train_epoch graph actually learns: loss decreases over epochs on
/// learnable synthetic data, driven entirely from Rust through PJRT.
#[test]
fn hlo_trainer_learns() {
    let Some(rt) = runtime() else { return };
    let manifest = Manifest::load(Runtime::default_dir()).unwrap();
    let trainer = HloTrainer::new(rt, &manifest, "micro_resnet_c10").expect("load trainer");
    let ds = SynthDataset::new(DatasetSpec::Cifar10, 3);
    let mut rng = Rng::new(4);
    let per_epoch = manifest.batches_per_epoch * manifest.batch_size;
    let slice = ds.sample(&mut rng, per_epoch, 0.0);
    let eval = ds.sample(&mut rng, manifest.eval_n, 0.0);
    let mut params = trainer.init_params(7);
    let mut first = None;
    let mut last = 0.0f32;
    for _ in 0..6 {
        let (new_params, loss) = trainer.train_epoch(&params, &slice.xs, &slice.ys, 0.05).unwrap();
        params = new_params;
        if first.is_none() {
            first = Some(loss);
        }
        last = loss;
    }
    let first = first.unwrap();
    assert!(last < first * 0.85, "loss {first} -> {last}");
    let (eloss, eacc) = trainer.eval(&params, &eval.xs, &eval.ys).unwrap();
    assert!(eloss.is_finite());
    assert!(eacc > 0.15, "accuracy {eacc} should beat 10-class chance");
}

/// Both micro architectures load and run one epoch.
#[test]
fn both_models_run() {
    let Some(rt) = runtime() else { return };
    let manifest = Manifest::load(Runtime::default_dir()).unwrap();
    for key in ["micro_resnet_c10", "micro_inception_c10"] {
        let trainer = HloTrainer::new(rt.clone(), &manifest, key).expect(key);
        let ds = SynthDataset::new(DatasetSpec::Cifar10, 1);
        let mut rng = Rng::new(1);
        let per_epoch = manifest.batches_per_epoch * manifest.batch_size;
        let slice = ds.sample(&mut rng, per_epoch, 0.0);
        let params = trainer.init_params(1);
        let (new_params, loss) =
            trainer.train_epoch(&params, &slice.xs, &slice.ys, 0.05).expect("epoch");
        assert!(loss.is_finite() && loss > 0.0, "{key}: loss {loss}");
        assert_eq!(new_params.tensors.len(), params.tensors.len());
        // Params must actually change.
        assert!(new_params.tensors[0] != params.tensors[0], "{key}: params frozen");
    }
}
