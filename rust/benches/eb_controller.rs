//! Rate–accuracy envelope of the adaptive error-bound controller
//! (DESIGN.md §15): real federated training with `ebc=plateau` vs a grid
//! of fixed bounds, reported as final accuracy + total traffic + the
//! resulting communication time across the bandwidth_sweep scenarios
//! (1 Mbps – 1 Gbps).
//!
//! The claim under test: the controller matches the accuracy of the best
//! fixed bound while moving strictly fewer bytes than the bound a
//! fixed-eb deployment would have to keep to *guarantee* that accuracy
//! (the tightest of the near-tied settings — a fixed-eb run cannot know
//! in advance that a looser bound would have been safe; the controller
//! discovers it online from the loss signal). Asserted in-bench, and the
//! `envelope` cell is floored by `results/baselines/eb_controller.json`.

mod bench_util;

use bench_util::*;
use fedgec::config::RunConfig;
use fedgec::coordinator::run_local;
use fedgec::fl::transport::bandwidth::LinkSpec;
use fedgec::metrics::{fmt_duration, Table};

const MBPS_POINTS: [f64; 7] = [1.0, 5.0, 10.0, 50.0, 100.0, 500.0, 1000.0];

/// Near-tie band on final accuracy: runs within this of the best fixed
/// setting count as "same accuracy" (deterministic seeds, but the easy
/// synthetic tasks land eb ≤ 3e-2 within training noise of each other).
const ACC_TOL: f32 = 0.03;

struct RunRow {
    label: String,
    eb: String,
    acc: f32,
    up: usize,
    down: usize,
}

impl RunRow {
    fn total(&self) -> usize {
        self.up + self.down
    }
}

fn run_one(base: &RunConfig, label: &str, ebc: &str, eb: f64) -> RunRow {
    let mut cfg = base.clone();
    cfg.ebc = ebc.into();
    cfg.rel_error_bound = eb;
    let summary = run_local(&cfg).unwrap();
    RunRow {
        label: label.to_string(),
        eb: format!("{eb}"),
        acc: summary.final_accuracy.unwrap(),
        up: summary.total_payload(),
        down: summary.total_downlink(),
    }
}

fn main() {
    banner("eb_controller", "adaptive-eb envelope (Fig. 9 x Fig. 11 axes)");
    let rounds = if full_mode() {
        12
    } else if quick_mode() {
        4
    } else {
        8
    };
    let base = RunConfig {
        model: "native".into(),
        dataset: fedgec::train::data::DatasetSpec::Caltech101,
        n_clients: 3,
        rounds,
        samples_per_client: 64,
        local_lr: 0.15,
        server_lr: 0.15,
        codec: "fedgec".into(),
        link: LinkSpec::infinite(),
        eval_every: 0,
        seed: 7,
        class_skew: 0.6,
        ..Default::default()
    };

    // Fixed-eb grid spanning the fig9 knee, tight → loose.
    let fixed: Vec<RunRow> = [2e-3, 2e-2, 1e-1]
        .iter()
        .map(|&eb| run_one(&base, &format!("fixed eb={eb}"), "fixed", eb))
        .collect();
    // The controller starts at the paper's safe knee (3e-2) and tightens
    // on loss plateaus (patience 2, factor 0.5, clamped at base/16).
    let ctl = run_one(&base, "ebc=plateau", "plateau", 3e-2);

    let best_acc = fixed.iter().map(|r| r.acc).fold(f32::MIN, f32::max);
    // The bound a fixed deployment must keep to guarantee best_acc: the
    // most expensive of the near-tied settings.
    let reference = fixed
        .iter()
        .filter(|r| r.acc >= best_acc - ACC_TOL)
        .max_by_key(|r| r.total())
        .expect("at least one fixed run ties the best accuracy");
    let envelope = ctl.acc >= best_acc - ACC_TOL && ctl.total() < reference.total();

    let mut headers: Vec<String> = ["run", "eb", "final acc", "up MB", "down MB", "total MB"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    for mbps in MBPS_POINTS {
        headers.push(format!("t@{mbps:.0}Mbps"));
    }
    headers.push("envelope".into());
    let headers: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut table = Table::new(
        "eb_controller: rate-accuracy envelope, ebc=plateau vs fixed eb grid",
        &headers,
    );
    for (r, env_cell) in fixed
        .iter()
        .map(|r| (r, "-".to_string()))
        .chain(std::iter::once((&ctl, if envelope { "1" } else { "0" }.to_string())))
    {
        let mut row = vec![
            r.label.clone(),
            r.eb.clone(),
            format!("{:.3}", r.acc),
            format!("{:.2}", r.up as f64 / 1e6),
            format!("{:.2}", r.down as f64 / 1e6),
            format!("{:.2}", r.total() as f64 / 1e6),
        ];
        for mbps in MBPS_POINTS {
            let link = LinkSpec::sym(mbps * 1e6, std::time::Duration::ZERO);
            let t = link.transmit_time(r.up) + link.downlink_time(r.down);
            row.push(fmt_duration(t));
        }
        row.push(env_cell);
        table.row(row);
    }
    table.print();
    table.save_csv("eb_controller").unwrap();
    let path = table.save_json("eb_controller").unwrap();
    println!("saved {path:?}");
    println!(
        "reference (tightest near-tied fixed bound): {} — acc {:.3}, {:.2} MB; \
         controller: acc {:.3}, {:.2} MB",
        reference.label,
        reference.acc,
        reference.total() as f64 / 1e6,
        ctl.acc,
        ctl.total() as f64 / 1e6
    );
    assert!(
        ctl.acc >= best_acc - ACC_TOL,
        "controller accuracy {:.3} fell more than {ACC_TOL} below the best fixed bound {:.3}",
        ctl.acc,
        best_acc
    );
    assert!(
        ctl.total() < reference.total(),
        "controller moved {} bytes, not strictly fewer than the reference fixed bound's {}",
        ctl.total(),
        reference.total()
    );
    println!("envelope holds: same accuracy, strictly fewer bytes at every bandwidth point");
}
