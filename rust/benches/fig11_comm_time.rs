//! Paper Fig. 11: end-to-end communication time.
//! Upper panel: per model, total comm time across error bounds at a fixed
//! 10 Mbps uplink (Ours vs SZ3 vs uncompressed dashed line).
//! Lower panel: across bandwidths 1 Mbps–1 Gbps at fixed eb = 3e-2, with
//! the break-even bandwidth (paper's stars, ~620 Mbps).
//! Plus: the frame-streaming panel — per-layer frames pipelined into the
//! link (compression of layer i+1 overlapping transmission of layer i)
//! vs the monolithic compress-then-send path.
//!
//! Methodology as in the paper [43]: measured codec wall time + analytic
//! transmission time S′/B over the simulated link; 100 rounds in full
//! mode (scaled-down subset otherwise).

mod bench_util;

use std::time::Duration;

use bench_util::*;
use fedgec::compress::spec::{CodecSpec, SpecDefaults};
use fedgec::compress::GradientCodec;
use fedgec::fl::transport::bandwidth::LinkSpec;
use fedgec::metrics::{fmt_duration, Table};
use fedgec::train::data::DatasetSpec;
use fedgec::train::gradgen::{GradGen, GradGenConfig};

fn build(codec_name: &str, eb: f64) -> Box<dyn GradientCodec> {
    CodecSpec::parse_with(codec_name, &SpecDefaults::with_rel_eb(eb)).unwrap().build()
}

struct Measured {
    raw: usize,
    payload: usize,
    codec_time: Duration,
}

fn measure(
    arch: fedgec::tensor::model_zoo::ModelArch,
    codec_name: &str,
    eb: f64,
    rounds: usize,
) -> Measured {
    use fedgec::compress::state::CodecState;
    use fedgec::compress::CodecEngine;
    let metas = arch.layers(10);
    let mut gen = GradGen::new(metas.clone(), GradGenConfig::for_dataset(DatasetSpec::Cifar10), 4);
    let mut client = build(codec_name, eb);
    // Server side: the production shape — one stateless engine plus an
    // explicit per-client state handle.
    let mut engine = CodecSpec::parse_with(codec_name, &SpecDefaults::with_rel_eb(eb))
        .unwrap()
        .build_engine();
    let mut state = CodecState::default();
    let mut m = Measured { raw: 0, payload: 0, codec_time: Duration::ZERO };
    for _ in 0..rounds {
        let g = gen.next_round();
        m.raw += g.byte_size();
        let t0 = std::time::Instant::now();
        let p = client.compress(&g).unwrap();
        engine.decode_payload(&p, &metas, &mut state).unwrap();
        m.codec_time += t0.elapsed();
        m.payload += p.len();
    }
    m
}

fn scale(m: &Measured, factor: f64) -> Measured {
    Measured {
        raw: (m.raw as f64 * factor) as usize,
        payload: (m.payload as f64 * factor) as usize,
        codec_time: Duration::from_secs_f64(m.codec_time.as_secs_f64() * factor),
    }
}

fn main() {
    banner("fig11_comm_time", "Fig. 11");
    let measured_rounds = if full_mode() { 10 } else { 3 };
    let total_rounds = 100; // the paper's round count; measured rounds are scaled up
    let factor = total_rounds as f64 / measured_rounds as f64;

    // ── Upper panel: comm time vs eb at 10 Mbps. ──
    let link10 = LinkSpec::sym(10e6, Duration::ZERO);
    let mut upper = Table::new(
        "Fig. 11 upper: total comm time, 100 rounds @ 10 Mbps",
        &["model", "eb", "uncompressed", "sz3", "ours", "ours vs uncomp"],
    );
    for arch in grid_models() {
        for &eb in &[1e-2, 3e-2, 5e-2] {
            let ours = scale(&measure(arch, "ours", eb, measured_rounds), factor);
            let sz3 = scale(&measure(arch, "sz3", eb, measured_rounds), factor);
            let unc = link10.transmit_time(ours.raw);
            let t_ours = ours.codec_time + link10.transmit_time(ours.payload);
            let t_sz3 = sz3.codec_time + link10.transmit_time(sz3.payload);
            upper.row(vec![
                arch.name().into(),
                format!("{eb}"),
                fmt_duration(unc),
                fmt_duration(t_sz3),
                fmt_duration(t_ours),
                format!("-{:.1}%", 100.0 * (1.0 - t_ours.as_secs_f64() / unc.as_secs_f64())),
            ]);
        }
    }
    upper.print();
    upper.save_csv("fig11_upper_eb_sweep").unwrap();

    // ── Lower panel: comm time vs bandwidth at eb = 3e-2. ──
    let eb = 3e-2;
    let arch = grid_models()[0];
    let ours = scale(&measure(arch, "ours", eb, measured_rounds), factor);
    let sz3 = scale(&measure(arch, "sz3", eb, measured_rounds), factor);
    let mut lower = Table::new(
        &format!("Fig. 11 lower: {} @ eb=3e-2 across bandwidths", arch.name()),
        &["bandwidth (Mbps)", "uncompressed", "sz3", "ours", "ours gain"],
    );
    let mut breakeven_seen = false;
    for &mbps in &[1.0, 2.0, 5.0, 10.0, 50.0, 100.0, 200.0, 500.0, 1000.0] {
        let link = LinkSpec::sym(mbps * 1e6, Duration::ZERO);
        let unc = link.transmit_time(ours.raw);
        let t_ours = ours.codec_time + link.transmit_time(ours.payload);
        let t_sz3 = sz3.codec_time + link.transmit_time(sz3.payload);
        let gain = 1.0 - t_ours.as_secs_f64() / unc.as_secs_f64();
        if gain < 0.0 && !breakeven_seen {
            breakeven_seen = true;
        }
        lower.row(vec![
            format!("{mbps}"),
            fmt_duration(unc),
            fmt_duration(t_sz3),
            fmt_duration(t_ours),
            format!("{:+.1}%", gain * 100.0),
        ]);
    }
    lower.print();
    lower.save_csv("fig11_lower_bandwidth_sweep").unwrap();

    let saved_bits = (ours.raw - ours.payload) as f64 * 8.0;
    let breakeven_mbps = saved_bits / ours.codec_time.as_secs_f64() / 1e6;
    println!(
        "break-even bandwidth ≈ {breakeven_mbps:.0} Mbps (paper: ~620 Mbps on Polaris; \
         scales with codec throughput)"
    );

    // ── Streaming panel: per-layer frames pipelined into the link. ──
    // Warm one round so the predictor has history, then time every
    // layer's frame individually through the session API and schedule
    // the frames onto a constrained link: monolithic = Σcomp + Σtx,
    // streamed = pipeline completion (comp of layer i+1 overlaps tx of
    // layer i).
    let metas = arch.layers(10);
    let mut gen =
        GradGen::new(metas.clone(), GradGenConfig::for_dataset(DatasetSpec::Cifar10), 4);
    let mut client = build("ours", eb);
    client.compress(&gen.next_round()).unwrap(); // warm predictor state
    let g = gen.next_round();
    let (layer_comp, layer_wire) = time_layer_frames(client.as_mut(), &g);
    let total_comp: Duration = layer_comp.iter().sum();
    let total_wire: usize = layer_wire.iter().sum();
    let mut stream = Table::new(
        &format!(
            "Fig. 11 streaming: {} @ eb=3e-2, {} layers/round, frame pipeline vs monolithic",
            arch.name(),
            g.layers.len()
        ),
        &["bandwidth (Mbps)", "monolithic", "streamed", "overlap win"],
    );
    let mut best_win = 0.0f64;
    for &mbps in &[1.0, 10.0, 50.0, 100.0, 500.0] {
        let link = LinkSpec::sym(mbps * 1e6, Duration::ZERO);
        let mono = total_comp + link.transmit_time(total_wire);
        let streamed = pipelined_time(&layer_comp, &layer_wire, &link);
        let win = 1.0 - streamed.as_secs_f64() / mono.as_secs_f64();
        best_win = best_win.max(win);
        stream.row(vec![
            format!("{mbps}"),
            fmt_duration(mono),
            fmt_duration(streamed),
            format!("-{:.1}%", win * 100.0),
        ]);
        // The pipeline can never be slower than compress-then-send, and
        // never faster than its two lower bounds.
        assert!(
            streamed.as_secs_f64() <= mono.as_secs_f64() * 1.0001,
            "streamed {streamed:?} vs monolithic {mono:?} at {mbps} Mbps"
        );
        let floor = total_comp
            .as_secs_f64()
            .max(link.transmit_time(total_wire).as_secs_f64());
        assert!(streamed.as_secs_f64() >= floor * 0.9999);
    }
    stream.print();
    stream.save_csv("fig11_streaming_overlap").unwrap();
    println!(
        "max overlap win {:.1}% (bound: min(comp, tx) fully hidden when they balance)",
        best_win * 100.0
    );
    assert!(
        best_win > 0.0,
        "frame streaming must reduce simulated wall-clock on some constrained link"
    );

    // Shape checks: large gains at <=10 Mbps; gain shrinks with bandwidth.
    let link1 = LinkSpec::sym(1e6, Duration::ZERO);
    let unc1 = link1.transmit_time(ours.raw).as_secs_f64();
    let t1 = (ours.codec_time + link1.transmit_time(ours.payload)).as_secs_f64();
    assert!(1.0 - t1 / unc1 > 0.7, "at 1 Mbps the reduction should exceed 70%");

    // ── Downlink panel: encode-once global-delta broadcast vs the raw
    // f32 fan-out. The server compresses θ_t − θ_ref once per round
    // (one cross-round predictor state for the whole federation) and
    // every client pulls the same encoded frames, so the codec cost
    // amortizes over the fan-out while the transfer shrinks by the
    // delta's compression ratio. ──
    {
        let fan_out = 16usize;
        let metas = arch.layers(10);
        let dl_rounds = if full_mode() { 6 } else { 3 };
        // The global model walks one aggregated-SGD step per round; the
        // delta is the cross-round-smooth signal the predictor feeds on.
        let (raw_bytes, delta_bytes, enc_time) =
            fedgec::train::gradgen::measure_downlink_delta(
                &metas,
                GradGenConfig::for_dataset(DatasetSpec::Cifar10),
                21,
                1e-3,
                fan_out,
                dl_rounds,
            )
            .unwrap();
        let per_round = delta_bytes / dl_rounds;
        let enc_per_round = enc_time / dl_rounds as u32;
        let mut dl = Table::new(
            &format!(
                "Fig. 11 downlink: {} global-delta broadcast @ eb=1e-3, {fan_out}-client fan-out",
                arch.name()
            ),
            &["down bandwidth (Mbps)", "raw broadcast", "delta broadcast", "win"],
        );
        for &mbps in &[10.0, 50.0, 100.0, 500.0] {
            // Zero latency like every other fig11 panel: only the
            // bandwidth term is compared (down = 4x the uplink rate).
            let link = LinkSpec {
                bits_per_sec: mbps / 4.0 * 1e6,
                down_bits_per_sec: mbps * 1e6,
                latency: Duration::ZERO,
            };
            let t_raw = link.downlink_time(raw_bytes);
            // Encode once → each client pays transfer + its 1/fan_out
            // share of the codec pass.
            let t_delta = link.downlink_time(per_round) + enc_per_round / fan_out as u32;
            dl.row(vec![
                format!("{mbps}"),
                fmt_duration(t_raw),
                fmt_duration(t_delta),
                format!("-{:.1}%", 100.0 * (1.0 - t_delta.as_secs_f64() / t_raw.as_secs_f64())),
            ]);
        }
        dl.print();
        dl.save_csv("fig11_downlink_broadcast").unwrap();
        let down_cr = raw_bytes as f64 / per_round as f64;
        println!("downlink delta CR {down_cr:.2} at eb=1e-3 (one encode fanned out x{fan_out})");
        assert!(down_cr > 1.5, "warm global-delta broadcast should compress: {down_cr:.2}");
    }
}
