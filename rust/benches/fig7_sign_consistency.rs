//! Paper Fig. 7: kernel sign-consistency statistics — (a) distribution
//! for a real conv layer vs (b) random kernels, (c) average consistency
//! across conv layers, (d) stability across training epochs.
//!
//! Real gradients come from the native conv net; when HLO artifacts are
//! present the micro-CNN's real JAX gradients are included too.

mod bench_util;

use bench_util::*;
use fedgec::metrics::Table;
use fedgec::tensor::sign_consistency;
use fedgec::train::data::{DatasetSpec, SynthDataset};
use fedgec::train::native::NativeNet;
use fedgec::util::rng::Rng;
use fedgec::util::stats;

fn consistency_hist(values: &[f64]) -> Vec<u64> {
    let mut bins = vec![0u64; 10];
    for &v in values {
        let b = ((v * 10.0) as usize).min(9);
        bins[b] += 1;
    }
    bins
}

fn main() {
    banner("fig7_sign_consistency", "Fig. 7");
    let ds = SynthDataset::new(DatasetSpec::Cifar10, 3);
    let mut rng = Rng::new(6);
    let batch = ds.sample(&mut rng, 64, 0.0);
    let mut net = NativeNet::new(10, 4);
    // Track consistency across epochs (Fig. 7d) while training.
    let epochs = if full_mode() { 40 } else { 20 };
    let mut per_epoch = Vec::new();
    let mut final_layer_consistencies: Vec<f64> = Vec::new();
    for _ in 0..epochs {
        let (_, _, g) = net.grad_batch(&batch);
        let mg = net.grads_to_model(&g);
        let conv = &mg.layers[0];
        let cons: Vec<f64> =
            conv.kernels().unwrap().map(sign_consistency).collect();
        per_epoch.push(stats::mean(&cons.iter().map(|&c| c as f32).collect::<Vec<_>>()) as f64);
        final_layer_consistencies = cons;
        net.apply(&g, 0.2);
    }

    // (a) real-layer distribution vs (b) random baseline.
    let mut rng2 = Rng::new(8);
    let random: Vec<f64> = (0..2000)
        .map(|_| {
            let k: Vec<f32> = (0..9).map(|_| rng2.normal_f32(0.0, 1.0)).collect();
            sign_consistency(&k)
        })
        .collect();
    let mut dist = Table::new(
        "Fig. 7(a,b): sign-consistency distribution (10 bins over [0,1])",
        &["bin", "real conv layer", "random kernels"],
    );
    let hr = consistency_hist(&final_layer_consistencies);
    let hb = consistency_hist(&random);
    for i in 0..10 {
        dist.row(vec![format!("{:.1}", i as f64 / 10.0), hr[i].to_string(), hb[i].to_string()]);
    }
    dist.print();
    dist.save_csv("fig7_distribution").unwrap();

    // (d) across epochs.
    let mut ep = Table::new("Fig. 7(d): mean consistency across epochs", &["epoch", "mean"]);
    for (i, c) in per_epoch.iter().enumerate() {
        ep.row(vec![i.to_string(), format!("{c:.4}")]);
    }
    ep.save_csv("fig7_across_epochs").unwrap();

    let real_mean =
        final_layer_consistencies.iter().sum::<f64>() / final_layer_consistencies.len() as f64;
    let rand_mean = random.iter().sum::<f64>() / random.len() as f64;
    println!(
        "\nreal mean consistency {real_mean:.3} vs random {rand_mean:.3}; \
         across-epoch range [{:.3}, {:.3}]",
        per_epoch.iter().cloned().fold(f64::INFINITY, f64::min),
        per_epoch.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    );
    // (c) across layers, from the HLO micro model if artifacts exist:
    // approximated here by both native conv+gradgen full-scale layers.
    use fedgec::tensor::model_zoo::ModelArch;
    use fedgec::train::gradgen::{GradGen, GradGenConfig};
    let metas = ModelArch::ResNet18.layers(10);
    let mut gen = GradGen::new(metas.clone(), GradGenConfig::default(), 9);
    let g = gen.next_round();
    let mut layers_tbl =
        Table::new("Fig. 7(c): mean consistency per conv layer (ResNet-18)", &["layer", "mean"]);
    let mut layer_means = Vec::new();
    for l in g.layers.iter().filter(|l| l.meta.kind.kernel_size() == Some(9)).take(16) {
        let cons: Vec<f32> =
            l.kernels().unwrap().map(|k| sign_consistency(k) as f32).collect();
        let m = stats::mean(&cons) as f64;
        layer_means.push(m);
        layers_tbl.row(vec![l.meta.name.clone(), format!("{m:.4}")]);
    }
    layers_tbl.save_csv("fig7_across_layers").unwrap();
    let spread = layer_means.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        - layer_means.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("across-layer mean spread {spread:.3} (paper: closely clustered)");

    assert!(real_mean > rand_mean + 0.05, "real kernels must beat random baseline");
    assert!(
        per_epoch.iter().all(|&c| c > rand_mean),
        "consistency should stay above random throughout training"
    );
}
