//! Design-choice ablations beyond the paper's tables (DESIGN.md §6):
//!
//! * lossless backend: Zstd vs Deflate vs from-scratch LZ77 vs none;
//! * sign-consistency threshold τ sweep;
//! * EMA decay β sweep;
//! * predictor components: full FedGEC vs magnitude-only vs sign-only
//!   (via τ/β degenerate settings) vs no predictor (SZ3 tail).

mod bench_util;

use bench_util::*;
use fedgec::compress::lossless::Backend;
use fedgec::compress::pipeline::{FedgecCodec, FedgecConfig};
use fedgec::compress::quant::ErrorBound;
use fedgec::compress::GradientCodec;
use fedgec::metrics::Table;
use fedgec::tensor::model_zoo::ModelArch;
use fedgec::train::gradgen::{GradGen, GradGenConfig};

fn run_cr(cfg: FedgecConfig, rounds: usize, seed: u64) -> f64 {
    let metas = ModelArch::ResNet18.layers(10);
    let mut gen = GradGen::new(metas, GradGenConfig::default(), seed);
    let mut codec = FedgecCodec::new(cfg);
    let (mut raw, mut comp) = (0usize, 0usize);
    for _ in 0..rounds {
        let g = gen.next_round();
        raw += g.byte_size();
        comp += codec.compress(&g).unwrap().len();
    }
    raw as f64 / comp as f64
}

fn main() {
    banner("ablation_design", "DESIGN.md §6 ablations");
    let rounds = grid_rounds();
    let eb = ErrorBound::Rel(3e-2);

    // ── Lossless backend. ──
    let mut t = Table::new("ablation: lossless backend (eb=3e-2)", &["backend", "CR"]);
    for backend in [Backend::Zstd(3), Backend::Zstd(9), Backend::Deflate, Backend::OwnLz, Backend::None]
    {
        let cfg = FedgecConfig { error_bound: eb, backend, ..Default::default() };
        let label = match backend {
            Backend::Zstd(l) => format!("zstd(level {l})"),
            b => b.name().to_string(),
        };
        t.row(vec![label, format!("{:.2}", run_cr(cfg, rounds, 1))]);
    }
    t.print();
    t.save_csv("ablation_backend").unwrap();

    // ── τ sweep (sign-consistency threshold). ──
    let mut t = Table::new("ablation: consistency threshold tau", &["tau", "CR"]);
    for tau in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let cfg = FedgecConfig { error_bound: eb, tau, ..Default::default() };
        t.row(vec![format!("{tau}"), format!("{:.2}", run_cr(cfg, rounds, 2))]);
    }
    t.print();
    t.save_csv("ablation_tau").unwrap();

    // ── β sweep (EMA decay). ──
    let mut t = Table::new("ablation: EMA decay beta", &["beta", "CR"]);
    for beta in [0.0f32, 0.5, 0.9, 0.99] {
        let cfg = FedgecConfig { error_bound: eb, beta, ..Default::default() };
        t.row(vec![format!("{beta}"), format!("{:.2}", run_cr(cfg, rounds, 3))]);
    }
    t.print();
    t.save_csv("ablation_beta").unwrap();

    // ── Component ablation. ──
    // tau=1.0+eps disables most sign prediction (only perfectly-consistent
    // kernels); sign-only is approximated by beta=0 (memory-less magnitude)
    let mut t = Table::new("ablation: predictor components", &["variant", "CR"]);
    let full = run_cr(FedgecConfig { error_bound: eb, ..Default::default() }, rounds, 4);
    let no_sign = run_cr(
        FedgecConfig { error_bound: eb, tau: 1.01, ..Default::default() },
        rounds,
        4,
    );
    let weak_mag = run_cr(
        FedgecConfig { error_bound: eb, beta: 0.0, ..Default::default() },
        rounds,
        4,
    );
    t.row(vec!["full predictor".into(), format!("{full:.2}")]);
    t.row(vec!["no sign prediction (tau>1)".into(), format!("{no_sign:.2}")]);
    t.row(vec!["memoryless magnitude (beta=0)".into(), format!("{weak_mag:.2}")]);
    t.print();
    t.save_csv("ablation_components").unwrap();

    assert!(full > no_sign, "sign prediction must contribute: {full:.2} vs {no_sign:.2}");
    println!("shape check: full predictor beats each ablated variant");
}
