//! Predictor-API ablation: per-layer compression for the fixed magnitude
//! predictors (`pred=ema|last|zero`) vs the per-layer race (`pred=auto`)
//! on the model-zoo CNN's calibrated gradient stream.
//!
//! Two assertions ride along:
//!  * **race exactness** — every `pred=auto` frame's recorded winner is
//!    the argmin of its measured candidate costs (zero slack);
//!  * **auto never loses** — per layer, `pred=auto`'s total bytes stay
//!    within the v3 self-description header overhead (plus a ≤1% state-
//!    drift allowance) of the best fixed predictor's total. `backend=none`
//!    keeps the byte accounting exact.
//!
//! Emits `results/predictor_ablation.csv` + `BENCH_predictor_ablation.json`
//! (uploaded by CI's bench-smoke job).

mod bench_util;

use bench_util::*;
use fedgec::compress::lossless::Backend;
use fedgec::compress::pipeline::{FedgecCodec, FedgecConfig};
use fedgec::compress::predictor::{MagnitudeSel, PredictorSpec, SignSel};
use fedgec::compress::GradientCodec;
use fedgec::metrics::Table;
use fedgec::tensor::model_zoo::ModelArch;
use fedgec::train::data::DatasetSpec;
use fedgec::train::gradgen::{GradGen, GradGenConfig};

const PREDS: [MagnitudeSel; 4] =
    [MagnitudeSel::Ema, MagnitudeSel::Last, MagnitudeSel::Zero, MagnitudeSel::Auto];

fn codec_for(mag: MagnitudeSel) -> FedgecCodec {
    FedgecCodec::new(FedgecConfig {
        backend: Backend::None,
        predictor: PredictorSpec { mag, sign: SignSel::Auto },
        ..Default::default()
    })
}

fn main() {
    banner("predictor_ablation", "per-layer predictor racing (pred=auto)");
    let arch = if quick_mode() { ModelArch::MicroResNet } else { ModelArch::ResNet18 };
    let metas = arch.layers(10);
    let rounds = if full_mode() {
        12
    } else if quick_mode() {
        4
    } else {
        8
    };
    let mut codecs: Vec<FedgecCodec> = PREDS.iter().map(|&m| codec_for(m)).collect();
    // Per predictor, per layer: summed compressed bytes + raw bytes.
    let mut bytes = vec![vec![0usize; metas.len()]; PREDS.len()];
    let mut raw = vec![0usize; metas.len()];
    // Per layer: how often each candidate won the auto race.
    let mut wins: Vec<std::collections::BTreeMap<String, usize>> =
        vec![Default::default(); metas.len()];

    let mut gen = GradGen::new(metas.clone(), GradGenConfig::for_dataset(DatasetSpec::Cifar10), 5);
    for _round in 0..rounds {
        let g = gen.next_round();
        for (li, l) in g.layers.iter().enumerate() {
            raw[li] += l.data.len() * 4;
        }
        for (pi, codec) in codecs.iter_mut().enumerate() {
            let (_, report) = codec.compress_with_report(&g).unwrap();
            for (li, lr) in report.layers.iter().enumerate() {
                bytes[pi][li] += lr.compressed_bytes;
                if PREDS[pi] == MagnitudeSel::Auto && lr.lossy {
                    // Race exactness: recorded winner == measured argmin.
                    assert_eq!(lr.pred_race.len(), 3, "layer {}", lr.name);
                    let min = lr.pred_race.iter().map(|&(_, c)| c).min().unwrap();
                    let winner = lr
                        .pred_race
                        .iter()
                        .find(|(name, _)| *name == lr.pred_tag)
                        .expect("winner in race log");
                    assert_eq!(winner.1, min, "layer {}: winner is not argmin", lr.name);
                    *wins[li].entry(lr.pred_tag.clone()).or_insert(0) += 1;
                }
            }
        }
    }

    let mut table = Table::new(
        "Predictor ablation: per-layer CR for pred=ema/last/zero/auto",
        &["layer", "raw KB", "ema", "last", "zero", "auto", "auto wins", "auto/best"],
    );
    let auto_idx = PREDS.len() - 1;
    for (li, meta) in metas.iter().enumerate() {
        let cr = |pi: usize| raw[li] as f64 / bytes[pi][li].max(1) as f64;
        let best_fixed = (0..auto_idx).map(|pi| bytes[pi][li]).min().unwrap();
        let auto = bytes[auto_idx][li];
        let wins_str = wins[li]
            .iter()
            .map(|(k, v)| format!("{k}:{v}"))
            .collect::<Vec<_>>()
            .join(" ");
        table.row(vec![
            meta.name.clone(),
            format!("{:.1}", raw[li] as f64 / 1024.0),
            format!("{:.2}", cr(0)),
            format!("{:.2}", cr(1)),
            format!("{:.2}", cr(2)),
            format!("{:.2}", cr(3)),
            if wins_str.is_empty() { "-".into() } else { wins_str },
            format!("{:.4}", auto as f64 / best_fixed as f64),
        ]);
        // "Never loses": auto tracks the best fixed predictor per layer
        // to within the v3 header it pays for self-description (≤ 16 B
        // per round per layer) plus a 1% allowance for the ≤2δ recon
        // drift between the runs' mirrored states.
        let slack = rounds * 16 + best_fixed / 100;
        assert!(
            auto <= best_fixed + slack,
            "layer {}: auto {} B vs best fixed {} B (+{} slack)",
            meta.name,
            auto,
            best_fixed,
            slack
        );
    }
    table.print();
    let csv = table.save_csv("predictor_ablation").unwrap();
    let json = table.save_json("predictor_ablation").unwrap();
    println!("saved {csv:?} and {json:?}");

    // Whole-model summary: the race never loses in aggregate either.
    let total = |pi: usize| bytes[pi].iter().sum::<usize>();
    let best_total = (0..auto_idx).map(total).min().unwrap();
    println!(
        "whole-model bytes: ema {} | last {} | zero {} | auto {} (best fixed {})",
        total(0),
        total(1),
        total(2),
        total(3),
        best_total
    );
    assert!(total(auto_idx) <= best_total + metas.len() * rounds * 16 + best_total / 100);
}
