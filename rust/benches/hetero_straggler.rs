//! Heterogeneity experiment (the paper's §1 motivation): a mixed
//! 4G/Wi-Fi/fiber fleet differs ~50× in upload latency, and the
//! synchronous round is gated by the slowest client. Shows how the
//! compressors shrink the straggler-dominated round time.

mod bench_util;

use std::time::Duration;

use bench_util::*;
use fedgec::baselines::{make_codec, qsgd_bits_for_bound};
use fedgec::compress::quant::ErrorBound;
use fedgec::fl::hetero::HeteroFleet;
use fedgec::metrics::{fmt_duration, Table};
use fedgec::tensor::model_zoo::ModelArch;
use fedgec::train::gradgen::{GradGen, GradGenConfig};

fn main() {
    banner("hetero_straggler", "paper §1 heterogeneity motivation");
    let n_clients = 16;
    let fleet = HeteroFleet::mixed(n_clients, (0.4, 0.4, 0.2), 11);
    let metas = ModelArch::ResNet18.layers(10);
    let raw_bytes: usize = metas.iter().map(|m| m.numel * 4).sum();
    println!(
        "fleet: {n_clients} clients (40% 4G / 40% wifi / 20% fiber), \
         payload {:.1} MB, raw disparity {:.1}x\n",
        raw_bytes as f64 / 1e6,
        fleet.disparity(raw_bytes)
    );

    let mut table = Table::new(
        "synchronous round upload time (slowest client gates)",
        &["codec", "CR", "round upload", "vs uncompressed"],
    );
    let t_raw = fleet.round_time(&vec![raw_bytes; n_clients], &vec![Duration::ZERO; n_clients]);
    table.row(vec!["uncompressed".into(), "1.00".into(), fmt_duration(t_raw), "-".into()]);
    for name in ["fedgec", "sz3", "qsgd", "topk+eblc"] {
        // Measure payload + codec time per client (same data distribution,
        // different per-client streams).
        let mut payloads = Vec::with_capacity(n_clients);
        let mut times = Vec::with_capacity(n_clients);
        let mut cr_sum = 0.0;
        for c in 0..n_clients {
            let mut gen =
                GradGen::new(metas.clone(), GradGenConfig::default(), 100 + c as u64);
            let mut codec =
                make_codec(name, ErrorBound::Rel(3e-2), qsgd_bits_for_bound(3e-2)).unwrap();
            // Warm one round, measure the second.
            codec.compress(&gen.next_round()).unwrap();
            let g = gen.next_round();
            let t0 = std::time::Instant::now();
            let p = codec.compress(&g).unwrap();
            times.push(t0.elapsed());
            cr_sum += g.byte_size() as f64 / p.len() as f64;
            payloads.push(p.len());
        }
        let t = fleet.round_time(&payloads, &times);
        table.row(vec![
            name.into(),
            format!("{:.2}", cr_sum / n_clients as f64),
            fmt_duration(t),
            format!("-{:.1}%", 100.0 * (1.0 - t.as_secs_f64() / t_raw.as_secs_f64())),
        ]);
    }
    table.print();
    table.save_csv("hetero_straggler").unwrap();
    println!(
        "shape check: compression cuts the straggler-gated round time by the CR factor \
         (minus codec overhead) — the mechanism behind the paper's end-to-end gains"
    );
}
