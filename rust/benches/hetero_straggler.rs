//! Heterogeneity experiment (the paper's §1 motivation): a mixed
//! 4G/Wi-Fi/fiber fleet differs ~50× in upload latency, and the
//! synchronous round is gated by the slowest client. Shows how the
//! compressors shrink the straggler-dominated round time, and how
//! frame-streaming (per-layer pipeline of compression into the link)
//! shaves the remaining codec latency off the critical path.

mod bench_util;

use std::time::Duration;

use bench_util::*;
use fedgec::compress::spec::{CodecSpec, SpecDefaults};
use fedgec::compress::GradientCodec;
use fedgec::fl::hetero::HeteroFleet;
use fedgec::metrics::{fmt_duration, Table};
use fedgec::tensor::model_zoo::ModelArch;
use fedgec::train::gradgen::{GradGen, GradGenConfig};

fn build(name: &str) -> Box<dyn GradientCodec> {
    CodecSpec::parse_with(name, &SpecDefaults::with_rel_eb(3e-2)).unwrap().build()
}

fn main() {
    banner("hetero_straggler", "paper §1 heterogeneity motivation");
    let n_clients = 16;
    let fleet = HeteroFleet::mixed(n_clients, (0.4, 0.4, 0.2), 11);
    let metas = ModelArch::ResNet18.layers(10);
    let raw_bytes: usize = metas.iter().map(|m| m.numel * 4).sum();
    println!(
        "fleet: {n_clients} clients (40% 4G / 40% wifi / 20% fiber), \
         payload {:.1} MB, raw disparity {:.1}x\n",
        raw_bytes as f64 / 1e6,
        fleet.disparity(raw_bytes)
    );

    let mut table = Table::new(
        "synchronous round upload time (slowest client gates)",
        &["codec", "CR", "round upload", "vs uncompressed"],
    );
    let t_raw = fleet.round_time(&vec![raw_bytes; n_clients], &vec![Duration::ZERO; n_clients]);
    table.row(vec!["uncompressed".into(), "1.00".into(), fmt_duration(t_raw), "-".into()]);
    for name in ["fedgec", "sz3", "qsgd", "topk+eblc"] {
        // Measure payload + codec time per client (same data distribution,
        // different per-client streams).
        let mut payloads = Vec::with_capacity(n_clients);
        let mut times = Vec::with_capacity(n_clients);
        let mut cr_sum = 0.0;
        for c in 0..n_clients {
            let mut gen =
                GradGen::new(metas.clone(), GradGenConfig::default(), 100 + c as u64);
            let mut codec = build(name);
            // Warm one round, measure the second.
            codec.compress(&gen.next_round()).unwrap();
            let g = gen.next_round();
            let t0 = std::time::Instant::now();
            let p = codec.compress(&g).unwrap();
            times.push(t0.elapsed());
            cr_sum += g.byte_size() as f64 / p.len() as f64;
            payloads.push(p.len());
        }
        let t = fleet.round_time(&payloads, &times);
        table.row(vec![
            name.into(),
            format!("{:.2}", cr_sum / n_clients as f64),
            fmt_duration(t),
            format!("-{:.1}%", 100.0 * (1.0 - t.as_secs_f64() / t_raw.as_secs_f64())),
        ]);
    }

    // Frame-streamed fedgec: per-layer encode times + frame sizes per
    // client, pipelined into each client's own link; the straggler still
    // gates, but its codec latency hides behind its transmission.
    {
        let mut mono = Vec::with_capacity(n_clients);
        let mut streamed = Vec::with_capacity(n_clients);
        for c in 0..n_clients {
            let mut gen =
                GradGen::new(metas.clone(), GradGenConfig::default(), 100 + c as u64);
            let mut codec = build("fedgec");
            codec.compress(&gen.next_round()).unwrap();
            let g = gen.next_round();
            let (layer_comp, layer_wire) = time_layer_frames(codec.as_mut(), &g);
            let link = &fleet.links[c];
            let total_comp: Duration = layer_comp.iter().sum();
            let total_wire: usize = layer_wire.iter().sum();
            mono.push(total_comp + link.transmit_time(total_wire));
            streamed.push(pipelined_time(&layer_comp, &layer_wire, link));
        }
        let t_mono = mono.iter().max().copied().unwrap_or(Duration::ZERO);
        let t_stream = streamed.iter().max().copied().unwrap_or(Duration::ZERO);
        table.row(vec![
            "fedgec (streamed frames)".into(),
            "-".into(),
            fmt_duration(t_stream),
            format!("-{:.1}%", 100.0 * (1.0 - t_stream.as_secs_f64() / t_raw.as_secs_f64())),
        ]);
        println!(
            "streaming hides codec latency behind the link: straggler round \
             {} monolithic -> {} streamed (-{:.1}%)",
            fmt_duration(t_mono),
            fmt_duration(t_stream),
            100.0 * (1.0 - t_stream.as_secs_f64() / t_mono.as_secs_f64())
        );
        assert!(
            t_stream.as_secs_f64() <= t_mono.as_secs_f64() * 1.0001,
            "streamed round must not exceed the monolithic round"
        );
    }
    table.print();
    table.save_csv("hetero_straggler").unwrap();
    println!(
        "shape check: compression cuts the straggler-gated round time by the CR factor \
         (minus codec overhead) — the mechanism behind the paper's end-to-end gains"
    );

    // ── Downlink panel: the broadcast direction. Even with fast access
    // downlinks (4G/Wi-Fi are down ≫ up — the asymmetric LinkSpec), a
    // raw f32 broadcast to a mixed fleet costs real round time; the
    // encode-once global-delta codec shrinks the pull for every client
    // at the cost of one shared encode. ──
    downlink_panel(&fleet, n_clients);

    // ── State-store panel: ratio + server memory footprint vs
    // participation fraction and store budget. Partial participation
    // leaves non-participants' mirror states parked in the store; a
    // byte budget evicts them, trading compression ratio (cold restarts
    // predict worse) for bounded server memory. ──
    state_store_panel();

    // ── Aggregation panel: server decode CPU under `agg=exact` vs
    // `agg=binsum` on a state-free fedgec fleet — the compressed-domain
    // route stops before dequantization and pays one dequantize pass
    // per layer per round instead of one per client. ──
    agg_panel();
}

fn agg_panel() {
    use fedgec::compress::pipeline::{FedgecCodec, FedgecConfig, FedgecEngine};
    use fedgec::compress::predictor::magnitude::MagnitudeSel;
    use fedgec::compress::predictor::sign::SignSel;
    use fedgec::compress::predictor::PredictorSpec;
    use fedgec::compress::quant::ErrorBound;
    use fedgec::fl::aggregate::AggMode;
    use fedgec::fl::server::Server;

    let n_clients = 8usize;
    let rounds = if full_mode() { 8 } else { 3 };
    let metas = ModelArch::MicroInception.layers(10);
    let params: Vec<Vec<f32>> = metas.iter().map(|m| vec![0.0; m.numel]).collect();
    let cfg = FedgecConfig {
        error_bound: ErrorBound::Abs(2e-3),
        predictor: PredictorSpec { mag: MagnitudeSel::Zero, sign: SignSel::None },
        ..Default::default()
    };

    let mut panel = Table::new(
        &format!(
            "compressed-domain aggregation: {n_clients} clients x {rounds} rounds \
             (state-free fedgec, abs eb)"
        ),
        &["agg", "decode CPU", "agg CPU", "binsum/exact layers", "dequant passes"],
    );
    for mode in AggMode::ALL {
        let mut server = Server::with_engine(
            params.clone(),
            metas.clone(),
            0.1,
            Box::new(FedgecEngine::new(cfg.clone())),
        )
        .with_agg_mode(mode);
        let mut codecs: Vec<FedgecCodec> = (0..n_clients)
            .map(|i| {
                server.admit(i as u32);
                FedgecCodec::new(cfg.clone())
            })
            .collect();
        let mut gens: Vec<GradGen> = (0..n_clients)
            .map(|i| GradGen::new(metas.clone(), GradGenConfig::default(), 900 + i as u64))
            .collect();
        let mut decode = Duration::ZERO;
        let mut agg_cpu = Duration::ZERO;
        let (mut binsum, mut exact, mut passes) = (0usize, 0usize, 0usize);
        for _round in 0..rounds {
            let mut agg = server.new_round_agg();
            for ci in 0..n_clients {
                let p = codecs[ci].compress(&gens[ci].next_round()).unwrap();
                let times = server.absorb_payload(ci as u32, &p, 1.0, &mut agg).unwrap();
                decode += times.decode;
                agg_cpu += times.agg;
            }
            let rep = server.finish_round(agg);
            agg_cpu += rep.finish_time;
            binsum += rep.binsum_layers;
            exact += rep.exact_layers + rep.mixed_layers;
            passes += rep.dequant_passes;
        }
        panel.row(vec![
            mode.name().into(),
            fmt_duration(decode),
            fmt_duration(agg_cpu),
            format!("{binsum}/{exact}"),
            passes.to_string(),
        ]);
    }
    panel.print();
    panel.save_csv("hetero_agg").unwrap();
    println!(
        "binsum dequantizes once per layer per round (vs once per client), \
         so its dequant-pass count stays flat as the fleet grows"
    );
}

fn downlink_panel(fleet: &HeteroFleet, n_clients: usize) {
    use fedgec::train::data::DatasetSpec;
    use fedgec::train::gradgen::measure_downlink_delta;
    let metas = ModelArch::ResNet18.layers(10);
    let rounds = 3usize;
    let (raw_bytes, delta_bytes, enc_time) = measure_downlink_delta(
        &metas,
        GradGenConfig::for_dataset(DatasetSpec::Cifar10),
        42,
        1e-3,
        n_clients,
        rounds,
    )
    .unwrap();
    let per_round = delta_bytes / rounds;
    let enc_per_round = enc_time / rounds as u32;
    // The broadcast pull alone (uplink legs zeroed): the slowest
    // downlink gates, exactly like the slowest uplink gates uploads.
    let zero_up = vec![0usize; fleet.links.len()];
    let zero_t = vec![Duration::ZERO; fleet.links.len()];
    let t_raw = fleet.round_time_bidirectional(raw_bytes, &zero_up, &zero_t);
    let t_delta = fleet.round_time_bidirectional(per_round, &zero_up, &zero_t) + enc_per_round;
    let mut panel = Table::new(
        "downlink broadcast pull (slowest downlink gates; eb=1e-3 delta codec)",
        &["broadcast", "bytes/client (MB)", "round pull", "vs raw"],
    );
    panel.row(vec![
        "raw f32".into(),
        format!("{:.2}", raw_bytes as f64 / 1e6),
        fmt_duration(t_raw),
        "-".into(),
    ]);
    panel.row(vec![
        "global delta (encode once)".into(),
        format!("{:.2}", per_round as f64 / 1e6),
        fmt_duration(t_delta),
        format!("-{:.1}%", 100.0 * (1.0 - t_delta.as_secs_f64() / t_raw.as_secs_f64())),
    ]);
    panel.print();
    panel.save_csv("hetero_downlink").unwrap();
    println!(
        "down CR {:.2}; one encode ({}) serves all {n_clients} clients",
        raw_bytes as f64 / per_round as f64,
        fmt_duration(enc_per_round),
    );
}

fn state_store_panel() {
    use fedgec::compress::pipeline::{FedgecCodec, FedgecConfig, FedgecEngine};
    use fedgec::compress::state::StateEpoch;
    use fedgec::compress::store::ShardedMemStore;
    use fedgec::compress::GradientCodec;
    use fedgec::fl::hetero::sample_participants;
    use fedgec::fl::server::Server;
    use fedgec::util::rng::Rng;

    let n_clients = 16usize;
    let rounds = if full_mode() { 10 } else { 5 };
    let metas = fedgec::tensor::model_zoo::ModelArch::MicroInception.layers(10);
    let params: Vec<Vec<f32>> = metas.iter().map(|m| vec![0.0; m.numel]).collect();

    // Measure one warm mirror state to express budgets in "states".
    let one_state = {
        let mut srv = Server::with_engine(
            params.clone(),
            metas.clone(),
            0.1,
            Box::new(FedgecEngine::new(FedgecConfig::default())),
        );
        srv.admit(0);
        let mut codec = FedgecCodec::new(FedgecConfig::default());
        let mut gen = GradGen::new(metas.clone(), GradGenConfig::default(), 1);
        let mut agg = srv.new_round_agg();
        let p = codec.compress(&gen.next_round()).unwrap();
        srv.absorb_payload(0, &p, 1.0, &mut agg).unwrap();
        srv.store_stats().resident_bytes
    };

    let mut panel = Table::new(
        &format!(
            "state store: {n_clients} clients x {rounds} rounds, \
             one mirror state = {:.0} KB",
            one_state as f64 / 1e3
        ),
        &["participation", "budget (states)", "mean CR", "resyncs", "peak store KB", "evictions"],
    );
    for &fraction in &[1.0f64, 0.5, 0.25] {
        for &budget_states in &[0usize, 8, 4] {
            let store = if budget_states == 0 {
                ShardedMemStore::new(4, None)
            } else {
                ShardedMemStore::new(4, Some(budget_states * one_state))
            };
            let mut server = Server::new(
                params.clone(),
                metas.clone(),
                0.1,
                Box::new(FedgecEngine::new(FedgecConfig::default())),
                Box::new(store),
            );
            let mut clients: Vec<(FedgecCodec, GradGen, StateEpoch)> = (0..n_clients)
                .map(|i| {
                    server.admit(i as u32);
                    (
                        FedgecCodec::new(FedgecConfig::default()),
                        GradGen::new(metas.clone(), GradGenConfig::default(), 300 + i as u64),
                        StateEpoch::cold(),
                    )
                })
                .collect();
            let mut part_rng = Rng::new(77);
            let (mut raw, mut payload) = (0usize, 0usize);
            let mut resyncs = 0usize;
            let mut peak_bytes = 0usize;
            for _round in 0..rounds {
                let mut agg = server.new_round_agg();
                for ci in sample_participants(n_clients, fraction, &mut part_rng) {
                    let (codec, gen, epoch) = &mut clients[ci];
                    if server.check_state(ci as u32, *epoch).unwrap() {
                        codec.reset();
                        *epoch = StateEpoch::cold();
                        resyncs += 1;
                    }
                    let g = gen.next_round();
                    raw += g.byte_size();
                    let p = codec.compress(&g).unwrap();
                    payload += p.len();
                    server.absorb_payload(ci as u32, &p, 1.0, &mut agg).unwrap();
                    epoch.advance(codec.state_fingerprint());
                }
                server.finish_round(agg);
                peak_bytes = peak_bytes.max(server.store_stats().resident_bytes);
            }
            let stats = server.store_stats();
            panel.row(vec![
                format!("{fraction}"),
                if budget_states == 0 { "unbounded".into() } else { budget_states.to_string() },
                format!("{:.2}", raw as f64 / payload as f64),
                resyncs.to_string(),
                format!("{:.0}", peak_bytes as f64 / 1e3),
                stats.evictions.to_string(),
            ]);
            // Budgets actually bound the footprint.
            if budget_states > 0 {
                assert!(
                    peak_bytes <= budget_states * one_state + 4 * one_state,
                    "peak {peak_bytes} vs budget {}",
                    budget_states * one_state
                );
            }
        }
    }
    panel.print();
    panel.save_csv("hetero_state_store").unwrap();
    println!(
        "tighter budgets and lower participation trade ratio (cold restarts) \
         for bounded server memory — the knob the resync protocol makes safe"
    );
}
