//! Paper Fig. 5: gradient oscillation under full-batch gradient descent —
//! consecutive gradients are strongly correlated or anti-correlated
//! (|gradient correlation| high), making signs predictable cross-round.
//!
//! Runs true full-batch GD with the native net at a large learning rate
//! (the oscillatory regime) and prints μ(t, t+1) per epoch.

mod bench_util;

use bench_util::*;
use fedgec::metrics::Table;
use fedgec::train::data::{DatasetSpec, SynthDataset};
use fedgec::train::native::NativeNet;
use fedgec::util::rng::Rng;
use fedgec::util::stats;

fn main() {
    banner("fig5_oscillation", "Fig. 5");
    let epochs = if full_mode() { 80 } else { 40 };
    let ds = SynthDataset::new(DatasetSpec::Cifar10, 3);
    let mut rng = Rng::new(17);
    // Full batch: the whole (small) client dataset every step.
    let batch = ds.sample(&mut rng, 128, 0.0);
    let mut net = NativeNet::new(10, 2);
    // Warm up toward the optimum first; near it, full-batch GD gradients
    // become highly (anti-)correlated between steps (paper's Eq. 3/4
    // regime — the transient from random init masks the effect).
    for _ in 0..30 {
        let (_, _, g) = net.grad_batch(&batch);
        net.apply(&g, 0.1);
    }
    let lr = 3.0;
    let mut prev: Option<Vec<f32>> = None;
    let mut corrs = Vec::new();
    for _ in 0..epochs {
        let (_, _, g) = net.grad_batch(&batch);
        let flat: Vec<f32> =
            g.conv_w.iter().chain(&g.fc_w).cloned().collect();
        if let Some(p) = &prev {
            corrs.push(stats::gradient_correlation(p, &flat));
        }
        prev = Some(flat);
        net.apply(&g, lr);
    }
    let mut table = Table::new(
        "Fig. 5: gradient correlation μ(t, t+1) under full-batch GD",
        &["epoch", "correlation"],
    );
    for (i, c) in corrs.iter().enumerate() {
        table.row(vec![i.to_string(), format!("{c:.4}")]);
    }
    table.print();
    let path = table.save_csv("fig5_oscillation").unwrap();
    println!("saved {path:?}");

    let strong = corrs.iter().filter(|c| c.abs() > 0.5).count();
    let anti = corrs.iter().filter(|&&c| c < 0.0).count();
    println!(
        "shape check: {strong}/{} epochs with |μ| > 0.5; {anti} anti-correlated \
         (paper: strong correlation or anti-correlation between successive gradients)",
        corrs.len()
    );
    assert!(
        strong * 2 > corrs.len(),
        "most consecutive full-batch gradients should be strongly (anti-)correlated"
    );
}
