//! §Perf: compressor throughput microbenchmarks — the L3 hot-path profile
//! driving the optimization pass (EXPERIMENTS.md §Perf). Reports per-stage
//! GB/s panels (predict/quantize/entropy × encode/decode) for **both**
//! kernel twins — the bounds-checked scalar loops and the chunked
//! unchecked fast loops (`compress::kernels`) — plus the fast/scalar
//! speedup, on a ResNet-18-scale gradient (MicroResNet under
//! `BENCH_QUICK=1`).
//!
//! The emitted `results/BENCH_perf_throughput.json` feeds the CI
//! perf-regression gate: `cargo run --bin bench_check` diffs it against
//! the committed floors in `results/baselines/perf_throughput.json`.

mod bench_util;

use std::time::Duration;

use bench_util::*;
use fedgec::compress::entropy::EntropyCoder;
use fedgec::compress::fused::{fused_decode, fused_encode, FusedEncodeOut, FusedParams};
use fedgec::compress::kernels;
use fedgec::compress::lossless::Backend;
use fedgec::compress::quant::{self, Quantized};
use fedgec::compress::spec::{CodecSpec, SpecDefaults};
use fedgec::compress::{huffman, GradientCodec};
use fedgec::metrics::Table;
use fedgec::tensor::model_zoo::ModelArch;
use fedgec::train::gradgen::{GradGen, GradGenConfig};
use fedgec::util::timer::{bench_loop, BenchStats};

/// One measurement under each kernel twin. The same closure is timed
/// twice: first with the scalar loops forced (`kernels::force_scalar`),
/// then on the default fast path.
struct Twin {
    scalar: BenchStats,
    fast: BenchStats,
}

fn twin(iters: usize, min_time: Duration, mut f: impl FnMut()) -> Twin {
    kernels::force_scalar(true);
    let scalar = bench_loop(iters, min_time, &mut f);
    kernels::force_scalar(false);
    let fast = bench_loop(iters, min_time, &mut f);
    Twin { scalar, fast }
}

fn gbs(stats: &BenchStats, bytes: usize) -> f64 {
    stats.mb_per_s(bytes) / 1e3
}

/// Append one `stage | scalar GB/s | fast GB/s | speedup | CR | trunc`
/// row. The trunc flag marks which twin hit the `bench_loop` iteration
/// cap before its min_time ("s", "f", or "sf") — those means come from
/// fewer samples than requested.
fn twin_row(table: &mut Table, stage: &str, bytes: usize, t: &Twin, cr: Option<f64>) {
    let s = gbs(&t.scalar, bytes);
    let f = gbs(&t.fast, bytes);
    let trunc = match (t.scalar.truncated, t.fast.truncated) {
        (false, false) => "-",
        (true, false) => "s",
        (false, true) => "f",
        (true, true) => "sf",
    };
    table.row(vec![
        stage.to_string(),
        format!("{s:.3}"),
        format!("{f:.3}"),
        format!("{:.2}", f / s),
        cr.map(|c| format!("{c:.2}")).unwrap_or_else(|| "-".into()),
        trunc.to_string(),
    ]);
}

fn main() {
    banner("perf_throughput", "EXPERIMENTS.md §Perf");
    let arch = if quick_mode() { ModelArch::MicroResNet } else { ModelArch::ResNet18 };
    let metas = arch.layers(10);
    let mut gen = GradGen::new(metas.clone(), GradGenConfig::default(), 2);
    let g0 = gen.next_round();
    let g = gen.next_round();
    let bytes = g.byte_size();
    println!("payload: {} gradient, {:.1} MB\n", arch.name(), bytes as f64 / 1e6);
    let iters = if full_mode() {
        5
    } else if quick_mode() {
        1
    } else {
        2
    };
    let min_time = Duration::from_millis(if full_mode() {
        3000
    } else if quick_mode() {
        50
    } else {
        800
    });

    let mut table = Table::new(
        "compressor throughput",
        &["stage", "scalar GB/s", "fast GB/s", "speedup", "CR", "trunc"],
    );

    // End-to-end codecs, every registered entropy-stage lane width.
    let specs = [
        "fedgec",
        "fedgec:ec=rans",
        "fedgec:ec=rans4",
        "fedgec:ec=rans8",
        "sz3",
        "qsgd",
        "topk",
    ];
    for name in specs {
        let mut client =
            CodecSpec::parse_with(name, &SpecDefaults::with_rel_eb(3e-2)).unwrap().build();
        client.compress(&g0).unwrap(); // warm state
        let mut payload_len = 0usize;
        let t = twin(iters, min_time, || {
            payload_len = client.compress(&g).unwrap().len();
        });
        let cr = bytes as f64 / payload_len as f64;
        twin_row(&mut table, &format!("{name} compress (e2e)"), bytes, &t, Some(cr));
    }
    // Decompression, every lane width (fresh server decoding rounds 1+2
    // each iteration keeps the predictor state consistent with the pair).
    for spec_str in ["fedgec", "fedgec:ec=rans", "fedgec:ec=rans4", "fedgec:ec=rans8"] {
        let d = SpecDefaults::with_rel_eb(3e-2);
        let mut client = CodecSpec::parse_with(spec_str, &d).unwrap().build();
        let p0 = client.compress(&g0).unwrap();
        let payload = client.compress(&g).unwrap();
        let t = twin(iters, min_time, || {
            let mut s = CodecSpec::parse_with(spec_str, &d).unwrap().build();
            s.decompress(&p0, &metas).unwrap();
            s.decompress(&payload, &metas).unwrap();
        });
        twin_row(&mut table, &format!("{spec_str} decompress (2 rounds)"), bytes * 2, &t, None);
    }

    // Stage microbenches on the largest layer.
    let largest = g.layers.iter().max_by_key(|l| l.data.len()).unwrap();
    let n = largest.data.len();
    let lbytes = n * 4;
    {
        use fedgec::util::stats as st;
        let prev_abs: Vec<f32> = g0
            .layers
            .iter()
            .max_by_key(|l| l.data.len())
            .unwrap()
            .data
            .iter()
            .map(|x| x.abs())
            .collect();
        let signs = vec![1.0f32; n];
        let abs: Vec<f32> = largest.data.iter().map(|x| x.abs()).collect();
        let (mu_curr, sigma_curr) = st::mean_std(&abs);
        let (mu_prev, sigma_prev) = st::mean_std(&prev_abs);
        let p = FusedParams {
            beta: 0.9,
            mu_curr,
            sigma_curr,
            mu_prev,
            sigma_prev,
            two_delta: 0.001,
            delta: 0.0005,
        };

        // Fused predict+quantize: encode then decode on the same frame.
        let mut mem = Vec::new();
        let mut out = FusedEncodeOut::default();
        let t = twin(iters * 3, min_time, || {
            mem.clear();
            fused_encode(&largest.data, &prev_abs, &mut mem, &signs, &p, &mut out);
        });
        twin_row(&mut table, "stage: fused predict+quantize encode", lbytes, &t, None);
        let mut dmem = Vec::new();
        let mut drecon = Vec::new();
        let t = twin(iters * 3, min_time, || {
            dmem.clear();
            fused_decode(&out.codes, &out.escapes, &prev_abs, &mut dmem, &signs, &p, &mut drecon)
                .unwrap();
        });
        twin_row(&mut table, "stage: fused decode", lbytes, &t, None);

        // Plain quantizer (the pred=last/zero and engine paths).
        let pred = vec![0.0f32; n];
        let mut q = Quantized::default();
        let mut recon = Vec::new();
        let t = twin(iters * 3, min_time, || {
            quant::quantize(&largest.data, &pred, 0.0005, &mut q, &mut recon);
        });
        twin_row(&mut table, "stage: quantize encode", lbytes, &t, None);
        let t = twin(iters * 3, min_time, || {
            quant::dequantize_checked(&q, &pred, 0.0005, &mut recon).unwrap();
        });
        twin_row(&mut table, "stage: dequantize decode", lbytes, &t, None);

        // Entropy-stage panel: Huffman vs every rANS lane width, encode
        // and decode, on the same code stream.
        let codes = out.codes.clone();
        let coders =
            [EntropyCoder::Huffman, EntropyCoder::Rans, EntropyCoder::Rans4, EntropyCoder::Rans8];
        for coder in coders {
            let mut stream = Vec::new();
            let t = twin(iters * 3, min_time, || {
                stream = coder.encode_to_bytes(&codes);
            });
            let cr = lbytes as f64 / stream.len() as f64;
            let label = format!("stage: {} encode", coder.name());
            twin_row(&mut table, &label, lbytes, &t, Some(cr));
            let t = twin(iters * 3, min_time, || {
                let _ = coder.decode_from_bytes(&stream).unwrap();
            });
            twin_row(&mut table, &format!("stage: {} decode", coder.name()), lbytes, &t, None);
        }

        // Lossless backends ride on the entropy bytes (no kernel twins).
        let entropy = huffman::encode_to_bytes(&codes);
        for backend in [Backend::Zstd(3), Backend::Deflate, Backend::OwnLz] {
            let stats = bench_loop(iters, min_time, || {
                let _ = backend.compress(&entropy).unwrap();
            });
            table.row(vec![
                format!("stage: lossless {}", backend.name()),
                "-".into(),
                format!("{:.3}", gbs(&stats, entropy.len())),
                "-".into(),
                "-".into(),
                if stats.truncated { "y".into() } else { "-".into() },
            ]);
        }
    }
    table.print();
    table.save_csv("perf_throughput").unwrap();
    let json = table.save_json("perf_throughput").unwrap();
    println!("saved {json:?}");
    println!("gate: cargo run --bin bench_check  (floors in results/baselines/)");
}
