//! §Perf: compressor throughput microbenchmarks — the L3 hot-path profile
//! driving the optimization pass (EXPERIMENTS.md §Perf). Reports MB/s per
//! pipeline stage and end-to-end for each codec, on a ResNet-18-scale
//! gradient (MicroResNet under `BENCH_QUICK=1`), including the
//! huff-vs-rANS entropy-stage panel.

mod bench_util;

use std::time::Duration;

use bench_util::*;
use fedgec::compress::entropy::EntropyCoder;
use fedgec::compress::huffman;
use fedgec::compress::lossless::Backend;
use fedgec::compress::spec::{CodecSpec, SpecDefaults};
use fedgec::compress::GradientCodec;
use fedgec::metrics::Table;
use fedgec::tensor::model_zoo::ModelArch;
use fedgec::train::gradgen::{GradGen, GradGenConfig};
use fedgec::util::timer::bench_loop;

fn main() {
    banner("perf_throughput", "EXPERIMENTS.md §Perf");
    let arch = if quick_mode() { ModelArch::MicroResNet } else { ModelArch::ResNet18 };
    let metas = arch.layers(10);
    let mut gen = GradGen::new(metas.clone(), GradGenConfig::default(), 2);
    let g0 = gen.next_round();
    let g = gen.next_round();
    let bytes = g.byte_size();
    println!("payload: {} gradient, {:.1} MB\n", arch.name(), bytes as f64 / 1e6);
    let iters = if full_mode() {
        5
    } else if quick_mode() {
        1
    } else {
        2
    };
    let min_time = Duration::from_millis(if full_mode() {
        3000
    } else if quick_mode() {
        50
    } else {
        800
    });

    let mut table = Table::new("compressor throughput", &["stage", "MB/s", "CR"]);

    // End-to-end codecs, including the rANS entropy-stage variant.
    for name in ["fedgec", "fedgec:ec=rans", "sz3", "qsgd", "topk"] {
        let mut client =
            CodecSpec::parse_with(name, &SpecDefaults::with_rel_eb(3e-2)).unwrap().build();
        client.compress(&g0).unwrap(); // warm state
        let mut payload_len = 0usize;
        let stats = bench_loop(iters, min_time, || {
            payload_len = client.compress(&g).unwrap().len();
        });
        table.row(vec![
            format!("{name} compress (e2e)"),
            format!("{:.0}", stats.mb_per_s(bytes)),
            format!("{:.2}", bytes as f64 / payload_len as f64),
        ]);
    }
    // Decompression, both entropy coders.
    for spec_str in ["fedgec", "fedgec:ec=rans"] {
        let d = SpecDefaults::with_rel_eb(3e-2);
        let mut client = CodecSpec::parse_with(spec_str, &d).unwrap().build();
        let p0 = client.compress(&g0).unwrap();
        let payload = client.compress(&g).unwrap();
        // Fresh server decompressing rounds 1+2 each iteration (keeps the
        // predictor state consistent with the payload pair).
        let stats = bench_loop(iters, min_time, || {
            let mut s = CodecSpec::parse_with(spec_str, &d).unwrap().build();
            s.decompress(&p0, &metas).unwrap();
            s.decompress(&payload, &metas).unwrap();
        });
        table.row(vec![
            format!("{spec_str} decompress (2 rounds)"),
            format!("{:.0}", stats.mb_per_s(bytes * 2)),
            "-".into(),
        ]);
    }

    // Stage microbenches on the largest layer.
    let largest = g.layers.iter().max_by_key(|l| l.data.len()).unwrap();
    let lbytes = largest.data.len() * 4;
    {
        use fedgec::compress::fused::{fused_encode, FusedEncodeOut, FusedParams};
        use fedgec::util::stats as st;
        let prev_abs: Vec<f32> = g0
            .layers
            .iter()
            .max_by_key(|l| l.data.len())
            .unwrap()
            .data
            .iter()
            .map(|x| x.abs())
            .collect();
        let signs = vec![1.0f32; largest.data.len()];
        let abs: Vec<f32> = largest.data.iter().map(|x| x.abs()).collect();
        let (mu_curr, sigma_curr) = st::mean_std(&abs);
        let (mu_prev, sigma_prev) = st::mean_std(&prev_abs);
        let p = FusedParams {
            beta: 0.9,
            mu_curr,
            sigma_curr,
            mu_prev,
            sigma_prev,
            two_delta: 0.001,
            delta: 0.0005,
        };
        let mut mem = vec![0.0f32; largest.data.len()];
        let mut out = FusedEncodeOut::default();
        let stats = bench_loop(iters * 3, min_time, || {
            fused_encode(&largest.data, &prev_abs, &mut mem, &signs, &p, &mut out);
        });
        table.row(vec![
            "stage: fused predict+quantize".into(),
            format!("{:.0}", stats.mb_per_s(lbytes)),
            "-".into(),
        ]);
        // Entropy-stage panel: Huffman vs 2-way interleaved rANS, encode
        // and decode, on the same code stream.
        let codes = out.codes.clone();
        for coder in [EntropyCoder::Huffman, EntropyCoder::Rans] {
            let mut stream = Vec::new();
            let stats = bench_loop(iters * 3, min_time, || {
                stream = coder.encode_to_bytes(&codes);
            });
            table.row(vec![
                format!("stage: {} encode", coder.name()),
                format!("{:.0}", stats.mb_per_s(lbytes)),
                format!("{:.2}", lbytes as f64 / stream.len() as f64),
            ]);
            let stats = bench_loop(iters * 3, min_time, || {
                let _ = coder.decode_from_bytes(&stream).unwrap();
            });
            table.row(vec![
                format!("stage: {} decode", coder.name()),
                format!("{:.0}", stats.mb_per_s(lbytes)),
                "-".into(),
            ]);
        }
        let entropy = huffman::encode_to_bytes(&codes);
        for backend in [Backend::Zstd(3), Backend::Deflate, Backend::OwnLz] {
            let stats = bench_loop(iters, min_time, || {
                let _ = backend.compress(&entropy).unwrap();
            });
            table.row(vec![
                format!("stage: lossless {}", backend.name()),
                format!("{:.0}", stats.mb_per_s(entropy.len())),
                "-".into(),
            ]);
        }
    }
    table.print();
    table.save_csv("perf_throughput").unwrap();
    let json = table.save_json("perf_throughput").unwrap();
    println!("saved {json:?}");
}
