//! Paper Fig. 3: generic predictors (Lorenzo, interpolation) fail on real
//! gradient data — predictions don't track the signal and the residuals
//! are no tighter (sometimes wider) than the original values.
//!
//! Uses REAL gradients from the pure-Rust conv net mid-training. Prints
//! the residual-vs-original standard deviations and entropies, and saves
//! the distributions for plotting.

mod bench_util;

use bench_util::*;
use fedgec::metrics::Table;
use fedgec::train::data::{DatasetSpec, SynthDataset};
use fedgec::train::native::NativeNet;
use fedgec::util::rng::Rng;
use fedgec::util::stats;

fn lorenzo_residuals(data: &[f32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(data.len());
    let mut prev = 0.0f32;
    for &x in data {
        out.push(x - prev);
        prev = x;
    }
    out
}

fn interp_residuals(data: &[f32]) -> Vec<f32> {
    // Midpoint linear interpolation from true neighbors (the idealized
    // generic-interpolation residual).
    let n = data.len();
    (0..n)
        .map(|i| {
            if i == 0 || i + 1 >= n {
                data[i]
            } else {
                data[i] - 0.5 * (data[i - 1] + data[i + 1])
            }
        })
        .collect()
}

fn main() {
    banner("fig3_generic_predictors", "Fig. 3");
    // Real conv gradients from a partially-trained native net.
    let ds = SynthDataset::new(DatasetSpec::Cifar10, 3);
    let mut rng = Rng::new(4);
    let batch = ds.sample(&mut rng, 64, 0.0);
    let mut net = NativeNet::new(10, 5);
    for _ in 0..10 {
        let (_, _, g) = net.grad_batch(&batch);
        net.apply(&g, 0.2);
    }
    let (_, _, g) = net.grad_batch(&batch);
    let grad = &g.fc_w; // large dense gradient — spatially unstructured

    let lorenzo = lorenzo_residuals(grad);
    let interp = interp_residuals(grad);
    let bins = 256;
    let mut table = Table::new(
        "Fig. 3: generic predictors on real gradient data",
        &["series", "std", "entropy(bits, 256 bins)"],
    );
    for (name, series) in
        [("original", grad.as_slice()), ("lorenzo residual", &lorenzo), ("interp residual", &interp)]
    {
        table.row(vec![
            name.to_string(),
            format!("{:.3e}", stats::std(series)),
            format!("{:.3}", stats::value_entropy(series, bins)),
        ]);
    }
    table.print();
    let path = table.save_csv("fig3_generic_predictors").unwrap();
    println!("saved {path:?}");

    // Histogram series for plotting (original vs lorenzo residual).
    let (lo, hi) = stats::finite_min_max(grad);
    let w = (hi - lo).max(1e-12);
    let mut hist = Table::new(
        "Fig. 3 histograms (normalized bin centers)",
        &["bin", "original", "lorenzo", "interp"],
    );
    let (centers, h0) = stats::histogram(grad, 64, lo - 0.2 * w, hi + 0.2 * w);
    let (_, h1) = stats::histogram(&lorenzo, 64, lo - 0.2 * w, hi + 0.2 * w);
    let (_, h2) = stats::histogram(&interp, 64, lo - 0.2 * w, hi + 0.2 * w);
    for i in 0..centers.len() {
        hist.row(vec![
            format!("{:.4e}", centers[i]),
            h0[i].to_string(),
            h1[i].to_string(),
            h2[i].to_string(),
        ]);
    }
    let path = hist.save_csv("fig3_histograms").unwrap();
    println!("saved {path:?}");

    // Control: the same predictors on smooth scientific-style data, where
    // they were designed to work — this is the paper's implicit contrast.
    let smooth: Vec<f32> = (0..grad.len()).map(|i| (i as f32 / 200.0).sin()).collect();
    let smooth_ratio =
        stats::std(&lorenzo_residuals(&smooth)) as f64 / stats::std(&smooth) as f64;
    let s0 = stats::std(grad) as f64;
    let s1 = stats::std(&lorenzo) as f64;
    let grad_ratio = s1 / s0;
    println!(
        "\nshape check (paper): Lorenzo residual/original std ratio = {grad_ratio:.2} on \
         gradients vs {smooth_ratio:.4} on smooth data — the generic predictor removes \
         orders of magnitude of variance on smooth data but almost none on gradients"
    );
    assert!(
        grad_ratio > 0.5,
        "lorenzo residuals should NOT be much tighter than the original on gradients"
    );
    assert!(smooth_ratio < 0.05, "lorenzo must crush smooth data (sanity of the control)");
    assert!(
        grad_ratio > smooth_ratio * 20.0,
        "the gradient/smooth contrast should be dramatic"
    );
}
