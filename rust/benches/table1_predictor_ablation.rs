//! Paper Table 1: magnitude-predictor ablation — Lorenzo, MA(3), MA(5),
//! AR(1), EMA without normalization, EMA with normalization — scored by
//! MSE (lower better) and Pearson correlation (higher better) against the
//! true next-round magnitudes.
//!
//! Expected shape: EMA(Norm) best on both metrics; EMA(NoNorm) second on
//! MSE; Lorenzo worst tier.

mod bench_util;

use bench_util::*;
use fedgec::compress::predictor::magnitude::{MagnitudeVariant, VariantRunner};
use fedgec::metrics::Table;
use fedgec::tensor::model_zoo::ModelArch;
use fedgec::train::data::DatasetSpec;
use fedgec::train::gradgen::{GradGen, GradGenConfig};
use fedgec::util::stats;

fn main() {
    banner("table1_predictor_ablation", "Table 1");
    let variants = [
        MagnitudeVariant::Lorenzo,
        MagnitudeVariant::MovingAverage(3),
        MagnitudeVariant::MovingAverage(5),
        MagnitudeVariant::Ar1,
        MagnitudeVariant::EmaNoNorm,
        MagnitudeVariant::EmaNorm,
    ];
    // Magnitude sequences from the calibrated gradient stream of a conv
    // layer (ResNet-18, CIFAR-10 statistics), like the paper's setup.
    let metas = ModelArch::ResNet18.layers(10);
    let conv_idx = metas
        .iter()
        .position(|m| m.kind.kernel_size() == Some(9) && m.numel > 100_000)
        .unwrap();
    let rounds = if full_mode() { 60 } else { 30 };
    let mut runners: Vec<VariantRunner> =
        variants.iter().map(|&v| VariantRunner::new(v, 0.9)).collect();
    let mut mse = vec![0.0f64; variants.len()];
    let mut corr = vec![0.0f64; variants.len()];
    let mut scored = 0usize;
    let mut gen = GradGen::new(metas.clone(), GradGenConfig::for_dataset(DatasetSpec::Cifar10), 3);
    for t in 0..rounds {
        let g = gen.next_round();
        let truth: Vec<f32> = g.layers[conv_idx].data.iter().map(|x| x.abs()).collect();
        for (k, r) in runners.iter_mut().enumerate() {
            let pred = r.step(&truth);
            if t >= 3 {
                mse[k] += stats::mse(&pred, &truth);
                corr[k] += stats::pearson(&pred, &truth);
            }
        }
        if t >= 3 {
            scored += 1;
        }
    }
    let mut table = Table::new(
        "Table 1: gradient magnitude predictor ablation",
        &["predictor", "MSE", "Corr"],
    );
    for (k, v) in variants.iter().enumerate() {
        table.row(vec![
            v.name(),
            format!("{:.3e}", mse[k] / scored as f64),
            format!("{:.4}", corr[k] / scored as f64),
        ]);
    }
    table.print();
    let path = table.save_csv("table1_predictor_ablation").unwrap();
    println!("saved {path:?}");
    let norm_idx = variants.len() - 1;
    let best_mse = mse.iter().cloned().fold(f64::INFINITY, f64::min);
    println!(
        "shape check: EMA(Norm) MSE {:.3e} vs best-other {:.3e} (paper: EMA(Norm) best)",
        mse[norm_idx] / scored as f64,
        best_mse / scored as f64
    );
    assert!((mse[norm_idx] - best_mse).abs() < 1e-12, "EMA(Norm) should have the lowest MSE");
    let best_corr = corr.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    assert!((corr[norm_idx] - best_corr).abs() < 1e-12, "EMA(Norm) should have the highest Corr");
}
