//! Paper Fig. 9: training accuracy under compression across error bounds,
//! vs the uncompressed baseline — Ours ≈ SZ3 ≈ uncompressed for
//! eb ≤ 3e-2 (5e-2 for easy data), QSGD degrades first.
//!
//! Real federated training with the native trainer (no artifacts needed;
//! the HLO-trainer variant of this experiment runs via
//! `examples/fl_e2e.rs`). `FEDGEC_FULL=1` adds datasets and error bounds.

mod bench_util;

use bench_util::*;
use fedgec::config::RunConfig;
use fedgec::coordinator::run_local;
use fedgec::fl::transport::bandwidth::LinkSpec;
use fedgec::metrics::Table;

fn main() {
    banner("fig9_accuracy", "Fig. 9");
    // The synthetic tasks are easier than CIFAR/Caltech proper, so the
    // degradation knee sits at larger bounds than the paper's 5e-2 — we
    // extend the sweep so the knee is visible (same qualitative shape:
    // flat plateau at tight bounds, cliff at loose ones).
    let bounds = if full_mode() {
        vec![1e-3, 1e-2, 3e-2, 1e-1, 3e-1, 6e-1]
    } else {
        vec![1e-2, 1e-1, 3e-1, 6e-1]
    };
    let datasets = vec![fedgec::train::data::DatasetSpec::Caltech101];
    let rounds = if full_mode() { 12 } else { 8 };
    let mut table = Table::new(
        "Fig. 9: final accuracy vs error bound (native FL, real training)",
        &["dataset", "codec", "eb", "final acc", "baseline acc", "gap"],
    );
    for dataset in datasets {
        // Uncompressed baseline.
        let base_cfg = RunConfig {
            model: "native".into(),
            dataset,
            n_clients: 3,
            rounds,
            samples_per_client: 64,
            local_lr: 0.15,
            server_lr: 0.15,
            codec: "none".into(),
            link: LinkSpec::infinite(),
            eval_every: 0,
            seed: 7,
            class_skew: 0.6,
            ..Default::default()
        };
        let baseline = run_local(&base_cfg).unwrap().final_accuracy.unwrap();
        for codec in ["fedgec", "sz3", "qsgd"] {
            for &eb in &bounds {
                let mut cfg = base_cfg.clone();
                cfg.codec = codec.into();
                cfg.rel_error_bound = eb;
                let acc = run_local(&cfg).unwrap().final_accuracy.unwrap();
                table.row(vec![
                    dataset.name().to_string(),
                    codec.to_string(),
                    format!("{eb}"),
                    format!("{acc:.3}"),
                    format!("{baseline:.3}"),
                    format!("{:+.3}", acc - baseline),
                ]);
            }
        }
    }
    table.print();
    let path = table.save_csv("fig9_accuracy").unwrap();
    println!("saved {path:?}");
    println!(
        "shape check (paper): ours/sz3 within noise of the uncompressed baseline \
         for eb <= 3e-2; degradation grows at 1e-1; qsgd degrades at coarser settings"
    );
}
