//! §Topology: sharded-aggregation scaling panel — round throughput,
//! merge cost, and the partial-aggregate memory footprint (the peak-RSS
//! proxy) as the shard count grows over a fixed synthetic fleet.
//!
//! The emitted `results/BENCH_topology_scale.json` feeds the CI
//! perf-regression gate against the floors in
//! `results/baselines/topology_scale.json`.

mod bench_util;

use std::time::Instant;

use bench_util::*;
use fedgec::compress::engine::CodecEngine;
use fedgec::compress::pipeline::{FedgecConfig, FedgecEngine};
use fedgec::compress::predictor::magnitude::MagnitudeSel;
use fedgec::compress::predictor::sign::SignSel;
use fedgec::compress::predictor::PredictorSpec;
use fedgec::compress::quant::ErrorBound;
use fedgec::fl::aggregate::AggMode;
use fedgec::fl::server::Server;
use fedgec::fl::topology::sharded::ShardedRunner;
use fedgec::fl::topology::synth::SynthFleet;
use fedgec::metrics::Table;
use fedgec::tensor::LayerMeta;

const ROUNDS: usize = 2;

fn cfg() -> FedgecConfig {
    // State-free spec: replayable payload bank, no store traffic — the
    // panel isolates decode + merge scaling.
    FedgecConfig {
        error_bound: ErrorBound::Abs(5e-3),
        predictor: PredictorSpec { mag: MagnitudeSel::Zero, sign: SignSel::None },
        ..Default::default()
    }
}

fn main() {
    banner("topology_scale", "DESIGN.md §13 (sharded aggregation)");
    let n_clients = if full_mode() {
        200_000
    } else if quick_mode() {
        8_000
    } else {
        40_000
    };
    let metas = vec![LayerMeta::dense("fc", 2048, 1), LayerMeta::other("bias", 32)];
    let fleet = SynthFleet::new(&cfg(), &metas, n_clients, 64, 17).unwrap();
    println!(
        "fleet: {n_clients} clients over a {} KB payload bank\n",
        fleet.resident_bytes() / 1000
    );

    let mut table = Table::new(
        "sharded aggregation scaling",
        &["shards", "clients/s", "round ms", "merge ms", "agg KB", "dropped"],
    );
    for shards in [1usize, 2, 4, 8] {
        let params: Vec<Vec<f32>> = metas.iter().map(|m| vec![0.01; m.numel]).collect();
        let mut server = Server::with_engine(
            params,
            metas.clone(),
            0.1,
            Box::new(FedgecEngine::new(cfg())),
        )
        .with_agg_mode(AggMode::Binsum);
        server.admit_all();
        let engines: Vec<Box<dyn CodecEngine>> = (0..shards)
            .map(|_| Box::new(FedgecEngine::new(cfg())) as Box<dyn CodecEngine>)
            .collect();
        let mut runner = ShardedRunner::new(&server, engines).unwrap();
        let mut merge_s = 0.0f64;
        let mut dropped = 0usize;
        let t0 = Instant::now();
        for _ in 0..ROUNDS {
            let stats = runner
                .run_round_direct(&mut server, |idx| fleet.shard_iter(shards, idx))
                .unwrap();
            merge_s += stats.merge_time.as_secs_f64();
            dropped += stats.dropped;
        }
        let wall = t0.elapsed().as_secs_f64();
        table.row(vec![
            format!("shards={shards}"),
            format!("{:.0}", (ROUNDS * n_clients) as f64 / wall),
            format!("{:.1}", wall * 1e3 / ROUNDS as f64),
            format!("{:.3}", merge_s * 1e3 / ROUNDS as f64),
            format!("{:.1}", runner.last_agg_resident_bytes as f64 / 1e3),
            format!("{dropped}"),
        ]);
    }
    table.print();
    table.save_csv("topology_scale").unwrap();
    let json = table.save_json("topology_scale").unwrap();
    println!("saved {json:?}");
    println!("gate: cargo run --bin bench_check  (floors in results/baselines/)");
}
