//! Paper Fig. 10: layer-wise analysis on ResNet-18's largest conv layer
//! (512×512 kernels of 3×3), CIFAR-10, τ = 0.5, eb = 3e-2:
//! (a) distribution of predicted kernels' values before/after prediction,
//! (b) overall layer distribution original vs combined, (c) CR per part.
//!
//! Expected shape: residuals concentrate sharply around zero; predicted
//! part CR > its SZ3 CR; combined CR > all-SZ3 CR.

mod bench_util;

use bench_util::*;
use fedgec::compress::pipeline::{FedgecCodec, FedgecConfig};
use fedgec::compress::quant::ErrorBound;
use fedgec::compress::spec::{CodecSpec, SpecDefaults};
use fedgec::compress::GradientCodec;
use fedgec::metrics::Table;
use fedgec::tensor::LayerMeta;
use fedgec::train::data::DatasetSpec;
use fedgec::train::gradgen::{GradGen, GradGenConfig};
use fedgec::util::stats;

fn main() {
    banner("fig10_layerwise", "Fig. 10");
    let eb = 3e-2;
    let tau = 0.5;
    // The paper's layer: 512x512 3x3 kernels = 2.36M params.
    let (oc, ic) = if full_mode() { (512, 512) } else { (512, 256) };
    let meta = LayerMeta::conv("layer4.1.b.conv", oc, ic, 3, 3);
    let cfg_gen = GradGenConfig::for_dataset(DatasetSpec::Cifar10);
    let mut gen = GradGen::new(vec![meta.clone()], cfg_gen, 10);
    let cfg = FedgecConfig { error_bound: ErrorBound::Rel(eb), tau, ..Default::default() };
    let mut client = FedgecCodec::new(cfg.clone());
    let mut server = FedgecCodec::new(cfg);
    // Warm round 1, analyze round 2 (predictor needs history).
    let metas = [meta.clone()];
    let g0 = gen.next_round();
    server.decompress(&client.compress(&g0).unwrap(), &metas).unwrap();
    let g = gen.next_round();
    let (payload, round_report) = client.compress_with_report(&g).unwrap();
    let recon = server.decompress(&payload, &metas).unwrap();
    let report = &round_report.layers[0];

    // Partition elements using the sign tensor implied by reconstruction:
    // recompute decisions like the codec did.
    use fedgec::compress::predictor::sign::{predict_signs, SignMode};
    let (signs, _, sign_stats) =
        predict_signs(&g.layers[0].data, &meta.kind, SignMode::MiniBatch { tau }, None, None);
    let data = &g.layers[0].data;
    let mut pred_vals = Vec::new();
    let mut pred_residuals = Vec::new();
    let mut unpred_vals = Vec::new();
    for i in 0..data.len() {
        if signs[i] != 0.0 {
            pred_vals.push(data[i]);
            // residual vs the actual reconstruction-based prediction:
            // recon = ĝ + e', so e ≈ data - (recon - quantized residual);
            // report the true residual via recon as proxy: data - ĝ where
            // ĝ = recon rounded to prediction — use data - recon + e'
            // Simpler faithful proxy: data - sign*|data| trend == use
            // codec recon error distribution instead:
            pred_residuals.push(data[i] - recon.layers[0].data[i] + 0.0);
        } else {
            unpred_vals.push(data[i]);
        }
    }
    // (a)+(b): distribution stats + histograms.
    let mut dist = Table::new(
        "Fig. 10(a,b): value distributions (std / entropy)",
        &["series", "std", "entropy(bits)"],
    );
    // The true residual tensor: data − ĝ. Recover ĝ from the codec's
    // recon minus dequantized residual is equivalent to recon − data
    // up to ±Δ; use a fresh single-layer pipeline probe instead:
    let residual_std = stats::std(&pred_residuals);
    for (name, series) in [
        ("original (predicted kernels)", pred_vals.as_slice()),
        ("recon error (predicted kernels)", pred_residuals.as_slice()),
        ("original (whole layer)", data.as_slice()),
    ] {
        dist.row(vec![
            name.to_string(),
            format!("{:.3e}", stats::std(series)),
            format!("{:.3}", stats::value_entropy(series, 256)),
        ]);
    }
    dist.print();
    dist.save_csv("fig10_distributions").unwrap();
    let _ = residual_std;

    // (c): CR per part.
    let combined_cr = g.byte_size() as f64 / payload.len() as f64;
    let mk_cr = |vals: &[f32]| -> f64 {
        if vals.is_empty() {
            return 0.0;
        }
        let gg = fedgec::tensor::ModelGrad {
            layers: vec![fedgec::tensor::LayerGrad::new(
                LayerMeta::other("part", vals.len()),
                vals.to_vec(),
            )],
        };
        let mut sz3 =
            CodecSpec::parse_with("sz3", &SpecDefaults::with_rel_eb(eb)).unwrap().build();
        gg.byte_size() as f64 / sz3.compress(&gg).unwrap().len() as f64
    };
    let all_sz3 = mk_cr(data);
    let pred_sz3 = mk_cr(&pred_vals);
    let unpred_sz3 = mk_cr(&unpred_vals);

    let mut crs = Table::new("Fig. 10(c): compression ratio per part", &["part", "CR"]);
    crs.row(vec!["whole layer, SZ3".into(), format!("{all_sz3:.2}")]);
    crs.row(vec!["predicted kernels, SZ3".into(), format!("{pred_sz3:.2}")]);
    crs.row(vec!["unpredicted kernels, SZ3".into(), format!("{unpred_sz3:.2}")]);
    crs.row(vec!["whole layer, Ours (combined)".into(), format!("{combined_cr:.2}")]);
    crs.print();
    crs.save_csv("fig10_cr_parts").unwrap();
    println!(
        "prediction ratio {:.1}%, sign mismatch {:.1}%, escapes {}",
        sign_stats.prediction_ratio() * 100.0,
        sign_stats.mismatch_rate() * 100.0,
        report.escape_count
    );
    println!(
        "shape check (paper): combined CR {combined_cr:.2} > all-SZ3 CR {all_sz3:.2}"
    );
    assert!(combined_cr > all_sz3, "our pipeline must beat plain SZ3 on this layer");
}
