//! Paper Table 4: compression ratios of Ours vs SZ3 vs QSGD across models
//! (ResNet-18/34, Inception V1/V3) × datasets (CIFAR-10, Caltech101,
//! Fashion-MNIST) × REL error bounds {1e-3, 1e-2, 3e-2, 5e-2}, plus the
//! Table 4b entropy-stage panel comparing the Huffman and rANS coders
//! layer by layer.
//!
//! Expected shape (paper §5.3): Ours > SZ3 > QSGD in every cell; the
//! Ours/SZ3 gap widens toward eb = 3e-2 (up to ~1.5×) then plateaus. For
//! the 4b panel, the rANS selector encodes against the exact Huffman size,
//! so rANS entropy bytes are ≤ Huffman's on **every** layer by
//! construction — the assert holds in quick mode too.

mod bench_util;

use bench_util::*;
use fedgec::compress::spec::{CodecSpec, SpecDefaults};
use fedgec::compress::GradientCodec;
use fedgec::metrics::Table;
use fedgec::train::gradgen::{GradGen, GradGenConfig};

fn cell_ratio(
    arch: fedgec::tensor::model_zoo::ModelArch,
    spec: fedgec::train::data::DatasetSpec,
    codec_name: &str,
    eb: f64,
    rounds: usize,
) -> f64 {
    let metas = arch.layers(spec.classes());
    let mut gen = GradGen::new(metas, GradGenConfig::for_dataset(spec), 0xF0 + eb.to_bits() % 97);
    let mut codec =
        CodecSpec::parse_with(codec_name, &SpecDefaults::with_rel_eb(eb)).unwrap().build();
    let (mut raw, mut comp) = (0usize, 0usize);
    for _ in 0..rounds {
        let g = gen.next_round();
        raw += g.byte_size();
        comp += codec.compress(&g).unwrap().len();
    }
    raw as f64 / comp as f64
}

/// Table 4b: run fedgec with the given entropy coder over `rounds` rounds
/// of the same seeded gradient trace; return the last round's per-layer
/// report and the cumulative whole-model CR.
fn entropy_panel_run(
    arch: fedgec::tensor::model_zoo::ModelArch,
    ds: fedgec::train::data::DatasetSpec,
    ec: &str,
    eb: f64,
    rounds: usize,
) -> (fedgec::compress::CodecReport, f64) {
    let metas = arch.layers(ds.classes());
    let mut gen = GradGen::new(metas, GradGenConfig::for_dataset(ds), 0xEC);
    let spec_str = format!("ours:ec={ec}");
    let mut codec =
        CodecSpec::parse_with(&spec_str, &SpecDefaults::with_rel_eb(eb)).unwrap().build();
    let (mut raw, mut comp) = (0usize, 0usize);
    let mut last = None;
    for _ in 0..rounds {
        let g = gen.next_round();
        let (payload, report) = codec.compress_with_report(&g).unwrap();
        raw += g.byte_size();
        comp += payload.len();
        last = Some(report);
    }
    (last.unwrap(), raw as f64 / comp as f64)
}

fn main() {
    banner("table4_compression_ratio", "Table 4");
    let bounds = grid_bounds();
    let mut headers: Vec<String> = vec!["model".into(), "dataset".into(), "codec".into()];
    headers.extend(bounds.iter().map(|e| format!("eb={e}")));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new("Table 4: compression ratio (Ours vs SZ3 vs QSGD)", &hdr_refs);
    let rounds = grid_rounds();
    let mut ours_wins = 0usize;
    let mut cells = 0usize;
    let mut max_gain: f64 = 0.0;
    for arch in grid_models() {
        for spec in grid_datasets() {
            let mut per_codec = Vec::new();
            for codec in ["ours", "sz3", "qsgd"] {
                let ratios: Vec<f64> =
                    bounds.iter().map(|&eb| cell_ratio(arch, spec, codec, eb, rounds)).collect();
                let mut row = vec![
                    arch.name().to_string(),
                    spec.name().to_string(),
                    codec.to_string(),
                ];
                row.extend(ratios.iter().map(|r| format!("{r:.2}")));
                table.row(row);
                per_codec.push(ratios);
            }
            for i in 0..bounds.len() {
                cells += 1;
                if per_codec[0][i] > per_codec[1][i] {
                    ours_wins += 1;
                }
                max_gain = max_gain.max(per_codec[0][i] / per_codec[1][i] - 1.0);
            }
        }
    }
    table.print();
    let path = table.save_csv("table4_compression_ratio").unwrap();
    println!("saved {path:?}");
    let json = table.save_json("table4_compression_ratio").unwrap();
    println!("saved {json:?}");
    println!(
        "shape check: Ours beats SZ3 in {ours_wins}/{cells} cells; max gain over SZ3 = {:.1}% \
         (paper: all cells, up to 52.67%)",
        max_gain * 100.0
    );

    // ── Table 4b: entropy stage, Huffman vs rANS, per layer. ──
    let arch = grid_models()[0];
    let ds = grid_datasets()[0];
    let eb = 1e-2;
    let (hu, hu_cr) = entropy_panel_run(arch, ds, "huff", eb, rounds);
    let (ra, ra_cr) = entropy_panel_run(arch, ds, "rans", eb, rounds);
    let mut panel = Table::new(
        &format!("Table 4b: fedgec entropy stage, huff vs rans ({} / {})", arch.name(), ds.name()),
        &["layer", "huff B", "rans B", "rans saving %"],
    );
    let (mut hu_total, mut ra_total) = (0usize, 0usize);
    for (h, r) in hu.layers.iter().zip(&ra.layers) {
        assert!(
            r.entropy_bytes <= h.entropy_bytes,
            "rANS lost to Huffman on layer {}: {} > {} bytes",
            h.name,
            r.entropy_bytes,
            h.entropy_bytes
        );
        hu_total += h.entropy_bytes;
        ra_total += r.entropy_bytes;
        let saving = if h.entropy_bytes > 0 {
            100.0 * (1.0 - r.entropy_bytes as f64 / h.entropy_bytes as f64)
        } else {
            0.0
        };
        panel.row(vec![
            h.name.clone(),
            h.entropy_bytes.to_string(),
            r.entropy_bytes.to_string(),
            format!("{saving:.2}"),
        ]);
    }
    panel.row(vec![
        "TOTAL".into(),
        hu_total.to_string(),
        ra_total.to_string(),
        format!("{:.2}", 100.0 * (1.0 - ra_total as f64 / hu_total.max(1) as f64)),
    ]);
    panel.print();
    println!("whole-model CR: ec=huff {hu_cr:.3} vs ec=rans {ra_cr:.3}");
    panel.save_csv("table4_entropy_panel").unwrap();
    let json = panel.save_json("table4_entropy_panel").unwrap();
    println!("saved {json:?}");

    // The paper-shape assertion needs the real grid; the quick smoke run
    // only checks that the pipeline executes and emits artifacts.
    if !quick_mode() {
        assert!(ours_wins * 10 >= cells * 9, "Ours should beat SZ3 in ~all cells");
    }
}
