//! Paper Fig. 4: gradient magnitudes across epochs show a decaying trend
//! whose variation is dominated by low-frequency components.
//!
//! Trains the native net for many rounds, tracks the mean |g| sequence,
//! applies the low-pass trend filter and the FFT magnitude spectrum.

mod bench_util;

use bench_util::*;
use fedgec::metrics::Table;
use fedgec::train::data::{DatasetSpec, SynthDataset};
use fedgec::train::native::NativeNet;
use fedgec::util::fft;
use fedgec::util::rng::Rng;
use fedgec::util::stats;

fn main() {
    banner("fig4_magnitude_spectrum", "Fig. 4");
    let epochs = if full_mode() { 200 } else { 96 };
    let ds = SynthDataset::new(DatasetSpec::Cifar10, 3);
    let mut rng = Rng::new(9);
    let batch = ds.sample(&mut rng, 64, 0.0);
    let mut net = NativeNet::new(10, 2);
    let mut magnitudes = Vec::with_capacity(epochs);
    for _ in 0..epochs {
        let (_, _, g) = net.grad_batch(&batch);
        let mean_abs =
            stats::mean(&g.conv_w.iter().map(|x| x.abs()).collect::<Vec<_>>()) as f64;
        magnitudes.push(mean_abs);
        net.apply(&g, 0.15);
    }
    let trend = stats::low_pass(&magnitudes, 0.15);
    // Detrended spectrum (the paper plots the magnitude spectrum of the
    // epoch series).
    let detrended: Vec<f64> =
        magnitudes.iter().zip(&trend).map(|(m, t)| m - t).collect();
    let spectrum = fft::magnitude_spectrum(&magnitudes);
    let spectrum_detr = fft::magnitude_spectrum(&detrended);

    let mut series = Table::new(
        "Fig. 4(a): |g| trend across epochs",
        &["epoch", "mean|g|", "low-pass trend"],
    );
    for (i, (m, t)) in magnitudes.iter().zip(&trend).enumerate() {
        series.row(vec![i.to_string(), format!("{m:.4e}"), format!("{t:.4e}")]);
    }
    let p1 = series.save_csv("fig4_magnitude_trend").unwrap();

    let mut spec = Table::new(
        "Fig. 4(b): magnitude spectrum",
        &["freq bin", "|FFT| raw", "|FFT| detrended"],
    );
    for (i, (a, b)) in spectrum.iter().zip(&spectrum_detr).enumerate() {
        spec.row(vec![i.to_string(), format!("{a:.4e}"), format!("{b:.4e}")]);
    }
    let p2 = spec.save_csv("fig4_spectrum").unwrap();
    println!("saved {p1:?}, {p2:?}");

    // Shape checks: magnitudes decay; low-frequency half carries most of
    // the (non-DC) spectral energy.
    let early = magnitudes[..epochs / 4].iter().sum::<f64>();
    let late = magnitudes[3 * epochs / 4..].iter().sum::<f64>();
    let half = spectrum.len() / 2;
    let low: f64 = spectrum[1..half.max(2)].iter().map(|x| x * x).sum();
    let high: f64 = spectrum[half.max(2)..].iter().map(|x| x * x).sum();
    println!(
        "decay: first-quarter sum {early:.3e} vs last-quarter {late:.3e}; \
         low-frequency energy share {:.1}%",
        100.0 * low / (low + high)
    );
    assert!(late < early, "magnitudes should decay across training");
    assert!(low > high, "low-frequency components should dominate (paper Fig. 4b)");
}
