//! Paper Table 5: effect of convolutional kernel size (3×3 / 5×5 / 7×7)
//! on layer-wise compression: CR of all parts (SZ3 baseline, predicted
//! kernels, residual w/ our predictor, unpredicted, combined), predicted
//! ratio, sign mismatch rate, bitmap overhead.
//!
//! Expected shape: combined CR gain best at 3×3/5×5; at 7×7 the predictable
//! fraction collapses and sign mismatch rises, eroding the gain; bitmap
//! overhead shrinks with kernel size.

mod bench_util;

use bench_util::*;
use fedgec::compress::huffman;
use fedgec::compress::spec::{CodecSpec, SpecDefaults};
use fedgec::compress::lossless::Backend;
use fedgec::compress::pipeline::{FedgecCodec, FedgecConfig};
use fedgec::compress::predictor::sign::{predict_signs, SignMeta, SignMode};
use fedgec::compress::quant::{self, ErrorBound, Quantized};
use fedgec::compress::GradientCodec;
use fedgec::metrics::Table;
use fedgec::tensor::{LayerGrad, LayerMeta, ModelGrad};
use fedgec::train::gradgen::{GradGen, GradGenConfig};
use fedgec::util::stats;

/// Compress a bare value slice with the plain SZ3-style pipeline (no
/// predictor) and return CR.
fn sz3_cr(data: &[f32], eb: f64) -> f64 {
    if data.is_empty() {
        return 0.0;
    }
    let g = ModelGrad {
        layers: vec![LayerGrad::new(LayerMeta::other("part", data.len()), data.to_vec())],
    };
    let mut codec =
        CodecSpec::parse_with("sz3", &SpecDefaults::with_rel_eb(eb)).unwrap().build();
    let payload = codec.compress(&g).unwrap();
    g.byte_size() as f64 / payload.len() as f64
}

/// CR of quantized residuals (already predicted) through Huffman+Zstd.
fn residual_cr(residuals: &[f32], delta: f64) -> f64 {
    if residuals.is_empty() {
        return 0.0;
    }
    let pred = vec![0.0f32; residuals.len()];
    let mut q = Quantized::default();
    let mut recon = Vec::new();
    quant::quantize(residuals, &pred, delta, &mut q, &mut recon);
    let entropy = huffman::encode_to_bytes(&q.codes);
    let closed = Backend::Zstd(3).compress(&entropy).unwrap();
    residuals.len() as f64 * 4.0 / (closed.len() + q.escapes.len() * 4) as f64
}

fn main() {
    banner("table5_kernel_size", "Table 5");
    let eb = 3e-2;
    let tau = 0.5;
    let mut table = Table::new(
        "Table 5: compression vs kernel size (eb=3e-2, tau=0.5)",
        &[
            "kernel", "All(SZ3)", "Pred.(SZ3)", "Residual(Ours)", "Unpred.", "Combined(Ours)",
            "Pred.Ratio", "SignMismatch", "BitmapOvhd",
        ],
    );
    for k in [3usize, 5, 7] {
        // The paper's layer: 512x512 kernels (scaled down off full mode).
        let (oc, ic) = if full_mode() { (512, 512) } else { (256, 256) };
        let meta = LayerMeta::conv("L", oc, ic, k, k);
        let mut gen = GradGen::new(vec![meta.clone()], GradGenConfig::default(), 1 + k as u64);
        // Warm one round so predictors have history, then analyze round 2.
        let mut codec_warm = FedgecCodec::new(FedgecConfig {
            error_bound: ErrorBound::Rel(eb),
            tau,
            ..Default::default()
        });
        let g0 = gen.next_round();
        codec_warm.compress(&g0).unwrap();
        let g = gen.next_round();
        let layer = &g.layers[0];
        let t = k * k;

        // Sign prediction decisions on the current gradient.
        let (signs, meta_info, sign_stats) =
            predict_signs(&layer.data, &meta.kind, SignMode::MiniBatch { tau }, None, None);
        let (lo, hi) = stats::finite_min_max(&layer.data);
        let delta = ErrorBound::Rel(eb).resolve(lo, hi);

        // Split elements into predicted / unpredicted kernels.
        let mut pred_vals = Vec::new();
        let mut unpred_vals = Vec::new();
        let mut residuals = Vec::new();
        // Residual after our full predictor (magnitude via warmed codec
        // state + sign): approximate magnitude prediction with |prev recon|
        // EMA state from codec_warm.
        let prev_abs: Vec<f32> = codec_warm.state.layers[0].prev_abs.clone().unwrap();
        let prev_abs = &prev_abs[..];
        let (mu_prev, sigma_prev) = stats::mean_std(prev_abs);
        let abs: Vec<f32> = layer.data.iter().map(|x| x.abs()).collect();
        let (mu_curr, sigma_curr) = stats::mean_std(&abs);
        let inv_sigma = 1.0 / sigma_prev.max(1e-12);
        for kern in 0..oc * ic {
            let range = kern * t..(kern + 1) * t;
            let predicted = signs[range.start] != 0.0;
            for i in range {
                if predicted {
                    pred_vals.push(layer.data[i]);
                    let z = (prev_abs[i] - mu_prev) * inv_sigma;
                    let m = 0.1 * z; // memory was 0 at warmup; one EMA step
                    let a_hat = (m * sigma_curr + mu_curr).max(0.0);
                    residuals.push(layer.data[i] - signs[i] * a_hat);
                } else {
                    unpred_vals.push(layer.data[i]);
                }
            }
        }

        let all_sz3 = sz3_cr(&layer.data, eb);
        let pred_sz3 = sz3_cr(&pred_vals, eb);
        let res_ours = residual_cr(&residuals, delta);
        let unpred_cr = sz3_cr(&unpred_vals, eb);

        // Combined: the real codec (warmed with round 1) on round 2.
        let payload = codec_warm.compress(&g).unwrap();
        let combined = g.byte_size() as f64 / payload.len() as f64;

        // Bitmap overhead relative to compressed size.
        let bitmap_bytes = match &meta_info {
            SignMeta::Bitmap(bm) => bm.byte_size(),
            _ => 0,
        };
        let overhead = bitmap_bytes as f64 / payload.len() as f64;

        table.row(vec![
            format!("{k}x{k}"),
            format!("{all_sz3:.2}"),
            format!("{pred_sz3:.2}"),
            format!("{res_ours:.2}"),
            format!("{unpred_cr:.2}"),
            format!("{combined:.2}"),
            format!("{:.1}%", sign_stats.prediction_ratio() * 100.0),
            format!("{:.1}%", sign_stats.mismatch_rate() * 100.0),
            format!("{:.1}%", overhead * 100.0),
        ]);
    }
    table.print();
    let path = table.save_csv("table5_kernel_size").unwrap();
    println!("saved {path:?}");
    println!(
        "shape check (paper): residual CR > predicted-part SZ3 CR at every size; \
         predict ratio collapses at 7x7; bitmap overhead shrinks with kernel size"
    );
}
