//! Shared helpers for the hand-rolled bench harness (`harness = false`;
//! criterion is unavailable offline). Each bench binary regenerates one
//! paper table/figure: printed as markdown + saved to `results/*.csv`.

#![allow(dead_code)]

use std::time::Duration;

use fedgec::compress::GradientCodec;
use fedgec::fl::transport::bandwidth::LinkSpec;
use fedgec::tensor::model_zoo::ModelArch;
use fedgec::tensor::ModelGrad;
use fedgec::train::data::DatasetSpec;

/// `FEDGEC_FULL=1` runs the paper's full grid; default is a fast subset.
pub fn full_mode() -> bool {
    std::env::var("FEDGEC_FULL").map(|v| v == "1").unwrap_or(false)
}

/// `BENCH_QUICK=1` shrinks every grid to a CI smoke test: small tensor
/// sizes, few rounds, minimal timing loops. The emitted `BENCH_*.json`
/// artifacts keep the same shape, so the per-PR trajectory stays
/// comparable run-over-run. `FEDGEC_FULL` wins if both are set.
pub fn quick_mode() -> bool {
    !full_mode() && std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Models for the compression-grid experiments.
pub fn grid_models() -> Vec<ModelArch> {
    if full_mode() {
        vec![
            ModelArch::ResNet18,
            ModelArch::ResNet34,
            ModelArch::InceptionV1,
            ModelArch::InceptionV3,
        ]
    } else if quick_mode() {
        vec![ModelArch::MicroResNet]
    } else {
        vec![ModelArch::ResNet18, ModelArch::InceptionV1]
    }
}

/// Datasets for the compression-grid experiments.
pub fn grid_datasets() -> Vec<DatasetSpec> {
    if full_mode() {
        vec![DatasetSpec::Cifar10, DatasetSpec::Caltech101, DatasetSpec::Fmnist]
    } else if quick_mode() {
        vec![DatasetSpec::Cifar10]
    } else {
        vec![DatasetSpec::Cifar10, DatasetSpec::Fmnist]
    }
}

/// The paper's REL error-bound sweep (Table 4 columns).
pub fn grid_bounds() -> Vec<f64> {
    if quick_mode() {
        vec![1e-2, 3e-2]
    } else {
        vec![1e-3, 1e-2, 3e-2, 5e-2]
    }
}

/// Number of gradient rounds averaged per grid cell.
pub fn grid_rounds() -> usize {
    if full_mode() {
        5
    } else if quick_mode() {
        2
    } else {
        3
    }
}

/// Time each layer's frame encode through the session API, returning the
/// per-layer (encode time, wire size) pairs that feed [`pipelined_time`].
/// Callers warm the codec's predictor state first.
pub fn time_layer_frames(
    codec: &mut dyn GradientCodec,
    g: &ModelGrad,
) -> (Vec<Duration>, Vec<usize>) {
    codec.begin(g.layers.len()).unwrap();
    let mut comp = Vec::with_capacity(g.layers.len());
    let mut wire = Vec::with_capacity(g.layers.len());
    for (idx, layer) in g.layers.iter().enumerate() {
        let t0 = std::time::Instant::now();
        let frame = codec.encode_layer(idx, layer).unwrap();
        comp.push(t0.elapsed());
        wire.push(frame.wire_size());
    }
    (comp, wire)
}

/// Simulated completion time of a frame-streamed upload on one link:
/// layer `i`'s frame starts transmitting once it is encoded AND the link
/// is free — the pipeline schedule behind the streaming benches.
pub fn pipelined_time(layer_comp: &[Duration], layer_wire: &[usize], link: &LinkSpec) -> Duration {
    let mut comp_done = 0.0f64;
    let mut send_done = 0.0f64;
    for (dt, &bytes) in layer_comp.iter().zip(layer_wire) {
        comp_done += dt.as_secs_f64();
        let start = comp_done.max(send_done);
        send_done = start + link.transmit_time(bytes).as_secs_f64();
    }
    Duration::from_secs_f64(send_done)
}

/// Banner for a bench binary.
pub fn banner(name: &str, paper_ref: &str) {
    println!("━━━ {name} — reproduces {paper_ref} ━━━");
    if !full_mode() {
        println!("(fast subset; set FEDGEC_FULL=1 for the paper's full grid)\n");
    }
}
