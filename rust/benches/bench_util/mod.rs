//! Shared helpers for the hand-rolled bench harness (`harness = false`;
//! criterion is unavailable offline). Each bench binary regenerates one
//! paper table/figure: printed as markdown + saved to `results/*.csv`.

#![allow(dead_code)]

use fedgec::tensor::model_zoo::ModelArch;
use fedgec::train::data::DatasetSpec;

/// `FEDGEC_FULL=1` runs the paper's full grid; default is a fast subset.
pub fn full_mode() -> bool {
    std::env::var("FEDGEC_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Models for the compression-grid experiments.
pub fn grid_models() -> Vec<ModelArch> {
    if full_mode() {
        vec![ModelArch::ResNet18, ModelArch::ResNet34, ModelArch::InceptionV1, ModelArch::InceptionV3]
    } else {
        vec![ModelArch::ResNet18, ModelArch::InceptionV1]
    }
}

/// Datasets for the compression-grid experiments.
pub fn grid_datasets() -> Vec<DatasetSpec> {
    if full_mode() {
        vec![DatasetSpec::Cifar10, DatasetSpec::Caltech101, DatasetSpec::Fmnist]
    } else {
        vec![DatasetSpec::Cifar10, DatasetSpec::Fmnist]
    }
}

/// The paper's REL error-bound sweep (Table 4 columns).
pub fn grid_bounds() -> Vec<f64> {
    vec![1e-3, 1e-2, 3e-2, 5e-2]
}

/// Number of gradient rounds averaged per grid cell.
pub fn grid_rounds() -> usize {
    if full_mode() {
        5
    } else {
        3
    }
}

/// Banner for a bench binary.
pub fn banner(name: &str, paper_ref: &str) {
    println!("━━━ {name} — reproduces {paper_ref} ━━━");
    if !full_mode() {
        println!("(fast subset; set FEDGEC_FULL=1 for the paper's full grid)\n");
    }
}
