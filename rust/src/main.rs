//! `fedgec` — the FL + gradient-compression launcher.
//!
//! Subcommands:
//!   run            single-process FL simulation (HLO or native trainer)
//!   serve          TCP parameter server (native trainer clients connect)
//!   client         TCP client joining a `serve` federation
//!   compress-file  run any codec over a raw f32 file, report CR + bound
//!   codecs         list the codec registry and spec grammar
//!   tail           render a round journal (JSONL) as a per-round table
//!   info           environment / artifact status

use fedgec::cli::Args;

use fedgec::config::RunConfig;
use fedgec::fl::transport::bandwidth::LinkSpec;
use fedgec::tensor::{LayerGrad, LayerMeta, ModelGrad};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("serve") => cmd_serve(&args),
        Some("client") => cmd_client(&args),
        Some("compress-file") => cmd_compress_file(&args),
        Some("codecs") => cmd_codecs(),
        Some("tail") => cmd_tail(&args),
        Some("info") => cmd_info(),
        _ => {
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "fedgec — gradient-aware error-bounded lossy compression for FL\n\
         \n\
         USAGE:\n\
         fedgec run [--config FILE] [--model M] [--dataset D] [--codec C]\n\
         \u{20}          [--rounds N] [--rel_error_bound EB] [--bandwidth_mbps B]\n\
         \u{20}          [--engine native|hlo] ... (any RunConfig key)\n\
         fedgec serve --addr 127.0.0.1:7070 [--config FILE]\n\
         \u{20}            [--metrics-addr 127.0.0.1:9100] [--journal FILE] [...]\n\
         fedgec client --addr 127.0.0.1:7070 --id K [--config FILE] [...]\n\
         fedgec compress-file --in FILE [--codec fedgec] [--eb 1e-2]\n\
         fedgec codecs\n\
         fedgec tail JOURNAL.jsonl [--follow]\n\
         fedgec info\n\
         \n\
         --codec accepts any CodecSpec string, e.g. 'fedgec:eb=rel1e-2,beta=0.9',\n\
         'fedgec:pred=auto,sign=kernel', 'qsgd:bits=5', 'topk:k=0.05',\n\
         'ef(qsgd:bits=5)'. See `fedgec codecs`. --pred / --sign set the\n\
         fedgec predictor defaults (pred=ema|last|zero|auto,\n\
         sign=auto|osc|kernel|none); explicit spec keys win.\n\
         --down compresses the server broadcast the same way (global-delta\n\
         codec, encode-once fan-out): --down fedgec --down_eb 1e-3; 'raw'\n\
         keeps the uncompressed broadcast. --down_bandwidth_mbps sets an\n\
         asymmetric downlink rate.\n\
         --ebc schedules the error bound per round (adaptive controller,\n\
         DESIGN.md \u{a7}15): --ebc plateau | plateau:3,0.5 | layerwise |\n\
         schedule:0:1e-2,20:5e-3. Default 'fixed' keeps --rel_error_bound\n\
         for the whole run. See `fedgec codecs` for the registry.\n\
         --metrics-addr exposes Prometheus text on GET /metrics while the\n\
         server runs; --journal FILE (run/serve) streams one JSONL record\n\
         per round event, rendered later with `fedgec tail`."
    );
}

fn cmd_codecs() -> fedgec::Result<()> {
    use fedgec::compress::spec::REGISTRY;
    let mut t = fedgec::metrics::Table::new(
        "codec registry (spec grammar: family[:key=value,...] | ef(<spec>))",
        &["family", "aliases", "example", "about"],
    );
    for fam in REGISTRY {
        t.row(vec![
            fam.family.to_string(),
            fam.aliases.join(", "),
            fam.example.to_string(),
            fam.about.to_string(),
        ]);
    }
    t.print();
    let mut p = fedgec::metrics::Table::new(
        "fedgec predictor registry (keys pred= / sign=)",
        &["key", "value", "about"],
    );
    for fam in fedgec::compress::predictor::magnitude::MAG_REGISTRY {
        p.row(vec!["pred".into(), fam.name.to_string(), fam.about.to_string()]);
    }
    for fam in fedgec::compress::predictor::sign::SIGN_REGISTRY {
        p.row(vec!["sign".into(), fam.name.to_string(), fam.about.to_string()]);
    }
    p.print();
    let mut c = fedgec::metrics::Table::new(
        "error-bound controller registry (key ebc=)",
        &["spec", "about"],
    );
    for (spec, about) in fedgec::compress::control::EBC_REGISTRY {
        c.row(vec![spec.to_string(), about.to_string()]);
    }
    c.print();
    Ok(())
}

fn load_config(args: &Args) -> fedgec::Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(path)?,
        None => RunConfig::default(),
    };
    for (k, v) in &args.flags {
        if k == "config" || k == "addr" || k == "id" || k == "threaded" || k == "in" || k == "out" {
            continue;
        }
        // Telemetry flags are consumed by the launcher, not RunConfig.
        if k == "metrics-addr" || k == "journal" || k == "follow" {
            continue;
        }
        cfg.apply_override(k, v)?;
    }
    Ok(cfg)
}

fn cmd_run(args: &Args) -> fedgec::Result<()> {
    let cfg = load_config(args)?;
    if let Some(path) = args.get("journal") {
        fedgec::telemetry::journal::attach(path)?;
    }
    let summary = if args.has("threaded") {
        fedgec::coordinator::run_threaded(&cfg)
    } else {
        fedgec::coordinator::run_local(&cfg)
    };
    // Flush the journal even when the run fails partway.
    fedgec::telemetry::journal::detach();
    fedgec::coordinator::print_summary(&cfg, &summary?);
    Ok(())
}

fn cmd_serve(args: &Args) -> fedgec::Result<()> {
    let cfg = load_config(args)?;
    anyhow::ensure!(cfg.model == "native", "TCP mode uses the native trainer (model=native)");
    if let Some(path) = args.get("journal") {
        fedgec::telemetry::journal::attach(path)?;
    }
    // Keep the exposition listener alive for the whole serve loop; Drop
    // shuts it down if the loop errors out early.
    let metrics = match args.get("metrics-addr") {
        Some(maddr) => {
            let srv = fedgec::telemetry::MetricsServer::bind(maddr)?;
            println!("metrics exposed on http://{}/metrics", srv.addr());
            Some(srv)
        }
        None => None,
    };
    let addr = args.get_or("addr", "127.0.0.1:7070");
    let listener = std::net::TcpListener::bind(addr)?;
    println!("server listening on {addr}, waiting for {} clients…", cfg.n_clients);
    let chans = fedgec::fl::transport::tcp::accept_n(&listener, cfg.n_clients, None)?;
    let mut channels: Vec<Box<dyn fedgec::fl::transport::Channel>> =
        chans.into_iter().map(|c| Box::new(c) as _).collect();
    let proto = fedgec::train::native::NativeNet::new(cfg.dataset.classes(), cfg.seed);
    let metas = proto.layer_metas();
    let init =
        vec![proto.conv_w.clone(), proto.conv_b.clone(), proto.fc_w.clone(), proto.fc_b.clone()];
    let mut server = fedgec::fl::server::Server::new(
        init,
        metas.clone(),
        cfg.server_lr,
        fedgec::coordinator::build_engine(&cfg)?,
        cfg.build_state_store()?,
    );
    if let Some(spec) = cfg.down_spec()? {
        server = server
            .with_downlink(fedgec::compress::downlink::DownlinkCodec::new(&spec, metas));
    }
    server.wait_hellos(&mut channels)?;
    for r in 0..cfg.rounds {
        let stats = server.run_round(&mut channels)?;
        println!(
            "round {r}: loss {:.4} CR {:.2} payload {:.1} KB | down {:.1} KB ({} syncs) | \
             {} states ({:.0} KB)",
            stats.mean_loss,
            stats.ratio(),
            stats.payload_bytes as f64 / 1e3,
            stats.downlink_bytes as f64 / 1e3,
            stats.full_syncs,
            stats.store_clients,
            stats.store_bytes as f64 / 1e3,
        );
    }
    server.shutdown(&mut channels)?;
    drop(metrics);
    fedgec::telemetry::journal::detach();
    println!("done.");
    Ok(())
}

fn cmd_tail(args: &Args) -> fedgec::Result<()> {
    let path = args
        .positionals
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: fedgec tail JOURNAL.jsonl [--follow]"))?;
    let render = |text: &str| -> fedgec::Result<()> {
        fedgec::telemetry::tail::table_from(text)?.print();
        Ok(())
    };
    if !args.has("follow") {
        return render(&std::fs::read_to_string(path)?);
    }
    // Follow mode: re-render whenever the file grows (coarse 500 ms poll
    // — the journal gains at most a handful of records per round).
    let mut last_len = usize::MAX;
    loop {
        if let Ok(text) = std::fs::read_to_string(path) {
            if text.len() != last_len {
                last_len = text.len();
                render(&text)?;
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(500));
    }
}

fn cmd_client(args: &Args) -> fedgec::Result<()> {
    let cfg = load_config(args)?;
    let addr = args.get_or("addr", "127.0.0.1:7070");
    let id = args.get_usize("id", 0)? as u32;
    let link = if cfg.link.bits_per_sec.is_finite() { Some(cfg.link) } else { None };
    let mut channel = fedgec::fl::transport::tcp::TcpChannel::connect(addr, link)?;
    let ds = fedgec::train::data::SynthDataset::new(cfg.dataset, cfg.seed);
    let mut rng = fedgec::util::rng::Rng::new(cfg.seed ^ 0xDA);
    let mut rng = rng.fork(id as u64);
    let slice = ds.sample(&mut rng, cfg.samples_per_client, cfg.class_skew);
    let trainer = fedgec::coordinator::native_trainer::NativeTrainer::new(
        cfg.dataset.classes(),
        slice,
        cfg.local_lr,
        cfg.seed,
    );
    let codec = fedgec::coordinator::build_codec(&cfg)?;
    let mut client = fedgec::fl::client::Client::new(id, Box::new(trainer), codec)
        .with_streaming(cfg.stream_updates);
    if let Some(spec) = cfg.down_spec()? {
        let metas = fedgec::train::native::NativeNet::new(cfg.dataset.classes(), cfg.seed)
            .layer_metas();
        client = client
            .with_downlink(fedgec::compress::downlink::DownlinkMirror::new(&spec, metas));
    }
    println!("client {id} connected to {addr}");
    client.run(&mut channel)
}

fn cmd_compress_file(args: &Args) -> fedgec::Result<()> {
    let path = args
        .get("in")
        .ok_or_else(|| anyhow::anyhow!("--in FILE required (raw little-endian f32s)"))?;
    let bytes = std::fs::read(path)?;
    let data = fedgec::compress::blob::bytes_to_f32s(&bytes)?;
    let eb = args.get_f64("eb", 1e-2)?;
    let codec_name = args.get_or("codec", "fedgec");
    let spec = fedgec::compress::spec::CodecSpec::parse_with(
        codec_name,
        &fedgec::compress::spec::SpecDefaults::with_rel_eb(eb),
    )?;
    let mut codec = spec.build();
    let meta = LayerMeta::other("file", data.len());
    let grads = ModelGrad { layers: vec![LayerGrad::new(meta.clone(), data)] };
    let t0 = std::time::Instant::now();
    let payload = codec.compress(&grads)?;
    let ct = t0.elapsed();
    let t1 = std::time::Instant::now();
    let recon = codec.decompress(&payload, &[meta])?;
    let dt = t1.elapsed();
    let max_err = grads.layers[0]
        .data
        .iter()
        .zip(&recon.layers[0].data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "{}: {} -> {} bytes (CR {:.2}) | compress {} decompress {} | max err {:.3e}",
        codec_name,
        grads.byte_size(),
        payload.len(),
        grads.byte_size() as f64 / payload.len() as f64,
        fedgec::metrics::fmt_duration(ct),
        fedgec::metrics::fmt_duration(dt),
        max_err
    );
    Ok(())
}

fn cmd_info() -> fedgec::Result<()> {
    println!("fedgec {}", env!("CARGO_PKG_VERSION"));
    let dir = fedgec::runtime::Runtime::default_dir();
    println!("artifacts dir: {dir:?}");
    match fedgec::runtime::manifest::Manifest::load(&dir) {
        Ok(m) => {
            println!(
                "manifest: {} models, {} kernels (epoch = {}x{} batches)",
                m.models.len(),
                m.kernels.len(),
                m.batches_per_epoch,
                m.batch_size
            );
            match fedgec::runtime::Runtime::new(&dir) {
                Ok(rt) => println!("PJRT platform: {}", rt.platform()),
                Err(e) => println!("PJRT unavailable: {e}"),
            }
        }
        Err(e) => println!("no artifacts ({e}); run `make artifacts`"),
    }
    let _ = LinkSpec::mbps(10.0);
    Ok(())
}
