//! CI perf-regression gate CLI — the thin driver over [`fedgec::metrics::gate`].
//!
//! For every committed baseline under `results/baselines/*.json`, loads
//! the matching fresh `BENCH_<bench>.json` artifact (from
//! `$FEDGEC_RESULTS` or `./results`) and fails the build if any floor
//! or pin is violated.
//!
//! Baseline-update workflow (also documented in .github/workflows/ci.yml):
//!
//! 1. run the benches locally: `cargo bench --bench perf_throughput` etc.
//! 2. re-record the pins: `cargo run --bin bench_check -- --update`
//! 3. review + commit the rewritten `results/baselines/*.json`
//!
//! `--update` only re-records pins; floors are hand-edited on purpose —
//! raising or lowering a floor is a reviewed decision, not a side effect
//! of a bench run.

use anyhow::{bail, Context, Result};
use fedgec::metrics::{self, gate};
use std::path::PathBuf;

const USAGE: &str = "usage: bench_check [--update] [--baselines <dir>]
  --update           re-record every pin from the fresh BENCH_*.json artifacts
  --baselines <dir>  baseline directory (default: results/baselines)
reads bench artifacts from $FEDGEC_RESULTS or ./results";

fn main() {
    if let Err(e) = run() {
        eprintln!("bench_check: {e:#}");
        std::process::exit(2);
    }
}

fn run() -> Result<()> {
    let mut update = false;
    let mut baselines = PathBuf::from("results/baselines");
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--update" => update = true,
            "--baselines" => baselines = args.next().context("--baselines needs a dir")?.into(),
            "--help" | "-h" => {
                println!("{USAGE}");
                return Ok(());
            }
            other => bail!("unknown argument {other:?}\n{USAGE}"),
        }
    }
    let mut entries: Vec<PathBuf> = std::fs::read_dir(&baselines)
        .with_context(|| format!("reading baselines dir {}", baselines.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    entries.sort();
    if entries.is_empty() {
        bail!("no baseline files in {}", baselines.display());
    }
    let mut failed = false;
    for path in entries {
        let b = gate::Baseline::parse(&std::fs::read_to_string(&path)?)
            .with_context(|| path.display().to_string())?;
        let bench_path = metrics::results_dir().join(format!("BENCH_{}.json", b.bench));
        let doc = gate::BenchDoc::parse(
            &std::fs::read_to_string(&bench_path)
                .with_context(|| format!("missing bench artifact {}", bench_path.display()))?,
        )
        .with_context(|| bench_path.display().to_string())?;
        if update {
            let up = b.updated_from(&doc).with_context(|| path.display().to_string())?;
            std::fs::write(&path, up.to_json().to_string())?;
            println!("updated {} ({} pins re-recorded)", path.display(), up.pins.len());
            continue;
        }
        let out = gate::check(&b, &doc);
        for n in &out.notes {
            println!("note: {n}");
        }
        for v in &out.violations {
            eprintln!("FAIL: {v}");
        }
        println!("{}: {} checks, {} violations", b.bench, out.checked, out.violations.len());
        failed |= !out.pass();
    }
    if failed {
        std::process::exit(1);
    }
    Ok(())
}
