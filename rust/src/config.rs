//! The run-configuration system: a typed config loadable from JSON files
//! (`configs/*.json`) with CLI overrides — the launcher contract of the
//! framework.

use crate::compress::predictor::magnitude::{MagnitudeSel, DEFAULT_BETA};
use crate::compress::predictor::sign::SignSel;
use crate::compress::quant::ErrorBound;
use crate::compress::spec::{CodecSpec, SpecDefaults};
use crate::fl::transport::bandwidth::LinkSpec;
use crate::train::data::DatasetSpec;
use crate::util::json::Json;
use std::time::Duration;

/// Which engine runs the codec's predict stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Native fused Rust path (production default).
    Native,
    /// PJRT execution of the Pallas kernel's lowering.
    Hlo,
}

/// Full configuration of one FL simulation run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Model key: `micro_resnet` / `micro_inception` (HLO) or `native`.
    pub model: String,
    pub dataset: DatasetSpec,
    pub n_clients: usize,
    pub rounds: usize,
    pub samples_per_client: usize,
    /// Local SGD learning rate.
    pub local_lr: f32,
    /// Server-side learning rate on the aggregated gradient.
    pub server_lr: f32,
    /// Codec spec string — any [`CodecSpec`] form, e.g. `fedgec`,
    /// `fedgec:eb=rel1e-2,beta=0.9`, `qsgd:bits=5`, `ef(topk:k=0.05)`.
    /// Bare legacy names resolve with defaults from the other knobs.
    pub codec: String,
    /// Relative error bound (paper's REL mode).
    pub rel_error_bound: f64,
    /// Simulated uplink.
    pub link: LinkSpec,
    pub engine: EngineKind,
    /// Evaluate every k rounds (0 = only at end).
    pub eval_every: usize,
    pub seed: u64,
    /// Non-IID label skew in [0,1].
    pub class_skew: f64,
    /// FedGEC knobs.
    pub beta: f32,
    pub tau: f64,
    pub full_batch: bool,
    /// Magnitude-predictor selector fed to the codec spec as the `pred`
    /// default: `ema` | `last` | `zero` | `auto` (the spec string's own
    /// `pred=` key — including the `ema:<beta>` form — wins).
    pub pred: String,
    /// Sign-policy selector (`sign` default): `auto` | `osc` | `kernel`
    /// | `none`.
    pub sign: String,
    /// Frame-stream client updates (overlapping compression with
    /// transmission) instead of monolithic blobs, in threaded/TCP mode.
    pub stream_updates: bool,
    /// Fraction of clients participating per round, in (0, 1]. Below 1
    /// the `run_local` coordinator samples a deterministic subset each
    /// round ([`crate::fl::hetero::sample_participants`]); threaded/TCP
    /// mode rejects partial participation rather than ignoring it.
    pub participation: f64,
    /// Server state-store budget in MB (0 = unbounded). Under a budget
    /// the store evicts LRU client states; evicted clients cold-start on
    /// their next round via the StateCheck/StateResync handshake.
    pub store_budget_mb: f64,
    /// Server state-store backend: `mem` (sharded in-memory) or `disk`
    /// (same hot tier, evictions spill to a temp directory).
    pub store: String,
    /// Downlink broadcast codec spec: any [`CodecSpec`] string to
    /// compress the per-round global-model **delta** (encoded once on
    /// the server and fanned out to every participant), or `raw` for
    /// the uncompressed f32 broadcast. See
    /// [`crate::compress::downlink`].
    pub down: String,
    /// Relative error bound for the downlink codec — the default for
    /// `eb` when the `down` spec string leaves it out. The global delta
    /// feeds directly into every client's model, so the default is an
    /// order tighter than the uplink bound.
    pub down_eb: f64,
    /// Server aggregation mode: `exact` (decode every contribution to
    /// dense f32, then FedAvg) or `binsum` (compressed-domain
    /// aggregation — eligible layers accumulate integer quantizer bins
    /// and dequantize once per round; ineligible layers fall back to the
    /// exact path per layer). See [`crate::compress::agg`].
    pub agg: String,
    /// Server decode shards in threaded mode: 1 = flat sequential loop,
    /// N > 1 partitions the round's channels across N worker threads
    /// with per-shard partial aggregates merged tree-wise (see
    /// [`crate::fl::topology::sharded`]). Requires `down = raw`.
    pub shards: usize,
    /// Aggregation topology: `flat` (every client at the root) or
    /// `edge:<fanout>` (clients grouped into subtrees of `fanout`, each
    /// served by an edge aggregator that uplinks one merged
    /// contribution — see [`crate::fl::topology::edge`]). Requires
    /// `down = raw` when not flat.
    pub tier: String,
    /// Adaptive error-bound controller spec
    /// ([`crate::compress::control::EbcSpec`]):
    /// `fixed` | `schedule:<r:eb,...>` | `plateau[:patience,factor]` |
    /// `layerwise`. Anything but `fixed` makes the server broadcast a
    /// per-round `EbPlan` wire record that every client's codec adopts
    /// before encoding. See DESIGN.md §15.
    pub ebc: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "micro_resnet".into(),
            dataset: DatasetSpec::Cifar10,
            n_clients: 4,
            rounds: 20,
            samples_per_client: 256,
            // NOTE: gradients travel as (θ_global − θ_local)/local_lr, so
            // server_lr == local_lr makes the aggregation exact FedAvg
            // (the update equals the weighted mean of client parameters).
            local_lr: 0.05,
            server_lr: 0.05,
            codec: "fedgec".into(),
            rel_error_bound: 1e-2,
            link: LinkSpec::mbps(10.0),
            engine: EngineKind::Native,
            eval_every: 5,
            seed: 42,
            class_skew: 0.5,
            beta: DEFAULT_BETA,
            tau: 0.5,
            full_batch: false,
            pred: "ema".into(),
            sign: "auto".into(),
            stream_updates: true,
            participation: 1.0,
            store_budget_mb: 0.0,
            store: "mem".into(),
            down: "raw".into(),
            down_eb: 1e-3,
            agg: "exact".into(),
            shards: 1,
            tier: "flat".into(),
            ebc: "fixed".into(),
        }
    }
}

impl RunConfig {
    /// Parse from a JSON document; unknown keys are ignored, missing keys
    /// keep defaults.
    pub fn from_json(src: &str) -> crate::Result<RunConfig> {
        let v = Json::parse(src)?;
        let mut cfg = RunConfig::default();
        cfg.apply_json(&v)?;
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> crate::Result<RunConfig> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read config {path}: {e}"))?;
        Self::from_json(&src)
    }

    fn apply_json(&mut self, v: &Json) -> crate::Result<()> {
        self.model = v.str_or("model", &self.model).to_string();
        if let Some(d) = v.get("dataset").and_then(Json::as_str) {
            self.dataset = DatasetSpec::from_name(d)
                .ok_or_else(|| anyhow::anyhow!("unknown dataset {d}"))?;
        }
        self.n_clients = v.usize_or("n_clients", self.n_clients);
        self.rounds = v.usize_or("rounds", self.rounds);
        self.samples_per_client = v.usize_or("samples_per_client", self.samples_per_client);
        self.local_lr = v.f64_or("local_lr", self.local_lr as f64) as f32;
        self.server_lr = v.f64_or("server_lr", self.server_lr as f64) as f32;
        self.codec = v.str_or("codec", &self.codec).to_string();
        self.rel_error_bound = v.f64_or("rel_error_bound", self.rel_error_bound);
        anyhow::ensure!(
            self.rel_error_bound.is_finite() && self.rel_error_bound > 0.0,
            "rel_error_bound must be a finite positive number, got {}",
            self.rel_error_bound
        );
        let mbps = v.f64_or("bandwidth_mbps", self.link.bits_per_sec / 1e6);
        // Downlink bandwidth: explicit key wins; setting only the uplink
        // on a *symmetric* link keeps it symmetric, but never erases an
        // explicitly asymmetric downlink (CLI overrides arrive one key
        // per apply_json call, in flag order — the outcome must not
        // depend on that order).
        let was_symmetric = self.link.down_bits_per_sec == self.link.bits_per_sec;
        let down_mbps = match (v.get("down_bandwidth_mbps"), v.get("bandwidth_mbps")) {
            (Some(_), _) => v.f64_or("down_bandwidth_mbps", mbps),
            (None, Some(_)) if was_symmetric => mbps,
            _ => self.link.down_bits_per_sec / 1e6,
        };
        let latency_ms = v.f64_or("latency_ms", self.link.latency.as_secs_f64() * 1e3);
        self.link = LinkSpec {
            bits_per_sec: mbps * 1e6,
            down_bits_per_sec: down_mbps * 1e6,
            latency: Duration::from_secs_f64(latency_ms / 1e3),
        };
        if let Some(e) = v.get("engine").and_then(Json::as_str) {
            self.engine = match e {
                "native" => EngineKind::Native,
                "hlo" => EngineKind::Hlo,
                _ => anyhow::bail!("unknown engine {e}"),
            };
        }
        self.eval_every = v.usize_or("eval_every", self.eval_every);
        self.seed = v.f64_or("seed", self.seed as f64) as u64;
        self.class_skew = v.f64_or("class_skew", self.class_skew);
        self.beta = v.f64_or("beta", self.beta as f64) as f32;
        self.tau = v.f64_or("tau", self.tau);
        self.full_batch = v.bool_or("full_batch", self.full_batch);
        self.pred = v.str_or("pred", &self.pred).to_string();
        anyhow::ensure!(
            MagnitudeSel::from_name(&self.pred).is_some(),
            "unknown pred '{}' (ema|last|zero|auto)",
            self.pred
        );
        self.sign = v.str_or("sign", &self.sign).to_string();
        anyhow::ensure!(
            SignSel::from_name(&self.sign).is_some(),
            "unknown sign '{}' (auto|osc|kernel|none)",
            self.sign
        );
        self.stream_updates = v.bool_or("stream", self.stream_updates);
        self.participation = v.f64_or("participation", self.participation);
        anyhow::ensure!(
            self.participation > 0.0 && self.participation <= 1.0,
            "participation {} outside (0, 1]",
            self.participation
        );
        self.store_budget_mb = v.f64_or("store_budget_mb", self.store_budget_mb);
        anyhow::ensure!(self.store_budget_mb >= 0.0, "store_budget_mb must be >= 0");
        self.store = v.str_or("store", &self.store).to_string();
        anyhow::ensure!(
            matches!(self.store.as_str(), "mem" | "disk"),
            "unknown store backend '{}' (mem|disk)",
            self.store
        );
        self.down = v.str_or("down", &self.down).to_string();
        self.down_eb = v.f64_or("down_eb", self.down_eb);
        anyhow::ensure!(
            self.down_eb.is_finite() && self.down_eb > 0.0,
            "down_eb must be a finite positive number, got {}",
            self.down_eb
        );
        self.agg = v.str_or("agg", &self.agg).to_string();
        anyhow::ensure!(
            crate::fl::aggregate::AggMode::from_name(&self.agg).is_some(),
            "unknown agg mode '{}' (exact|binsum)",
            self.agg
        );
        self.shards = v.usize_or("shards", self.shards);
        anyhow::ensure!(
            (1..=4096).contains(&self.shards),
            "shards {} outside 1..=4096",
            self.shards
        );
        self.tier = v.str_or("tier", &self.tier).to_string();
        crate::fl::topology::TierSpec::from_name(&self.tier)
            .map_err(|e| anyhow::anyhow!("tier '{}': {e}", self.tier))?;
        self.ebc = v.str_or("ebc", &self.ebc).to_string();
        crate::compress::control::EbcSpec::parse(&self.ebc)
            .map_err(|e| anyhow::anyhow!("ebc '{}': {e}", self.ebc))?;
        // Fail fast on unparseable codec specs (both directions).
        self.codec_spec().map_err(|e| anyhow::anyhow!("codec '{}': {e}", self.codec))?;
        self.down_spec().map_err(|e| anyhow::anyhow!("down '{}': {e}", self.down))?;
        Ok(())
    }

    /// Apply `key=value` CLI overrides (same keys as the JSON form).
    pub fn apply_override(&mut self, key: &str, value: &str) -> crate::Result<()> {
        let quoted = matches!(
            key,
            "model"
                | "dataset"
                | "codec"
                | "engine"
                | "store"
                | "down"
                | "pred"
                | "sign"
                | "agg"
                | "tier"
                | "ebc"
        );
        let json_val = if quoted { format!("\"{value}\"") } else { value.to_string() };
        let doc = format!("{{\"{key}\": {json_val}}}");
        let v = Json::parse(&doc).map_err(|e| anyhow::anyhow!("override {key}={value}: {e}"))?;
        self.apply_json(&v)
    }

    /// The error bound as the codec type.
    pub fn error_bound(&self) -> ErrorBound {
        ErrorBound::Rel(self.rel_error_bound)
    }

    /// Resolve the codec spec string, with the config's scalar knobs
    /// (`rel_error_bound`, `beta`, `tau`, `full_batch`) as defaults for
    /// keys the spec leaves out. Explicit spec keys win.
    pub fn codec_spec(&self) -> crate::Result<CodecSpec> {
        let d = SpecDefaults {
            error_bound: self.error_bound(),
            qsgd_bits: crate::baselines::qsgd_bits_for_bound(self.rel_error_bound),
            beta: self.beta,
            tau: self.tau,
            full_batch: self.full_batch,
            pred: MagnitudeSel::from_name(&self.pred)
                .ok_or_else(|| anyhow::anyhow!("unknown pred '{}'", self.pred))?,
            sign: SignSel::from_name(&self.sign)
                .ok_or_else(|| anyhow::anyhow!("unknown sign '{}'", self.sign))?,
            ..Default::default()
        };
        CodecSpec::parse_with(&self.codec, &d)
    }

    /// Resolve the downlink codec spec: `None` when the broadcast stays
    /// raw (`down = "raw"`/`"none"`), otherwise the spec the server's
    /// [`crate::compress::downlink::DownlinkCodec`] and every client's
    /// mirror are built from. `down_eb` fills an omitted `eb` key.
    pub fn down_spec(&self) -> crate::Result<Option<CodecSpec>> {
        let d = SpecDefaults::with_rel_eb(self.down_eb);
        let spec = CodecSpec::parse_with(&self.down, &d)?;
        Ok(match spec {
            CodecSpec::Raw => None,
            other => Some(other),
        })
    }

    /// The adaptive error-bound controller spec (validated at load, so
    /// this never fails after `from_json` / `apply_override`).
    pub fn ebc_spec(&self) -> crate::compress::control::EbcSpec {
        crate::compress::control::EbcSpec::parse(&self.ebc)
            .unwrap_or(crate::compress::control::EbcSpec::Fixed)
    }

    /// The aggregation mode as the typed enum (validated at load, so
    /// this never fails after `from_json` / `apply_override`).
    pub fn agg_mode(&self) -> crate::fl::aggregate::AggMode {
        crate::fl::aggregate::AggMode::from_name(&self.agg)
            .unwrap_or(crate::fl::aggregate::AggMode::Exact)
    }

    /// The aggregation topology as the typed enum (validated at load,
    /// so this never fails after `from_json` / `apply_override`).
    pub fn tier_spec(&self) -> crate::fl::topology::TierSpec {
        crate::fl::topology::TierSpec::from_name(&self.tier)
            .unwrap_or(crate::fl::topology::TierSpec::Flat)
    }

    /// Build the server-side state store this config describes.
    pub fn build_state_store(
        &self,
    ) -> crate::Result<Box<dyn crate::compress::store::StateStore>> {
        use crate::compress::store::{DiskSpillStore, ShardedMemStore};
        let budget = if self.store_budget_mb > 0.0 {
            Some((self.store_budget_mb * 1e6) as usize)
        } else {
            None
        };
        match self.store.as_str() {
            "mem" => Ok(Box::new(ShardedMemStore::new(8, budget))),
            "disk" => {
                let dir = std::env::temp_dir()
                    .join(format!("fedgec_spill_{}_{}", std::process::id(), self.seed));
                // Disk spill needs a finite hot tier; default to 64 MB
                // when the budget is left unbounded.
                let hot = budget.unwrap_or(64 << 20);
                Ok(Box::new(DiskSpillStore::new(dir, 8, hot)?))
            }
            other => anyhow::bail!("unknown store backend '{other}'"),
        }
    }

    /// Manifest key of the model artifact for the chosen dataset.
    pub fn model_key(&self) -> String {
        format!("{}_{}", self.model, self.dataset.class_suffix())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = RunConfig::default();
        assert_eq!(c.model_key(), "micro_resnet_c10");
        assert!(c.rel_error_bound > 0.0);
    }

    #[test]
    fn json_overrides() {
        let c = RunConfig::from_json(
            r#"{"model": "micro_inception", "dataset": "caltech101",
                "rounds": 3, "bandwidth_mbps": 1.5, "engine": "hlo",
                "codec": "sz3", "rel_error_bound": 0.03}"#,
        )
        .unwrap();
        assert_eq!(c.model_key(), "micro_inception_c101");
        assert_eq!(c.rounds, 3);
        assert_eq!(c.engine, EngineKind::Hlo);
        assert!((c.link.bits_per_sec - 1.5e6).abs() < 1.0);
        assert_eq!(c.codec, "sz3");
    }

    #[test]
    fn cli_override() {
        let mut c = RunConfig::default();
        c.apply_override("rounds", "7").unwrap();
        c.apply_override("dataset", "fmnist").unwrap();
        assert_eq!(c.rounds, 7);
        assert_eq!(c.dataset, DatasetSpec::Fmnist);
        assert!(c.apply_override("dataset", "nope").is_err());
    }

    #[test]
    fn bad_engine_errors() {
        assert!(RunConfig::from_json(r#"{"engine": "gpu"}"#).is_err());
    }

    #[test]
    fn codec_spec_strings_accepted() {
        let c = RunConfig::from_json(r#"{"codec": "qsgd:bits=6"}"#).unwrap();
        assert_eq!(c.codec_spec().unwrap(), CodecSpec::Qsgd { bits: 6, seed: 0 });
        // Legacy bare names resolve with the config's scalar knobs.
        let c2 = RunConfig::from_json(
            r#"{"codec": "fedgec", "rel_error_bound": 0.03, "beta": 0.8}"#,
        )
        .unwrap();
        match c2.codec_spec().unwrap() {
            CodecSpec::Fedgec { eb, beta, .. } => {
                assert_eq!(eb, ErrorBound::Rel(0.03));
                assert!((beta - 0.8).abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
        // Unparseable specs are rejected at config load.
        assert!(RunConfig::from_json(r#"{"codec": "bogus"}"#).is_err());
        assert!(RunConfig::from_json(r#"{"codec": "qsgd:bits=99"}"#).is_err());
    }

    #[test]
    fn pred_and_sign_keys_parse_and_feed_spec_defaults() {
        // Config-level selectors become the spec defaults…
        let c = RunConfig::from_json(r#"{"pred": "auto", "sign": "none"}"#).unwrap();
        match c.codec_spec().unwrap() {
            CodecSpec::Fedgec { pred, sign, .. } => {
                assert_eq!(pred, MagnitudeSel::Auto);
                assert_eq!(sign, SignSel::None);
            }
            other => panic!("{other:?}"),
        }
        // …and explicit spec keys win over them.
        let c =
            RunConfig::from_json(r#"{"codec": "fedgec:pred=last", "pred": "auto"}"#).unwrap();
        match c.codec_spec().unwrap() {
            CodecSpec::Fedgec { pred, .. } => assert_eq!(pred, MagnitudeSel::Last),
            other => panic!("{other:?}"),
        }
        // Garbage is rejected at config load, CLI overrides quote.
        assert!(RunConfig::from_json(r#"{"pred": "bogus"}"#).is_err());
        assert!(RunConfig::from_json(r#"{"sign": "bogus"}"#).is_err());
        let mut c = RunConfig::default();
        c.apply_override("pred", "zero").unwrap();
        c.apply_override("sign", "kernel").unwrap();
        assert!(matches!(
            c.codec_spec().unwrap(),
            CodecSpec::Fedgec { pred: MagnitudeSel::Zero, sign: SignSel::Kernel, .. }
        ));
        // Defaults stay the classic pipeline.
        let d = RunConfig::default();
        assert_eq!(d.pred, "ema");
        assert_eq!(d.sign, "auto");
    }

    #[test]
    fn down_keys_parse_and_validate() {
        // Default: raw broadcast, no downlink codec.
        let d = RunConfig::default();
        assert_eq!(d.down, "raw");
        assert!(d.down_spec().unwrap().is_none());
        // A spec string builds the downlink codec with down_eb defaults.
        let c = RunConfig::from_json(r#"{"down": "fedgec", "down_eb": 1e-3}"#).unwrap();
        match c.down_spec().unwrap() {
            Some(CodecSpec::Fedgec { eb, .. }) => assert_eq!(eb, ErrorBound::Rel(1e-3)),
            other => panic!("{other:?}"),
        }
        // Explicit spec keys win over down_eb.
        let c = RunConfig::from_json(r#"{"down": "fedgec:eb=rel5e-4,ec=rans"}"#).unwrap();
        match c.down_spec().unwrap() {
            Some(CodecSpec::Fedgec { eb, .. }) => assert_eq!(eb, ErrorBound::Rel(5e-4)),
            other => panic!("{other:?}"),
        }
        // `none` is an alias for the raw broadcast.
        assert!(RunConfig::from_json(r#"{"down": "none"}"#)
            .unwrap()
            .down_spec()
            .unwrap()
            .is_none());
        // Garbage is rejected at config load.
        assert!(RunConfig::from_json(r#"{"down": "bogus"}"#).is_err());
        assert!(RunConfig::from_json(r#"{"down_eb": 0.0}"#).is_err());
        // CLI override path quotes the spec string.
        let mut c = RunConfig::default();
        c.apply_override("down", "sz3:eb=rel1e-3").unwrap();
        assert!(matches!(c.down_spec().unwrap(), Some(CodecSpec::Sz3 { .. })));
    }

    #[test]
    fn asymmetric_bandwidth_keys() {
        // Only the uplink set: the link stays symmetric.
        let c = RunConfig::from_json(r#"{"bandwidth_mbps": 10}"#).unwrap();
        assert!((c.link.down_bits_per_sec - 10e6).abs() < 1.0);
        // Both directions set: down ≫ up.
        let c =
            RunConfig::from_json(r#"{"bandwidth_mbps": 10, "down_bandwidth_mbps": 80}"#).unwrap();
        assert!((c.link.bits_per_sec - 10e6).abs() < 1.0);
        assert!((c.link.down_bits_per_sec - 80e6).abs() < 1.0);
        // Downlink alone leaves the uplink at its default.
        let mut c = RunConfig::default();
        let up = c.link.bits_per_sec;
        c.apply_override("down_bandwidth_mbps", "200").unwrap();
        assert_eq!(c.link.bits_per_sec, up);
        assert!((c.link.down_bits_per_sec - 200e6).abs() < 1.0);
        // CLI overrides arrive one key at a time: either flag order
        // must yield the same asymmetric link.
        let mut a = RunConfig::default();
        a.apply_override("down_bandwidth_mbps", "80").unwrap();
        a.apply_override("bandwidth_mbps", "10").unwrap();
        let mut b = RunConfig::default();
        b.apply_override("bandwidth_mbps", "10").unwrap();
        b.apply_override("down_bandwidth_mbps", "80").unwrap();
        for c in [&a, &b] {
            assert!((c.link.bits_per_sec - 10e6).abs() < 1.0);
            assert!((c.link.down_bits_per_sec - 80e6).abs() < 1.0);
        }
    }

    #[test]
    fn agg_key_parses_and_validates() {
        use crate::fl::aggregate::AggMode;
        // Default: exact dense aggregation.
        let d = RunConfig::default();
        assert_eq!(d.agg, "exact");
        assert_eq!(d.agg_mode(), AggMode::Exact);
        // JSON and CLI forms both select binsum.
        let c = RunConfig::from_json(r#"{"agg": "binsum"}"#).unwrap();
        assert_eq!(c.agg_mode(), AggMode::Binsum);
        let mut c = RunConfig::default();
        c.apply_override("agg", "binsum").unwrap();
        assert_eq!(c.agg_mode(), AggMode::Binsum);
        // Garbage is rejected at config load.
        assert!(RunConfig::from_json(r#"{"agg": "bogus"}"#).is_err());
        assert!(c.apply_override("agg", "nope").is_err());
    }

    #[test]
    fn shards_and_tier_keys_parse_and_validate() {
        use crate::fl::topology::TierSpec;
        // Defaults: flat topology, one shard.
        let d = RunConfig::default();
        assert_eq!(d.shards, 1);
        assert_eq!(d.tier_spec(), TierSpec::Flat);
        // JSON and CLI forms.
        let c = RunConfig::from_json(r#"{"shards": 8, "tier": "edge:32"}"#).unwrap();
        assert_eq!(c.shards, 8);
        assert_eq!(c.tier_spec(), TierSpec::Edge { fanout: 32 });
        let mut c = RunConfig::default();
        c.apply_override("shards", "4").unwrap();
        c.apply_override("tier", "edge:16").unwrap();
        assert_eq!(c.shards, 4);
        assert_eq!(c.tier_spec(), TierSpec::Edge { fanout: 16 });
        // Out-of-range / garbage rejected at load.
        assert!(RunConfig::from_json(r#"{"shards": 0}"#).is_err());
        assert!(RunConfig::from_json(r#"{"shards": 5000}"#).is_err());
        assert!(RunConfig::from_json(r#"{"tier": "edge:1"}"#).is_err());
        assert!(RunConfig::from_json(r#"{"tier": "ring"}"#).is_err());
    }

    #[test]
    fn error_bounds_validated_at_parse_time() {
        // Zero, negative and non-finite bounds must fail at config load
        // with the offending key named — never reach the quantizer.
        // (1e999 overflows f64 parsing to +inf; the JSON grammar itself
        // has no NaN literal — that arrives via spec strings below.)
        for bad in ["0.0", "-1e-2", "1e999", "-1e999"] {
            let doc = format!("{{\"rel_error_bound\": {bad}}}");
            let err = RunConfig::from_json(&doc).expect_err(&doc).to_string();
            assert!(err.contains("rel_error_bound"), "{doc}: {err}");
            let doc = format!("{{\"down_eb\": {bad}}}");
            let err = RunConfig::from_json(&doc).expect_err(&doc).to_string();
            assert!(err.contains("down_eb"), "{doc}: {err}");
        }
        // The spec-string route is validated too (naming its eb key).
        let err = RunConfig::from_json(r#"{"codec": "fedgec:eb=nan"}"#)
            .expect_err("nan eb spec")
            .to_string();
        assert!(err.contains("eb"), "{err}");
        assert!(RunConfig::from_json(r#"{"down": "fedgec:eb=rel0"}"#).is_err());
    }

    #[test]
    fn ebc_key_parses_and_validates() {
        use crate::compress::control::EbcSpec;
        // Default: fixed controller, nothing broadcast.
        let d = RunConfig::default();
        assert_eq!(d.ebc, "fixed");
        assert!(d.ebc_spec().is_fixed());
        // JSON and CLI forms.
        let c = RunConfig::from_json(r#"{"ebc": "plateau:3,0.25"}"#).unwrap();
        assert_eq!(c.ebc_spec(), EbcSpec::Plateau { patience: 3, factor: 0.25 });
        let mut c = RunConfig::default();
        c.apply_override("ebc", "schedule:0:0.03,10:0.01").unwrap();
        assert!(matches!(c.ebc_spec(), EbcSpec::Schedule(_)));
        c.apply_override("ebc", "layerwise").unwrap();
        assert_eq!(c.ebc_spec(), EbcSpec::Layerwise);
        // Garbage rejected at load, naming the key.
        let err =
            RunConfig::from_json(r#"{"ebc": "bogus"}"#).expect_err("bogus ebc").to_string();
        assert!(err.contains("ebc"), "{err}");
        assert!(RunConfig::from_json(r#"{"ebc": "plateau:0,0.5"}"#).is_err());
    }

    #[test]
    fn stream_toggle_parses() {
        assert!(RunConfig::default().stream_updates);
        let c = RunConfig::from_json(r#"{"stream": false}"#).unwrap();
        assert!(!c.stream_updates);
    }

    #[test]
    fn participation_and_store_keys_parse() {
        use crate::compress::store::StateStore as _;
        let c = RunConfig::from_json(
            r#"{"participation": 0.5, "store_budget_mb": 2.5, "store": "mem"}"#,
        )
        .unwrap();
        assert!((c.participation - 0.5).abs() < 1e-12);
        assert!((c.store_budget_mb - 2.5).abs() < 1e-12);
        assert!(c.build_state_store().is_ok());
        // Defaults: full participation, unbounded mem store.
        let d = RunConfig::default();
        assert_eq!(d.participation, 1.0);
        assert_eq!(d.store, "mem");
        assert!(d.build_state_store().unwrap().stats().budget_bytes.is_none());
        // Invalid values rejected at load.
        assert!(RunConfig::from_json(r#"{"participation": 0.0}"#).is_err());
        assert!(RunConfig::from_json(r#"{"participation": 1.5}"#).is_err());
        assert!(RunConfig::from_json(r#"{"store": "s3"}"#).is_err());
    }
}
