//! Minimal CLI argument parser (no `clap` offline): subcommand + `--key
//! value` / `--key=value` flags + bare positionals.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positionals: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> crate::Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // Value is the next token unless it's another flag or
                    // missing -> boolean true.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(stripped.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(stripped.to_string(), "true".to_string());
                        }
                    }
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positionals.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> crate::Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> crate::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key}: bad number '{v}'")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> crate::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key}: bad integer '{v}'")),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("run --config x.json --rounds 5 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("config"), Some("x.json"));
        assert_eq!(a.get_usize("rounds", 0).unwrap(), 5);
        assert_eq!(a.get("verbose"), Some("true"));
    }

    #[test]
    fn equals_form() {
        let a = parse("bench --eb=0.03");
        assert_eq!(a.get_f64("eb", 0.0).unwrap(), 0.03);
    }

    #[test]
    fn positionals() {
        let a = parse("compress file1 file2 --codec sz3");
        assert_eq!(a.positionals, vec!["file1", "file2"]);
        assert_eq!(a.get("codec"), Some("sz3"));
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("run --rounds xyz");
        assert!(a.get_usize("rounds", 0).is_err());
    }
}
