//! # FedGEC — gradient-aware error-bounded lossy compression for federated learning
//!
//! Reproduction of *"An Efficient Gradient-Aware Error-Bounded Lossy
//! Compressor for Federated Learning"* (CS.LG 2025) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the production compression pipeline
//!   ([`compress`]), comparator baselines ([`baselines`]), and a complete
//!   federated-learning runtime ([`fl`], [`coordinator`]) with simulated
//!   bandwidth links.
//! * **L2/L1 (python/, build time only)** — a JAX micro-CNN whose
//!   `train_epoch`/`eval` graphs and a fused Pallas `predict_quantize`
//!   kernel are AOT-lowered to HLO text and executed from Rust through
//!   PJRT ([`runtime`]).
//!
//! See `DESIGN.md` for the paper-to-module map and `EXPERIMENTS.md` for
//! the reproduced tables/figures.

pub mod util;
pub mod tensor;
pub mod compress;
pub mod baselines;
pub mod fl;
pub mod train;
pub mod runtime;
pub mod metrics;
pub mod telemetry;
pub mod coordinator;
pub mod config;
pub mod cli;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
