//! Radix-2 iterative FFT — substrate for the Fig. 4 magnitude-spectrum
//! experiment (the paper shows gradient-magnitude dynamics are dominated
//! by low-frequency components).

use std::f64::consts::PI;

/// Complex number as (re, im) — kept bare to avoid any dependency.
pub type C = (f64, f64);

#[inline]
fn c_add(a: C, b: C) -> C {
    (a.0 + b.0, a.1 + b.1)
}
#[inline]
fn c_sub(a: C, b: C) -> C {
    (a.0 - b.0, a.1 - b.1)
}
#[inline]
fn c_mul(a: C, b: C) -> C {
    (a.0 * b.0 - a.1 * b.1, a.0 * b.1 + a.1 * b.0)
}

/// In-place radix-2 decimation-in-time FFT. `xs.len()` must be a power of
/// two. `inverse` computes the unscaled inverse transform (caller divides
/// by n).
pub fn fft_in_place(xs: &mut [C], inverse: bool) {
    let n = xs.len();
    assert!(n.is_power_of_two(), "fft length must be a power of two, got {n}");
    // Bit-reversal permutation.
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            xs.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let wlen = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let mut w = (1.0, 0.0);
            for k in 0..len / 2 {
                let u = xs[i + k];
                let v = c_mul(xs[i + k + len / 2], w);
                xs[i + k] = c_add(u, v);
                xs[i + k + len / 2] = c_sub(u, v);
                w = c_mul(w, wlen);
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Magnitude spectrum of a real signal, zero-padded to the next power of
/// two. Returns the first n/2+1 magnitudes (one-sided spectrum).
pub fn magnitude_spectrum(signal: &[f64]) -> Vec<f64> {
    if signal.is_empty() {
        return vec![];
    }
    let n = signal.len().next_power_of_two();
    let mut xs: Vec<C> = signal.iter().map(|&x| (x, 0.0)).collect();
    xs.resize(n, (0.0, 0.0));
    fft_in_place(&mut xs, false);
    xs[..n / 2 + 1]
        .iter()
        .map(|&(re, im)| (re * re + im * im).sqrt())
        .collect()
}

/// Naive O(n^2) DFT used only as a test oracle.
#[cfg(test)]
pub fn dft_naive(xs: &[C]) -> Vec<C> {
    let n = xs.len();
    (0..n)
        .map(|k| {
            let mut acc = (0.0, 0.0);
            for (t, &x) in xs.iter().enumerate() {
                let ang = -2.0 * PI * (k * t) as f64 / n as f64;
                acc = c_add(acc, c_mul(x, (ang.cos(), ang.sin())));
            }
            acc
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fft_matches_naive_dft() {
        let mut rng = Rng::new(1);
        let n = 64;
        let xs: Vec<C> = (0..n).map(|_| (rng.gauss(), rng.gauss())).collect();
        let want = dft_naive(&xs);
        let mut got = xs.clone();
        fft_in_place(&mut got, false);
        for (g, w) in got.iter().zip(&want) {
            assert!((g.0 - w.0).abs() < 1e-9 && (g.1 - w.1).abs() < 1e-9);
        }
    }

    #[test]
    fn fft_inverse_roundtrip() {
        let mut rng = Rng::new(2);
        let n = 128;
        let xs: Vec<C> = (0..n).map(|_| (rng.gauss(), 0.0)).collect();
        let mut y = xs.clone();
        fft_in_place(&mut y, false);
        fft_in_place(&mut y, true);
        for (a, b) in xs.iter().zip(&y) {
            assert!((a.0 - b.0 / n as f64).abs() < 1e-9);
        }
    }

    #[test]
    fn spectrum_peaks_at_tone_frequency() {
        let n = 256;
        let freq = 16;
        let signal: Vec<f64> =
            (0..n).map(|t| (2.0 * PI * freq as f64 * t as f64 / n as f64).sin()).collect();
        let spec = magnitude_spectrum(&signal);
        let argmax = spec.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert_eq!(argmax, freq);
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_panics() {
        let mut xs = vec![(0.0, 0.0); 3];
        fft_in_place(&mut xs, false);
    }
}
