//! A small fixed-size worker pool (no `tokio`/`rayon` offline). Used for
//! parallel per-layer compression and for driving many simulated FL
//! clients concurrently.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool with a shared job queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (`n >= 1`).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("fedgec-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx.as_ref().unwrap().send(Box::new(job)).expect("pool alive");
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Heuristic worker count for layer-parallel gradient encoding:
/// sequential for small models (thread fan-out costs more than it saves),
/// otherwise up to 8 workers bounded by layer count and hardware.
pub fn layer_parallelism(n_layers: usize, total_numel: usize) -> usize {
    const MIN_NUMEL: usize = 1 << 16;
    if n_layers < 2 || total_numel < MIN_NUMEL {
        return 1;
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(n_layers)
        .min(8)
}

/// Map `f` over `items` in parallel preserving order, using `n_threads`
/// scoped threads (no pool needed; good for per-layer compression).
pub fn parallel_map<T, U, F>(items: Vec<T>, n_threads: usize, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Sync,
{
    let n_threads = n_threads.max(1);
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    if n_threads == 1 || n == 1 {
        return items.into_iter().map(|x| f(x)).collect();
    }
    let slots: Vec<Mutex<Option<U>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let work: Mutex<std::vec::IntoIter<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>().into_iter());
    thread::scope(|s| {
        for _ in 0..n_threads.min(n) {
            s.spawn(|| loop {
                let next = { work.lock().unwrap().next() };
                match next {
                    Some((i, item)) => {
                        let out = f(item);
                        *slots[i].lock().unwrap() = Some(out);
                    }
                    None => break,
                }
            });
        }
    });
    slots.into_iter().map(|m| m.into_inner().unwrap().expect("slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..200).collect();
        let out = parallel_map(items, 8, |x| x * 2);
        assert_eq!(out, (0..200).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_thread() {
        let out = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }
}
