//! MSB-first bit-level I/O used by the Huffman coder, the two-level sign
//! bitmaps (paper Fig. 8) and the Elias integer codes of the QSGD baseline.

/// Append-only MSB-first bit writer with a 64-bit accumulator (bits are
/// kept left-aligned in `acc`; whole bytes are flushed eagerly). The
/// accumulator makes `put_bits` ~8× faster than per-bit writes — this is
/// on the compressor's hot path (§Perf).
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Left-aligned pending bits.
    acc: u64,
    /// Number of pending bits in `acc` (< 8 after each call).
    nbits: u8,
    total_bits: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of bits written so far.
    #[inline]
    pub fn bit_len(&self) -> usize {
        self.total_bits
    }

    /// Write a single bit.
    #[inline]
    pub fn put_bit(&mut self, bit: bool) {
        self.put_bits(bit as u64, 1);
    }

    #[inline]
    fn flush_bytes(&mut self) {
        while self.nbits >= 8 {
            self.buf.push((self.acc >> 56) as u8);
            self.acc <<= 8;
            self.nbits -= 8;
        }
    }

    /// Write the low `n` bits of `value`, MSB first. `n <= 64`.
    #[inline]
    pub fn put_bits(&mut self, value: u64, n: u8) {
        debug_assert!(n <= 64);
        if n == 0 {
            return;
        }
        self.total_bits += n as usize;
        let masked = if n == 64 { value } else { value & ((1u64 << n) - 1) };
        let free = 64 - self.nbits;
        if n <= free {
            self.acc |= if n == 64 { masked } else { masked << (free - n) };
            self.nbits += n;
            self.flush_bytes();
        } else {
            // Split: high `free` bits now, rest after the flush.
            let hi = masked >> (n - free);
            self.acc |= hi;
            self.nbits = 64;
            self.flush_bytes();
            let rest = n - free;
            let lo = masked & ((1u64 << rest) - 1);
            self.acc |= lo << (64 - self.nbits - rest);
            self.nbits += rest;
            self.flush_bytes();
        }
    }

    /// Finish and return the byte buffer (final byte zero-padded).
    pub fn into_bytes(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.buf.push((self.acc >> 56) as u8);
        }
        self.buf
    }
}

/// MSB-first bit reader over a byte slice.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize, // bit position
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    /// Bits remaining.
    #[inline]
    pub fn remaining(&self) -> usize {
        self.buf.len() * 8 - self.pos
    }

    /// Read one bit; `None` at end of stream.
    #[inline]
    pub fn get_bit(&mut self) -> Option<bool> {
        if self.pos >= self.buf.len() * 8 {
            return None;
        }
        let byte = self.buf[self.pos / 8];
        let bit = (byte >> (7 - (self.pos % 8))) & 1 == 1;
        self.pos += 1;
        Some(bit)
    }

    /// Read `n` bits MSB-first into the low bits of a u64.
    pub fn get_bits(&mut self, n: u8) -> Option<u64> {
        debug_assert!(n <= 64);
        let mut v = 0u64;
        for _ in 0..n {
            v = (v << 1) | self.get_bit()? as u64;
        }
        Some(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bits() {
        let mut w = BitWriter::new();
        let pattern = [true, false, true, true, false, false, true, false, true];
        for &b in &pattern {
            w.put_bit(b);
        }
        assert_eq!(w.bit_len(), pattern.len());
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &b in &pattern {
            assert_eq!(r.get_bit(), Some(b));
        }
    }

    #[test]
    fn roundtrip_multi_bit_values() {
        let mut w = BitWriter::new();
        let vals: [(u64, u8); 5] = [(0b101, 3), (0xFF, 8), (0, 1), (0x1234, 16), (u64::MAX, 64)];
        for &(v, n) in &vals {
            w.put_bits(v, n);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &vals {
            let mask = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
            assert_eq!(r.get_bits(n), Some(v & mask));
        }
    }

    #[test]
    fn reader_end_of_stream() {
        let mut w = BitWriter::new();
        w.put_bits(0b11, 2);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        // one padded byte -> 8 bits available, then None
        for _ in 0..8 {
            assert!(r.get_bit().is_some());
        }
        assert_eq!(r.get_bit(), None);
    }

    #[test]
    fn empty_writer() {
        let w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        assert!(w.into_bytes().is_empty());
    }
}
