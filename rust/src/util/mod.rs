//! Substrates built from scratch for the offline environment: PRNG, JSON,
//! statistics, FFT, bit I/O, a thread pool, and a mini property-testing
//! harness. These stand in for `rand`, `serde`, `criterion`, `proptest`
//! and `tokio`, none of which are available offline (see DESIGN.md §3).

pub mod rng;
pub mod json;
pub mod stats;
pub mod fft;
pub mod bitio;
pub mod threadpool;
pub mod prop;
pub mod timer;
