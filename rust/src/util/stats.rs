//! Statistical helpers used across the compressor and the experiment
//! harnesses: moments, Pearson correlation, Shannon entropy, histograms.
//!
//! All reductions are **sequential in index order** — this is load-bearing:
//! the client and server must compute bit-identical `mean`/`std` scalars so
//! their predictor states stay synchronized (DESIGN.md §1).

/// Sequential mean of an `f32` slice (f64 accumulator, deterministic order).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|&x| x as f64).sum();
    (s / xs.len() as f64) as f32
}

/// Population standard deviation (deterministic order).
pub fn std(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs) as f64;
    let s: f64 = xs.iter().map(|&x| (x as f64 - m) * (x as f64 - m)).sum();
    ((s / xs.len() as f64).sqrt()) as f32
}

/// Mean and population std in a single deterministic pass (f64 sum and
/// sum-of-squares; Var = E[x²] − E[x]², clamped at 0). Both FL sides use
/// exactly this function so predictor states stay synchronized.
pub fn mean_std(xs: &[f32]) -> (f32, f32) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let (mut s, mut s2) = (0.0f64, 0.0f64);
    for &x in xs {
        let xd = x as f64;
        s += xd;
        s2 += xd * xd;
    }
    let n = xs.len() as f64;
    let m = s / n;
    ((m as f32), ((s2 / n - m * m).max(0.0).sqrt() as f32))
}

/// Mean and population std of `|x|` in one pass without materializing the
/// absolute tensor (hot path of Alg. 3 line 8).
pub fn mean_std_abs(xs: &[f32]) -> (f32, f32) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let (mut s, mut s2) = (0.0f64, 0.0f64);
    for &x in xs {
        let a = x.abs() as f64;
        s += a;
        s2 += a * a;
    }
    let n = xs.len() as f64;
    let m = s / n;
    ((m as f32), ((s2 / n - m * m).max(0.0).sqrt() as f32))
}

/// Pearson correlation coefficient between two equal-length slices.
/// Returns 0.0 for degenerate inputs (empty, zero variance).
pub fn pearson(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n == 0 {
        return 0.0;
    }
    let ma = mean(a) as f64;
    let mb = mean(b) as f64;
    let (mut sab, mut saa, mut sbb) = (0.0f64, 0.0f64, 0.0f64);
    for i in 0..n {
        let da = a[i] as f64 - ma;
        let db = b[i] as f64 - mb;
        sab += da * db;
        saa += da * da;
        sbb += db * db;
    }
    if saa <= 0.0 || sbb <= 0.0 {
        return 0.0;
    }
    sab / (saa.sqrt() * sbb.sqrt())
}

/// Cosine similarity ⟨a,b⟩ / (‖a‖‖b‖) — the paper's "gradient correlation"
/// (Eq. 4). Returns 0.0 for zero vectors.
pub fn gradient_correlation(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for i in 0..a.len() {
        let x = a[i] as f64;
        let y = b[i] as f64;
        dot += x * y;
        na += x * x;
        nb += y * y;
    }
    if na <= 0.0 || nb <= 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Mean squared error between prediction and truth.
pub fn mse(pred: &[f32], truth: &[f32]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let s: f64 = pred
        .iter()
        .zip(truth)
        .map(|(&p, &t)| {
            let d = p as f64 - t as f64;
            d * d
        })
        .sum();
    s / pred.len() as f64
}

/// Shannon entropy (bits/symbol) of a symbol stream given as i64 symbols.
pub fn shannon_entropy(symbols: impl IntoIterator<Item = i64>) -> f64 {
    use std::collections::HashMap;
    let mut counts: HashMap<i64, u64> = HashMap::new();
    let mut n = 0u64;
    for s in symbols {
        *counts.entry(s).or_insert(0) += 1;
        n += 1;
    }
    if n == 0 {
        return 0.0;
    }
    let nf = n as f64;
    counts
        .values()
        .map(|&c| {
            let p = c as f64 / nf;
            -p * p.log2()
        })
        .sum()
}

/// Shannon entropy of quantized f32 data (quantize into `bins` over
/// [min,max] first). Used by the motivation benches (Fig. 3).
pub fn value_entropy(xs: &[f32], bins: usize) -> f64 {
    if xs.is_empty() || bins == 0 {
        return 0.0;
    }
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &x in xs {
        if x < lo {
            lo = x;
        }
        if x > hi {
            hi = x;
        }
    }
    if !lo.is_finite() || !hi.is_finite() || hi <= lo {
        return 0.0;
    }
    let w = (hi - lo) / bins as f32;
    shannon_entropy(xs.iter().map(|&x| (((x - lo) / w) as i64).min(bins as i64 - 1)))
}

/// Fixed-width histogram: returns (bin_centers, counts).
pub fn histogram(xs: &[f32], bins: usize, lo: f32, hi: f32) -> (Vec<f32>, Vec<u64>) {
    let mut counts = vec![0u64; bins];
    let w = (hi - lo) / bins as f32;
    for &x in xs {
        if x.is_finite() && x >= lo && x < hi {
            let b = ((x - lo) / w) as usize;
            counts[b.min(bins - 1)] += 1;
        }
    }
    let centers = (0..bins).map(|i| lo + w * (i as f32 + 0.5)).collect();
    (centers, counts)
}

/// Min and max ignoring non-finite values; returns (0,0) if none finite.
pub fn finite_min_max(xs: &[f32]) -> (f32, f32) {
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &x in xs {
        if x.is_finite() {
            if x < lo {
                lo = x;
            }
            if x > hi {
                hi = x;
            }
        }
    }
    if lo > hi {
        (0.0, 0.0)
    } else {
        (lo, hi)
    }
}

/// Simple single-pole low-pass filter (for Fig. 4's magnitude trend).
pub fn low_pass(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut y = match xs.first() {
        Some(&x) => x,
        None => return out,
    };
    for &x in xs {
        y = alpha * x + (1.0 - alpha) * y;
        out.push(y);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-6);
        assert!((std(&xs) - 1.118034).abs() < 1e-5);
    }

    #[test]
    fn mean_std_empty() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[]), 0.0);
    }

    #[test]
    fn pearson_perfect() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [2.0f32, 4.0, 6.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        let c = [-2.0f32, -4.0, -6.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_degenerate() {
        assert_eq!(pearson(&[1.0, 1.0], &[2.0, 3.0]), 0.0);
        assert_eq!(pearson(&[], &[]), 0.0);
    }

    #[test]
    fn gradient_correlation_matches_cosine() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 1.0];
        assert!(gradient_correlation(&a, &b).abs() < 1e-12);
        let c = [-1.0f32, 0.0];
        assert!((gradient_correlation(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn entropy_uniform_vs_constant() {
        let e_const = shannon_entropy(std::iter::repeat(3i64).take(100));
        assert!(e_const.abs() < 1e-12);
        let e_uni = shannon_entropy((0..256).map(|i| i as i64));
        assert!((e_uni - 8.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_counts() {
        let xs = [0.1f32, 0.2, 0.9];
        let (_, counts) = histogram(&xs, 2, 0.0, 1.0);
        assert_eq!(counts, vec![2, 1]);
    }

    #[test]
    fn mse_zero_for_identical() {
        let xs = [1.0f32, -2.0, 3.0];
        assert_eq!(mse(&xs, &xs), 0.0);
    }

    #[test]
    fn finite_min_max_skips_nan() {
        let xs = [f32::NAN, 1.0, -2.0, f32::INFINITY];
        assert_eq!(finite_min_max(&xs), (-2.0, 1.0));
    }

    #[test]
    fn low_pass_smooths() {
        let xs: Vec<f64> = (0..100).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let y = low_pass(&xs, 0.1);
        let late = y[90].abs();
        assert!(late < 0.5, "late={late}");
    }
}
