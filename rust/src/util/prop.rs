//! Mini property-testing harness (offline substitute for `proptest`,
//! see DESIGN.md §3). Runs a property over many randomized cases from a
//! seeded [`Rng`]; on failure it reports the case index and seed so the
//! exact counterexample is reproducible.

use crate::util::rng::Rng;

/// Run `cases` randomized checks of `prop`. Each case gets a forked RNG.
/// Panics with the failing case/seed on the first violation.
///
/// Under Miri (the CI unsafe-kernel audit) every suite shrinks to a
/// handful of cases: the interpreter is ~100x slower than native, and
/// the goal there is UB coverage of each code path, not distributional
/// coverage.
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    let cases = if cfg!(miri) { cases.min(4) } else { cases };
    let base_seed = std::env::var("FEDGEC_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xFED6EC);
    let mut root = Rng::new(base_seed);
    for case in 0..cases {
        let mut rng = root.fork(case as u64);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed {base_seed}): {msg}");
        }
    }
}

/// Generate a random gradient-like tensor: mixture of Gaussian bulk and
/// occasional heavy-tailed outliers, with a random scale — the shapes the
/// compressor must always survive.
pub fn arb_gradient(rng: &mut Rng, n: usize) -> Vec<f32> {
    let scale = 10f64.powf(rng.uniform(-6.0, 1.0)) as f32;
    (0..n)
        .map(|_| {
            if rng.chance(0.02) {
                (rng.laplace() * 20.0) as f32 * scale
            } else {
                rng.normal_f32(0.0, scale)
            }
        })
        .collect()
}

/// Random tensor length, biased toward interesting small sizes and block
/// boundaries.
pub fn arb_len(rng: &mut Rng, max: usize) -> usize {
    // Same rationale as in [`check`]: Miri runs want every size class
    // (sub-chunk, chunk boundary, tail) without megabyte tensors.
    let max = if cfg!(miri) { max.min(512) } else { max };
    match rng.next_below(6) {
        0 => 1 + rng.next_below(4),
        1 => 63 + rng.next_below(4),
        2 => 255 + rng.next_below(4),
        _ => 1 + rng.next_below(max.max(2) - 1),
    }
}

/// Random relative error bound in the paper's range [1e-4, 1e-1].
pub fn arb_error_bound(rng: &mut Rng) -> f64 {
    10f64.powf(rng.uniform(-4.0, -1.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 50, |rng| {
            let x = rng.next_f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("x={x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_failure() {
        check("fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn arb_gradient_is_finite_sized() {
        let mut rng = Rng::new(1);
        let g = arb_gradient(&mut rng, 1000);
        assert_eq!(g.len(), 1000);
        assert!(g.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn arb_len_in_bounds() {
        let mut rng = Rng::new(2);
        for _ in 0..1000 {
            let n = arb_len(&mut rng, 500);
            assert!(n >= 1);
        }
    }
}
