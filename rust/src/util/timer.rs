//! Tiny timing helpers for the hand-rolled bench harness (criterion is not
//! available offline).

use std::time::{Duration, Instant};

/// Time a closure, returning (result, elapsed).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Run `f` repeatedly for at least `min_iters` iterations and `min_time`,
/// returning per-iteration stats in seconds: (mean, min, max, iters).
pub fn bench_loop(min_iters: usize, min_time: Duration, mut f: impl FnMut()) -> BenchStats {
    // Warmup.
    f();
    let mut samples = Vec::new();
    let start = Instant::now();
    let mut iters = 0usize;
    while iters < min_iters || start.elapsed() < min_time {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        iters += 1;
        if iters > 10_000 {
            break;
        }
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0f64, f64::max);
    BenchStats { mean_s: mean, min_s: min, max_s: max, iters }
}

/// Result of [`bench_loop`].
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub iters: usize,
}

impl BenchStats {
    /// Throughput in MB/s for a payload of `bytes` processed per iteration.
    pub fn mb_per_s(&self, bytes: usize) -> f64 {
        bytes as f64 / 1e6 / self.mean_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_returns_result() {
        let (v, d) = timed(|| 40 + 2);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0 || d.as_nanos() == 0); // just runs
    }

    #[test]
    fn bench_loop_runs_min_iters() {
        let mut n = 0;
        let stats = bench_loop(5, Duration::from_millis(0), || n += 1);
        assert!(stats.iters >= 5);
        assert!(stats.min_s <= stats.mean_s && stats.mean_s <= stats.max_s + 1e-12);
    }
}
