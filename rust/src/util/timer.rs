//! Tiny timing helpers for the hand-rolled bench harness (criterion is not
//! available offline).

use std::time::{Duration, Instant};

/// Iteration cap: `bench_loop` stops sampling here even if `min_time`
/// has not elapsed (sub-microsecond bodies would otherwise spin for
/// millions of iterations). Hitting the cap early is recorded in
/// [`BenchStats::truncated`] so tables can flag the row instead of
/// silently reporting an under-sampled mean.
pub const MAX_ITERS: usize = 10_000;

/// Time a closure, returning (result, elapsed).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Run `f` repeatedly for at least `min_iters` iterations and `min_time`
/// (capped at [`MAX_ITERS`]), returning per-iteration stats in seconds.
pub fn bench_loop(min_iters: usize, min_time: Duration, mut f: impl FnMut()) -> BenchStats {
    // Warmup.
    f();
    let mut samples = Vec::new();
    let start = Instant::now();
    let mut iters = 0usize;
    let mut truncated = false;
    while iters < min_iters || start.elapsed() < min_time {
        if iters >= MAX_ITERS {
            // The old guard broke *after* pushing sample 10_001 and
            // before the while condition was rechecked, so the cap cut
            // the run short without any trace in the stats.
            truncated = start.elapsed() < min_time;
            break;
        }
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        iters += 1;
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0f64, f64::max);
    BenchStats { mean_s: mean, min_s: min, max_s: max, iters, truncated }
}

/// Result of [`bench_loop`].
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub iters: usize,
    /// True when the [`MAX_ITERS`] cap fired before `min_time` elapsed —
    /// the mean is from fewer samples than the caller asked for.
    pub truncated: bool,
}

impl BenchStats {
    /// Throughput in MB/s for a payload of `bytes` processed per iteration.
    pub fn mb_per_s(&self, bytes: usize) -> f64 {
        bytes as f64 / 1e6 / self.mean_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_returns_result() {
        let (v, d) = timed(|| 40 + 2);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0 || d.as_nanos() == 0); // just runs
    }

    #[test]
    fn bench_loop_runs_min_iters() {
        let mut n = 0;
        let stats = bench_loop(5, Duration::from_millis(0), || n += 1);
        assert!(stats.iters >= 5);
        assert!(stats.min_s <= stats.mean_s && stats.mean_s <= stats.max_s + 1e-12);
        assert!(!stats.truncated, "min_time=0 can always be met");
    }

    #[test]
    fn bench_loop_flags_truncation() {
        // An empty body hits the MAX_ITERS cap long before an hour
        // elapses; the stats must say so.
        let stats = bench_loop(1, Duration::from_secs(3600), || {});
        assert_eq!(stats.iters, MAX_ITERS);
        assert!(stats.truncated);
    }

    #[test]
    fn bench_loop_cap_reached_in_time_is_not_truncated() {
        // min_time already satisfied when the cap fires -> a full run.
        let stats = bench_loop(MAX_ITERS + 5, Duration::ZERO, || {});
        assert_eq!(stats.iters, MAX_ITERS);
        assert!(!stats.truncated);
    }
}
