//! Minimal JSON parser/serializer — the config-system substrate (`serde`
//! is unavailable offline). Supports the full JSON grammar; numbers are
//! kept as f64.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `obj.get(key)` as f64 with a default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Json::as_usize).unwrap_or(default)
    }
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Json::as_bool).unwrap_or(default)
    }

    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }
    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }
    fn skip_ws(&mut self) {
        while let Some(c) = self.peek() {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8 starting at pos-1.
                    let start = self.pos - 1;
                    let width = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + width).min(self.src.len());
                    let s = std::str::from_utf8(&self.src[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{it}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"alpha":0.9,"list":[1,2,3],"name":"fedgec","on":true}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn errors_on_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn defaults_helpers() {
        let v = Json::parse(r#"{"x": 3}"#).unwrap();
        assert_eq!(v.usize_or("x", 0), 3);
        assert_eq!(v.usize_or("y", 7), 7);
        assert_eq!(v.str_or("z", "d"), "d");
    }
}
