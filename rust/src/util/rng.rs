//! Deterministic pseudo-random number generation (no `rand` crate offline).
//!
//! [`Rng`] is xoshiro256** seeded through SplitMix64 — the standard
//! recommendation from Blackman & Vigna. All simulation randomness in the
//! crate flows through this type so every experiment is reproducible from
//! a single `u64` seed.

/// xoshiro256** PRNG with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box-Muller.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (for per-client / per-layer RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire's method).
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box-Muller (polar-free, cached spare).
    pub fn gauss(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/std as `f32`.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.gauss() as f32
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample a standard-Laplace variate (heavy-tailed gradient noise).
    pub fn laplace(&mut self) -> f64 {
        let u = self.next_f64() - 0.5;
        -u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.gauss();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn next_below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.next_below(17) < 17);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn laplace_symmetric() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.laplace()).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
    }
}
