//! The coordinator ties everything together: config → dataset + trainer +
//! codecs + link model → synchronous FedAvg rounds → metrics.
//!
//! Two execution modes:
//!
//! * [`run_local`] — single-threaded simulation with virtual-time link
//!   accounting (the paper's Fig. 11 methodology). Supports the HLO
//!   trainer (PJRT micro-CNNs, real gradients) and the native trainer.
//! * [`run_threaded`] — real client threads over in-process channels with
//!   live bandwidth throttling (native trainer; also exercised over TCP by
//!   the `serve`/`client` CLI subcommands and the transport tests).
//!
//! Scale model: each client owns its stateful compressor, but the server
//! holds **one** stateless decode engine plus a bounded `StateStore` —
//! with `cfg.participation < 1` only a sampled subset trains per round
//! (`run_local`; threaded mode rejects partial participation), and the
//! per-round `RoundStats` record the store's state-memory trajectory.
//! With `cfg.down` set, the broadcast compresses too: the global-model
//! delta is encoded once per round and every participant trains on the
//! server's tracked lossy reference (`run_local`) or decodes the
//! fanned-out frames itself (`run_threaded`).

pub mod native_trainer;

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use crate::compress::control::{EbController, EbSignals};
use crate::compress::downlink::{DownlinkCodec, DownlinkMirror};
use crate::compress::engine::CodecEngine;
use crate::compress::pipeline::{FedgecCodec, FedgecConfig, FedgecEngine};
use crate::compress::spec::CodecSpec;
use crate::compress::state::StateEpoch;
use crate::compress::store::{ClientId, ShardedMemStore};
use crate::compress::GradientCodec;
use crate::config::{EngineKind, RunConfig};
use crate::fl::client::{Client, LocalTrainer};
use crate::fl::hetero::sample_participants;
use crate::fl::round::{RoundStats, RunSummary, ShardStats};
use crate::fl::server::Server;
use crate::fl::topology::edge::EdgeAggregator;
use crate::fl::topology::sharded::ShardedRunner;
use crate::fl::topology::TierSpec;
use crate::fl::transport::bandwidth::{LinkSpec, VirtualLink};
use crate::fl::transport::{inproc, Channel};
use crate::runtime::engine::HloPredictEngine;
use crate::runtime::manifest::Manifest;
use crate::runtime::trainer::{HloTrainer, Params};
use crate::telemetry::{self, journal};
use crate::tensor::{LayerGrad, LayerMeta, ModelGrad};
use crate::train::data::SynthDataset;
use native_trainer::NativeTrainer;

/// Build the codec described by the config's spec string (the client
/// side — one stateful compressor per client).
pub fn build_codec(cfg: &RunConfig) -> crate::Result<Box<dyn GradientCodec>> {
    Ok(cfg.codec_spec()?.build())
}

/// Build the server-side stateless decode engine for the config's spec.
pub fn build_engine(cfg: &RunConfig) -> crate::Result<Box<dyn CodecEngine>> {
    Ok(cfg.codec_spec()?.build_engine())
}

/// Build the server-side downlink broadcaster (`None` = raw broadcast).
pub fn build_downlink(
    cfg: &RunConfig,
    metas: &[LayerMeta],
) -> crate::Result<Option<DownlinkCodec>> {
    Ok(cfg.down_spec()?.map(|spec| DownlinkCodec::new(&spec, metas.to_vec())))
}

/// Simulation-side downlink broadcast for one round: plan + encode once,
/// account per-participant bytes and virtual downlink time, and return
/// the params view every participant trains on. With a downlink codec
/// attached the view is the server's tracked lossy reference — exactly
/// the bytes the wire protocol would deliver (delta recipients decode to
/// it, full-sync recipients receive it verbatim).
fn sim_downlink_round(
    down: &mut Option<DownlinkCodec>,
    server_params: &[Vec<f32>],
    participants: &[usize],
    link: &LinkSpec,
    stats: &mut RoundStats,
) -> crate::Result<Vec<Vec<f32>>> {
    // `stats` is fresh per round in both callers, so the deltas below
    // are this round's whole downlink contribution.
    let bytes0 = stats.downlink_bytes;
    let raw0 = stats.downlink_raw_bytes;
    let syncs0 = stats.full_syncs;
    let params = match down {
        None => {
            let raw: usize = server_params.iter().map(|t| t.len() * 4).sum();
            stats.downlink_bytes += raw * participants.len();
            stats.downlink_raw_bytes += raw * participants.len();
            stats.down_transmit_time += link.downlink_time(raw) * participants.len() as u32;
            server_params.to_vec()
        }
        Some(down) => {
            let ids: Vec<ClientId> = participants.iter().map(|&i| i as u32).collect();
            let bc = down.encode_round(server_params, &ids)?;
            stats.down_codec_time += bc.stats.encode_time;
            stats.downlink_raw_bytes += bc.stats.raw_bytes * participants.len();
            let cold: std::collections::HashSet<ClientId> = bc.cold.into_iter().collect();
            for id in &ids {
                // Cold clients pull the full reference; warm ones pull
                // the shared delta frames (encoded once for everyone).
                let bytes = if cold.contains(id) {
                    stats.full_syncs += 1;
                    bc.stats.raw_bytes
                } else {
                    bc.stats.delta_bytes
                };
                stats.downlink_bytes += bytes;
                stats.down_transmit_time += link.downlink_time(bytes);
            }
            down.reference()
                .ok_or_else(|| anyhow::anyhow!("downlink reference missing after encode"))?
                .to_vec()
        }
    };
    telemetry::DOWNLINK_BYTES.add((stats.downlink_bytes - bytes0) as u64);
    telemetry::DOWNLINK_RAW_BYTES.add((stats.downlink_raw_bytes - raw0) as u64);
    telemetry::DOWNLINK_FULL_SYNCS.add((stats.full_syncs - syncs0) as u64);
    Ok(params)
}

/// Resolve a spec into the FedGEC config (HLO paths require fedgec).
fn fedgec_config(cfg: &RunConfig) -> crate::Result<FedgecConfig> {
    match cfg.codec_spec()? {
        CodecSpec::Fedgec { eb, beta, tau, full_batch, autotune, ec, backend, pred, sign } => {
            // The PJRT/HLO backend executes the Pallas lowering of the
            // EMA predict kernel — the other magnitude predictors run
            // native only.
            anyhow::ensure!(
                pred == crate::compress::predictor::magnitude::MagnitudeSel::Ema,
                "HLO engine implements the EMA magnitude predictor; pred={} needs engine=native",
                pred.name()
            );
            Ok(FedgecConfig {
                error_bound: eb,
                beta,
                tau,
                full_batch,
                autotune,
                entropy: ec,
                backend,
                predictor: crate::compress::predictor::PredictorSpec { mag: pred, sign },
                ..Default::default()
            })
        }
        other => anyhow::bail!("HLO engine requires the fedgec codec, got {other}"),
    }
}

/// Build a FedGEC codec with the HLO predict engine attached (client).
fn build_codec_hlo(
    cfg: &RunConfig,
    rt: Rc<RefCell<crate::runtime::Runtime>>,
) -> crate::Result<Box<dyn GradientCodec>> {
    let fc = fedgec_config(cfg)?;
    let engine = HloPredictEngine::new(rt, 4096)?;
    Ok(Box::new(FedgecCodec::with_engine(fc, Box::new(engine))))
}

/// Build the FedGEC decode engine with the HLO predict backend (server —
/// note: one engine for the whole federation, where the old design
/// instantiated one PJRT-backed codec per client).
fn build_engine_hlo(
    cfg: &RunConfig,
    rt: Rc<RefCell<crate::runtime::Runtime>>,
) -> crate::Result<Box<dyn CodecEngine>> {
    let fc = fedgec_config(cfg)?;
    let engine = HloPredictEngine::new(rt, 4096)?;
    Ok(Box::new(FedgecEngine::with_engine(fc, Box::new(engine))))
}

/// One simulated client in `run_local` (HLO path).
struct HloClientSim {
    data_xs: Vec<f32>,
    data_ys: Vec<i32>,
    codec: Box<dyn GradientCodec>,
    epoch: StateEpoch,
    n_samples: usize,
}

/// Single-threaded FL simulation — the main experiment driver.
pub fn run_local(cfg: &RunConfig) -> crate::Result<RunSummary> {
    match cfg.model.as_str() {
        "native" => run_local_native(cfg),
        _ => run_local_hlo(cfg),
    }
}

/// Build the run's optional error-bound controller (`ebc=` key;
/// `None` for `ebc=fixed` — the legacy single-eb path pays nothing).
/// The controller's base bound is the config's `rel_error_bound`
/// magnitude; [`crate::compress::control::EbPlan::bound_for`] preserves
/// the codec's Abs/Rel mode when the plan is applied.
fn build_controller(cfg: &RunConfig) -> Option<Box<dyn EbController>> {
    let spec = cfg.ebc_spec();
    if spec.is_fixed() {
        None
    } else {
        Some(spec.build(cfg.rel_error_bound))
    }
}

/// The in-process equivalent of the wire `StateCheck`/`StateResync`
/// handshake: ask the server to compare epochs; on mismatch reset the
/// client codec to cold start. Returns whether a reset happened.
fn sim_state_handshake(
    server: &mut Server,
    client_id: u32,
    codec: &mut dyn GradientCodec,
    epoch: &mut StateEpoch,
) -> crate::Result<bool> {
    let reset = server.check_state(client_id, *epoch)?;
    if reset {
        codec.reset();
        *epoch = StateEpoch::cold();
    }
    Ok(reset)
}

fn run_local_hlo(cfg: &RunConfig) -> crate::Result<RunSummary> {
    let art_dir = crate::runtime::Runtime::default_dir();
    let manifest = Manifest::load(&art_dir)?;
    let rt = Rc::new(RefCell::new(crate::runtime::Runtime::new(&art_dir)?));
    let trainer = HloTrainer::new(rt.clone(), &manifest, &cfg.model_key())?;
    let metas = trainer.layer_metas();

    // Data: one slice per client (shaped for the AOT epoch), one eval set.
    let ds = SynthDataset::new(cfg.dataset, cfg.seed);
    let per_epoch = manifest.batches_per_epoch * manifest.batch_size;
    let mut data_rng = crate::util::rng::Rng::new(cfg.seed ^ 0xDA);
    let mut clients: Vec<HloClientSim> = (0..cfg.n_clients)
        .map(|i| {
            let mut rng = data_rng.fork(i as u64);
            let slice = ds.sample(&mut rng, per_epoch, cfg.class_skew);
            let codec = if cfg.engine == EngineKind::Hlo {
                build_codec_hlo(cfg, rt.clone())
            } else {
                build_codec(cfg)
            };
            codec.map(|codec| HloClientSim {
                data_xs: slice.xs,
                data_ys: slice.ys,
                codec,
                epoch: StateEpoch::cold(),
                n_samples: per_epoch,
            })
        })
        .collect::<crate::Result<_>>()?;
    let eval_slice = {
        let mut rng = data_rng.fork(0xE7A1);
        ds.sample(&mut rng, manifest.eval_n, 0.0)
    };

    // Server: global params + ONE decode engine + a keyed state store.
    let init = trainer.init_params(cfg.seed);
    let server_engine = if cfg.engine == EngineKind::Hlo {
        build_engine_hlo(cfg, rt.clone())?
    } else {
        build_engine(cfg)?
    };
    let mut server = Server::new(
        init.tensors,
        metas.clone(),
        cfg.server_lr,
        server_engine,
        cfg.build_state_store()?,
    )
    .with_agg_mode(cfg.agg_mode());
    for ci in 0..cfg.n_clients {
        server.admit(ci as u32);
    }

    let mut downlink = build_downlink(cfg, &metas)?;
    let mut controller = build_controller(cfg);
    let mut part_rng = crate::util::rng::Rng::new(cfg.seed ^ 0x9A57);
    let mut summary = RunSummary::default();
    for round in 0..cfg.rounds {
        let participants = sample_participants(cfg.n_clients, cfg.participation, &mut part_rng);
        let mut stats = RoundStats {
            round: round as u32,
            participants: participants.len(),
            ..Default::default()
        };
        let span = journal::RoundSpan::begin(round as u32, 0);
        // Error-bound plan first: the server engine and every
        // participant adopt the identical plan before any compression,
        // so mirror eb tags (and hence fingerprints) agree bit for bit.
        let plan = controller.as_mut().and_then(|c| c.plan(round as u32));
        if let Some(p) = &plan {
            server.apply_eb_plan(p);
            for &ci in &participants {
                clients[ci].codec.apply_eb_plan(p);
            }
            span.eb_plan(p);
            telemetry::ROUND_EB.set((p.round_eb as f64 * 1e9) as u64);
            stats.round_eb = Some(p.round_eb);
        }
        let mut layer_bytes: Vec<usize> = Vec::new();
        let mut agg = server.new_round_agg();
        let global = sim_downlink_round(
            &mut downlink,
            &server.params,
            &participants,
            &cfg.link,
            &mut stats,
        )?;
        span.downlink(
            stats.downlink_bytes,
            stats.downlink_raw_bytes,
            stats.full_syncs,
            stats.down_codec_time,
            stats.down_transmit_time,
        );
        // Per-client tallies go through the same ShardStats the served
        // topologies use, so the journal fold replays identical
        // arithmetic (client-side comp/transmit stay round-level).
        let mut shard = ShardStats::default();
        for &ci in &participants {
            let client = &mut clients[ci];
            if sim_state_handshake(
                &mut server,
                ci as u32,
                client.codec.as_mut(),
                &mut client.epoch,
            )? {
                shard.resyncs += 1;
                span.client_event(0, ci, "resync");
            }
            // Local epoch via PJRT.
            let params = Params { tensors: global.clone() };
            let (new_params, loss) =
                trainer.train_epoch(&params, &client.data_xs, &client.data_ys, cfg.local_lr)?;
            shard.loss_sum += loss as f64;
            // Gradient = (θ_global − θ_local)/lr, per layer.
            let grads = ModelGrad {
                layers: metas
                    .iter()
                    .zip(global.iter().zip(&new_params.tensors))
                    .map(|(meta, (old, new))| {
                        let inv_lr = 1.0 / cfg.local_lr;
                        let data: Vec<f32> =
                            old.iter().zip(new).map(|(o, n)| (o - n) * inv_lr).collect();
                        LayerGrad::new(meta.clone(), data)
                    })
                    .collect(),
            };
            let raw_bytes = grads.byte_size();
            shard.raw_bytes += raw_bytes;
            let t0 = Instant::now();
            let (payload, rep) = client.codec.compress_with_report(&grads)?;
            stats.comp_time += t0.elapsed();
            if controller.is_some() {
                accumulate_layer_bytes(&mut layer_bytes, &rep);
            }
            shard.payload_bytes += payload.len();
            let mut link = VirtualLink::new(cfg.link);
            stats.transmit_time += link.send(payload.len());
            let times =
                server.absorb_payload(ci as u32, &payload, client.n_samples as f64, &mut agg)?;
            shard.served += 1;
            shard.decode_time += times.decode;
            shard.agg_time += times.agg;
            span.client_served(
                0,
                ci as u64,
                payload.len(),
                raw_bytes,
                times.decode,
                times.agg,
                loss as f64,
            );
            client.epoch.advance(client.codec.state_fingerprint());
        }
        span.shard(0, &shard);
        telemetry::record_shard(&shard);
        span.sim(stats.comp_time, stats.transmit_time);
        let served = shard.served;
        shard.fold_into(&mut stats);
        stats.mean_loss /= served.max(1) as f64;
        server.record_store_occupancy(&mut stats);
        span.store(stats.store_clients, stats.store_bytes);
        let rep = server.finish_round(agg);
        stats.agg_time += rep.finish_time;
        stats.binsum_layers = rep.binsum_layers;
        stats.exact_layers = rep.exact_layers + rep.mixed_layers;
        stats.dequant_passes = rep.dequant_passes;
        span.finish(
            rep.finish_time,
            stats.binsum_layers,
            stats.exact_layers,
            stats.dequant_passes,
        );
        let do_eval = (cfg.eval_every > 0 && (round + 1) % cfg.eval_every == 0)
            || round + 1 == cfg.rounds;
        if do_eval {
            let params = Params { tensors: server.params.clone() };
            let (eloss, eacc) = trainer.eval(&params, &eval_slice.xs, &eval_slice.ys)?;
            stats.eval = Some((eloss, eacc));
            summary.final_accuracy = Some(eacc);
            span.eval(eloss, eacc);
        }
        if let Some(c) = controller.as_mut() {
            c.observe(&EbSignals {
                round: round as u32,
                train_loss: stats.mean_loss,
                eval: stats.eval,
                layer_bytes: std::mem::take(&mut layer_bytes),
            });
        }
        span.participants(stats.participants);
        span.end(&stats);
        summary.rounds.push(stats);
    }
    Ok(summary)
}

/// Fold one payload's per-layer on-wire bytes into the round's tallies
/// (the layerwise controller's byte-share signal).
fn accumulate_layer_bytes(acc: &mut Vec<usize>, rep: &crate::compress::frame::CodecReport) {
    if acc.len() < rep.layers.len() {
        acc.resize(rep.layers.len(), 0);
    }
    for (slot, l) in acc.iter_mut().zip(&rep.layers) {
        *slot += l.compressed_bytes;
    }
}

fn run_local_native(cfg: &RunConfig) -> crate::Result<RunSummary> {
    let ds = SynthDataset::new(cfg.dataset, cfg.seed);
    let mut data_rng = crate::util::rng::Rng::new(cfg.seed ^ 0xDA);
    let mut trainers: Vec<NativeTrainer> = (0..cfg.n_clients)
        .map(|i| {
            let mut rng = data_rng.fork(i as u64);
            let slice = ds.sample(&mut rng, cfg.samples_per_client, cfg.class_skew);
            NativeTrainer::new(cfg.dataset.classes(), slice, cfg.local_lr, cfg.seed)
        })
        .collect();
    let eval_slice = {
        let mut rng = data_rng.fork(0xE7A1);
        ds.sample(&mut rng, 256, 0.0)
    };
    let proto = crate::train::native::NativeNet::new(cfg.dataset.classes(), cfg.seed);
    let metas = proto.layer_metas();
    let init: Vec<Vec<f32>> =
        vec![proto.conv_w.clone(), proto.conv_b.clone(), proto.fc_w.clone(), proto.fc_b.clone()];
    let mut server = Server::new(
        init,
        metas.clone(),
        cfg.server_lr,
        build_engine(cfg)?,
        cfg.build_state_store()?,
    )
    .with_agg_mode(cfg.agg_mode());
    for ci in 0..cfg.n_clients {
        server.admit(ci as u32);
    }
    let mut client_codecs: Vec<Box<dyn GradientCodec>> =
        (0..cfg.n_clients).map(|_| build_codec(cfg)).collect::<crate::Result<_>>()?;
    let mut epochs = vec![StateEpoch::cold(); cfg.n_clients];

    let mut downlink = build_downlink(cfg, &metas)?;
    let mut controller = build_controller(cfg);
    let mut part_rng = crate::util::rng::Rng::new(cfg.seed ^ 0x9A57);
    let mut summary = RunSummary::default();
    for round in 0..cfg.rounds {
        let participants = sample_participants(cfg.n_clients, cfg.participation, &mut part_rng);
        let mut stats = RoundStats {
            round: round as u32,
            participants: participants.len(),
            ..Default::default()
        };
        let span = journal::RoundSpan::begin(round as u32, 0);
        // Same plan-before-compression discipline as the HLO path.
        let plan = controller.as_mut().and_then(|c| c.plan(round as u32));
        if let Some(p) = &plan {
            server.apply_eb_plan(p);
            for &ci in &participants {
                client_codecs[ci].apply_eb_plan(p);
            }
            span.eb_plan(p);
            telemetry::ROUND_EB.set((p.round_eb as f64 * 1e9) as u64);
            stats.round_eb = Some(p.round_eb);
        }
        let mut layer_bytes: Vec<usize> = Vec::new();
        let mut agg = server.new_round_agg();
        let global = sim_downlink_round(
            &mut downlink,
            &server.params,
            &participants,
            &cfg.link,
            &mut stats,
        )?;
        span.downlink(
            stats.downlink_bytes,
            stats.downlink_raw_bytes,
            stats.full_syncs,
            stats.down_codec_time,
            stats.down_transmit_time,
        );
        // Same ShardStats bookkeeping as the served topologies — the
        // journal fold replays this exact accumulation.
        let mut shard = ShardStats::default();
        for &ci in &participants {
            if sim_state_handshake(
                &mut server,
                ci as u32,
                client_codecs[ci].as_mut(),
                &mut epochs[ci],
            )? {
                shard.resyncs += 1;
                span.client_event(0, ci, "resync");
            }
            let (grads, loss) = trainers[ci].train_round(&global)?;
            shard.loss_sum += loss as f64;
            let raw_bytes = grads.byte_size();
            shard.raw_bytes += raw_bytes;
            let t0 = Instant::now();
            let (payload, rep) = client_codecs[ci].compress_with_report(&grads)?;
            stats.comp_time += t0.elapsed();
            if controller.is_some() {
                accumulate_layer_bytes(&mut layer_bytes, &rep);
            }
            shard.payload_bytes += payload.len();
            let mut link = VirtualLink::new(cfg.link);
            stats.transmit_time += link.send(payload.len());
            let times = server.absorb_payload(
                ci as u32,
                &payload,
                trainers[ci].n_samples() as f64,
                &mut agg,
            )?;
            shard.served += 1;
            shard.decode_time += times.decode;
            shard.agg_time += times.agg;
            span.client_served(
                0,
                ci as u64,
                payload.len(),
                raw_bytes,
                times.decode,
                times.agg,
                loss as f64,
            );
            epochs[ci].advance(client_codecs[ci].state_fingerprint());
        }
        span.shard(0, &shard);
        telemetry::record_shard(&shard);
        span.sim(stats.comp_time, stats.transmit_time);
        let served = shard.served;
        shard.fold_into(&mut stats);
        stats.mean_loss /= served.max(1) as f64;
        server.record_store_occupancy(&mut stats);
        span.store(stats.store_clients, stats.store_bytes);
        let rep = server.finish_round(agg);
        stats.agg_time += rep.finish_time;
        stats.binsum_layers = rep.binsum_layers;
        stats.exact_layers = rep.exact_layers + rep.mixed_layers;
        stats.dequant_passes = rep.dequant_passes;
        span.finish(
            rep.finish_time,
            stats.binsum_layers,
            stats.exact_layers,
            stats.dequant_passes,
        );
        let do_eval = (cfg.eval_every > 0 && (round + 1) % cfg.eval_every == 0)
            || round + 1 == cfg.rounds;
        if do_eval {
            let (eloss, eacc) = NativeTrainer::eval_params(
                cfg.dataset.classes(),
                &server.params,
                &eval_slice,
            );
            stats.eval = Some((eloss, eacc));
            summary.final_accuracy = Some(eacc);
            span.eval(eloss, eacc);
        }
        if let Some(c) = controller.as_mut() {
            c.observe(&EbSignals {
                round: round as u32,
                train_loss: stats.mean_loss,
                eval: stats.eval,
                layer_bytes: std::mem::take(&mut layer_bytes),
            });
        }
        span.participants(stats.participants);
        span.end(&stats);
        summary.rounds.push(stats);
    }
    Ok(summary)
}

/// Threaded mode: clients on real threads, in-process channels, live
/// throttling. Native trainer only (PJRT handles are not Send).
pub fn run_threaded(cfg: &RunConfig) -> crate::Result<RunSummary> {
    anyhow::ensure!(cfg.model == "native", "threaded mode requires model=native");
    // Threaded rounds drive every connected channel; sampling a subset
    // is a run_local feature. Fail loudly rather than silently running
    // full participation under a partial-participation config.
    anyhow::ensure!(
        cfg.participation >= 1.0,
        "threaded mode runs the full fleet; participation={} requires run_local",
        cfg.participation
    );
    let ds = SynthDataset::new(cfg.dataset, cfg.seed);
    let mut data_rng = crate::util::rng::Rng::new(cfg.seed ^ 0xDA);
    let proto = crate::train::native::NativeNet::new(cfg.dataset.classes(), cfg.seed);
    let metas = proto.layer_metas();
    let init: Vec<Vec<f32>> =
        vec![proto.conv_w.clone(), proto.conv_b.clone(), proto.fc_w.clone(), proto.fc_b.clone()];

    let down_spec = cfg.down_spec()?;
    let mut server_channels: Vec<Box<dyn Channel>> = Vec::new();
    let mut handles = Vec::new();
    for i in 0..cfg.n_clients {
        let (srv_end, cli_end) = inproc::pair(Some(cfg.link));
        server_channels.push(Box::new(srv_end));
        let mut rng = data_rng.fork(i as u64);
        let slice = ds.sample(&mut rng, cfg.samples_per_client, cfg.class_skew);
        let trainer = NativeTrainer::new(cfg.dataset.classes(), slice, cfg.local_lr, cfg.seed);
        let codec = build_codec(cfg)?;
        let mut client =
            Client::new(i as u32, Box::new(trainer), codec).with_streaming(cfg.stream_updates);
        if let Some(spec) = &down_spec {
            client = client.with_downlink(DownlinkMirror::new(spec, metas.clone()));
        }
        let mut ch = cli_end;
        handles.push(std::thread::spawn(move || client.run(&mut ch)));
    }
    let mut server = Server::new(
        init,
        metas.clone(),
        cfg.server_lr,
        build_engine(cfg)?,
        cfg.build_state_store()?,
    )
    .with_agg_mode(cfg.agg_mode());
    if let Some(spec) = &down_spec {
        server = server.with_downlink(DownlinkCodec::new(spec, metas.clone()));
    }
    if let Some(c) = build_controller(cfg) {
        server = server.with_controller(c);
    }
    let mut summary = RunSummary::default();
    match cfg.tier_spec() {
        TierSpec::Edge { fanout } => {
            anyhow::ensure!(
                down_spec.is_none(),
                "tier=edge requires down=raw (edges re-fan the raw broadcast bytes)"
            );
            // Group the client channels into subtrees of `fanout`, one
            // edge-aggregator thread per subtree. Subtree predictor
            // state lives at its edge in a per-edge in-memory store
            // (each edge gets the full configured budget).
            let edge_budget = if cfg.store_budget_mb > 0.0 {
                Some((cfg.store_budget_mb * 1e6) as usize)
            } else {
                None
            };
            let mut edge_channels: Vec<Box<dyn Channel>> = Vec::new();
            let mut edge_handles = Vec::new();
            let mut idx = 0u32;
            while !server_channels.is_empty() {
                let take = fanout.min(server_channels.len());
                let mut subtree: Vec<Box<dyn Channel>> =
                    server_channels.drain(..take).collect();
                let (root_end, edge_end) = inproc::pair(None);
                edge_channels.push(Box::new(root_end));
                let mut edge = EdgeAggregator::new(
                    idx,
                    build_engine(cfg)?,
                    Box::new(ShardedMemStore::new(8, edge_budget)),
                    metas.clone(),
                    cfg.agg_mode(),
                );
                edge_handles.push(std::thread::spawn(move || {
                    let mut up: Box<dyn Channel> = Box::new(edge_end);
                    edge.run(up.as_mut(), &mut subtree)
                }));
                idx += 1;
            }
            server.wait_hellos(&mut edge_channels)?;
            for _ in 0..cfg.rounds {
                let stats =
                    crate::fl::topology::edge::run_round_root(&mut server, &mut edge_channels)?;
                summary.rounds.push(stats);
            }
            server.shutdown(&mut edge_channels)?;
            for h in edge_handles {
                h.join().map_err(|_| anyhow::anyhow!("edge thread panicked"))??;
            }
        }
        TierSpec::Flat if cfg.shards > 1 => {
            anyhow::ensure!(
                down_spec.is_none(),
                "shards>1 requires down=raw (workers fan the same broadcast bytes)"
            );
            server.wait_hellos(&mut server_channels)?;
            let engines = (0..cfg.shards)
                .map(|_| build_engine(cfg))
                .collect::<crate::Result<Vec<_>>>()?;
            let mut runner = ShardedRunner::new(&server, engines)?;
            for _ in 0..cfg.rounds {
                summary.rounds.push(runner.run_round(&mut server, &mut server_channels)?);
            }
            server.shutdown(&mut server_channels)?;
        }
        TierSpec::Flat => {
            server.wait_hellos(&mut server_channels)?;
            for _ in 0..cfg.rounds {
                summary.rounds.push(server.run_round(&mut server_channels)?);
            }
            server.shutdown(&mut server_channels)?;
        }
    }
    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("client thread panicked"))??;
    }
    // Final eval on the aggregated model.
    let eval_slice = {
        let mut rng = data_rng.fork(0xE7A1);
        ds.sample(&mut rng, 256, 0.0)
    };
    let (_, acc) =
        NativeTrainer::eval_params(cfg.dataset.classes(), &server.params, &eval_slice);
    summary.final_accuracy = Some(acc);
    Ok(summary)
}

/// Print a run summary as a table.
pub fn print_summary(cfg: &RunConfig, summary: &RunSummary) {
    let mut t = crate::metrics::Table::new(
        &format!(
            "FL run: model={} dataset={} codec={} eb={} down={} link={:.0}/{:.0}Mbps \
             participation={}",
            cfg.model,
            cfg.dataset.name(),
            cfg.codec,
            cfg.rel_error_bound,
            cfg.down,
            cfg.link.bits_per_sec / 1e6,
            cfg.link.down_bits_per_sec / 1e6,
            cfg.participation,
        ),
        &[
            "round", "loss", "CR", "payload(KB)", "down(KB)", "downCR", "syncs", "comm time",
            "part", "drop", "store(KB)", "eval acc",
        ],
    );
    for r in &summary.rounds {
        t.row(vec![
            r.round.to_string(),
            format!("{:.4}", r.mean_loss),
            format!("{:.2}", r.ratio()),
            format!("{:.1}", r.payload_bytes as f64 / 1e3),
            format!("{:.1}", r.downlink_bytes as f64 / 1e3),
            format!("{:.2}", r.down_ratio()),
            r.full_syncs.to_string(),
            crate::metrics::fmt_duration(r.comm_time()),
            r.participants.to_string(),
            r.dropped.to_string(),
            format!("{:.1}", r.store_bytes as f64 / 1e3),
            r.eval.map(|(_, a)| format!("{:.3}", a)).unwrap_or_else(|| "-".into()),
        ]);
    }
    t.print();
    println!(
        "mean CR {:.2} | down CR {:.2} | total comm {} | final acc {}",
        summary.mean_ratio(),
        summary.mean_down_ratio(),
        crate::metrics::fmt_duration(summary.total_comm_time()),
        summary.final_accuracy.map(|a| format!("{a:.3}")).unwrap_or_else(|| "-".into()),
    );
    let binsum: usize = summary.rounds.iter().map(|r| r.binsum_layers).sum();
    let exact: usize = summary.rounds.iter().map(|r| r.exact_layers).sum();
    println!(
        "agg={} | server decode {} | agg time {} | layers binsum/exact {}/{}",
        cfg.agg,
        crate::metrics::fmt_duration(summary.total_server_decode_time()),
        crate::metrics::fmt_duration(summary.total_agg_time()),
        binsum,
        exact,
    );
    let shards = summary.rounds.iter().map(|r| r.shards).max().unwrap_or(0);
    if shards > 1 || cfg.tier != "flat" || summary.total_dropped() > 0 {
        let merge: std::time::Duration = summary.rounds.iter().map(|r| r.merge_time).sum();
        println!(
            "tier={} | shards {} | merge {} | dropped {}",
            cfg.tier,
            shards,
            crate::metrics::fmt_duration(merge),
            summary.total_dropped(),
        );
    }
}
