//! [`LocalTrainer`] implementation over the pure-Rust conv net — the
//! trainer used by threaded/TCP FL runs and artifact-free tests.

use crate::fl::client::LocalTrainer;
use crate::tensor::{LayerMeta, ModelGrad};
use crate::train::data::DataSlice;
use crate::train::native::NativeNet;

/// Mini-batch size of the native trainer's local epoch.
const BS: usize = 32;

/// Per-client native trainer: local data + a scratch model.
pub struct NativeTrainer {
    data: DataSlice,
    lr: f32,
    scratch: NativeNet,
}

impl NativeTrainer {
    pub fn new(classes: usize, data: DataSlice, lr: f32, seed: u64) -> Self {
        NativeTrainer { data, lr, scratch: NativeNet::new(classes, seed) }
    }

    fn load_params(net: &mut NativeNet, params: &[Vec<f32>]) {
        net.conv_w.copy_from_slice(&params[0]);
        net.conv_b.copy_from_slice(&params[1]);
        net.fc_w.copy_from_slice(&params[2]);
        net.fc_b.copy_from_slice(&params[3]);
    }

    /// Evaluate arbitrary parameters on a data slice.
    pub fn eval_params(classes: usize, params: &[Vec<f32>], data: &DataSlice) -> (f32, f32) {
        let mut net = NativeNet::new(classes, 0);
        Self::load_params(&mut net, params);
        let (loss, acc, _) = net.grad_batch(data);
        (loss, acc)
    }
}

impl LocalTrainer for NativeTrainer {
    fn train_round(&mut self, params: &[Vec<f32>]) -> crate::Result<(ModelGrad, f32)> {
        anyhow::ensure!(params.len() == 4, "native trainer expects 4 tensors");
        Self::load_params(&mut self.scratch, params);
        let img_len: usize = crate::train::data::IMG.iter().product();
        let n = self.data.n;
        let mut total_loss = 0.0f64;
        let mut batches = 0usize;
        let mut start = 0usize;
        while start < n {
            let bs = BS.min(n - start);
            let batch = DataSlice {
                xs: self.data.xs[start * img_len..(start + bs) * img_len].to_vec(),
                ys: self.data.ys[start..start + bs].to_vec(),
                n: bs,
            };
            let (loss, _, g) = self.scratch.grad_batch(&batch);
            self.scratch.apply(&g, self.lr);
            total_loss += loss as f64;
            batches += 1;
            start += bs;
        }
        // Round gradient = (θ_global − θ_local)/lr.
        let inv_lr = 1.0 / self.lr;
        let metas = self.scratch.layer_metas();
        let locals: [&Vec<f32>; 4] =
            [&self.scratch.conv_w, &self.scratch.conv_b, &self.scratch.fc_w, &self.scratch.fc_b];
        let layers = metas
            .into_iter()
            .zip(params.iter().zip(locals))
            .map(|(meta, (old, new))| {
                let data: Vec<f32> =
                    old.iter().zip(new).map(|(o, n)| (o - n) * inv_lr).collect();
                crate::tensor::LayerGrad::new(meta, data)
            })
            .collect();
        Ok((ModelGrad { layers }, total_loss as f32 / batches.max(1) as f32))
    }

    fn layer_metas(&self) -> Vec<LayerMeta> {
        self.scratch.layer_metas()
    }

    fn n_samples(&self) -> usize {
        self.data.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::data::{DatasetSpec, SynthDataset};
    use crate::util::rng::Rng;

    #[test]
    fn train_round_produces_correct_shapes() {
        let ds = SynthDataset::new(DatasetSpec::Cifar10, 1);
        let mut rng = Rng::new(2);
        let slice = ds.sample(&mut rng, 48, 0.0);
        let mut t = NativeTrainer::new(10, slice, 0.1, 3);
        let net = NativeNet::new(10, 3);
        let params =
            vec![net.conv_w.clone(), net.conv_b.clone(), net.fc_w.clone(), net.fc_b.clone()];
        let (g, loss) = t.train_round(&params).unwrap();
        assert_eq!(g.layers.len(), 4);
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(g.layers[0].data.len(), net.conv_w.len());
        // Gradient should be nonzero.
        assert!(g.flat().iter().any(|&x| x != 0.0));
    }

    #[test]
    fn repeated_rounds_reduce_loss() {
        let ds = SynthDataset::new(DatasetSpec::Cifar10, 5);
        let mut rng = Rng::new(6);
        let slice = ds.sample(&mut rng, 64, 0.0);
        let mut t = NativeTrainer::new(10, slice, 0.3, 7);
        let net = NativeNet::new(10, 7);
        let mut params =
            vec![net.conv_w.clone(), net.conv_b.clone(), net.fc_w.clone(), net.fc_b.clone()];
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..8 {
            let (g, loss) = t.train_round(&params).unwrap();
            if first.is_none() {
                first = Some(loss);
            }
            last = loss;
            // server applies full update (lr matches local for 1 client)
            for (p, l) in params.iter_mut().zip(&g.layers) {
                for (w, &d) in p.iter_mut().zip(&l.data) {
                    *w -= 0.3 * d;
                }
            }
        }
        assert!(last < first.unwrap(), "{:?} -> {last}", first);
    }
}
