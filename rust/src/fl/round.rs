//! Round bookkeeping: per-round statistics and the round-time model of
//! paper §2.1 (`T_comm = T_comp + S′/B + T_decomp`).

use std::time::Duration;

use crate::fl::transport::bandwidth::LinkSpec;

/// Statistics of one synchronous FedAvg round.
#[derive(Debug, Clone, Default)]
pub struct RoundStats {
    pub round: u32,
    /// Mean client training loss.
    pub mean_loss: f64,
    /// Sum of compressed payload bytes across clients.
    pub payload_bytes: usize,
    /// Sum of uncompressed gradient bytes across clients.
    pub raw_bytes: usize,
    /// Total client-side compression time.
    pub comp_time: Duration,
    /// Total server-side decompression time.
    pub decomp_time: Duration,
    /// Total (virtual or real) transmission time.
    pub transmit_time: Duration,
    /// Downlink broadcast bytes actually sent, summed over recipients
    /// (delta frames for synced clients, full-sync bootstraps for cold
    /// ones; the raw f32 broadcast when no downlink codec runs).
    pub downlink_bytes: usize,
    /// What the raw f32 broadcast would have cost, summed over
    /// recipients (the downlink analogue of `raw_bytes`).
    pub downlink_raw_bytes: usize,
    /// Total (virtual) downlink transmission time across recipients.
    pub down_transmit_time: Duration,
    /// Downlink codec time: the server's encode-once pass plus its
    /// reference-mirror decode (paid once per round, amortized over the
    /// whole fan-out).
    pub down_codec_time: Duration,
    /// Cold clients bootstrapped via `FullSync` this round.
    pub full_syncs: usize,
    /// Evaluation results if this round evaluated.
    pub eval: Option<(f32, f32)>,
    /// Clients that participated this round (partial participation:
    /// < total fleet size).
    pub participants: usize,
    /// State resets ordered by the epoch handshake this round (evicted /
    /// dropped-out / cold-rejoined clients).
    pub resyncs: usize,
    /// Server state-store occupancy after the round: mirror states held
    /// across both tiers (resident + spilled to disk) and their bytes —
    /// the "state-memory trajectory".
    pub store_clients: usize,
    pub store_bytes: usize,
    /// Server-side payload decode CPU this round (the portion of
    /// `decomp_time` spent turning wire bytes into aggregator input —
    /// the cost `agg=binsum` attacks by stopping before dequantization).
    pub server_decode_time: Duration,
    /// Aggregation CPU this round: accumulator adds plus the
    /// `finish_round` dequantize-and-divide.
    pub agg_time: Duration,
    /// Layers aggregated on the integer-bin route this round.
    pub binsum_layers: usize,
    /// Layers aggregated on the dense f32 route (includes mixed-route
    /// layers that were demoted mid-round).
    pub exact_layers: usize,
    /// Dequantize passes performed by the aggregator (binsum target:
    /// exactly one per bin-routed layer per round).
    pub dequant_passes: usize,
}

impl RoundStats {
    /// Compression ratio achieved this round (an empty round is a
    /// neutral 1.0, matching `CompressionStats::ratio`).
    pub fn ratio(&self) -> f64 {
        crate::compress::CompressionStats {
            raw_bytes: self.raw_bytes,
            compressed_bytes: self.payload_bytes,
        }
        .ratio()
    }

    /// Downlink compression ratio (raw broadcast / actual broadcast; a
    /// round with no broadcast accounting is a neutral 1.0).
    pub fn down_ratio(&self) -> f64 {
        crate::compress::CompressionStats {
            raw_bytes: self.downlink_raw_bytes,
            compressed_bytes: self.downlink_bytes,
        }
        .ratio()
    }

    /// End-to-end communication time (paper Eq. 1, both directions):
    /// `T_comp + S'/B_up + T_decomp` for the uplink plus the downlink
    /// broadcast's codec and transmit terms.
    pub fn comm_time(&self) -> Duration {
        self.comp_time
            + self.transmit_time
            + self.decomp_time
            + self.down_codec_time
            + self.down_transmit_time
    }

    /// What the same round would have cost uncompressed in **both**
    /// directions: `S/B_up + S_down/B_down`.
    pub fn uncompressed_time(&self, link: &LinkSpec) -> Duration {
        link.transmit_time(self.raw_bytes) + link.downlink_time(self.downlink_raw_bytes)
    }
}

/// Aggregated run summary across rounds.
#[derive(Debug, Clone, Default)]
pub struct RunSummary {
    pub rounds: Vec<RoundStats>,
    pub final_accuracy: Option<f32>,
}

impl RunSummary {
    pub fn total_payload(&self) -> usize {
        self.rounds.iter().map(|r| r.payload_bytes).sum()
    }
    pub fn total_raw(&self) -> usize {
        self.rounds.iter().map(|r| r.raw_bytes).sum()
    }
    pub fn mean_ratio(&self) -> f64 {
        crate::compress::CompressionStats {
            raw_bytes: self.total_raw(),
            compressed_bytes: self.total_payload(),
        }
        .ratio()
    }
    pub fn total_comm_time(&self) -> Duration {
        self.rounds.iter().map(|r| r.comm_time()).sum()
    }
    /// Run-wide server decode CPU (the `agg=binsum` headline number).
    pub fn total_server_decode_time(&self) -> Duration {
        self.rounds.iter().map(|r| r.server_decode_time).sum()
    }
    /// Run-wide aggregation CPU.
    pub fn total_agg_time(&self) -> Duration {
        self.rounds.iter().map(|r| r.agg_time).sum()
    }
    pub fn total_downlink(&self) -> usize {
        self.rounds.iter().map(|r| r.downlink_bytes).sum()
    }
    pub fn total_downlink_raw(&self) -> usize {
        self.rounds.iter().map(|r| r.downlink_raw_bytes).sum()
    }
    /// Run-wide downlink compression ratio.
    pub fn mean_down_ratio(&self) -> f64 {
        crate::compress::CompressionStats {
            raw_bytes: self.total_downlink_raw(),
            compressed_bytes: self.total_downlink(),
        }
        .ratio()
    }
    pub fn loss_curve(&self) -> Vec<f64> {
        self.rounds.iter().map(|r| r.mean_loss).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_time_model() {
        // Eq. 1 with both directions: uplink comp/transmit/decomp plus
        // the downlink broadcast's codec and transmit terms.
        let st = RoundStats {
            comp_time: Duration::from_millis(10),
            decomp_time: Duration::from_millis(5),
            transmit_time: Duration::from_millis(100),
            down_codec_time: Duration::from_millis(3),
            down_transmit_time: Duration::from_millis(40),
            payload_bytes: 100,
            raw_bytes: 1000,
            downlink_bytes: 200,
            downlink_raw_bytes: 1000,
            ..Default::default()
        };
        assert_eq!(st.comm_time(), Duration::from_millis(158));
        assert!((st.ratio() - 10.0).abs() < 1e-12);
        assert!((st.down_ratio() - 5.0).abs() < 1e-12);
        // Uncompressed cost covers both directions of an asymmetric link.
        let link = LinkSpec {
            bits_per_sec: 8e3, // 1000 raw bytes up -> 1 s
            down_bits_per_sec: 16e3, // 1000 raw bytes down -> 0.5 s
            latency: Duration::ZERO,
        };
        assert!((st.uncompressed_time(&link).as_secs_f64() - 1.5).abs() < 1e-9);
        // A round with no downlink accounting reduces to the old model.
        let up_only = RoundStats {
            comp_time: Duration::from_millis(10),
            decomp_time: Duration::from_millis(5),
            transmit_time: Duration::from_millis(100),
            ..Default::default()
        };
        assert_eq!(up_only.comm_time(), Duration::from_millis(115));
        assert_eq!(up_only.down_ratio(), 1.0);
    }

    #[test]
    fn summary_aggregates() {
        let mut s = RunSummary::default();
        for _ in 0..3 {
            s.rounds.push(RoundStats {
                payload_bytes: 10,
                raw_bytes: 100,
                downlink_bytes: 25,
                downlink_raw_bytes: 100,
                ..Default::default()
            });
        }
        assert_eq!(s.total_payload(), 30);
        assert!((s.mean_ratio() - 10.0).abs() < 1e-12);
        assert_eq!(s.total_downlink(), 75);
        assert_eq!(s.total_downlink_raw(), 300);
        assert!((s.mean_down_ratio() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn summary_totals_agg_times() {
        let mut s = RunSummary::default();
        for _ in 0..4 {
            s.rounds.push(RoundStats {
                server_decode_time: Duration::from_millis(6),
                agg_time: Duration::from_millis(2),
                binsum_layers: 3,
                exact_layers: 1,
                dequant_passes: 3,
                ..Default::default()
            });
        }
        assert_eq!(s.total_server_decode_time(), Duration::from_millis(24));
        assert_eq!(s.total_agg_time(), Duration::from_millis(8));
    }
}
