//! Round bookkeeping: per-round statistics and the round-time model of
//! paper §2.1 (`T_comm = T_comp + S′/B + T_decomp`).

use std::time::Duration;

use crate::fl::transport::bandwidth::LinkSpec;

/// Statistics of one synchronous FedAvg round.
///
/// `PartialEq` backs the journal-fold exactness checks: a fold over
/// `telemetry::journal` records must reproduce these fields *exactly*
/// (integer-nanosecond durations; identical f64 association order).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RoundStats {
    pub round: u32,
    /// Mean client training loss.
    pub mean_loss: f64,
    /// Sum of compressed payload bytes across clients.
    pub payload_bytes: usize,
    /// Sum of uncompressed gradient bytes across clients.
    pub raw_bytes: usize,
    /// Total client-side compression time.
    pub comp_time: Duration,
    /// Total server-side decompression time.
    pub decomp_time: Duration,
    /// Total (virtual or real) transmission time.
    pub transmit_time: Duration,
    /// Downlink broadcast bytes actually sent, summed over recipients
    /// (delta frames for synced clients, full-sync bootstraps for cold
    /// ones; the raw f32 broadcast when no downlink codec runs).
    pub downlink_bytes: usize,
    /// What the raw f32 broadcast would have cost, summed over
    /// recipients (the downlink analogue of `raw_bytes`).
    pub downlink_raw_bytes: usize,
    /// Total (virtual) downlink transmission time across recipients.
    pub down_transmit_time: Duration,
    /// Downlink codec time: the server's encode-once pass plus its
    /// reference-mirror decode (paid once per round, amortized over the
    /// whole fan-out).
    pub down_codec_time: Duration,
    /// Cold clients bootstrapped via `FullSync` this round.
    pub full_syncs: usize,
    /// Evaluation results if this round evaluated.
    pub eval: Option<(f32, f32)>,
    /// Clients that participated this round (partial participation:
    /// < total fleet size).
    pub participants: usize,
    /// State resets ordered by the epoch handshake this round (evicted /
    /// dropped-out / cold-rejoined clients).
    pub resyncs: usize,
    /// Server state-store occupancy after the round: mirror states held
    /// across both tiers (resident + spilled to disk) and their bytes —
    /// the "state-memory trajectory".
    pub store_clients: usize,
    pub store_bytes: usize,
    /// Server-side payload decode CPU this round (the portion of
    /// `decomp_time` spent turning wire bytes into aggregator input —
    /// the cost `agg=binsum` attacks by stopping before dequantization).
    pub server_decode_time: Duration,
    /// Aggregation CPU this round: accumulator adds plus the
    /// `finish_round` dequantize-and-divide.
    pub agg_time: Duration,
    /// Layers aggregated on the integer-bin route this round.
    pub binsum_layers: usize,
    /// Layers aggregated on the dense f32 route (includes mixed-route
    /// layers that were demoted mid-round).
    pub exact_layers: usize,
    /// Dequantize passes performed by the aggregator (binsum target:
    /// exactly one per bin-routed layer per round).
    pub dequant_passes: usize,
    /// Clients whose contribution was dropped whole this round — a
    /// channel error, protocol violation, or failed decode no longer
    /// aborts the round; the faulty client is excluded and counted here.
    pub dropped: usize,
    /// Worker shards (or edge aggregators) that served the round;
    /// 1 for the flat sequential loop, 0 for hand-built stats.
    pub shards: usize,
    /// Wall-clock of the partial-aggregate merge tree at round end
    /// (zero when a single aggregator served the whole round).
    pub merge_time: Duration,
    /// The error-bound controller's broadcast bound for this round
    /// (`None` when no plan was emitted — fixed eb or a pre-milestone
    /// schedule round; see [`crate::compress::control`]).
    pub round_eb: Option<f32>,
}

impl RoundStats {
    /// Compression ratio achieved this round (an empty round is a
    /// neutral 1.0, matching `CompressionStats::ratio`).
    pub fn ratio(&self) -> f64 {
        crate::compress::CompressionStats {
            raw_bytes: self.raw_bytes,
            compressed_bytes: self.payload_bytes,
        }
        .ratio()
    }

    /// Downlink compression ratio (raw broadcast / actual broadcast; a
    /// round with no broadcast accounting is a neutral 1.0).
    pub fn down_ratio(&self) -> f64 {
        crate::compress::CompressionStats {
            raw_bytes: self.downlink_raw_bytes,
            compressed_bytes: self.downlink_bytes,
        }
        .ratio()
    }

    /// End-to-end communication time (paper Eq. 1, both directions):
    /// `T_comp + S'/B_up + T_decomp` for the uplink plus the downlink
    /// broadcast's codec and transmit terms.
    pub fn comm_time(&self) -> Duration {
        self.comp_time
            + self.transmit_time
            + self.decomp_time
            + self.down_codec_time
            + self.down_transmit_time
    }

    /// What the same round would have cost uncompressed in **both**
    /// directions: `S/B_up + S_down/B_down`.
    pub fn uncompressed_time(&self, link: &LinkSpec) -> Duration {
        link.transmit_time(self.raw_bytes) + link.downlink_time(self.downlink_raw_bytes)
    }
}

/// The uplink-side tallies one shard worker (or edge aggregator)
/// accumulates while serving its slice of the fleet. Shards fold into
/// the round's [`RoundStats`] at merge time; edges ship theirs to the
/// root inside `Msg::AggPush`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ShardStats {
    /// Contributions absorbed into the shard's partial aggregate.
    pub served: usize,
    /// Contributions dropped whole (channel error, protocol violation,
    /// failed decode).
    pub dropped: usize,
    /// State resets ordered by the epoch handshake.
    pub resyncs: usize,
    /// Compressed payload bytes received.
    pub payload_bytes: usize,
    /// Uncompressed gradient bytes those payloads stand for.
    pub raw_bytes: usize,
    /// Sum of reported client training losses (divide by the round's
    /// total `served` after merging, not per shard).
    pub loss_sum: f64,
    /// Payload decode CPU.
    pub decode_time: Duration,
    /// Aggregator-add CPU.
    pub agg_time: Duration,
}

impl ShardStats {
    /// Accumulate another shard's tallies (order-independent).
    pub fn absorb(&mut self, other: &ShardStats) {
        self.served += other.served;
        self.dropped += other.dropped;
        self.resyncs += other.resyncs;
        self.payload_bytes += other.payload_bytes;
        self.raw_bytes += other.raw_bytes;
        self.loss_sum += other.loss_sum;
        self.decode_time += other.decode_time;
        self.agg_time += other.agg_time;
    }

    /// Fold the merged tallies into the round's stats. `mean_loss`
    /// receives the raw loss sum — the caller divides by `served` once
    /// all shards are in.
    pub fn fold_into(&self, stats: &mut RoundStats) {
        stats.dropped += self.dropped;
        stats.resyncs += self.resyncs;
        stats.payload_bytes += self.payload_bytes;
        stats.raw_bytes += self.raw_bytes;
        stats.mean_loss += self.loss_sum;
        stats.decomp_time += self.decode_time;
        stats.server_decode_time += self.decode_time;
        stats.agg_time += self.agg_time;
    }

    /// Serialize for the edge→root `AggPush` header.
    pub fn write_wire(&self, w: &mut crate::compress::blob::BlobWriter) {
        w.put_u64(self.served as u64);
        w.put_u64(self.dropped as u64);
        w.put_u64(self.resyncs as u64);
        w.put_u64(self.payload_bytes as u64);
        w.put_u64(self.raw_bytes as u64);
        w.put_f64(self.loss_sum);
        w.put_u64(self.decode_time.as_nanos() as u64);
        w.put_u64(self.agg_time.as_nanos() as u64);
    }

    /// Deserialize an `AggPush` header.
    pub fn read_wire(r: &mut crate::compress::blob::BlobReader) -> crate::Result<ShardStats> {
        let loss_guard = |v: f64| -> crate::Result<f64> {
            anyhow::ensure!(v.is_finite(), "shard stats: non-finite loss sum {v}");
            Ok(v)
        };
        Ok(ShardStats {
            served: r.get_u64()? as usize,
            dropped: r.get_u64()? as usize,
            resyncs: r.get_u64()? as usize,
            payload_bytes: r.get_u64()? as usize,
            raw_bytes: r.get_u64()? as usize,
            loss_sum: loss_guard(r.get_f64()?)?,
            decode_time: Duration::from_nanos(r.get_u64()?),
            agg_time: Duration::from_nanos(r.get_u64()?),
        })
    }
}

/// Aggregated run summary across rounds.
#[derive(Debug, Clone, Default)]
pub struct RunSummary {
    pub rounds: Vec<RoundStats>,
    pub final_accuracy: Option<f32>,
}

impl RunSummary {
    pub fn total_payload(&self) -> usize {
        self.rounds.iter().map(|r| r.payload_bytes).sum()
    }
    pub fn total_raw(&self) -> usize {
        self.rounds.iter().map(|r| r.raw_bytes).sum()
    }
    pub fn mean_ratio(&self) -> f64 {
        crate::compress::CompressionStats {
            raw_bytes: self.total_raw(),
            compressed_bytes: self.total_payload(),
        }
        .ratio()
    }
    pub fn total_comm_time(&self) -> Duration {
        self.rounds.iter().map(|r| r.comm_time()).sum()
    }
    /// Run-wide server decode CPU (the `agg=binsum` headline number).
    pub fn total_server_decode_time(&self) -> Duration {
        self.rounds.iter().map(|r| r.server_decode_time).sum()
    }
    /// Run-wide aggregation CPU.
    pub fn total_agg_time(&self) -> Duration {
        self.rounds.iter().map(|r| r.agg_time).sum()
    }
    pub fn total_downlink(&self) -> usize {
        self.rounds.iter().map(|r| r.downlink_bytes).sum()
    }
    pub fn total_downlink_raw(&self) -> usize {
        self.rounds.iter().map(|r| r.downlink_raw_bytes).sum()
    }
    /// Run-wide downlink compression ratio.
    pub fn mean_down_ratio(&self) -> f64 {
        crate::compress::CompressionStats {
            raw_bytes: self.total_downlink_raw(),
            compressed_bytes: self.total_downlink(),
        }
        .ratio()
    }
    pub fn loss_curve(&self) -> Vec<f64> {
        self.rounds.iter().map(|r| r.mean_loss).collect()
    }
    /// Run-wide count of contributions dropped whole.
    pub fn total_dropped(&self) -> usize {
        self.rounds.iter().map(|r| r.dropped).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_time_model() {
        // Eq. 1 with both directions: uplink comp/transmit/decomp plus
        // the downlink broadcast's codec and transmit terms.
        let st = RoundStats {
            comp_time: Duration::from_millis(10),
            decomp_time: Duration::from_millis(5),
            transmit_time: Duration::from_millis(100),
            down_codec_time: Duration::from_millis(3),
            down_transmit_time: Duration::from_millis(40),
            payload_bytes: 100,
            raw_bytes: 1000,
            downlink_bytes: 200,
            downlink_raw_bytes: 1000,
            ..Default::default()
        };
        assert_eq!(st.comm_time(), Duration::from_millis(158));
        assert!((st.ratio() - 10.0).abs() < 1e-12);
        assert!((st.down_ratio() - 5.0).abs() < 1e-12);
        // Uncompressed cost covers both directions of an asymmetric link.
        let link = LinkSpec {
            bits_per_sec: 8e3, // 1000 raw bytes up -> 1 s
            down_bits_per_sec: 16e3, // 1000 raw bytes down -> 0.5 s
            latency: Duration::ZERO,
        };
        assert!((st.uncompressed_time(&link).as_secs_f64() - 1.5).abs() < 1e-9);
        // A round with no downlink accounting reduces to the old model.
        let up_only = RoundStats {
            comp_time: Duration::from_millis(10),
            decomp_time: Duration::from_millis(5),
            transmit_time: Duration::from_millis(100),
            ..Default::default()
        };
        assert_eq!(up_only.comm_time(), Duration::from_millis(115));
        assert_eq!(up_only.down_ratio(), 1.0);
    }

    #[test]
    fn summary_aggregates() {
        let mut s = RunSummary::default();
        for _ in 0..3 {
            s.rounds.push(RoundStats {
                payload_bytes: 10,
                raw_bytes: 100,
                downlink_bytes: 25,
                downlink_raw_bytes: 100,
                ..Default::default()
            });
        }
        assert_eq!(s.total_payload(), 30);
        assert!((s.mean_ratio() - 10.0).abs() < 1e-12);
        assert_eq!(s.total_downlink(), 75);
        assert_eq!(s.total_downlink_raw(), 300);
        assert!((s.mean_down_ratio() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn shard_stats_absorb_fold_and_wire() {
        let a = ShardStats {
            served: 5,
            dropped: 1,
            resyncs: 2,
            payload_bytes: 100,
            raw_bytes: 1000,
            loss_sum: 2.5,
            decode_time: Duration::from_millis(3),
            agg_time: Duration::from_millis(1),
        };
        let b = ShardStats { served: 3, dropped: 0, loss_sum: 1.5, ..Default::default() };
        let mut total = a;
        total.absorb(&b);
        assert_eq!(total.served, 8);
        assert_eq!(total.dropped, 1);
        assert_eq!(total.loss_sum, 4.0);
        let mut stats = RoundStats::default();
        total.fold_into(&mut stats);
        assert_eq!(stats.dropped, 1);
        assert_eq!(stats.resyncs, 2);
        assert_eq!(stats.payload_bytes, 100);
        assert_eq!(stats.mean_loss, 4.0);
        assert_eq!(stats.server_decode_time, Duration::from_millis(3));
        // Wire roundtrip is exact.
        let mut w = crate::compress::blob::BlobWriter::new();
        a.write_wire(&mut w);
        let bytes = w.into_bytes();
        let mut r = crate::compress::blob::BlobReader::new(&bytes);
        assert_eq!(ShardStats::read_wire(&mut r).unwrap(), a);
        assert_eq!(r.remaining(), 0);
        // Truncation and poisoned loss sums are rejected.
        assert!(ShardStats::read_wire(&mut crate::compress::blob::BlobReader::new(&bytes[..10]))
            .is_err());
        let mut w = crate::compress::blob::BlobWriter::new();
        ShardStats { loss_sum: f64::NAN, ..Default::default() }.write_wire(&mut w);
        let bytes = w.into_bytes();
        let mut r = crate::compress::blob::BlobReader::new(&bytes);
        assert!(ShardStats::read_wire(&mut r).is_err());
    }

    #[test]
    fn summary_totals_agg_times() {
        let mut s = RunSummary::default();
        for _ in 0..4 {
            s.rounds.push(RoundStats {
                server_decode_time: Duration::from_millis(6),
                agg_time: Duration::from_millis(2),
                binsum_layers: 3,
                exact_layers: 1,
                dequant_passes: 3,
                ..Default::default()
            });
        }
        assert_eq!(s.total_server_decode_time(), Duration::from_millis(24));
        assert_eq!(s.total_agg_time(), Duration::from_millis(8));
    }
}
