//! Round bookkeeping: per-round statistics and the round-time model of
//! paper §2.1 (`T_comm = T_comp + S′/B + T_decomp`).

use std::time::Duration;

use crate::fl::transport::bandwidth::LinkSpec;

/// Statistics of one synchronous FedAvg round.
#[derive(Debug, Clone, Default)]
pub struct RoundStats {
    pub round: u32,
    /// Mean client training loss.
    pub mean_loss: f64,
    /// Sum of compressed payload bytes across clients.
    pub payload_bytes: usize,
    /// Sum of uncompressed gradient bytes across clients.
    pub raw_bytes: usize,
    /// Total client-side compression time.
    pub comp_time: Duration,
    /// Total server-side decompression time.
    pub decomp_time: Duration,
    /// Total (virtual or real) transmission time.
    pub transmit_time: Duration,
    /// Evaluation results if this round evaluated.
    pub eval: Option<(f32, f32)>,
    /// Clients that participated this round (partial participation:
    /// < total fleet size).
    pub participants: usize,
    /// State resets ordered by the epoch handshake this round (evicted /
    /// dropped-out / cold-rejoined clients).
    pub resyncs: usize,
    /// Server state-store occupancy after the round: mirror states held
    /// across both tiers (resident + spilled to disk) and their bytes —
    /// the "state-memory trajectory".
    pub store_clients: usize,
    pub store_bytes: usize,
}

impl RoundStats {
    /// Compression ratio achieved this round (an empty round is a
    /// neutral 1.0, matching `CompressionStats::ratio`).
    pub fn ratio(&self) -> f64 {
        crate::compress::CompressionStats {
            raw_bytes: self.raw_bytes,
            compressed_bytes: self.payload_bytes,
        }
        .ratio()
    }

    /// End-to-end communication time (paper Eq. 1):
    /// `T_comp + S'/B + T_decomp` (per-round totals).
    pub fn comm_time(&self) -> Duration {
        self.comp_time + self.transmit_time + self.decomp_time
    }

    /// What the same round would have cost uncompressed: `S/B`.
    pub fn uncompressed_time(&self, link: &LinkSpec) -> Duration {
        link.transmit_time(self.raw_bytes)
    }
}

/// Aggregated run summary across rounds.
#[derive(Debug, Clone, Default)]
pub struct RunSummary {
    pub rounds: Vec<RoundStats>,
    pub final_accuracy: Option<f32>,
}

impl RunSummary {
    pub fn total_payload(&self) -> usize {
        self.rounds.iter().map(|r| r.payload_bytes).sum()
    }
    pub fn total_raw(&self) -> usize {
        self.rounds.iter().map(|r| r.raw_bytes).sum()
    }
    pub fn mean_ratio(&self) -> f64 {
        crate::compress::CompressionStats {
            raw_bytes: self.total_raw(),
            compressed_bytes: self.total_payload(),
        }
        .ratio()
    }
    pub fn total_comm_time(&self) -> Duration {
        self.rounds.iter().map(|r| r.comm_time()).sum()
    }
    pub fn loss_curve(&self) -> Vec<f64> {
        self.rounds.iter().map(|r| r.mean_loss).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_time_model() {
        let st = RoundStats {
            comp_time: Duration::from_millis(10),
            decomp_time: Duration::from_millis(5),
            transmit_time: Duration::from_millis(100),
            payload_bytes: 100,
            raw_bytes: 1000,
            ..Default::default()
        };
        assert_eq!(st.comm_time(), Duration::from_millis(115));
        assert!((st.ratio() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn summary_aggregates() {
        let mut s = RunSummary::default();
        for _ in 0..3 {
            s.rounds.push(RoundStats { payload_bytes: 10, raw_bytes: 100, ..Default::default() });
        }
        assert_eq!(s.total_payload(), 30);
        assert!((s.mean_ratio() - 10.0).abs() < 1e-12);
    }
}
