//! Heterogeneous-client round-time model — the paper's introduction
//! motivation: a 4G client (20–40 Mbps), Wi-Fi clients (100–200 Mbps) and
//! fiber clients (1 Gbps) can differ 50× in upload latency, and the
//! synchronous round is gated by the **slowest** participant. Compression
//! shrinks exactly that critical path.

use std::time::Duration;

use crate::fl::transport::bandwidth::LinkSpec;

/// Typical client connectivity classes (paper §1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkClass {
    /// 4G-LTE uplink: 20–40 Mbps.
    Cellular,
    /// Wi-Fi: 100–200 Mbps.
    Wifi,
    /// Fiber broadband: ~1 Gbps.
    Fiber,
}

impl LinkClass {
    pub fn name(&self) -> &'static str {
        match self {
            LinkClass::Cellular => "4G",
            LinkClass::Wifi => "wifi",
            LinkClass::Fiber => "fiber",
        }
    }

    /// Sample a link for this class (deterministic via the given RNG).
    /// Access links are asymmetric: LTE and Wi-Fi downlinks run several
    /// times faster than their uplinks; fiber is symmetric.
    pub fn sample(&self, rng: &mut crate::util::rng::Rng) -> LinkSpec {
        let mbps = match self {
            LinkClass::Cellular => rng.uniform(20.0, 40.0),
            LinkClass::Wifi => rng.uniform(100.0, 200.0),
            LinkClass::Fiber => rng.uniform(800.0, 1000.0),
        };
        let down_mbps = match self {
            // LTE advertises ~3-4x the uplink on the shared downlink.
            LinkClass::Cellular => rng.uniform(80.0, 150.0),
            // Consumer Wi-Fi backhaul: down ≫ up.
            LinkClass::Wifi => rng.uniform(300.0, 600.0),
            // Fiber is symmetric.
            LinkClass::Fiber => mbps,
        };
        let latency_ms = match self {
            LinkClass::Cellular => rng.uniform(30.0, 60.0),
            LinkClass::Wifi => rng.uniform(5.0, 15.0),
            LinkClass::Fiber => rng.uniform(1.0, 5.0),
        };
        LinkSpec {
            bits_per_sec: mbps * 1e6,
            down_bits_per_sec: down_mbps * 1e6,
            latency: Duration::from_secs_f64(latency_ms / 1e3),
        }
    }
}

/// Deterministically sample this round's participating client subset:
/// each client joins with probability `fraction` (at least one always
/// participates so a synchronous round can complete). Shared by the
/// coordinator's partial-participation loop, the scale tests, and the
/// straggler bench so "half the fleet" means the same thing everywhere.
pub fn sample_participants(
    n_clients: usize,
    fraction: f64,
    rng: &mut crate::util::rng::Rng,
) -> Vec<usize> {
    if n_clients == 0 {
        return Vec::new();
    }
    if fraction >= 1.0 {
        return (0..n_clients).collect();
    }
    let picked: Vec<usize> = (0..n_clients).filter(|_| rng.chance(fraction.max(0.0))).collect();
    if picked.is_empty() {
        vec![rng.next_below(n_clients)]
    } else {
        picked
    }
}

/// A federation's connectivity mix.
#[derive(Debug, Clone)]
pub struct HeteroFleet {
    pub links: Vec<LinkSpec>,
}

impl HeteroFleet {
    /// Build a mixed fleet: `fractions` of (cellular, wifi, fiber).
    pub fn mixed(n: usize, fractions: (f64, f64, f64), seed: u64) -> Self {
        let mut rng = crate::util::rng::Rng::new(seed ^ 0x4E7);
        let (fc, fw, _) = fractions;
        let links = (0..n)
            .map(|_| {
                let u = rng.next_f64();
                let class = if u < fc {
                    LinkClass::Cellular
                } else if u < fc + fw {
                    LinkClass::Wifi
                } else {
                    LinkClass::Fiber
                };
                class.sample(&mut rng)
            })
            .collect();
        HeteroFleet { links }
    }

    /// Synchronous-round upload time for per-client payload sizes plus
    /// per-client codec time: the round is gated by the slowest client.
    pub fn round_time(&self, payload_bytes: &[usize], codec_time: &[Duration]) -> Duration {
        assert_eq!(payload_bytes.len(), self.links.len());
        assert_eq!(codec_time.len(), self.links.len());
        self.links
            .iter()
            .zip(payload_bytes)
            .zip(codec_time)
            .map(|((link, &b), &c)| link.transmit_time(b) + c)
            .max()
            .unwrap_or(Duration::ZERO)
    }

    /// Full synchronous round including the downlink broadcast: each
    /// client first pulls `down_bytes` over its downlink, then computes
    /// for `codec_time` and pushes its payload over its uplink — the
    /// slowest end-to-end client gates the round. `down_bytes` is one
    /// value because the broadcast is the same encoded bytes for every
    /// client (encode-once fan-out); only the link under it differs.
    pub fn round_time_bidirectional(
        &self,
        down_bytes: usize,
        payload_bytes: &[usize],
        codec_time: &[Duration],
    ) -> Duration {
        assert_eq!(payload_bytes.len(), self.links.len());
        assert_eq!(codec_time.len(), self.links.len());
        self.links
            .iter()
            .zip(payload_bytes)
            .zip(codec_time)
            .map(|((link, &b), &c)| link.downlink_time(down_bytes) + c + link.transmit_time(b))
            .max()
            .unwrap_or(Duration::ZERO)
    }

    /// The fleet restricted to a participating subset (partial
    /// participation: the synchronous round is gated by the slowest
    /// *participant*, not the slowest client overall).
    pub fn subset(&self, ids: &[usize]) -> HeteroFleet {
        HeteroFleet { links: ids.iter().map(|&i| self.links[i]).collect() }
    }

    /// Straggler gap: slowest / fastest upload for a uniform payload.
    pub fn disparity(&self, payload_bytes: usize) -> f64 {
        let times: Vec<f64> =
            self.links.iter().map(|l| l.transmit_time(payload_bytes).as_secs_f64()).collect();
        let max = times.iter().cloned().fold(0.0, f64::max);
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        if min > 0.0 {
            max / min
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn classes_have_expected_order() {
        let mut rng = Rng::new(1);
        let c = LinkClass::Cellular.sample(&mut rng);
        let w = LinkClass::Wifi.sample(&mut rng);
        let f = LinkClass::Fiber.sample(&mut rng);
        assert!(c.bits_per_sec < w.bits_per_sec);
        assert!(w.bits_per_sec < f.bits_per_sec);
        // Access networks are down ≫ up; fiber is symmetric.
        assert!(c.down_bits_per_sec > 2.0 * c.bits_per_sec);
        assert!(w.down_bits_per_sec > 1.5 * w.bits_per_sec);
        assert_eq!(f.down_bits_per_sec, f.bits_per_sec);
    }

    #[test]
    fn bidirectional_round_adds_broadcast_pull() {
        let fleet = HeteroFleet {
            links: vec![LinkSpec {
                bits_per_sec: 1e6,
                down_bits_per_sec: 4e6,
                latency: Duration::ZERO,
            }],
        };
        // 1 MB down at 4 Mbps (2 s) + 1 MB up at 1 Mbps (8 s) = 10 s.
        let t = fleet.round_time_bidirectional(
            1_000_000,
            &[1_000_000],
            &[Duration::ZERO],
        );
        assert!((t.as_secs_f64() - 10.0).abs() < 1e-9, "{t:?}");
        // Uplink-only model is unchanged.
        let up = fleet.round_time(&[1_000_000], &[Duration::ZERO]);
        assert!((up.as_secs_f64() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn disparity_matches_paper_scale() {
        // All-cellular vs fiber can reach tens of x (paper: "up to 50x").
        let fleet = HeteroFleet::mixed(50, (0.4, 0.4, 0.2), 7);
        let d = fleet.disparity(10_000_000);
        assert!(d > 10.0, "disparity {d}");
        assert!(d < 100.0, "disparity {d}");
    }

    #[test]
    fn round_gated_by_slowest() {
        let fleet = HeteroFleet {
            links: vec![
                LinkSpec::sym(1e6, Duration::ZERO),
                LinkSpec::sym(1e9, Duration::ZERO),
            ],
        };
        let t = fleet.round_time(&[1_000_000, 1_000_000], &[Duration::ZERO; 2]);
        assert!((t.as_secs_f64() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn compression_shrinks_critical_path_proportionally() {
        let fleet = HeteroFleet::mixed(16, (0.5, 0.3, 0.2), 3);
        let raw = vec![40_000_000usize; 16];
        let compressed = vec![2_500_000usize; 16]; // 16x CR
        let zero = vec![Duration::ZERO; 16];
        let t_raw = fleet.round_time(&raw, &zero);
        let t_cmp = fleet.round_time(&compressed, &zero);
        let speedup = t_raw.as_secs_f64() / t_cmp.as_secs_f64();
        assert!(speedup > 10.0, "speedup {speedup}");
    }

    #[test]
    fn participant_sampling_is_deterministic_and_nonempty() {
        let mut a = Rng::new(4);
        let mut b = Rng::new(4);
        let pa = sample_participants(100, 0.5, &mut a);
        let pb = sample_participants(100, 0.5, &mut b);
        assert_eq!(pa, pb);
        assert!(pa.len() > 20 && pa.len() < 80, "{}", pa.len());
        // Degenerate fractions still yield a runnable round.
        assert_eq!(sample_participants(10, 1.0, &mut a), (0..10).collect::<Vec<_>>());
        assert_eq!(sample_participants(10, 0.0, &mut a).len(), 1);
        assert!(sample_participants(0, 0.5, &mut a).is_empty());
    }

    #[test]
    fn subset_round_gated_by_slowest_participant() {
        let fleet = HeteroFleet {
            links: vec![
                LinkSpec::sym(1e6, Duration::ZERO),
                LinkSpec::sym(1e9, Duration::ZERO),
            ],
        };
        // Leaving the 1 Mbps straggler out shrinks the round 1000x.
        let full = fleet.round_time(&[1_000_000; 2], &[Duration::ZERO; 2]);
        let fast_only = fleet.subset(&[1]).round_time(&[1_000_000], &[Duration::ZERO]);
        assert!(full.as_secs_f64() > fast_only.as_secs_f64() * 100.0);
    }

    #[test]
    fn deterministic_fleet() {
        let a = HeteroFleet::mixed(8, (0.3, 0.4, 0.3), 5);
        let b = HeteroFleet::mixed(8, (0.3, 0.4, 0.3), 5);
        assert_eq!(a.links, b.links);
    }
}
