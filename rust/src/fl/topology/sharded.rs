//! The sharded round runner: one round's channels partitioned across
//! worker threads, each owning a forked [`DecodeCore`] and a private
//! partial [`RoundAgg`], merged tree-wise at round end.
//!
//! Memory stays O(shards × model), never O(clients): a worker holds one
//! in-flight decode plus its partial aggregate, and the shared
//! [`crate::compress::store::StateStore`] is the only per-client state
//! (bounded by its own budget). `last_agg_resident_bytes` reports the
//! peak partial-aggregate footprint so the scale tests can assert the
//! bound.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::compress::control::{EbPlan, EbSignals};
use crate::compress::engine::CodecEngine;
use crate::compress::store::ClientId;
use crate::fl::aggregate::RoundAgg;
use crate::fl::protocol::Msg;
use crate::fl::round::{RoundStats, ShardStats};
use crate::fl::server::{DecodeCore, Server};
use crate::fl::topology::{shard_sizes, tree_merge};
use crate::fl::transport::Channel;
use crate::telemetry::{self, journal};

/// One client's uplink in pre-received form, for driving shard workers
/// without live channels (synthetic fleets, churn soaks). Payloads are
/// `Arc<[u8]>` so a bank of distinct payloads fans out to millions of
/// clients without copying.
#[derive(Clone)]
pub struct Contribution {
    pub client: ClientId,
    pub payload: Arc<[u8]>,
    pub weight: f64,
    pub loss: f32,
}

/// Worker pool for sharded rounds: `shards` decode cores forked from
/// one server (shared store + admissions, private engines).
pub struct ShardedRunner {
    cores: Vec<DecodeCore>,
    /// Bytes held by all per-shard partial aggregates at the end of the
    /// last round, just before the merge — the figure that must stay
    /// O(shards × model) for the million-client configuration.
    pub last_agg_resident_bytes: usize,
}

impl ShardedRunner {
    /// One worker per engine. Engines are not shared across threads, so
    /// the caller builds `shards` of them (same config) and the runner
    /// forks a decode core around each.
    pub fn new(server: &Server, engines: Vec<Box<dyn CodecEngine>>) -> crate::Result<Self> {
        anyhow::ensure!(!engines.is_empty(), "sharded runner needs at least one engine");
        let cores = engines.into_iter().map(|e| server.fork_core(e)).collect();
        Ok(ShardedRunner { cores, last_agg_resident_bytes: 0 })
    }

    pub fn shards(&self) -> usize {
        self.cores.len()
    }

    /// Consult the server's controller for this round's error-bound
    /// plan and apply it to **every** worker core (the server's own
    /// engine adopts it inside [`Server::plan_round_eb`]) — a worker
    /// decoding under a stale eb would fork its mirror fingerprints.
    fn plan_round(&mut self, server: &mut Server) -> Option<EbPlan> {
        let plan = server.plan_round_eb()?;
        for core in &mut self.cores {
            core.apply_eb_plan(&plan);
        }
        Some(plan)
    }

    /// Run one full round over live channels, sharded: the broadcast
    /// bytes are encoded once and every worker fans the same buffer to
    /// its slice, serves the handshake + updates into its private
    /// partial, and the partials merge tree-wise into the server's
    /// round step. Matches the flat [`Server::run_round`] bit-for-bit
    /// on binsum layers and to f64-reassociation accuracy on dense
    /// layers (see `DESIGN.md` §13).
    pub fn run_round(
        &mut self,
        server: &mut Server,
        channels: &mut [Box<dyn Channel>],
    ) -> crate::Result<RoundStats> {
        anyhow::ensure!(
            !server.has_downlink(),
            "sharded runner drives the raw encode-once broadcast only \
             (compressed downlink is a flat-topology feature for now)"
        );
        let round = server.round();
        let agg_mode = server.agg_mode();
        let raw_model_bytes = server.raw_model_bytes();
        let mut stats = RoundStats {
            round,
            participants: channels.len(),
            shards: self.cores.len(),
            downlink_raw_bytes: raw_model_bytes * channels.len(),
            downlink_bytes: raw_model_bytes * channels.len(),
            ..Default::default()
        };
        let span = journal::RoundSpan::begin(round, self.cores.len());
        // Error-bound plan first: encoded once, each worker fans the
        // same buffer to its slice ahead of the params broadcast.
        let eb_msg: Option<Arc<[u8]>> = self.plan_round(server).map(|plan| {
            span.eb_plan(&plan);
            telemetry::ROUND_EB.set((plan.round_eb as f64 * 1e9) as u64);
            stats.round_eb = Some(plan.round_eb);
            Msg::EbPlan { round, plan: plan.to_wire() }.encode().into()
        });
        span.downlink(
            stats.downlink_bytes,
            stats.downlink_raw_bytes,
            0,
            Duration::ZERO,
            Duration::ZERO,
        );
        telemetry::DOWNLINK_BYTES.add(stats.downlink_bytes as u64);
        telemetry::DOWNLINK_RAW_BYTES.add(stats.downlink_raw_bytes as u64);
        let bytes: Arc<[u8]> = Msg::encode_global_params(round, &server.params).into();
        let sizes = shard_sizes(channels.len(), self.cores.len());
        let mut slices: Vec<&mut [Box<dyn Channel>]> = Vec::with_capacity(sizes.len());
        let mut rest = channels;
        for sz in &sizes {
            let (head, tail) = rest.split_at_mut(*sz);
            slices.push(head);
            rest = tail;
        }
        let parts: Vec<(RoundAgg, ShardStats)> = std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(slices.len());
            for (shard_idx, (core, slice)) in self.cores.iter_mut().zip(slices).enumerate() {
                let bytes = Arc::clone(&bytes);
                let eb_msg = eb_msg.clone();
                handles.push(s.spawn(move || {
                    for ch in slice.iter_mut() {
                        // Best-effort, like the flat broadcast: a dead
                        // channel becomes a dropped client below. The
                        // plan precedes the params on every channel.
                        if let Some(eb) = &eb_msg {
                            let _ = ch.send_encoded(eb);
                        }
                        let _ = ch.send_encoded(&bytes);
                    }
                    let mut agg = RoundAgg::for_mode(agg_mode);
                    let st = core.serve_round(slice, round, raw_model_bytes, shard_idx, &mut agg);
                    (agg, st)
                }));
            }
            handles.into_iter().map(|h| h.join().expect("shard worker panicked")).collect()
        });
        self.merge_and_finish(server, parts, &mut stats)?;
        span.participants(stats.participants);
        span.end(&stats);
        Ok(stats)
    }

    /// Run one round from a channel-less contribution source: worker
    /// `i` drains `source(i)` and absorbs each contribution directly.
    /// This is the synthetic-fleet path — a million clients need
    /// neither threads nor sockets, just payloads — and the churn
    /// soak's resync driver. `participants` is reported as
    /// served + dropped (the source decides who shows up).
    pub fn run_round_direct<I, F>(
        &mut self,
        server: &mut Server,
        source: F,
    ) -> crate::Result<RoundStats>
    where
        I: Iterator<Item = Contribution>,
        F: Fn(usize) -> I + Sync,
    {
        let round = server.round();
        let agg_mode = server.agg_mode();
        let raw_model_bytes = server.raw_model_bytes();
        let mut stats =
            RoundStats { round, shards: self.cores.len(), ..Default::default() };
        let span = journal::RoundSpan::begin(round, self.cores.len());
        // Channel-less path: the source encodes its own payloads, but
        // the worker cores must still decode under the round's plan.
        if let Some(plan) = self.plan_round(server) {
            span.eb_plan(&plan);
            telemetry::ROUND_EB.set((plan.round_eb as f64 * 1e9) as u64);
            stats.round_eb = Some(plan.round_eb);
        }
        let parts: Vec<(RoundAgg, ShardStats)> = std::thread::scope(|s| {
            let source = &source;
            let mut handles = Vec::with_capacity(self.cores.len());
            for (shard_idx, core) in self.cores.iter_mut().enumerate() {
                handles.push(s.spawn(move || {
                    let span = journal::RoundSpan::at(round);
                    let mut agg = RoundAgg::for_mode(agg_mode);
                    let mut st = ShardStats::default();
                    for c in source(shard_idx) {
                        match core.absorb_payload(c.client, &c.payload, c.weight, &mut agg) {
                            Ok(times) => {
                                st.served += 1;
                                st.payload_bytes += c.payload.len();
                                st.raw_bytes += raw_model_bytes;
                                st.loss_sum += c.loss as f64;
                                st.decode_time += times.decode;
                                st.agg_time += times.agg;
                                span.client_served(
                                    shard_idx,
                                    c.client as u64,
                                    c.payload.len(),
                                    raw_model_bytes,
                                    times.decode,
                                    times.agg,
                                    c.loss as f64,
                                );
                            }
                            Err(_) => {
                                st.dropped += 1;
                                span.client_event(shard_idx, c.client as usize, "drop");
                            }
                        }
                    }
                    telemetry::record_shard(&st);
                    (agg, st)
                }));
            }
            handles.into_iter().map(|h| h.join().expect("shard worker panicked")).collect()
        });
        let served = self.merge_and_finish(server, parts, &mut stats)?;
        stats.participants = served + stats.dropped;
        span.participants(stats.participants);
        span.end(&stats);
        Ok(stats)
    }

    /// Merge worker partials tree-wise into one aggregate and drive the
    /// server's round step. Returns the total served count.
    fn merge_and_finish(
        &mut self,
        server: &mut Server,
        parts: Vec<(RoundAgg, ShardStats)>,
        stats: &mut RoundStats,
    ) -> crate::Result<usize> {
        let agg_mode = server.agg_mode();
        // Single-threaded absorb in worker order: the journal's `shard`
        // records are emitted here (not in the workers) so the fold
        // replays this exact accumulation order.
        let span = journal::RoundSpan::at(stats.round);
        let mut shard_total = ShardStats::default();
        let mut aggs = Vec::with_capacity(parts.len());
        for (i, (agg, st)) in parts.into_iter().enumerate() {
            span.shard(i, &st);
            shard_total.absorb(&st);
            aggs.push(agg);
        }
        self.last_agg_resident_bytes = aggs.iter().map(RoundAgg::approx_bytes).sum();
        let t0 = Instant::now();
        let merged = tree_merge(aggs)?;
        stats.merge_time = t0.elapsed();
        telemetry::MERGE_NS.add_duration(stats.merge_time);
        span.merge(stats.merge_time);
        let served = shard_total.served;
        shard_total.fold_into(stats);
        stats.mean_loss /= served.max(1) as f64;
        server.observe_round(&EbSignals {
            round: stats.round,
            train_loss: stats.mean_loss,
            eval: None,
            layer_bytes: Vec::new(),
        });
        server.record_store_occupancy(stats);
        span.store(stats.store_clients, stats.store_bytes);
        let rep = server.finish_round(merged.unwrap_or_else(|| RoundAgg::for_mode(agg_mode)));
        stats.agg_time += rep.finish_time;
        stats.binsum_layers = rep.binsum_layers;
        stats.exact_layers = rep.exact_layers + rep.mixed_layers;
        stats.dequant_passes = rep.dequant_passes;
        span.finish(
            rep.finish_time,
            stats.binsum_layers,
            stats.exact_layers,
            stats.dequant_passes,
        );
        Ok(served)
    }
}
