//! Aggregation topologies beyond the flat server loop: the sharded
//! round runner ([`sharded`]) that partitions one round's channels
//! across worker threads, and the edge-aggregator tier ([`edge`]) that
//! collapses whole client subtrees into single uplink contributions —
//! together, the million-client configuration (`DESIGN.md` §13).
//!
//! Both topologies lean on the same two primitives:
//!
//! * **Partial aggregates that merge.** Every worker owns a private
//!   [`RoundAgg`]; at round end the partials fold together with
//!   [`RoundAgg::merge`] in the fixed pairwise order of
//!   [`tree_merge`]. For binsum layers the partials are exact i64 bin
//!   sums, so the merged result is **bit-identical** to the flat loop
//!   regardless of sharding; dense f64 partials are merged in a
//!   deterministic tree order, so a given shard count always produces
//!   the same bits (and any shard count matches flat to ~1e-5
//!   relative, the usual f64-reassociation envelope).
//! * **A decode core per worker.** [`crate::fl::server::DecodeCore`]
//!   carries the engine plus shared store/admission handles, so shard
//!   workers serve their channel slices with the exact same handshake
//!   and fault boundary as the flat server.

pub mod edge;
pub mod sharded;
pub mod synth;

use crate::fl::aggregate::RoundAgg;

/// Which aggregation topology a coordinator run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierSpec {
    /// Every client talks straight to the root server.
    Flat,
    /// Clients are grouped into subtrees of `fanout`; each subtree is
    /// served by an edge aggregator that forwards one merged
    /// contribution to the root.
    Edge { fanout: usize },
}

impl TierSpec {
    /// Parse `"flat"` or `"edge:<fanout>"` (fanout ≥ 2).
    pub fn from_name(name: &str) -> crate::Result<TierSpec> {
        if name == "flat" {
            return Ok(TierSpec::Flat);
        }
        if let Some(rest) = name.strip_prefix("edge:") {
            let fanout: usize = rest
                .parse()
                .map_err(|_| anyhow::anyhow!("tier=edge:<fanout>: bad fanout {rest:?}"))?;
            anyhow::ensure!(fanout >= 2, "tier=edge:<fanout> needs fanout >= 2, got {fanout}");
            return Ok(TierSpec::Edge { fanout });
        }
        anyhow::bail!("unknown tier {name:?} (expected flat or edge:<fanout>)")
    }

    pub fn name(&self) -> String {
        match self {
            TierSpec::Flat => "flat".into(),
            TierSpec::Edge { fanout } => format!("edge:{fanout}"),
        }
    }
}

/// Contiguous balanced partition: split `n_items` across `shards`
/// slices whose sizes differ by at most one (larger slices first).
/// Returns fewer than `shards` entries only when there are fewer items
/// than shards; never returns an empty slice.
pub fn shard_sizes(n_items: usize, shards: usize) -> Vec<usize> {
    if n_items == 0 {
        return Vec::new();
    }
    let shards = shards.clamp(1, n_items);
    let base = n_items / shards;
    let extra = n_items % shards;
    (0..shards).map(|i| base + usize::from(i < extra)).collect()
}

/// Fold per-shard partial aggregates in a **fixed pairwise tree
/// order**: rounds of left-to-right pair merges, so merging is O(log
/// shards) depth and — crucially — the f64 summation order for dense
/// layers depends only on the shard count, never on thread timing.
/// Returns `None` for an empty input.
pub fn tree_merge(mut parts: Vec<RoundAgg>) -> crate::Result<Option<RoundAgg>> {
    while parts.len() > 1 {
        let mut next = Vec::with_capacity(parts.len().div_ceil(2));
        let mut it = parts.into_iter();
        while let Some(mut left) = it.next() {
            if let Some(right) = it.next() {
                left.merge(right)?;
            }
            next.push(left);
        }
        parts = next;
    }
    Ok(parts.pop())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::aggregate::{AggMode, FedAvg, RoundAgg};
    use crate::tensor::{LayerGrad, LayerMeta, ModelGrad};

    #[test]
    fn tier_spec_parses_and_rejects() {
        assert_eq!(TierSpec::from_name("flat").unwrap(), TierSpec::Flat);
        assert_eq!(
            TierSpec::from_name("edge:4").unwrap(),
            TierSpec::Edge { fanout: 4 }
        );
        assert_eq!(TierSpec::Edge { fanout: 4 }.name(), "edge:4");
        assert!(TierSpec::from_name("edge:1").is_err());
        assert!(TierSpec::from_name("edge:x").is_err());
        assert!(TierSpec::from_name("ring").is_err());
    }

    #[test]
    fn shard_sizes_balance_and_cover() {
        assert_eq!(shard_sizes(10, 3), vec![4, 3, 3]);
        assert_eq!(shard_sizes(3, 8), vec![1, 1, 1]); // never empty slices
        assert_eq!(shard_sizes(0, 4), Vec::<usize>::new());
        assert_eq!(shard_sizes(7, 1), vec![7]);
        for (n, s) in [(1_000_000, 8), (17, 5), (64, 64)] {
            let sizes = shard_sizes(n, s);
            assert_eq!(sizes.iter().sum::<usize>(), n);
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "{n}/{s}: {sizes:?}");
        }
    }

    fn part(vals: &[f32], weight: f64) -> RoundAgg {
        let mut fa = FedAvg::new();
        let grads = ModelGrad {
            layers: vec![LayerGrad::new(LayerMeta::other("l", vals.len()), vals.to_vec())],
        };
        fa.add(&grads, weight).unwrap();
        RoundAgg::Exact(fa)
    }

    #[test]
    fn tree_merge_is_deterministic_and_complete() {
        assert!(tree_merge(Vec::new()).unwrap().is_none());
        // 5 parts: tree order ((0+1)+(2+3))+4 — every part lands once.
        let parts: Vec<RoundAgg> =
            (0..5).map(|i| part(&[i as f32, 1.0], (i + 1) as f64)).collect();
        let (mean, _) = tree_merge(parts).unwrap().unwrap().finish();
        // Weighted mean of i over weights i+1: sum(i*(i+1))/15 = 40/15.
        let expect = 40.0f32 / 15.0;
        assert!((mean[0][0] - expect).abs() < 1e-6);
        assert!((mean[0][1] - 1.0).abs() < 1e-6);
        // Same parts, same order ⇒ same bits.
        let parts2: Vec<RoundAgg> =
            (0..5).map(|i| part(&[i as f32, 1.0], (i + 1) as f64)).collect();
        let (mean2, _) = tree_merge(parts2).unwrap().unwrap().finish();
        assert_eq!(mean, mean2);
    }

    #[test]
    fn tree_merge_rejects_route_mix() {
        let exact = part(&[1.0], 1.0);
        let bin = RoundAgg::for_mode(AggMode::Binsum);
        assert!(tree_merge(vec![exact, bin]).is_err());
    }
}
