//! Synthetic client fleets for topology-scale tests and benches: a
//! small bank of pre-compressed payloads fanned out to an arbitrarily
//! large client population as shared `Arc<[u8]>` buffers, so a
//! million-client round costs O(bank + shards) memory, not O(clients).

use std::sync::Arc;

use crate::compress::pipeline::{FedgecCodec, FedgecConfig};
use crate::compress::store::ClientId;
use crate::compress::GradientCodec;
use crate::fl::topology::shard_sizes;
use crate::fl::topology::sharded::Contribution;
use crate::tensor::{LayerGrad, LayerMeta, ModelGrad};
use crate::util::rng::Rng;

/// A simulated fleet: `n_clients` clients whose uplinks are drawn from
/// a bank of `distinct` pre-compressed payloads (client `c` always
/// uploads payload `c % distinct`, so reruns are deterministic).
pub struct SynthFleet {
    n_clients: usize,
    payloads: Vec<Arc<[u8]>>,
}

impl SynthFleet {
    /// Compress `distinct` random gradient models under `cfg` to build
    /// the payload bank. Use a state-free config (`pred=zero`,
    /// `sign=none`, absolute error bound) so replaying one payload for
    /// many clients is protocol-legal: a fresh codec per round is the
    /// same codec.
    pub fn new(
        cfg: &FedgecConfig,
        metas: &[LayerMeta],
        n_clients: usize,
        distinct: usize,
        seed: u64,
    ) -> crate::Result<Self> {
        anyhow::ensure!(distinct >= 1, "synth fleet needs at least one distinct payload");
        anyhow::ensure!(n_clients >= 1, "synth fleet needs at least one client");
        let mut rng = Rng::new(seed);
        let mut payloads = Vec::with_capacity(distinct);
        for _ in 0..distinct {
            let grads = ModelGrad {
                layers: metas
                    .iter()
                    .map(|m| {
                        let data: Vec<f32> =
                            (0..m.numel).map(|_| rng.normal_f32(0.0, 0.1)).collect();
                        LayerGrad::new(m.clone(), data)
                    })
                    .collect(),
            };
            let mut codec = FedgecCodec::new(cfg.clone());
            payloads.push(codec.compress(&grads)?.into());
        }
        Ok(SynthFleet { n_clients, payloads })
    }

    pub fn n_clients(&self) -> usize {
        self.n_clients
    }

    /// Bytes held by the payload bank — the whole fleet's uplink
    /// footprint (everything else is shared).
    pub fn resident_bytes(&self) -> usize {
        self.payloads.iter().map(|p| p.len()).sum()
    }

    /// Client `c`'s uplink: a shared handle into the bank, unit weight.
    pub fn contribution(&self, client: ClientId) -> Contribution {
        Contribution {
            client,
            payload: Arc::clone(&self.payloads[client as usize % self.payloads.len()]),
            weight: 1.0,
            loss: 0.25,
        }
    }

    /// Shard `idx`'s contiguous slice of the fleet under a `shards`-way
    /// partition — the `source` argument for
    /// [`crate::fl::topology::sharded::ShardedRunner::run_round_direct`].
    pub fn shard_iter(
        &self,
        shards: usize,
        idx: usize,
    ) -> impl Iterator<Item = Contribution> + '_ {
        let sizes = shard_sizes(self.n_clients, shards);
        let start: usize = sizes[..idx.min(sizes.len())].iter().sum();
        let len = sizes.get(idx).copied().unwrap_or(0);
        (start..start + len).map(move |c| self.contribution(c as ClientId))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::predictor::magnitude::MagnitudeSel;
    use crate::compress::predictor::sign::SignSel;
    use crate::compress::predictor::PredictorSpec;
    use crate::compress::quant::ErrorBound;

    fn cfg() -> FedgecConfig {
        FedgecConfig {
            error_bound: ErrorBound::Abs(5e-3),
            predictor: PredictorSpec { mag: MagnitudeSel::Zero, sign: SignSel::None },
            ..Default::default()
        }
    }

    #[test]
    fn bank_is_shared_and_shards_cover_the_fleet() {
        let metas = vec![LayerMeta::other("l", 64)];
        let fleet = SynthFleet::new(&cfg(), &metas, 100, 4, 7).unwrap();
        // Clients 4 apart share the same allocation; neighbors differ.
        let a = fleet.contribution(3);
        let b = fleet.contribution(7);
        let c = fleet.contribution(4);
        assert!(Arc::ptr_eq(&a.payload, &b.payload));
        assert!(!Arc::ptr_eq(&a.payload, &c.payload));
        assert!(fleet.resident_bytes() > 0);
        // An 8-way shard sweep visits every client exactly once, in id
        // order within each contiguous slice.
        let mut seen = Vec::new();
        for idx in 0..8 {
            seen.extend(fleet.shard_iter(8, idx).map(|c| c.client));
        }
        assert_eq!(seen, (0..100u32).collect::<Vec<_>>());
        // Out-of-range shard index is an empty slice, not a panic.
        assert_eq!(fleet.shard_iter(8, 9).count(), 0);
    }
}
