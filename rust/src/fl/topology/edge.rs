//! The edge-aggregator tier: clients → edge → root. An edge aggregator
//! serves its subtree exactly like a (downlink-less) server — same
//! Hello admission, state handshake, update decode, fault boundary —
//! but instead of stepping a model it forwards **one merged
//! contribution** upward per round as an `AggPush` (serialized
//! [`ShardStats`] header + partial [`RoundAgg`] body).
//!
//! Wire flow per round (`DESIGN.md` §13):
//!
//! ```text
//! root  --GlobalParams-->  edge  --same Arc<[u8]>-->  each client
//! client --StateCheck/Update--> edge        (ordinary uplink protocol)
//! edge  --AggPush{stats, partial agg}-->  root
//! ```
//!
//! The broadcast buffer crosses the edge **without re-encoding**: the
//! edge receives the raw bytes ([`Channel::recv_raw`]) and re-fans the
//! same shared allocation to its subtree, so the encode-once invariant
//! holds across the whole tree.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::compress::blob::{BlobReader, BlobWriter};
use crate::compress::control::{EbPlan, EbSignals};
use crate::compress::engine::CodecEngine;
use crate::compress::store::{ClientId, StateStore};
use crate::fl::aggregate::{AggMode, RoundAgg};
use crate::fl::protocol::Msg;
use crate::fl::round::{RoundStats, ShardStats};
use crate::fl::server::{DecodeCore, Server};
use crate::fl::topology::tree_merge;
use crate::fl::transport::Channel;
use crate::telemetry::{self, journal};
use crate::tensor::LayerMeta;

/// Client-id namespace for edge aggregators themselves (their Hello to
/// the root must not collide with real client ids).
pub const EDGE_ID_BASE: ClientId = 0x4000_0000;

/// Serialize one round's edge contribution: stats header, then the
/// partial aggregate.
pub fn encode_agg_push(stats: &ShardStats, agg: &RoundAgg) -> Vec<u8> {
    let mut w = BlobWriter::new();
    stats.write_wire(&mut w);
    agg.write_wire(&mut w);
    w.into_bytes()
}

/// Parse an `AggPush` payload back into its stats + partial aggregate,
/// rejecting trailing garbage.
pub fn decode_agg_push(bytes: &[u8]) -> crate::Result<(ShardStats, RoundAgg)> {
    let mut r = BlobReader::new(bytes);
    let stats = ShardStats::read_wire(&mut r)?;
    let agg = RoundAgg::read_wire(&mut r)?;
    anyhow::ensure!(r.remaining() == 0, "agg-push: {} trailing bytes", r.remaining());
    Ok((stats, agg))
}

/// One mid-tier aggregator owning a client subtree: its own decode
/// core (engine + store + admissions — subtree state lives at the
/// edge, never at the root) and the subtree's channels' fault boundary.
pub struct EdgeAggregator {
    id: ClientId,
    core: DecodeCore,
    agg_mode: AggMode,
}

impl EdgeAggregator {
    /// `idx` numbers the edge within its tier (id = `EDGE_ID_BASE +
    /// idx`). The store bounds the subtree's predictor-state memory;
    /// `agg_mode` must match the root's so partials merge.
    pub fn new(
        idx: u32,
        engine: Box<dyn CodecEngine>,
        store: Box<dyn StateStore>,
        metas: Vec<LayerMeta>,
        agg_mode: AggMode,
    ) -> Self {
        EdgeAggregator {
            id: EDGE_ID_BASE + idx,
            core: DecodeCore::standalone(engine, store, metas),
            agg_mode,
        }
    }

    pub fn id(&self) -> ClientId {
        self.id
    }

    /// Serve the subtree until the root says shutdown: collect the
    /// subtree's Hellos (duplicate ids rejected, like the root), then
    /// per round re-fan the broadcast bytes, serve the slice, and push
    /// the merged contribution upward. `Shutdown` is forwarded down.
    pub fn run(
        &mut self,
        up: &mut dyn Channel,
        down: &mut [Box<dyn Channel>],
    ) -> crate::Result<()> {
        let mut seen = std::collections::HashSet::new();
        for ch in down.iter_mut() {
            match ch.recv()? {
                Msg::Hello { client_id } => {
                    anyhow::ensure!(
                        seen.insert(client_id),
                        "edge {}: duplicate Hello for client {client_id}",
                        self.id
                    );
                    self.core.admit(client_id);
                }
                other => anyhow::bail!("edge {}: expected Hello, got {other:?}", self.id),
            }
        }
        up.send(&Msg::Hello { client_id: self.id })?;
        let raw_model_bytes = self.core.raw_model_bytes();
        loop {
            let raw: Arc<[u8]> = up.recv_raw()?;
            match Msg::decode(&raw)? {
                Msg::EbPlan { plan, .. } => {
                    // Root's per-round error-bound plan: adopt it for
                    // this edge's own decodes, then relay the identical
                    // bytes down so the subtree derives the same
                    // quantizer (encode-once, like the broadcast).
                    let plan = EbPlan::from_wire(&plan)?;
                    self.core.apply_eb_plan(&plan);
                    for ch in down.iter_mut() {
                        let _ = ch.send_encoded(&raw);
                    }
                }
                Msg::GlobalParams { round, .. } => {
                    for ch in down.iter_mut() {
                        // Same allocation onward; dead subtree channels
                        // become dropped clients in serve_round.
                        let _ = ch.send_encoded(&raw);
                    }
                    let mut agg = RoundAgg::for_mode(self.agg_mode);
                    let shard = (self.id - EDGE_ID_BASE) as usize;
                    let st = self.core.serve_round(down, round, raw_model_bytes, shard, &mut agg);
                    up.send(&Msg::AggPush { round, payload: encode_agg_push(&st, &agg) })?;
                }
                Msg::Shutdown => {
                    for ch in down.iter_mut() {
                        let _ = ch.send(&Msg::Shutdown);
                    }
                    return Ok(());
                }
                other => anyhow::bail!("edge {}: unexpected {other:?}", self.id),
            }
        }
    }
}

/// Receive one edge's round contribution (strict: wrong round or a
/// malformed payload fails this edge).
fn recv_agg_push(ch: &mut dyn Channel, round: u32) -> crate::Result<(ShardStats, RoundAgg)> {
    match ch.recv()? {
        Msg::AggPush { round: r, payload } => {
            anyhow::ensure!(r == round, "edge answered round {r} during round {round}");
            decode_agg_push(&payload)
        }
        other => anyhow::bail!("root: expected AggPush, got {other:?}"),
    }
}

/// Run one round at the **root** of an edge tier: broadcast the model
/// once to every edge (each re-fans the same bytes), then collect one
/// `AggPush` per edge and merge the partials tree-wise into the round
/// step.
///
/// Fault boundary: a failed edge (dead channel, wrong round, malformed
/// push) drops its **whole subtree's** contribution and counts as one
/// entry in `RoundStats.dropped` — the root cannot know how many
/// clients sat behind a subtree that never reported. `participants`
/// counts clients the surviving edges saw (served + dropped), plus
/// those dropped edges. Downlink byte accounting covers the root→edge
/// hop; the subtree re-fan of the same buffer is the edges' traffic,
/// visible in their uplinked `raw_bytes`.
pub fn run_round_root(
    server: &mut Server,
    edges: &mut [Box<dyn Channel>],
) -> crate::Result<RoundStats> {
    anyhow::ensure!(
        !server.has_downlink(),
        "edge tier drives the raw encode-once broadcast only \
         (compressed downlink is a flat-topology feature for now)"
    );
    let round = server.round();
    let agg_mode = server.agg_mode();
    let raw_model_bytes = server.raw_model_bytes();
    let mut stats = RoundStats {
        round,
        shards: edges.len(),
        downlink_raw_bytes: raw_model_bytes * edges.len(),
        downlink_bytes: raw_model_bytes * edges.len(),
        ..Default::default()
    };
    let span = journal::RoundSpan::begin(round, edges.len());
    // The round's error-bound plan travels root → edge → client ahead
    // of the params broadcast; each hop relays the same bytes.
    if let Some(plan) = server.plan_round_eb() {
        let eb: Arc<[u8]> = Msg::EbPlan { round, plan: plan.to_wire() }.encode().into();
        for ch in edges.iter_mut() {
            let _ = ch.send_encoded(&eb);
        }
        span.eb_plan(&plan);
        telemetry::ROUND_EB.set((plan.round_eb as f64 * 1e9) as u64);
        stats.round_eb = Some(plan.round_eb);
    }
    span.downlink(
        stats.downlink_bytes,
        stats.downlink_raw_bytes,
        0,
        Duration::ZERO,
        Duration::ZERO,
    );
    telemetry::DOWNLINK_BYTES.add(stats.downlink_bytes as u64);
    telemetry::DOWNLINK_RAW_BYTES.add(stats.downlink_raw_bytes as u64);
    let bytes: Arc<[u8]> = Msg::encode_global_params(round, &server.params).into();
    for ch in edges.iter_mut() {
        let _ = ch.send_encoded(&bytes);
    }
    let mut shard_total = ShardStats::default();
    let mut parts = Vec::with_capacity(edges.len());
    let mut dropped_edges = 0usize;
    // The edges' own serve loops already fed the global counters; the
    // root only journals the received tallies (single-threaded, in
    // receive order — the order the fold must replay).
    for (i, ch) in edges.iter_mut().enumerate() {
        let t_push = telemetry::active().then(Instant::now);
        match recv_agg_push(ch.as_mut(), round) {
            Ok((st, agg)) => {
                if let Some(t) = t_push {
                    telemetry::EDGE_PUSH_LATENCY.observe(t.elapsed());
                }
                span.shard(i, &st);
                shard_total.absorb(&st);
                parts.push(agg);
            }
            Err(_) => {
                dropped_edges += 1;
                telemetry::EDGE_SUBTREE_DROPS.inc();
                span.edge_drop(i);
            }
        }
    }
    let t0 = Instant::now();
    let merged = tree_merge(parts)?;
    stats.merge_time = t0.elapsed();
    telemetry::MERGE_NS.add_duration(stats.merge_time);
    span.merge(stats.merge_time);
    let served = shard_total.served;
    shard_total.fold_into(&mut stats);
    stats.dropped += dropped_edges;
    stats.participants = served + shard_total.dropped + dropped_edges;
    stats.mean_loss /= served.max(1) as f64;
    server.observe_round(&EbSignals {
        round,
        train_loss: stats.mean_loss,
        eval: None,
        layer_bytes: Vec::new(),
    });
    server.record_store_occupancy(&mut stats);
    span.store(stats.store_clients, stats.store_bytes);
    let rep = server.finish_round(merged.unwrap_or_else(|| RoundAgg::for_mode(agg_mode)));
    stats.agg_time += rep.finish_time;
    stats.binsum_layers = rep.binsum_layers;
    stats.exact_layers = rep.exact_layers + rep.mixed_layers;
    stats.dequant_passes = rep.dequant_passes;
    span.finish(rep.finish_time, stats.binsum_layers, stats.exact_layers, stats.dequant_passes);
    span.participants(stats.participants);
    span.end(&stats);
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::aggregate::FedAvg;
    use crate::tensor::{LayerGrad, ModelGrad};

    #[test]
    fn agg_push_roundtrips_and_rejects_trailers() {
        let mut fa = FedAvg::new();
        let grads = ModelGrad {
            layers: vec![LayerGrad::new(LayerMeta::other("l", 3), vec![1.0, -2.0, 0.5])],
        };
        fa.add(&grads, 2.0).unwrap();
        let st = ShardStats { served: 2, dropped: 1, loss_sum: 0.75, ..Default::default() };
        let wire = encode_agg_push(&st, &RoundAgg::Exact(fa));
        let (st2, agg2) = decode_agg_push(&wire).unwrap();
        assert_eq!(st, st2);
        assert!(agg2.approx_bytes() > 0);
        // Weighted mean of one contribution is the contribution.
        let (mean, _) = agg2.finish();
        assert_eq!(mean, vec![vec![1.0, -2.0, 0.5]]);
        // Trailing garbage and truncation both fail.
        let mut long = encode_agg_push(&st, &RoundAgg::Exact(FedAvg::new()));
        long.push(0);
        assert!(decode_agg_push(&long).is_err());
        assert!(decode_agg_push(&wire[..wire.len() - 1]).is_err());
    }
}
