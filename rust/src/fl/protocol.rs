//! Wire messages between clients and the parameter server, with a
//! dependency-free binary framing (length-prefixed, tagged). Carried by
//! any [`super::transport`] implementation.

use crate::compress::blob::{BlobReader, BlobWriter};

/// Client → server and server → client messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Client joins the federation.
    Hello { client_id: u32 },
    /// Server broadcasts global parameters (raw f32 tensors, flattened
    /// per layer) for a round.
    GlobalParams { round: u32, tensors: Vec<Vec<f32>> },
    /// Client uploads its compressed gradient payload for a round as one
    /// monolithic blob (the legacy whole-model path).
    Update { client_id: u32, round: u32, payload: Vec<u8>, train_loss: f32, n_samples: u32 },
    /// Client opens a frame-streamed update: exactly `n_layers`
    /// [`Msg::UpdateFrame`] messages follow on the same channel. Streaming
    /// lets the transport transmit layer `i` while layer `i+1` is still
    /// compressing (the paper's comm/comp overlap).
    UpdateBegin { client_id: u32, round: u32, n_layers: u32, train_loss: f32, n_samples: u32 },
    /// One self-delimiting per-layer frame
    /// ([`crate::compress::Frame::to_wire`] bytes) of a streamed update.
    UpdateFrame { client_id: u32, round: u32, frame: Vec<u8> },
    /// Client announces its predictor-state epoch before uploading:
    /// `rounds` absorbed so far and the state fingerprint (see
    /// [`crate::compress::StateEpoch`]). Sent after every
    /// `GlobalParams`; the server answers with [`Msg::StateResync`].
    StateCheck { client_id: u32, rounds: u32, fingerprint: u64 },
    /// Server's verdict on a [`Msg::StateCheck`]: `reset = true` means
    /// the epochs disagree (evicted state, dropout with lost state, cold
    /// rejoin) — **both** sides deterministically reset to the codec's
    /// round-1 path before the client compresses this round's update.
    StateResync { client_id: u32, reset: bool },
    /// Server opens a compressed downlink broadcast: exactly `n_layers`
    /// [`Msg::DeltaFrame`]s of the global-model **delta** vs the tracked
    /// reference follow. `reset = true` orders every synced client to
    /// cold-reset its downlink decoder first (a cold client joined the
    /// stream, so the encoder restarted — see
    /// [`crate::compress::downlink`]).
    DeltaBegin { round: u32, n_layers: u32, reset: bool },
    /// One self-delimiting per-layer frame of the round's global delta —
    /// encoded **once** on the server and fanned out to every
    /// participant as the same shared bytes.
    DeltaFrame { round: u32, frame: Vec<u8> },
    /// Downlink bootstrap for cold clients (first round, rejoin after a
    /// missed broadcast, poisoned view): the full reference model,
    /// bit-exact as the server tracks it.
    FullSync { round: u32, tensors: Vec<Vec<f32>> },
    /// Edge aggregator → root: one merged partial aggregate for the
    /// round, covering the edge's whole subtree. The payload is a
    /// [`crate::fl::aggregate::RoundAgg`] wire body prefixed by the
    /// subtree's `ShardStats` (see [`crate::fl::topology::edge`]).
    AggPush { round: u32, payload: Vec<u8> },
    /// Server → clients, before the round's params broadcast: this
    /// round's error-bound plan as a versioned `EBP` record
    /// ([`crate::compress::control::EbPlan::to_wire`]). Encoded once and
    /// fanned out as shared bytes; edge aggregators apply it to their
    /// own engines and relay it verbatim. Only sent when an `ebc=`
    /// controller other than `fixed` is active, so legacy round message
    /// sequences are unchanged.
    EbPlan { round: u32, plan: Vec<u8> },
    /// Server ends the session.
    Shutdown,
}

/// Write a `tag + round + tensors` message body (shared by
/// `GlobalParams` and `FullSync`).
fn write_tensors_msg(w: &mut BlobWriter, tag: u8, round: u32, tensors: &[Vec<f32>]) {
    w.put_u8(tag);
    w.put_u32(round);
    w.put_u32(tensors.len() as u32);
    for t in tensors {
        w.put_f32_slice(t);
    }
}

impl Msg {
    /// Encode a `GlobalParams` broadcast without owning the tensors: the
    /// raw broadcast path serializes **once** and fans the same bytes
    /// out to every channel (see [`super::transport::Channel::send_encoded`]).
    pub fn encode_global_params(round: u32, tensors: &[Vec<f32>]) -> Vec<u8> {
        let mut w = BlobWriter::new();
        write_tensors_msg(&mut w, 1, round, tensors);
        w.into_bytes()
    }

    /// Encode a `FullSync` bootstrap without owning the tensors
    /// (encode-once for every cold client of the round).
    pub fn encode_full_sync(round: u32, tensors: &[Vec<f32>]) -> Vec<u8> {
        let mut w = BlobWriter::new();
        write_tensors_msg(&mut w, 10, round, tensors);
        w.into_bytes()
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = BlobWriter::new();
        match self {
            Msg::Hello { client_id } => {
                w.put_u8(0);
                w.put_u32(*client_id);
            }
            Msg::GlobalParams { round, tensors } => {
                write_tensors_msg(&mut w, 1, *round, tensors);
            }
            Msg::Update { client_id, round, payload, train_loss, n_samples } => {
                w.put_u8(2);
                w.put_u32(*client_id);
                w.put_u32(*round);
                w.put_f32(*train_loss);
                w.put_u32(*n_samples);
                w.put_bytes(payload);
            }
            Msg::Shutdown => w.put_u8(3),
            Msg::UpdateBegin { client_id, round, n_layers, train_loss, n_samples } => {
                w.put_u8(4);
                w.put_u32(*client_id);
                w.put_u32(*round);
                w.put_u32(*n_layers);
                w.put_f32(*train_loss);
                w.put_u32(*n_samples);
            }
            Msg::UpdateFrame { client_id, round, frame } => {
                w.put_u8(5);
                w.put_u32(*client_id);
                w.put_u32(*round);
                w.put_bytes(frame);
            }
            Msg::StateCheck { client_id, rounds, fingerprint } => {
                w.put_u8(6);
                w.put_u32(*client_id);
                w.put_u32(*rounds);
                w.put_u64(*fingerprint);
            }
            Msg::StateResync { client_id, reset } => {
                w.put_u8(7);
                w.put_u32(*client_id);
                w.put_u8(u8::from(*reset));
            }
            Msg::DeltaBegin { round, n_layers, reset } => {
                w.put_u8(8);
                w.put_u32(*round);
                w.put_u32(*n_layers);
                w.put_u8(u8::from(*reset));
            }
            Msg::DeltaFrame { round, frame } => {
                w.put_u8(9);
                w.put_u32(*round);
                w.put_bytes(frame);
            }
            Msg::FullSync { round, tensors } => {
                write_tensors_msg(&mut w, 10, *round, tensors);
            }
            Msg::AggPush { round, payload } => {
                w.put_u8(11);
                w.put_u32(*round);
                w.put_bytes(payload);
            }
            Msg::EbPlan { round, plan } => {
                w.put_u8(12);
                w.put_u32(*round);
                w.put_bytes(plan);
            }
        }
        w.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> crate::Result<Msg> {
        let mut r = BlobReader::new(buf);
        Ok(match r.get_u8()? {
            0 => Msg::Hello { client_id: r.get_u32()? },
            1 => {
                let round = r.get_u32()?;
                let n = r.get_u32()? as usize;
                let mut tensors = Vec::with_capacity(n);
                for _ in 0..n {
                    tensors.push(r.get_f32_vec()?);
                }
                Msg::GlobalParams { round, tensors }
            }
            2 => {
                let client_id = r.get_u32()?;
                let round = r.get_u32()?;
                let train_loss = r.get_f32()?;
                let n_samples = r.get_u32()?;
                let payload = r.get_bytes()?.to_vec();
                Msg::Update { client_id, round, payload, train_loss, n_samples }
            }
            3 => Msg::Shutdown,
            4 => {
                let client_id = r.get_u32()?;
                let round = r.get_u32()?;
                let n_layers = r.get_u32()?;
                let train_loss = r.get_f32()?;
                let n_samples = r.get_u32()?;
                Msg::UpdateBegin { client_id, round, n_layers, train_loss, n_samples }
            }
            5 => {
                let client_id = r.get_u32()?;
                let round = r.get_u32()?;
                let frame = r.get_bytes()?.to_vec();
                Msg::UpdateFrame { client_id, round, frame }
            }
            6 => {
                let client_id = r.get_u32()?;
                let rounds = r.get_u32()?;
                let fingerprint = r.get_u64()?;
                Msg::StateCheck { client_id, rounds, fingerprint }
            }
            7 => {
                let client_id = r.get_u32()?;
                let reset = match r.get_u8()? {
                    0 => false,
                    1 => true,
                    b => anyhow::bail!("bad StateResync flag {b}"),
                };
                Msg::StateResync { client_id, reset }
            }
            8 => {
                let round = r.get_u32()?;
                let n_layers = r.get_u32()?;
                let reset = match r.get_u8()? {
                    0 => false,
                    1 => true,
                    b => anyhow::bail!("bad DeltaBegin reset flag {b}"),
                };
                Msg::DeltaBegin { round, n_layers, reset }
            }
            9 => {
                let round = r.get_u32()?;
                let frame = r.get_bytes()?.to_vec();
                Msg::DeltaFrame { round, frame }
            }
            10 => {
                let round = r.get_u32()?;
                let n = r.get_u32()? as usize;
                let mut tensors = Vec::with_capacity(n);
                for _ in 0..n {
                    tensors.push(r.get_f32_vec()?);
                }
                Msg::FullSync { round, tensors }
            }
            11 => {
                let round = r.get_u32()?;
                let payload = r.get_bytes()?.to_vec();
                Msg::AggPush { round, payload }
            }
            12 => {
                let round = r.get_u32()?;
                let plan = r.get_bytes()?.to_vec();
                Msg::EbPlan { round, plan }
            }
            t => anyhow::bail!("unknown message tag {t}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The wire tag of every variant. The exhaustive `match` is the
    /// point: adding a `Msg` variant fails compilation here until the
    /// sample list below (and therefore the roundtrip suite) grows too.
    fn wire_tag(m: &Msg) -> u8 {
        match m {
            Msg::Hello { .. } => 0,
            Msg::GlobalParams { .. } => 1,
            Msg::Update { .. } => 2,
            Msg::Shutdown => 3,
            Msg::UpdateBegin { .. } => 4,
            Msg::UpdateFrame { .. } => 5,
            Msg::StateCheck { .. } => 6,
            Msg::StateResync { .. } => 7,
            Msg::DeltaBegin { .. } => 8,
            Msg::DeltaFrame { .. } => 9,
            Msg::FullSync { .. } => 10,
            Msg::AggPush { .. } => 11,
            Msg::EbPlan { .. } => 12,
        }
    }
    const N_VARIANTS: usize = 13;

    fn sample_of_every_variant() -> Vec<Msg> {
        vec![
            Msg::Hello { client_id: 3 },
            Msg::GlobalParams { round: 7, tensors: vec![vec![1.0, -2.0], vec![0.5]] },
            Msg::Update {
                client_id: 1,
                round: 7,
                payload: vec![1, 2, 3, 255],
                train_loss: 0.25,
                n_samples: 512,
            },
            Msg::UpdateBegin {
                client_id: 2,
                round: 9,
                n_layers: 4,
                train_loss: 1.5,
                n_samples: 64,
            },
            Msg::UpdateFrame { client_id: 2, round: 9, frame: vec![0, 0, 0, 0, 1, 0, 0, 0, 42] },
            Msg::StateCheck { client_id: 4, rounds: 12, fingerprint: 0xDEAD_BEEF_CAFE_F00D },
            Msg::StateResync { client_id: 4, reset: true },
            Msg::StateResync { client_id: 5, reset: false },
            Msg::DeltaBegin { round: 3, n_layers: 9, reset: true },
            Msg::DeltaBegin { round: 4, n_layers: 1, reset: false },
            Msg::DeltaFrame { round: 3, frame: vec![2, 0, 0, 0, 1, 0, 0, 0, 7] },
            Msg::FullSync { round: 5, tensors: vec![vec![0.5, -0.25], vec![], vec![3.0]] },
            Msg::AggPush { round: 6, payload: vec![1, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0] },
            Msg::EbPlan { round: 8, plan: vec![1, 10, 215, 35, 60, 0] },
            Msg::Shutdown,
        ]
    }

    #[test]
    fn roundtrip_is_exhaustive_over_variants() {
        let msgs = sample_of_every_variant();
        let mut seen = std::collections::HashSet::new();
        for m in msgs {
            seen.insert(wire_tag(&m));
            assert_eq!(Msg::decode(&m.encode()).unwrap(), m);
        }
        // Every variant (every wire tag) appears in the sample list.
        assert_eq!(seen.len(), N_VARIANTS, "sample list missing a variant");
        assert_eq!(seen, (0..N_VARIANTS as u8).collect::<std::collections::HashSet<u8>>());
    }

    #[test]
    fn encode_once_helpers_match_owned_encode() {
        let tensors = vec![vec![1.0f32, -2.0], vec![0.5]];
        assert_eq!(
            Msg::encode_global_params(7, &tensors),
            Msg::GlobalParams { round: 7, tensors: tensors.clone() }.encode()
        );
        assert_eq!(
            Msg::encode_full_sync(9, &tensors),
            Msg::FullSync { round: 9, tensors }.encode()
        );
    }

    #[test]
    fn garbage_errors_never_panics() {
        // Unknown tag: the first byte past the last known variant.
        assert!(Msg::decode(&[N_VARIANTS as u8]).is_err());
        assert!(Msg::decode(&[0xFF]).is_err());
        assert!(Msg::decode(&[]).is_err());
        // Truncated bodies for every known tag.
        for tag in 0..N_VARIANTS as u8 {
            if tag == 3 {
                continue; // Shutdown has no body
            }
            assert!(Msg::decode(&[tag]).is_err(), "tag {tag} with empty body");
            assert!(Msg::decode(&[tag, 0]).is_err(), "tag {tag} truncated");
        }
        // Bad boolean flags are rejected, not coerced.
        let mut resync = Msg::StateResync { client_id: 1, reset: true }.encode();
        *resync.last_mut().unwrap() = 2;
        assert!(Msg::decode(&resync).is_err());
        let mut begin = Msg::DeltaBegin { round: 1, n_layers: 2, reset: true }.encode();
        *begin.last_mut().unwrap() = 7;
        assert!(Msg::decode(&begin).is_err());
    }
}
