//! Wire messages between clients and the parameter server, with a
//! dependency-free binary framing (length-prefixed, tagged). Carried by
//! any [`super::transport`] implementation.

use crate::compress::blob::{BlobReader, BlobWriter};

/// Client → server and server → client messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Client joins the federation.
    Hello { client_id: u32 },
    /// Server broadcasts global parameters (raw f32 tensors, flattened
    /// per layer) for a round.
    GlobalParams { round: u32, tensors: Vec<Vec<f32>> },
    /// Client uploads its compressed gradient payload for a round as one
    /// monolithic blob (the legacy whole-model path).
    Update { client_id: u32, round: u32, payload: Vec<u8>, train_loss: f32, n_samples: u32 },
    /// Client opens a frame-streamed update: exactly `n_layers`
    /// [`Msg::UpdateFrame`] messages follow on the same channel. Streaming
    /// lets the transport transmit layer `i` while layer `i+1` is still
    /// compressing (the paper's comm/comp overlap).
    UpdateBegin { client_id: u32, round: u32, n_layers: u32, train_loss: f32, n_samples: u32 },
    /// One self-delimiting per-layer frame
    /// ([`crate::compress::Frame::to_wire`] bytes) of a streamed update.
    UpdateFrame { client_id: u32, round: u32, frame: Vec<u8> },
    /// Client announces its predictor-state epoch before uploading:
    /// `rounds` absorbed so far and the state fingerprint (see
    /// [`crate::compress::StateEpoch`]). Sent after every
    /// `GlobalParams`; the server answers with [`Msg::StateResync`].
    StateCheck { client_id: u32, rounds: u32, fingerprint: u64 },
    /// Server's verdict on a [`Msg::StateCheck`]: `reset = true` means
    /// the epochs disagree (evicted state, dropout with lost state, cold
    /// rejoin) — **both** sides deterministically reset to the codec's
    /// round-1 path before the client compresses this round's update.
    StateResync { client_id: u32, reset: bool },
    /// Server ends the session.
    Shutdown,
}

impl Msg {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = BlobWriter::new();
        match self {
            Msg::Hello { client_id } => {
                w.put_u8(0);
                w.put_u32(*client_id);
            }
            Msg::GlobalParams { round, tensors } => {
                w.put_u8(1);
                w.put_u32(*round);
                w.put_u32(tensors.len() as u32);
                for t in tensors {
                    w.put_f32_slice(t);
                }
            }
            Msg::Update { client_id, round, payload, train_loss, n_samples } => {
                w.put_u8(2);
                w.put_u32(*client_id);
                w.put_u32(*round);
                w.put_f32(*train_loss);
                w.put_u32(*n_samples);
                w.put_bytes(payload);
            }
            Msg::Shutdown => w.put_u8(3),
            Msg::UpdateBegin { client_id, round, n_layers, train_loss, n_samples } => {
                w.put_u8(4);
                w.put_u32(*client_id);
                w.put_u32(*round);
                w.put_u32(*n_layers);
                w.put_f32(*train_loss);
                w.put_u32(*n_samples);
            }
            Msg::UpdateFrame { client_id, round, frame } => {
                w.put_u8(5);
                w.put_u32(*client_id);
                w.put_u32(*round);
                w.put_bytes(frame);
            }
            Msg::StateCheck { client_id, rounds, fingerprint } => {
                w.put_u8(6);
                w.put_u32(*client_id);
                w.put_u32(*rounds);
                w.put_u64(*fingerprint);
            }
            Msg::StateResync { client_id, reset } => {
                w.put_u8(7);
                w.put_u32(*client_id);
                w.put_u8(u8::from(*reset));
            }
        }
        w.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> crate::Result<Msg> {
        let mut r = BlobReader::new(buf);
        Ok(match r.get_u8()? {
            0 => Msg::Hello { client_id: r.get_u32()? },
            1 => {
                let round = r.get_u32()?;
                let n = r.get_u32()? as usize;
                let mut tensors = Vec::with_capacity(n);
                for _ in 0..n {
                    tensors.push(r.get_f32_vec()?);
                }
                Msg::GlobalParams { round, tensors }
            }
            2 => {
                let client_id = r.get_u32()?;
                let round = r.get_u32()?;
                let train_loss = r.get_f32()?;
                let n_samples = r.get_u32()?;
                let payload = r.get_bytes()?.to_vec();
                Msg::Update { client_id, round, payload, train_loss, n_samples }
            }
            3 => Msg::Shutdown,
            4 => {
                let client_id = r.get_u32()?;
                let round = r.get_u32()?;
                let n_layers = r.get_u32()?;
                let train_loss = r.get_f32()?;
                let n_samples = r.get_u32()?;
                Msg::UpdateBegin { client_id, round, n_layers, train_loss, n_samples }
            }
            5 => {
                let client_id = r.get_u32()?;
                let round = r.get_u32()?;
                let frame = r.get_bytes()?.to_vec();
                Msg::UpdateFrame { client_id, round, frame }
            }
            6 => {
                let client_id = r.get_u32()?;
                let rounds = r.get_u32()?;
                let fingerprint = r.get_u64()?;
                Msg::StateCheck { client_id, rounds, fingerprint }
            }
            7 => {
                let client_id = r.get_u32()?;
                let reset = match r.get_u8()? {
                    0 => false,
                    1 => true,
                    b => anyhow::bail!("bad StateResync flag {b}"),
                };
                Msg::StateResync { client_id, reset }
            }
            t => anyhow::bail!("unknown message tag {t}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_variants() {
        let msgs = [
            Msg::Hello { client_id: 3 },
            Msg::GlobalParams { round: 7, tensors: vec![vec![1.0, -2.0], vec![0.5]] },
            Msg::Update {
                client_id: 1,
                round: 7,
                payload: vec![1, 2, 3, 255],
                train_loss: 0.25,
                n_samples: 512,
            },
            Msg::UpdateBegin {
                client_id: 2,
                round: 9,
                n_layers: 4,
                train_loss: 1.5,
                n_samples: 64,
            },
            Msg::UpdateFrame { client_id: 2, round: 9, frame: vec![0, 0, 0, 0, 1, 0, 0, 0, 42] },
            Msg::StateCheck { client_id: 4, rounds: 12, fingerprint: 0xDEAD_BEEF_CAFE_F00D },
            Msg::StateResync { client_id: 4, reset: true },
            Msg::StateResync { client_id: 5, reset: false },
            Msg::Shutdown,
        ];
        for m in msgs {
            assert_eq!(Msg::decode(&m.encode()).unwrap(), m);
        }
    }

    #[test]
    fn garbage_errors() {
        assert!(Msg::decode(&[9]).is_err());
        assert!(Msg::decode(&[]).is_err());
        assert!(Msg::decode(&[1, 0]).is_err());
    }
}
