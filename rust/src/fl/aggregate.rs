//! FedAvg aggregation (McMahan et al. 2017): weighted averaging of client
//! gradients by sample count, then a global SGD step.
//!
//! Two interchangeable accumulators sit behind [`RoundAgg`]: the classic
//! dense [`FedAvg`] (`agg=exact`) and the compressed-domain
//! [`BinAggregator`] (`agg=binsum`, see [`crate::compress::agg`]). Both
//! accumulate in f64 — f32 running sums lose ulps per contribution and
//! visibly drift at 10k-client scale (see the precision test below) —
//! and both *drop* malformed contributions with an `Err` instead of
//! panicking, so a corrupt or misbehaving client cannot kill the server.

use crate::compress::agg::{AggReport, BinAggregator};
use crate::tensor::ModelGrad;

/// Weighted-average accumulator over reconstructed client gradients.
#[derive(Default)]
pub struct FedAvg {
    sum: Vec<Vec<f64>>,
    total_weight: f64,
}

impl FedAvg {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one client's gradient with the given weight (its sample
    /// count). A shape mismatch against the accumulated model is an
    /// `Err` with the sums untouched — the contribution is dropped
    /// whole, like `absorb_payload` drops failed decodes.
    pub fn add(&mut self, grad: &ModelGrad, weight: f64) -> crate::Result<()> {
        anyhow::ensure!(weight.is_finite() && weight >= 0.0, "fedavg: bad weight {weight}");
        if !self.sum.is_empty() {
            anyhow::ensure!(
                self.sum.len() == grad.layers.len(),
                "fedavg: {} layers, expected {}",
                grad.layers.len(),
                self.sum.len()
            );
            for (i, (acc, layer)) in self.sum.iter().zip(&grad.layers).enumerate() {
                anyhow::ensure!(
                    acc.len() == layer.data.len(),
                    "fedavg: layer {i} has {} elements, expected {}",
                    layer.data.len(),
                    acc.len()
                );
            }
        } else {
            self.sum = grad.layers.iter().map(|l| vec![0.0f64; l.data.len()]).collect();
        }
        for (acc, layer) in self.sum.iter_mut().zip(&grad.layers) {
            for (a, &g) in acc.iter_mut().zip(&layer.data) {
                *a += weight * g as f64;
            }
        }
        self.total_weight += weight;
        Ok(())
    }

    /// Number of contributions so far (weight mass).
    pub fn weight(&self) -> f64 {
        self.total_weight
    }

    /// Finish: produce the weighted mean gradient per layer.
    pub fn mean(self) -> Vec<Vec<f32>> {
        let inv = if self.total_weight > 0.0 { 1.0 / self.total_weight } else { 0.0 };
        self.sum
            .into_iter()
            .map(|t| t.into_iter().map(|v| (v * inv) as f32).collect())
            .collect()
    }
}

/// Which aggregation route a run uses (`RunConfig.agg`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AggMode {
    /// Decode every payload to f32 and run dense FedAvg.
    #[default]
    Exact,
    /// Aggregate fedgec frames in the integer-bin domain, dequantizing
    /// once per layer per round; ineligible layers fall back per layer.
    Binsum,
}

impl AggMode {
    pub const ALL: [AggMode; 2] = [AggMode::Exact, AggMode::Binsum];

    pub fn name(&self) -> &'static str {
        match self {
            AggMode::Exact => "exact",
            AggMode::Binsum => "binsum",
        }
    }

    pub fn from_name(s: &str) -> Option<AggMode> {
        match s {
            "exact" => Some(AggMode::Exact),
            "binsum" => Some(AggMode::Binsum),
            _ => None,
        }
    }
}

/// One round's aggregator, either route. The server constructs it
/// (`Server::new_round_agg`), `absorb_payload` feeds it, and
/// `finish_round` consumes it.
pub enum RoundAgg {
    Exact(FedAvg),
    Bin(BinAggregator),
}

impl RoundAgg {
    pub fn for_mode(mode: AggMode) -> RoundAgg {
        match mode {
            AggMode::Exact => RoundAgg::Exact(FedAvg::new()),
            AggMode::Binsum => RoundAgg::Bin(BinAggregator::new()),
        }
    }

    /// Weight mass absorbed so far.
    pub fn weight(&self) -> f64 {
        match self {
            RoundAgg::Exact(fa) => fa.weight(),
            RoundAgg::Bin(ba) => ba.weight(),
        }
    }

    /// Finish the round: weighted mean per layer plus the route report
    /// (a wholly-exact round reports every layer on the exact route).
    pub fn finish(self) -> (Vec<Vec<f32>>, AggReport) {
        match self {
            RoundAgg::Exact(fa) => {
                let mean = fa.mean();
                let report = AggReport::all_exact(mean.len());
                (mean, report)
            }
            RoundAgg::Bin(ba) => ba.finish(),
        }
    }
}

/// Apply the aggregated gradient: `θ ← θ − lr·ḡ` per layer.
pub fn apply_update(params: &mut [Vec<f32>], mean_grad: &[Vec<f32>], lr: f32) {
    assert_eq!(params.len(), mean_grad.len());
    for (p, g) in params.iter_mut().zip(mean_grad) {
        assert_eq!(p.len(), g.len());
        for (w, &d) in p.iter_mut().zip(g) {
            *w -= lr * d;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{LayerGrad, LayerMeta};

    fn grad(vals: &[f32]) -> ModelGrad {
        ModelGrad {
            layers: vec![LayerGrad::new(LayerMeta::other("x", vals.len()), vals.to_vec())],
        }
    }

    #[test]
    fn weighted_mean() {
        let mut agg = FedAvg::new();
        agg.add(&grad(&[1.0, 0.0]), 1.0).unwrap();
        agg.add(&grad(&[4.0, 3.0]), 3.0).unwrap();
        let m = agg.mean();
        assert_eq!(m[0], vec![3.25, 2.25]);
    }

    #[test]
    fn apply_update_sgd() {
        let mut params = vec![vec![1.0f32, 2.0]];
        apply_update(&mut params, &[vec![10.0, -10.0]], 0.1);
        assert_eq!(params[0], vec![0.0, 3.0]);
    }

    #[test]
    fn empty_aggregator_mean_is_empty() {
        let agg = FedAvg::new();
        assert!(agg.mean().is_empty());
    }

    #[test]
    fn mismatched_contribution_is_err_and_dropped() {
        let mut agg = FedAvg::new();
        agg.add(&grad(&[1.0, 1.0]), 1.0).unwrap();
        // Layer-count mismatch.
        let empty = ModelGrad::default();
        assert!(agg.add(&empty, 1.0).is_err());
        // Element-count mismatch.
        assert!(agg.add(&grad(&[1.0, 1.0, 1.0]), 1.0).is_err());
        // Garbage weight.
        assert!(agg.add(&grad(&[1.0, 1.0]), f64::NAN).is_err());
        // Sums untouched by the rejected contributions.
        assert_eq!(agg.weight(), 1.0);
        assert_eq!(agg.mean()[0], vec![1.0, 1.0]);
    }

    /// The satellite's 10k-contribution precision gate: f64 accumulators
    /// must track an explicit f64 reference exactly, where the old f32
    /// running sums drift by many ulps (adding 1e-4-scale contributions
    /// onto a sum of ~1e4 loses low bits every add).
    #[test]
    fn ten_thousand_contributions_match_f64_reference() {
        let n = 64;
        let mut agg = FedAvg::new();
        let mut ref_sum = vec![0.0f64; n];
        let mut ref_w = 0.0f64;
        for k in 0..10_000u32 {
            // Deterministic, sign-varied, scale-varied contributions.
            let vals: Vec<f32> = (0..n)
                .map(|i| {
                    let s = if (k + i as u32) % 2 == 0 { 1.0 } else { -1.0 };
                    s * (1.0 + (k % 97) as f32 * 1e-4) * (0.1 + i as f32 * 1e-3)
                })
                .collect();
            let w = 1.0 + (k % 7) as f64;
            for (r, &v) in ref_sum.iter_mut().zip(&vals) {
                *r += w * v as f64;
            }
            ref_w += w;
            agg.add(&grad(&vals), w).unwrap();
        }
        let mean = agg.mean();
        for (got, r) in mean[0].iter().zip(&ref_sum) {
            let want = (r / ref_w) as f32;
            assert_eq!(*got, want, "f64 accumulation must match the reference bit-for-bit");
        }
    }

    #[test]
    fn round_agg_dispatches_both_modes() {
        assert_eq!(AggMode::from_name("exact"), Some(AggMode::Exact));
        assert_eq!(AggMode::from_name("binsum"), Some(AggMode::Binsum));
        assert_eq!(AggMode::from_name("bogus"), None);
        for mode in AggMode::ALL {
            assert_eq!(AggMode::from_name(mode.name()), Some(mode));
        }
        let mut agg = RoundAgg::for_mode(AggMode::Exact);
        if let RoundAgg::Exact(fa) = &mut agg {
            fa.add(&grad(&[2.0]), 2.0).unwrap();
        }
        assert_eq!(agg.weight(), 2.0);
        let (mean, report) = agg.finish();
        assert_eq!(mean[0], vec![2.0]);
        assert_eq!(report.exact_layers, 1);
        assert_eq!(report.binsum_layers, 0);
        let (mean, report) = RoundAgg::for_mode(AggMode::Binsum).finish();
        assert!(mean.is_empty());
        assert_eq!(report.dequant_passes, 0);
    }
}
