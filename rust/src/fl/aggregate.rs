//! FedAvg aggregation (McMahan et al. 2017): weighted averaging of client
//! gradients by sample count, then a global SGD step.

use crate::tensor::ModelGrad;

/// Weighted-average accumulator over reconstructed client gradients.
#[derive(Default)]
pub struct FedAvg {
    sum: Vec<Vec<f32>>,
    total_weight: f64,
}

impl FedAvg {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one client's gradient with the given weight (its sample count).
    pub fn add(&mut self, grad: &ModelGrad, weight: f64) {
        if self.sum.is_empty() {
            self.sum = grad.layers.iter().map(|l| vec![0.0f32; l.data.len()]).collect();
        }
        assert_eq!(self.sum.len(), grad.layers.len(), "layer count changed");
        for (acc, layer) in self.sum.iter_mut().zip(&grad.layers) {
            assert_eq!(acc.len(), layer.data.len());
            let w = weight as f32;
            for (a, &g) in acc.iter_mut().zip(&layer.data) {
                *a += w * g;
            }
        }
        self.total_weight += weight;
    }

    /// Number of contributions so far (weight mass).
    pub fn weight(&self) -> f64 {
        self.total_weight
    }

    /// Finish: produce the weighted mean gradient per layer.
    pub fn mean(mut self) -> Vec<Vec<f32>> {
        let inv = if self.total_weight > 0.0 { 1.0 / self.total_weight as f32 } else { 0.0 };
        for t in &mut self.sum {
            for v in t.iter_mut() {
                *v *= inv;
            }
        }
        self.sum
    }
}

/// Apply the aggregated gradient: `θ ← θ − lr·ḡ` per layer.
pub fn apply_update(params: &mut [Vec<f32>], mean_grad: &[Vec<f32>], lr: f32) {
    assert_eq!(params.len(), mean_grad.len());
    for (p, g) in params.iter_mut().zip(mean_grad) {
        assert_eq!(p.len(), g.len());
        for (w, &d) in p.iter_mut().zip(g) {
            *w -= lr * d;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{LayerGrad, LayerMeta};

    fn grad(vals: &[f32]) -> ModelGrad {
        ModelGrad {
            layers: vec![LayerGrad::new(LayerMeta::other("x", vals.len()), vals.to_vec())],
        }
    }

    #[test]
    fn weighted_mean() {
        let mut agg = FedAvg::new();
        agg.add(&grad(&[1.0, 0.0]), 1.0);
        agg.add(&grad(&[4.0, 3.0]), 3.0);
        let m = agg.mean();
        assert_eq!(m[0], vec![3.25, 2.25]);
    }

    #[test]
    fn apply_update_sgd() {
        let mut params = vec![vec![1.0f32, 2.0]];
        apply_update(&mut params, &[vec![10.0, -10.0]], 0.1);
        assert_eq!(params[0], vec![0.0, 3.0]);
    }

    #[test]
    fn empty_aggregator_mean_is_empty() {
        let agg = FedAvg::new();
        assert!(agg.mean().is_empty());
    }
}
