//! FedAvg aggregation (McMahan et al. 2017): weighted averaging of client
//! gradients by sample count, then a global SGD step.
//!
//! Two interchangeable accumulators sit behind [`RoundAgg`]: the classic
//! dense [`FedAvg`] (`agg=exact`) and the compressed-domain
//! [`BinAggregator`] (`agg=binsum`, see [`crate::compress::agg`]). Both
//! accumulate in f64 — f32 running sums lose ulps per contribution and
//! visibly drift at 10k-client scale (see the precision test below) —
//! and both *drop* malformed contributions with an `Err` instead of
//! panicking, so a corrupt or misbehaving client cannot kill the server.

use crate::compress::agg::{AggReport, BinAggregator};
use crate::compress::blob::{BlobReader, BlobWriter};
use crate::tensor::ModelGrad;

/// Weighted-average accumulator over reconstructed client gradients.
#[derive(Default)]
pub struct FedAvg {
    sum: Vec<Vec<f64>>,
    total_weight: f64,
}

impl FedAvg {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one client's gradient with the given weight (its sample
    /// count). A shape mismatch against the accumulated model is an
    /// `Err` with the sums untouched — the contribution is dropped
    /// whole, like `absorb_payload` drops failed decodes.
    pub fn add(&mut self, grad: &ModelGrad, weight: f64) -> crate::Result<()> {
        anyhow::ensure!(weight.is_finite() && weight >= 0.0, "fedavg: bad weight {weight}");
        if !self.sum.is_empty() {
            anyhow::ensure!(
                self.sum.len() == grad.layers.len(),
                "fedavg: {} layers, expected {}",
                grad.layers.len(),
                self.sum.len()
            );
            for (i, (acc, layer)) in self.sum.iter().zip(&grad.layers).enumerate() {
                anyhow::ensure!(
                    acc.len() == layer.data.len(),
                    "fedavg: layer {i} has {} elements, expected {}",
                    layer.data.len(),
                    acc.len()
                );
            }
        } else {
            self.sum = grad.layers.iter().map(|l| vec![0.0f64; l.data.len()]).collect();
        }
        for (acc, layer) in self.sum.iter_mut().zip(&grad.layers) {
            for (a, &g) in acc.iter_mut().zip(&layer.data) {
                *a += weight * g as f64;
            }
        }
        self.total_weight += weight;
        Ok(())
    }

    /// Number of contributions so far (weight mass).
    pub fn weight(&self) -> f64 {
        self.total_weight
    }

    /// Finish: produce the weighted mean gradient per layer.
    pub fn mean(self) -> Vec<Vec<f32>> {
        let inv = if self.total_weight > 0.0 { 1.0 / self.total_weight } else { 0.0 };
        self.sum
            .into_iter()
            .map(|t| t.into_iter().map(|v| (v * inv) as f32).collect())
            .collect()
    }

    /// Merge another accumulator's sums (the dense shard exchange).
    /// Either side may be empty; populated sides must agree on shape.
    pub fn merge(&mut self, other: FedAvg) -> crate::Result<()> {
        if other.sum.is_empty() {
            self.total_weight += other.total_weight;
            return Ok(());
        }
        if self.sum.is_empty() {
            self.sum = other.sum;
            self.total_weight += other.total_weight;
            return Ok(());
        }
        anyhow::ensure!(
            self.sum.len() == other.sum.len(),
            "fedavg merge: {} layers vs {}",
            other.sum.len(),
            self.sum.len()
        );
        for (i, (acc, o)) in self.sum.iter().zip(&other.sum).enumerate() {
            anyhow::ensure!(
                acc.len() == o.len(),
                "fedavg merge: layer {i} has {} elements vs {}",
                o.len(),
                acc.len()
            );
        }
        for (acc, o) in self.sum.iter_mut().zip(&other.sum) {
            for (a, &b) in acc.iter_mut().zip(o) {
                *a += b;
            }
        }
        self.total_weight += other.total_weight;
        Ok(())
    }

    /// Heap bytes held by the f64 sums (peak-memory proxy).
    pub fn approx_bytes(&self) -> usize {
        self.sum.iter().map(|l| l.len() * 8).sum()
    }

    /// Serialize the partial sums for the edge→root exchange.
    pub fn write_wire(&self, w: &mut BlobWriter) {
        w.put_f64(self.total_weight);
        w.put_u32(self.sum.len() as u32);
        for layer in &self.sum {
            w.put_f64_slice(layer);
        }
    }

    /// Deserialize a pushed partial aggregate (bounds-checked; shape
    /// errors surface at [`FedAvg::merge`] time).
    pub fn read_wire(r: &mut BlobReader) -> crate::Result<FedAvg> {
        let total_weight = r.get_f64()?;
        anyhow::ensure!(
            total_weight.is_finite() && total_weight >= 0.0,
            "fedavg wire: bad total weight {total_weight}"
        );
        let n = r.get_u32()? as usize;
        anyhow::ensure!(n <= 65_536, "fedavg wire: implausible layer count {n}");
        let mut sum = Vec::with_capacity(n);
        for _ in 0..n {
            sum.push(r.get_f64_vec()?);
        }
        Ok(FedAvg { sum, total_weight })
    }
}

/// Which aggregation route a run uses (`RunConfig.agg`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AggMode {
    /// Decode every payload to f32 and run dense FedAvg.
    #[default]
    Exact,
    /// Aggregate fedgec frames in the integer-bin domain, dequantizing
    /// once per layer per round; ineligible layers fall back per layer.
    Binsum,
}

impl AggMode {
    pub const ALL: [AggMode; 2] = [AggMode::Exact, AggMode::Binsum];

    pub fn name(&self) -> &'static str {
        match self {
            AggMode::Exact => "exact",
            AggMode::Binsum => "binsum",
        }
    }

    pub fn from_name(s: &str) -> Option<AggMode> {
        match s {
            "exact" => Some(AggMode::Exact),
            "binsum" => Some(AggMode::Binsum),
            _ => None,
        }
    }
}

/// One round's aggregator, either route. The server constructs it
/// (`Server::new_round_agg`), `absorb_payload` feeds it, and
/// `finish_round` consumes it.
pub enum RoundAgg {
    Exact(FedAvg),
    Bin(BinAggregator),
}

impl RoundAgg {
    pub fn for_mode(mode: AggMode) -> RoundAgg {
        match mode {
            AggMode::Exact => RoundAgg::Exact(FedAvg::new()),
            AggMode::Binsum => RoundAgg::Bin(BinAggregator::new()),
        }
    }

    /// Weight mass absorbed so far.
    pub fn weight(&self) -> f64 {
        match self {
            RoundAgg::Exact(fa) => fa.weight(),
            RoundAgg::Bin(ba) => ba.weight(),
        }
    }

    /// Finish the round: weighted mean per layer plus the route report
    /// (a wholly-exact round reports every layer on the exact route).
    pub fn finish(self) -> (Vec<Vec<f32>>, AggReport) {
        match self {
            RoundAgg::Exact(fa) => {
                let mean = fa.mean();
                let report = AggReport::all_exact(mean.len());
                (mean, report)
            }
            RoundAgg::Bin(ba) => ba.finish(),
        }
    }

    /// Merge another shard's partial aggregate into this one — the
    /// tree-merge step of the sharded runner and edge tier. Both sides
    /// must ride the same route: the route is a fleet-wide config
    /// (`RunConfig.agg`), so a mismatch is a wiring bug, not data.
    pub fn merge(&mut self, other: RoundAgg) -> crate::Result<()> {
        match (self, other) {
            (RoundAgg::Exact(a), RoundAgg::Exact(b)) => a.merge(b),
            (RoundAgg::Bin(a), RoundAgg::Bin(b)) => a.merge(b),
            _ => anyhow::bail!("round-agg merge: exact and binsum shards cannot mix"),
        }
    }

    /// Heap bytes held by the accumulators (peak-memory proxy for the
    /// topology benches: aggregate memory is O(shards·model), never
    /// O(clients)).
    pub fn approx_bytes(&self) -> usize {
        match self {
            RoundAgg::Exact(fa) => fa.approx_bytes(),
            RoundAgg::Bin(ba) => ba.approx_bytes(),
        }
    }

    /// Serialize for `Msg::AggPush` (route tag + route-specific body).
    pub fn write_wire(&self, w: &mut BlobWriter) {
        match self {
            RoundAgg::Exact(fa) => {
                w.put_u8(0);
                fa.write_wire(w);
            }
            RoundAgg::Bin(ba) => {
                w.put_u8(1);
                ba.write_wire(w);
            }
        }
    }

    /// Deserialize an `AggPush` body (the root validates the route
    /// against its own `AggMode` at merge time).
    pub fn read_wire(r: &mut BlobReader) -> crate::Result<RoundAgg> {
        match r.get_u8()? {
            0 => Ok(RoundAgg::Exact(FedAvg::read_wire(r)?)),
            1 => Ok(RoundAgg::Bin(BinAggregator::read_wire(r)?)),
            t => anyhow::bail!("round-agg wire: unknown route tag {t}"),
        }
    }
}

/// Apply the aggregated gradient: `θ ← θ − lr·ḡ` per layer.
pub fn apply_update(params: &mut [Vec<f32>], mean_grad: &[Vec<f32>], lr: f32) {
    assert_eq!(params.len(), mean_grad.len());
    for (p, g) in params.iter_mut().zip(mean_grad) {
        assert_eq!(p.len(), g.len());
        for (w, &d) in p.iter_mut().zip(g) {
            *w -= lr * d;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{LayerGrad, LayerMeta};

    fn grad(vals: &[f32]) -> ModelGrad {
        ModelGrad {
            layers: vec![LayerGrad::new(LayerMeta::other("x", vals.len()), vals.to_vec())],
        }
    }

    #[test]
    fn weighted_mean() {
        let mut agg = FedAvg::new();
        agg.add(&grad(&[1.0, 0.0]), 1.0).unwrap();
        agg.add(&grad(&[4.0, 3.0]), 3.0).unwrap();
        let m = agg.mean();
        assert_eq!(m[0], vec![3.25, 2.25]);
    }

    #[test]
    fn apply_update_sgd() {
        let mut params = vec![vec![1.0f32, 2.0]];
        apply_update(&mut params, &[vec![10.0, -10.0]], 0.1);
        assert_eq!(params[0], vec![0.0, 3.0]);
    }

    #[test]
    fn empty_aggregator_mean_is_empty() {
        let agg = FedAvg::new();
        assert!(agg.mean().is_empty());
    }

    #[test]
    fn mismatched_contribution_is_err_and_dropped() {
        let mut agg = FedAvg::new();
        agg.add(&grad(&[1.0, 1.0]), 1.0).unwrap();
        // Layer-count mismatch.
        let empty = ModelGrad::default();
        assert!(agg.add(&empty, 1.0).is_err());
        // Element-count mismatch.
        assert!(agg.add(&grad(&[1.0, 1.0, 1.0]), 1.0).is_err());
        // Garbage weight.
        assert!(agg.add(&grad(&[1.0, 1.0]), f64::NAN).is_err());
        // Sums untouched by the rejected contributions.
        assert_eq!(agg.weight(), 1.0);
        assert_eq!(agg.mean()[0], vec![1.0, 1.0]);
    }

    /// The satellite's 10k-contribution precision gate: f64 accumulators
    /// must track an explicit f64 reference exactly, where the old f32
    /// running sums drift by many ulps (adding 1e-4-scale contributions
    /// onto a sum of ~1e4 loses low bits every add).
    #[test]
    fn ten_thousand_contributions_match_f64_reference() {
        let n = 64;
        let mut agg = FedAvg::new();
        let mut ref_sum = vec![0.0f64; n];
        let mut ref_w = 0.0f64;
        for k in 0..10_000u32 {
            // Deterministic, sign-varied, scale-varied contributions.
            let vals: Vec<f32> = (0..n)
                .map(|i| {
                    let s = if (k + i as u32) % 2 == 0 { 1.0 } else { -1.0 };
                    s * (1.0 + (k % 97) as f32 * 1e-4) * (0.1 + i as f32 * 1e-3)
                })
                .collect();
            let w = 1.0 + (k % 7) as f64;
            for (r, &v) in ref_sum.iter_mut().zip(&vals) {
                *r += w * v as f64;
            }
            ref_w += w;
            agg.add(&grad(&vals), w).unwrap();
        }
        let mean = agg.mean();
        for (got, r) in mean[0].iter().zip(&ref_sum) {
            let want = (r / ref_w) as f32;
            assert_eq!(*got, want, "f64 accumulation must match the reference bit-for-bit");
        }
    }

    #[test]
    fn fedavg_merge_matches_single_accumulator() {
        // Shard-split FedAvg must equal the flat accumulation exactly
        // when the merge preserves the shard-local sum order.
        let contribs: Vec<(Vec<f32>, f64)> = (0..10)
            .map(|k| {
                let vals: Vec<f32> = (0..5).map(|i| (k * 5 + i) as f32 * 0.37 - 3.0).collect();
                (vals, 1.0 + (k % 3) as f64 * 0.5)
            })
            .collect();
        let mut flat = FedAvg::new();
        for (vals, w) in &contribs {
            flat.add(&grad(vals), *w).unwrap();
        }
        let mut shard_a = FedAvg::new();
        let mut shard_b = FedAvg::new();
        for (k, (vals, w)) in contribs.iter().enumerate() {
            let shard = if k < 5 { &mut shard_a } else { &mut shard_b };
            shard.add(&grad(vals), *w).unwrap();
        }
        shard_a.merge(shard_b).unwrap();
        assert_eq!(shard_a.weight(), flat.weight());
        // f64 sums of ≤10 values in a different association: identical
        // here because each element sum is exact in f64 at this scale.
        let want = flat.mean();
        let got = shard_a.mean();
        for (a, b) in got[0].iter().zip(&want[0]) {
            assert!((a - b).abs() <= 1e-6 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn fedavg_merge_handles_empty_sides_and_rejects_mismatch() {
        let mut a = FedAvg::new();
        a.merge(FedAvg::new()).unwrap();
        assert!(a.mean().is_empty());
        let mut b = FedAvg::new();
        b.add(&grad(&[1.0, 2.0]), 2.0).unwrap();
        let mut empty = FedAvg::new();
        empty.merge(b).unwrap();
        assert_eq!(empty.weight(), 2.0);
        // Shape mismatch is an error with the sums untouched.
        let mut c = FedAvg::new();
        c.add(&grad(&[1.0, 2.0, 3.0]), 1.0).unwrap();
        assert!(empty.merge(c).is_err());
        assert_eq!(empty.weight(), 2.0);
        assert_eq!(empty.mean()[0], vec![1.0, 2.0]);
    }

    #[test]
    fn round_agg_merge_rejects_route_mix() {
        let mut exact = RoundAgg::for_mode(AggMode::Exact);
        assert!(exact.merge(RoundAgg::for_mode(AggMode::Binsum)).is_err());
        assert!(exact.merge(RoundAgg::for_mode(AggMode::Exact)).is_ok());
    }

    #[test]
    fn round_agg_wire_roundtrips_both_routes() {
        let mut exact = RoundAgg::for_mode(AggMode::Exact);
        if let RoundAgg::Exact(fa) = &mut exact {
            fa.add(&grad(&[1.5, -2.5]), 3.0).unwrap();
        }
        let mut w = BlobWriter::new();
        exact.write_wire(&mut w);
        let bytes = w.into_bytes();
        let back = RoundAgg::read_wire(&mut BlobReader::new(&bytes)).unwrap();
        assert_eq!(back.weight(), 3.0);
        assert_eq!(back.approx_bytes(), exact.approx_bytes());
        let (want, _) = exact.finish();
        let (got, _) = back.finish();
        assert_eq!(want, got);

        let bin = RoundAgg::for_mode(AggMode::Binsum);
        let mut w = BlobWriter::new();
        bin.write_wire(&mut w);
        let bytes = w.into_bytes();
        assert!(matches!(
            RoundAgg::read_wire(&mut BlobReader::new(&bytes)).unwrap(),
            RoundAgg::Bin(_)
        ));
        // Unknown route tag and truncation are rejected.
        assert!(RoundAgg::read_wire(&mut BlobReader::new(&[7])).is_err());
        assert!(RoundAgg::read_wire(&mut BlobReader::new(&[])).is_err());
    }

    #[test]
    fn round_agg_dispatches_both_modes() {
        assert_eq!(AggMode::from_name("exact"), Some(AggMode::Exact));
        assert_eq!(AggMode::from_name("binsum"), Some(AggMode::Binsum));
        assert_eq!(AggMode::from_name("bogus"), None);
        for mode in AggMode::ALL {
            assert_eq!(AggMode::from_name(mode.name()), Some(mode));
        }
        let mut agg = RoundAgg::for_mode(AggMode::Exact);
        if let RoundAgg::Exact(fa) = &mut agg {
            fa.add(&grad(&[2.0]), 2.0).unwrap();
        }
        assert_eq!(agg.weight(), 2.0);
        let (mean, report) = agg.finish();
        assert_eq!(mean[0], vec![2.0]);
        assert_eq!(report.exact_layers, 1);
        assert_eq!(report.binsum_layers, 0);
        let (mean, report) = RoundAgg::for_mode(AggMode::Binsum).finish();
        assert!(mean.is_empty());
        assert_eq!(report.dequant_passes, 0);
    }
}
