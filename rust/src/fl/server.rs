//! The FL parameter server: broadcasts global parameters, decompresses
//! client payloads (Alg. 4) and aggregates via FedAvg.
//!
//! Scale model: the server owns **one** stateless
//! [`CodecEngine`](crate::compress::engine::CodecEngine) plus a bounded
//! [`StateStore`] keyed by stable [`ClientId`] — not one mirrored codec
//! per client. Each participant's predictor state is checked out of the
//! store for the duration of its decode and checked back in with an
//! advanced [`StateEpoch`]; eviction, dropout and cold rejoin are
//! detected by the `StateCheck`/`StateResync` handshake and resolved by
//! a deterministic cold-start reset on both sides (never by silent
//! divergence).
//!
//! Accepts both monolithic `Update` blobs and frame-streamed updates
//! (`UpdateBegin` + per-layer `UpdateFrame`s), decoding each frame as it
//! arrives. Tracks the per-round communication statistics that drive the
//! Fig. 11 experiments.

use std::collections::HashSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::compress::agg::{AggReport, BinFrame};
use crate::compress::downlink::DownlinkCodec;
use crate::compress::engine::CodecEngine;
use crate::compress::frame::Frame;
use crate::compress::session::EngineDecodeSession;
use crate::compress::state::{ClientState, StateEpoch};
use crate::compress::store::{ClientId, ShardedMemStore, StateStore, StoreStats};
use crate::fl::aggregate::{apply_update, AggMode, RoundAgg};
use crate::fl::protocol::Msg;
use crate::fl::round::RoundStats;
use crate::fl::transport::Channel;
use crate::tensor::{LayerGrad, LayerMeta, ModelGrad};

/// Where one payload's server-side CPU went: wire-to-aggregator-input
/// decode vs the aggregator's accumulate.
#[derive(Debug, Clone, Copy, Default)]
pub struct AbsorbTimes {
    pub decode: Duration,
    pub agg: Duration,
}

/// A frame-streamed update in the form the round's aggregator consumes.
enum Streamed {
    Dense(ModelGrad),
    Bins(Vec<BinFrame>),
}

/// Parameter-server state.
pub struct Server {
    /// Global model parameters (flat per layer, matching `metas`).
    pub params: Vec<Vec<f32>>,
    pub metas: Vec<LayerMeta>,
    /// Server-side learning rate applied to the aggregated gradient.
    pub lr: f32,
    /// The single stateless decompressor shared by all clients.
    engine: Box<dyn CodecEngine>,
    /// Per-client predictor-state ownership (bounded, evictable).
    store: Box<dyn StateStore>,
    /// Clients admitted to the federation (via `Hello` or `admit`).
    /// Payloads and state checks from unknown ids are rejected with a
    /// proper `Err`, never an index panic.
    admitted: HashSet<ClientId>,
    /// Downlink broadcast compressor (`None` = raw f32 broadcast; even
    /// then the broadcast message is encoded once and fanned out).
    downlink: Option<DownlinkCodec>,
    /// Client id behind each channel index (recorded by `wait_hellos`;
    /// the downlink codec keys its synced-set on these).
    channel_ids: Vec<ClientId>,
    /// How rounds aggregate (`agg=exact|binsum`, see
    /// [`crate::compress::agg`]). Binsum-ineligible layers fall back
    /// per layer inside the aggregator, so this is always safe to set.
    agg_mode: AggMode,
    round: u32,
}

impl Server {
    /// Full constructor: engine + explicit store backend.
    pub fn new(
        params: Vec<Vec<f32>>,
        metas: Vec<LayerMeta>,
        lr: f32,
        engine: Box<dyn CodecEngine>,
        store: Box<dyn StateStore>,
    ) -> Self {
        Server {
            params,
            metas,
            lr,
            engine,
            store,
            admitted: HashSet::new(),
            downlink: None,
            channel_ids: Vec::new(),
            agg_mode: AggMode::Exact,
            round: 0,
        }
    }

    /// Attach a downlink broadcast compressor: the per-round global
    /// delta is encoded once and fanned out to every participant (see
    /// [`crate::compress::downlink`]).
    pub fn with_downlink(mut self, downlink: DownlinkCodec) -> Self {
        self.downlink = Some(downlink);
        self
    }

    /// Select the aggregation route for subsequent rounds.
    pub fn with_agg_mode(mut self, mode: AggMode) -> Self {
        self.agg_mode = mode;
        self
    }

    pub fn agg_mode(&self) -> AggMode {
        self.agg_mode
    }

    /// Fresh per-round aggregator matching the configured route (drive
    /// it through [`Self::absorb_payload`] then [`Self::finish_round`]).
    pub fn new_round_agg(&self) -> RoundAgg {
        RoundAgg::for_mode(self.agg_mode)
    }

    /// The downlink reference model — bit-identical to every synced
    /// client's view (`None` without a downlink codec or before the
    /// first broadcast).
    pub fn downlink_reference(&self) -> Option<&[Vec<f32>]> {
        self.downlink.as_ref().and_then(|d| d.reference())
    }

    /// Convenience: engine over an unbounded sharded in-memory store.
    pub fn with_engine(
        params: Vec<Vec<f32>>,
        metas: Vec<LayerMeta>,
        lr: f32,
        engine: Box<dyn CodecEngine>,
    ) -> Self {
        Self::new(params, metas, lr, engine, Box::new(ShardedMemStore::new(8, None)))
    }

    pub fn round(&self) -> u32 {
        self.round
    }

    /// Admit a client id (the transportless simulation path's `Hello`).
    pub fn admit(&mut self, client: ClientId) {
        self.admitted.insert(client);
    }

    pub fn is_admitted(&self, client: ClientId) -> bool {
        self.admitted.contains(&client)
    }

    /// Current state-store occupancy.
    pub fn store_stats(&self) -> StoreStats {
        self.store.stats()
    }

    /// Peek a client's stored state epoch (observability; `None` when no
    /// state is held — never seen, reset, or evicted).
    pub fn state_epoch(&self, client: ClientId) -> crate::Result<Option<StateEpoch>> {
        self.store.epoch(client)
    }

    /// Fill a round's store-occupancy fields: held mirror states and
    /// their bytes across *both* tiers (resident + spilled), so the
    /// state-memory trajectory is honest for disk-backed stores too.
    pub fn record_store_occupancy(&self, stats: &mut RoundStats) {
        let occ = self.store.stats();
        stats.store_clients = occ.resident_clients + occ.spilled_clients;
        stats.store_bytes = occ.resident_bytes + occ.spilled_bytes;
    }

    fn ensure_admitted(&self, client: ClientId) -> crate::Result<()> {
        anyhow::ensure!(
            self.admitted.contains(&client),
            "unknown client {client}: not admitted to this federation"
        );
        Ok(())
    }

    /// Compare a client's reported state epoch against the stored one
    /// and decide whether both sides must cold-start (`true` = reset).
    ///
    /// Decision table (`None` = no stored state — never seen or
    /// evicted): equal epochs ⇒ in sync, keep going; anything else ⇒
    /// drop the server copy and order a reset. A cold client against no
    /// stored state is the ordinary round-1 path, not a mismatch.
    pub fn check_state(
        &mut self,
        client: ClientId,
        client_epoch: StateEpoch,
    ) -> crate::Result<bool> {
        self.ensure_admitted(client)?;
        if !self.engine.stateful() {
            return Ok(false);
        }
        let in_sync = match self.store.epoch(client)? {
            None => client_epoch.is_cold(),
            Some(server_epoch) => server_epoch == client_epoch,
        };
        if !in_sync {
            self.store.remove(client)?;
        }
        Ok(!in_sync)
    }

    /// Check a client's state out of the store (cold default if absent).
    fn checkout(&mut self, client: ClientId) -> crate::Result<ClientState> {
        Ok(self.store.take(client)?.unwrap_or_else(ClientState::cold))
    }

    /// Check a state back in with its epoch advanced by one round.
    fn checkin(&mut self, client: ClientId, mut cs: ClientState) -> crate::Result<()> {
        if !self.engine.stateful() {
            return Ok(());
        }
        cs.epoch.advance(cs.codec.fingerprint());
        self.store.put(client, cs)
    }

    /// Process one already-received client payload: decompress to the
    /// round aggregator's input form (dense f32 for `agg=exact`, integer
    /// bins where eligible for `agg=binsum`) and absorb it. Returns the
    /// decode/aggregate time split. (Exposed for the single-threaded
    /// simulation path.) Unknown `client` ids are a proper `Err`; a
    /// failed decode or a malformed contribution is dropped whole.
    pub fn absorb_payload(
        &mut self,
        client: ClientId,
        payload: &[u8],
        weight: f64,
        agg: &mut RoundAgg,
    ) -> crate::Result<AbsorbTimes> {
        self.ensure_admitted(client)?;
        let mut cs = self.checkout(client)?;
        let t0 = Instant::now();
        let decoded = match agg {
            RoundAgg::Exact(_) => self
                .engine
                .decode_payload(payload, &self.metas, &mut cs.codec)
                .map(|(grads, _report)| Streamed::Dense(grads)),
            RoundAgg::Bin(_) => self
                .engine
                .decode_payload_to_bins(payload, &self.metas, &mut cs.codec)
                .map(|(frames, _report)| Streamed::Bins(frames)),
        };
        let decode = t0.elapsed();
        match decoded {
            Ok(streamed) => {
                self.checkin(client, cs)?;
                let t1 = Instant::now();
                match (streamed, agg) {
                    (Streamed::Dense(grads), RoundAgg::Exact(fa)) => fa.add(&grads, weight)?,
                    (Streamed::Bins(frames), RoundAgg::Bin(ba)) => ba.add(&frames, weight)?,
                    _ => unreachable!("decode form matches the aggregator route"),
                }
                Ok(AbsorbTimes { decode, agg: t1.elapsed() })
            }
            Err(e) => {
                // A failed decode may have half-updated the state: drop
                // it so the next handshake forces a clean cold start.
                self.store.remove(client)?;
                Err(e)
            }
        }
    }

    /// Receive one frame-streamed update that was opened by an
    /// `UpdateBegin` declaring `n_layers` frames, decoding each frame as
    /// it lands (to integer bins where the round aggregates in the
    /// compressed domain) and absorbing the result. Returns the total
    /// frame wire bytes and the decode/aggregate time split.
    fn recv_streamed_update(
        &mut self,
        client: ClientId,
        channel: &mut dyn Channel,
        round: u32,
        n_layers: usize,
        weight: f64,
        agg: &mut RoundAgg,
    ) -> crate::Result<(usize, AbsorbTimes)> {
        anyhow::ensure!(
            n_layers == self.metas.len(),
            "client streamed {} layers, model has {}",
            n_layers,
            self.metas.len()
        );
        let use_bins = matches!(agg, RoundAgg::Bin(_));
        let mut cs = self.checkout(client)?;
        let mut decode = || -> crate::Result<(Streamed, usize, Duration)> {
            let mut session =
                EngineDecodeSession::new(self.engine.as_mut(), &mut cs.codec, n_layers);
            let mut grads = ModelGrad::default();
            let mut bins = Vec::new();
            let mut wire_bytes = 0usize;
            let mut decode_time = Duration::ZERO;
            for li in 0..n_layers {
                match channel.recv()? {
                    Msg::UpdateFrame { round: r, frame, .. } => {
                        anyhow::ensure!(r == round, "frame for round {r} during round {round}");
                        wire_bytes += frame.len();
                        let frame = Frame::from_wire(&frame)?;
                        let t0 = Instant::now();
                        // The session enforces frame ordering/indexing.
                        if use_bins {
                            bins.push(session.decode_frame_to_bins(&frame, &self.metas[li])?);
                        } else {
                            grads.layers.push(session.decode_frame(&frame, &self.metas[li])?);
                        }
                        decode_time += t0.elapsed();
                    }
                    other => anyhow::bail!("expected UpdateFrame, got {other:?}"),
                }
            }
            session.finish()?;
            let streamed =
                if use_bins { Streamed::Bins(bins) } else { Streamed::Dense(grads) };
            Ok((streamed, wire_bytes, decode_time))
        };
        match decode() {
            Ok((streamed, wire_bytes, decode_time)) => {
                self.checkin(client, cs)?;
                let t0 = Instant::now();
                match (streamed, agg) {
                    (Streamed::Dense(grads), RoundAgg::Exact(fa)) => fa.add(&grads, weight)?,
                    (Streamed::Bins(frames), RoundAgg::Bin(ba)) => ba.add(&frames, weight)?,
                    _ => unreachable!("decode form matches the aggregator route"),
                }
                Ok((wire_bytes, AbsorbTimes { decode: decode_time, agg: t0.elapsed() }))
            }
            Err(e) => {
                self.store.remove(client)?;
                Err(e)
            }
        }
    }

    /// Finish the round: fold the aggregator (for `agg=binsum` this is
    /// the single dequantize-and-divide), apply the mean gradient to
    /// the global parameters, and report the per-layer routes taken.
    pub fn finish_round(&mut self, agg: RoundAgg) -> AggReport {
        let t0 = Instant::now();
        let (mean, mut report) = agg.finish();
        if !mean.is_empty() {
            apply_update(&mut self.params, &mean, self.lr);
        }
        report.finish_time = t0.elapsed();
        self.round += 1;
        report
    }

    /// Broadcast this round's model to every channel. The message bytes
    /// are encoded **once** and fanned out as the same shared buffer —
    /// for both the raw `GlobalParams` path and the compressed
    /// delta/full-sync path.
    fn broadcast(
        &mut self,
        channels: &mut [Box<dyn Channel>],
        round: u32,
        stats: &mut RoundStats,
    ) -> crate::Result<()> {
        let raw_model_bytes: usize = self.metas.iter().map(|m| m.numel * 4).sum();
        stats.downlink_raw_bytes = raw_model_bytes * channels.len();
        // Byte accounting convention (matches the uplink and the
        // run_local simulation): frame/tensor payload bytes only, no
        // `Msg` envelope — so threaded and simulated runs of the same
        // config report the same down CR, and the raw path reads 1.0.
        match &mut self.downlink {
            None => {
                let bytes: Arc<[u8]> = Msg::encode_global_params(round, &self.params).into();
                stats.downlink_bytes = raw_model_bytes * channels.len();
                for ch in channels.iter_mut() {
                    ch.send_encoded(&bytes)?;
                }
            }
            Some(down) => {
                anyhow::ensure!(
                    self.channel_ids.len() == channels.len(),
                    "downlink broadcast needs the Hello id behind every channel \
                     (run wait_hellos first)"
                );
                let bc = down.encode_round(&self.params, &self.channel_ids)?;
                stats.down_codec_time += bc.stats.encode_time;
                let delta_payload = bc.stats.delta_bytes;
                // Encode each message once; every recipient gets the
                // same buffers.
                let delta_msgs: Option<(Arc<[u8]>, Vec<Arc<[u8]>>)> = bc.delta.map(|d| {
                    let begin: Arc<[u8]> = Msg::DeltaBegin {
                        round,
                        n_layers: d.frames.len() as u32,
                        reset: d.reset,
                    }
                    .encode()
                    .into();
                    let frames = d
                        .frames
                        .iter()
                        .map(|f| Msg::DeltaFrame { round, frame: f.to_wire() }.encode())
                        .map(Arc::from)
                        .collect();
                    (begin, frames)
                });
                let full_sync: Option<Arc<[u8]>> = if bc.cold.is_empty() {
                    None
                } else {
                    let reference = down
                        .reference()
                        .ok_or_else(|| anyhow::anyhow!("downlink reference missing"))?;
                    Some(Msg::encode_full_sync(round, reference).into())
                };
                let cold: HashSet<ClientId> = bc.cold.into_iter().collect();
                for (idx, ch) in channels.iter_mut().enumerate() {
                    if cold.contains(&self.channel_ids[idx]) {
                        let bytes = full_sync
                            .as_ref()
                            .ok_or_else(|| anyhow::anyhow!("cold client without full sync"))?;
                        stats.full_syncs += 1;
                        stats.downlink_bytes += raw_model_bytes;
                        ch.send_encoded(bytes)?;
                    } else {
                        let (begin, frames) = delta_msgs
                            .as_ref()
                            .ok_or_else(|| anyhow::anyhow!("warm client without a delta"))?;
                        stats.downlink_bytes += delta_payload;
                        ch.send_encoded(begin)?;
                        for f in frames {
                            ch.send_encoded(f)?;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Full synchronous round over live channels (threaded/TCP mode):
    /// broadcast params (encode-once fan-out; compressed delta when a
    /// downlink codec is attached), run the state handshake, collect
    /// updates (monolithic or frame-streamed), aggregate, step.
    pub fn run_round(&mut self, channels: &mut [Box<dyn Channel>]) -> crate::Result<RoundStats> {
        let round = self.round;
        let mut stats = RoundStats { round, participants: channels.len(), ..Default::default() };
        self.broadcast(channels, round, &mut stats)?;
        // ── Pass 1: state epoch handshake (before any client trains). ──
        for ch in channels.iter_mut() {
            match ch.recv()? {
                Msg::StateCheck { client_id, rounds, fingerprint } => {
                    let reset =
                        self.check_state(client_id, StateEpoch { rounds, fingerprint })?;
                    if reset {
                        stats.resyncs += 1;
                    }
                    ch.send(&Msg::StateResync { client_id, reset })?;
                }
                other => anyhow::bail!("expected StateCheck, got {other:?}"),
            }
        }
        // ── Pass 2: updates. ──
        let raw_model_bytes: usize = self.metas.iter().map(|m| m.numel * 4).sum();
        let mut agg = self.new_round_agg();
        for idx in 0..channels.len() {
            match channels[idx].recv()? {
                Msg::Update { client_id, round: r, payload, train_loss, n_samples } => {
                    anyhow::ensure!(r == round, "client {client_id} answered round {r}");
                    stats.payload_bytes += payload.len();
                    stats.raw_bytes += raw_model_bytes;
                    stats.mean_loss += train_loss as f64;
                    let times =
                        self.absorb_payload(client_id, &payload, n_samples as f64, &mut agg)?;
                    stats.decomp_time += times.decode;
                    stats.server_decode_time += times.decode;
                    stats.agg_time += times.agg;
                }
                Msg::UpdateBegin { client_id, round: r, n_layers, train_loss, n_samples } => {
                    anyhow::ensure!(r == round, "client {client_id} answered round {r}");
                    self.ensure_admitted(client_id)?;
                    stats.raw_bytes += raw_model_bytes;
                    stats.mean_loss += train_loss as f64;
                    let (wire_bytes, times) = self.recv_streamed_update(
                        client_id,
                        channels[idx].as_mut(),
                        round,
                        n_layers as usize,
                        n_samples as f64,
                        &mut agg,
                    )?;
                    stats.payload_bytes += wire_bytes;
                    stats.decomp_time += times.decode;
                    stats.server_decode_time += times.decode;
                    stats.agg_time += times.agg;
                }
                other => anyhow::bail!("server: unexpected {other:?}"),
            }
        }
        stats.mean_loss /= channels.len().max(1) as f64;
        self.record_store_occupancy(&mut stats);
        let rep = self.finish_round(agg);
        stats.agg_time += rep.finish_time;
        stats.binsum_layers = rep.binsum_layers;
        stats.exact_layers = rep.exact_layers + rep.mixed_layers;
        stats.dequant_passes = rep.dequant_passes;
        Ok(stats)
    }

    /// Send shutdown to all clients.
    pub fn shutdown(&self, channels: &mut [Box<dyn Channel>]) -> crate::Result<()> {
        for ch in channels.iter_mut() {
            ch.send(&Msg::Shutdown)?;
        }
        Ok(())
    }

    /// Wait for the Hello of every client (threaded/TCP mode), admitting
    /// each announced id and recording which id sits behind each channel
    /// (the downlink broadcast plans its fan-out against these).
    pub fn wait_hellos(&mut self, channels: &mut [Box<dyn Channel>]) -> crate::Result<()> {
        self.channel_ids.clear();
        for ch in channels.iter_mut() {
            match ch.recv()? {
                Msg::Hello { client_id } => {
                    self.admitted.insert(client_id);
                    self.channel_ids.push(client_id);
                }
                other => anyhow::bail!("expected Hello, got {other:?}"),
            }
        }
        Ok(())
    }

    /// View the current global parameters as a ModelGrad-shaped object
    /// (for checkpoint compression examples).
    pub fn params_as_model(&self) -> ModelGrad {
        ModelGrad {
            layers: self
                .metas
                .iter()
                .zip(&self.params)
                .map(|(m, p)| LayerGrad::new(m.clone(), p.clone()))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::pipeline::{FedgecCodec, FedgecConfig, FedgecEngine};
    use crate::compress::GradientCodec;
    use crate::fl::aggregate::FedAvg;
    use crate::tensor::LayerMeta;
    use crate::util::rng::Rng;

    fn small_model() -> (Vec<Vec<f32>>, Vec<LayerMeta>) {
        let metas = vec![LayerMeta::dense("fc", 1500, 1), LayerMeta::other("b", 8)];
        let params = vec![vec![0.0; 1500], vec![0.0; 8]];
        (params, metas)
    }

    fn server() -> Server {
        let (params, metas) = small_model();
        Server::with_engine(
            params,
            metas,
            0.1,
            Box::new(FedgecEngine::new(FedgecConfig::default())),
        )
    }

    fn grads(metas: &[LayerMeta], rng: &mut Rng) -> ModelGrad {
        ModelGrad {
            layers: metas
                .iter()
                .map(|m| {
                    let data: Vec<f32> =
                        (0..m.numel).map(|_| rng.normal_f32(0.0, 0.5)).collect();
                    LayerGrad::new(m.clone(), data)
                })
                .collect(),
        }
    }

    #[test]
    fn unknown_client_is_err_not_panic() {
        let mut srv = server();
        let mut agg = RoundAgg::Exact(FedAvg::new());
        // Out-of-range / never-admitted ids used to panic on
        // `self.codecs[client_idx]`; now they are a proper Err.
        let err = srv.absorb_payload(99, &[1, 2, 3], 1.0, &mut agg).unwrap_err();
        assert!(err.to_string().contains("unknown client 99"), "{err}");
        assert!(srv.check_state(99, StateEpoch::cold()).is_err());
        srv.admit(7);
        assert!(srv.is_admitted(7) && !srv.is_admitted(99));
    }

    #[test]
    fn state_handshake_warm_and_cold_paths() {
        let mut srv = server();
        srv.admit(0);
        let metas = srv.metas.clone();
        let mut rng = Rng::new(3);
        let mut client = FedgecCodec::new(FedgecConfig::default());
        let mut epoch = StateEpoch::cold();
        // Round 1: both cold — no reset.
        assert!(!srv.check_state(0, epoch).unwrap());
        let mut agg = srv.new_round_agg();
        let p = client.compress(&grads(&metas, &mut rng)).unwrap();
        srv.absorb_payload(0, &p, 1.0, &mut agg).unwrap();
        epoch.advance(client.state_fingerprint());
        // Round 2: warm on both sides — still no reset, epochs agree.
        assert!(!srv.check_state(0, epoch).unwrap());
        // Client loses its state (simulated device churn): mismatch ⇒
        // reset ordered, server copy dropped.
        let fresh = FedgecCodec::new(FedgecConfig::default());
        assert!(srv.check_state(0, StateEpoch::cold()).unwrap());
        assert_eq!(srv.store_stats().resident_clients, 0);
        // Cold restart re-converges.
        let mut client = fresh;
        let p = client.compress(&grads(&metas, &mut rng)).unwrap();
        srv.absorb_payload(0, &p, 1.0, &mut agg).unwrap();
        let mut epoch = StateEpoch::cold();
        epoch.advance(client.state_fingerprint());
        assert!(!srv.check_state(0, epoch).unwrap());
    }

    #[test]
    fn binsum_round_matches_exact_round() {
        // Two servers over the SAME client payloads: agg=binsum must
        // track agg=exact within 1e-5 relative while dequantizing each
        // bin-routed layer exactly once.
        use crate::compress::predictor::magnitude::MagnitudeSel;
        use crate::compress::predictor::sign::SignSel;
        use crate::compress::predictor::PredictorSpec;
        use crate::compress::quant::ErrorBound;
        let cfg = FedgecConfig {
            error_bound: ErrorBound::Abs(2e-3),
            predictor: PredictorSpec { mag: MagnitudeSel::Zero, sign: SignSel::None },
            ..Default::default()
        };
        let (params, metas) = small_model();
        let mut exact = Server::with_engine(
            params.clone(),
            metas.clone(),
            0.1,
            Box::new(FedgecEngine::new(cfg.clone())),
        );
        let mut bin = Server::with_engine(
            params,
            metas.clone(),
            0.1,
            Box::new(FedgecEngine::new(cfg.clone())),
        )
        .with_agg_mode(AggMode::Binsum);
        assert_eq!(bin.agg_mode(), AggMode::Binsum);
        let mut rng = Rng::new(77);
        for round in 0..3 {
            let mut agg_e = exact.new_round_agg();
            let mut agg_b = bin.new_round_agg();
            for client in 0..3u64 {
                exact.admit(client);
                bin.admit(client);
                // State-free mode: a fresh codec per round is the same
                // codec (no cross-round state to warm).
                let mut codec = FedgecCodec::new(cfg.clone());
                let p = codec.compress(&grads(&metas, &mut rng)).unwrap();
                let w = (client + 1) as f64;
                exact.absorb_payload(client, &p, w, &mut agg_e).unwrap();
                bin.absorb_payload(client, &p, w, &mut agg_b).unwrap();
            }
            let re = exact.finish_round(agg_e);
            let rb = bin.finish_round(agg_b);
            assert_eq!(re.binsum_layers, 0);
            // fc (1500 > t_lossy) rides the bin route; the small bias
            // layer is stored lossless and falls back dense.
            assert_eq!(rb.binsum_layers, 1, "round {round}");
            assert_eq!(rb.exact_layers, 1, "round {round}");
            assert_eq!(rb.dequant_passes, 1, "round {round}");
            for (a, b) in exact.params.iter().flatten().zip(bin.params.iter().flatten()) {
                assert!(
                    (a - b).abs() <= 1e-5 * a.abs().max(1e-3),
                    "round {round}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn failed_decode_drops_server_state() {
        let mut srv = server();
        srv.admit(1);
        let metas = srv.metas.clone();
        let mut rng = Rng::new(9);
        let mut client = FedgecCodec::new(FedgecConfig::default());
        let mut agg = srv.new_round_agg();
        let p = client.compress(&grads(&metas, &mut rng)).unwrap();
        srv.absorb_payload(1, &p, 1.0, &mut agg).unwrap();
        assert_eq!(srv.store_stats().resident_clients, 1);
        assert!(srv.absorb_payload(1, &[0xFF; 16], 1.0, &mut agg).is_err());
        // Corrupt payload must not leave a half-updated mirror behind.
        assert_eq!(srv.store_stats().resident_clients, 0);
    }
}
