//! The FL parameter server: broadcasts global parameters, decompresses
//! client payloads (Alg. 4) with one mirrored codec per client, and
//! aggregates via FedAvg. Accepts both monolithic `Update` blobs and
//! frame-streamed updates (`UpdateBegin` + per-layer `UpdateFrame`s),
//! decoding each frame as it arrives. Tracks the per-round communication
//! statistics that drive the Fig. 11 experiments.

use std::time::{Duration, Instant};

use crate::compress::frame::Frame;
use crate::compress::session::DecodeSession;
use crate::compress::GradientCodec;
use crate::fl::aggregate::{apply_update, FedAvg};
use crate::fl::protocol::Msg;
use crate::fl::round::RoundStats;
use crate::fl::transport::Channel;
use crate::tensor::{LayerGrad, LayerMeta, ModelGrad};

/// Parameter-server state.
pub struct Server {
    /// Global model parameters (flat per layer, matching `metas`).
    pub params: Vec<Vec<f32>>,
    pub metas: Vec<LayerMeta>,
    /// Server-side learning rate applied to the aggregated gradient.
    pub lr: f32,
    /// One decompressor per client (their predictor states are mirrors of
    /// the corresponding client-side compressors).
    pub codecs: Vec<Box<dyn GradientCodec>>,
    round: u32,
}

impl Server {
    pub fn new(
        params: Vec<Vec<f32>>,
        metas: Vec<LayerMeta>,
        lr: f32,
        codecs: Vec<Box<dyn GradientCodec>>,
    ) -> Self {
        Server { params, metas, lr, codecs, round: 0 }
    }

    pub fn round(&self) -> u32 {
        self.round
    }

    /// Process one already-received client payload: decompress + absorb
    /// into the aggregator. Returns decompression time. (Exposed for the
    /// single-threaded simulation path.)
    pub fn absorb_payload(
        &mut self,
        client_idx: usize,
        payload: &[u8],
        weight: f64,
        agg: &mut FedAvg,
    ) -> crate::Result<Duration> {
        let t0 = Instant::now();
        let grads = self.codecs[client_idx].decompress(payload, &self.metas)?;
        let dt = t0.elapsed();
        agg.add(&grads, weight);
        Ok(dt)
    }

    /// Receive one frame-streamed update that was opened by an
    /// `UpdateBegin` declaring `n_layers` frames, decoding each frame as
    /// it lands. Returns the decoded gradients, total frame wire bytes,
    /// and decode time.
    fn recv_streamed_update(
        &mut self,
        client_idx: usize,
        channel: &mut dyn Channel,
        round: u32,
        n_layers: usize,
    ) -> crate::Result<(ModelGrad, usize, Duration)> {
        anyhow::ensure!(
            n_layers == self.metas.len(),
            "client streamed {} layers, model has {}",
            n_layers,
            self.metas.len()
        );
        let mut session = DecodeSession::new(self.codecs[client_idx].as_mut(), n_layers)?;
        let mut grads = ModelGrad::default();
        let mut wire_bytes = 0usize;
        let mut decode_time = Duration::ZERO;
        for li in 0..n_layers {
            match channel.recv()? {
                Msg::UpdateFrame { round: r, frame, .. } => {
                    anyhow::ensure!(r == round, "frame for round {r} during round {round}");
                    wire_bytes += frame.len();
                    let frame = Frame::from_wire(&frame)?;
                    let t0 = Instant::now();
                    // The session enforces frame ordering/indexing.
                    let layer = session.decode_frame(&frame, &self.metas[li])?;
                    decode_time += t0.elapsed();
                    grads.layers.push(layer);
                }
                other => anyhow::bail!("expected UpdateFrame, got {other:?}"),
            }
        }
        session.finish()?;
        Ok((grads, wire_bytes, decode_time))
    }

    /// Apply the aggregated mean gradient to the global parameters.
    pub fn finish_round(&mut self, agg: FedAvg) {
        let mean = agg.mean();
        if !mean.is_empty() {
            apply_update(&mut self.params, &mean, self.lr);
        }
        self.round += 1;
    }

    /// Full synchronous round over live channels (threaded/TCP mode):
    /// broadcast params, collect updates (monolithic or frame-streamed),
    /// aggregate, step.
    pub fn run_round(&mut self, channels: &mut [Box<dyn Channel>]) -> crate::Result<RoundStats> {
        let round = self.round;
        let bcast = Msg::GlobalParams { round, tensors: self.params.clone() };
        for ch in channels.iter_mut() {
            ch.send(&bcast)?;
        }
        let raw_model_bytes: usize = self.metas.iter().map(|m| m.numel * 4).sum();
        let mut agg = FedAvg::new();
        let mut stats = RoundStats { round, ..Default::default() };
        for idx in 0..channels.len() {
            match channels[idx].recv()? {
                Msg::Update { client_id, round: r, payload, train_loss, n_samples } => {
                    anyhow::ensure!(r == round, "client {client_id} answered round {r}");
                    stats.payload_bytes += payload.len();
                    stats.raw_bytes += raw_model_bytes;
                    stats.mean_loss += train_loss as f64;
                    let dt = self.absorb_payload(idx, &payload, n_samples as f64, &mut agg)?;
                    stats.decomp_time += dt;
                }
                Msg::UpdateBegin { client_id, round: r, n_layers, train_loss, n_samples } => {
                    anyhow::ensure!(r == round, "client {client_id} answered round {r}");
                    stats.raw_bytes += raw_model_bytes;
                    stats.mean_loss += train_loss as f64;
                    let (grads, wire_bytes, dt) = self.recv_streamed_update(
                        idx,
                        channels[idx].as_mut(),
                        round,
                        n_layers as usize,
                    )?;
                    stats.payload_bytes += wire_bytes;
                    stats.decomp_time += dt;
                    agg.add(&grads, n_samples as f64);
                }
                other => anyhow::bail!("server: unexpected {other:?}"),
            }
        }
        stats.mean_loss /= channels.len().max(1) as f64;
        self.finish_round(agg);
        Ok(stats)
    }

    /// Send shutdown to all clients.
    pub fn shutdown(&self, channels: &mut [Box<dyn Channel>]) -> crate::Result<()> {
        for ch in channels.iter_mut() {
            ch.send(&Msg::Shutdown)?;
        }
        Ok(())
    }

    /// Wait for the Hello of every client (threaded/TCP mode).
    pub fn wait_hellos(&self, channels: &mut [Box<dyn Channel>]) -> crate::Result<()> {
        for ch in channels.iter_mut() {
            match ch.recv()? {
                Msg::Hello { .. } => {}
                other => anyhow::bail!("expected Hello, got {other:?}"),
            }
        }
        Ok(())
    }

    /// View the current global parameters as a ModelGrad-shaped object
    /// (for checkpoint compression examples).
    pub fn params_as_model(&self) -> ModelGrad {
        ModelGrad {
            layers: self
                .metas
                .iter()
                .zip(&self.params)
                .map(|(m, p)| LayerGrad::new(m.clone(), p.clone()))
                .collect(),
        }
    }
}
