//! The FL parameter server: broadcasts global parameters, decompresses
//! client payloads (Alg. 4) and aggregates via FedAvg.
//!
//! Scale model: the server owns **one** stateless
//! [`CodecEngine`](crate::compress::engine::CodecEngine) plus a bounded
//! [`StateStore`] keyed by stable [`ClientId`] — not one mirrored codec
//! per client. Each participant's predictor state is checked out of the
//! store for the duration of its decode and checked back in with an
//! advanced [`StateEpoch`]; eviction, dropout and cold rejoin are
//! detected by the `StateCheck`/`StateResync` handshake and resolved by
//! a deterministic cold-start reset on both sides (never by silent
//! divergence).
//!
//! The decode half lives in [`DecodeCore`]: engine + shared store +
//! shared admission registry. The flat [`Server`] owns one core and
//! serves channels sequentially; [`Server::fork_core`] hands each
//! worker of the sharded runner (see [`crate::fl::topology`]) its own
//! core over the *same* store and membership, and an edge aggregator
//! owns a standalone core for its subtree.
//!
//! Fault model: a client's channel error, protocol violation, or failed
//! decode drops **that client's contribution whole** (validate-before-
//! mutate, like the aggregators) and is tallied in `RoundStats.dropped`
//! — one bad client cannot abort a round.
//!
//! Accepts both monolithic `Update` blobs and frame-streamed updates
//! (`UpdateBegin` + per-layer `UpdateFrame`s), decoding each frame as it
//! arrives. Tracks the per-round communication statistics that drive the
//! Fig. 11 experiments.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use crate::compress::agg::{AggReport, BinFrame};
use crate::compress::control::{EbController, EbPlan, EbSignals};
use crate::compress::downlink::DownlinkCodec;
use crate::compress::engine::CodecEngine;
use crate::compress::frame::Frame;
use crate::compress::session::EngineDecodeSession;
use crate::compress::state::{ClientState, StateEpoch};
use crate::compress::store::{ClientId, ShardedMemStore, StateStore, StoreStats};
use crate::fl::aggregate::{apply_update, AggMode, RoundAgg};
use crate::fl::protocol::Msg;
use crate::fl::round::{RoundStats, ShardStats};
use crate::fl::transport::Channel;
use crate::telemetry::{self, journal};
use crate::tensor::{LayerGrad, LayerMeta, ModelGrad};

/// Where one payload's server-side CPU went: wire-to-aggregator-input
/// decode vs the aggregator's accumulate.
#[derive(Debug, Clone, Copy, Default)]
pub struct AbsorbTimes {
    pub decode: Duration,
    pub agg: Duration,
}

/// A frame-streamed update in the form the round's aggregator consumes.
enum Streamed {
    Dense(ModelGrad),
    Bins(Vec<BinFrame>),
}

/// What one successfully served update contributed. Committed into
/// [`ShardStats`] only on success, so a dropped client leaves no trace
/// in the tallies.
struct Served {
    client: ClientId,
    wire_bytes: usize,
    loss: f32,
    times: AbsorbTimes,
}

/// The federation's admission registry, shared by every decode core:
/// the flat server and all its shard forks see one membership, updated
/// concurrently.
#[derive(Default)]
pub struct Admissions {
    /// Open admission: every id is implicitly admitted. For synthetic
    /// million-client fleets, where materializing the id set would make
    /// server memory O(clients) with no protocol benefit.
    open: AtomicBool,
    ids: RwLock<HashSet<ClientId>>,
}

impl Admissions {
    pub fn admit(&self, client: ClientId) {
        self.ids.write().expect("admissions lock").insert(client);
    }

    pub fn admit_all(&self) {
        self.open.store(true, Ordering::Relaxed);
    }

    pub fn contains(&self, client: ClientId) -> bool {
        self.open.load(Ordering::Relaxed)
            || self.ids.read().expect("admissions lock").contains(&client)
    }
}

/// The server's decode half: one codec engine (engines hold scratch
/// buffers and are not shared across threads) plus shared handles to
/// the state store and admission registry. Everything needed to turn
/// uplinks into aggregator input, with no reference to the global
/// model, so shard workers and edge aggregators can run it anywhere.
pub struct DecodeCore {
    metas: Arc<Vec<LayerMeta>>,
    engine: Box<dyn CodecEngine>,
    store: Arc<dyn StateStore>,
    admissions: Arc<Admissions>,
}

impl DecodeCore {
    /// A core with its own store and membership — the edge-aggregator
    /// construction (an edge owns its subtree's state outright).
    pub fn standalone(
        engine: Box<dyn CodecEngine>,
        store: Box<dyn StateStore>,
        metas: Vec<LayerMeta>,
    ) -> Self {
        DecodeCore {
            metas: Arc::new(metas),
            engine,
            store: Arc::from(store),
            admissions: Arc::new(Admissions::default()),
        }
    }

    pub fn admit(&self, client: ClientId) {
        self.admissions.admit(client);
    }

    pub fn is_admitted(&self, client: ClientId) -> bool {
        self.admissions.contains(client)
    }

    /// Current state-store occupancy.
    pub fn store_stats(&self) -> StoreStats {
        self.store.stats()
    }

    /// Uncompressed f32 bytes of one full model under these metas.
    pub fn raw_model_bytes(&self) -> usize {
        self.metas.iter().map(|m| m.numel * 4).sum()
    }

    /// Adopt the round's error-bound plan: the engine tags decoded
    /// mirrors with the same eb the encoding clients use, keeping
    /// `StateStore` fingerprints bit-identical (DESIGN.md §15).
    pub fn apply_eb_plan(&mut self, plan: &EbPlan) {
        self.engine.apply_eb_plan(plan);
    }

    fn ensure_admitted(&self, client: ClientId) -> crate::Result<()> {
        anyhow::ensure!(
            self.admissions.contains(client),
            "unknown client {client}: not admitted to this federation"
        );
        Ok(())
    }

    /// Compare a client's reported state epoch against the stored one
    /// and decide whether both sides must cold-start (`true` = reset).
    ///
    /// Decision table (`None` = no stored state — never seen or
    /// evicted): equal epochs ⇒ in sync, keep going; anything else ⇒
    /// drop the server copy and order a reset. A cold client against no
    /// stored state is the ordinary round-1 path, not a mismatch.
    pub fn check_state(
        &mut self,
        client: ClientId,
        client_epoch: StateEpoch,
    ) -> crate::Result<bool> {
        self.ensure_admitted(client)?;
        if !self.engine.stateful() {
            return Ok(false);
        }
        let in_sync = match self.store.epoch(client)? {
            None => client_epoch.is_cold(),
            Some(server_epoch) => server_epoch == client_epoch,
        };
        if !in_sync {
            self.store.remove(client)?;
        }
        Ok(!in_sync)
    }

    /// Check a client's state out of the store (cold default if absent).
    /// Stateless engines skip the store round-trip entirely — at
    /// million-client scale those lock acquisitions are pure overhead.
    fn checkout(&mut self, client: ClientId) -> crate::Result<ClientState> {
        if !self.engine.stateful() {
            return Ok(ClientState::cold());
        }
        Ok(self.store.take(client)?.unwrap_or_else(ClientState::cold))
    }

    /// Check a state back in with its epoch advanced by one round.
    fn checkin(&mut self, client: ClientId, mut cs: ClientState) -> crate::Result<()> {
        if !self.engine.stateful() {
            return Ok(());
        }
        cs.epoch.advance(cs.codec.fingerprint());
        self.store.put(client, cs)
    }

    /// Process one already-received client payload: decompress to the
    /// round aggregator's input form (dense f32 for `agg=exact`, integer
    /// bins where eligible for `agg=binsum`) and absorb it. Returns the
    /// decode/aggregate time split. Unknown `client` ids are a proper
    /// `Err`; a failed decode or a malformed contribution is dropped
    /// whole.
    pub fn absorb_payload(
        &mut self,
        client: ClientId,
        payload: &[u8],
        weight: f64,
        agg: &mut RoundAgg,
    ) -> crate::Result<AbsorbTimes> {
        self.ensure_admitted(client)?;
        let mut cs = self.checkout(client)?;
        let t0 = Instant::now();
        let decoded = match agg {
            RoundAgg::Exact(_) => self
                .engine
                .decode_payload(payload, &self.metas, &mut cs.codec)
                .map(|(grads, report)| {
                    journal::report_detail(client as u64, &report);
                    Streamed::Dense(grads)
                }),
            RoundAgg::Bin(_) => self
                .engine
                .decode_payload_to_bins(payload, &self.metas, &mut cs.codec)
                .map(|(frames, report)| {
                    journal::report_detail(client as u64, &report);
                    Streamed::Bins(frames)
                }),
        };
        let decode = t0.elapsed();
        match decoded {
            Ok(streamed) => {
                self.checkin(client, cs)?;
                let t1 = Instant::now();
                match (streamed, agg) {
                    (Streamed::Dense(grads), RoundAgg::Exact(fa)) => fa.add(&grads, weight)?,
                    (Streamed::Bins(frames), RoundAgg::Bin(ba)) => ba.add(&frames, weight)?,
                    _ => unreachable!("decode form matches the aggregator route"),
                }
                Ok(AbsorbTimes { decode, agg: t1.elapsed() })
            }
            Err(e) => {
                // A failed decode may have half-updated the state: drop
                // it so the next handshake forces a clean cold start.
                self.store.remove(client)?;
                Err(e)
            }
        }
    }

    /// Receive one frame-streamed update that was opened by an
    /// `UpdateBegin` declaring `n_layers` frames, decoding each frame as
    /// it lands (to integer bins where the round aggregates in the
    /// compressed domain) and absorbing the result. Returns the total
    /// frame wire bytes and the decode/aggregate time split.
    fn recv_streamed_update(
        &mut self,
        client: ClientId,
        channel: &mut dyn Channel,
        round: u32,
        n_layers: usize,
        weight: f64,
        agg: &mut RoundAgg,
    ) -> crate::Result<(usize, AbsorbTimes)> {
        anyhow::ensure!(
            n_layers == self.metas.len(),
            "client streamed {} layers, model has {}",
            n_layers,
            self.metas.len()
        );
        let use_bins = matches!(agg, RoundAgg::Bin(_));
        let mut cs = self.checkout(client)?;
        let metas = Arc::clone(&self.metas);
        let mut decode = || -> crate::Result<(Streamed, usize, Duration)> {
            let mut session =
                EngineDecodeSession::new(self.engine.as_mut(), &mut cs.codec, n_layers);
            let mut grads = ModelGrad::default();
            let mut bins = Vec::new();
            let mut wire_bytes = 0usize;
            let mut decode_time = Duration::ZERO;
            for li in 0..n_layers {
                match channel.recv()? {
                    Msg::UpdateFrame { round: r, frame, .. } => {
                        anyhow::ensure!(r == round, "frame for round {r} during round {round}");
                        wire_bytes += frame.len();
                        let frame = Frame::from_wire(&frame)?;
                        let t0 = Instant::now();
                        // The session enforces frame ordering/indexing.
                        if use_bins {
                            bins.push(session.decode_frame_to_bins(&frame, &metas[li])?);
                        } else {
                            grads.layers.push(session.decode_frame(&frame, &metas[li])?);
                        }
                        decode_time += t0.elapsed();
                    }
                    other => anyhow::bail!("expected UpdateFrame, got {other:?}"),
                }
            }
            session.finish()?;
            let streamed =
                if use_bins { Streamed::Bins(bins) } else { Streamed::Dense(grads) };
            Ok((streamed, wire_bytes, decode_time))
        };
        match decode() {
            Ok((streamed, wire_bytes, decode_time)) => {
                self.checkin(client, cs)?;
                let t0 = Instant::now();
                match (streamed, agg) {
                    (Streamed::Dense(grads), RoundAgg::Exact(fa)) => fa.add(&grads, weight)?,
                    (Streamed::Bins(frames), RoundAgg::Bin(ba)) => ba.add(&frames, weight)?,
                    _ => unreachable!("decode form matches the aggregator route"),
                }
                Ok((wire_bytes, AbsorbTimes { decode: decode_time, agg: t0.elapsed() }))
            }
            Err(e) => {
                self.store.remove(client)?;
                Err(e)
            }
        }
    }

    /// Serve one channel's pass-1 state handshake: receive its
    /// `StateCheck`, answer with the reset verdict. Returns whether a
    /// reset was ordered.
    pub fn serve_state_check(&mut self, ch: &mut dyn Channel) -> crate::Result<bool> {
        match ch.recv()? {
            Msg::StateCheck { client_id, rounds, fingerprint } => {
                let reset = self.check_state(client_id, StateEpoch { rounds, fingerprint })?;
                ch.send(&Msg::StateResync { client_id, reset })?;
                Ok(reset)
            }
            other => anyhow::bail!("expected StateCheck, got {other:?}"),
        }
    }

    /// Serve one channel's pass-2 update (monolithic or frame-streamed),
    /// absorbing it into `agg`.
    fn serve_update(
        &mut self,
        ch: &mut dyn Channel,
        round: u32,
        agg: &mut RoundAgg,
    ) -> crate::Result<Served> {
        match ch.recv()? {
            Msg::Update { client_id, round: r, payload, train_loss, n_samples } => {
                anyhow::ensure!(r == round, "client {client_id} answered round {r}");
                let times = self.absorb_payload(client_id, &payload, n_samples as f64, agg)?;
                Ok(Served {
                    client: client_id,
                    wire_bytes: payload.len(),
                    loss: train_loss,
                    times,
                })
            }
            Msg::UpdateBegin { client_id, round: r, n_layers, train_loss, n_samples } => {
                anyhow::ensure!(r == round, "client {client_id} answered round {r}");
                self.ensure_admitted(client_id)?;
                let (wire_bytes, times) = self.recv_streamed_update(
                    client_id,
                    ch,
                    round,
                    n_layers as usize,
                    n_samples as f64,
                    agg,
                )?;
                Ok(Served { client: client_id, wire_bytes, loss: train_loss, times })
            }
            other => anyhow::bail!("server: unexpected {other:?}"),
        }
    }

    /// Serve a slice of channels for one round: the pass-1 state
    /// handshake, then pass-2 update collection, absorbing every good
    /// contribution into `agg`.
    ///
    /// This is the fault boundary: a per-channel failure (hung-up
    /// channel, protocol violation, failed decode) drops that client's
    /// contribution whole and is tallied in `dropped` — it never aborts
    /// the round. A client dropped in pass 1 is skipped in pass 2; a
    /// client whose streamed update failed mid-frame leaves its
    /// remaining frames queued, which poisons *its own* channel for
    /// subsequent rounds (it keeps being dropped), never its neighbors.
    ///
    /// The same loop serves a flat server over all channels, one shard
    /// worker over its slice, and an edge aggregator over its subtree.
    /// `shard` only labels this slice's journal records (0 for a flat
    /// server, the worker/edge index otherwise).
    ///
    /// This is where per-client work actually happens, so it is also
    /// where the global telemetry counters absorb the slice's tallies —
    /// merge paths must *not* re-count received `ShardStats`.
    pub fn serve_round(
        &mut self,
        channels: &mut [Box<dyn Channel>],
        round: u32,
        raw_model_bytes: usize,
        shard: usize,
        agg: &mut RoundAgg,
    ) -> ShardStats {
        let span = journal::RoundSpan::at(round);
        let mut st = ShardStats::default();
        let mut dead = vec![false; channels.len()];
        for (idx, ch) in channels.iter_mut().enumerate() {
            match self.serve_state_check(ch.as_mut()) {
                Ok(true) => {
                    st.resyncs += 1;
                    span.client_event(shard, idx, "resync");
                }
                Ok(false) => {}
                Err(_) => {
                    dead[idx] = true;
                    st.dropped += 1;
                    span.client_event(shard, idx, "drop");
                }
            }
        }
        for (idx, ch) in channels.iter_mut().enumerate() {
            if dead[idx] {
                continue;
            }
            match self.serve_update(ch.as_mut(), round, agg) {
                Ok(served) => {
                    st.served += 1;
                    st.payload_bytes += served.wire_bytes;
                    st.raw_bytes += raw_model_bytes;
                    st.loss_sum += served.loss as f64;
                    st.decode_time += served.times.decode;
                    st.agg_time += served.times.agg;
                    span.client_served(
                        shard,
                        served.client as u64,
                        served.wire_bytes,
                        raw_model_bytes,
                        served.times.decode,
                        served.times.agg,
                        served.loss as f64,
                    );
                }
                Err(_) => {
                    dead[idx] = true;
                    st.dropped += 1;
                    span.client_event(shard, idx, "drop");
                }
            }
        }
        telemetry::record_shard(&st);
        st
    }
}

/// Parameter-server state.
pub struct Server {
    /// Global model parameters (flat per layer, matching `metas`).
    pub params: Vec<Vec<f32>>,
    /// Layer shapes. Treated as immutable after construction — the
    /// decode cores hold a shared snapshot taken by the constructor.
    pub metas: Vec<LayerMeta>,
    /// Server-side learning rate applied to the aggregated gradient.
    pub lr: f32,
    /// The decode half: engine + shared store + shared admissions.
    core: DecodeCore,
    /// Downlink broadcast compressor (`None` = raw f32 broadcast; even
    /// then the broadcast message is encoded once and fanned out).
    downlink: Option<DownlinkCodec>,
    /// Client id behind each channel index (recorded by `wait_hellos`;
    /// the downlink codec keys its synced-set on these).
    channel_ids: Vec<ClientId>,
    /// How rounds aggregate (`agg=exact|binsum`, see
    /// [`crate::compress::agg`]). Binsum-ineligible layers fall back
    /// per layer inside the aggregator, so this is always safe to set.
    agg_mode: AggMode,
    /// Per-round error-bound controller (`ebc=` key; `None` = fixed eb,
    /// no plan broadcast, legacy message sequences unchanged).
    controller: Option<Box<dyn EbController>>,
    round: u32,
}

impl Server {
    /// Full constructor: engine + explicit store backend.
    pub fn new(
        params: Vec<Vec<f32>>,
        metas: Vec<LayerMeta>,
        lr: f32,
        engine: Box<dyn CodecEngine>,
        store: Box<dyn StateStore>,
    ) -> Self {
        let core = DecodeCore {
            metas: Arc::new(metas.clone()),
            engine,
            store: Arc::from(store),
            admissions: Arc::new(Admissions::default()),
        };
        Server {
            params,
            metas,
            lr,
            core,
            downlink: None,
            channel_ids: Vec::new(),
            agg_mode: AggMode::Exact,
            controller: None,
            round: 0,
        }
    }

    /// Attach a downlink broadcast compressor: the per-round global
    /// delta is encoded once and fanned out to every participant (see
    /// [`crate::compress::downlink`]).
    pub fn with_downlink(mut self, downlink: DownlinkCodec) -> Self {
        self.downlink = Some(downlink);
        self
    }

    /// Whether a downlink codec is attached (the sharded/edge topologies
    /// require the raw encode-once broadcast).
    pub fn has_downlink(&self) -> bool {
        self.downlink.is_some()
    }

    /// Select the aggregation route for subsequent rounds.
    pub fn with_agg_mode(mut self, mode: AggMode) -> Self {
        self.agg_mode = mode;
        self
    }

    pub fn agg_mode(&self) -> AggMode {
        self.agg_mode
    }

    /// Attach a per-round error-bound controller (`ebc=` key; see
    /// [`crate::compress::control`]). When the controller emits a plan
    /// for a round, it is applied to this server's engine and broadcast
    /// as a `Msg::EbPlan` record ahead of the params broadcast.
    pub fn with_controller(mut self, controller: Box<dyn EbController>) -> Self {
        self.controller = Some(controller);
        self
    }

    /// Consult the controller for this round's plan. On `Some`, the
    /// server's own decode engine adopts it immediately; the caller is
    /// responsible for delivering the identical plan to every client
    /// (and every forked core) before any payload of the round.
    pub fn plan_round_eb(&mut self) -> Option<EbPlan> {
        let plan = self.controller.as_mut()?.plan(self.round)?;
        self.core.apply_eb_plan(&plan);
        Some(plan)
    }

    /// Feed the round's observed signals back to the controller (no-op
    /// without one).
    pub fn observe_round(&mut self, sig: &EbSignals) {
        if let Some(c) = self.controller.as_mut() {
            c.observe(sig);
        }
    }

    /// Apply a plan to this server's decode engine directly (the
    /// simulation paths plan outside the server; see
    /// [`Self::plan_round_eb`] for the in-server path).
    pub fn apply_eb_plan(&mut self, plan: &EbPlan) {
        self.core.apply_eb_plan(plan);
    }

    /// Fresh per-round aggregator matching the configured route (drive
    /// it through [`Self::absorb_payload`] then [`Self::finish_round`]).
    pub fn new_round_agg(&self) -> RoundAgg {
        RoundAgg::for_mode(self.agg_mode)
    }

    /// The downlink reference model — bit-identical to every synced
    /// client's view (`None` without a downlink codec or before the
    /// first broadcast).
    pub fn downlink_reference(&self) -> Option<&[Vec<f32>]> {
        self.downlink.as_ref().and_then(|d| d.reference())
    }

    /// Convenience: engine over an unbounded sharded in-memory store.
    pub fn with_engine(
        params: Vec<Vec<f32>>,
        metas: Vec<LayerMeta>,
        lr: f32,
        engine: Box<dyn CodecEngine>,
    ) -> Self {
        Self::new(params, metas, lr, engine, Box::new(ShardedMemStore::new(8, None)))
    }

    pub fn round(&self) -> u32 {
        self.round
    }

    /// Fork the decode half for a shard worker: a fresh engine wrapped
    /// around shared handles to *this* server's store, metas, and
    /// admission registry — one membership and one state-store across
    /// all workers, engines per worker.
    pub fn fork_core(&self, engine: Box<dyn CodecEngine>) -> DecodeCore {
        DecodeCore {
            metas: Arc::clone(&self.core.metas),
            engine,
            store: Arc::clone(&self.core.store),
            admissions: Arc::clone(&self.core.admissions),
        }
    }

    /// Admit a client id (the transportless simulation path's `Hello`).
    pub fn admit(&mut self, client: ClientId) {
        self.core.admit(client);
    }

    /// Open admission: treat every client id as admitted. For synthetic
    /// large-fleet drivers where materializing the id set would make
    /// server memory O(clients).
    pub fn admit_all(&mut self) {
        self.core.admissions.admit_all();
    }

    pub fn is_admitted(&self, client: ClientId) -> bool {
        self.core.is_admitted(client)
    }

    /// Current state-store occupancy.
    pub fn store_stats(&self) -> StoreStats {
        self.core.store.stats()
    }

    /// Peek a client's stored state epoch (observability; `None` when no
    /// state is held — never seen, reset, or evicted).
    pub fn state_epoch(&self, client: ClientId) -> crate::Result<Option<StateEpoch>> {
        self.core.store.epoch(client)
    }

    /// Uncompressed f32 bytes of one full model broadcast/update.
    pub fn raw_model_bytes(&self) -> usize {
        self.core.raw_model_bytes()
    }

    /// Fill a round's store-occupancy fields: held mirror states and
    /// their bytes across *both* tiers (resident + spilled), so the
    /// state-memory trajectory is honest for disk-backed stores too.
    pub fn record_store_occupancy(&self, stats: &mut RoundStats) {
        let occ = self.core.store.stats();
        stats.store_clients = occ.resident_clients + occ.spilled_clients;
        stats.store_bytes = occ.resident_bytes + occ.spilled_bytes;
        telemetry::STORE_RESIDENT_CLIENTS.set(stats.store_clients as u64);
        telemetry::STORE_RESIDENT_BYTES.set(stats.store_bytes as u64);
    }

    /// See [`DecodeCore::check_state`].
    pub fn check_state(
        &mut self,
        client: ClientId,
        client_epoch: StateEpoch,
    ) -> crate::Result<bool> {
        self.core.check_state(client, client_epoch)
    }

    /// See [`DecodeCore::absorb_payload`]. (Exposed for the
    /// single-threaded simulation path and the direct-drive topology
    /// tests.)
    pub fn absorb_payload(
        &mut self,
        client: ClientId,
        payload: &[u8],
        weight: f64,
        agg: &mut RoundAgg,
    ) -> crate::Result<AbsorbTimes> {
        self.core.absorb_payload(client, payload, weight, agg)
    }

    /// Finish the round: fold the aggregator (for `agg=binsum` this is
    /// the single dequantize-and-divide), apply the mean gradient to
    /// the global parameters, and report the per-layer routes taken.
    pub fn finish_round(&mut self, agg: RoundAgg) -> AggReport {
        let t0 = Instant::now();
        let (mean, mut report) = agg.finish();
        if !mean.is_empty() {
            apply_update(&mut self.params, &mean, self.lr);
        }
        report.finish_time = t0.elapsed();
        telemetry::ROUNDS.inc();
        telemetry::FINISH_NS.add_duration(report.finish_time);
        self.round += 1;
        report
    }

    /// Broadcast this round's model to every channel. The message bytes
    /// are encoded **once** and fanned out as the same shared buffer —
    /// for both the raw `GlobalParams` path and the compressed
    /// delta/full-sync path. Per-channel sends are best-effort: a dead
    /// channel surfaces as a dropped client in the receive passes
    /// instead of aborting the broadcast.
    fn broadcast(
        &mut self,
        channels: &mut [Box<dyn Channel>],
        round: u32,
        stats: &mut RoundStats,
    ) -> crate::Result<()> {
        let raw_model_bytes = self.core.raw_model_bytes();
        stats.downlink_raw_bytes = raw_model_bytes * channels.len();
        // Byte accounting convention (matches the uplink and the
        // run_local simulation): frame/tensor payload bytes only, no
        // `Msg` envelope — so threaded and simulated runs of the same
        // config report the same down CR, and the raw path reads 1.0.
        match &mut self.downlink {
            None => {
                let bytes: Arc<[u8]> = Msg::encode_global_params(round, &self.params).into();
                stats.downlink_bytes = raw_model_bytes * channels.len();
                for ch in channels.iter_mut() {
                    let _ = ch.send_encoded(&bytes);
                }
            }
            Some(down) => {
                anyhow::ensure!(
                    self.channel_ids.len() == channels.len(),
                    "downlink broadcast needs the Hello id behind every channel \
                     (run wait_hellos first)"
                );
                let bc = down.encode_round(&self.params, &self.channel_ids)?;
                stats.down_codec_time += bc.stats.encode_time;
                let delta_payload = bc.stats.delta_bytes;
                // Encode each message once; every recipient gets the
                // same buffers.
                let delta_msgs: Option<(Arc<[u8]>, Vec<Arc<[u8]>>)> = bc.delta.map(|d| {
                    let begin: Arc<[u8]> = Msg::DeltaBegin {
                        round,
                        n_layers: d.frames.len() as u32,
                        reset: d.reset,
                    }
                    .encode()
                    .into();
                    let frames = d
                        .frames
                        .iter()
                        .map(|f| Msg::DeltaFrame { round, frame: f.to_wire() }.encode())
                        .map(Arc::from)
                        .collect();
                    (begin, frames)
                });
                let full_sync: Option<Arc<[u8]>> = if bc.cold.is_empty() {
                    None
                } else {
                    let reference = down
                        .reference()
                        .ok_or_else(|| anyhow::anyhow!("downlink reference missing"))?;
                    Some(Msg::encode_full_sync(round, reference).into())
                };
                let cold: HashSet<ClientId> = bc.cold.into_iter().collect();
                for (idx, ch) in channels.iter_mut().enumerate() {
                    if cold.contains(&self.channel_ids[idx]) {
                        let bytes = full_sync
                            .as_ref()
                            .ok_or_else(|| anyhow::anyhow!("cold client without full sync"))?;
                        stats.full_syncs += 1;
                        stats.downlink_bytes += raw_model_bytes;
                        let _ = ch.send_encoded(bytes);
                    } else {
                        let (begin, frames) = delta_msgs
                            .as_ref()
                            .ok_or_else(|| anyhow::anyhow!("warm client without a delta"))?;
                        stats.downlink_bytes += delta_payload;
                        let _ = ch.send_encoded(begin);
                        for f in frames {
                            let _ = ch.send_encoded(f);
                        }
                    }
                }
            }
        }
        // `stats` is fresh per round, so the fields are this broadcast's
        // whole contribution.
        telemetry::DOWNLINK_BYTES.add(stats.downlink_bytes as u64);
        telemetry::DOWNLINK_RAW_BYTES.add(stats.downlink_raw_bytes as u64);
        telemetry::DOWNLINK_FULL_SYNCS.add(stats.full_syncs as u64);
        Ok(())
    }

    /// Full synchronous round over live channels (threaded/TCP mode):
    /// broadcast params (encode-once fan-out; compressed delta when a
    /// downlink codec is attached), run the state handshake, collect
    /// updates (monolithic or frame-streamed), aggregate, step. A
    /// faulty client is dropped whole and counted in
    /// `RoundStats.dropped`; the round itself always completes.
    pub fn run_round(&mut self, channels: &mut [Box<dyn Channel>]) -> crate::Result<RoundStats> {
        let round = self.round;
        let mut stats = RoundStats {
            round,
            participants: channels.len(),
            shards: 1,
            ..Default::default()
        };
        let span = journal::RoundSpan::begin(round, 1);
        // Error-bound plan first: every client must derive the round's
        // quantizer before any params/update traffic. Encode once, fan
        // out the shared buffer; a dead channel is dropped later by the
        // receive passes, same as the params broadcast.
        if let Some(plan) = self.plan_round_eb() {
            let bytes: Arc<[u8]> =
                Msg::EbPlan { round, plan: plan.to_wire() }.encode().into();
            for ch in channels.iter_mut() {
                let _ = ch.send_encoded(&bytes);
            }
            span.eb_plan(&plan);
            telemetry::ROUND_EB.set((plan.round_eb as f64 * 1e9) as u64);
            stats.round_eb = Some(plan.round_eb);
        }
        self.broadcast(channels, round, &mut stats)?;
        span.downlink(
            stats.downlink_bytes,
            stats.downlink_raw_bytes,
            stats.full_syncs,
            stats.down_codec_time,
            Duration::ZERO,
        );
        let raw_model_bytes = self.core.raw_model_bytes();
        let mut agg = self.new_round_agg();
        let shard = self.core.serve_round(channels, round, raw_model_bytes, 0, &mut agg);
        span.shard(0, &shard);
        let served = shard.served;
        shard.fold_into(&mut stats);
        stats.mean_loss /= served.max(1) as f64;
        // The threaded path has no held-out eval; the controller sees
        // the mean training loss and the per-shard byte totals.
        self.observe_round(&EbSignals {
            round,
            train_loss: stats.mean_loss,
            eval: None,
            layer_bytes: Vec::new(),
        });
        self.record_store_occupancy(&mut stats);
        span.store(stats.store_clients, stats.store_bytes);
        let rep = self.finish_round(agg);
        stats.agg_time += rep.finish_time;
        stats.binsum_layers = rep.binsum_layers;
        stats.exact_layers = rep.exact_layers + rep.mixed_layers;
        stats.dequant_passes = rep.dequant_passes;
        span.finish(
            rep.finish_time,
            stats.binsum_layers,
            stats.exact_layers,
            stats.dequant_passes,
        );
        span.participants(stats.participants);
        span.end(&stats);
        Ok(stats)
    }

    /// Send shutdown to all clients (best-effort: already-dead channels
    /// are skipped, matching the round-level fault model).
    pub fn shutdown(&self, channels: &mut [Box<dyn Channel>]) -> crate::Result<()> {
        for ch in channels.iter_mut() {
            let _ = ch.send(&Msg::Shutdown);
        }
        Ok(())
    }

    /// Wait for the Hello of every client (threaded/TCP mode), admitting
    /// each announced id and recording which id sits behind each channel
    /// (the downlink broadcast plans its fan-out against these). A
    /// duplicate id is rejected with an `Err`: two channels claiming one
    /// id would corrupt the `channel_ids`-keyed downlink fan-out and
    /// silently share predictor state.
    pub fn wait_hellos(&mut self, channels: &mut [Box<dyn Channel>]) -> crate::Result<()> {
        self.channel_ids.clear();
        let mut seen = HashSet::new();
        for ch in channels.iter_mut() {
            match ch.recv()? {
                Msg::Hello { client_id } => {
                    anyhow::ensure!(
                        seen.insert(client_id),
                        "duplicate Hello for client {client_id}: one id, one channel"
                    );
                    self.core.admit(client_id);
                    self.channel_ids.push(client_id);
                }
                other => anyhow::bail!("expected Hello, got {other:?}"),
            }
        }
        Ok(())
    }

    /// View the current global parameters as a ModelGrad-shaped object
    /// (for checkpoint compression examples).
    pub fn params_as_model(&self) -> ModelGrad {
        ModelGrad {
            layers: self
                .metas
                .iter()
                .zip(&self.params)
                .map(|(m, p)| LayerGrad::new(m.clone(), p.clone()))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::pipeline::{FedgecCodec, FedgecConfig, FedgecEngine};
    use crate::compress::predictor::magnitude::MagnitudeSel;
    use crate::compress::predictor::sign::SignSel;
    use crate::compress::predictor::PredictorSpec;
    use crate::compress::quant::ErrorBound;
    use crate::compress::GradientCodec;
    use crate::fl::aggregate::FedAvg;
    use crate::tensor::LayerMeta;
    use crate::util::rng::Rng;

    fn small_model() -> (Vec<Vec<f32>>, Vec<LayerMeta>) {
        let metas = vec![LayerMeta::dense("fc", 1500, 1), LayerMeta::other("b", 8)];
        let params = vec![vec![0.0; 1500], vec![0.0; 8]];
        (params, metas)
    }

    fn server() -> Server {
        let (params, metas) = small_model();
        Server::with_engine(
            params,
            metas,
            0.1,
            Box::new(FedgecEngine::new(FedgecConfig::default())),
        )
    }

    /// State-free abs-eb spec: the fleet-wide single-Δ regime where a
    /// fresh codec per round is the same codec.
    fn state_free_cfg() -> FedgecConfig {
        FedgecConfig {
            error_bound: ErrorBound::Abs(2e-3),
            predictor: PredictorSpec { mag: MagnitudeSel::Zero, sign: SignSel::None },
            ..Default::default()
        }
    }

    fn grads(metas: &[LayerMeta], rng: &mut Rng) -> ModelGrad {
        ModelGrad {
            layers: metas
                .iter()
                .map(|m| {
                    let data: Vec<f32> =
                        (0..m.numel).map(|_| rng.normal_f32(0.0, 0.5)).collect();
                    LayerGrad::new(m.clone(), data)
                })
                .collect(),
        }
    }

    #[test]
    fn unknown_client_is_err_not_panic() {
        let mut srv = server();
        let mut agg = RoundAgg::Exact(FedAvg::new());
        // Out-of-range / never-admitted ids used to panic on
        // `self.codecs[client_idx]`; now they are a proper Err.
        let err = srv.absorb_payload(99, &[1, 2, 3], 1.0, &mut agg).unwrap_err();
        assert!(err.to_string().contains("unknown client 99"), "{err}");
        assert!(srv.check_state(99, StateEpoch::cold()).is_err());
        srv.admit(7);
        assert!(srv.is_admitted(7) && !srv.is_admitted(99));
        // Open admission flips every id to admitted (synthetic fleets).
        srv.admit_all();
        assert!(srv.is_admitted(99));
    }

    #[test]
    fn state_handshake_warm_and_cold_paths() {
        let mut srv = server();
        srv.admit(0);
        let metas = srv.metas.clone();
        let mut rng = Rng::new(3);
        let mut client = FedgecCodec::new(FedgecConfig::default());
        let mut epoch = StateEpoch::cold();
        // Round 1: both cold — no reset.
        assert!(!srv.check_state(0, epoch).unwrap());
        let mut agg = srv.new_round_agg();
        let p = client.compress(&grads(&metas, &mut rng)).unwrap();
        srv.absorb_payload(0, &p, 1.0, &mut agg).unwrap();
        epoch.advance(client.state_fingerprint());
        // Round 2: warm on both sides — still no reset, epochs agree.
        assert!(!srv.check_state(0, epoch).unwrap());
        // Client loses its state (simulated device churn): mismatch ⇒
        // reset ordered, server copy dropped.
        let fresh = FedgecCodec::new(FedgecConfig::default());
        assert!(srv.check_state(0, StateEpoch::cold()).unwrap());
        assert_eq!(srv.store_stats().resident_clients, 0);
        // Cold restart re-converges.
        let mut client = fresh;
        let p = client.compress(&grads(&metas, &mut rng)).unwrap();
        srv.absorb_payload(0, &p, 1.0, &mut agg).unwrap();
        let mut epoch = StateEpoch::cold();
        epoch.advance(client.state_fingerprint());
        assert!(!srv.check_state(0, epoch).unwrap());
    }

    #[test]
    fn binsum_round_matches_exact_round() {
        // Two servers over the SAME client payloads: agg=binsum must
        // track agg=exact within 1e-5 relative while dequantizing each
        // bin-routed layer exactly once.
        let cfg = state_free_cfg();
        let (params, metas) = small_model();
        let mut exact = Server::with_engine(
            params.clone(),
            metas.clone(),
            0.1,
            Box::new(FedgecEngine::new(cfg.clone())),
        );
        let mut bin = Server::with_engine(
            params,
            metas.clone(),
            0.1,
            Box::new(FedgecEngine::new(cfg.clone())),
        )
        .with_agg_mode(AggMode::Binsum);
        assert_eq!(bin.agg_mode(), AggMode::Binsum);
        let mut rng = Rng::new(77);
        for round in 0..3 {
            let mut agg_e = exact.new_round_agg();
            let mut agg_b = bin.new_round_agg();
            for client in 0..3u64 {
                exact.admit(client);
                bin.admit(client);
                // State-free mode: a fresh codec per round is the same
                // codec (no cross-round state to warm).
                let mut codec = FedgecCodec::new(cfg.clone());
                let p = codec.compress(&grads(&metas, &mut rng)).unwrap();
                let w = (client + 1) as f64;
                exact.absorb_payload(client, &p, w, &mut agg_e).unwrap();
                bin.absorb_payload(client, &p, w, &mut agg_b).unwrap();
            }
            let re = exact.finish_round(agg_e);
            let rb = bin.finish_round(agg_b);
            assert_eq!(re.binsum_layers, 0);
            // fc (1500 > t_lossy) rides the bin route; the small bias
            // layer is stored lossless and falls back dense.
            assert_eq!(rb.binsum_layers, 1, "round {round}");
            assert_eq!(rb.exact_layers, 1, "round {round}");
            assert_eq!(rb.dequant_passes, 1, "round {round}");
            for (a, b) in exact.params.iter().flatten().zip(bin.params.iter().flatten()) {
                assert!(
                    (a - b).abs() <= 1e-5 * a.abs().max(1e-3),
                    "round {round}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn failed_decode_drops_server_state() {
        let mut srv = server();
        srv.admit(1);
        let metas = srv.metas.clone();
        let mut rng = Rng::new(9);
        let mut client = FedgecCodec::new(FedgecConfig::default());
        let mut agg = srv.new_round_agg();
        let p = client.compress(&grads(&metas, &mut rng)).unwrap();
        srv.absorb_payload(1, &p, 1.0, &mut agg).unwrap();
        assert_eq!(srv.store_stats().resident_clients, 1);
        assert!(srv.absorb_payload(1, &[0xFF; 16], 1.0, &mut agg).is_err());
        // Corrupt payload must not leave a half-updated mirror behind.
        assert_eq!(srv.store_stats().resident_clients, 0);
    }

    #[test]
    fn duplicate_hello_is_rejected() {
        use crate::fl::transport::inproc::pair;
        let mut srv = server();
        let (s1, mut c1) = pair(None);
        let (s2, mut c2) = pair(None);
        c1.send(&Msg::Hello { client_id: 5 }).unwrap();
        c2.send(&Msg::Hello { client_id: 5 }).unwrap();
        let mut chans: Vec<Box<dyn Channel>> = vec![Box::new(s1), Box::new(s2)];
        let err = srv.wait_hellos(&mut chans).unwrap_err();
        assert!(err.to_string().contains("duplicate Hello"), "{err}");
        // Distinct ids are admitted as before.
        let (s1, mut c1) = pair(None);
        let (s2, mut c2) = pair(None);
        c1.send(&Msg::Hello { client_id: 5 }).unwrap();
        c2.send(&Msg::Hello { client_id: 6 }).unwrap();
        let mut chans: Vec<Box<dyn Channel>> = vec![Box::new(s1), Box::new(s2)];
        srv.wait_hellos(&mut chans).unwrap();
        assert!(srv.is_admitted(5) && srv.is_admitted(6));
    }

    #[test]
    fn faulty_channels_drop_clients_not_the_round() {
        use crate::fl::transport::inproc::pair;
        let cfg = state_free_cfg();
        let (params, metas) = small_model();
        let mut srv = Server::with_engine(
            params,
            metas.clone(),
            0.1,
            Box::new(FedgecEngine::new(cfg.clone())),
        );
        // Four clients: 0 and 1 behave; 2 hangs up right after the
        // first broadcast; 3 uploads a corrupt payload every round.
        let mut server_ends: Vec<Box<dyn Channel>> = Vec::new();
        let mut handles = Vec::new();
        for id in 0..4u32 {
            let (s, mut c) = pair(None);
            server_ends.push(Box::new(s));
            let cfg = cfg.clone();
            let metas = metas.clone();
            handles.push(std::thread::spawn(move || {
                c.send(&Msg::Hello { client_id: id }).unwrap();
                for round in 0..2u32 {
                    match c.recv().unwrap() {
                        Msg::GlobalParams { .. } => {}
                        other => panic!("client {id}: unexpected {other:?}"),
                    }
                    if id == 2 {
                        return; // channel goes dead mid-round
                    }
                    c.send(&Msg::StateCheck { client_id: id, rounds: 0, fingerprint: 0 })
                        .unwrap();
                    match c.recv().unwrap() {
                        Msg::StateResync { .. } => {}
                        other => panic!("client {id}: unexpected {other:?}"),
                    }
                    let payload = if id == 3 {
                        vec![0xFF; 64] // decode must fail server-side
                    } else {
                        let mut rng = Rng::new(100 + (id + round * 10) as u64);
                        FedgecCodec::new(cfg.clone())
                            .compress(&grads(&metas, &mut rng))
                            .unwrap()
                    };
                    c.send(&Msg::Update {
                        client_id: id,
                        round,
                        payload,
                        train_loss: 0.5,
                        n_samples: 8,
                    })
                    .unwrap();
                }
                // Drain until shutdown so server sends never race the
                // channel teardown.
                loop {
                    match c.recv() {
                        Ok(Msg::Shutdown) | Err(_) => return,
                        Ok(_) => {}
                    }
                }
            }));
        }
        srv.wait_hellos(&mut server_ends).unwrap();
        for round in 0..2 {
            let stats = srv.run_round(&mut server_ends).unwrap();
            assert_eq!(stats.participants, 4);
            assert_eq!(stats.dropped, 2, "round {round}: hung-up + corrupt client");
            assert_eq!(stats.shards, 1);
            // The healthy clients' losses still average cleanly.
            assert!((stats.mean_loss - 0.5).abs() < 1e-9, "round {round}");
        }
        srv.shutdown(&mut server_ends).unwrap();
        for h in handles {
            h.join().unwrap();
        }
    }
}
