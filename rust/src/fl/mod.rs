//! The federated-learning runtime: a FedAvg parameter server, clients,
//! pluggable transports (in-process channels and real TCP) and a
//! token-bucket bandwidth simulator — the Rust equivalent of the APPFL
//! stack the paper integrates into (§5.1), with the compressor as a
//! first-class feature of the wire path.

pub mod aggregate;
pub mod client;
pub mod hetero;
pub mod protocol;
pub mod round;
pub mod server;
pub mod transport;
