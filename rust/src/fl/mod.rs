//! The federated-learning runtime: a FedAvg parameter server, clients,
//! pluggable transports (in-process channels and real TCP) and a
//! token-bucket bandwidth simulator — the Rust equivalent of the APPFL
//! stack the paper integrates into (§5.1), with the compressor as a
//! first-class feature of the wire path.
//!
//! The server scales by *not* mirroring one codec per client: it pairs
//! one stateless [`crate::compress::engine::CodecEngine`] with a keyed
//! [`crate::compress::store::StateStore`] of per-client predictor
//! states, and the `StateCheck`/`StateResync` protocol handshake keeps
//! dropout, rejoin and eviction deterministic (see `DESIGN.md` §8).
//!
//! Both traffic directions compress: uploads as per-client gradient
//! payloads, and the broadcast as a **global-model delta** encoded once
//! and fanned out to every participant as shared bytes, with cold
//! clients bootstrapped by `FullSync` (see
//! [`crate::compress::downlink`] and `DESIGN.md` §9).
//!
//! Beyond the flat single-thread server loop, [`topology`] scales the
//! round itself: a sharded round runner that partitions channels across
//! worker threads (each with its own decode core and partial
//! aggregate, merged tree-wise at round end) and an edge-aggregator
//! tier that collapses whole subtrees into one uplink contribution —
//! the million-client configuration (see `DESIGN.md` §13).

pub mod aggregate;
pub mod client;
pub mod hetero;
pub mod protocol;
pub mod round;
pub mod server;
pub mod topology;
pub mod transport;

pub use crate::compress::store::ClientId;
