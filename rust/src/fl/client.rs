//! The FL client: local training + gradient compression (paper Fig. 1
//! workflow, client side of Alg. 3).
//!
//! By default the client **streams** its update as per-layer frames: a
//! worker thread runs the encoder session while this thread pushes
//! finished frames into the (possibly bandwidth-throttled) channel, so
//! layer `i+1` compresses while layer `i` transmits — the comm/comp
//! overlap behind the paper's end-to-end win. Set `stream = false` to
//! fall back to the monolithic `Msg::Update` blob.

use std::sync::mpsc;

use crate::compress::downlink::DownlinkMirror;
use crate::compress::frame::Frame;
use crate::compress::session::EncodeSession;
use crate::compress::state::StateEpoch;
use crate::compress::GradientCodec;
use crate::fl::protocol::Msg;
use crate::fl::transport::Channel;
use crate::tensor::{LayerMeta, ModelGrad};

/// Local-training backend owned by one client.
pub trait LocalTrainer: Send {
    /// Run one local round from the given global parameters; return the
    /// round gradient (accumulated update direction) and the training
    /// loss. `(θ_global − θ_local)/lr` for SGD trainers.
    fn train_round(&mut self, params: &[Vec<f32>]) -> crate::Result<(ModelGrad, f32)>;

    /// Layer metadata describing the gradient tensors.
    fn layer_metas(&self) -> Vec<LayerMeta>;

    /// Number of local samples (FedAvg weight).
    fn n_samples(&self) -> usize;
}

/// A federated client: trainer + codec + identity.
pub struct Client {
    pub id: u32,
    pub trainer: Box<dyn LocalTrainer>,
    pub codec: Box<dyn GradientCodec>,
    /// Stream per-layer frames (default) instead of one monolithic blob.
    pub stream: bool,
    /// Epoch of the codec's mirrored predictor state: advanced after
    /// every uploaded round, announced to the server in `StateCheck`
    /// before the next one. Survives dropout (the client just rejoins
    /// with its last epoch); reset to cold on a `StateResync`.
    pub epoch: StateEpoch,
    /// Downlink delta mirror (`None` = the server broadcasts raw
    /// `GlobalParams`). Must match the server's `down` codec spec.
    pub downlink: Option<DownlinkMirror>,
}

impl Client {
    pub fn new(id: u32, trainer: Box<dyn LocalTrainer>, codec: Box<dyn GradientCodec>) -> Self {
        Client { id, trainer, codec, stream: true, epoch: StateEpoch::cold(), downlink: None }
    }

    /// Select monolithic vs frame-streamed uploads.
    pub fn with_streaming(mut self, stream: bool) -> Self {
        self.stream = stream;
        self
    }

    /// Attach the downlink delta mirror (required when the server runs a
    /// downlink codec: the broadcast arrives as `FullSync`/`DeltaBegin`
    /// instead of `GlobalParams`).
    pub fn with_downlink(mut self, mirror: DownlinkMirror) -> Self {
        self.downlink = Some(mirror);
        self
    }

    /// One local round: train, compress, report (payload, loss, raw bytes).
    pub fn local_round(&mut self, params: &[Vec<f32>]) -> crate::Result<(Vec<u8>, f32, usize)> {
        let (grads, loss) = self.trainer.train_round(params)?;
        let raw = grads.byte_size();
        let payload = self.codec.compress(&grads)?;
        Ok((payload, loss, raw))
    }

    /// One streamed round: train, then pipeline per-layer encoding with
    /// sending. The encoder runs on a scoped worker thread; this thread
    /// drains finished frames into the channel, so a throttled `send`
    /// overlaps with the next layer's compression.
    fn streamed_round(
        &mut self,
        round: u32,
        params: &[Vec<f32>],
        channel: &mut dyn Channel,
    ) -> crate::Result<()> {
        let (grads, train_loss) = self.trainer.train_round(params)?;
        let n_layers = grads.layers.len();
        channel.send(&Msg::UpdateBegin {
            client_id: self.id,
            round,
            n_layers: n_layers as u32,
            train_loss,
            n_samples: self.trainer.n_samples() as u32,
        })?;
        let client_id = self.id;
        let mut session = EncodeSession::new(self.codec.as_mut(), n_layers)?;
        // Small buffer: keeps at most a couple of encoded frames in
        // flight, so compression stays just ahead of the link.
        let (tx, rx) = mpsc::sync_channel::<crate::Result<Frame>>(2);
        std::thread::scope(|scope| -> crate::Result<()> {
            scope.spawn(move || {
                for layer in &grads.layers {
                    let frame = session.encode_layer(layer);
                    let stop = frame.is_err();
                    if tx.send(frame).is_err() || stop {
                        break;
                    }
                }
            });
            for frame in rx {
                let frame = frame?;
                channel.send(&Msg::UpdateFrame {
                    client_id,
                    round,
                    frame: frame.to_wire(),
                })?;
            }
            Ok(())
        })
    }

    /// Announce the state epoch and obey the server's resync verdict
    /// (runs once per round, before training). On reset both sides have
    /// agreed to the codec's round-1 cold-start path.
    fn state_handshake(&mut self, channel: &mut dyn Channel) -> crate::Result<()> {
        channel.send(&Msg::StateCheck {
            client_id: self.id,
            rounds: self.epoch.rounds,
            fingerprint: self.codec.state_fingerprint(),
        })?;
        match channel.recv()? {
            Msg::StateResync { reset, .. } => {
                if reset {
                    self.codec.reset();
                    self.epoch = StateEpoch::cold();
                }
                Ok(())
            }
            other => anyhow::bail!("client {}: expected StateResync, got {other:?}", self.id),
        }
    }

    /// One full round against resolved global parameters: handshake,
    /// train, upload (streamed or monolithic), advance the state epoch.
    fn round_body(
        &mut self,
        round: u32,
        params: &[Vec<f32>],
        channel: &mut dyn Channel,
    ) -> crate::Result<()> {
        self.state_handshake(channel)?;
        if self.stream {
            self.streamed_round(round, params, channel)?;
        } else {
            let (payload, train_loss, _) = self.local_round(params)?;
            channel.send(&Msg::Update {
                client_id: self.id,
                round,
                payload,
                train_loss,
                n_samples: self.trainer.n_samples() as u32,
            })?;
        }
        self.epoch.advance(self.codec.state_fingerprint());
        Ok(())
    }

    fn downlink_mirror(&mut self, what: &str) -> crate::Result<&mut DownlinkMirror> {
        let id = self.id;
        self.downlink
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("client {id}: {what} without a downlink codec"))
    }

    /// Blocking message loop against a server channel (threaded/TCP mode).
    pub fn run(&mut self, channel: &mut dyn Channel) -> crate::Result<()> {
        channel.send(&Msg::Hello { client_id: self.id })?;
        loop {
            match channel.recv()? {
                Msg::GlobalParams { round, tensors } => {
                    self.round_body(round, &tensors, channel)?;
                }
                Msg::FullSync { round, tensors } => {
                    let mirror = self.downlink_mirror("FullSync")?;
                    mirror.full_sync(tensors)?;
                    let params = mirror.params().expect("full_sync leaves a reference").to_vec();
                    self.round_body(round, &params, channel)?;
                }
                Msg::DeltaBegin { round, n_layers, reset } => {
                    // Bound the wire-declared count by the model before
                    // allocating or blocking on frames (corrupt-stream
                    // OOM guard, same discipline as decode_bounded).
                    let expected = self.downlink_mirror("DeltaBegin")?.metas().len();
                    anyhow::ensure!(
                        n_layers as usize == expected,
                        "client {}: delta declares {n_layers} layers, model has {expected}",
                        self.id
                    );
                    let mut frames = Vec::with_capacity(expected);
                    for _ in 0..n_layers {
                        match channel.recv()? {
                            Msg::DeltaFrame { round: r, frame } => {
                                anyhow::ensure!(
                                    r == round,
                                    "client {}: delta frame for round {r} during round {round}",
                                    self.id
                                );
                                frames.push(Frame::from_wire(&frame)?);
                            }
                            other => anyhow::bail!(
                                "client {}: expected DeltaFrame, got {other:?}",
                                self.id
                            ),
                        }
                    }
                    let mirror = self.downlink_mirror("DeltaBegin")?;
                    let params = mirror.apply_delta(reset, &frames)?.to_vec();
                    self.round_body(round, &params, channel)?;
                }
                Msg::EbPlan { plan, .. } => {
                    // The round's error-bound plan precedes the params
                    // broadcast; adopt it before any compression so the
                    // quantizer (and the mirror eb tag) matches the
                    // server bit for bit.
                    let plan = crate::compress::control::EbPlan::from_wire(&plan)?;
                    self.codec.apply_eb_plan(&plan);
                }
                Msg::Shutdown => return Ok(()),
                other => anyhow::bail!("client {}: unexpected {other:?}", self.id),
            }
        }
    }
}
