//! The FL client: local training + gradient compression (paper Fig. 1
//! workflow, client side of Alg. 3).

use crate::compress::GradientCodec;
use crate::fl::protocol::Msg;
use crate::fl::transport::Channel;
use crate::tensor::{LayerMeta, ModelGrad};

/// Local-training backend owned by one client.
pub trait LocalTrainer: Send {
    /// Run one local round from the given global parameters; return the
    /// round gradient (accumulated update direction) and the training
    /// loss. `(θ_global − θ_local)/lr` for SGD trainers.
    fn train_round(&mut self, params: &[Vec<f32>]) -> crate::Result<(ModelGrad, f32)>;

    /// Layer metadata describing the gradient tensors.
    fn layer_metas(&self) -> Vec<LayerMeta>;

    /// Number of local samples (FedAvg weight).
    fn n_samples(&self) -> usize;
}

/// A federated client: trainer + codec + identity.
pub struct Client {
    pub id: u32,
    pub trainer: Box<dyn LocalTrainer>,
    pub codec: Box<dyn GradientCodec>,
}

impl Client {
    pub fn new(id: u32, trainer: Box<dyn LocalTrainer>, codec: Box<dyn GradientCodec>) -> Self {
        Client { id, trainer, codec }
    }

    /// One local round: train, compress, report (payload, loss, raw bytes).
    pub fn local_round(&mut self, params: &[Vec<f32>]) -> crate::Result<(Vec<u8>, f32, usize)> {
        let (grads, loss) = self.trainer.train_round(params)?;
        let raw = grads.byte_size();
        let payload = self.codec.compress(&grads)?;
        Ok((payload, loss, raw))
    }

    /// Blocking message loop against a server channel (threaded/TCP mode).
    pub fn run(&mut self, channel: &mut dyn Channel) -> crate::Result<()> {
        channel.send(&Msg::Hello { client_id: self.id })?;
        loop {
            match channel.recv()? {
                Msg::GlobalParams { round, tensors } => {
                    let (payload, train_loss, _) = self.local_round(&tensors)?;
                    channel.send(&Msg::Update {
                        client_id: self.id,
                        round,
                        payload,
                        train_loss,
                        n_samples: self.trainer.n_samples() as u32,
                    })?;
                }
                Msg::Shutdown => return Ok(()),
                other => anyhow::bail!("client {}: unexpected {other:?}", self.id),
            }
        }
    }
}
