//! The bandwidth-constrained link model (paper §2.1, §5.5): transmission
//! time = S′/B plus a fixed latency, optionally enforced in real time
//! (token bucket sleeping) or accounted analytically (fast simulation —
//! what the paper does by "calculating the expected transmission time
//! under limited bandwidth and introducing artificial latency" [43]).

use std::time::{Duration, Instant};

/// Link parameters. Real client links are asymmetric — 4G and Wi-Fi
/// downlinks run several times faster than their uplinks — so the spec
/// carries both directions; symmetric constructors set them equal.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    /// Uplink bandwidth in bits per second (e.g. `10e6` = 10 Mbps).
    pub bits_per_sec: f64,
    /// Downlink (server → client) bandwidth in bits per second.
    pub down_bits_per_sec: f64,
    /// One-way latency.
    pub latency: Duration,
}

impl LinkSpec {
    /// Symmetric link (uplink == downlink).
    pub fn sym(bits_per_sec: f64, latency: Duration) -> Self {
        LinkSpec { bits_per_sec, down_bits_per_sec: bits_per_sec, latency }
    }
    /// Symmetric link in Mbps with the stock 20 ms latency.
    pub fn mbps(mbps: f64) -> Self {
        Self::sym(mbps * 1e6, Duration::from_millis(20))
    }
    /// Asymmetric link in Mbps (down ≫ up on most access networks).
    pub fn asym_mbps(up_mbps: f64, down_mbps: f64) -> Self {
        LinkSpec {
            bits_per_sec: up_mbps * 1e6,
            down_bits_per_sec: down_mbps * 1e6,
            latency: Duration::from_millis(20),
        }
    }
    /// Unthrottled link.
    pub fn infinite() -> Self {
        Self::sym(f64::INFINITY, Duration::ZERO)
    }
    /// The same link seen from the other end: up and down swapped — the
    /// spec governing the *peer's* sends (the server transmits on the
    /// client's downlink).
    pub fn flipped(&self) -> LinkSpec {
        LinkSpec {
            bits_per_sec: self.down_bits_per_sec,
            down_bits_per_sec: self.bits_per_sec,
            latency: self.latency,
        }
    }
    fn time_at(&self, bytes: usize, bits_per_sec: f64) -> Duration {
        if !bits_per_sec.is_finite() {
            return self.latency;
        }
        let secs = (bytes as f64 * 8.0) / bits_per_sec;
        self.latency + Duration::from_secs_f64(secs)
    }
    /// Time to transmit `bytes` over the uplink.
    pub fn transmit_time(&self, bytes: usize) -> Duration {
        self.time_at(bytes, self.bits_per_sec)
    }
    /// Time to receive `bytes` over the downlink.
    pub fn downlink_time(&self, bytes: usize) -> Duration {
        self.time_at(bytes, self.down_bits_per_sec)
    }
}

/// Accounting-only link simulator: tracks virtual transmission time
/// without sleeping — used by the Fig. 11 bench to sweep 1 Mbps–1 Gbps in
/// reasonable wall-clock time.
#[derive(Debug, Clone)]
pub struct VirtualLink {
    pub spec: LinkSpec,
    pub bytes_sent: usize,
    pub virtual_time: Duration,
}

impl VirtualLink {
    pub fn new(spec: LinkSpec) -> Self {
        VirtualLink { spec, bytes_sent: 0, virtual_time: Duration::ZERO }
    }
    /// Account one transfer; returns its transmission time.
    pub fn send(&mut self, bytes: usize) -> Duration {
        let t = self.spec.transmit_time(bytes);
        self.bytes_sent += bytes;
        self.virtual_time += t;
        t
    }
}

/// Real-time throttler (token bucket): sleeps so the observed throughput
/// matches the link spec. Used by the TCP transport for live runs.
pub struct Throttler {
    spec: LinkSpec,
    /// Time before which the link is busy.
    busy_until: Instant,
}

impl Throttler {
    pub fn new(spec: LinkSpec) -> Self {
        Throttler { spec, busy_until: Instant::now() }
    }

    /// Block until `bytes` may be considered transmitted.
    pub fn consume(&mut self, bytes: usize) {
        let dur = self.spec.transmit_time(bytes);
        let now = Instant::now();
        let start = self.busy_until.max(now);
        self.busy_until = start + dur;
        let wait = self.busy_until.saturating_duration_since(now);
        if !wait.is_zero() {
            crate::telemetry::THROTTLE_WAIT_NS.add_duration(wait);
            std::thread::sleep(wait);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transmit_time_formula() {
        let link = LinkSpec::sym(8e6, Duration::ZERO);
        // 1 MB over 8 Mbps = 1 s.
        assert!((link.transmit_time(1_000_000).as_secs_f64() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn latency_added() {
        let link = LinkSpec::sym(8e6, Duration::from_millis(50));
        assert!((link.transmit_time(0).as_secs_f64() - 0.05).abs() < 1e-9);
    }

    #[test]
    fn infinite_link_costs_nothing() {
        let link = LinkSpec::infinite();
        assert_eq!(link.transmit_time(1 << 30), Duration::ZERO);
    }

    #[test]
    fn virtual_link_accumulates() {
        let mut v = VirtualLink::new(LinkSpec::sym(8e6, Duration::ZERO));
        v.send(500_000);
        v.send(500_000);
        assert_eq!(v.bytes_sent, 1_000_000);
        assert!((v.virtual_time.as_secs_f64() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn asymmetric_directions_and_flip() {
        let link = LinkSpec::asym_mbps(10.0, 80.0);
        // 1 MB: 0.8 s up, 0.1 s down (plus the stock 20 ms latency).
        let up = link.transmit_time(1_000_000).as_secs_f64();
        let down = link.downlink_time(1_000_000).as_secs_f64();
        assert!((up - 0.82).abs() < 1e-9, "up {up}");
        assert!((down - 0.12).abs() < 1e-9, "down {down}");
        // The peer's view swaps the directions.
        let peer = link.flipped();
        assert_eq!(peer.transmit_time(1_000_000), link.downlink_time(1_000_000));
        assert_eq!(peer.downlink_time(1_000_000), link.transmit_time(1_000_000));
        // Symmetric constructors keep both directions equal.
        let sym = LinkSpec::mbps(10.0);
        assert_eq!(sym.transmit_time(12345), sym.downlink_time(12345));
        assert_eq!(LinkSpec::infinite().downlink_time(1 << 30), Duration::ZERO);
    }

    #[test]
    fn throttler_enforces_rate() {
        // 80 kbit/s -> 10 KB takes ~1s; use smaller scale to keep test fast:
        // 8 Mbit/s -> 100 KB takes ~0.1 s.
        let mut t = Throttler::new(LinkSpec::sym(8e6, Duration::ZERO));
        let t0 = Instant::now();
        t.consume(50_000);
        t.consume(50_000);
        let elapsed = t0.elapsed().as_secs_f64();
        assert!(elapsed >= 0.09, "elapsed {elapsed}");
        assert!(elapsed < 0.5, "elapsed {elapsed}");
    }
}
