//! In-process transport: paired mpsc channels with optional bandwidth
//! throttling. The default for single-process FL simulation.

use std::sync::mpsc::{channel, Receiver, Sender};

use super::bandwidth::{LinkSpec, Throttler};
use super::Channel;
use crate::fl::protocol::Msg;

/// One endpoint of an in-process duplex channel.
pub struct InProcChannel {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    throttle: Option<Throttler>,
}

/// Create a connected (server_end, client_end) pair. `link` throttles
/// sends on **both** ends in real time when set.
pub fn pair(link: Option<LinkSpec>) -> (InProcChannel, InProcChannel) {
    let (tx_a, rx_b) = channel();
    let (tx_b, rx_a) = channel();
    (
        InProcChannel { tx: tx_a, rx: rx_a, throttle: link.map(Throttler::new) },
        InProcChannel { tx: tx_b, rx: rx_b, throttle: link.map(Throttler::new) },
    )
}

impl Channel for InProcChannel {
    fn send(&mut self, msg: &Msg) -> crate::Result<()> {
        let bytes = msg.encode();
        if let Some(t) = &mut self.throttle {
            t.consume(bytes.len());
        }
        self.tx.send(bytes).map_err(|_| anyhow::anyhow!("peer hung up"))
    }

    fn recv(&mut self) -> crate::Result<Msg> {
        let bytes = self.rx.recv().map_err(|_| anyhow::anyhow!("peer hung up"))?;
        Msg::decode(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ping_pong() {
        let (mut a, mut b) = pair(None);
        a.send(&Msg::Hello { client_id: 1 }).unwrap();
        assert_eq!(b.recv().unwrap(), Msg::Hello { client_id: 1 });
        b.send(&Msg::Shutdown).unwrap();
        assert_eq!(a.recv().unwrap(), Msg::Shutdown);
    }

    #[test]
    fn across_threads() {
        let (mut a, mut b) = pair(None);
        let h = std::thread::spawn(move || {
            let m = b.recv().unwrap();
            b.send(&m).unwrap();
        });
        a.send(&Msg::Hello { client_id: 42 }).unwrap();
        assert_eq!(a.recv().unwrap(), Msg::Hello { client_id: 42 });
        h.join().unwrap();
    }

    #[test]
    fn hung_up_errors() {
        let (mut a, b) = pair(None);
        drop(b);
        assert!(a.send(&Msg::Shutdown).is_err());
    }
}
