//! In-process transport: paired mpsc channels with optional bandwidth
//! throttling. The default for single-process FL simulation.
//!
//! Messages travel as shared `Arc<[u8]>` buffers, so the server's
//! encode-once broadcast path ([`Channel::send_encoded`]) fans the same
//! allocation out to every client without copying, let alone
//! re-encoding.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use super::bandwidth::{LinkSpec, Throttler};
use super::Channel;
use crate::fl::protocol::Msg;

/// One endpoint of an in-process duplex channel.
pub struct InProcChannel {
    tx: Sender<Arc<[u8]>>,
    rx: Receiver<Arc<[u8]>>,
    throttle: Option<Throttler>,
}

/// Create a connected (server_end, client_end) pair. When `link` is set,
/// sends are throttled in real time **per direction**: the client end
/// transmits at the uplink rate, the server end at the (often much
/// larger) downlink rate.
pub fn pair(link: Option<LinkSpec>) -> (InProcChannel, InProcChannel) {
    let (tx_a, rx_b) = channel();
    let (tx_b, rx_a) = channel();
    (
        // Server end: its sends ride the downlink.
        InProcChannel {
            tx: tx_a,
            rx: rx_a,
            throttle: link.map(|l| Throttler::new(l.flipped())),
        },
        // Client end: its sends ride the uplink.
        InProcChannel { tx: tx_b, rx: rx_b, throttle: link.map(Throttler::new) },
    )
}

impl InProcChannel {
    fn push(&mut self, bytes: Arc<[u8]>) -> crate::Result<()> {
        if let Some(t) = &mut self.throttle {
            t.consume(bytes.len());
        }
        crate::telemetry::TX_BYTES_INPROC.add(bytes.len() as u64);
        self.tx.send(bytes).map_err(|_| anyhow::anyhow!("peer hung up"))
    }

    fn pull(&mut self) -> crate::Result<Arc<[u8]>> {
        let bytes = self.rx.recv().map_err(|_| anyhow::anyhow!("peer hung up"))?;
        crate::telemetry::RX_BYTES_INPROC.add(bytes.len() as u64);
        Ok(bytes)
    }
}

impl Channel for InProcChannel {
    fn send(&mut self, msg: &Msg) -> crate::Result<()> {
        self.push(msg.encode().into())
    }

    fn send_encoded(&mut self, bytes: &Arc<[u8]>) -> crate::Result<()> {
        self.push(bytes.clone())
    }

    fn recv(&mut self) -> crate::Result<Msg> {
        let bytes = self.pull()?;
        Msg::decode(&bytes)
    }

    fn recv_raw(&mut self) -> crate::Result<Arc<[u8]>> {
        self.pull()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ping_pong() {
        let (mut a, mut b) = pair(None);
        a.send(&Msg::Hello { client_id: 1 }).unwrap();
        assert_eq!(b.recv().unwrap(), Msg::Hello { client_id: 1 });
        b.send(&Msg::Shutdown).unwrap();
        assert_eq!(a.recv().unwrap(), Msg::Shutdown);
    }

    #[test]
    fn across_threads() {
        let (mut a, mut b) = pair(None);
        let h = std::thread::spawn(move || {
            let m = b.recv().unwrap();
            b.send(&m).unwrap();
        });
        a.send(&Msg::Hello { client_id: 42 }).unwrap();
        assert_eq!(a.recv().unwrap(), Msg::Hello { client_id: 42 });
        h.join().unwrap();
    }

    #[test]
    fn hung_up_errors() {
        let (mut a, b) = pair(None);
        drop(b);
        assert!(a.send(&Msg::Shutdown).is_err());
    }

    #[test]
    fn send_encoded_forwards_shared_bytes() {
        let (mut a, mut b) = pair(None);
        let msg = Msg::GlobalParams { round: 2, tensors: vec![vec![1.0, -1.0]] };
        let bytes: Arc<[u8]> = msg.encode().into();
        a.send_encoded(&bytes).unwrap();
        a.send_encoded(&bytes).unwrap(); // same allocation, fanned out twice
        assert_eq!(b.recv().unwrap(), msg);
        assert_eq!(b.recv().unwrap(), msg);
    }

    #[test]
    fn recv_raw_returns_the_shared_allocation() {
        // The edge-aggregator hop: send_encoded → recv_raw must hand
        // back the very same allocation (zero-copy), not a re-encode.
        let (mut a, mut b) = pair(None);
        let msg = Msg::GlobalParams { round: 5, tensors: vec![vec![0.5; 8]] };
        let bytes: Arc<[u8]> = msg.encode().into();
        a.send_encoded(&bytes).unwrap();
        let got = b.recv_raw().unwrap();
        assert!(Arc::ptr_eq(&bytes, &got), "recv_raw must forward the shared buffer");
        assert_eq!(Msg::decode(&got).unwrap(), msg);
    }
}
