//! Transports carrying [`crate::fl::protocol::Msg`] frames, plus the
//! bandwidth-constrained link model used for the paper's Fig. 11
//! end-to-end communication-time experiments.

pub mod bandwidth;
pub mod inproc;
pub mod tcp;

use crate::fl::protocol::Msg;

/// A bidirectional, blocking message channel endpoint.
pub trait Channel: Send {
    fn send(&mut self, msg: &Msg) -> crate::Result<()>;
    fn recv(&mut self) -> crate::Result<Msg>;
}
