//! Transports carrying [`crate::fl::protocol::Msg`] frames, plus the
//! bandwidth-constrained link model used for the paper's Fig. 11
//! end-to-end communication-time experiments.

pub mod bandwidth;
pub mod inproc;
pub mod tcp;

use std::sync::Arc;

use crate::fl::protocol::Msg;

/// A bidirectional, blocking message channel endpoint.
pub trait Channel: Send {
    fn send(&mut self, msg: &Msg) -> crate::Result<()>;

    /// Send pre-encoded message bytes — the encode-once fan-out path:
    /// the server serializes a broadcast message **once** and hands
    /// every channel the same shared buffer. Transports that carry raw
    /// bytes forward the buffer without re-encoding; this default
    /// decodes and re-sends for transports that only know `Msg`.
    fn send_encoded(&mut self, bytes: &Arc<[u8]>) -> crate::Result<()> {
        self.send(&Msg::decode(bytes)?)
    }

    fn recv(&mut self) -> crate::Result<Msg>;

    /// Receive one message as its raw encoded bytes — the dual of
    /// [`Channel::send_encoded`]: an edge aggregator that re-fans a
    /// broadcast to its subtree wants the wire bytes, not the decoded
    /// message, so the encode-once buffer survives the hop. Transports
    /// that carry raw bytes return the shared buffer directly; this
    /// default re-encodes for transports that only know `Msg`.
    fn recv_raw(&mut self) -> crate::Result<Arc<[u8]>> {
        Ok(self.recv()?.encode().into())
    }
}
