//! TCP transport: length-prefixed frames over `std::net`, with optional
//! real-time bandwidth throttling on send. Lets the FL runtime span real
//! processes/machines (blocking sockets + threads; no async runtime is
//! available offline, and the message pattern is strictly
//! request/response per round).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};

use super::bandwidth::{LinkSpec, Throttler};
use super::Channel;
use crate::fl::protocol::Msg;

/// Maximum accepted frame (guards against corrupt length prefixes).
const MAX_FRAME: usize = 1 << 30;

/// A framed TCP endpoint.
pub struct TcpChannel {
    stream: TcpStream,
    throttle: Option<Throttler>,
}

impl TcpChannel {
    /// Wrap a stream. `link` is this endpoint's view of the connection:
    /// sends are throttled at its **uplink** rate (`bits_per_sec`).
    pub fn new(stream: TcpStream, link: Option<LinkSpec>) -> crate::Result<Self> {
        stream.set_nodelay(true)?;
        Ok(TcpChannel { stream, throttle: link.map(Throttler::new) })
    }

    /// Connect to a server (client side: sends ride the uplink).
    pub fn connect(addr: &str, link: Option<LinkSpec>) -> crate::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        Self::new(stream, link)
    }
}

/// Listen and accept `n` client channels (in accept order). `link` is
/// the *client's* view of each connection; the server's sends ride the
/// client's **downlink**, so the accepted endpoints throttle at the
/// flipped rate — the same per-direction discipline as
/// [`super::inproc::pair`].
pub fn accept_n(
    listener: &TcpListener,
    n: usize,
    link: Option<LinkSpec>,
) -> crate::Result<Vec<TcpChannel>> {
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let (stream, _) = listener.accept()?;
        out.push(TcpChannel::new(stream, link.map(|l| l.flipped()))?);
    }
    Ok(out)
}

impl TcpChannel {
    fn write_frame(&mut self, bytes: &[u8]) -> crate::Result<()> {
        if let Some(t) = &mut self.throttle {
            t.consume(bytes.len() + 4);
        }
        crate::telemetry::TX_BYTES_TCP.add(bytes.len() as u64 + 4);
        self.stream.write_all(&(bytes.len() as u32).to_le_bytes())?;
        self.stream.write_all(bytes)?;
        Ok(())
    }
}

impl Channel for TcpChannel {
    fn send(&mut self, msg: &Msg) -> crate::Result<()> {
        self.write_frame(&msg.encode())
    }

    /// Encode-once fan-out: forward pre-encoded message bytes straight
    /// to the socket without a decode/re-encode round trip.
    fn send_encoded(&mut self, bytes: &std::sync::Arc<[u8]>) -> crate::Result<()> {
        self.write_frame(bytes)
    }

    fn recv(&mut self) -> crate::Result<Msg> {
        let mut len_buf = [0u8; 4];
        self.stream.read_exact(&mut len_buf)?;
        let len = u32::from_le_bytes(len_buf) as usize;
        if len > MAX_FRAME {
            anyhow::bail!("frame length {len} exceeds cap");
        }
        let mut buf = vec![0u8; len];
        self.stream.read_exact(&mut buf)?;
        crate::telemetry::RX_BYTES_TCP.add(len as u64 + 4);
        Msg::decode(&buf)
    }
}
