//! TopK sparsification baseline (Aji & Heafield 2017): transmit only the
//! k-fraction largest-magnitude elements (delta-coded indices + f32
//! values), zeroing the rest. Representative of the sparsification family
//! the paper contrasts in §7.1 — high CR, uncontrolled per-element error.

use crate::compress::blob::{BlobReader, BlobWriter};
use crate::compress::frame::{Frame, LayerReport};
use crate::compress::lossless::{self, Backend};
use crate::compress::GradientCodec;
use crate::tensor::{LayerGrad, LayerMeta, ModelGrad};

/// TopK codec with fraction `k` (e.g. 0.05 = keep 5%).
pub struct TopKCodec {
    pub k: f64,
    pub backend: Backend,
}

impl TopKCodec {
    pub fn new(k: f64) -> Self {
        assert!(k > 0.0 && k <= 1.0);
        TopKCodec { k, backend: Backend::default() }
    }

    fn compress_layer(&self, layer: &LayerGrad) -> Vec<u8> {
        let data = &layer.data;
        let keep = self.keep_count(data.len());
        // Select top-k by |value| (partial sort of indices).
        let mut idx: Vec<u32> = (0..data.len() as u32).collect();
        idx.select_nth_unstable_by(keep - 1, |&a, &b| {
            data[b as usize]
                .abs()
                .partial_cmp(&data[a as usize].abs())
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut kept: Vec<u32> = idx[..keep].to_vec();
        kept.sort_unstable();
        let mut w = BlobWriter::new();
        w.put_u32(data.len() as u32);
        w.put_u32(keep as u32);
        // Delta-coded indices.
        let mut prev = 0u32;
        for &i in &kept {
            w.put_u32(i - prev);
            prev = i;
        }
        for &i in &kept {
            w.put_f32(data[i as usize]);
        }
        w.into_bytes()
    }

    fn decompress_layer(
        &self,
        meta: &LayerMeta,
        body: &[u8],
    ) -> crate::Result<(Vec<f32>, LayerReport)> {
        let mut r = BlobReader::new(body);
        let n = r.get_u32()? as usize;
        if n != meta.numel {
            anyhow::bail!("topk layer {}: numel {} != {}", meta.name, n, meta.numel);
        }
        let keep = r.get_u32()? as usize;
        let mut indices = Vec::with_capacity(keep);
        let mut acc = 0u32;
        for _ in 0..keep {
            acc += r.get_u32()?;
            indices.push(acc);
        }
        let mut out = vec![0.0f32; n];
        for &i in &indices {
            let v = r.get_f32()?;
            *out.get_mut(i as usize)
                .ok_or_else(|| anyhow::anyhow!("topk index {i} out of range"))? = v;
        }
        Ok((out, Self::layer_report(meta.name.clone(), n, keep)))
    }

    /// The delta-coded index stream is the side info; kept values travel
    /// as exact f32s (no entropy stage).
    fn layer_report(name: String, n: usize, keep: usize) -> LayerReport {
        LayerReport {
            name,
            raw_bytes: n * 4,
            side_info_bytes: keep * 4,
            lossy: true,
            ..Default::default()
        }
    }

    fn keep_count(&self, n: usize) -> usize {
        ((n as f64 * self.k).ceil() as usize).clamp(1, n)
    }
}

impl GradientCodec for TopKCodec {
    fn encode_layer(&mut self, idx: usize, layer: &LayerGrad) -> crate::Result<Frame> {
        let closed = self.backend.compress(&self.compress_layer(layer))?;
        let n = layer.data.len();
        let report = Self::layer_report(layer.meta.name.clone(), n, self.keep_count(n));
        Ok(Frame::new(idx, closed, report))
    }

    fn decode_frame(
        &mut self,
        frame: &Frame,
        meta: &LayerMeta,
    ) -> crate::Result<(LayerGrad, LayerReport)> {
        let body = lossless::decompress(&frame.payload)?;
        let (data, mut report) = self.decompress_layer(meta, &body)?;
        report.compressed_bytes = frame.wire_size();
        Ok((LayerGrad::new(meta.clone(), data), report))
    }

    /// Stateless per layer ⇒ parallel whole-model encode.
    fn encode_model(&mut self, grads: &ModelGrad) -> crate::Result<Vec<Frame>> {
        let this = &*self;
        crate::compress::session::encode_model_parallel(grads, |_, layer| {
            let closed = this.backend.compress(&this.compress_layer(layer))?;
            let n = layer.data.len();
            Ok((closed, Self::layer_report(layer.meta.name.clone(), n, this.keep_count(n))))
        })
    }

    fn name(&self) -> &'static str {
        "topk"
    }

    fn reset(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn keeps_largest_elements() {
        let data = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 0.0, 1.0, -0.3];
        let g = ModelGrad { layers: vec![LayerGrad::new(LayerMeta::other("g", 8), data)] };
        let metas: Vec<LayerMeta> = g.layers.iter().map(|l| l.meta.clone()).collect();
        let mut codec = TopKCodec::new(0.25); // keep 2
        let payload = codec.compress(&g).unwrap();
        let recon = codec.decompress(&payload, &metas).unwrap();
        assert_eq!(recon.layers[0].data[1], -5.0);
        assert_eq!(recon.layers[0].data[3], 3.0);
        let nonzero = recon.layers[0].data.iter().filter(|&&x| x != 0.0).count();
        assert_eq!(nonzero, 2);
    }

    #[test]
    fn ratio_scales_with_k() {
        let mut rng = Rng::new(1);
        let data: Vec<f32> = (0..100_000).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let g = ModelGrad { layers: vec![LayerGrad::new(LayerMeta::other("g", 100_000), data)] };
        let p1 = TopKCodec::new(0.01).compress(&g).unwrap();
        let p10 = TopKCodec::new(0.10).compress(&g).unwrap();
        assert!(p1.len() < p10.len());
        assert!(g.byte_size() as f64 / p1.len() as f64 > 10.0);
    }

    #[test]
    fn roundtrip_preserves_kept_values_exactly() {
        let mut rng = Rng::new(2);
        let data: Vec<f32> = (0..1000).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let g = ModelGrad {
            layers: vec![LayerGrad::new(LayerMeta::other("g", 1000), data.clone())],
        };
        let metas: Vec<LayerMeta> = g.layers.iter().map(|l| l.meta.clone()).collect();
        let mut codec = TopKCodec::new(0.05);
        let payload = codec.compress(&g).unwrap();
        let recon = codec.decompress(&payload, &metas).unwrap();
        for (r, o) in recon.layers[0].data.iter().zip(&data) {
            assert!(*r == 0.0 || r == o);
        }
    }
}
