//! Comparator baselines from the paper's evaluation: a faithful SZ3-style
//! EBLC (generic Lorenzo/interpolation predictors over the same
//! quantize→Huffman→lossless backend), QSGD (stochastic quantization with
//! Elias coding), and TopK sparsification (the sparsification family the
//! paper contrasts in §7.1).

pub mod composed;
pub mod elias;
pub mod qsgd;
pub mod sz3;
pub mod topk;

use crate::compress::blob::{bytes_to_f32s, f32s_to_bytes, BlobReader, BlobWriter};
use crate::compress::GradientCodec;
use crate::tensor::{LayerGrad, LayerMeta, ModelGrad};

/// Identity codec (`codec = "none"`): raw f32 transmission, CR = 1. The
/// uncompressed baseline of Fig. 9 / Fig. 11.
#[derive(Default)]
pub struct RawCodec;

impl GradientCodec for RawCodec {
    fn compress(&mut self, grads: &ModelGrad) -> crate::Result<Vec<u8>> {
        let mut w = BlobWriter::new();
        w.put_u32(grads.layers.len() as u32);
        for l in &grads.layers {
            w.put_bytes(&f32s_to_bytes(&l.data));
        }
        Ok(w.into_bytes())
    }

    fn decompress(&mut self, payload: &[u8], metas: &[LayerMeta]) -> crate::Result<ModelGrad> {
        let mut r = BlobReader::new(payload);
        let n = r.get_u32()? as usize;
        anyhow::ensure!(n == metas.len(), "raw payload {} layers != {}", n, metas.len());
        let mut out = ModelGrad::default();
        for meta in metas {
            let data = bytes_to_f32s(r.get_bytes()?)?;
            anyhow::ensure!(data.len() == meta.numel, "raw layer {} size", meta.name);
            out.layers.push(LayerGrad::new(meta.clone(), data));
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "none"
    }

    fn reset(&mut self) {}
}

/// Factory over every codec in the repo (ours + baselines), keyed by the
/// names used in configs and bench tables.
pub fn make_codec(
    name: &str,
    error_bound: crate::compress::quant::ErrorBound,
    qsgd_bits: u8,
) -> Option<Box<dyn GradientCodec>> {
    match name {
        "fedgec" | "ours" => {
            let cfg = crate::compress::pipeline::FedgecConfig { error_bound, ..Default::default() };
            Some(Box::new(crate::compress::pipeline::FedgecCodec::new(cfg)))
        }
        "sz3" => Some(Box::new(sz3::Sz3Codec::new(sz3::Sz3Config {
            error_bound,
            ..Default::default()
        }))),
        "qsgd" => Some(Box::new(qsgd::QsgdCodec::new(qsgd_bits, 0))),
        "topk" => Some(Box::new(topk::TopKCodec::new(0.05))),
        "none" | "raw" => Some(Box::new(RawCodec)),
        "topk+eblc" => Some(Box::new(composed::SparsifiedEblc::new(0.05, error_bound))),
        "ef-topk" => Some(Box::new(composed::ErrorFeedback::new(Box::new(
            topk::TopKCodec::new(0.05),
        )))),
        "ef-qsgd" => Some(Box::new(composed::ErrorFeedback::new(Box::new(
            qsgd::QsgdCodec::new(qsgd_bits, 0),
        )))),
        _ => None,
    }
}

/// Map a REL error bound to a comparable QSGD bit-width, following the
/// paper's §5.3 pairing: {1e-3,1e-2,3e-2,5e-2,1e-1} ↔ {10,7,5,4,3} bits.
pub fn qsgd_bits_for_bound(rel_eb: f64) -> u8 {
    if rel_eb <= 1e-3 {
        10
    } else if rel_eb <= 1e-2 {
        7
    } else if rel_eb <= 3e-2 {
        5
    } else if rel_eb <= 5e-2 {
        4
    } else {
        3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::quant::ErrorBound;

    #[test]
    fn factory_knows_all_codecs() {
        for name in ["fedgec", "ours", "sz3", "qsgd", "topk", "none"] {
            assert!(make_codec(name, ErrorBound::Rel(1e-2), 5).is_some(), "{name}");
        }
        assert!(make_codec("nope", ErrorBound::Rel(1e-2), 5).is_none());
    }

    #[test]
    fn qsgd_bit_mapping_matches_paper() {
        assert_eq!(qsgd_bits_for_bound(1e-3), 10);
        assert_eq!(qsgd_bits_for_bound(1e-2), 7);
        assert_eq!(qsgd_bits_for_bound(3e-2), 5);
        assert_eq!(qsgd_bits_for_bound(5e-2), 4);
        assert_eq!(qsgd_bits_for_bound(1e-1), 3);
    }
}
