//! Comparator baselines from the paper's evaluation: a faithful SZ3-style
//! EBLC (generic Lorenzo/interpolation predictors over the same
//! quantize→Huffman→lossless backend), QSGD (stochastic quantization with
//! Elias coding), and TopK sparsification (the sparsification family the
//! paper contrasts in §7.1).
//!
//! All baselines speak the session/frame API of
//! [`crate::compress::GradientCodec`] and are constructed through
//! [`crate::compress::spec::CodecSpec`].

pub mod composed;
pub mod elias;
pub mod qsgd;
pub mod sz3;
pub mod topk;

use crate::compress::blob::{bytes_to_f32s, f32s_to_bytes};
use crate::compress::frame::{Frame, LayerReport};
use crate::compress::spec::{CodecSpec, SpecDefaults};
use crate::compress::GradientCodec;
use crate::tensor::{LayerGrad, LayerMeta};

/// Identity codec (`codec = "raw"` / `"none"`): raw f32 transmission,
/// CR ≈ 1. The uncompressed baseline of Fig. 9 / Fig. 11.
#[derive(Default)]
pub struct RawCodec;

impl GradientCodec for RawCodec {
    fn encode_layer(&mut self, idx: usize, layer: &LayerGrad) -> crate::Result<Frame> {
        let report = LayerReport {
            name: layer.meta.name.clone(),
            raw_bytes: layer.data.len() * 4,
            ..Default::default()
        };
        Ok(Frame::new(idx, f32s_to_bytes(&layer.data), report))
    }

    fn decode_frame(
        &mut self,
        frame: &Frame,
        meta: &LayerMeta,
    ) -> crate::Result<(LayerGrad, LayerReport)> {
        let data = bytes_to_f32s(&frame.payload)?;
        anyhow::ensure!(
            data.len() == meta.numel,
            "raw layer {}: {} values != {}",
            meta.name,
            data.len(),
            meta.numel
        );
        let report = LayerReport {
            name: meta.name.clone(),
            raw_bytes: data.len() * 4,
            compressed_bytes: frame.wire_size(),
            ..Default::default()
        };
        Ok((LayerGrad::new(meta.clone(), data), report))
    }

    fn name(&self) -> &'static str {
        "none"
    }

    fn reset(&mut self) {}
}

/// Deprecated positional factory over every codec in the repo, kept as a
/// shim for legacy call sites. Forwards the name to
/// [`CodecSpec::parse_with`] with the positional knobs as defaults, so
/// every legacy name (`fedgec`, `ours`, `sz3`, `qsgd`, `topk`, `none`,
/// `raw`, `topk+eblc`, `ef-topk`, `ef-qsgd`) still resolves.
#[deprecated(note = "construct codecs via compress::spec::CodecSpec::parse / ::build")]
pub fn make_codec(
    name: &str,
    error_bound: crate::compress::quant::ErrorBound,
    qsgd_bits: u8,
) -> Option<Box<dyn GradientCodec>> {
    let d = SpecDefaults { error_bound, qsgd_bits, ..Default::default() };
    CodecSpec::parse_with(name, &d).ok().map(|s| s.build())
}

/// Map a REL error bound to a comparable QSGD bit-width, following the
/// paper's §5.3 pairing: {1e-3,1e-2,3e-2,5e-2,1e-1} ↔ {10,7,5,4,3} bits.
pub fn qsgd_bits_for_bound(rel_eb: f64) -> u8 {
    if rel_eb <= 1e-3 {
        10
    } else if rel_eb <= 1e-2 {
        7
    } else if rel_eb <= 3e-2 {
        5
    } else if rel_eb <= 5e-2 {
        4
    } else {
        3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::quant::ErrorBound;

    #[test]
    #[allow(deprecated)]
    fn legacy_factory_names_still_resolve() {
        // The deprecated shim must keep resolving every name the old
        // positional factory knew.
        for name in [
            "fedgec",
            "ours",
            "sz3",
            "qsgd",
            "topk",
            "none",
            "raw",
            "topk+eblc",
            "ef-topk",
            "ef-qsgd",
        ] {
            assert!(make_codec(name, ErrorBound::Rel(1e-2), 5).is_some(), "{name}");
        }
        assert!(make_codec("nope", ErrorBound::Rel(1e-2), 5).is_none());
    }

    #[test]
    #[allow(deprecated)]
    fn shim_forwards_positional_knobs() {
        // Positional eb/bits become the spec defaults.
        let q = make_codec("qsgd", ErrorBound::Rel(1e-2), 9).unwrap();
        assert_eq!(q.name(), "qsgd");
        let spec = CodecSpec::parse_with(
            "qsgd",
            &SpecDefaults { qsgd_bits: 9, ..Default::default() },
        )
        .unwrap();
        assert_eq!(spec, CodecSpec::Qsgd { bits: 9, seed: 0 });
    }

    #[test]
    fn qsgd_bit_mapping_matches_paper() {
        assert_eq!(qsgd_bits_for_bound(1e-3), 10);
        assert_eq!(qsgd_bits_for_bound(1e-2), 7);
        assert_eq!(qsgd_bits_for_bound(3e-2), 5);
        assert_eq!(qsgd_bits_for_bound(5e-2), 4);
        assert_eq!(qsgd_bits_for_bound(1e-1), 3);
    }
}
