//! QSGD baseline (Alistarh et al., NeurIPS 2017): per-layer stochastic
//! quantization onto `s = 2^bits − 1` levels of `|g_i| / ‖g‖₂`, encoded as
//! sign bits + Elias-gamma level codes, closed with the same lossless
//! backend. Not error-bounded — the paper maps REL bounds to bit-widths
//! for comparability (§5.3, reproduced in
//! [`crate::baselines::qsgd_bits_for_bound`]).

use super::elias;
use crate::compress::blob::{BlobReader, BlobWriter};
use crate::compress::frame::{Frame, LayerReport};
use crate::compress::lossless::{self, Backend};
use crate::compress::GradientCodec;
use crate::tensor::{LayerGrad, LayerMeta, ModelGrad};
use crate::util::bitio::{BitReader, BitWriter};
use crate::util::rng::Rng;

/// Bucket size: norms are taken per 512-element bucket, the standard
/// practical QSGD configuration (whole-tensor norms degenerate on
/// multi-million-element conv layers — nearly every level rounds to 0).
pub const BUCKET: usize = 512;

/// QSGD codec. Stochastic rounding is driven by a per-(round, layer) RNG
/// derived from the seed, so runs are reproducible AND layers encode in
/// parallel; the randomness is part of the *encoder* only.
pub struct QsgdCodec {
    pub bits: u8,
    pub backend: Backend,
    seed: u64,
    /// Round counter feeding the per-layer RNG derivation (bumped by
    /// `begin` so repeated rounds draw fresh randomness).
    round: u64,
}

impl QsgdCodec {
    pub fn new(bits: u8, seed: u64) -> Self {
        assert!((1..=16).contains(&bits));
        QsgdCodec { bits, backend: Backend::default(), seed: seed ^ 0x9560d, round: 0 }
    }

    fn levels(&self) -> u32 {
        (1u32 << self.bits) - 1
    }

    /// Independent stochastic-rounding stream for one layer of one round.
    fn layer_rng(&self, idx: usize) -> Rng {
        Rng::new(
            self.seed
                ^ self.round.wrapping_mul(0x9E3779B97F4A7C15)
                ^ (idx as u64).wrapping_mul(0xD1B54A32D192ED03),
        )
    }

    fn compress_layer(&self, layer: &LayerGrad, rng: &mut Rng) -> (Vec<u8>, LayerReport) {
        let data = &layer.data;
        let s = self.levels() as f64;
        let mut w = BlobWriter::new();
        w.put_u32(data.len() as u32);
        // Per-bucket L2 norms.
        let n_buckets = data.len().div_ceil(BUCKET).max(1);
        w.put_u32(n_buckets as u32);
        let mut norms = Vec::with_capacity(n_buckets);
        for chunk in data.chunks(BUCKET) {
            let norm: f64 = chunk.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
            w.put_f64(norm);
            norms.push(norm);
        }
        // Sign bitmap then level stream.
        let mut signs = BitWriter::new();
        let mut lvls = BitWriter::new();
        for (b, chunk) in data.chunks(BUCKET).enumerate() {
            let norm = norms[b];
            for &x in chunk {
                signs.put_bit(x < 0.0);
                let r = if norm > 0.0 { (x.abs() as f64 / norm) * s } else { 0.0 };
                let l = r.floor();
                let frac = r - l;
                let level = l as u64 + if rng.chance(frac) { 1 } else { 0 };
                // Elias needs v >= 1: shift by one.
                elias::gamma_encode(&mut lvls, level + 1);
            }
        }
        let sign_bytes = signs.into_bytes();
        let lvl_bytes = lvls.into_bytes();
        // Norms + sign bitmap are side info; the Elias level stream is
        // the entropy part — mirrored by the decoder's report.
        let report = LayerReport {
            name: layer.meta.name.clone(),
            raw_bytes: data.len() * 4,
            side_info_bytes: 8 * n_buckets + sign_bytes.len(),
            entropy_bytes: lvl_bytes.len(),
            lossy: true,
            ..Default::default()
        };
        w.put_bytes(&sign_bytes);
        w.put_bytes(&lvl_bytes);
        (w.into_bytes(), report)
    }

    fn decompress_layer(&self, meta: &LayerMeta, body: &[u8]) -> crate::Result<(Vec<f32>, LayerReport)> {
        let mut r = BlobReader::new(body);
        let n = r.get_u32()? as usize;
        if n != meta.numel {
            anyhow::bail!("qsgd layer {}: numel {} != {}", meta.name, n, meta.numel);
        }
        let n_buckets = r.get_u32()? as usize;
        if n_buckets != n.div_ceil(BUCKET).max(1) {
            anyhow::bail!("qsgd layer {}: bucket count {}", meta.name, n_buckets);
        }
        let mut norms = Vec::with_capacity(n_buckets);
        for _ in 0..n_buckets {
            norms.push(r.get_f64()?);
        }
        let sign_bytes = r.get_bytes()?;
        let lvl_bytes = r.get_bytes()?;
        let side_info = 8 * n_buckets + sign_bytes.len();
        let mut signs = BitReader::new(sign_bytes);
        let mut lvls = BitReader::new(lvl_bytes);
        let s = self.levels() as f64;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let norm = norms[i / BUCKET];
            let neg = signs.get_bit().ok_or_else(|| anyhow::anyhow!("sign underrun"))?;
            let level =
                elias::gamma_decode(&mut lvls).ok_or_else(|| anyhow::anyhow!("level underrun"))? - 1;
            let mag = norm * level as f64 / s;
            out.push(if neg { -mag as f32 } else { mag as f32 });
        }
        let report = LayerReport {
            name: meta.name.clone(),
            raw_bytes: n * 4,
            side_info_bytes: side_info,
            entropy_bytes: lvl_bytes.len(),
            lossy: true,
            ..Default::default()
        };
        Ok((out, report))
    }

}

impl GradientCodec for QsgdCodec {
    fn begin(&mut self, n_layers: usize) -> crate::Result<()> {
        let _ = n_layers;
        self.round = self.round.wrapping_add(1);
        Ok(())
    }

    fn encode_layer(&mut self, idx: usize, layer: &LayerGrad) -> crate::Result<Frame> {
        let mut rng = self.layer_rng(idx);
        let (body, report) = self.compress_layer(layer, &mut rng);
        let closed = self.backend.compress(&body)?;
        Ok(Frame::new(idx, closed, report))
    }

    fn decode_frame(
        &mut self,
        frame: &Frame,
        meta: &LayerMeta,
    ) -> crate::Result<(LayerGrad, LayerReport)> {
        let body = lossless::decompress(&frame.payload)?;
        let (data, mut report) = self.decompress_layer(meta, &body)?;
        report.compressed_bytes = frame.wire_size();
        Ok((LayerGrad::new(meta.clone(), data), report))
    }

    /// Per-layer RNG streams are independent ⇒ parallel encode.
    fn encode_model(&mut self, grads: &ModelGrad) -> crate::Result<Vec<Frame>> {
        self.begin(grads.layers.len())?;
        let this = &*self;
        crate::compress::session::encode_model_parallel(grads, |idx, layer| {
            let mut rng = this.layer_rng(idx);
            let (body, report) = this.compress_layer(layer, &mut rng);
            Ok((this.backend.compress(&body)?, report))
        })
    }

    fn name(&self) -> &'static str {
        "qsgd"
    }

    fn reset(&mut self) {
        self.round = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn grads(n: usize, seed: u64) -> (ModelGrad, Vec<LayerMeta>) {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        let g = ModelGrad { layers: vec![LayerGrad::new(LayerMeta::other("g", n), data)] };
        let metas = g.layers.iter().map(|l| l.meta.clone()).collect();
        (g, metas)
    }

    #[test]
    fn roundtrip_unbiased_and_bounded() {
        let (g, metas) = grads(20_000, 1);
        let mut codec = QsgdCodec::new(8, 7);
        let payload = codec.compress(&g).unwrap();
        let recon = codec.decompress(&payload, &metas).unwrap();
        let orig = &g.layers[0].data;
        let rec = &recon.layers[0].data;
        // Per-element error bounded by its bucket's norm/s; stochastic
        // rounding approximately unbiased overall.
        let mut bias = 0.0f64;
        let mut max_bin = 0.0f64;
        for (b, chunk) in orig.chunks(BUCKET).enumerate() {
            let norm: f64 = chunk.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
            let bin = norm / 255.0;
            max_bin = max_bin.max(bin);
            for (i, o) in chunk.iter().enumerate() {
                let r = rec[b * BUCKET + i];
                assert!((*o as f64 - r as f64).abs() <= bin + 1e-9);
                bias += (*o - r) as f64;
            }
        }
        assert!((bias / orig.len() as f64).abs() < max_bin * 0.1, "bias={bias}");
    }

    #[test]
    fn fewer_bits_smaller_payload() {
        let (g, _) = grads(50_000, 2);
        let p3 = QsgdCodec::new(3, 0).compress(&g).unwrap();
        let p10 = QsgdCodec::new(10, 0).compress(&g).unwrap();
        assert!(p3.len() < p10.len(), "{} vs {}", p3.len(), p10.len());
        // And both beat raw f32.
        assert!(p10.len() < g.byte_size());
    }

    #[test]
    fn zero_layer_roundtrip() {
        let g = ModelGrad {
            layers: vec![LayerGrad::new(LayerMeta::other("z", 100), vec![0.0; 100])],
        };
        let metas: Vec<LayerMeta> = g.layers.iter().map(|l| l.meta.clone()).collect();
        let mut codec = QsgdCodec::new(4, 0);
        let payload = codec.compress(&g).unwrap();
        let recon = codec.decompress(&payload, &metas).unwrap();
        assert!(recon.layers[0].data.iter().all(|&x| x == 0.0));
    }
}
