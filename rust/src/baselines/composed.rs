//! Codec composition — the paper's §7.1 claim made concrete:
//!
//! * [`SparsifiedEblc`] — TopK sparsification upstream, the
//!   predictor-enhanced EBLC downstream on the *selected* values ("our
//!   predictor-enhanced EBLC can serve as a downstream quantizer applied
//!   to the selected subset in a sparsified gradient, further reducing
//!   transmission cost without violating error guarantees"). Indices are
//!   delta+varint coded; the kept values keep the per-element bound.
//!
//! * [`ErrorFeedback`] — the standard EF wrapper (Karimireddy et al. 2019,
//!   cited in §7.1) around any inner codec: the compression error is
//!   accumulated and re-injected next round, stabilizing non-error-bounded
//!   codecs like TopK/QSGD.

use crate::compress::blob::{BlobReader, BlobWriter};
use crate::compress::frame::{Frame, LayerReport};
use crate::compress::huffman;
use crate::compress::lossless::{self, Backend};
use crate::compress::quant::{ErrorBound, CODE_RADIUS, ESCAPE_CODE};
use crate::compress::GradientCodec;
use crate::tensor::{LayerGrad, LayerMeta};

/// TopK → error-bounded quantization of the kept values.
pub struct SparsifiedEblc {
    /// Keep fraction.
    pub k: f64,
    pub error_bound: ErrorBound,
    pub backend: Backend,
}

impl SparsifiedEblc {
    pub fn new(k: f64, error_bound: ErrorBound) -> Self {
        assert!(k > 0.0 && k <= 1.0);
        SparsifiedEblc { k, error_bound, backend: Backend::default() }
    }

    fn compress_layer(&self, layer: &LayerGrad) -> (Vec<u8>, LayerReport) {
        let data = &layer.data;
        let keep = ((data.len() as f64 * self.k).ceil() as usize).clamp(1, data.len());
        let mut idx: Vec<u32> = (0..data.len() as u32).collect();
        idx.select_nth_unstable_by(keep - 1, |&a, &b| {
            data[b as usize]
                .abs()
                .partial_cmp(&data[a as usize].abs())
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut kept: Vec<u32> = idx[..keep].to_vec();
        kept.sort_unstable();
        let vals: Vec<f32> = kept.iter().map(|&i| data[i as usize]).collect();
        // Error-bounded quantization of the kept values (pred = 0; the
        // kept set is already sparse/unstructured).
        let (lo, hi) = crate::util::stats::finite_min_max(&vals);
        let delta = self.error_bound.resolve(lo, hi) as f32;
        let two_delta = 2.0 * delta;
        let inv = if two_delta > 0.0 { 1.0 / two_delta } else { 0.0 };
        let mut codes = Vec::with_capacity(keep);
        let mut escapes = Vec::new();
        for &v in &vals {
            let code_f = (v * inv + 0.5).floor();
            let code = code_f as i32;
            let r = code as f32 * two_delta;
            if v.is_finite()
                && two_delta > 0.0
                && code_f.abs() <= CODE_RADIUS as f32
                && (r - v).abs() <= delta
            {
                codes.push(code);
            } else {
                codes.push(ESCAPE_CODE);
                escapes.push(v);
            }
        }
        let mut w = BlobWriter::new();
        w.put_u32(data.len() as u32);
        w.put_u32(keep as u32);
        w.put_f64(delta as f64);
        // Delta-coded indices as varint bytes, then entropy streams.
        let mut idx_bytes = Vec::with_capacity(keep * 2);
        let mut prev = 0u32;
        for &i in &kept {
            let mut d = i - prev;
            prev = i;
            loop {
                let b = (d & 0x7f) as u8;
                d >>= 7;
                if d == 0 {
                    idx_bytes.push(b);
                    break;
                }
                idx_bytes.push(b | 0x80);
            }
        }
        let entropy = huffman::encode_to_bytes(&codes);
        let report = LayerReport {
            name: layer.meta.name.clone(),
            raw_bytes: data.len() * 4,
            side_info_bytes: idx_bytes.len() + escapes.len() * 4,
            entropy_bytes: entropy.len(),
            escape_count: escapes.len(),
            lossy: true,
            ..Default::default()
        };
        w.put_bytes(&idx_bytes);
        w.put_bytes(&entropy);
        w.put_f32_slice(&escapes);
        (w.into_bytes(), report)
    }

    fn decompress_layer(
        &self,
        meta: &LayerMeta,
        body: &[u8],
    ) -> crate::Result<(Vec<f32>, LayerReport)> {
        let mut r = BlobReader::new(body);
        let n = r.get_u32()? as usize;
        anyhow::ensure!(n == meta.numel, "sparse-eblc layer {}: numel", meta.name);
        let keep = r.get_u32()? as usize;
        anyhow::ensure!(keep <= n, "sparse-eblc layer {}: keep {} > numel", meta.name, keep);
        let delta = r.get_f64()? as f32;
        let idx_bytes = r.get_bytes()?;
        let entropy = r.get_bytes()?;
        // `keep` is bounded by the trusted numel above, so it caps the
        // decode against corrupt streams declaring inflated counts.
        let (codes, _) =
            crate::compress::entropy::EntropyCoder::Huffman.decode_bounded(entropy, keep)?;
        anyhow::ensure!(codes.len() == keep, "sparse-eblc: code count");
        let escapes = r.get_f32_vec()?;
        let report = LayerReport {
            name: meta.name.clone(),
            raw_bytes: n * 4,
            side_info_bytes: idx_bytes.len() + escapes.len() * 4,
            entropy_bytes: entropy.len(),
            escape_count: escapes.len(),
            lossy: true,
            ..Default::default()
        };
        // Decode indices.
        let mut out = vec![0.0f32; n];
        let mut pos = 0usize;
        let mut acc = 0u32;
        let mut esc = escapes.iter();
        let two_delta = 2.0 * delta;
        for &code in &codes {
            let mut d = 0u32;
            let mut shift = 0;
            loop {
                let b = *idx_bytes.get(pos).ok_or_else(|| anyhow::anyhow!("idx underrun"))?;
                pos += 1;
                d |= ((b & 0x7f) as u32) << shift;
                if b & 0x80 == 0 {
                    break;
                }
                shift += 7;
            }
            acc += d;
            let v = if code == ESCAPE_CODE {
                *esc.next().ok_or_else(|| anyhow::anyhow!("escape underrun"))?
            } else {
                code as f32 * two_delta
            };
            *out.get_mut(acc as usize).ok_or_else(|| anyhow::anyhow!("index {acc} oob"))? = v;
        }
        Ok((out, report))
    }
}

impl GradientCodec for SparsifiedEblc {
    fn encode_layer(&mut self, idx: usize, layer: &LayerGrad) -> crate::Result<Frame> {
        let (body, report) = self.compress_layer(layer);
        let closed = self.backend.compress(&body)?;
        Ok(Frame::new(idx, closed, report))
    }

    fn decode_frame(
        &mut self,
        frame: &Frame,
        meta: &LayerMeta,
    ) -> crate::Result<(LayerGrad, LayerReport)> {
        let body = lossless::decompress(&frame.payload)?;
        let (data, mut report) = self.decompress_layer(meta, &body)?;
        report.compressed_bytes = frame.wire_size();
        Ok((LayerGrad::new(meta.clone(), data), report))
    }

    fn name(&self) -> &'static str {
        "topk+eblc"
    }

    fn reset(&mut self) {}
}

/// Error-feedback wrapper: `compress(g + residual)`, where `residual`
/// accumulates what the inner codec lost last round. The decompressor
/// side is pass-through (EF is a client-side mechanism).
pub struct ErrorFeedback {
    pub inner: Box<dyn GradientCodec>,
    residual: Vec<Vec<f32>>,
}

impl ErrorFeedback {
    pub fn new(inner: Box<dyn GradientCodec>) -> Self {
        ErrorFeedback { inner, residual: Vec::new() }
    }
}

impl GradientCodec for ErrorFeedback {
    fn begin(&mut self, n_layers: usize) -> crate::Result<()> {
        if self.residual.len() != n_layers {
            self.residual = vec![Vec::new(); n_layers];
        }
        self.inner.begin(n_layers)
    }

    fn encode_layer(&mut self, idx: usize, layer: &LayerGrad) -> crate::Result<Frame> {
        // g' = g + residual (lazily sized on first sight of the layer).
        if self.residual.len() <= idx {
            self.residual.resize(idx + 1, Vec::new());
        }
        if self.residual[idx].len() != layer.data.len() {
            self.residual[idx] = vec![0.0; layer.data.len()];
        }
        let adjusted = LayerGrad::new(
            layer.meta.clone(),
            layer
                .data
                .iter()
                .zip(&self.residual[idx])
                .map(|(g, r)| g + r)
                .collect(),
        );
        let frame = self.inner.encode_layer(idx, &adjusted)?;
        // residual' = g' − decode(frame): reconstruct through a scratch
        // decode on the inner codec — valid for the stateless family
        // (topk/qsgd) that EF is meant for; stateful inners (fedgec) are
        // already error-bounded and gain nothing from EF.
        let (recon, _) = self.inner.decode_frame(&frame, &layer.meta)?;
        for ((res, adj), rec) in
            self.residual[idx].iter_mut().zip(&adjusted.data).zip(&recon.data)
        {
            *res = adj - rec;
        }
        Ok(frame)
    }

    fn decode_frame(
        &mut self,
        frame: &Frame,
        meta: &LayerMeta,
    ) -> crate::Result<(LayerGrad, LayerReport)> {
        // EF is a client-side mechanism: the decompressor side is
        // pass-through.
        self.inner.decode_frame(frame, meta)
    }

    fn name(&self) -> &'static str {
        "error-feedback"
    }

    fn reset(&mut self) {
        self.inner.reset();
        self.residual.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::topk::TopKCodec;
    use crate::tensor::ModelGrad;
    use crate::util::rng::Rng;

    fn grads(n: usize, seed: u64) -> (ModelGrad, Vec<LayerMeta>) {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        let g = ModelGrad { layers: vec![LayerGrad::new(LayerMeta::other("g", n), data)] };
        let metas = g.layers.iter().map(|l| l.meta.clone()).collect();
        (g, metas)
    }

    #[test]
    fn sparsified_eblc_kept_values_bounded() {
        let (g, metas) = grads(10_000, 1);
        let mut codec = SparsifiedEblc::new(0.1, ErrorBound::Rel(1e-2));
        let payload = codec.compress(&g).unwrap();
        let recon = codec.decompress(&payload, &metas).unwrap();
        let orig = &g.layers[0].data;
        let rec = &recon.layers[0].data;
        let mut kept = 0;
        for (o, r) in orig.iter().zip(rec) {
            if *r != 0.0 {
                kept += 1;
                // kept values honor the bound relative to the kept range
                assert!((o - r).abs() < 0.05 * o.abs().max(1.0), "{o} vs {r}");
            }
        }
        assert!(kept >= 1000 && kept <= 1100, "kept {kept}");
    }

    #[test]
    fn sparsified_eblc_beats_plain_topk_size() {
        let (g, _) = grads(100_000, 2);
        let p_plain = TopKCodec::new(0.05).compress(&g).unwrap();
        let p_composed =
            SparsifiedEblc::new(0.05, ErrorBound::Rel(3e-2)).compress(&g).unwrap();
        assert!(
            p_composed.len() < p_plain.len(),
            "composed {} should beat plain topk {} (paper §7.1)",
            p_composed.len(),
            p_plain.len()
        );
    }

    #[test]
    fn error_feedback_recovers_dropped_mass() {
        // With EF, a repeated constant gradient eventually transmits all
        // coordinates (residual accumulation promotes dropped ones).
        let n = 1000;
        let (g, metas) = grads(n, 3);
        let mut ef = ErrorFeedback::new(Box::new(TopKCodec::new(0.05)));
        let mut seen = vec![false; n];
        for _ in 0..30 {
            let payload = ef.compress(&g).unwrap();
            let recon = ef.decompress(&payload, &metas).unwrap();
            for (s, v) in seen.iter_mut().zip(&recon.layers[0].data) {
                if *v != 0.0 {
                    *s = true;
                }
            }
        }
        let covered = seen.iter().filter(|&&s| s).count();
        assert!(
            covered > n / 2,
            "EF should cycle through coordinates, covered {covered}/{n}"
        );
        // Without EF, TopK keeps sending the same top 5%.
        let mut plain = TopKCodec::new(0.05);
        let mut seen2 = vec![false; n];
        for _ in 0..30 {
            let payload = plain.compress(&g).unwrap();
            let recon = plain.decompress(&payload, &metas).unwrap();
            for (s, v) in seen2.iter_mut().zip(&recon.layers[0].data) {
                if *v != 0.0 {
                    *s = true;
                }
            }
        }
        let covered2 = seen2.iter().filter(|&&s| s).count();
        assert!(covered2 < covered, "plain {covered2} vs EF {covered}");
    }

    #[test]
    fn spec_registry_includes_composed() {
        use crate::compress::spec::CodecSpec;
        assert_eq!(CodecSpec::parse("topk+eblc:k=0.05,eb=rel1e-2").unwrap().build().name(), "topk+eblc");
        assert_eq!(CodecSpec::parse("ef(topk:k=0.05)").unwrap().build().name(), "error-feedback");
    }
}
