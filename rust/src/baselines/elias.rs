//! Elias gamma / delta codes for positive integers — the integer coding
//! used by the QSGD baseline (Alistarh et al., NeurIPS 2017 encode their
//! quantization levels with Elias codes).

use crate::util::bitio::{BitReader, BitWriter};

/// Elias gamma: `floor(log2 v)` zeros, then the binary of `v`. `v >= 1`.
pub fn gamma_encode(w: &mut BitWriter, v: u64) {
    debug_assert!(v >= 1);
    let nbits = 64 - v.leading_zeros() as u8; // position of MSB, 1-based
    for _ in 0..nbits - 1 {
        w.put_bit(false);
    }
    w.put_bits(v, nbits);
}

/// Decode one gamma-coded integer.
pub fn gamma_decode(r: &mut BitReader) -> Option<u64> {
    let mut zeros = 0u8;
    loop {
        match r.get_bit()? {
            false => {
                zeros += 1;
                if zeros > 63 {
                    return None;
                }
            }
            true => break,
        }
    }
    let rest = r.get_bits(zeros)?;
    Some((1u64 << zeros) | rest)
}

/// Elias delta: gamma-code the bit length, then the mantissa. Better for
/// large values.
pub fn delta_encode(w: &mut BitWriter, v: u64) {
    debug_assert!(v >= 1);
    let nbits = 64 - v.leading_zeros() as u8;
    gamma_encode(w, nbits as u64);
    if nbits > 1 {
        w.put_bits(v & !(1u64 << (nbits - 1)), nbits - 1);
    }
}

/// Decode one delta-coded integer.
pub fn delta_decode(r: &mut BitReader) -> Option<u64> {
    let nbits = gamma_decode(r)? as u8;
    if nbits == 0 || nbits > 64 {
        return None;
    }
    if nbits == 1 {
        return Some(1);
    }
    let rest = r.get_bits(nbits - 1)?;
    Some((1u64 << (nbits - 1)) | rest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn gamma_roundtrip_small() {
        let mut w = BitWriter::new();
        let vals = [1u64, 2, 3, 4, 5, 17, 100, 1 << 20];
        for &v in &vals {
            gamma_encode(&mut w, v);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &v in &vals {
            assert_eq!(gamma_decode(&mut r), Some(v));
        }
    }

    #[test]
    fn delta_roundtrip() {
        let mut w = BitWriter::new();
        let vals = [1u64, 2, 7, 1000, u32::MAX as u64, 1 << 50];
        for &v in &vals {
            delta_encode(&mut w, v);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &v in &vals {
            assert_eq!(delta_decode(&mut r), Some(v));
        }
    }

    #[test]
    fn gamma_one_is_single_bit() {
        let mut w = BitWriter::new();
        gamma_encode(&mut w, 1);
        assert_eq!(w.bit_len(), 1);
    }

    #[test]
    fn property_roundtrip() {
        prop::check("elias roundtrip", 100, |rng| {
            let n = 1 + rng.next_below(200);
            let vals: Vec<u64> = (0..n).map(|_| 1 + rng.next_below(1 << 30) as u64).collect();
            let mut w = BitWriter::new();
            for &v in &vals {
                if v % 2 == 0 {
                    gamma_encode(&mut w, v);
                } else {
                    delta_encode(&mut w, v);
                }
            }
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            for &v in &vals {
                let got = if v % 2 == 0 { gamma_decode(&mut r) } else { delta_decode(&mut r) };
                if got != Some(v) {
                    return Err(format!("{v} -> {got:?}"));
                }
            }
            Ok(())
        });
    }
}
