//! SZ3-style baseline EBLC (Liang et al., IEEE TBD 2022; Zhao et al.,
//! ICDE 2021): generic **Lorenzo** and multi-level **cubic interpolation**
//! predictors over the same error-bounded quantizer, Huffman coder and
//! lossless backend as FedGEC. This is the state-of-the-art comparator of
//! the paper's Table 4; its predictors assume smooth, spatially-correlated
//! data — exactly the assumption that fails on gradients (paper §3.1,
//! Fig. 3).
//!
//! Faithful details:
//! * prediction always uses **reconstructed** values (decompressor
//!   reproducibility);
//! * interpolation is level-by-level (stride halving), cubic where four
//!   neighbors exist, linear at boundaries — the 1-D analogue of SZ3's
//!   dynamic spline interpolation;
//! * per-layer predictor selection between Lorenzo and interpolation by
//!   sampled residual magnitude, mirroring SZ3's auto-tuning.

use crate::compress::blob::{
    bytes_to_f32s, f32s_to_bytes, put_coder_suffix, read_section_coder, section_tag_for,
    BlobReader, BlobWriter, SECTION_LOSSLESS,
};
use crate::compress::entropy::EntropyCoder;
use crate::compress::frame::{Frame, LayerReport};
use crate::compress::lossless::{self, Backend};
use crate::compress::quant::{ErrorBound, CODE_RADIUS, ESCAPE_CODE};
use crate::compress::GradientCodec;
use crate::tensor::{LayerGrad, LayerMeta, ModelGrad};
use crate::util::stats;

/// SZ3 baseline configuration.
#[derive(Debug, Clone)]
pub struct Sz3Config {
    pub error_bound: ErrorBound,
    /// Small-layer lossless threshold (same convention as FedGEC).
    pub t_lossy: usize,
    /// Stage-3 entropy coder (same registry as FedGEC; spec key `ec`).
    pub entropy: EntropyCoder,
    pub backend: Backend,
    /// Force a predictor instead of auto-selecting.
    pub force_predictor: Option<Predictor>,
}

impl Default for Sz3Config {
    fn default() -> Self {
        Sz3Config {
            error_bound: ErrorBound::Rel(1e-2),
            t_lossy: 1024,
            entropy: EntropyCoder::Huffman,
            backend: Backend::default(),
            force_predictor: None,
        }
    }
}

/// Which generic predictor a layer used.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Predictor {
    Lorenzo,
    Interpolation,
}

impl Predictor {
    fn tag(&self) -> u8 {
        match self {
            Predictor::Lorenzo => 0,
            Predictor::Interpolation => 1,
        }
    }
    fn from_tag(t: u8) -> anyhow::Result<Self> {
        match t {
            0 => Ok(Predictor::Lorenzo),
            1 => Ok(Predictor::Interpolation),
            _ => anyhow::bail!("bad predictor tag {t}"),
        }
    }
}

/// Quantize helper shared by both predictors: given prediction `pred` for
/// element `x`, emit code/escape and return the reconstruction.
#[inline]
fn quantize_one(
    x: f32,
    pred: f32,
    delta: f32,
    two_delta: f32,
    inv_two_delta: f32,
    codes: &mut Vec<i32>,
    escapes: &mut Vec<f32>,
) -> f32 {
    if !x.is_finite() || two_delta <= 0.0 {
        codes.push(ESCAPE_CODE);
        escapes.push(x);
        return x;
    }
    let code_f = ((x - pred) * inv_two_delta + 0.5).floor();
    if code_f.abs() > CODE_RADIUS as f32 {
        codes.push(ESCAPE_CODE);
        escapes.push(x);
        return x;
    }
    let code = code_f as i32;
    let r = pred + code as f32 * two_delta;
    if (r - x).abs() > delta || !r.is_finite() {
        codes.push(ESCAPE_CODE);
        escapes.push(x);
        x
    } else {
        codes.push(code);
        r
    }
}

/// Lorenzo-1D encode: pred[i] = recon[i-1].
fn lorenzo_encode(data: &[f32], delta: f32) -> (Vec<i32>, Vec<f32>, Vec<f32>) {
    let two_delta = 2.0 * delta;
    let inv = if two_delta > 0.0 { 1.0 / two_delta } else { 0.0 };
    let mut codes = Vec::with_capacity(data.len());
    let mut escapes = Vec::new();
    let mut recon = Vec::with_capacity(data.len());
    let mut prev = 0.0f32;
    for &x in data {
        let r = quantize_one(x, prev, delta, two_delta, inv, &mut codes, &mut escapes);
        recon.push(r);
        prev = r;
    }
    (codes, escapes, recon)
}

fn lorenzo_decode(codes: &[i32], escapes: &[f32], delta: f32) -> anyhow::Result<Vec<f32>> {
    let two_delta = 2.0 * delta;
    let mut esc = escapes.iter();
    let mut recon = Vec::with_capacity(codes.len());
    let mut prev = 0.0f32;
    for &c in codes {
        let r = if c == ESCAPE_CODE {
            *esc.next().ok_or_else(|| anyhow::anyhow!("escape underrun"))?
        } else {
            prev + c as f32 * two_delta
        };
        recon.push(r);
        prev = r;
    }
    Ok(recon)
}

/// The interpolation traversal: positions are visited level by level.
/// Returns, for each visit, (index, stride) in order. Level-0 anchors
/// (index 0 and, implicitly, Lorenzo along top-level stride) come first.
fn interp_levels(n: usize) -> Vec<(usize, usize)> {
    // Top stride: largest power of two < n (at least 1).
    let mut order = Vec::with_capacity(n);
    if n == 0 {
        return order;
    }
    let mut top = 1usize;
    while top * 2 < n {
        top *= 2;
    }
    // Anchors at multiples of `top` (predicted by Lorenzo over anchors).
    let mut i = 0;
    while i < n {
        order.push((i, 0)); // stride 0 marks anchor
        i += top;
    }
    let mut s = top / 2;
    while s >= 1 {
        let mut i = s;
        while i < n {
            if (i / s) % 2 == 1 {
                order.push((i, s));
            }
            i += s;
        }
        if s == 1 {
            break;
        }
        s /= 2;
    }
    order
}

/// Cubic/linear interpolation prediction at index `i` with stride `s`,
/// reading already-reconstructed neighbors.
#[inline]
fn interp_predict(recon: &[f32], filled: &[bool], i: usize, s: usize, n: usize) -> f32 {
    let prev = i.checked_sub(s).filter(|&j| filled[j]);
    let next = (i + s < n && filled[i + s]).then_some(i + s);
    let prev2 = i.checked_sub(3 * s).filter(|&j| filled[j]);
    let next2 = (i + 3 * s < n && filled[i + 3 * s]).then_some(i + 3 * s);
    match (prev2, prev, next, next2) {
        // Full cubic stencil (Catmull-Rom style weights used by SZ3):
        (Some(a), Some(b), Some(c), Some(d)) => {
            (-recon[a] + 9.0 * recon[b] + 9.0 * recon[c] - recon[d]) / 16.0
        }
        (_, Some(b), Some(c), _) => 0.5 * (recon[b] + recon[c]),
        (_, Some(b), None, _) => recon[b],
        (_, None, Some(c), _) => recon[c],
        _ => 0.0,
    }
}

fn interp_encode(data: &[f32], delta: f32) -> (Vec<i32>, Vec<f32>, Vec<f32>) {
    let n = data.len();
    let two_delta = 2.0 * delta;
    let inv = if two_delta > 0.0 { 1.0 / two_delta } else { 0.0 };
    // codes are emitted in traversal order; decoder replays the same order.
    let order = interp_levels(n);
    let mut codes = Vec::with_capacity(n);
    let mut escapes = Vec::new();
    let mut recon = vec![0.0f32; n];
    let mut filled = vec![false; n];
    let mut prev_anchor = 0.0f32;
    for &(i, s) in &order {
        let pred =
            if s == 0 { prev_anchor } else { interp_predict(&recon, &filled, i, s, n) };
        let r = quantize_one(data[i], pred, delta, two_delta, inv, &mut codes, &mut escapes);
        recon[i] = r;
        filled[i] = true;
        if s == 0 {
            prev_anchor = r;
        }
    }
    (codes, escapes, recon)
}

fn interp_decode(codes: &[i32], escapes: &[f32], n: usize, delta: f32) -> anyhow::Result<Vec<f32>> {
    let two_delta = 2.0 * delta;
    let order = interp_levels(n);
    if order.len() != codes.len() {
        anyhow::bail!("interp: {} codes for {} positions", codes.len(), order.len());
    }
    let mut esc = escapes.iter();
    let mut recon = vec![0.0f32; n];
    let mut filled = vec![false; n];
    let mut prev_anchor = 0.0f32;
    for (&(i, s), &c) in order.iter().zip(codes) {
        let pred = if s == 0 { prev_anchor } else { interp_predict(&recon, &filled, i, s, n) };
        let r = if c == ESCAPE_CODE {
            *esc.next().ok_or_else(|| anyhow::anyhow!("escape underrun"))?
        } else {
            pred + c as f32 * two_delta
        };
        recon[i] = r;
        filled[i] = true;
        if s == 0 {
            prev_anchor = r;
        }
    }
    Ok(recon)
}

/// Sampled auto-selection between predictors (SZ3's tuning step): score
/// each on a strided sample of first differences vs interpolation errors
/// computed on the raw data.
fn select_predictor(data: &[f32]) -> Predictor {
    let n = data.len();
    if n < 64 {
        return Predictor::Lorenzo;
    }
    let step = (n / 1024).max(1);
    let mut lorenzo_err = 0.0f64;
    let mut interp_err = 0.0f64;
    let mut i = step.max(2);
    while i + 1 < n {
        lorenzo_err += (data[i] - data[i - 1]).abs() as f64;
        interp_err += (data[i] - 0.5 * (data[i - 1] + data[i + 1])).abs() as f64;
        i += step;
    }
    if lorenzo_err <= interp_err {
        Predictor::Lorenzo
    } else {
        Predictor::Interpolation
    }
}

/// The SZ3-style codec. Stateless across rounds (generic EBLCs have no
/// cross-round memory — that is the paper's point), so layers encode in
/// parallel trivially.
pub struct Sz3Codec {
    pub cfg: Sz3Config,
}

impl Sz3Codec {
    pub fn new(cfg: Sz3Config) -> Self {
        Sz3Codec { cfg }
    }

    /// Compress a single layer into its closed frame payload.
    fn compress_layer(&self, layer: &LayerGrad) -> crate::Result<(Vec<u8>, LayerReport)> {
        let data = &layer.data;
        let mut report = LayerReport {
            name: layer.meta.name.clone(),
            raw_bytes: data.len() * 4,
            ..Default::default()
        };
        let mut w = BlobWriter::new();
        if data.len() <= self.cfg.t_lossy {
            w.put_u8(SECTION_LOSSLESS);
            w.put_bytes(&f32s_to_bytes(data));
            return Ok((self.cfg.backend.compress(&w.into_bytes())?, report));
        }
        report.lossy = true;
        let (lo, hi) = stats::finite_min_max(data);
        let delta = self.cfg.error_bound.resolve(lo, hi) as f32;
        let pred = self.cfg.force_predictor.unwrap_or_else(|| select_predictor(data));
        let (codes, escapes, _recon) = match pred {
            Predictor::Lorenzo => lorenzo_encode(data, delta),
            Predictor::Interpolation => interp_encode(data, delta),
        };
        let coder = self.cfg.entropy;
        let entropy = coder.encode_to_bytes(&codes);
        report.entropy_bytes = entropy.len();
        report.entropy_coder = coder.name().to_string();
        report.escape_count = escapes.len();
        report.side_info_bytes = escapes.len() * 4;
        // Huffman keeps seed-compatible v1 bytes; other coders bump to v2.
        w.put_u8(section_tag_for(coder));
        w.put_u8(pred.tag());
        put_coder_suffix(&mut w, coder);
        w.put_u32(data.len() as u32);
        w.put_f64(delta as f64);
        w.put_bytes(&entropy);
        w.put_f32_slice(&escapes);
        Ok((self.cfg.backend.compress(&w.into_bytes())?, report))
    }

    fn decompress_layer(
        &self,
        meta: &LayerMeta,
        section: &[u8],
    ) -> crate::Result<(Vec<f32>, LayerReport)> {
        let mut r = BlobReader::new(section);
        let mut report = LayerReport { name: meta.name.clone(), ..Default::default() };
        let tag = r.get_u8()?;
        if tag == SECTION_LOSSLESS {
            let data = bytes_to_f32s(r.get_bytes()?)?;
            anyhow::ensure!(data.len() == meta.numel, "sz3 layer {}: lossless numel", meta.name);
            report.raw_bytes = data.len() * 4;
            return Ok((data, report));
        }
        report.lossy = true;
        let pred = Predictor::from_tag(r.get_u8()?)?;
        let coder = read_section_coder(&mut r, tag)
            .map_err(|e| anyhow::anyhow!("sz3 layer {}: {e}", meta.name))?;
        report.entropy_coder = coder.name().to_string();
        let n = r.get_u32()? as usize;
        if n != meta.numel {
            anyhow::bail!("sz3 layer {}: numel {} != {}", meta.name, n, meta.numel);
        }
        report.raw_bytes = n * 4;
        let delta = r.get_f64()? as f32;
        let entropy = r.get_bytes()?;
        report.entropy_bytes = entropy.len();
        // `n` matches the trusted meta, so it bounds the decode against
        // corrupt streams declaring inflated symbol counts.
        let (codes, _) = coder.decode_bounded(entropy, n)?;
        if codes.len() != n {
            anyhow::bail!("sz3 layer {}: {} codes for {} elements", meta.name, codes.len(), n);
        }
        let escapes = r.get_f32_vec()?;
        report.escape_count = escapes.len();
        report.side_info_bytes = escapes.len() * 4;
        let data = match pred {
            Predictor::Lorenzo => lorenzo_decode(&codes, &escapes, delta),
            Predictor::Interpolation => interp_decode(&codes, &escapes, n, delta),
        }?;
        Ok((data, report))
    }
}

impl GradientCodec for Sz3Codec {
    fn encode_layer(&mut self, idx: usize, layer: &LayerGrad) -> crate::Result<Frame> {
        let (payload, report) = self.compress_layer(layer)?;
        Ok(Frame::new(idx, payload, report))
    }

    fn decode_frame(
        &mut self,
        frame: &Frame,
        meta: &LayerMeta,
    ) -> crate::Result<(LayerGrad, LayerReport)> {
        let section = lossless::decompress(&frame.payload)?;
        let (data, mut report) = self.decompress_layer(meta, &section)?;
        report.compressed_bytes = frame.wire_size();
        Ok((LayerGrad::new(meta.clone(), data), report))
    }

    /// Stateless per layer ⇒ embarrassingly parallel whole-model encode.
    fn encode_model(&mut self, grads: &ModelGrad) -> crate::Result<Vec<Frame>> {
        let this = &*self;
        crate::compress::session::encode_model_parallel(grads, |_, layer| {
            this.compress_layer(layer)
        })
    }

    fn name(&self) -> &'static str {
        "sz3"
    }

    fn reset(&mut self) {}
}

/// SZ3 has no cross-round state to externalize — the engine form *is*
/// the codec (that statelessness is exactly what the paper's Fig. 3
/// shows costing ratio on gradients). The explicit state handle is
/// accepted and ignored so the server can swap codec families without
/// changing its store plumbing.
impl crate::compress::engine::CodecEngine for Sz3Codec {
    fn name(&self) -> &'static str {
        "sz3"
    }

    fn decode_frame(
        &mut self,
        frame: &Frame,
        meta: &LayerMeta,
        _state: &mut crate::compress::state::CodecState,
    ) -> crate::Result<(LayerGrad, LayerReport)> {
        GradientCodec::decode_frame(self, frame, meta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn interp_order_covers_all_indices_once() {
        for n in [1usize, 2, 3, 7, 8, 9, 100, 1000] {
            let order = interp_levels(n);
            let mut seen = vec![false; n];
            for &(i, _) in &order {
                assert!(!seen[i], "duplicate index {i} for n={n}");
                seen[i] = true;
            }
            assert!(seen.iter().all(|&s| s), "missing index for n={n}");
        }
    }

    #[test]
    fn lorenzo_roundtrip_smooth_data() {
        let data: Vec<f32> = (0..1000).map(|i| (i as f32 / 50.0).sin()).collect();
        let delta = 0.001;
        let (codes, escapes, recon) = lorenzo_encode(&data, delta);
        let dec = lorenzo_decode(&codes, &escapes, delta).unwrap();
        assert_eq!(recon, dec);
        for (r, x) in dec.iter().zip(&data) {
            assert!((r - x).abs() <= delta * 1.0001);
        }
    }

    #[test]
    fn interp_roundtrip_smooth_data() {
        let data: Vec<f32> = (0..1037).map(|i| (i as f32 / 80.0).cos() * 2.0).collect();
        let delta = 0.001;
        let (codes, escapes, recon) = interp_encode(&data, delta);
        let dec = interp_decode(&codes, &escapes, data.len(), delta).unwrap();
        assert_eq!(recon, dec);
        for (r, x) in dec.iter().zip(&data) {
            assert!((r - x).abs() <= delta * 1.0001);
        }
    }

    #[test]
    fn smooth_data_compresses_much_better_than_noise() {
        // The SZ3 design premise: smooth scientific data -> tiny residuals.
        let smooth: Vec<f32> = (0..100_000).map(|i| (i as f32 / 500.0).sin()).collect();
        let mut rng = Rng::new(8);
        let noise: Vec<f32> = (0..100_000).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut codec = Sz3Codec::new(Sz3Config {
            error_bound: ErrorBound::Rel(1e-3),
            ..Default::default()
        });
        let mk = |data: Vec<f32>| ModelGrad {
            layers: vec![LayerGrad::new(LayerMeta::other("x", 100_000), data)],
        };
        let smooth_payload = codec.compress(&mk(smooth)).unwrap();
        let noise_payload = codec.compress(&mk(noise)).unwrap();
        assert!(
            smooth_payload.len() * 3 < noise_payload.len(),
            "smooth {} vs noise {}",
            smooth_payload.len(),
            noise_payload.len()
        );
    }

    #[test]
    fn full_codec_roundtrip_bound() {
        let mut rng = Rng::new(9);
        let data: Vec<f32> = (0..5000).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        let g = ModelGrad { layers: vec![LayerGrad::new(LayerMeta::other("g", 5000), data)] };
        let metas: Vec<LayerMeta> = g.layers.iter().map(|l| l.meta.clone()).collect();
        for eb in [1e-3, 1e-2, 5e-2] {
            let mut codec = Sz3Codec::new(Sz3Config {
                error_bound: ErrorBound::Rel(eb),
                ..Default::default()
            });
            let payload = codec.compress(&g).unwrap();
            let recon = codec.decompress(&payload, &metas).unwrap();
            let (lo, hi) = stats::finite_min_max(&g.layers[0].data);
            let delta = ErrorBound::Rel(eb).resolve(lo, hi) as f32;
            for (r, x) in recon.layers[0].data.iter().zip(&g.layers[0].data) {
                assert!((r - x).abs() <= delta * 1.0001);
            }
        }
    }

    #[test]
    fn rans_entropy_stage_roundtrips_identically() {
        let mut rng = Rng::new(10);
        let data: Vec<f32> = (0..20_000).map(|_| rng.normal_f32(0.0, 0.2)).collect();
        let g = ModelGrad { layers: vec![LayerGrad::new(LayerMeta::other("g", 20_000), data)] };
        let metas: Vec<LayerMeta> = g.layers.iter().map(|l| l.meta.clone()).collect();
        let mut outs = Vec::new();
        for ec in [EntropyCoder::Huffman, EntropyCoder::Rans] {
            let mut codec = Sz3Codec::new(Sz3Config { entropy: ec, ..Default::default() });
            let payload = codec.compress(&g).unwrap();
            let (recon, report) = codec.decompress_with_report(&payload, &metas).unwrap();
            assert_eq!(report.layers[0].entropy_coder, ec.name());
            outs.push(recon.layers[0].data.clone());
        }
        // The entropy stage is lossless: identical reconstructions.
        for (a, b) in outs[0].iter().zip(&outs[1]) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn property_roundtrip_random_gradients() {
        prop::check("sz3 roundtrip", 40, |rng| {
            let n = 16 + prop::arb_len(rng, 4000);
            let data = prop::arb_gradient(rng, n);
            let eb = prop::arb_error_bound(rng);
            let g = ModelGrad {
                layers: vec![LayerGrad::new(LayerMeta::other("g", n), data.clone())],
            };
            let metas: Vec<LayerMeta> = g.layers.iter().map(|l| l.meta.clone()).collect();
            let force = if rng.chance(0.5) {
                Some(Predictor::Lorenzo)
            } else {
                Some(Predictor::Interpolation)
            };
            let mut codec = Sz3Codec::new(Sz3Config {
                error_bound: ErrorBound::Rel(eb),
                t_lossy: 8,
                force_predictor: force,
                ..Default::default()
            });
            let payload = codec.compress(&g).map_err(|e| e.to_string())?;
            let recon = codec.decompress(&payload, &metas).map_err(|e| e.to_string())?;
            let (lo, hi) = stats::finite_min_max(&data);
            let delta = ErrorBound::Rel(eb).resolve(lo, hi) as f32;
            for (r, x) in recon.layers[0].data.iter().zip(&data) {
                if x.is_finite() && (r - x).abs() > delta * 1.001 {
                    return Err(format!("bound violated: {r} vs {x}, delta {delta}"));
                }
            }
            Ok(())
        });
    }
}
