//! Sharded atomics-based metric primitives: counters, gauges, and
//! fixed-bucket histograms with `&'static str` identity.
//!
//! All metrics are `static` items constructed in a `const` context, so
//! there is no registration step and no lock on the hot path — an
//! update is one relaxed `fetch_add` on a cache-line-padded lane picked
//! per thread. With the `telemetry-off` cargo feature the whole module
//! is swapped for zero-sized no-op twins with the identical API, so
//! instrumented call sites compile to nothing and the perf gate's
//! floors hold by construction, not by promise.

/// Counter lanes: updates land on `thread-id mod LANES`, reads sum all
/// lanes. Eight 64-byte lanes bound the memory cost at 512 B/counter
/// while keeping the sharded runner's workers off each other's lines.
pub const LANES: usize = 8;

/// Upper bound on histogram bucket count (excluding the implicit
/// `+Inf` bucket); `Histogram::new` panics at first use beyond it.
pub const HIST_MAX_BOUNDS: usize = 16;

#[cfg(not(feature = "telemetry-off"))]
mod imp {
    use super::{HIST_MAX_BOUNDS, LANES};
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
    use std::time::Duration;

    /// One cache-line-padded counter lane (64-byte aligned so two lanes
    /// never share a line).
    #[repr(align(64))]
    struct Lane(AtomicU64);

    /// Monotonic counter sharded over [`LANES`] padded atomics.
    pub struct Counter {
        lanes: [Lane; LANES],
    }

    impl Counter {
        pub const fn new() -> Counter {
            Counter {
                lanes: [
                    Lane(AtomicU64::new(0)),
                    Lane(AtomicU64::new(0)),
                    Lane(AtomicU64::new(0)),
                    Lane(AtomicU64::new(0)),
                    Lane(AtomicU64::new(0)),
                    Lane(AtomicU64::new(0)),
                    Lane(AtomicU64::new(0)),
                    Lane(AtomicU64::new(0)),
                ],
            }
        }

        #[inline]
        pub fn add(&self, v: u64) {
            self.lanes[lane_index()].0.fetch_add(v, Ordering::Relaxed);
        }

        #[inline]
        pub fn inc(&self) {
            self.add(1);
        }

        /// Accumulate a duration as integer nanoseconds (exposition
        /// divides by 1e9; exact for any realistic process lifetime).
        #[inline]
        pub fn add_duration(&self, d: Duration) {
            self.add(d.as_nanos() as u64);
        }

        /// Sum over all lanes. Relaxed: concurrent updates may or may
        /// not be visible, but the value is always a valid past total.
        pub fn get(&self) -> u64 {
            self.lanes.iter().map(|l| l.0.load(Ordering::Relaxed)).sum()
        }

        /// Test support; production counters are process-monotonic.
        pub fn reset(&self) {
            for l in &self.lanes {
                l.0.store(0, Ordering::Relaxed);
            }
        }
    }

    /// Last-write-wins instantaneous value (occupancy snapshots).
    pub struct Gauge(AtomicU64);

    impl Gauge {
        pub const fn new() -> Gauge {
            Gauge(AtomicU64::new(0))
        }

        #[inline]
        pub fn set(&self, v: u64) {
            self.0.store(v, Ordering::Relaxed);
        }

        pub fn get(&self) -> u64 {
            self.0.load(Ordering::Relaxed)
        }

        pub fn reset(&self) {
            self.set(0);
        }
    }

    /// Fixed-bucket duration histogram. Bounds are seconds, ascending;
    /// observations scan linearly (≤ [`HIST_MAX_BOUNDS`] compares), so
    /// an observe is a handful of loads plus three relaxed adds.
    pub struct Histogram {
        bounds: &'static [f64],
        counts: [AtomicU64; HIST_MAX_BOUNDS + 1],
        sum_ns: AtomicU64,
        count: AtomicU64,
    }

    impl Histogram {
        pub const fn new(bounds: &'static [f64]) -> Histogram {
            Histogram {
                bounds,
                counts: [
                    AtomicU64::new(0),
                    AtomicU64::new(0),
                    AtomicU64::new(0),
                    AtomicU64::new(0),
                    AtomicU64::new(0),
                    AtomicU64::new(0),
                    AtomicU64::new(0),
                    AtomicU64::new(0),
                    AtomicU64::new(0),
                    AtomicU64::new(0),
                    AtomicU64::new(0),
                    AtomicU64::new(0),
                    AtomicU64::new(0),
                    AtomicU64::new(0),
                    AtomicU64::new(0),
                    AtomicU64::new(0),
                    AtomicU64::new(0),
                ],
            }
        }

        pub fn observe(&self, d: Duration) {
            assert!(self.bounds.len() <= HIST_MAX_BOUNDS, "too many histogram buckets");
            let s = d.as_secs_f64();
            let mut i = 0;
            // Prometheus buckets are upper-inclusive: the observation
            // lands in the first bucket whose bound is >= the value.
            while i < self.bounds.len() && s > self.bounds[i] {
                i += 1;
            }
            self.counts[i].fetch_add(1, Ordering::Relaxed);
            self.sum_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
            self.count.fetch_add(1, Ordering::Relaxed);
        }

        pub fn bounds(&self) -> &'static [f64] {
            self.bounds
        }

        /// Per-bucket (non-cumulative) counts; index `bounds.len()` is
        /// the overflow (`+Inf`) bucket.
        pub fn bucket_counts(&self) -> Vec<u64> {
            self.counts[..=self.bounds.len()]
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect()
        }

        pub fn sum_seconds(&self) -> f64 {
            self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9
        }

        pub fn count(&self) -> u64 {
            self.count.load(Ordering::Relaxed)
        }

        pub fn reset(&self) {
            for c in &self.counts {
                c.store(0, Ordering::Relaxed);
            }
            self.sum_ns.store(0, Ordering::Relaxed);
            self.count.store(0, Ordering::Relaxed);
        }
    }

    static NEXT_LANE: AtomicUsize = AtomicUsize::new(0);

    thread_local! {
        /// Each thread's home lane, assigned round-robin on first use.
        static LANE: usize = NEXT_LANE.fetch_add(1, Ordering::Relaxed) & (LANES - 1);
    }

    #[inline]
    fn lane_index() -> usize {
        // `try_with`: counter updates during thread teardown (Drop impls
        // running after TLS destruction) fall back to lane 0.
        LANE.try_with(|l| *l).unwrap_or(0)
    }
}

#[cfg(feature = "telemetry-off")]
mod imp {
    use std::time::Duration;

    /// Zero-sized no-op twin of the live counter: every method compiles
    /// away, every read is zero.
    pub struct Counter;

    impl Counter {
        pub const fn new() -> Counter {
            Counter
        }
        #[inline]
        pub fn add(&self, _v: u64) {}
        #[inline]
        pub fn inc(&self) {}
        #[inline]
        pub fn add_duration(&self, _d: Duration) {}
        pub fn get(&self) -> u64 {
            0
        }
        pub fn reset(&self) {}
    }

    pub struct Gauge;

    impl Gauge {
        pub const fn new() -> Gauge {
            Gauge
        }
        #[inline]
        pub fn set(&self, _v: u64) {}
        pub fn get(&self) -> u64 {
            0
        }
        pub fn reset(&self) {}
    }

    /// Keeps its bounds so the exposition endpoint renders the same
    /// (all-zero) bucket layout under `telemetry-off`.
    pub struct Histogram {
        bounds: &'static [f64],
    }

    impl Histogram {
        pub const fn new(bounds: &'static [f64]) -> Histogram {
            Histogram { bounds }
        }
        #[inline]
        pub fn observe(&self, _d: Duration) {}
        pub fn bounds(&self) -> &'static [f64] {
            self.bounds
        }
        pub fn bucket_counts(&self) -> Vec<u64> {
            vec![0; self.bounds.len() + 1]
        }
        pub fn sum_seconds(&self) -> f64 {
            0.0
        }
        pub fn count(&self) -> u64 {
            0
        }
        pub fn reset(&self) {}
    }
}

pub use imp::{Counter, Gauge, Histogram};

/// How a raw `u64` metric value renders at exposition time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Unit {
    /// Render the integer as-is.
    Plain,
    /// The counter accumulates nanoseconds; render as seconds.
    NanosToSeconds,
}

/// Which primitive backs a registry entry.
pub enum MetricKind {
    Counter(&'static Counter),
    Gauge(&'static Gauge),
    Histogram(&'static Histogram),
}

/// One exposition-registry entry. Entries sharing a `name` (label
/// variants of the same metric) must be adjacent in the registry so the
/// renderer emits a single `# HELP`/`# TYPE` block per family.
pub struct MetricDef {
    /// Prometheus metric name (`fedgec_*`, `_total` for counters).
    pub name: &'static str,
    /// Label pairs rendered inside `{}`, or `""` for none.
    pub labels: &'static str,
    pub help: &'static str,
    pub unit: Unit,
    pub kind: MetricKind,
}

#[cfg(all(test, not(feature = "telemetry-off")))]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn counter_sums_lanes_and_resets() {
        static C: Counter = Counter::new();
        C.reset();
        C.add(5);
        C.inc();
        C.add_duration(Duration::from_nanos(4));
        assert_eq!(C.get(), 10);
        // Updates from other threads land on other lanes but sum in.
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| C.add(100));
            }
        });
        assert_eq!(C.get(), 410);
        C.reset();
        assert_eq!(C.get(), 0);
    }

    #[test]
    fn gauge_is_last_write_wins() {
        static G: Gauge = Gauge::new();
        G.set(7);
        G.set(3);
        assert_eq!(G.get(), 3);
        G.reset();
    }

    #[test]
    fn histogram_buckets_are_upper_inclusive() {
        static BOUNDS: [f64; 3] = [0.001, 0.01, 0.1];
        static H: Histogram = Histogram::new(&BOUNDS);
        H.reset();
        H.observe(Duration::from_micros(500)); // 0.0005 -> bucket 0
        H.observe(Duration::from_millis(1)); // == bound -> bucket 0
        H.observe(Duration::from_millis(5)); // bucket 1
        H.observe(Duration::from_secs(2)); // +Inf bucket
        assert_eq!(H.bucket_counts(), vec![2, 1, 0, 1]);
        assert_eq!(H.count(), 4);
        assert!((H.sum_seconds() - 2.0065).abs() < 1e-9);
        H.reset();
        assert_eq!(H.count(), 0);
    }
}
