//! Round tracing journal: a bounded in-memory ring of pre-rendered
//! JSONL records, flushed to a file by a background writer thread.
//!
//! # Schema (versioned)
//!
//! Every record is one JSON object per line carrying a version (`"v":1`
//! for the original records, `"v":2` for `eb_plan`) and a
//! `"t"` type tag. Durations are integer nanoseconds (`*_ns` keys) —
//! exact in a JSON f64 below 2^53 ns ≈ 104 days. Record types:
//!
//! | `t`           | emitted by                  | payload |
//! |---------------|-----------------------------|---------|
//! | `round_begin` | every runner, once          | `round`, `shards` |
//! | `client`      | serve/simulation loops      | `ev` ∈ served/drop/resync + detail |
//! | `shard`       | the single-threaded merge   | exact [`ShardStats`] fields, in merge order |
//! | `edge_drop`   | root on a dead edge         | `edge` |
//! | `merge`       | tree-merge                  | `merge_ns` |
//! | `finish`      | finish_round                | `finish_ns`, route counts |
//! | `store`       | occupancy snapshot          | `clients`, `bytes` |
//! | `downlink`    | broadcast/sim accounting    | bytes, full_syncs, codec/transmit ns |
//! | `sim`         | local simulation loop       | client-side comp/transmit ns |
//! | `participants`| every runner, once final    | `n` |
//! | `eval`        | eval rounds                 | `loss`, `acc` |
//! | `eb_plan`     | ebc controller rounds (`"v":2`) | `eb`, `layers` |
//! | `layer`       | decode detail (env-gated)   | per-layer coder route + predictor tag |
//! | `round_end`   | every runner, last          | the full [`RoundStats`] |
//! | `lost`        | the writer                  | `n` records dropped on ring overflow |
//!
//! [`fold_journal`] reconstructs each round's [`RoundStats`] purely
//! from the non-`round_end` records; because `shard` records are
//! emitted from the single-threaded merge path in merge order, the fold
//! reproduces the runner's own arithmetic *exactly* (same f64
//! association order, integer-nanosecond durations) — asserted by
//! `tests/telemetry.rs` and the `fl_e2e` example.

use crate::compress::control::EbPlan;
use crate::fl::round::{RoundStats, ShardStats};
use crate::util::json::Json;
use crate::Result;
use std::collections::{BTreeMap, VecDeque};
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Ring capacity in records. Overflow drops the *incoming* record: the
/// buffered history stays coherent, the loss is counted, and the writer
/// emits a `lost` record (plus `fedgec_journal_dropped_total`).
pub const RING_CAP: usize = 1 << 16;

/// Background writer poll/flush cadence.
const FLUSH_INTERVAL: Duration = Duration::from_millis(50);

struct Ring {
    lines: VecDeque<String>,
    dropped: u64,
}

impl Ring {
    const fn new() -> Ring {
        Ring { lines: VecDeque::new(), dropped: 0 }
    }

    /// Push one rendered line; false (and a counted loss) when full.
    fn push(&mut self, line: String) -> bool {
        if self.lines.len() >= RING_CAP {
            self.dropped += 1;
            false
        } else {
            self.lines.push_back(line);
            true
        }
    }
}

static RING: Mutex<Ring> = Mutex::new(Ring::new());
static JOURNAL_ON: AtomicBool = AtomicBool::new(false);

struct Writer {
    stop: Arc<AtomicBool>,
    handle: JoinHandle<()>,
}

static WRITER: Mutex<Option<Writer>> = Mutex::new(None);

/// Fast-path check: true while a journal file is attached. Callers
/// skip all record formatting when false.
#[inline]
pub fn on() -> bool {
    JOURNAL_ON.load(Ordering::Relaxed)
}

/// Attach the journal to `path` (truncating any existing file) and
/// start the background writer. An already-attached journal is
/// detached (fully flushed) first.
pub fn attach<P: AsRef<Path>>(path: P) -> Result<()> {
    detach();
    let mut out = BufWriter::new(File::create(path.as_ref())?);
    // A fresh journal never inherits records buffered before attach.
    {
        let mut ring = RING.lock().unwrap();
        ring.lines.clear();
        ring.dropped = 0;
    }
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let handle = std::thread::spawn(move || loop {
        let stopping = stop2.load(Ordering::SeqCst);
        let _ = drain_into(&mut out);
        let _ = out.flush();
        if stopping {
            break;
        }
        std::thread::sleep(FLUSH_INTERVAL);
    });
    *WRITER.lock().unwrap() = Some(Writer { stop, handle });
    JOURNAL_ON.store(true, Ordering::SeqCst);
    super::sink_attached();
    Ok(())
}

/// Detach the journal: stop accepting records, drain the ring, flush,
/// and join the writer. Idempotent; a no-op when nothing is attached.
pub fn detach() {
    let w = WRITER.lock().unwrap().take();
    if let Some(w) = w {
        JOURNAL_ON.store(false, Ordering::SeqCst);
        w.stop.store(true, Ordering::SeqCst);
        let _ = w.handle.join();
        super::sink_detached();
    }
}

fn drain_into(out: &mut impl Write) -> std::io::Result<()> {
    let (lines, dropped) = {
        let mut ring = RING.lock().unwrap();
        let lines: Vec<String> = ring.lines.drain(..).collect();
        (lines, std::mem::take(&mut ring.dropped))
    };
    for line in &lines {
        out.write_all(line.as_bytes())?;
        out.write_all(b"\n")?;
    }
    if dropped > 0 {
        writeln!(out, "{{\"v\":1,\"t\":\"lost\",\"n\":{dropped}}}")?;
    }
    Ok(())
}

fn push_line(line: String) {
    if !RING.lock().unwrap().push(line) {
        super::JOURNAL_DROPPED.inc();
    }
}

// ---------------------------------------------------------------------
// Record emission
// ---------------------------------------------------------------------

fn base(t: &str) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("v".to_string(), Json::Num(1.0));
    m.insert("t".to_string(), Json::Str(t.to_string()));
    m
}

fn put(m: &mut BTreeMap<String, Json>, k: &str, v: f64) {
    m.insert(k.to_string(), Json::Num(v));
}

fn put_ns(m: &mut BTreeMap<String, Json>, k: &str, d: Duration) {
    put(m, k, d.as_nanos() as f64);
}

fn emit(m: BTreeMap<String, Json>) {
    push_line(Json::Obj(m).to_string());
}

/// Span handle emitting one round's journal records. Every method is a
/// no-op while no journal is attached, so callers hold spans
/// unconditionally.
pub struct RoundSpan {
    round: u32,
}

impl RoundSpan {
    /// Open a round: emits `round_begin` with the topology width
    /// (worker shards, edge count, or 0 for a hand-built loop).
    pub fn begin(round: u32, shards: usize) -> RoundSpan {
        let span = RoundSpan { round };
        if on() {
            let mut m = span.rec("round_begin");
            put(&mut m, "shards", shards as f64);
            emit(m);
        }
        span
    }

    /// A handle for an already-open round (emits nothing).
    pub fn at(round: u32) -> RoundSpan {
        RoundSpan { round }
    }

    fn rec(&self, t: &str) -> BTreeMap<String, Json> {
        let mut m = base(t);
        put(&mut m, "round", self.round as f64);
        m
    }

    /// One successfully served client update.
    pub fn client_served(
        &self,
        shard: usize,
        client: u64,
        bytes: usize,
        raw: usize,
        decode: Duration,
        agg: Duration,
        loss: f64,
    ) {
        if !on() {
            return;
        }
        let mut m = self.rec("client");
        m.insert("ev".to_string(), Json::Str("served".to_string()));
        put(&mut m, "shard", shard as f64);
        put(&mut m, "client", client as f64);
        put(&mut m, "bytes", bytes as f64);
        put(&mut m, "raw", raw as f64);
        put_ns(&mut m, "decode_ns", decode);
        put_ns(&mut m, "agg_ns", agg);
        put(&mut m, "loss", loss);
        emit(m);
    }

    /// A drop or resync on channel index `ch` (`ev` ∈ "drop"/"resync";
    /// these paths have no trusted client id on the wire).
    pub fn client_event(&self, shard: usize, ch: usize, ev: &str) {
        if !on() {
            return;
        }
        let mut m = self.rec("client");
        m.insert("ev".to_string(), Json::Str(ev.to_string()));
        put(&mut m, "shard", shard as f64);
        put(&mut m, "ch", ch as f64);
        emit(m);
    }

    /// Per-shard tallies — **must** be emitted from the single-threaded
    /// merge path in merge order; [`fold_journal`]'s exactness argument
    /// depends on reproducing the runner's accumulation order.
    pub fn shard(&self, shard: usize, st: &ShardStats) {
        if !on() {
            return;
        }
        let mut m = self.rec("shard");
        put(&mut m, "shard", shard as f64);
        put(&mut m, "served", st.served as f64);
        put(&mut m, "dropped", st.dropped as f64);
        put(&mut m, "resyncs", st.resyncs as f64);
        put(&mut m, "payload_bytes", st.payload_bytes as f64);
        put(&mut m, "raw_bytes", st.raw_bytes as f64);
        put(&mut m, "loss_sum", st.loss_sum);
        put_ns(&mut m, "decode_ns", st.decode_time);
        put_ns(&mut m, "agg_ns", st.agg_time);
        emit(m);
    }

    /// An edge aggregator whose whole subtree dropped this round.
    pub fn edge_drop(&self, edge: usize) {
        if !on() {
            return;
        }
        let mut m = self.rec("edge_drop");
        put(&mut m, "edge", edge as f64);
        emit(m);
    }

    pub fn merge(&self, merge: Duration) {
        if !on() {
            return;
        }
        let mut m = self.rec("merge");
        put_ns(&mut m, "merge_ns", merge);
        emit(m);
    }

    pub fn finish(&self, finish: Duration, binsum: usize, exact: usize, dequant: usize) {
        if !on() {
            return;
        }
        let mut m = self.rec("finish");
        put_ns(&mut m, "finish_ns", finish);
        put(&mut m, "binsum", binsum as f64);
        put(&mut m, "exact", exact as f64);
        put(&mut m, "dequant", dequant as f64);
        emit(m);
    }

    pub fn store(&self, clients: usize, bytes: usize) {
        if !on() {
            return;
        }
        let mut m = self.rec("store");
        put(&mut m, "clients", clients as f64);
        put(&mut m, "bytes", bytes as f64);
        emit(m);
    }

    pub fn downlink(
        &self,
        bytes: usize,
        raw: usize,
        full_syncs: usize,
        codec: Duration,
        transmit: Duration,
    ) {
        if !on() {
            return;
        }
        let mut m = self.rec("downlink");
        put(&mut m, "bytes", bytes as f64);
        put(&mut m, "raw", raw as f64);
        put(&mut m, "full_syncs", full_syncs as f64);
        put_ns(&mut m, "codec_ns", codec);
        put_ns(&mut m, "transmit_ns", transmit);
        emit(m);
    }

    /// Client-side simulation costs (local runner only).
    pub fn sim(&self, comp: Duration, transmit: Duration) {
        if !on() {
            return;
        }
        let mut m = self.rec("sim");
        put_ns(&mut m, "comp_ns", comp);
        put_ns(&mut m, "transmit_ns", transmit);
        emit(m);
    }

    pub fn participants(&self, n: usize) {
        if !on() {
            return;
        }
        let mut m = self.rec("participants");
        put(&mut m, "n", n as f64);
        emit(m);
    }

    /// The round's broadcast error-bound plan (a `"v":2` record — older
    /// readers that bail on unknown types must be tolerant; `fedgec
    /// tail` renders unknowns as pass-through rows).
    pub fn eb_plan(&self, plan: &EbPlan) {
        if !on() {
            return;
        }
        let mut m = self.rec("eb_plan");
        m.insert("v".to_string(), Json::Num(2.0));
        put(&mut m, "eb", plan.round_eb as f64);
        put(
            &mut m,
            "layers",
            plan.per_layer.as_ref().map_or(0, Vec::len) as f64,
        );
        emit(m);
    }

    pub fn eval(&self, loss: f32, acc: f32) {
        if !on() {
            return;
        }
        let mut m = self.rec("eval");
        put(&mut m, "loss", loss as f64);
        put(&mut m, "acc", acc as f64);
        emit(m);
    }

    /// Close the round with the runner's own `RoundStats` — the record
    /// the fold checks itself against.
    pub fn end(&self, stats: &RoundStats) {
        if !on() {
            return;
        }
        emit(stats_json(stats));
    }
}

/// Per-layer decode-route detail (`t:"layer"`), emitted only when both
/// a journal is attached and `FEDGEC_JOURNAL_DETAIL=1` — at fleet scale
/// this is the highest-volume record type. Ignored by the fold.
pub fn layer_detail(client: u64, layer: &str, coder: &str, pred: &str) {
    if !on() || !detail_enabled() {
        return;
    }
    let mut m = base("layer");
    put(&mut m, "client", client as f64);
    m.insert("layer".to_string(), Json::Str(layer.to_string()));
    m.insert("coder".to_string(), Json::Str(coder.to_string()));
    m.insert("pred".to_string(), Json::Str(pred.to_string()));
    emit(m);
}

/// Emit one `layer` record per layer of a decoded payload's
/// [`CodecReport`](crate::compress::frame::CodecReport). Same gating as
/// [`layer_detail`]; the early return skips the iteration entirely.
pub fn report_detail(client: u64, report: &crate::compress::frame::CodecReport) {
    if !on() || !detail_enabled() {
        return;
    }
    for l in &report.layers {
        layer_detail(client, &l.name, &l.entropy_coder, &l.pred_tag);
    }
}

fn detail_enabled() -> bool {
    static DETAIL: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *DETAIL.get_or_init(|| std::env::var("FEDGEC_JOURNAL_DETAIL").as_deref() == Ok("1"))
}

// ---------------------------------------------------------------------
// round_end serialization + the fold
// ---------------------------------------------------------------------

fn stats_json(s: &RoundStats) -> BTreeMap<String, Json> {
    let mut m = base("round_end");
    put(&mut m, "round", s.round as f64);
    put(&mut m, "mean_loss", s.mean_loss);
    put(&mut m, "payload_bytes", s.payload_bytes as f64);
    put(&mut m, "raw_bytes", s.raw_bytes as f64);
    put_ns(&mut m, "comp_ns", s.comp_time);
    put_ns(&mut m, "decomp_ns", s.decomp_time);
    put_ns(&mut m, "transmit_ns", s.transmit_time);
    put(&mut m, "downlink_bytes", s.downlink_bytes as f64);
    put(&mut m, "downlink_raw_bytes", s.downlink_raw_bytes as f64);
    put_ns(&mut m, "down_transmit_ns", s.down_transmit_time);
    put_ns(&mut m, "down_codec_ns", s.down_codec_time);
    put(&mut m, "full_syncs", s.full_syncs as f64);
    if let Some((loss, acc)) = s.eval {
        put(&mut m, "eval_loss", loss as f64);
        put(&mut m, "eval_acc", acc as f64);
    }
    if let Some(eb) = s.round_eb {
        put(&mut m, "round_eb", eb as f64);
    }
    put(&mut m, "participants", s.participants as f64);
    put(&mut m, "resyncs", s.resyncs as f64);
    put(&mut m, "store_clients", s.store_clients as f64);
    put(&mut m, "store_bytes", s.store_bytes as f64);
    put_ns(&mut m, "server_decode_ns", s.server_decode_time);
    put_ns(&mut m, "agg_ns", s.agg_time);
    put(&mut m, "binsum_layers", s.binsum_layers as f64);
    put(&mut m, "exact_layers", s.exact_layers as f64);
    put(&mut m, "dequant_passes", s.dequant_passes as f64);
    put(&mut m, "dropped", s.dropped as f64);
    put(&mut m, "shards", s.shards as f64);
    put_ns(&mut m, "merge_ns", s.merge_time);
    m
}

fn num(v: &Json, k: &str) -> Result<f64> {
    v.get(k).and_then(Json::as_f64).ok_or_else(|| anyhow::anyhow!("journal: missing key {k:?}"))
}

fn us(v: &Json, k: &str) -> Result<usize> {
    Ok(num(v, k)? as usize)
}

fn dur(v: &Json, k: &str) -> Result<Duration> {
    Ok(Duration::from_nanos(num(v, k)? as u64))
}

/// Parse a `round_end` record back into the exact `RoundStats` it was
/// rendered from (numbers round-trip through [`Json`] losslessly below
/// 2^53). The exhaustive literal means a new `RoundStats` field fails
/// compilation here until the journal schema learns it.
fn stats_from_json(v: &Json) -> Result<RoundStats> {
    let eval = match (v.get("eval_loss"), v.get("eval_acc")) {
        (Some(l), Some(a)) => Some((
            l.as_f64().ok_or_else(|| anyhow::anyhow!("journal: bad eval_loss"))? as f32,
            a.as_f64().ok_or_else(|| anyhow::anyhow!("journal: bad eval_acc"))? as f32,
        )),
        _ => None,
    };
    let round_eb = match v.get("round_eb") {
        Some(e) => {
            Some(e.as_f64().ok_or_else(|| anyhow::anyhow!("journal: bad round_eb"))? as f32)
        }
        None => None,
    };
    Ok(RoundStats {
        round: us(v, "round")? as u32,
        mean_loss: num(v, "mean_loss")?,
        payload_bytes: us(v, "payload_bytes")?,
        raw_bytes: us(v, "raw_bytes")?,
        comp_time: dur(v, "comp_ns")?,
        decomp_time: dur(v, "decomp_ns")?,
        transmit_time: dur(v, "transmit_ns")?,
        downlink_bytes: us(v, "downlink_bytes")?,
        downlink_raw_bytes: us(v, "downlink_raw_bytes")?,
        down_transmit_time: dur(v, "down_transmit_ns")?,
        down_codec_time: dur(v, "down_codec_ns")?,
        full_syncs: us(v, "full_syncs")?,
        eval,
        participants: us(v, "participants")?,
        resyncs: us(v, "resyncs")?,
        store_clients: us(v, "store_clients")?,
        store_bytes: us(v, "store_bytes")?,
        server_decode_time: dur(v, "server_decode_ns")?,
        agg_time: dur(v, "agg_ns")?,
        binsum_layers: us(v, "binsum_layers")?,
        exact_layers: us(v, "exact_layers")?,
        dequant_passes: us(v, "dequant_passes")?,
        dropped: us(v, "dropped")?,
        shards: us(v, "shards")?,
        merge_time: dur(v, "merge_ns")?,
        round_eb,
    })
}

/// One folded round: the totals reconstructed from the event records,
/// plus the runner's own `round_end` record when present.
#[derive(Debug)]
pub struct FoldedRound {
    pub round: u32,
    pub folded: RoundStats,
    pub reported: Option<RoundStats>,
}

/// Reconstruct per-round [`RoundStats`] from a journal's event records
/// (everything except `round_end`, which is kept aside as the runner's
/// self-report for comparison). `client`, `layer`, and `lost` records
/// are detail and do not participate in the fold.
pub fn fold_journal(text: &str) -> Result<Vec<FoldedRound>> {
    struct Fold {
        stats: RoundStats,
        served: usize,
        reported: Option<RoundStats>,
    }
    let mut rounds: Vec<Fold> = Vec::new();
    let mut index: BTreeMap<u32, usize> = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let v = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("journal line {}: {e}", lineno + 1))?;
        let t = v.get("t").and_then(Json::as_str).unwrap_or("").to_string();
        if matches!(t.as_str(), "lost" | "client" | "layer") {
            continue;
        }
        let round = us(&v, "round")? as u32;
        let slot = match index.get(&round) {
            Some(&i) => i,
            None => {
                rounds.push(Fold {
                    stats: RoundStats { round, ..RoundStats::default() },
                    served: 0,
                    reported: None,
                });
                index.insert(round, rounds.len() - 1);
                rounds.len() - 1
            }
        };
        let fold = &mut rounds[slot];
        match t.as_str() {
            "round_begin" => fold.stats.shards = us(&v, "shards")?,
            "shard" => {
                let sh = ShardStats {
                    served: us(&v, "served")?,
                    dropped: us(&v, "dropped")?,
                    resyncs: us(&v, "resyncs")?,
                    payload_bytes: us(&v, "payload_bytes")?,
                    raw_bytes: us(&v, "raw_bytes")?,
                    loss_sum: num(&v, "loss_sum")?,
                    decode_time: dur(&v, "decode_ns")?,
                    agg_time: dur(&v, "agg_ns")?,
                };
                fold.served += sh.served;
                sh.fold_into(&mut fold.stats);
            }
            "edge_drop" => fold.stats.dropped += 1,
            "merge" => fold.stats.merge_time = dur(&v, "merge_ns")?,
            "finish" => {
                fold.stats.agg_time += dur(&v, "finish_ns")?;
                fold.stats.binsum_layers = us(&v, "binsum")?;
                fold.stats.exact_layers = us(&v, "exact")?;
                fold.stats.dequant_passes = us(&v, "dequant")?;
            }
            "store" => {
                fold.stats.store_clients = us(&v, "clients")?;
                fold.stats.store_bytes = us(&v, "bytes")?;
            }
            "downlink" => {
                fold.stats.downlink_bytes += us(&v, "bytes")?;
                fold.stats.downlink_raw_bytes += us(&v, "raw")?;
                fold.stats.full_syncs += us(&v, "full_syncs")?;
                fold.stats.down_codec_time += dur(&v, "codec_ns")?;
                fold.stats.down_transmit_time += dur(&v, "transmit_ns")?;
            }
            "sim" => {
                fold.stats.comp_time += dur(&v, "comp_ns")?;
                fold.stats.transmit_time += dur(&v, "transmit_ns")?;
            }
            "participants" => fold.stats.participants = us(&v, "n")?,
            "eval" => {
                fold.stats.eval = Some((num(&v, "loss")? as f32, num(&v, "acc")? as f32));
            }
            "eb_plan" => {
                fold.stats.round_eb = Some(num(&v, "eb")? as f32);
            }
            "round_end" => fold.reported = Some(stats_from_json(&v)?),
            other => {
                anyhow::bail!("journal line {}: unknown record type {other:?}", lineno + 1)
            }
        }
    }
    Ok(rounds
        .into_iter()
        .map(|mut f| {
            // Same final division the runners perform: the loss sum
            // accumulated in merge order over the round's total served.
            f.stats.mean_loss /= f.served.max(1) as f64;
            FoldedRound { round: f.stats.round, folded: f.stats, reported: f.reported }
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_drops_incoming_on_overflow_and_counts() {
        let mut ring = Ring::new();
        for i in 0..RING_CAP {
            assert!(ring.push(format!("line {i}")));
        }
        assert!(!ring.push("overflow".to_string()));
        assert!(!ring.push("overflow".to_string()));
        assert_eq!(ring.dropped, 2);
        assert_eq!(ring.lines.len(), RING_CAP);
        // The buffered prefix is intact: the newest records were shed.
        let want = format!("line {}", RING_CAP - 1);
        assert_eq!(ring.lines.back(), Some(&want));
    }

    #[test]
    fn round_end_roundtrips_exactly() {
        let stats = RoundStats {
            round: 7,
            mean_loss: 0.123456789012345,
            payload_bytes: 123_456,
            raw_bytes: 2_000_000,
            comp_time: Duration::from_nanos(123_456_789),
            decomp_time: Duration::from_nanos(987_654_321),
            transmit_time: Duration::from_nanos(1),
            downlink_bytes: 77,
            downlink_raw_bytes: 770,
            down_transmit_time: Duration::from_nanos(55),
            down_codec_time: Duration::from_nanos(66),
            full_syncs: 3,
            eval: Some((0.25f32, 0.875f32)),
            participants: 9,
            resyncs: 2,
            store_clients: 4,
            store_bytes: 4096,
            server_decode_time: Duration::from_nanos(424_242),
            agg_time: Duration::from_nanos(313_131),
            binsum_layers: 5,
            exact_layers: 1,
            dequant_passes: 5,
            dropped: 1,
            shards: 4,
            merge_time: Duration::from_nanos(999),
            round_eb: Some(5e-3f32),
        };
        let line = Json::Obj(stats_json(&stats)).to_string();
        let parsed = Json::parse(&line).unwrap();
        let back = stats_from_json(&parsed).unwrap();
        assert_eq!(back, stats);
        // eval / round_eb absence round-trips too.
        let no_eval = RoundStats { eval: None, round_eb: None, ..stats };
        let line = Json::Obj(stats_json(&no_eval)).to_string();
        let back = stats_from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, no_eval);
    }

    #[test]
    fn fold_reconstructs_a_handwritten_round() {
        // Two shards in merge order + downlink + finish + participants:
        // the fold must reproduce the runner's arithmetic.
        let text = r#"
            {"v":1,"t":"round_begin","round":3,"shards":2}
            {"v":1,"t":"downlink","round":3,"bytes":100,"raw":400,"full_syncs":1,"codec_ns":50,"transmit_ns":60}
            {"v":1,"t":"client","round":3,"ev":"served","shard":0,"client":1,"bytes":10,"raw":40,"decode_ns":5,"agg_ns":6,"loss":0.5}
            {"v":1,"t":"shard","round":3,"shard":0,"served":2,"dropped":1,"resyncs":1,"payload_bytes":20,"raw_bytes":80,"loss_sum":1.25,"decode_ns":10,"agg_ns":12}
            {"v":1,"t":"shard","round":3,"shard":1,"served":2,"dropped":0,"resyncs":0,"payload_bytes":22,"raw_bytes":80,"loss_sum":0.75,"decode_ns":11,"agg_ns":13}
            {"v":1,"t":"merge","round":3,"merge_ns":777}
            {"v":1,"t":"store","round":3,"clients":4,"bytes":2048}
            {"v":1,"t":"finish","round":3,"finish_ns":1000,"binsum":2,"exact":1,"dequant":2}
            {"v":1,"t":"participants","round":3,"n":5}
            {"v":1,"t":"eval","round":3,"loss":0.5,"acc":0.75}
            {"v":2,"t":"eb_plan","round":3,"eb":0.01,"layers":0}
            {"v":1,"t":"lost","n":3}
        "#;
        let folded = fold_journal(text).unwrap();
        assert_eq!(folded.len(), 1);
        let s = &folded[0].folded;
        assert_eq!(s.round, 3);
        assert_eq!(s.shards, 2);
        assert_eq!(s.dropped, 1);
        assert_eq!(s.resyncs, 1);
        assert_eq!(s.payload_bytes, 42);
        assert_eq!(s.raw_bytes, 160);
        assert_eq!(s.mean_loss, 2.0 / 4.0);
        assert_eq!(s.decomp_time, Duration::from_nanos(21));
        assert_eq!(s.server_decode_time, Duration::from_nanos(21));
        assert_eq!(s.agg_time, Duration::from_nanos(25 + 1000));
        assert_eq!(s.merge_time, Duration::from_nanos(777));
        assert_eq!(s.downlink_bytes, 100);
        assert_eq!(s.downlink_raw_bytes, 400);
        assert_eq!(s.full_syncs, 1);
        assert_eq!(s.down_codec_time, Duration::from_nanos(50));
        assert_eq!(s.down_transmit_time, Duration::from_nanos(60));
        assert_eq!(s.store_clients, 4);
        assert_eq!(s.store_bytes, 2048);
        assert_eq!((s.binsum_layers, s.exact_layers, s.dequant_passes), (2, 1, 2));
        assert_eq!(s.participants, 5);
        assert_eq!(s.eval, Some((0.5, 0.75)));
        assert_eq!(s.round_eb, Some(0.01f32));
        assert!(folded[0].reported.is_none());
    }

    #[test]
    fn fold_rejects_garbage() {
        assert!(fold_journal("not json").is_err());
        assert!(fold_journal(r#"{"v":1,"t":"mystery","round":0}"#).is_err());
        // Missing keys in a typed record are an error, not a default.
        assert!(fold_journal(r#"{"v":1,"t":"shard","round":0}"#).is_err());
    }
}
