//! Prometheus text-format (0.0.4) rendering of the metric registry and
//! the tiny blocking `GET /metrics` listener behind `--metrics-addr`.

use super::registry::{MetricDef, MetricKind, Unit};
use std::fmt::Write as _;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Render every [`super::REGISTRY`] metric as Prometheus text
/// exposition. Adjacent same-name entries (label variants) share one
/// `# HELP`/`# TYPE` block, as the format requires.
pub fn render() -> String {
    let defs = super::REGISTRY;
    let mut out = String::new();
    let mut i = 0;
    while i < defs.len() {
        let d = &defs[i];
        let kind = match d.kind {
            MetricKind::Counter(_) => "counter",
            MetricKind::Gauge(_) => "gauge",
            MetricKind::Histogram(_) => "histogram",
        };
        let _ = writeln!(out, "# HELP {} {}", d.name, d.help);
        let _ = writeln!(out, "# TYPE {} {}", d.name, kind);
        let mut j = i;
        while j < defs.len() && defs[j].name == d.name {
            render_one(&mut out, &defs[j]);
            j += 1;
        }
        i = j;
    }
    out
}

fn value(unit: Unit, raw: u64) -> String {
    match unit {
        Unit::Plain => format!("{raw}"),
        Unit::NanosToSeconds => format!("{}", raw as f64 / 1e9),
    }
}

fn render_one(out: &mut String, d: &MetricDef) {
    let sel = if d.labels.is_empty() {
        d.name.to_string()
    } else {
        format!("{}{{{}}}", d.name, d.labels)
    };
    match d.kind {
        MetricKind::Counter(c) => {
            let _ = writeln!(out, "{} {}", sel, value(d.unit, c.get()));
        }
        MetricKind::Gauge(g) => {
            let _ = writeln!(out, "{} {}", sel, value(d.unit, g.get()));
        }
        MetricKind::Histogram(h) => {
            let counts = h.bucket_counts();
            let mut cum = 0u64;
            for (bi, b) in h.bounds().iter().enumerate() {
                cum += counts[bi];
                let _ = writeln!(out, "{}_bucket{{le=\"{}\"}} {}", d.name, b, cum);
            }
            cum += counts[h.bounds().len()];
            let _ = writeln!(out, "{}_bucket{{le=\"+Inf\"}} {}", d.name, cum);
            let _ = writeln!(out, "{}_sum {}", d.name, h.sum_seconds());
            let _ = writeln!(out, "{}_count {}", d.name, cum);
        }
    }
}

/// A blocking `/metrics` HTTP listener on a background thread.
/// One request per connection, `Connection: close` — scrape traffic,
/// not a web server. Registered as a telemetry sink while alive.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9184`; port 0 picks a free port)
    /// and start serving `GET /metrics`.
    pub fn bind(addr: &str) -> crate::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                if let Ok(stream) = conn {
                    let _ = handle_conn(stream);
                }
            }
        });
        super::sink_attached();
        Ok(MetricsServer { addr, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0 for tests).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the listener and join its thread. Idempotent; also runs on
    /// drop.
    pub fn shutdown(&mut self) {
        if let Some(handle) = self.handle.take() {
            self.stop.store(true, Ordering::SeqCst);
            // Unblock the accept loop with a throwaway connection.
            let _ = TcpStream::connect(self.addr);
            let _ = handle.join();
            super::sink_detached();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_conn(mut stream: TcpStream) -> std::io::Result<()> {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
    // Read the request head only; a scrape has no body.
    let mut buf = [0u8; 4096];
    let mut n = 0;
    loop {
        if n == buf.len() {
            break;
        }
        let r = stream.read(&mut buf[n..])?;
        if r == 0 {
            break;
        }
        n += r;
        if buf[..n].windows(4).any(|w| w == b"\r\n\r\n") {
            break;
        }
    }
    let head = String::from_utf8_lossy(&buf[..n]);
    let line = head.lines().next().unwrap_or("");
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let is_metrics = path == "/metrics" || path.starts_with("/metrics?");
    let (status, body) = if method == "GET" && is_metrics {
        ("200 OK", render())
    } else {
        ("404 Not Found", "not found\n".to_string())
    };
    let header = format!(
        "HTTP/1.1 {status}\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(header.as_bytes())?;
    stream.write_all(body.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_groups_are_adjacent_and_selectors_unique() {
        let defs = super::super::REGISTRY;
        // (name, labels) pairs are unique.
        let mut seen = std::collections::BTreeSet::new();
        for d in defs {
            let fresh = seen.insert((d.name, d.labels));
            assert!(fresh, "duplicate metric {} {{{}}}", d.name, d.labels);
        }
        // Same-name entries are adjacent (one HELP/TYPE block each).
        let mut names = std::collections::BTreeSet::new();
        let mut i = 0;
        while i < defs.len() {
            let name = defs[i].name;
            assert!(names.insert(name), "metric family {name} split across the registry");
            while i < defs.len() && defs[i].name == name {
                i += 1;
            }
        }
    }

    #[test]
    fn render_is_wellformed_prometheus_text() {
        let text = render();
        for required in [
            "fedgec_rounds_total",
            "fedgec_uplink_bytes_total",
            "fedgec_downlink_bytes_total",
            "fedgec_decode_seconds_total",
            "fedgec_agg_seconds_total",
            "fedgec_merge_seconds_total",
            "fedgec_store_hits_total",
            "fedgec_store_misses_total",
            "fedgec_store_evictions_total",
            "fedgec_resyncs_total",
            "fedgec_clients_dropped_total",
        ] {
            assert!(text.contains(&format!("# TYPE {required} ")), "missing {required}");
        }
        // Every sample line is `name[{labels}] <number>`.
        for line in text.lines() {
            if line.starts_with('#') || line.is_empty() {
                continue;
            }
            let (sel, val) = line.rsplit_once(' ').expect("sample line has a value");
            assert!(sel.starts_with("fedgec_"), "bad selector {sel:?}");
            assert!(val.parse::<f64>().is_ok(), "non-numeric sample {val:?} in {line:?}");
        }
        // The histogram renders cumulative buckets ending at +Inf.
        assert!(text.contains("fedgec_edge_push_seconds_bucket{le=\"+Inf\"}"));
        assert!(text.contains("fedgec_edge_push_seconds_count"));
    }
}
