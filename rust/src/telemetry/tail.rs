//! `fedgec tail <journal.jsonl>`: fold a round journal into the same
//! per-round table a live run prints, for post-hoc (or `--follow`
//! polling) inspection of a traced run.

use super::journal::fold_journal;
use crate::metrics::{fmt_duration, Table};
use crate::util::json::Json;
use crate::Result;

/// Record types this binary's fold understands. Anything else — a newer
/// writer's schema — degrades to a pass-through row instead of failing
/// the whole tail (the fold itself stays strict).
const KNOWN_TYPES: &[&str] = &[
    "round_begin",
    "client",
    "shard",
    "edge_drop",
    "merge",
    "finish",
    "store",
    "downlink",
    "sim",
    "participants",
    "eval",
    "eb_plan",
    "layer",
    "round_end",
    "lost",
];

const N_COLS: usize = 15;

/// Fold `text` (JSONL journal contents) into a per-round table.
/// Prefers each round's own `round_end` record; rounds that never
/// closed (a live tail mid-round) fall back to the folded totals.
/// Records of unknown type render as pass-through rows at the bottom
/// (type + raw line), closed by a `lost`-style count row — a journal
/// from a newer writer stays readable instead of erroring out.
pub fn table_from(text: &str) -> Result<Table> {
    let mut known = String::with_capacity(text.len());
    let mut unknown: Vec<(usize, String, String)> = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let t = Json::parse(line)
            .ok()
            .and_then(|v| v.get("t").and_then(Json::as_str).map(str::to_string));
        match t {
            Some(t) if !KNOWN_TYPES.contains(&t.as_str()) => {
                unknown.push((lineno + 1, t, line.to_string()));
            }
            // Known records — and unparseable lines, which the fold
            // rejects with a line-numbered error — go to the fold.
            _ => {
                known.push_str(line);
                known.push('\n');
            }
        }
    }
    let folded = fold_journal(&known)?;
    let mut t = Table::new(
        "round journal",
        &[
            "round",
            "part",
            "drop",
            "resync",
            "loss",
            "eb",
            "CR",
            "up KB",
            "down KB",
            "full syncs",
            "decode CPU",
            "agg CPU",
            "merge",
            "store KB",
            "eval acc",
        ],
    );
    for fr in &folded {
        let s = fr.reported.as_ref().unwrap_or(&fr.folded);
        t.row(vec![
            s.round.to_string(),
            s.participants.to_string(),
            s.dropped.to_string(),
            s.resyncs.to_string(),
            format!("{:.4}", s.mean_loss),
            s.round_eb.map(|eb| format!("{eb:.1e}")).unwrap_or_else(|| "-".to_string()),
            format!("{:.2}", s.ratio()),
            format!("{:.1}", s.payload_bytes as f64 / 1e3),
            format!("{:.1}", s.downlink_bytes as f64 / 1e3),
            s.full_syncs.to_string(),
            fmt_duration(s.server_decode_time),
            fmt_duration(s.agg_time),
            fmt_duration(s.merge_time),
            format!("{:.1}", s.store_bytes as f64 / 1e3),
            s.eval.map(|(_, acc)| format!("{acc:.3}")).unwrap_or_else(|| "-".to_string()),
        ]);
    }
    for (lineno, ty, raw) in &unknown {
        t.row(passthrough_row(&format!("?{lineno}"), &format!("t:{ty}"), raw));
    }
    if !unknown.is_empty() {
        // Mirrors the writer's own `lost` record: records present but
        // not understood, counted rather than silently skipped.
        t.row(passthrough_row("lost", &unknown.len().to_string(), "unknown record types"));
    }
    Ok(t)
}

/// A table row carrying a non-round record: first cell, second cell,
/// dashes, and the raw text (truncated) in the last cell.
fn passthrough_row(first: &str, second: &str, raw: &str) -> Vec<String> {
    let mut row = vec!["-".to_string(); N_COLS];
    row[0] = first.to_string();
    row[1] = second.to_string();
    let mut text = raw.to_string();
    if text.len() > 48 {
        let mut cut = 48;
        while !text.is_char_boundary(cut) {
            cut -= 1;
        }
        text.truncate(cut);
        text.push('…');
    }
    row[N_COLS - 1] = text;
    row
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_one_row_per_round() {
        let text = concat!(
            r#"{"v":1,"t":"round_begin","round":0,"shards":1}"#,
            "\n",
            r#"{"v":1,"t":"shard","round":0,"shard":0,"served":2,"dropped":0,"resyncs":1,"#,
            r#""payload_bytes":2000,"raw_bytes":8000,"loss_sum":1.0,"decode_ns":5000,"agg_ns":700}"#,
            "\n",
            r#"{"v":2,"t":"eb_plan","round":0,"eb":0.01,"layers":0}"#,
            "\n",
            r#"{"v":1,"t":"participants","round":0,"n":2}"#,
            "\n",
            r#"{"v":1,"t":"round_begin","round":1,"shards":1}"#,
            "\n",
        );
        let t = table_from(text).unwrap();
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "0");
        assert_eq!(t.rows[0][1], "2");
        assert_eq!(t.rows[0][4], "0.5000"); // loss_sum / served
        assert_eq!(t.rows[0][5], "1.0e-2"); // eb_plan record
        assert_eq!(t.rows[0][6], "4.00"); // 8000 / 2000
        assert_eq!(t.rows[1][5], "-"); // no plan that round
        let md = t.markdown();
        assert!(md.contains("round journal"));
    }

    #[test]
    fn unknown_record_types_pass_through_with_a_count() {
        let text = concat!(
            r#"{"v":1,"t":"round_begin","round":0,"shards":1}"#,
            "\n",
            r#"{"v":3,"t":"mystery","round":0,"x":1}"#,
            "\n",
            r#"{"v":1,"t":"participants","round":0,"n":2}"#,
            "\n",
            r#"{"v":9,"t":"from_the_future","payload":"whatever"}"#,
            "\n",
        );
        let t = table_from(text).unwrap();
        // 1 round row + 2 pass-through rows + 1 count row.
        assert_eq!(t.rows.len(), 4);
        assert_eq!(t.rows[1][0], "?2");
        assert_eq!(t.rows[1][1], "t:mystery");
        assert!(t.rows[1].last().unwrap().contains("mystery"));
        assert_eq!(t.rows[2][1], "t:from_the_future");
        let count = t.rows.last().unwrap();
        assert_eq!(count[0], "lost");
        assert_eq!(count[1], "2");
        // A journal with only known records emits no lost row.
        let clean = r#"{"v":1,"t":"round_begin","round":0,"shards":1}"#;
        assert_eq!(table_from(clean).unwrap().rows.len(), 1);
        // Invalid JSON still fails loudly — tolerance covers unknown
        // types, not corrupt files.
        assert!(table_from("not json").is_err());
    }
}
