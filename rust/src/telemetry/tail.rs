//! `fedgec tail <journal.jsonl>`: fold a round journal into the same
//! per-round table a live run prints, for post-hoc (or `--follow`
//! polling) inspection of a traced run.

use super::journal::fold_journal;
use crate::metrics::{fmt_duration, Table};
use crate::Result;

/// Fold `text` (JSONL journal contents) into a per-round table.
/// Prefers each round's own `round_end` record; rounds that never
/// closed (a live tail mid-round) fall back to the folded totals.
pub fn table_from(text: &str) -> Result<Table> {
    let folded = fold_journal(text)?;
    let mut t = Table::new(
        "round journal",
        &[
            "round",
            "part",
            "drop",
            "resync",
            "loss",
            "CR",
            "up KB",
            "down KB",
            "full syncs",
            "decode CPU",
            "agg CPU",
            "merge",
            "store KB",
            "eval acc",
        ],
    );
    for fr in &folded {
        let s = fr.reported.as_ref().unwrap_or(&fr.folded);
        t.row(vec![
            s.round.to_string(),
            s.participants.to_string(),
            s.dropped.to_string(),
            s.resyncs.to_string(),
            format!("{:.4}", s.mean_loss),
            format!("{:.2}", s.ratio()),
            format!("{:.1}", s.payload_bytes as f64 / 1e3),
            format!("{:.1}", s.downlink_bytes as f64 / 1e3),
            s.full_syncs.to_string(),
            fmt_duration(s.server_decode_time),
            fmt_duration(s.agg_time),
            fmt_duration(s.merge_time),
            format!("{:.1}", s.store_bytes as f64 / 1e3),
            s.eval.map(|(_, acc)| format!("{acc:.3}")).unwrap_or_else(|| "-".to_string()),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_one_row_per_round() {
        let text = concat!(
            r#"{"v":1,"t":"round_begin","round":0,"shards":1}"#,
            "\n",
            r#"{"v":1,"t":"shard","round":0,"shard":0,"served":2,"dropped":0,"resyncs":1,"#,
            r#""payload_bytes":2000,"raw_bytes":8000,"loss_sum":1.0,"decode_ns":5000,"agg_ns":700}"#,
            "\n",
            r#"{"v":1,"t":"participants","round":0,"n":2}"#,
            "\n",
            r#"{"v":1,"t":"round_begin","round":1,"shards":1}"#,
            "\n",
        );
        let t = table_from(text).unwrap();
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "0");
        assert_eq!(t.rows[0][1], "2");
        assert_eq!(t.rows[0][4], "0.5000"); // loss_sum / served
        assert_eq!(t.rows[0][5], "4.00"); // 8000 / 2000
        let md = t.markdown();
        assert!(md.contains("round journal"));
    }
}
