//! Telemetry: low-overhead metrics registry, round/span tracing
//! journal, and the Prometheus exposition endpoint (DESIGN.md §14).
//!
//! Three layers, strictly ordered by cost:
//!
//! 1. **Counters** ([`registry`]) — always-on relaxed atomics at
//!    frame/layer/round granularity (never per element). The
//!    `telemetry-off` cargo feature swaps in zero-sized no-op twins.
//! 2. **Journal** ([`journal`]) — per-round JSONL records pushed into a
//!    bounded ring and flushed by a background writer; callers format
//!    nothing unless a journal file is attached.
//! 3. **Exposition** ([`expose`]) — `GET /metrics` on a tiny blocking
//!    HTTP listener reads the counters on demand.
//!
//! Overhead policy: byte/count tallies are unconditional (their cost is
//! one relaxed `fetch_add` per layer or frame); any *new* `Instant`
//! timing introduced for telemetry is gated on [`active`], which is
//! true only while a sink (journal or metrics listener) is attached or
//! `FEDGEC_TELEMETRY=1` forces it.

pub mod expose;
pub mod journal;
pub mod registry;
pub mod tail;

pub use expose::MetricsServer;
pub use registry::{Counter, Gauge, Histogram, MetricDef, MetricKind, Unit};

use crate::fl::round::ShardStats;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Attached-sink count: the journal writer and each metrics listener
/// register here so [`active`] can gate optional instrumentation.
static ACTIVE_SINKS: AtomicUsize = AtomicUsize::new(0);

#[cfg(not(feature = "telemetry-off"))]
static ENV_FORCE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();

pub(crate) fn sink_attached() {
    ACTIVE_SINKS.fetch_add(1, Ordering::SeqCst);
}

pub(crate) fn sink_detached() {
    ACTIVE_SINKS.fetch_sub(1, Ordering::SeqCst);
}

/// True while any telemetry sink is attached (or `FEDGEC_TELEMETRY=1`).
/// Gates instrumentation whose *measurement* has a cost — extra
/// `Instant::now` pairs — as opposed to the always-on counters.
#[cfg(not(feature = "telemetry-off"))]
#[inline]
pub fn active() -> bool {
    ACTIVE_SINKS.load(Ordering::Relaxed) > 0
        || *ENV_FORCE.get_or_init(|| std::env::var("FEDGEC_TELEMETRY").as_deref() == Ok("1"))
}

/// Compiled out: never active under `telemetry-off`.
#[cfg(feature = "telemetry-off")]
#[inline]
pub fn active() -> bool {
    false
}

// ---------------------------------------------------------------------
// Metric statics. Grouped by subsystem; all are in REGISTRY below.
// ---------------------------------------------------------------------

pub static ROUNDS: Counter = Counter::new();
pub static CLIENTS_SERVED: Counter = Counter::new();
pub static CLIENTS_DROPPED: Counter = Counter::new();
pub static RESYNCS: Counter = Counter::new();
pub static UPLINK_BYTES: Counter = Counter::new();
pub static UPLINK_RAW_BYTES: Counter = Counter::new();
pub static DOWNLINK_BYTES: Counter = Counter::new();
pub static DOWNLINK_RAW_BYTES: Counter = Counter::new();

pub static DECODE_NS: Counter = Counter::new();
pub static AGG_NS: Counter = Counter::new();
pub static MERGE_NS: Counter = Counter::new();
pub static FINISH_NS: Counter = Counter::new();
pub static ENCODE_NS: Counter = Counter::new();

pub static STORE_HITS: Counter = Counter::new();
pub static STORE_MISSES: Counter = Counter::new();
pub static STORE_EVICTIONS: Counter = Counter::new();
pub static STORE_SPILL_LOADS: Counter = Counter::new();
pub static STORE_SPILL_BYTES: Counter = Counter::new();
pub static STORE_RESIDENT_BYTES: Gauge = Gauge::new();
pub static STORE_RESIDENT_CLIENTS: Gauge = Gauge::new();

/// The error-bound controller's current round bound, scaled by 1e9
/// (gauges are integer-valued; eb ~1e-3 would truncate to zero).
pub static ROUND_EB: Gauge = Gauge::new();

pub static DOWNLINK_FULL_SYNCS: Counter = Counter::new();
pub static DOWNLINK_RESETS: Counter = Counter::new();
pub static DOWNLINK_CODEC_NS: Counter = Counter::new();

pub static ENTROPY_RAW_BYTES: Counter = Counter::new();
pub static ENTROPY_HUFF_BYTES: Counter = Counter::new();
pub static ENTROPY_RANS_BYTES: Counter = Counter::new();
pub static ENTROPY_RANS4_BYTES: Counter = Counter::new();
pub static ENTROPY_RANS8_BYTES: Counter = Counter::new();

pub static TX_BYTES_INPROC: Counter = Counter::new();
pub static RX_BYTES_INPROC: Counter = Counter::new();
pub static TX_BYTES_TCP: Counter = Counter::new();
pub static RX_BYTES_TCP: Counter = Counter::new();
pub static THROTTLE_WAIT_NS: Counter = Counter::new();

static EDGE_PUSH_BOUNDS: [f64; 8] = [0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0];
pub static EDGE_PUSH_LATENCY: Histogram = Histogram::new(&EDGE_PUSH_BOUNDS);
pub static EDGE_SUBTREE_DROPS: Counter = Counter::new();

pub static JOURNAL_DROPPED: Counter = Counter::new();

/// Exposition registry: every metric the `/metrics` endpoint renders.
/// Same-name entries (label variants) are adjacent — the renderer
/// relies on it; `tests/telemetry.rs` enforces it.
pub static REGISTRY: &[MetricDef] = &[
    MetricDef {
        name: "fedgec_rounds_total",
        labels: "",
        help: "Aggregation rounds finished",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&ROUNDS),
    },
    MetricDef {
        name: "fedgec_clients_served_total",
        labels: "",
        help: "Client updates absorbed into an aggregate",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&CLIENTS_SERVED),
    },
    MetricDef {
        name: "fedgec_clients_dropped_total",
        labels: "",
        help: "Client contributions dropped whole",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&CLIENTS_DROPPED),
    },
    MetricDef {
        name: "fedgec_resyncs_total",
        labels: "",
        help: "State resets ordered by the epoch handshake",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&RESYNCS),
    },
    MetricDef {
        name: "fedgec_uplink_bytes_total",
        labels: "",
        help: "Compressed client payload bytes received",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&UPLINK_BYTES),
    },
    MetricDef {
        name: "fedgec_uplink_raw_bytes_total",
        labels: "",
        help: "Uncompressed gradient bytes the payloads stand for",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&UPLINK_RAW_BYTES),
    },
    MetricDef {
        name: "fedgec_downlink_bytes_total",
        labels: "",
        help: "Broadcast bytes sent, summed over recipients",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&DOWNLINK_BYTES),
    },
    MetricDef {
        name: "fedgec_downlink_raw_bytes_total",
        labels: "",
        help: "Raw f32 broadcast equivalent, summed over recipients",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&DOWNLINK_RAW_BYTES),
    },
    MetricDef {
        name: "fedgec_decode_seconds_total",
        labels: "",
        help: "Server payload decode CPU",
        unit: Unit::NanosToSeconds,
        kind: MetricKind::Counter(&DECODE_NS),
    },
    MetricDef {
        name: "fedgec_agg_seconds_total",
        labels: "",
        help: "Aggregator accumulate CPU",
        unit: Unit::NanosToSeconds,
        kind: MetricKind::Counter(&AGG_NS),
    },
    MetricDef {
        name: "fedgec_merge_seconds_total",
        labels: "",
        help: "Partial-aggregate tree-merge wall clock",
        unit: Unit::NanosToSeconds,
        kind: MetricKind::Counter(&MERGE_NS),
    },
    MetricDef {
        name: "fedgec_finish_seconds_total",
        labels: "",
        help: "finish_round dequantize-and-divide plus model apply",
        unit: Unit::NanosToSeconds,
        kind: MetricKind::Counter(&FINISH_NS),
    },
    MetricDef {
        name: "fedgec_encode_seconds_total",
        labels: "",
        help: "Uplink layer-encode CPU (gated: counted while a sink is attached)",
        unit: Unit::NanosToSeconds,
        kind: MetricKind::Counter(&ENCODE_NS),
    },
    MetricDef {
        name: "fedgec_store_hits_total",
        labels: "",
        help: "Hot-tier state-store checkouts that found the client",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&STORE_HITS),
    },
    MetricDef {
        name: "fedgec_store_misses_total",
        labels: "",
        help: "Hot-tier state-store checkouts that missed",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&STORE_MISSES),
    },
    MetricDef {
        name: "fedgec_store_evictions_total",
        labels: "",
        help: "States evicted from the hot tier by the budget",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&STORE_EVICTIONS),
    },
    MetricDef {
        name: "fedgec_store_spill_loads_total",
        labels: "",
        help: "States reloaded from the disk-spill tier",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&STORE_SPILL_LOADS),
    },
    MetricDef {
        name: "fedgec_store_spill_bytes_total",
        labels: "",
        help: "Bytes written to the disk-spill tier",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&STORE_SPILL_BYTES),
    },
    MetricDef {
        name: "fedgec_store_resident_bytes",
        labels: "",
        help: "State bytes held across both store tiers after the last round",
        unit: Unit::Plain,
        kind: MetricKind::Gauge(&STORE_RESIDENT_BYTES),
    },
    MetricDef {
        name: "fedgec_store_resident_clients",
        labels: "",
        help: "Client states held across both store tiers after the last round",
        unit: Unit::Plain,
        kind: MetricKind::Gauge(&STORE_RESIDENT_CLIENTS),
    },
    MetricDef {
        name: "fedgec_round_eb_nanos",
        labels: "",
        help: "Error-bound controller's current round bound, scaled by 1e9",
        unit: Unit::Plain,
        kind: MetricKind::Gauge(&ROUND_EB),
    },
    MetricDef {
        name: "fedgec_downlink_full_syncs_total",
        labels: "",
        help: "Cold clients bootstrapped via FullSync",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&DOWNLINK_FULL_SYNCS),
    },
    MetricDef {
        name: "fedgec_downlink_resets_total",
        labels: "",
        help: "Downlink delta-stream resets forced by cold joins",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&DOWNLINK_RESETS),
    },
    MetricDef {
        name: "fedgec_downlink_codec_seconds_total",
        labels: "",
        help: "Downlink encode-once plus mirror-decode CPU",
        unit: Unit::NanosToSeconds,
        kind: MetricKind::Counter(&DOWNLINK_CODEC_NS),
    },
    MetricDef {
        name: "fedgec_entropy_encoded_bytes_total",
        labels: "coder=\"raw\"",
        help: "Entropy-stage output bytes by winning coder",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&ENTROPY_RAW_BYTES),
    },
    MetricDef {
        name: "fedgec_entropy_encoded_bytes_total",
        labels: "coder=\"huff\"",
        help: "Entropy-stage output bytes by winning coder",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&ENTROPY_HUFF_BYTES),
    },
    MetricDef {
        name: "fedgec_entropy_encoded_bytes_total",
        labels: "coder=\"rans\"",
        help: "Entropy-stage output bytes by winning coder",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&ENTROPY_RANS_BYTES),
    },
    MetricDef {
        name: "fedgec_entropy_encoded_bytes_total",
        labels: "coder=\"rans4\"",
        help: "Entropy-stage output bytes by winning coder",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&ENTROPY_RANS4_BYTES),
    },
    MetricDef {
        name: "fedgec_entropy_encoded_bytes_total",
        labels: "coder=\"rans8\"",
        help: "Entropy-stage output bytes by winning coder",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&ENTROPY_RANS8_BYTES),
    },
    MetricDef {
        name: "fedgec_transport_tx_bytes_total",
        labels: "transport=\"inproc\"",
        help: "Frame bytes pushed into a channel",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&TX_BYTES_INPROC),
    },
    MetricDef {
        name: "fedgec_transport_tx_bytes_total",
        labels: "transport=\"tcp\"",
        help: "Frame bytes pushed into a channel",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&TX_BYTES_TCP),
    },
    MetricDef {
        name: "fedgec_transport_rx_bytes_total",
        labels: "transport=\"inproc\"",
        help: "Frame bytes received from a channel",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&RX_BYTES_INPROC),
    },
    MetricDef {
        name: "fedgec_transport_rx_bytes_total",
        labels: "transport=\"tcp\"",
        help: "Frame bytes received from a channel",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&RX_BYTES_TCP),
    },
    MetricDef {
        name: "fedgec_throttle_wait_seconds_total",
        labels: "",
        help: "Time senders slept in the bandwidth throttler",
        unit: Unit::NanosToSeconds,
        kind: MetricKind::Counter(&THROTTLE_WAIT_NS),
    },
    MetricDef {
        name: "fedgec_edge_push_seconds",
        labels: "",
        help: "Root-side wait for one edge AggPush",
        unit: Unit::Plain,
        kind: MetricKind::Histogram(&EDGE_PUSH_LATENCY),
    },
    MetricDef {
        name: "fedgec_edge_subtree_drops_total",
        labels: "",
        help: "Edge aggregators whose whole subtree dropped for a round",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&EDGE_SUBTREE_DROPS),
    },
    MetricDef {
        name: "fedgec_journal_dropped_total",
        labels: "",
        help: "Journal records lost to ring-buffer overflow",
        unit: Unit::Plain,
        kind: MetricKind::Counter(&JOURNAL_DROPPED),
    },
];

/// Mirror one shard's round tallies into the global counters — called
/// wherever client updates are actually served (`DecodeCore::
/// serve_round`, the direct-ingest sharded path, the local simulation
/// loop), never where already-counted tallies are merged again.
pub fn record_shard(st: &ShardStats) {
    CLIENTS_SERVED.add(st.served as u64);
    CLIENTS_DROPPED.add(st.dropped as u64);
    RESYNCS.add(st.resyncs as u64);
    UPLINK_BYTES.add(st.payload_bytes as u64);
    UPLINK_RAW_BYTES.add(st.raw_bytes as u64);
    DECODE_NS.add_duration(st.decode_time);
    AGG_NS.add_duration(st.agg_time);
}
