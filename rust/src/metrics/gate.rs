//! Perf-regression gate: compares a fresh `BENCH_*.json` artifact (the
//! all-strings table emitted by [`super::Table::save_json`]) against a
//! committed baseline of **floors** (hard minima, e.g. a GB/s or speedup
//! threshold) and **pins** (values that must stay within a relative
//! tolerance, e.g. compression ratios within 1%).
//!
//! Baselines live in `rust/results/baselines/<bench>.json`:
//!
//! ```json
//! {
//!   "bench": "perf_throughput",
//!   "floors": [{"row": "fused encode", "col": "speedup", "min": 1.2}],
//!   "pins":   [{"row": "TOTAL", "col": "CR", "value": 12.3, "rel_tol": 0.01}]
//! }
//! ```
//!
//! A pin with `"value": null` is *record-only*: the gate reports the
//! current value without judging it — the seeding state before the first
//! `bench_check --update` run on the reference machine. Floors are
//! deliberately conservative (well under the speedups a quiet machine
//! shows) so shared-runner noise does not flake the gate, while a real
//! regression — a fast kernel silently falling back to scalar — still
//! trips it.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;

/// Hard minimum on one table cell: `cell(row, col) >= min` or the gate fails.
#[derive(Debug, Clone, PartialEq)]
pub struct Floor {
    pub row: String,
    pub col: String,
    pub min: f64,
}

/// Tolerance band on one table cell: `|cell - value| / |value| <= rel_tol`.
/// `value: None` records the current cell without judging it.
#[derive(Debug, Clone, PartialEq)]
pub struct Pin {
    pub row: String,
    pub col: String,
    pub value: Option<f64>,
    pub rel_tol: f64,
}

/// One committed baseline file: the bench it gates plus its constraints.
#[derive(Debug, Clone, PartialEq)]
pub struct Baseline {
    pub bench: String,
    pub floors: Vec<Floor>,
    pub pins: Vec<Pin>,
}

impl Baseline {
    /// Parse a `results/baselines/*.json` document.
    pub fn parse(src: &str) -> Result<Baseline> {
        let v = Json::parse(src).map_err(|e| anyhow!("baseline: {e}"))?;
        let bench = v
            .get("bench")
            .and_then(Json::as_str)
            .context("baseline: missing \"bench\"")?
            .to_string();
        let mut floors = Vec::new();
        for f in v.get("floors").and_then(Json::as_arr).unwrap_or(&[]) {
            floors.push(Floor {
                row: f.get("row").and_then(Json::as_str).context("floor: missing row")?.into(),
                col: f.get("col").and_then(Json::as_str).context("floor: missing col")?.into(),
                min: f.get("min").and_then(Json::as_f64).context("floor: missing min")?,
            });
        }
        let mut pins = Vec::new();
        for p in v.get("pins").and_then(Json::as_arr).unwrap_or(&[]) {
            pins.push(Pin {
                row: p.get("row").and_then(Json::as_str).context("pin: missing row")?.into(),
                col: p.get("col").and_then(Json::as_str).context("pin: missing col")?.into(),
                value: match p.get("value") {
                    None | Some(Json::Null) => None,
                    Some(j) => Some(j.as_f64().context("pin: non-numeric value")?),
                },
                rel_tol: p.f64_or("rel_tol", 0.01),
            });
        }
        Ok(Baseline { bench, floors, pins })
    }

    /// Serialize back to the committed-file format.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("bench".to_string(), Json::Str(self.bench.clone()));
        obj.insert(
            "floors".to_string(),
            Json::Arr(
                self.floors
                    .iter()
                    .map(|f| {
                        let mut o = BTreeMap::new();
                        o.insert("row".to_string(), Json::Str(f.row.clone()));
                        o.insert("col".to_string(), Json::Str(f.col.clone()));
                        o.insert("min".to_string(), Json::Num(f.min));
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        obj.insert(
            "pins".to_string(),
            Json::Arr(
                self.pins
                    .iter()
                    .map(|p| {
                        let mut o = BTreeMap::new();
                        o.insert("row".to_string(), Json::Str(p.row.clone()));
                        o.insert("col".to_string(), Json::Str(p.col.clone()));
                        o.insert(
                            "value".to_string(),
                            p.value.map(Json::Num).unwrap_or(Json::Null),
                        );
                        o.insert("rel_tol".to_string(), Json::Num(p.rel_tol));
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        Json::Obj(obj)
    }

    /// A copy of this baseline with every pin re-recorded from `doc` —
    /// the `bench_check --update` path. Floors are never auto-updated:
    /// raising or lowering a floor is a reviewed decision.
    pub fn updated_from(&self, doc: &BenchDoc) -> Result<Baseline> {
        let mut out = self.clone();
        for p in &mut out.pins {
            p.value = Some(doc.cell(&p.row, &p.col)?);
        }
        Ok(out)
    }
}

/// A parsed `BENCH_*.json` table (title/headers/rows, all strings).
#[derive(Debug, Clone)]
pub struct BenchDoc {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl BenchDoc {
    pub fn parse(src: &str) -> Result<BenchDoc> {
        let v = Json::parse(src).map_err(|e| anyhow!("bench json: {e}"))?;
        let headers = v
            .get("headers")
            .and_then(Json::as_arr)
            .context("bench json: missing headers")?
            .iter()
            .map(|h| h.as_str().unwrap_or_default().to_string())
            .collect();
        let rows = v
            .get("rows")
            .and_then(Json::as_arr)
            .context("bench json: missing rows")?
            .iter()
            .map(|r| {
                r.as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|c| c.as_str().unwrap_or_default().to_string())
                    .collect()
            })
            .collect();
        Ok(BenchDoc { headers, rows })
    }

    /// Numeric cell lookup: the row whose *first* column equals `row`,
    /// in the column named `col`. A missing row/col or a non-numeric
    /// cell is an error — a gated metric that vanished is a regression,
    /// not a skip.
    pub fn cell(&self, row: &str, col: &str) -> Result<f64> {
        let ci = self
            .headers
            .iter()
            .position(|h| h == col)
            .with_context(|| format!("column {col:?} not in {:?}", self.headers))?;
        let r = self
            .rows
            .iter()
            .find(|r| r.first().map(String::as_str) == Some(row))
            .with_context(|| format!("row {row:?} not found"))?;
        let cell = r.get(ci).with_context(|| format!("row {row:?} has no column {ci}"))?;
        parse_metric(cell).with_context(|| format!("cell [{row:?}][{col:?}] = {cell:?}"))
    }
}

/// Parse a table cell as a number. Bench tables print human-readable
/// cells, so a trailing unit suffix (`x`, `%`) is tolerated; anything
/// else is a hard error.
pub fn parse_metric(cell: &str) -> Result<f64> {
    let t = cell.trim().trim_end_matches(['x', '%']);
    t.parse::<f64>().map_err(|_| anyhow!("not a numeric metric"))
}

/// The gate verdict for one baseline: every violated constraint, plus
/// informational notes (record-only pins).
#[derive(Debug, Default)]
pub struct GateOutcome {
    pub checked: usize,
    pub violations: Vec<String>,
    pub notes: Vec<String>,
}

impl GateOutcome {
    pub fn pass(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Evaluate one baseline against a fresh bench table.
pub fn check(b: &Baseline, doc: &BenchDoc) -> GateOutcome {
    let mut out = GateOutcome::default();
    for f in &b.floors {
        out.checked += 1;
        match doc.cell(&f.row, &f.col) {
            Ok(v) if v >= f.min => {}
            Ok(v) => out.violations.push(format!(
                "{}: floor [{}][{}] = {v} < min {}",
                b.bench, f.row, f.col, f.min
            )),
            Err(e) => {
                out.violations.push(format!("{}: floor [{}][{}]: {e}", b.bench, f.row, f.col))
            }
        }
    }
    for p in &b.pins {
        out.checked += 1;
        let v = match doc.cell(&p.row, &p.col) {
            Ok(v) => v,
            Err(e) => {
                out.violations.push(format!("{}: pin [{}][{}]: {e}", b.bench, p.row, p.col));
                continue;
            }
        };
        match p.value {
            None => out.notes.push(format!(
                "{}: pin [{}][{}] unpinned, current value {v} (run bench_check --update)",
                b.bench, p.row, p.col
            )),
            Some(want) => {
                let dev = (v - want).abs() / want.abs().max(1e-12);
                if dev > p.rel_tol {
                    out.violations.push(format!(
                        "{}: pin [{}][{}] = {v} deviates {:.2}% from {want} (tol {:.2}%)",
                        b.bench,
                        p.row,
                        p.col,
                        dev * 100.0,
                        p.rel_tol * 100.0
                    ));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc() -> BenchDoc {
        BenchDoc {
            headers: vec!["stage".into(), "GB/s".into(), "speedup".into(), "CR".into()],
            rows: vec![
                vec!["quantize enc".into(), "2.50".into(), "3.1x".into(), "-".into()],
                vec!["TOTAL".into(), "-".into(), "-".into(), "12.30".into()],
            ],
        }
    }

    #[test]
    fn floor_passes_and_fails() {
        let b = Baseline {
            bench: "t".into(),
            floors: vec![Floor { row: "quantize enc".into(), col: "speedup".into(), min: 1.2 }],
            pins: vec![],
        };
        assert!(check(&b, &doc()).pass());
        let b2 = Baseline {
            floors: vec![Floor { row: "quantize enc".into(), col: "speedup".into(), min: 5.0 }],
            ..b
        };
        let out = check(&b2, &doc());
        assert!(!out.pass());
        assert!(out.violations[0].contains("floor"), "{:?}", out.violations);
    }

    #[test]
    fn pin_tolerance_band() {
        let mk = |value, rel_tol| Baseline {
            bench: "t".into(),
            floors: vec![],
            pins: vec![Pin { row: "TOTAL".into(), col: "CR".into(), value, rel_tol }],
        };
        assert!(check(&mk(Some(12.25), 0.01), &doc()).pass()); // within 1%
        assert!(!check(&mk(Some(11.0), 0.01), &doc()).pass()); // ~12% off
        // Record-only pin: never a violation, always a note.
        let out = check(&mk(None, 0.01), &doc());
        assert!(out.pass());
        assert_eq!(out.notes.len(), 1);
        assert!(out.notes[0].contains("12.3"), "{:?}", out.notes);
    }

    #[test]
    fn missing_metric_is_a_violation_not_a_skip() {
        let b = Baseline {
            bench: "t".into(),
            floors: vec![Floor { row: "gone".into(), col: "GB/s".into(), min: 0.0 }],
            pins: vec![Pin {
                row: "TOTAL".into(),
                col: "nope".into(),
                value: Some(1.0),
                rel_tol: 0.1,
            }],
        };
        let out = check(&b, &doc());
        assert_eq!(out.violations.len(), 2);
    }

    #[test]
    fn baseline_json_roundtrip_and_update() {
        let src = r#"{"bench":"t","floors":[{"row":"quantize enc","col":"speedup","min":1.2}],
            "pins":[{"row":"TOTAL","col":"CR","value":null,"rel_tol":0.01}]}"#;
        let b = Baseline::parse(src).unwrap();
        assert_eq!(b.pins[0].value, None);
        let re = Baseline::parse(&b.to_json().to_string()).unwrap();
        assert_eq!(b, re);
        // --update records the fresh cell into the null pin; floors stay.
        let up = b.updated_from(&doc()).unwrap();
        assert_eq!(up.pins[0].value, Some(12.3));
        assert_eq!(up.floors, b.floors);
        let out = check(&up, &doc());
        assert!(out.pass() && out.notes.is_empty());
    }

    #[test]
    fn metric_parsing_tolerates_unit_suffixes_only() {
        assert_eq!(parse_metric(" 3.1x ").unwrap(), 3.1);
        assert_eq!(parse_metric("85%").unwrap(), 85.0);
        assert!(parse_metric("-").is_err());
        assert!(parse_metric("fast").is_err());
    }
}
