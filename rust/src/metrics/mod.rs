//! Experiment output: markdown tables on stdout + CSV files under
//! `results/` (the bench harness substrate standing in for criterion's
//! reports).

pub mod gate;

use std::fmt::Write as _;
use std::path::PathBuf;

/// A simple column-aligned table that prints as markdown and saves as CSV.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    /// Render as a markdown table.
    pub fn markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            widths[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "### {}", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(line, " {c:<w$} |");
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{:-<width$}|", "", width = w + 2);
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        println!("{}", self.markdown());
    }

    /// Save as `results/<name>.csv`.
    pub fn save_csv(&self, name: &str) -> crate::Result<PathBuf> {
        let dir = results_dir();
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut out = String::new();
        // RFC 4180 quoting: commas, quotes, AND newlines force a quoted
        // field (a bare newline in a cell would otherwise split the row).
        let esc = |s: &str| {
            if s.contains([',', '"', '\n', '\r']) {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        std::fs::write(&path, out)?;
        Ok(path)
    }

    /// Save as `results/BENCH_<name>.json` — the machine-readable twin of
    /// the CSV that CI's bench-smoke job uploads as a workflow artifact,
    /// so the perf/ratio trajectory is tracked per-PR.
    pub fn save_json(&self, name: &str) -> crate::Result<PathBuf> {
        use crate::util::json::Json;
        use std::collections::BTreeMap;
        let dir = results_dir();
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("BENCH_{name}.json"));
        let mut obj = BTreeMap::new();
        obj.insert("title".to_string(), Json::Str(self.title.clone()));
        obj.insert(
            "headers".to_string(),
            Json::Arr(self.headers.iter().map(|h| Json::Str(h.clone())).collect()),
        );
        obj.insert(
            "rows".to_string(),
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                    .collect(),
            ),
        );
        std::fs::write(&path, Json::Obj(obj).to_string())?;
        Ok(path)
    }
}

/// Render a unified per-layer [`crate::compress::CodecReport`] as a
/// table — the one report format every codec and bench shares.
pub fn report_table(report: &crate::compress::CodecReport) -> Table {
    let mut t = Table::new(
        &format!("per-layer compression ({})", report.codec),
        &["layer", "raw KB", "wire KB", "CR", "side-info B", "entropy B", "escapes", "mode"],
    );
    for l in &report.layers {
        t.row(vec![
            l.name.clone(),
            format!("{:.1}", l.raw_bytes as f64 / 1e3),
            format!("{:.1}", l.compressed_bytes as f64 / 1e3),
            format!("{:.2}", l.ratio()),
            l.side_info_bytes.to_string(),
            l.entropy_bytes.to_string(),
            l.escape_count.to_string(),
            if l.lossy { "lossy".into() } else { "lossless".into() },
        ]);
    }
    let totals = report.totals();
    t.row(vec![
        "TOTAL".into(),
        format!("{:.1}", totals.raw_bytes as f64 / 1e3),
        format!("{:.1}", totals.compressed_bytes as f64 / 1e3),
        format!("{:.2}", totals.ratio()),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    t
}

/// Results directory: `$FEDGEC_RESULTS` or `./results`.
pub fn results_dir() -> PathBuf {
    std::env::var("FEDGEC_RESULTS").map(PathBuf::from).unwrap_or_else(|_| "results".into())
}

/// Format a Duration in human units.
pub fn fmt_duration(d: std::time::Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 100.0 {
        format!("{s:.0}s")
    } else if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes the tests that mutate the process-global
    /// `FEDGEC_RESULTS` env var (test threads run concurrently).
    static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.markdown();
        assert!(md.contains("### demo"));
        assert!(md.contains("| a"));
        assert!(md.lines().count() >= 4);
    }

    #[test]
    #[should_panic]
    fn wrong_arity_panics() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_escaping() {
        let _guard = ENV_LOCK.lock().unwrap();
        let mut t = Table::new("demo", &["a"]);
        t.row(vec!["x,y".into()]);
        std::env::set_var("FEDGEC_RESULTS", std::env::temp_dir().join("fedgec_test_results"));
        let p = t.save_csv("escape_test").unwrap();
        std::env::remove_var("FEDGEC_RESULTS");
        let content = std::fs::read_to_string(p).unwrap();
        assert!(content.contains("\"x,y\""));
    }

    /// Minimal RFC 4180 reader for the round-trip test: quoted fields,
    /// doubled-quote escapes, embedded commas/newlines.
    fn parse_csv(text: &str) -> Vec<Vec<String>> {
        let mut rows = Vec::new();
        let mut row = Vec::new();
        let mut field = String::new();
        let mut quoted = false;
        let mut chars = text.chars().peekable();
        while let Some(c) = chars.next() {
            if quoted {
                match c {
                    '"' if chars.peek() == Some(&'"') => {
                        chars.next();
                        field.push('"');
                    }
                    '"' => quoted = false,
                    _ => field.push(c),
                }
            } else {
                match c {
                    '"' => quoted = true,
                    ',' => row.push(std::mem::take(&mut field)),
                    '\n' => {
                        row.push(std::mem::take(&mut field));
                        rows.push(std::mem::take(&mut row));
                    }
                    '\r' => {}
                    _ => field.push(c),
                }
            }
        }
        if !field.is_empty() || !row.is_empty() {
            row.push(field);
            rows.push(row);
        }
        rows
    }

    #[test]
    fn csv_round_trips_commas_quotes_and_newlines() {
        let _guard = ENV_LOCK.lock().unwrap();
        let mut t = Table::new("rt", &["plain", "tricky"]);
        let cells = [
            ["x", "a,b"],
            ["y", "say \"hi\""],
            ["z", "two\nlines"],
            ["w", "all, \"of\"\r\nit"],
        ];
        for r in &cells {
            t.row(vec![r[0].into(), r[1].into()]);
        }
        std::env::set_var("FEDGEC_RESULTS", std::env::temp_dir().join("fedgec_test_results"));
        let p = t.save_csv("roundtrip_test").unwrap();
        std::env::remove_var("FEDGEC_RESULTS");
        let parsed = parse_csv(&std::fs::read_to_string(p).unwrap());
        assert_eq!(parsed[0], vec!["plain", "tricky"]);
        for (i, r) in cells.iter().enumerate() {
            // \r\n inside a quoted field survives as written; the bare
            // \n case and the comma/quote cases must come back verbatim.
            let got = &parsed[i + 1];
            assert_eq!(got[0], r[0], "row {i}");
            assert_eq!(got[1].replace("\r\n", "\n"), r[1].replace("\r\n", "\n"), "row {i}");
        }
        assert_eq!(parsed.len(), cells.len() + 1, "newline cells must not add rows");
    }

    #[test]
    fn save_json_emits_parseable_bench_artifact() {
        let _guard = ENV_LOCK.lock().unwrap();
        let mut t = Table::new("json demo", &["a", "b"]);
        t.row(vec!["x \"q\"".into(), "2".into()]);
        std::env::set_var("FEDGEC_RESULTS", std::env::temp_dir().join("fedgec_test_results"));
        let p = t.save_json("json_demo").unwrap();
        std::env::remove_var("FEDGEC_RESULTS");
        assert!(p.file_name().unwrap().to_str().unwrap().starts_with("BENCH_"));
        let content = std::fs::read_to_string(p).unwrap();
        let parsed = crate::util::json::Json::parse(&content).unwrap();
        assert_eq!(parsed.get("title").and_then(|j| j.as_str()), Some("json demo"));
        assert_eq!(parsed.get("headers").and_then(|j| j.as_arr()).unwrap().len(), 2);
        let rows = parsed.get("rows").and_then(|j| j.as_arr()).unwrap();
        assert_eq!(rows[0].as_arr().unwrap()[0].as_str(), Some("x \"q\""));
    }

    #[test]
    fn report_table_renders_layers_and_total() {
        use crate::compress::{CodecReport, LayerReport};
        let mut rep = CodecReport::new("demo");
        rep.push(LayerReport {
            name: "conv".into(),
            raw_bytes: 4000,
            compressed_bytes: 400,
            lossy: true,
            ..Default::default()
        });
        let t = report_table(&rep);
        let md = t.markdown();
        assert!(md.contains("conv"));
        assert!(md.contains("TOTAL"));
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(std::time::Duration::from_secs(200)), "200s");
        assert!(fmt_duration(std::time::Duration::from_millis(5)).ends_with("ms"));
    }
}
