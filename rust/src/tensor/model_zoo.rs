//! Layer-shape tables for the paper's evaluation models (Table 2):
//! ResNet-18 / ResNet-34 (He et al. 2016) and Inception V1 / V3
//! (Szegedy et al. 2015/2016). These drive the full-scale synthetic
//! gradient generator (`train/gradgen.rs`) used by Table 4 / Table 5 /
//! Fig. 10 / Fig. 11 — the *shapes* are the real architectures; only the
//! gradient values are synthesized (DESIGN.md §5).

use super::LayerMeta;

/// The four evaluation models of the paper plus micro models for real
/// CPU training.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelArch {
    ResNet18,
    ResNet34,
    InceptionV1,
    InceptionV3,
    /// Tiny residual CNN actually trained via JAX/HLO in this repo.
    MicroResNet,
    /// Tiny multi-branch CNN actually trained via JAX/HLO in this repo.
    MicroInception,
}

impl ModelArch {
    pub fn name(&self) -> &'static str {
        match self {
            ModelArch::ResNet18 => "resnet18",
            ModelArch::ResNet34 => "resnet34",
            ModelArch::InceptionV1 => "inception_v1",
            ModelArch::InceptionV3 => "inception_v3",
            ModelArch::MicroResNet => "micro_resnet",
            ModelArch::MicroInception => "micro_inception",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Some(match s {
            "resnet18" => ModelArch::ResNet18,
            "resnet34" => ModelArch::ResNet34,
            "inception_v1" | "inceptionv1" => ModelArch::InceptionV1,
            "inception_v3" | "inceptionv3" => ModelArch::InceptionV3,
            "micro_resnet" => ModelArch::MicroResNet,
            "micro_inception" => ModelArch::MicroInception,
            _ => return None,
        })
    }

    /// Layer table for `num_classes` output classes.
    pub fn layers(&self, num_classes: usize) -> Vec<LayerMeta> {
        match self {
            ModelArch::ResNet18 => resnet(&[2, 2, 2, 2], false, num_classes),
            ModelArch::ResNet34 => resnet(&[3, 4, 6, 3], false, num_classes),
            ModelArch::InceptionV1 => inception_v1(num_classes),
            ModelArch::InceptionV3 => inception_v3(num_classes),
            ModelArch::MicroResNet => micro_resnet(num_classes),
            ModelArch::MicroInception => micro_inception(num_classes),
        }
    }

    /// Total parameter count for `num_classes`.
    pub fn param_count(&self, num_classes: usize) -> usize {
        self.layers(num_classes).iter().map(|l| l.numel).sum()
    }
}

fn bn(name: &str, ch: usize, out: &mut Vec<LayerMeta>) {
    out.push(LayerMeta::other(&format!("{name}.bn.weight"), ch));
    out.push(LayerMeta::other(&format!("{name}.bn.bias"), ch));
}

fn conv_bn(name: &str, out_ch: usize, in_ch: usize, k: usize, out: &mut Vec<LayerMeta>) {
    out.push(LayerMeta::conv(&format!("{name}.conv"), out_ch, in_ch, k, k));
    bn(name, out_ch, out);
}

/// Basic-block ResNet (18/34 use BasicBlock; 50+ would use Bottleneck).
fn resnet(blocks: &[usize; 4], _bottleneck: bool, num_classes: usize) -> Vec<LayerMeta> {
    let mut l = Vec::new();
    conv_bn("stem", 64, 3, 7, &mut l);
    let widths = [64usize, 128, 256, 512];
    let mut in_ch = 64;
    for (stage, (&n_blocks, &w)) in blocks.iter().zip(widths.iter()).enumerate() {
        for b in 0..n_blocks {
            let name = format!("layer{}.{}", stage + 1, b);
            conv_bn(&format!("{name}.a"), w, in_ch, 3, &mut l);
            conv_bn(&format!("{name}.b"), w, w, 3, &mut l);
            if in_ch != w {
                // 1x1 downsample projection on the first block of a stage.
                conv_bn(&format!("{name}.down"), w, in_ch, 1, &mut l);
            }
            in_ch = w;
        }
    }
    l.push(LayerMeta::dense("fc", num_classes, 512));
    l.push(LayerMeta::other("fc.bias", num_classes));
    l
}

/// One GoogLeNet inception block: 1x1, 3x3 (with reduce), 5x5 (with
/// reduce), pool-proj branches.
#[allow(clippy::too_many_arguments)]
fn inception_block(
    name: &str,
    in_ch: usize,
    c1: usize,
    c3r: usize,
    c3: usize,
    c5r: usize,
    c5: usize,
    pp: usize,
    l: &mut Vec<LayerMeta>,
) -> usize {
    conv_bn(&format!("{name}.b1"), c1, in_ch, 1, l);
    conv_bn(&format!("{name}.b3r"), c3r, in_ch, 1, l);
    conv_bn(&format!("{name}.b3"), c3, c3r, 3, l);
    conv_bn(&format!("{name}.b5r"), c5r, in_ch, 1, l);
    conv_bn(&format!("{name}.b5"), c5, c5r, 5, l);
    conv_bn(&format!("{name}.pp"), pp, in_ch, 1, l);
    c1 + c3 + c5 + pp
}

/// GoogLeNet / Inception V1 (Szegedy 2015, Table 1 of that paper).
fn inception_v1(num_classes: usize) -> Vec<LayerMeta> {
    let mut l = Vec::new();
    conv_bn("stem.1", 64, 3, 7, &mut l);
    conv_bn("stem.2r", 64, 64, 1, &mut l);
    conv_bn("stem.2", 192, 64, 3, &mut l);
    let mut ch = 192;
    let blocks: &[(&str, [usize; 6])] = &[
        ("3a", [64, 96, 128, 16, 32, 32]),
        ("3b", [128, 128, 192, 32, 96, 64]),
        ("4a", [192, 96, 208, 16, 48, 64]),
        ("4b", [160, 112, 224, 24, 64, 64]),
        ("4c", [128, 128, 256, 24, 64, 64]),
        ("4d", [112, 144, 288, 32, 64, 64]),
        ("4e", [256, 160, 320, 32, 128, 128]),
        ("5a", [256, 160, 320, 32, 128, 128]),
        ("5b", [384, 192, 384, 48, 128, 128]),
    ];
    for (name, p) in blocks {
        ch = inception_block(name, ch, p[0], p[1], p[2], p[3], p[4], p[5], &mut l);
    }
    l.push(LayerMeta::dense("fc", num_classes, ch));
    l.push(LayerMeta::other("fc.bias", num_classes));
    l
}

/// Inception V3 (Szegedy 2016) — simplified but faithful layer inventory:
/// factorized stem, 3× InceptionA, grid reduction, 4× InceptionB with 7×1/
/// 1×7 factorizations, reduction, 2× InceptionC.
fn inception_v3(num_classes: usize) -> Vec<LayerMeta> {
    let mut l = Vec::new();
    conv_bn("stem.1", 32, 3, 3, &mut l);
    conv_bn("stem.2", 32, 32, 3, &mut l);
    conv_bn("stem.3", 64, 32, 3, &mut l);
    conv_bn("stem.4", 80, 64, 1, &mut l);
    conv_bn("stem.5", 192, 80, 3, &mut l);
    // InceptionA x3 (in 192 -> 256 -> 288 -> 288)
    let mut ch = 192;
    for (i, pool_ch) in [32usize, 64, 64].iter().enumerate() {
        let name = format!("mixed_a{i}");
        conv_bn(&format!("{name}.b1"), 64, ch, 1, &mut l);
        conv_bn(&format!("{name}.b5r"), 48, ch, 1, &mut l);
        conv_bn(&format!("{name}.b5"), 64, 48, 5, &mut l);
        conv_bn(&format!("{name}.b3r"), 64, ch, 1, &mut l);
        conv_bn(&format!("{name}.b3a"), 96, 64, 3, &mut l);
        conv_bn(&format!("{name}.b3b"), 96, 96, 3, &mut l);
        conv_bn(&format!("{name}.pp"), *pool_ch, ch, 1, &mut l);
        ch = 64 + 64 + 96 + pool_ch;
    }
    // Reduction A
    conv_bn("red_a.b3", 384, ch, 3, &mut l);
    conv_bn("red_a.b3r", 64, ch, 1, &mut l);
    conv_bn("red_a.b3a", 96, 64, 3, &mut l);
    conv_bn("red_a.b3b", 96, 96, 3, &mut l);
    ch = 384 + 96 + ch;
    // InceptionB x4 with 1x7/7x1 factorized convs
    for (i, c7) in [128usize, 160, 160, 192].iter().enumerate() {
        let name = format!("mixed_b{i}");
        conv_bn(&format!("{name}.b1"), 192, ch, 1, &mut l);
        l.push(LayerMeta::conv(&format!("{name}.b7r.conv"), *c7, ch, 1, 1));
        bn(&format!("{name}.b7r"), *c7, &mut l);
        l.push(LayerMeta::conv(&format!("{name}.b7a.conv"), *c7, *c7, 1, 7));
        bn(&format!("{name}.b7a"), *c7, &mut l);
        l.push(LayerMeta::conv(&format!("{name}.b7b.conv"), 192, *c7, 7, 1));
        bn(&format!("{name}.b7b"), 192, &mut l);
        l.push(LayerMeta::conv(&format!("{name}.b7x2a.conv"), *c7, ch, 1, 1));
        bn(&format!("{name}.b7x2a"), *c7, &mut l);
        l.push(LayerMeta::conv(&format!("{name}.b7x2b.conv"), *c7, *c7, 7, 1));
        bn(&format!("{name}.b7x2b"), *c7, &mut l);
        l.push(LayerMeta::conv(&format!("{name}.b7x2c.conv"), 192, *c7, 1, 7));
        bn(&format!("{name}.b7x2c"), 192, &mut l);
        conv_bn(&format!("{name}.pp"), 192, ch, 1, &mut l);
        ch = 192 * 4;
    }
    // Reduction B
    conv_bn("red_b.b3r", 192, ch, 1, &mut l);
    conv_bn("red_b.b3", 320, 192, 3, &mut l);
    conv_bn("red_b.b7r", 192, ch, 1, &mut l);
    conv_bn("red_b.b7a", 192, 192, 7, &mut l); // stand-in for 1x7+7x1 pair
    conv_bn("red_b.b7b", 192, 192, 3, &mut l);
    ch = 320 + 192 + ch;
    // InceptionC x2
    for i in 0..2 {
        let name = format!("mixed_c{i}");
        conv_bn(&format!("{name}.b1"), 320, ch, 1, &mut l);
        conv_bn(&format!("{name}.b3r"), 384, ch, 1, &mut l);
        l.push(LayerMeta::conv(&format!("{name}.b3a.conv"), 384, 384, 1, 3));
        bn(&format!("{name}.b3a"), 384, &mut l);
        l.push(LayerMeta::conv(&format!("{name}.b3b.conv"), 384, 384, 3, 1));
        bn(&format!("{name}.b3b"), 384, &mut l);
        conv_bn(&format!("{name}.b3x2r"), 448, ch, 1, &mut l);
        conv_bn(&format!("{name}.b3x2"), 384, 448, 3, &mut l);
        l.push(LayerMeta::conv(&format!("{name}.b3x2a.conv"), 384, 384, 1, 3));
        bn(&format!("{name}.b3x2a"), 384, &mut l);
        l.push(LayerMeta::conv(&format!("{name}.b3x2b.conv"), 384, 384, 3, 1));
        bn(&format!("{name}.b3x2b"), 384, &mut l);
        conv_bn(&format!("{name}.pp"), 192, ch, 1, &mut l);
        ch = 320 + 384 * 2 + 384 * 2 + 192;
    }
    l.push(LayerMeta::dense("fc", num_classes, ch));
    l.push(LayerMeta::other("fc.bias", num_classes));
    l
}

/// Micro residual CNN matching python/compile/model.py (really trained).
fn micro_resnet(num_classes: usize) -> Vec<LayerMeta> {
    let mut l = Vec::new();
    l.push(LayerMeta::conv("stem.conv", 16, 3, 3, 3));
    l.push(LayerMeta::other("stem.bias", 16));
    for (i, (w_in, w_out)) in [(16usize, 16usize), (16, 32)].iter().enumerate() {
        l.push(LayerMeta::conv(&format!("block{i}.a.conv"), *w_out, *w_in, 3, 3));
        l.push(LayerMeta::other(&format!("block{i}.a.bias"), *w_out));
        l.push(LayerMeta::conv(&format!("block{i}.b.conv"), *w_out, *w_out, 3, 3));
        l.push(LayerMeta::other(&format!("block{i}.b.bias"), *w_out));
        if w_in != w_out {
            l.push(LayerMeta::conv(&format!("block{i}.down.conv"), *w_out, *w_in, 1, 1));
            l.push(LayerMeta::other(&format!("block{i}.down.bias"), *w_out));
        }
    }
    l.push(LayerMeta::dense("fc", num_classes, 32 * 8 * 8));
    l.push(LayerMeta::other("fc.bias", num_classes));
    l
}

/// Micro inception CNN matching python/compile/model.py.
fn micro_inception(num_classes: usize) -> Vec<LayerMeta> {
    let mut l = Vec::new();
    l.push(LayerMeta::conv("stem.conv", 16, 3, 3, 3));
    l.push(LayerMeta::other("stem.bias", 16));
    for (i, in_ch) in [16usize, 32].iter().enumerate() {
        let name = format!("mix{i}");
        l.push(LayerMeta::conv(&format!("{name}.b1.conv"), 8, *in_ch, 1, 1));
        l.push(LayerMeta::other(&format!("{name}.b1.bias"), 8));
        l.push(LayerMeta::conv(&format!("{name}.b3.conv"), 16, *in_ch, 3, 3));
        l.push(LayerMeta::other(&format!("{name}.b3.bias"), 16));
        l.push(LayerMeta::conv(&format!("{name}.b5.conv"), 8, *in_ch, 5, 5));
        l.push(LayerMeta::other(&format!("{name}.b5.bias"), 8));
    }
    l.push(LayerMeta::dense("fc", num_classes, 32 * 8 * 8));
    l.push(LayerMeta::other("fc.bias", num_classes));
    l
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 2 gives 11.7M / 21.8M / 6.6M / 23.9M params. Our layer
    /// inventories should land in the same ballpark (±15% — BN bookkeeping
    /// and aux heads differ between implementations).
    #[test]
    fn param_counts_match_paper_scale() {
        let cases = [
            (ModelArch::ResNet18, 11.7e6),
            (ModelArch::ResNet34, 21.8e6),
            (ModelArch::InceptionV1, 6.6e6),
            (ModelArch::InceptionV3, 23.9e6),
        ];
        for (arch, want) in cases {
            let got = arch.param_count(1000) as f64;
            let ratio = got / want;
            assert!(
                (0.8..1.25).contains(&ratio),
                "{}: got {got:.3e}, paper {want:.3e} (ratio {ratio:.2})",
                arch.name()
            );
        }
    }

    #[test]
    fn micro_models_are_small() {
        assert!(ModelArch::MicroResNet.param_count(10) < 300_000);
        assert!(ModelArch::MicroInception.param_count(10) < 300_000);
    }

    #[test]
    fn resnet18_has_3x3_convs() {
        let layers = ModelArch::ResNet18.layers(10);
        let n3x3 = layers
            .iter()
            .filter(|l| matches!(l.kind, super::super::LayerKind::Conv { kh: 3, kw: 3, .. }))
            .count();
        assert!(n3x3 >= 16, "resnet18 should have >=16 3x3 convs, got {n3x3}");
    }

    #[test]
    fn largest_resnet18_conv_is_512x512x3x3() {
        let layers = ModelArch::ResNet18.layers(10);
        let max = layers.iter().max_by_key(|l| l.numel).unwrap();
        // Paper §5.4: largest conv layer in ResNet-18 is 512x512 kernels of 3x3.
        assert_eq!(max.numel, 512 * 512 * 3 * 3);
    }

    #[test]
    fn names_roundtrip() {
        for a in [
            ModelArch::ResNet18,
            ModelArch::ResNet34,
            ModelArch::InceptionV1,
            ModelArch::InceptionV3,
            ModelArch::MicroResNet,
            ModelArch::MicroInception,
        ] {
            assert_eq!(ModelArch::from_name(a.name()), Some(a));
        }
    }
}
