//! Gradient tensors and layer metadata.
//!
//! The compressor treats a model update as an ordered list of
//! [`LayerGrad`]s. Convolutional layers carry their kernel geometry so the
//! sign predictor (paper §4.3) can iterate kernels `K_{o,i}` of size
//! `kh × kw`.

pub mod model_zoo;

/// What kind of parameter tensor a layer is — drives the sign predictor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// Convolution weight with shape `[out_ch, in_ch, kh, kw]`.
    Conv { out_ch: usize, in_ch: usize, kh: usize, kw: usize },
    /// Dense / fully-connected weight `[out, in]`.
    Dense { out: usize, inp: usize },
    /// Anything else (bias, batch-norm scale/shift, embeddings…).
    Other,
}

impl LayerKind {
    /// Kernel element count `T = kh*kw` for conv layers.
    pub fn kernel_size(&self) -> Option<usize> {
        match self {
            LayerKind::Conv { kh, kw, .. } => Some(kh * kw),
            _ => None,
        }
    }
    /// Number of kernels `out_ch * in_ch` for conv layers.
    pub fn kernel_count(&self) -> Option<usize> {
        match self {
            LayerKind::Conv { out_ch, in_ch, .. } => Some(out_ch * in_ch),
            _ => None,
        }
    }
    /// Total element count implied by the kind (conv/dense only).
    pub fn numel(&self) -> Option<usize> {
        match self {
            LayerKind::Conv { out_ch, in_ch, kh, kw } => Some(out_ch * in_ch * kh * kw),
            LayerKind::Dense { out, inp } => Some(out * inp),
            LayerKind::Other => None,
        }
    }
}

/// Metadata for one layer of a model.
#[derive(Debug, Clone)]
pub struct LayerMeta {
    pub name: String,
    pub kind: LayerKind,
    pub numel: usize,
}

impl LayerMeta {
    pub fn conv(name: &str, out_ch: usize, in_ch: usize, kh: usize, kw: usize) -> Self {
        LayerMeta {
            name: name.to_string(),
            kind: LayerKind::Conv { out_ch, in_ch, kh, kw },
            numel: out_ch * in_ch * kh * kw,
        }
    }
    pub fn dense(name: &str, out: usize, inp: usize) -> Self {
        LayerMeta { name: name.to_string(), kind: LayerKind::Dense { out, inp }, numel: out * inp }
    }
    pub fn other(name: &str, numel: usize) -> Self {
        LayerMeta { name: name.to_string(), kind: LayerKind::Other, numel }
    }
}

/// One layer's gradient: metadata + flat row-major values.
///
/// For conv layers the flat layout is `[o][i][kh][kw]`, so kernel `(o,i)`
/// occupies the contiguous range `[(o*in_ch+i)*T, (o*in_ch+i+1)*T)`.
#[derive(Debug, Clone)]
pub struct LayerGrad {
    pub meta: LayerMeta,
    pub data: Vec<f32>,
}

impl LayerGrad {
    pub fn new(meta: LayerMeta, data: Vec<f32>) -> Self {
        debug_assert_eq!(meta.numel, data.len(), "layer {}: meta/data mismatch", meta.name);
        LayerGrad { meta, data }
    }

    /// Iterate contiguous kernel slices for conv layers.
    pub fn kernels(&self) -> Option<impl Iterator<Item = &[f32]>> {
        let t = self.meta.kind.kernel_size()?;
        Some(self.data.chunks_exact(t))
    }
}

/// A full model update: ordered layers. Total bytes = 4 * total numel.
#[derive(Debug, Clone, Default)]
pub struct ModelGrad {
    pub layers: Vec<LayerGrad>,
}

impl ModelGrad {
    pub fn numel(&self) -> usize {
        self.layers.iter().map(|l| l.data.len()).sum()
    }
    pub fn byte_size(&self) -> usize {
        self.numel() * 4
    }
    /// Flatten all layers into one vector (for correlation computations).
    pub fn flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.numel());
        for l in &self.layers {
            out.extend_from_slice(&l.data);
        }
        out
    }
}

/// Compute the paper's kernel sign-consistency (Eq. 5) for a kernel slice:
/// `(max(P,N) + Z - ceil(T/2)) / (T - ceil(T/2))`, clamped to [0,1].
pub fn sign_consistency(kernel: &[f32]) -> f64 {
    let t = kernel.len();
    if t <= 1 {
        return 1.0;
    }
    let (mut p, mut n, mut z) = (0usize, 0usize, 0usize);
    for &x in kernel {
        if x > 0.0 {
            p += 1;
        } else if x < 0.0 {
            n += 1;
        } else {
            z += 1;
        }
    }
    let half = t.div_ceil(2);
    let num = (p.max(n) + z) as f64 - half as f64;
    let den = (t - half) as f64;
    (num / den).clamp(0.0, 1.0)
}

/// Dominant sign of a kernel: +1.0 if positives outnumber negatives,
/// -1.0 otherwise (ties break negative, matching the bitmap convention
/// where bit 1 = positive).
pub fn dominant_sign(kernel: &[f32]) -> f32 {
    let (mut p, mut n) = (0usize, 0usize);
    for &x in kernel {
        if x > 0.0 {
            p += 1;
        } else if x < 0.0 {
            n += 1;
        }
    }
    if p > n {
        1.0
    } else {
        -1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_meta_numel() {
        let m = LayerMeta::conv("c", 4, 3, 3, 3);
        assert_eq!(m.numel, 108);
        assert_eq!(m.kind.kernel_size(), Some(9));
        assert_eq!(m.kind.kernel_count(), Some(12));
        let d = LayerMeta::dense("d", 10, 20);
        assert_eq!(d.numel, 200);
        assert_eq!(d.kind.kernel_size(), None);
    }

    #[test]
    fn kernels_iterate_contiguously() {
        let meta = LayerMeta::conv("c", 2, 1, 1, 2); // 2 kernels of size 2
        let g = LayerGrad::new(meta, vec![1.0, 2.0, 3.0, 4.0]);
        let ks: Vec<&[f32]> = g.kernels().unwrap().collect();
        assert_eq!(ks, vec![&[1.0, 2.0][..], &[3.0, 4.0][..]]);
    }

    #[test]
    fn sign_consistency_extremes() {
        // All same sign -> 1.0
        assert_eq!(sign_consistency(&[1.0f32; 9]), 1.0);
        assert_eq!(sign_consistency(&[-1.0f32; 9]), 1.0);
        // Max disagreement for T=9: P=5,N=4 -> (5-5)/4 = 0
        let mixed = [1.0f32, 1.0, 1.0, 1.0, 1.0, -1.0, -1.0, -1.0, -1.0];
        assert_eq!(sign_consistency(&mixed), 0.0);
        // Zeros count as neutral: 9 zeros -> (0+9-5)/4 = 1.0
        assert_eq!(sign_consistency(&[0.0f32; 9]), 1.0);
    }

    #[test]
    fn sign_consistency_mid() {
        // T=9, P=7, N=2 -> (7-5)/4 = 0.5
        let k = [1.0f32, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, -1.0, -1.0];
        assert!((sign_consistency(&k) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn dominant_sign_majority() {
        assert_eq!(dominant_sign(&[1.0, 1.0, -1.0]), 1.0);
        assert_eq!(dominant_sign(&[-1.0, -1.0, 1.0]), -1.0);
        assert_eq!(dominant_sign(&[0.0, 0.0]), -1.0); // tie -> negative
    }

    #[test]
    fn model_grad_sizes() {
        let mut mg = ModelGrad::default();
        mg.layers.push(LayerGrad::new(LayerMeta::other("b", 3), vec![1.0, 2.0, 3.0]));
        mg.layers.push(LayerGrad::new(LayerMeta::other("c", 2), vec![4.0, 5.0]));
        assert_eq!(mg.numel(), 5);
        assert_eq!(mg.byte_size(), 20);
        assert_eq!(mg.flat(), vec![1.0, 2.0, 3.0, 4.0, 5.0]);
    }
}
