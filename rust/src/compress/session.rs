//! Encode/decode sessions: the streaming view of a [`GradientCodec`].
//!
//! A session wraps one round of a codec's frame API, tracking the layer
//! cursor and accumulating the unified [`CodecReport`]. The FL client
//! drives an [`EncodeSession`] to emit frames into the transport as they
//! are produced (pipelining compression with transmission); the server
//! drives a [`DecodeSession`] as frames arrive.
//!
//! The whole-model entry points (`GradientCodec::compress` /
//! `::decompress`) are blanket adapters over the same machinery.

use super::engine::CodecEngine;
use super::frame::{CodecReport, Frame, LayerReport};
use super::state::CodecState;
use super::GradientCodec;
use crate::tensor::{LayerGrad, LayerMeta, ModelGrad};
use crate::util::threadpool;

/// One round's encoder session over a codec.
pub struct EncodeSession<'c> {
    codec: &'c mut dyn GradientCodec,
    report: CodecReport,
    n_layers: usize,
    next: usize,
}

impl<'c> EncodeSession<'c> {
    /// Begin an encode session for an `n_layers` model.
    pub fn new(codec: &'c mut dyn GradientCodec, n_layers: usize) -> crate::Result<Self> {
        codec.begin(n_layers)?;
        let report = CodecReport::new(codec.name());
        Ok(EncodeSession { codec, report, n_layers, next: 0 })
    }

    /// Encode the next layer (layers must arrive in model order).
    pub fn encode_layer(&mut self, layer: &LayerGrad) -> crate::Result<Frame> {
        anyhow::ensure!(
            self.next < self.n_layers,
            "encode session: layer {} past declared {}",
            self.next,
            self.n_layers
        );
        let frame = self.codec.encode_layer(self.next, layer)?;
        self.report.push(frame.report.clone());
        self.next += 1;
        Ok(frame)
    }

    /// Layers encoded so far.
    pub fn encoded(&self) -> usize {
        self.next
    }

    /// Close the session, returning the accumulated report.
    pub fn finish(self) -> crate::Result<CodecReport> {
        anyhow::ensure!(
            self.next == self.n_layers,
            "encode session closed after {} of {} layers",
            self.next,
            self.n_layers
        );
        Ok(self.report)
    }
}

/// One round's decoder session over a codec (the server-side mirror).
pub struct DecodeSession<'c> {
    codec: &'c mut dyn GradientCodec,
    report: CodecReport,
    n_layers: usize,
    next: usize,
}

impl<'c> DecodeSession<'c> {
    pub fn new(codec: &'c mut dyn GradientCodec, n_layers: usize) -> crate::Result<Self> {
        codec.begin(n_layers)?;
        let report = CodecReport::new(codec.name());
        Ok(DecodeSession { codec, report, n_layers, next: 0 })
    }

    /// Decode the next frame; frames must arrive in model order and carry
    /// the matching layer index.
    pub fn decode_frame(&mut self, frame: &Frame, meta: &LayerMeta) -> crate::Result<LayerGrad> {
        anyhow::ensure!(
            self.next < self.n_layers,
            "decode session: frame {} past declared {}",
            self.next,
            self.n_layers
        );
        anyhow::ensure!(
            frame.index as usize == self.next,
            "decode session: frame index {} != expected {}",
            frame.index,
            self.next
        );
        let (layer, report) = self.codec.decode_frame(frame, meta)?;
        self.report.push(report);
        self.next += 1;
        Ok(layer)
    }

    pub fn decoded(&self) -> usize {
        self.next
    }

    pub fn finish(self) -> crate::Result<CodecReport> {
        anyhow::ensure!(
            self.next == self.n_layers,
            "decode session closed after {} of {} frames",
            self.next,
            self.n_layers
        );
        Ok(self.report)
    }
}

/// One round's decoder session over a stateless [`CodecEngine`] and an
/// explicitly checked-out client state — the server-side mirror in the
/// externalized-state world. Same ordering/report discipline as
/// [`DecodeSession`], different state ownership.
pub struct EngineDecodeSession<'e> {
    engine: &'e mut dyn CodecEngine,
    state: &'e mut CodecState,
    report: CodecReport,
    n_layers: usize,
    next: usize,
}

impl<'e> EngineDecodeSession<'e> {
    pub fn new(
        engine: &'e mut dyn CodecEngine,
        state: &'e mut CodecState,
        n_layers: usize,
    ) -> Self {
        let report = CodecReport::new(engine.name());
        EngineDecodeSession { engine, state, report, n_layers, next: 0 }
    }

    /// Decode the next frame; frames must arrive in model order and carry
    /// the matching layer index.
    pub fn decode_frame(&mut self, frame: &Frame, meta: &LayerMeta) -> crate::Result<LayerGrad> {
        anyhow::ensure!(
            self.next < self.n_layers,
            "decode session: frame {} past declared {}",
            self.next,
            self.n_layers
        );
        anyhow::ensure!(
            frame.index as usize == self.next,
            "decode session: frame index {} != expected {}",
            frame.index,
            self.next
        );
        let (layer, report) = self.engine.decode_frame(frame, meta, self.state)?;
        self.report.push(report);
        self.next += 1;
        Ok(layer)
    }

    /// Decode the next frame for compressed-domain aggregation (see
    /// [`crate::compress::agg`]): eligible frames stop before
    /// dequantization, everything else arrives as a dense fallback.
    /// Same ordering/report discipline as [`Self::decode_frame`].
    pub fn decode_frame_to_bins(
        &mut self,
        frame: &Frame,
        meta: &LayerMeta,
    ) -> crate::Result<crate::compress::agg::BinFrame> {
        anyhow::ensure!(
            self.next < self.n_layers,
            "decode session: frame {} past declared {}",
            self.next,
            self.n_layers
        );
        anyhow::ensure!(
            frame.index as usize == self.next,
            "decode session: frame index {} != expected {}",
            frame.index,
            self.next
        );
        let (bf, report) = self.engine.decode_frame_to_bins(frame, meta, self.state)?;
        self.report.push(report);
        self.next += 1;
        Ok(bf)
    }

    pub fn decoded(&self) -> usize {
        self.next
    }

    pub fn finish(self) -> crate::Result<CodecReport> {
        anyhow::ensure!(
            self.next == self.n_layers,
            "decode session closed after {} of {} frames",
            self.next,
            self.n_layers
        );
        Ok(self.report)
    }
}

/// Shared scaffolding for layer-parallel whole-model encoding: codecs
/// whose per-layer encode is a pure function of the layer (stateless, or
/// with independently derived randomness) implement `encode_model` as a
/// call to this with a per-layer closure. Falls back to a sequential
/// loop below the [`threadpool::layer_parallelism`] threshold; output is
/// identical either way.
pub fn encode_model_parallel<F>(grads: &ModelGrad, f: F) -> crate::Result<Vec<Frame>>
where
    F: Fn(usize, &LayerGrad) -> crate::Result<(Vec<u8>, LayerReport)> + Sync,
{
    let threads = threadpool::layer_parallelism(grads.layers.len(), grads.numel());
    let results: Vec<crate::Result<(Vec<u8>, LayerReport)>> = if threads <= 1 {
        grads.layers.iter().enumerate().map(|(idx, layer)| f(idx, layer)).collect()
    } else {
        let items: Vec<(usize, &LayerGrad)> = grads.layers.iter().enumerate().collect();
        threadpool::parallel_map(items, threads, |(idx, layer)| f(idx, layer))
    };
    let mut frames = Vec::with_capacity(results.len());
    for (idx, res) in results.into_iter().enumerate() {
        let (payload, report) = res?;
        frames.push(Frame::new(idx, payload, report));
    }
    Ok(frames)
}

/// Decode an ordered frame sequence into a whole model (shared by the
/// blanket `decompress` adapter and the streamed server path).
pub fn decode_frames(
    codec: &mut dyn GradientCodec,
    frames: &[Frame],
    metas: &[LayerMeta],
) -> crate::Result<(ModelGrad, CodecReport)> {
    anyhow::ensure!(
        frames.len() == metas.len(),
        "{} frames for {} layers",
        frames.len(),
        metas.len()
    );
    let mut session = DecodeSession::new(codec, metas.len())?;
    let mut out = ModelGrad::default();
    for (frame, meta) in frames.iter().zip(metas) {
        out.layers.push(session.decode_frame(frame, meta)?);
    }
    Ok((out, session.finish()?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::RawCodec;
    use crate::tensor::LayerMeta;

    fn model() -> ModelGrad {
        ModelGrad {
            layers: vec![
                LayerGrad::new(LayerMeta::other("a", 3), vec![1.0, -2.0, 3.0]),
                LayerGrad::new(LayerMeta::other("b", 2), vec![0.5, 0.25]),
            ],
        }
    }

    #[test]
    fn sessions_roundtrip_and_report() {
        let g = model();
        let metas: Vec<LayerMeta> = g.layers.iter().map(|l| l.meta.clone()).collect();
        let mut enc = RawCodec;
        let mut session = EncodeSession::new(&mut enc, 2).unwrap();
        let frames: Vec<Frame> =
            g.layers.iter().map(|l| session.encode_layer(l).unwrap()).collect();
        let report = session.finish().unwrap();
        assert_eq!(report.layers.len(), 2);
        assert_eq!(report.total_raw(), g.byte_size());

        let mut dec = RawCodec;
        let (back, dreport) = decode_frames(&mut dec, &frames, &metas).unwrap();
        assert_eq!(back.layers[0].data, g.layers[0].data);
        assert_eq!(back.layers[1].data, g.layers[1].data);
        assert_eq!(dreport.total_raw(), report.total_raw());
    }

    #[test]
    fn out_of_order_frame_rejected() {
        let g = model();
        let metas: Vec<LayerMeta> = g.layers.iter().map(|l| l.meta.clone()).collect();
        let mut enc = RawCodec;
        let mut session = EncodeSession::new(&mut enc, 2).unwrap();
        let mut frames: Vec<Frame> =
            g.layers.iter().map(|l| session.encode_layer(l).unwrap()).collect();
        frames.swap(0, 1);
        let mut dec = RawCodec;
        assert!(decode_frames(&mut dec, &frames, &metas).is_err());
    }

    #[test]
    fn engine_session_roundtrips_with_external_state() {
        use crate::compress::engine::StatelessEngine;
        let g = model();
        let metas: Vec<LayerMeta> = g.layers.iter().map(|l| l.meta.clone()).collect();
        let mut enc = RawCodec;
        let mut session = EncodeSession::new(&mut enc, 2).unwrap();
        let frames: Vec<Frame> =
            g.layers.iter().map(|l| session.encode_layer(l).unwrap()).collect();
        let mut engine = StatelessEngine::new(Box::new(RawCodec));
        let mut state = CodecState::default();
        let mut dec = EngineDecodeSession::new(&mut engine, &mut state, 2);
        for (f, m) in frames.iter().zip(&metas) {
            dec.decode_frame(f, m).unwrap();
        }
        assert_eq!(dec.decoded(), 2);
        let report = dec.finish().unwrap();
        assert_eq!(report.total_raw(), g.byte_size());
        // Out-of-order frames rejected, unfinished sessions error.
        let mut dec = EngineDecodeSession::new(&mut engine, &mut state, 2);
        assert!(dec.decode_frame(&frames[1], &metas[1]).is_err());
        let mut dec = EngineDecodeSession::new(&mut engine, &mut state, 2);
        dec.decode_frame(&frames[0], &metas[0]).unwrap();
        assert!(dec.finish().is_err());
    }

    #[test]
    fn unfinished_session_errors() {
        let g = model();
        let mut enc = RawCodec;
        let mut session = EncodeSession::new(&mut enc, 2).unwrap();
        session.encode_layer(&g.layers[0]).unwrap();
        assert!(session.finish().is_err());
    }
}
