//! Compressed-domain aggregation: weighted FedAvg over **integer
//! quantizer bins**, dequantizing once per layer per round.
//!
//! The quantizer is a uniform linear binner (`recon = pred + 2Δ·code`,
//! exact-f32 escapes — see [`super::quant`]), so a weighted sum of
//! reconstructions distributes over the bins:
//!
//! ```text
//! Σ_c w_c·recon_c[i] = Σ_c w_c·pred_c[i]            (prediction sums)
//!                    + 2Δ·Σ_c w_c·code_c[i]         (integer bin sums)
//!                    + Σ_c w_c·x_c[i]               (escape side-channel)
//! ```
//!
//! exactly when every participating frame for a layer shares the same Δ
//! — the abs-eb regime with one quantizer config fleet-wide. The server
//! then accumulates `Σ w_c·code_c` in i64 (f64 for non-integral weights
//! or past the overflow guard), the rare escapes and any dense
//! contributions in an f64 side accumulator, and performs a **single**
//! dequantize-and-divide at `finish` instead of one per client.
//!
//! Validity is decided per layer, never globally: frames a codec cannot
//! express as bins arrive as [`BinFrame::Dense`] and take the exact-f32
//! route; a mid-round Δ mismatch demotes the layer's integer sums into
//! the dense accumulator and the layer finishes on the mixed route. The
//! chosen route is recorded per layer (see [`AggRoute`]) and surfaced
//! through [`AggReport`] into `RoundStats`. DESIGN.md §11 has the full
//! fallback matrix.
//!
//! [`LayerBinSum`] is the per-shard partial-sum type: two shards that
//! aggregated disjoint client subsets [`merge`](LayerBinSum::merge)
//! commutatively, which is the exchange unit for the ROADMAP's sharded
//! server.

use crate::compress::blob::{BlobReader, BlobWriter};
use crate::compress::quant::{count_escapes, ESCAPE_CODE};
use crate::tensor::LayerGrad;

/// One decoded layer frame in the form the aggregator consumes: either
/// the compressed-domain triple (integer codes + escape stream +
/// prediction, sharing one Δ) or a dense f32 fallback for layers the
/// bin route cannot cover.
#[derive(Debug, Clone)]
pub enum BinFrame {
    /// `recon = pred + 2Δ·code`, escapes stored exact. An empty `pred`
    /// means the all-zero prediction (the state-free `pred=zero` mode —
    /// nothing to sum).
    Bins {
        codes: Vec<i32>,
        escapes: Vec<f32>,
        pred: Vec<f32>,
        delta: f64,
    },
    /// Fully reconstructed layer (the exact-f32 route).
    Dense(LayerGrad),
}

impl BinFrame {
    /// Element count of the layer this frame encodes.
    pub fn numel(&self) -> usize {
        match self {
            BinFrame::Bins { codes, .. } => codes.len(),
            BinFrame::Dense(layer) => layer.data.len(),
        }
    }
}

/// The aggregation route a layer ended on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggRoute {
    /// Every contribution arrived as bins under one Δ.
    Binsum,
    /// Every contribution took the dense f32 path.
    Exact,
    /// Bins and dense contributions met (or a Δ mismatch demoted the
    /// integer sums mid-round).
    Mixed,
}

impl AggRoute {
    pub fn name(&self) -> &'static str {
        match self {
            AggRoute::Binsum => "binsum",
            AggRoute::Exact => "exact",
            AggRoute::Mixed => "mixed",
        }
    }
}

/// Conservative per-element magnitude bound on a single frame's
/// weight-scaled code (codes are escape-clamped to ±2^24): demote the
/// i64 bins to f64 before `Σ w·code` can overflow.
const BIN_OVERFLOW_GUARD: i64 = i64::MAX / 2;
const CODE_BOUND: i64 = 1 << 24;

/// Per-layer weighted partial sums — the shard exchange unit.
///
/// `total[i] = 2Δ·(bins[i] + bins_f[i]) + pred[i] + dense[i]`, where
/// `dense` also carries escapes, demoted integer sums, and whole dense
/// contributions. Empty vectors mean "all zero" (lazily allocated).
#[derive(Debug, Clone, Default)]
pub struct LayerBinSum {
    numel: usize,
    /// Δ shared by the integer bins; 0.0 until the first bins frame.
    delta: f64,
    /// Integer bin sums `Σ w·code` (integral weights inside the
    /// overflow guard).
    bins: Vec<i64>,
    /// f64 bin sums (non-integral weights, or overflow-demoted).
    bins_f: Vec<f64>,
    /// Weighted prediction sums `Σ w·pred`.
    pred: Vec<f64>,
    /// Exact f32 side: escapes, dense contributions, Δ-mismatch folds.
    dense: Vec<f64>,
    bin_frames: usize,
    dense_frames: usize,
    /// Once a Δ mismatch folded the bins, stay dense for the round.
    demoted: bool,
    /// Running bound on `max_i |bins[i]|` (overflow sentinel).
    bin_bound: i64,
    /// Dequantize passes charged to this layer (demotion folds; the
    /// final fold is charged by `finish`).
    dequant_passes: usize,
}

impl LayerBinSum {
    pub fn new(numel: usize) -> Self {
        LayerBinSum { numel, ..Default::default() }
    }

    pub fn numel(&self) -> usize {
        self.numel
    }

    /// Route this layer would report if the round finished now.
    pub fn route(&self) -> AggRoute {
        let has_bins = self.bin_frames > 0;
        let has_dense = self.dense_frames > 0 || self.demoted;
        match (has_bins, has_dense) {
            (true, false) => AggRoute::Binsum,
            (true, true) => AggRoute::Mixed,
            _ => AggRoute::Exact,
        }
    }

    fn ensure_dense(&mut self) -> &mut Vec<f64> {
        if self.dense.is_empty() {
            self.dense = vec![0.0; self.numel];
        }
        &mut self.dense
    }

    /// Fold the integer/f64 bin sums into the dense accumulator under
    /// the currently pinned Δ (a dequantize pass), leaving the bins
    /// empty. Called on Δ mismatch and by `merge`.
    fn demote(&mut self) {
        if self.bins.is_empty() && self.bins_f.is_empty() {
            self.demoted = true;
            return;
        }
        let two_delta = 2.0 * self.delta;
        let n = self.numel;
        if self.dense.is_empty() {
            self.dense = vec![0.0; n];
        }
        for i in 0..n {
            let b = self.bins.get(i).copied().unwrap_or(0) as f64
                + self.bins_f.get(i).copied().unwrap_or(0.0);
            self.dense[i] += two_delta * b;
        }
        self.bins = Vec::new();
        self.bins_f = Vec::new();
        self.bin_bound = 0;
        self.delta = 0.0;
        self.demoted = true;
        self.dequant_passes += 1;
    }

    /// Accumulate one bins contribution. Caller has already validated
    /// lengths and the escape stream (see [`BinAggregator::add`]).
    fn add_bins(&mut self, codes: &[i32], escapes: &[f32], pred: &[f32], delta: f64, weight: f64) {
        // Δ mismatch against the pinned bins: fold and go dense.
        if self.bin_frames > 0 && !self.demoted && delta != self.delta {
            self.demote();
        }
        self.bin_frames += 1;
        if self.demoted {
            // Dense route for this frame: one weighted dequantize.
            let two_wd = 2.0 * delta * weight;
            let dense = self.ensure_dense();
            let mut esc = escapes.iter();
            for (i, &c) in codes.iter().enumerate() {
                if c == ESCAPE_CODE {
                    dense[i] += weight * (*esc.next().expect("validated escape stream")) as f64;
                } else {
                    dense[i] += two_wd * c as f64;
                }
            }
            self.dequant_passes += 1;
        } else {
            self.delta = delta;
            // Integral weights inside the guard stay in exact i64;
            // anything else accumulates in the f64 bins.
            let w_int = (weight.fract() == 0.0 && weight.abs() < (1i64 << 32) as f64)
                .then(|| weight as i64)
                .filter(|w| {
                    self.bin_bound.saturating_add(w.abs().saturating_mul(CODE_BOUND))
                        < BIN_OVERFLOW_GUARD
                });
            match w_int {
                Some(w) => {
                    self.bin_bound += w.abs() * CODE_BOUND;
                    if self.bins.is_empty() {
                        self.bins = vec![0; self.numel];
                    }
                    let mut esc = escapes.iter();
                    for (i, &c) in codes.iter().enumerate() {
                        if c == ESCAPE_CODE {
                            let x = *esc.next().expect("validated escape stream");
                            self.ensure_dense_at(i, weight * x as f64);
                        } else {
                            self.bins[i] += w * c as i64;
                        }
                    }
                }
                None => {
                    if self.bins_f.is_empty() {
                        self.bins_f = vec![0.0; self.numel];
                    }
                    let mut esc = escapes.iter();
                    for (i, &c) in codes.iter().enumerate() {
                        if c == ESCAPE_CODE {
                            let x = *esc.next().expect("validated escape stream");
                            self.ensure_dense_at(i, weight * x as f64);
                        } else {
                            self.bins_f[i] += weight * c as f64;
                        }
                    }
                }
            }
        }
        if !pred.is_empty() {
            if self.pred.is_empty() {
                self.pred = vec![0.0; self.numel];
            }
            // Escaped elements reconstruct to their exact stored value;
            // the prediction does not participate there.
            for ((p, &v), &c) in self.pred.iter_mut().zip(pred).zip(codes) {
                if c != ESCAPE_CODE {
                    *p += weight * v as f64;
                }
            }
        }
    }

    /// Sparse add into the dense accumulator (escape hits are rare —
    /// avoid allocating it until one lands).
    fn ensure_dense_at(&mut self, i: usize, v: f64) {
        if self.dense.is_empty() {
            self.dense = vec![0.0; self.numel];
        }
        self.dense[i] += v;
    }

    /// Accumulate one dense contribution (the exact route).
    fn add_dense(&mut self, data: &[f32], weight: f64) {
        self.dense_frames += 1;
        let dense = self.ensure_dense();
        for (a, &g) in dense.iter_mut().zip(data) {
            *a += weight * g as f64;
        }
    }

    /// Merge another shard's partial sums for the same layer into this
    /// one. Bins merge exactly under a shared Δ; a Δ mismatch folds the
    /// incoming shard dense (one dequantize pass), so the result is
    /// always well-defined.
    pub fn merge(&mut self, mut other: LayerBinSum) -> crate::Result<()> {
        anyhow::ensure!(
            self.numel == other.numel,
            "bin-sum merge: layer size {} != {}",
            self.numel,
            other.numel
        );
        let deltas_clash = self.bin_frames > 0
            && other.bin_frames > 0
            && !self.demoted
            && !other.demoted
            && self.delta != other.delta;
        if deltas_clash || self.demoted {
            other.demote();
        } else if other.demoted {
            self.demote();
        }
        if other.bin_frames > 0 && !other.demoted {
            self.delta = other.delta;
        }
        if !other.bins.is_empty() {
            if self.bins.is_empty() {
                self.bins = vec![0; self.numel];
            }
            for (a, b) in self.bins.iter_mut().zip(&other.bins) {
                *a += b;
            }
            self.bin_bound = self.bin_bound.saturating_add(other.bin_bound);
            if self.bin_bound >= BIN_OVERFLOW_GUARD {
                // Past the guard: carry the merged sums in f64 from now
                // on (the sums themselves are still exact here).
                if self.bins_f.is_empty() {
                    self.bins_f = vec![0.0; self.numel];
                }
                for (a, b) in self.bins_f.iter_mut().zip(&self.bins) {
                    *a += *b as f64;
                }
                self.bins = Vec::new();
                self.bin_bound = 0;
            }
        }
        if !other.bins_f.is_empty() {
            if self.bins_f.is_empty() {
                self.bins_f = vec![0.0; self.numel];
            }
            for (a, b) in self.bins_f.iter_mut().zip(&other.bins_f) {
                *a += b;
            }
        }
        if !other.pred.is_empty() {
            if self.pred.is_empty() {
                self.pred = vec![0.0; self.numel];
            }
            for (a, b) in self.pred.iter_mut().zip(&other.pred) {
                *a += b;
            }
        }
        if !other.dense.is_empty() {
            let dense = self.ensure_dense();
            for (a, b) in dense.iter_mut().zip(&other.dense) {
                *a += b;
            }
        }
        self.bin_frames += other.bin_frames;
        self.dense_frames += other.dense_frames;
        self.demoted |= other.demoted;
        self.dequant_passes += other.dequant_passes;
        Ok(())
    }

    /// The single dequantize-and-divide: fold bins, predictions and the
    /// dense side together and scale by `inv_w`. Consumes the layer and
    /// reports (total, dequantize passes incl. the final fold).
    fn finish(self, inv_w: f64) -> (Vec<f32>, usize) {
        let mut passes = self.dequant_passes;
        let two_delta = 2.0 * self.delta;
        let has_bins = !self.bins.is_empty() || !self.bins_f.is_empty();
        if has_bins {
            passes += 1;
        }
        let n = self.numel;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let b = self.bins.get(i).copied().unwrap_or(0) as f64
                + self.bins_f.get(i).copied().unwrap_or(0.0);
            let total = two_delta * b
                + self.pred.get(i).copied().unwrap_or(0.0)
                + self.dense.get(i).copied().unwrap_or(0.0);
            out.push((total * inv_w) as f32);
        }
        (out, passes)
    }

    /// Heap bytes held by the accumulators — the peak-memory proxy the
    /// topology benches report (empty vectors cost nothing; that is the
    /// point of the lazy allocation).
    pub fn approx_bytes(&self) -> usize {
        self.bins.len() * 8 + self.bins_f.len() * 8 + self.pred.len() * 8 + self.dense.len() * 8
    }

    /// Serialize the partial sums — the edge→root exchange format.
    /// Pairs with [`LayerBinSum::read_wire`].
    pub fn write_wire(&self, w: &mut BlobWriter) {
        w.put_u32(self.numel as u32);
        w.put_f64(self.delta);
        w.put_u8(self.demoted as u8);
        w.put_u64(self.bin_bound as u64);
        w.put_u32(self.bin_frames as u32);
        w.put_u32(self.dense_frames as u32);
        w.put_u32(self.dequant_passes as u32);
        w.put_i64_slice(&self.bins);
        w.put_f64_slice(&self.bins_f);
        w.put_f64_slice(&self.pred);
        w.put_f64_slice(&self.dense);
    }

    /// Deserialize one layer's partial sums, validating every shape
    /// invariant before the value can reach [`LayerBinSum::merge`].
    pub fn read_wire(r: &mut BlobReader) -> crate::Result<LayerBinSum> {
        let numel = r.get_u32()? as usize;
        let delta = r.get_f64()?;
        anyhow::ensure!(
            delta.is_finite() && delta >= 0.0,
            "bin-sum wire: Δ {delta} not finite-nonnegative"
        );
        let demoted = match r.get_u8()? {
            0 => false,
            1 => true,
            t => anyhow::bail!("bin-sum wire: bad demoted flag {t}"),
        };
        let bin_bound = r.get_u64()? as i64;
        anyhow::ensure!(bin_bound >= 0, "bin-sum wire: negative bin bound");
        let bin_frames = r.get_u32()? as usize;
        let dense_frames = r.get_u32()? as usize;
        let dequant_passes = r.get_u32()? as usize;
        let bins = r.get_i64_vec()?;
        let bins_f = r.get_f64_vec()?;
        let pred = r.get_f64_vec()?;
        let dense = r.get_f64_vec()?;
        let lens = [
            ("bins", bins.len()),
            ("bins_f", bins_f.len()),
            ("pred", pred.len()),
            ("dense", dense.len()),
        ];
        for (name, len) in lens {
            anyhow::ensure!(
                len == 0 || len == numel,
                "bin-sum wire: {name} has {len} elements, layer has {numel}"
            );
        }
        Ok(LayerBinSum {
            numel,
            delta,
            bins,
            bins_f,
            pred,
            dense,
            bin_frames,
            dense_frames,
            demoted,
            bin_bound,
            dequant_passes,
        })
    }
}

/// What one aggregation round did, per layer route (feeds
/// `RoundStats`/BENCH reporting).
#[derive(Debug, Clone, Default)]
pub struct AggReport {
    /// Layers finished entirely on the integer-bin route.
    pub binsum_layers: usize,
    /// Layers finished entirely on the dense f32 route.
    pub exact_layers: usize,
    /// Layers that saw both (incl. Δ-mismatch demotions).
    pub mixed_layers: usize,
    /// Total dequantize passes performed (the binsum invariant is one
    /// per bin-routed layer per round; demotions add theirs honestly).
    pub dequant_passes: usize,
    /// Wall-clock of the `finish` fold (filled by the server).
    pub finish_time: std::time::Duration,
}

impl AggReport {
    /// Report for a round aggregated wholly on the classic dense path.
    pub fn all_exact(layers: usize) -> Self {
        AggReport { exact_layers: layers, ..Default::default() }
    }
}

/// Streaming integer-bin FedAvg: the compressed-domain twin of
/// [`crate::fl::aggregate::FedAvg`]. Contributions are all-or-nothing —
/// a malformed frame set returns `Err` and leaves the sums untouched.
#[derive(Default)]
pub struct BinAggregator {
    layers: Vec<LayerBinSum>,
    total_weight: f64,
}

impl BinAggregator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Weight mass absorbed so far.
    pub fn weight(&self) -> f64 {
        self.total_weight
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Validate one client's frame set against the accumulated shape:
    /// layer count, element counts, escape-stream consistency and Δ
    /// sanity — all *before* any mutation, so a rejected contribution
    /// is dropped whole (mirroring `FedAvg::add`).
    fn validate(&self, frames: &[BinFrame], weight: f64) -> crate::Result<()> {
        anyhow::ensure!(
            weight.is_finite() && weight >= 0.0,
            "bin aggregation: bad weight {weight}"
        );
        if !self.layers.is_empty() {
            anyhow::ensure!(
                frames.len() == self.layers.len(),
                "bin aggregation: {} layers, expected {}",
                frames.len(),
                self.layers.len()
            );
        }
        for (i, f) in frames.iter().enumerate() {
            if let Some(acc) = self.layers.get(i) {
                anyhow::ensure!(
                    f.numel() == acc.numel(),
                    "bin aggregation: layer {i} has {} elements, expected {}",
                    f.numel(),
                    acc.numel()
                );
            }
            if let BinFrame::Bins { codes, escapes, pred, delta } = f {
                anyhow::ensure!(
                    delta.is_finite() && *delta > 0.0,
                    "bin aggregation: layer {i} Δ {delta} not positive-finite"
                );
                anyhow::ensure!(
                    pred.is_empty() || pred.len() == codes.len(),
                    "bin aggregation: layer {i} pred len {} != {}",
                    pred.len(),
                    codes.len()
                );
                let escaped = count_escapes(codes);
                anyhow::ensure!(
                    escaped == escapes.len(),
                    "bin aggregation: layer {i} has {escaped} escape codes, {} values",
                    escapes.len()
                );
            }
        }
        Ok(())
    }

    /// Absorb one client's decoded frame set with the given weight.
    pub fn add(&mut self, frames: &[BinFrame], weight: f64) -> crate::Result<()> {
        self.validate(frames, weight)?;
        if self.layers.is_empty() {
            self.layers = frames.iter().map(|f| LayerBinSum::new(f.numel())).collect();
        }
        for (acc, f) in self.layers.iter_mut().zip(frames) {
            match f {
                BinFrame::Bins { codes, escapes, pred, delta } => {
                    acc.add_bins(codes, escapes, pred, *delta, weight)
                }
                BinFrame::Dense(layer) => acc.add_dense(&layer.data, weight),
            }
        }
        self.total_weight += weight;
        Ok(())
    }

    /// Merge another aggregator's partial sums (shard exchange). Both
    /// sides must have seen the same model shape (or be empty).
    pub fn merge(&mut self, other: BinAggregator) -> crate::Result<()> {
        if other.layers.is_empty() {
            return Ok(());
        }
        if self.layers.is_empty() {
            self.layers = other.layers;
            self.total_weight = other.total_weight;
            return Ok(());
        }
        anyhow::ensure!(
            self.layers.len() == other.layers.len(),
            "bin-sum merge: {} layers vs {}",
            self.layers.len(),
            other.layers.len()
        );
        for (acc, o) in self.layers.iter_mut().zip(other.layers) {
            acc.merge(o)?;
        }
        self.total_weight += other.total_weight;
        Ok(())
    }

    /// Finish the round: one dequantize-and-divide per layer. Returns
    /// the weighted mean per layer (empty if nothing was absorbed, like
    /// `FedAvg::mean`) and the route report.
    pub fn finish(self) -> (Vec<Vec<f32>>, AggReport) {
        let inv_w = if self.total_weight > 0.0 { 1.0 / self.total_weight } else { 0.0 };
        let mut report = AggReport::default();
        let mut mean = Vec::with_capacity(self.layers.len());
        for layer in self.layers {
            match layer.route() {
                AggRoute::Binsum => report.binsum_layers += 1,
                AggRoute::Exact => report.exact_layers += 1,
                AggRoute::Mixed => report.mixed_layers += 1,
            }
            let (out, passes) = layer.finish(inv_w);
            report.dequant_passes += passes;
            mean.push(out);
        }
        (mean, report)
    }

    /// Heap bytes held across all layer accumulators (peak-memory
    /// proxy).
    pub fn approx_bytes(&self) -> usize {
        self.layers.iter().map(LayerBinSum::approx_bytes).sum()
    }

    /// Serialize the whole partial aggregate for the edge→root push.
    pub fn write_wire(&self, w: &mut BlobWriter) {
        w.put_f64(self.total_weight);
        w.put_u32(self.layers.len() as u32);
        for layer in &self.layers {
            layer.write_wire(w);
        }
    }

    /// Deserialize an aggregate pushed by an edge, rejecting malformed
    /// input before it can reach [`BinAggregator::merge`].
    pub fn read_wire(r: &mut BlobReader) -> crate::Result<BinAggregator> {
        let total_weight = r.get_f64()?;
        anyhow::ensure!(
            total_weight.is_finite() && total_weight >= 0.0,
            "bin-sum wire: bad total weight {total_weight}"
        );
        let n = r.get_u32()? as usize;
        anyhow::ensure!(n <= 65_536, "bin-sum wire: implausible layer count {n}");
        let mut layers = Vec::with_capacity(n);
        for _ in 0..n {
            layers.push(LayerBinSum::read_wire(r)?);
        }
        Ok(BinAggregator { layers, total_weight })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::aggregate::FedAvg;
    use crate::tensor::{LayerMeta, ModelGrad};

    fn dequant(codes: &[i32], escapes: &[f32], pred: &[f32], delta: f64) -> Vec<f32> {
        let mut esc = escapes.iter();
        codes
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                if c == ESCAPE_CODE {
                    *esc.next().unwrap()
                } else {
                    let p = pred.get(i).copied().unwrap_or(0.0);
                    p + (2.0 * delta * c as f64) as f32
                }
            })
            .collect()
    }

    fn dense_model(vals: &[f32]) -> ModelGrad {
        ModelGrad {
            layers: vec![LayerGrad::new(LayerMeta::other("x", vals.len()), vals.to_vec())],
        }
    }

    #[test]
    fn binsum_matches_dense_reference() {
        let delta = 1e-3f64;
        let clients: Vec<(Vec<i32>, Vec<f32>, f64)> = vec![
            (vec![3, -7, 0, ESCAPE_CODE, 12], vec![0.777], 2.0),
            (vec![-1, 4, 9, 2, -6], vec![], 5.0),
            (vec![0, 0, ESCAPE_CODE, ESCAPE_CODE, 1], vec![-0.25, 1.5], 1.0),
        ];
        let mut agg = BinAggregator::new();
        let mut reference = FedAvg::new();
        for (codes, escapes, w) in &clients {
            let frame = BinFrame::Bins {
                codes: codes.clone(),
                escapes: escapes.clone(),
                pred: Vec::new(),
                delta,
            };
            agg.add(std::slice::from_ref(&frame), *w).unwrap();
            reference.add(&dense_model(&dequant(codes, escapes, &[], delta)), *w).unwrap();
        }
        let (mean, report) = agg.finish();
        let want = reference.mean();
        assert_eq!(report.binsum_layers, 1);
        assert_eq!(report.exact_layers + report.mixed_layers, 0);
        assert_eq!(report.dequant_passes, 1);
        for (a, b) in mean[0].iter().zip(&want[0]) {
            assert!((a - b).abs() <= 1e-6 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn prediction_sums_participate() {
        let delta = 5e-4f64;
        // Nonzero prediction under the escape slot (index 2 of codes_a):
        // escapes reconstruct exactly, so that prediction must NOT land
        // in the sums.
        let pred_a = vec![0.5f32, -0.25, 0.7];
        let pred_b = vec![0.1f32, 0.1, 0.1];
        let codes_a = vec![2, -3, ESCAPE_CODE];
        let codes_b = vec![0, 8, -8];
        let mut agg = BinAggregator::new();
        let mut reference = FedAvg::new();
        for (codes, escapes, pred, w) in [
            (&codes_a, vec![1.25f32], &pred_a, 3.0),
            (&codes_b, vec![], &pred_b, 2.0),
        ] {
            let frame = BinFrame::Bins {
                codes: codes.clone(),
                escapes: escapes.clone(),
                pred: pred.clone(),
                delta,
            };
            agg.add(std::slice::from_ref(&frame), w).unwrap();
            reference.add(&dense_model(&dequant(codes, &escapes, pred, delta)), w).unwrap();
        }
        let (mean, _) = agg.finish();
        let want = reference.mean();
        for (a, b) in mean[0].iter().zip(&want[0]) {
            assert!((a - b).abs() <= 1e-6 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn delta_mismatch_demotes_to_mixed_route() {
        let codes = vec![10, -10, 5];
        let f1 = BinFrame::Bins { codes: codes.clone(), escapes: vec![], pred: vec![], delta: 1e-3 };
        let f2 = BinFrame::Bins { codes: codes.clone(), escapes: vec![], pred: vec![], delta: 2e-3 };
        let mut agg = BinAggregator::new();
        agg.add(std::slice::from_ref(&f1), 1.0).unwrap();
        agg.add(std::slice::from_ref(&f2), 3.0).unwrap();
        let mut reference = FedAvg::new();
        reference.add(&dense_model(&dequant(&codes, &[], &[], 1e-3)), 1.0).unwrap();
        reference.add(&dense_model(&dequant(&codes, &[], &[], 2e-3)), 3.0).unwrap();
        let (mean, report) = agg.finish();
        let want = reference.mean();
        assert_eq!(report.mixed_layers, 1);
        assert_eq!(report.binsum_layers, 0);
        // Demotion fold + the incoming frame's dense dequantize; no
        // final fold (bins are empty after the demotion).
        assert_eq!(report.dequant_passes, 2);
        for (a, b) in mean[0].iter().zip(&want[0]) {
            assert!((a - b).abs() <= 1e-6 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn dense_and_bins_mix_per_layer() {
        // Layer 0 gets bins from one client and dense from another;
        // layer 1 is dense from both.
        let delta = 1e-3;
        let c0 = vec![
            BinFrame::Bins { codes: vec![4, -4], escapes: vec![], pred: vec![], delta },
            BinFrame::Dense(LayerGrad::new(LayerMeta::other("b", 2), vec![1.0, 2.0])),
        ];
        let c1 = vec![
            BinFrame::Dense(LayerGrad::new(LayerMeta::other("a", 2), vec![0.5, 0.5])),
            BinFrame::Dense(LayerGrad::new(LayerMeta::other("b", 2), vec![-1.0, 0.0])),
        ];
        let mut agg = BinAggregator::new();
        agg.add(&c0, 1.0).unwrap();
        agg.add(&c1, 1.0).unwrap();
        let (mean, report) = agg.finish();
        assert_eq!(report.mixed_layers, 1);
        assert_eq!(report.exact_layers, 1);
        let d = (2.0 * delta) as f32;
        assert!((mean[0][0] - (4.0 * d + 0.5) / 2.0).abs() < 1e-6);
        assert!((mean[1][0] - 0.0).abs() < 1e-6);
        assert!((mean[1][1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn non_integral_weights_use_f64_bins_exactly() {
        let delta = 1e-2;
        let codes = vec![100, -100, 7];
        let mut agg = BinAggregator::new();
        let mut reference = FedAvg::new();
        for w in [0.5, 1.75, 2.0] {
            let f = BinFrame::Bins { codes: codes.clone(), escapes: vec![], pred: vec![], delta };
            agg.add(std::slice::from_ref(&f), w).unwrap();
            reference.add(&dense_model(&dequant(&codes, &[], &[], delta)), w).unwrap();
        }
        let (mean, report) = agg.finish();
        assert_eq!(report.binsum_layers, 1);
        let want = reference.mean();
        for (a, b) in mean[0].iter().zip(&want[0]) {
            assert!((a - b).abs() <= 1e-6 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn malformed_contributions_are_dropped_whole() {
        let delta = 1e-3;
        let good = BinFrame::Bins { codes: vec![1, 2], escapes: vec![], pred: vec![], delta };
        let mut agg = BinAggregator::new();
        agg.add(std::slice::from_ref(&good), 1.0).unwrap();
        // Wrong layer count.
        assert!(agg.add(&[], 1.0).is_err());
        // Wrong element count.
        let short = BinFrame::Bins { codes: vec![1], escapes: vec![], pred: vec![], delta };
        assert!(agg.add(std::slice::from_ref(&short), 1.0).is_err());
        // Escape stream inconsistent with the codes.
        let bad_esc =
            BinFrame::Bins { codes: vec![ESCAPE_CODE, 2], escapes: vec![], pred: vec![], delta };
        assert!(agg.add(std::slice::from_ref(&bad_esc), 1.0).is_err());
        // Bad Δ and bad weight.
        let bad_delta = BinFrame::Bins { codes: vec![1, 2], escapes: vec![], pred: vec![], delta: 0.0 };
        assert!(agg.add(std::slice::from_ref(&bad_delta), 1.0).is_err());
        assert!(agg.add(std::slice::from_ref(&good), f64::NAN).is_err());
        // The one good contribution is all that survived: with total
        // weight 1 the mean equals that contribution exactly.
        assert_eq!(agg.weight(), 1.0);
        let (mean, _) = agg.finish();
        assert_eq!(mean.len(), 1);
        assert!((mean[0][0] - (2.0 * delta) as f32).abs() < 1e-9);
        assert!((mean[0][1] - (4.0 * delta) as f32).abs() < 1e-9);
    }

    #[test]
    fn shard_merge_matches_single_aggregator() {
        let delta = 1e-3;
        let mk = |codes: Vec<i32>, escapes: Vec<f32>| BinFrame::Bins {
            codes,
            escapes,
            pred: vec![],
            delta,
        };
        let a1 = vec![mk(vec![5, ESCAPE_CODE, -2], vec![0.9])];
        let a2 = vec![mk(vec![1, 1, 1], vec![])];
        let b1 = vec![mk(vec![-4, 0, 8], vec![])];
        // One aggregator over all three...
        let mut whole = BinAggregator::new();
        whole.add(&a1, 2.0).unwrap();
        whole.add(&a2, 1.0).unwrap();
        whole.add(&b1, 3.0).unwrap();
        // ...vs two shards merged.
        let mut shard_a = BinAggregator::new();
        shard_a.add(&a1, 2.0).unwrap();
        shard_a.add(&a2, 1.0).unwrap();
        let mut shard_b = BinAggregator::new();
        shard_b.add(&b1, 3.0).unwrap();
        shard_a.merge(shard_b).unwrap();
        let (want, wrep) = whole.finish();
        let (got, grep) = shard_a.finish();
        assert_eq!(want, got, "shard merge must be exact (integer bins)");
        assert_eq!(wrep.binsum_layers, grep.binsum_layers);
    }

    #[test]
    fn wire_roundtrip_preserves_partial_sums() {
        let delta = 1e-3;
        // A deliberately messy aggregate: bins + escapes + pred on one
        // layer, dense on the other, non-integral weight in the mix.
        let c0 = vec![
            BinFrame::Bins {
                codes: vec![4, ESCAPE_CODE, -2],
                escapes: vec![0.5],
                pred: vec![0.1, 0.2, 0.3],
                delta,
            },
            BinFrame::Dense(LayerGrad::new(LayerMeta::other("b", 2), vec![1.0, -2.0])),
        ];
        let mut agg = BinAggregator::new();
        agg.add(&c0, 2.0).unwrap();
        agg.add(&c0, 1.5).unwrap();
        let mut w = BlobWriter::new();
        agg.write_wire(&mut w);
        let bytes = w.into_bytes();
        let mut r = BlobReader::new(&bytes);
        let back = BinAggregator::read_wire(&mut r).unwrap();
        assert_eq!(r.remaining(), 0);
        assert_eq!(back.weight(), agg.weight());
        assert_eq!(back.approx_bytes(), agg.approx_bytes());
        let (want, wrep) = agg.finish();
        let (got, grep) = back.finish();
        assert_eq!(want, got, "wire roundtrip must be bit-exact");
        assert_eq!(wrep.dequant_passes, grep.dequant_passes);
        assert_eq!(wrep.binsum_layers, grep.binsum_layers);
    }

    #[test]
    fn wire_rejects_malformed_input() {
        // Truncation at every prefix length must error, never panic.
        let f = BinFrame::Bins { codes: vec![1, 2], escapes: vec![], pred: vec![], delta: 1e-3 };
        let mut agg = BinAggregator::new();
        agg.add(std::slice::from_ref(&f), 1.0).unwrap();
        let mut w = BlobWriter::new();
        agg.write_wire(&mut w);
        let bytes = w.into_bytes();
        for cut in 0..bytes.len() {
            assert!(BinAggregator::read_wire(&mut BlobReader::new(&bytes[..cut])).is_err());
        }
        // A vector length that disagrees with numel is rejected.
        let mut w = BlobWriter::new();
        w.put_u32(3); // numel
        w.put_f64(1e-3);
        w.put_u8(0);
        w.put_u64(0);
        w.put_u32(1);
        w.put_u32(0);
        w.put_u32(0);
        w.put_i64_slice(&[1, 2]); // 2 != 3
        w.put_f64_slice(&[]);
        w.put_f64_slice(&[]);
        w.put_f64_slice(&[]);
        let bytes = w.into_bytes();
        assert!(LayerBinSum::read_wire(&mut BlobReader::new(&bytes)).is_err());
    }

    #[test]
    fn empty_aggregator_finishes_empty() {
        let (mean, report) = BinAggregator::new().finish();
        assert!(mean.is_empty());
        assert_eq!(report.dequant_passes, 0);
    }

    #[test]
    fn overflow_guard_demotes_to_f64_bins() {
        // A weight big enough that a second frame would cross the i64
        // guard: the aggregator must keep accepting frames and stay
        // correct (f64 carries the sums).
        let huge_w = (1u64 << 31) as f64 - 1.0;
        let codes = vec![3, -3];
        let f = BinFrame::Bins { codes: codes.clone(), escapes: vec![], pred: vec![], delta: 1e-3 };
        let mut agg = BinAggregator::new();
        for _ in 0..4 {
            agg.add(std::slice::from_ref(&f), huge_w).unwrap();
        }
        let (mean, report) = agg.finish();
        assert_eq!(report.binsum_layers, 1);
        // Mean of identical contributions is the contribution itself.
        assert!((mean[0][0] - (2.0 * 1e-3 * 3.0) as f32).abs() < 1e-7);
    }
}
