//! The paper's contribution: a gradient-aware error-bounded lossy
//! compressor (EBLC) following the standard four-stage pipeline
//! (prediction → quantization → entropy coding → lossless), with the
//! prediction stage replaced by the cross-round magnitude predictor
//! (Alg. 1) and the oscillation / kernel-consistency sign predictor
//! (Alg. 2 + the Fig. 8 two-level bitmap).

pub mod autotune;
pub mod blob;
pub mod fused;
pub mod huffman;
pub mod lossless;
pub mod lz;
pub mod pipeline;
pub mod predictor;
pub mod quant;
pub mod state;

use crate::tensor::{LayerMeta, ModelGrad};

/// A round-stateful gradient codec. The compressor side lives on the
/// client, the decompressor side on the server; both mutate internal
/// predictor state every round and must stay synchronized through the
/// payload alone (paper §4.1).
pub trait GradientCodec: Send {
    /// Compress one round's gradients, updating internal state to the
    /// reconstructed values.
    fn compress(&mut self, grads: &ModelGrad) -> crate::Result<Vec<u8>>;

    /// Decompress one round's payload, updating internal state.
    fn decompress(&mut self, payload: &[u8], metas: &[LayerMeta]) -> crate::Result<ModelGrad>;

    /// Human-readable codec name for reports.
    fn name(&self) -> &'static str;

    /// Reset all cross-round state (new training run).
    fn reset(&mut self);
}

/// Compression-ratio bookkeeping shared by benches and the FL metrics.
#[derive(Debug, Clone, Copy, Default)]
pub struct CompressionStats {
    pub raw_bytes: usize,
    pub compressed_bytes: usize,
}

impl CompressionStats {
    pub fn ratio(&self) -> f64 {
        if self.compressed_bytes == 0 {
            0.0
        } else {
            self.raw_bytes as f64 / self.compressed_bytes as f64
        }
    }
    pub fn add(&mut self, raw: usize, compressed: usize) {
        self.raw_bytes += raw;
        self.compressed_bytes += compressed;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ratio() {
        let mut s = CompressionStats::default();
        s.add(100, 10);
        s.add(100, 10);
        assert!((s.ratio() - 10.0).abs() < 1e-12);
        assert_eq!(CompressionStats::default().ratio(), 0.0);
    }
}
