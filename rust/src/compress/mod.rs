//! The paper's contribution: a gradient-aware error-bounded lossy
//! compressor (EBLC) following the standard four-stage pipeline
//! (prediction → quantization → entropy coding → lossless), with the
//! prediction stage replaced by the cross-round magnitude predictor
//! (Alg. 1) and the oscillation / kernel-consistency sign predictor
//! (Alg. 2 + the Fig. 8 two-level bitmap).
//!
//! Codecs speak the **session/frame API**: one self-delimiting
//! [`Frame`] per layer ([`GradientCodec::encode_layer`] /
//! [`GradientCodec::decode_frame`]), so large models can compress layers
//! in parallel and the FL transport can stream frames while later layers
//! are still encoding. The whole-model `compress`/`decompress` entry
//! points are provided blanket adapters over the same frames. Codecs are
//! constructed from a [`spec::CodecSpec`] descriptor (see that module for
//! the grammar and registry).

pub mod agg;
pub mod autotune;
pub mod blob;
pub mod control;
pub mod downlink;
pub mod engine;
pub mod entropy;
pub mod frame;
pub mod fused;
pub mod huffman;
pub mod kernels;
pub mod lossless;
pub mod lz;
pub mod pipeline;
pub mod predictor;
pub mod quant;
pub mod session;
pub mod spec;
pub mod state;
pub mod store;

pub use agg::{AggReport, AggRoute, BinAggregator, BinFrame, LayerBinSum};
pub use control::{EbController, EbPlan, EbSignals, EbcSpec};
pub use downlink::{DownlinkCodec, DownlinkMirror};
pub use engine::CodecEngine;
pub use entropy::EntropyCoder;
pub use frame::{CodecReport, Frame, LayerReport};
pub use state::{ClientState, StateEpoch};
pub use store::{ClientId, StateStore};

use crate::tensor::{LayerGrad, LayerMeta, ModelGrad};

/// A round-stateful gradient codec. The compressor side lives on the
/// client, the decompressor side on the server; both mutate internal
/// predictor state every round and must stay synchronized through the
/// payload alone (paper §4.1).
///
/// Implementors provide the per-layer frame primitives; the whole-model
/// `compress`/`decompress`/`*_with_report` methods are blanket adapters
/// that every call site may keep using.
pub trait GradientCodec: Send {
    /// Start a round session for an `n_layers` model (both sides call
    /// this before the first `encode_layer`/`decode_frame` of a round;
    /// allocates per-layer state where the codec keeps any).
    fn begin(&mut self, n_layers: usize) -> crate::Result<()> {
        let _ = n_layers;
        Ok(())
    }

    /// Encode layer `idx` into a self-delimiting frame.
    fn encode_layer(&mut self, idx: usize, layer: &LayerGrad) -> crate::Result<Frame>;

    /// Decode one frame (the frame's `index` selects per-layer state).
    fn decode_frame(
        &mut self,
        frame: &Frame,
        meta: &LayerMeta,
    ) -> crate::Result<(LayerGrad, LayerReport)>;

    /// Encode a whole model to frames. The default encodes sequentially;
    /// codecs with independent per-layer state override this to encode
    /// layers in parallel on [`crate::util::threadpool`].
    fn encode_model(&mut self, grads: &ModelGrad) -> crate::Result<Vec<Frame>> {
        self.begin(grads.layers.len())?;
        grads
            .layers
            .iter()
            .enumerate()
            .map(|(idx, layer)| self.encode_layer(idx, layer))
            .collect()
    }

    /// Human-readable codec name for reports.
    fn name(&self) -> &'static str;

    /// Reset all cross-round state (new training run, or a
    /// `StateResync` cold-start ordered by the server).
    fn reset(&mut self);

    /// Adopt a server-broadcast error-bound plan for the coming round
    /// (`ebc=` controllers, DESIGN.md §15). Codecs without a lossy
    /// quantizer ignore it — the plan only steers encode-side Δ choice,
    /// so a no-op here is always safe.
    fn apply_eb_plan(&mut self, plan: &control::EbPlan) {
        let _ = plan;
    }

    /// Fingerprint of the *mirrored* cross-round state — what the
    /// `StateCheck` handshake compares against the server's stored copy.
    /// Stateless codecs (and codecs whose only state is client-local,
    /// like error feedback's residual) report the cold fingerprint.
    fn state_fingerprint(&self) -> u64 {
        state::CodecState::default().fingerprint()
    }

    // ── Blanket whole-model adapters. ──

    /// Compress one round's gradients into a single payload.
    fn compress(&mut self, grads: &ModelGrad) -> crate::Result<Vec<u8>> {
        Ok(self.compress_with_report(grads)?.0)
    }

    /// Compress and return the unified per-layer report alongside.
    fn compress_with_report(
        &mut self,
        grads: &ModelGrad,
    ) -> crate::Result<(Vec<u8>, CodecReport)> {
        let frames = self.encode_model(grads)?;
        let report = CodecReport::from_frames(self.name(), &frames);
        Ok((frame::frames_to_payload(&frames), report))
    }

    /// Decompress one round's payload.
    fn decompress(&mut self, payload: &[u8], metas: &[LayerMeta]) -> crate::Result<ModelGrad> {
        Ok(self.decompress_with_report(payload, metas)?.0)
    }

    /// Decompress and return the unified per-layer report alongside.
    fn decompress_with_report(
        &mut self,
        payload: &[u8],
        metas: &[LayerMeta],
    ) -> crate::Result<(ModelGrad, CodecReport)> {
        let frames = frame::payload_to_frames(payload)?;
        anyhow::ensure!(
            frames.len() == metas.len(),
            "payload has {} layers, expected {}",
            frames.len(),
            metas.len()
        );
        let mut report = CodecReport::new(self.name());
        self.begin(metas.len())?;
        let mut decoded = Vec::with_capacity(frames.len());
        for (i, (f, meta)) in frames.iter().zip(metas).enumerate() {
            anyhow::ensure!(f.index as usize == i, "frame {} out of order ({})", i, f.index);
            let (layer, rep) = self.decode_frame(f, meta)?;
            report.push(rep);
            decoded.push(layer);
        }
        Ok((ModelGrad { layers: decoded }, report))
    }
}

/// Compression-ratio bookkeeping shared by benches and the FL metrics.
#[derive(Debug, Clone, Copy, Default)]
pub struct CompressionStats {
    pub raw_bytes: usize,
    pub compressed_bytes: usize,
}

impl CompressionStats {
    /// Raw/compressed ratio. An empty round (nothing sent, nothing to
    /// send) is a neutral 1.0, not a nonsensical 0.0.
    pub fn ratio(&self) -> f64 {
        if self.compressed_bytes == 0 {
            if self.raw_bytes == 0 {
                1.0
            } else {
                0.0
            }
        } else {
            self.raw_bytes as f64 / self.compressed_bytes as f64
        }
    }
    pub fn add(&mut self, raw: usize, compressed: usize) {
        self.raw_bytes += raw;
        self.compressed_bytes += compressed;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ratio() {
        let mut s = CompressionStats::default();
        s.add(100, 10);
        s.add(100, 10);
        assert!((s.ratio() - 10.0).abs() < 1e-12);
        // Empty accounting is neutral (CR 1), not 0.
        assert_eq!(CompressionStats::default().ratio(), 1.0);
        // Degenerate "raw but nothing compressed" stays 0.
        let s = CompressionStats { raw_bytes: 10, compressed_bytes: 0 };
        assert_eq!(s.ratio(), 0.0);
    }
}
