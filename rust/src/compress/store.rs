//! Keyed storage for cross-round predictor state — the server side of
//! the externalized-state redesign.
//!
//! The parameter server used to mirror **one codec object per client**
//! in a positionally indexed `Vec<Box<dyn GradientCodec>>`: O(clients ×
//! model) resident memory, no dropout/rejoin, no eviction. Now the
//! server runs a single stateless [`crate::compress::engine::CodecEngine`]
//! and checks each participant's [`ClientState`] in and out of a
//! [`StateStore`] keyed by stable [`ClientId`]:
//!
//! * [`ShardedMemStore`] — sharded in-memory map (per-shard `Mutex`, so
//!   concurrent decode workers on `util::threadpool` contend per shard,
//!   not globally), LRU eviction under a byte budget.
//! * [`DiskSpillStore`] — the same hot tier, but eviction serializes the
//!   cold state to disk via a compact **exact** record encoding and
//!   reloads it transparently on the client's next round.
//!
//! Eviction is *safe*, not silent: the stored [`StateEpoch`] disappears
//! with the state, so the next `StateCheck` handshake from that client
//! mismatches and both sides deterministically reset to the codec's
//! round-1 path (see `fl::server`).
//!
//! # Spill record format (`FGS3`)
//!
//! v3 of the record: the per-layer error-bound bits were added after the
//! predictor tag (the `ebc=` controllers make the bound a per-round,
//! per-layer quantity, and it is a fingerprint input), and the magic
//! bumped with it so a v2 (`FGS2`) record — like v1 (`FGS1`) before it —
//! fails the magic check outright instead of misparsing.
//!
//! ```text
//! magic  u32  "FGS3" (0x33534746 LE)
//! rounds u32  ┐ StateEpoch — uncompressed, so `epoch()` peeks the
//! fprint u64  ┘ header without decoding the body
//! body   bytes (lossless-backend container, zstd by default):
//!   n_layers u32, then per layer:
//!     flags  u8   bit0 = prev_recon present, bit1 = prev_prev_abs present
//!     pred   u8   magnitude-predictor selector tag (a fingerprint input,
//!                 so evict→reload under a different predictor config can
//!                 never alias; see `LayerState::pred`)
//!     eb     u32  canonical error-bound bits of the last lossy round
//!                 (`ErrorBound::state_bits`; 0 = never lossy-coded) —
//!                 same aliasing rule as `pred`, see `LayerState::eb`
//!     memory byte-planed f32s (length-prefixed)
//!     [prev_recon  byte-planed f32s]
//!     [prev_prev_abs byte-planed f32s]
//! ```
//!
//! Two compaction levers, both bit-exact (the evict→reload property test
//! demands fingerprint-identical round-trips, which rules out lossy
//! fixed-point re-quantization of the state):
//!
//! 1. **Derived-view elision** — `prev_abs` and `prev_sign` are pure
//!    functions of `prev_recon` (`|x|`, `sign(x)`), so they are never
//!    written; [`LayerState::rebuild_derived`] recomputes them on load.
//!    That alone drops 2 of the 5 per-layer buffers.
//! 2. **Byte-plane transposition** — f32 words are split into four byte
//!    planes (sign/exponent bytes land together), which the lossless
//!    backend compresses far better than interleaved words.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::blob::{BlobReader, BlobWriter};
use super::lossless::{self, Backend};
use super::state::{ClientState, LayerState, StateEpoch};

/// Stable client identity — the store key that replaced vector position.
/// Matches the `client_id` carried by every protocol message.
pub type ClientId = u32;

/// Occupancy snapshot of a store (benchmarked as the "state-memory
/// trajectory" of a run).
#[derive(Debug, Clone, Copy, Default)]
pub struct StoreStats {
    /// States resident in memory.
    pub resident_clients: usize,
    /// Bytes of resident state buffers.
    pub resident_bytes: usize,
    /// States currently spilled to disk (0 for memory-only stores).
    pub spilled_clients: usize,
    /// Bytes of spill records on disk.
    pub spilled_bytes: usize,
    /// Lifetime evictions from the hot tier (drops or spills).
    pub evictions: u64,
    /// Lifetime reloads from the spill tier.
    pub spill_loads: u64,
    /// Configured hot-tier byte budget (None = unbounded).
    pub budget_bytes: Option<usize>,
}

/// Keyed ownership of per-client mirror state. All methods take `&self`
/// (interior per-shard locking) so one store instance can serve
/// concurrent decode workers.
///
/// The access pattern is check-out/check-in: [`StateStore::take`]
/// removes the state for the duration of a round's decode,
/// [`StateStore::put`] returns it (possibly evicting others to fit the
/// budget). `take` of an absent/evicted client returns `Ok(None)` — the
/// caller cold-starts, which the epoch handshake makes safe.
pub trait StateStore: Send + Sync {
    /// Check out a client's state (removes it from the store).
    fn take(&self, client: ClientId) -> crate::Result<Option<ClientState>>;

    /// Check a client's state back in after a round's decode.
    fn put(&self, client: ClientId, state: ClientState) -> crate::Result<()>;

    /// Drop a client's state entirely (resync reset / permanent leave).
    fn remove(&self, client: ClientId) -> crate::Result<()>;

    /// Peek the stored epoch without materializing the full state.
    fn epoch(&self, client: ClientId) -> crate::Result<Option<StateEpoch>>;

    /// Current occupancy.
    fn stats(&self) -> StoreStats;
}

// ───────────────────────── spill record codec ─────────────────────────

const SPILL_MAGIC: u32 = u32::from_le_bytes(*b"FGS3");
const FLAG_RECON: u8 = 1;
const FLAG_PPREV: u8 = 2;

/// Split f32 words into four byte planes (all byte-0s, then byte-1s, …).
fn split_planes(v: &[f32]) -> Vec<u8> {
    let n = v.len();
    let mut out = vec![0u8; n * 4];
    for (i, x) in v.iter().enumerate() {
        let b = x.to_le_bytes();
        out[i] = b[0];
        out[n + i] = b[1];
        out[2 * n + i] = b[2];
        out[3 * n + i] = b[3];
    }
    out
}

/// Inverse of [`split_planes`].
fn join_planes(buf: &[u8]) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(buf.len() % 4 == 0, "plane buffer length {} not /4", buf.len());
    let n = buf.len() / 4;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        out.push(f32::from_le_bytes([buf[i], buf[n + i], buf[2 * n + i], buf[3 * n + i]]));
    }
    Ok(out)
}

/// Serialize a [`ClientState`] to the compact exact spill record.
pub fn encode_client_state(cs: &ClientState, backend: Backend) -> crate::Result<Vec<u8>> {
    let mut body = BlobWriter::new();
    body.put_u32(cs.codec.layers.len() as u32);
    for l in &cs.codec.layers {
        let mut flags = 0u8;
        if l.prev_recon.is_some() {
            flags |= FLAG_RECON;
        }
        if l.prev_prev_abs.is_some() {
            flags |= FLAG_PPREV;
        }
        body.put_u8(flags);
        body.put_u8(l.pred);
        body.put_u32(l.eb);
        body.put_bytes(&split_planes(&l.memory));
        if let Some(r) = &l.prev_recon {
            body.put_bytes(&split_planes(r));
        }
        if let Some(p) = &l.prev_prev_abs {
            body.put_bytes(&split_planes(p));
        }
    }
    let mut w = BlobWriter::new();
    w.put_u32(SPILL_MAGIC);
    w.put_u32(cs.epoch.rounds);
    w.put_u64(cs.epoch.fingerprint);
    w.put_bytes(&backend.compress(&body.into_bytes())?);
    Ok(w.into_bytes())
}

/// Deserialize a spill record back into a [`ClientState`] (bit-exact:
/// the decoded state fingerprints identically to the encoded one).
pub fn decode_client_state(buf: &[u8]) -> crate::Result<ClientState> {
    let mut r = BlobReader::new(buf);
    anyhow::ensure!(r.get_u32()? == SPILL_MAGIC, "bad spill record magic");
    let rounds = r.get_u32()?;
    let fingerprint = r.get_u64()?;
    let body = lossless::decompress(r.get_bytes()?)?;
    let mut b = BlobReader::new(&body);
    let n_layers = b.get_u32()? as usize;
    let mut layers = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        let flags = b.get_u8()?;
        let pred = b.get_u8()?;
        let eb = b.get_u32()?;
        let mut l =
            LayerState { pred, eb, memory: join_planes(b.get_bytes()?)?, ..Default::default() };
        if flags & FLAG_RECON != 0 {
            l.prev_recon = Some(join_planes(b.get_bytes()?)?);
        }
        if flags & FLAG_PPREV != 0 {
            l.prev_prev_abs = Some(join_planes(b.get_bytes()?)?);
        }
        l.rebuild_derived();
        layers.push(l);
    }
    let cs = ClientState {
        codec: super::state::CodecState { layers },
        epoch: StateEpoch { rounds, fingerprint },
    };
    anyhow::ensure!(
        cs.codec.fingerprint() == fingerprint,
        "spill record fingerprint mismatch (corrupt or stale record)"
    );
    Ok(cs)
}

/// Peek the epoch of a spill record without decompressing the body.
pub fn peek_spill_epoch(buf: &[u8]) -> crate::Result<StateEpoch> {
    let mut r = BlobReader::new(buf);
    anyhow::ensure!(r.get_u32()? == SPILL_MAGIC, "bad spill record magic");
    Ok(StateEpoch { rounds: r.get_u32()?, fingerprint: r.get_u64()? })
}

// ───────────────────────── sharded memory store ─────────────────────────

struct Entry {
    state: ClientState,
    bytes: usize,
    last_used: u64,
}

#[derive(Default)]
struct Shard {
    entries: HashMap<ClientId, Entry>,
    bytes: usize,
}

type EvictHook = Box<dyn Fn(ClientId, &ClientState) -> crate::Result<()> + Send + Sync>;

/// Sharded in-memory [`StateStore`] with LRU eviction under a byte
/// budget. Shard = `client_id % n_shards`, each behind its own `Mutex`,
/// so concurrent per-client decodes contend only within a shard. The
/// budget is split evenly across shards; each shard always admits at
/// least one resident state (a single state larger than the whole budget
/// is kept rather than thrashed).
pub struct ShardedMemStore {
    shards: Vec<Mutex<Shard>>,
    /// Per-shard byte budget (None = unbounded).
    shard_budget: Option<usize>,
    total_budget: Option<usize>,
    clock: AtomicU64,
    evictions: AtomicU64,
    /// Called with each evicted state *before* it is dropped (the spill
    /// store's hook persists it to disk).
    on_evict: Option<EvictHook>,
}

impl ShardedMemStore {
    /// `budget_bytes` caps resident state bytes across all shards
    /// (None = unbounded — the old one-mirror-per-client behavior, minus
    /// the per-client codec objects).
    pub fn new(n_shards: usize, budget_bytes: Option<usize>) -> Self {
        let n = n_shards.max(1);
        ShardedMemStore {
            shards: (0..n).map(|_| Mutex::new(Shard::default())).collect(),
            shard_budget: budget_bytes.map(|b| b.div_euclid(n).max(1)),
            total_budget: budget_bytes,
            clock: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            on_evict: None,
        }
    }

    /// Unbounded single-shard store (tests / small federations).
    pub fn unbounded() -> Self {
        Self::new(1, None)
    }

    fn with_evict_hook(mut self, hook: EvictHook) -> Self {
        self.on_evict = Some(hook);
        self
    }

    fn shard(&self, client: ClientId) -> &Mutex<Shard> {
        &self.shards[client as usize % self.shards.len()]
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    /// Evict LRU entries until the shard fits its budget (keeping at
    /// least one), spilling through the hook when configured.
    fn enforce_budget(&self, shard: &mut Shard) -> crate::Result<()> {
        let budget = match self.shard_budget {
            Some(b) => b,
            None => return Ok(()),
        };
        while shard.bytes > budget && shard.entries.len() > 1 {
            let victim = shard
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&id, _)| id)
                .expect("non-empty shard");
            let entry = shard.entries.remove(&victim).expect("victim present");
            shard.bytes -= entry.bytes;
            if let Some(hook) = &self.on_evict {
                hook(victim, &entry.state)?;
            }
            self.evictions.fetch_add(1, Ordering::Relaxed);
            crate::telemetry::STORE_EVICTIONS.inc();
        }
        Ok(())
    }
}

impl StateStore for ShardedMemStore {
    fn take(&self, client: ClientId) -> crate::Result<Option<ClientState>> {
        let mut shard = self.shard(client).lock().unwrap();
        let hit = shard.entries.remove(&client).map(|e| {
            shard.bytes -= e.bytes;
            e.state
        });
        match hit {
            Some(_) => crate::telemetry::STORE_HITS.inc(),
            None => crate::telemetry::STORE_MISSES.inc(),
        }
        Ok(hit)
    }

    fn put(&self, client: ClientId, state: ClientState) -> crate::Result<()> {
        let bytes = state.byte_size();
        let last_used = self.tick();
        let mut shard = self.shard(client).lock().unwrap();
        if let Some(old) = shard.entries.insert(client, Entry { state, bytes, last_used }) {
            shard.bytes -= old.bytes;
        }
        shard.bytes += bytes;
        self.enforce_budget(&mut shard)
    }

    fn remove(&self, client: ClientId) -> crate::Result<()> {
        let mut shard = self.shard(client).lock().unwrap();
        if let Some(e) = shard.entries.remove(&client) {
            shard.bytes -= e.bytes;
        }
        Ok(())
    }

    fn epoch(&self, client: ClientId) -> crate::Result<Option<StateEpoch>> {
        let shard = self.shard(client).lock().unwrap();
        Ok(shard.entries.get(&client).map(|e| e.state.epoch))
    }

    fn stats(&self) -> StoreStats {
        let mut s = StoreStats {
            budget_bytes: self.total_budget,
            evictions: self.evictions.load(Ordering::Relaxed),
            ..Default::default()
        };
        for shard in &self.shards {
            let shard = shard.lock().unwrap();
            s.resident_clients += shard.entries.len();
            s.resident_bytes += shard.bytes;
        }
        s
    }
}

// ───────────────────────── disk-spill store ─────────────────────────

#[derive(Clone, Copy)]
struct SpillMeta {
    epoch: StateEpoch,
    bytes: usize,
}

struct SpillTier {
    dir: PathBuf,
    index: Mutex<HashMap<ClientId, SpillMeta>>,
    spill_loads: AtomicU64,
}

impl SpillTier {
    fn path(&self, client: ClientId) -> PathBuf {
        self.dir.join(format!("client_{client}.fgs"))
    }

    fn write(&self, client: ClientId, state: &ClientState) -> crate::Result<()> {
        let record = encode_client_state(state, Backend::default())?;
        let meta = SpillMeta { epoch: state.epoch, bytes: record.len() };
        std::fs::write(self.path(client), &record)
            .map_err(|e| anyhow::anyhow!("spill write {}: {e}", self.path(client).display()))?;
        crate::telemetry::STORE_SPILL_BYTES.add(meta.bytes as u64);
        self.index.lock().unwrap().insert(client, meta);
        Ok(())
    }

    fn load(&self, client: ClientId) -> crate::Result<Option<ClientState>> {
        if self.index.lock().unwrap().remove(&client).is_none() {
            return Ok(None);
        }
        let path = self.path(client);
        let buf = std::fs::read(&path)
            .map_err(|e| anyhow::anyhow!("spill read {}: {e}", path.display()))?;
        let _ = std::fs::remove_file(&path);
        self.spill_loads.fetch_add(1, Ordering::Relaxed);
        crate::telemetry::STORE_SPILL_LOADS.inc();
        Ok(Some(decode_client_state(&buf)?))
    }

    fn remove(&self, client: ClientId) {
        if self.index.lock().unwrap().remove(&client).is_some() {
            let _ = std::fs::remove_file(self.path(client));
        }
    }
}

/// Two-tier [`StateStore`]: a budgeted [`ShardedMemStore`] hot tier whose
/// evictions serialize cold states to disk (`FGS3` records) instead of
/// dropping them. A spilled client's next round transparently reloads —
/// no resync reset, just disk latency.
pub struct DiskSpillStore {
    hot: ShardedMemStore,
    tier: Arc<SpillTier>,
}

impl DiskSpillStore {
    /// `dir` is created if missing; existing `*.fgs` files in it are
    /// ignored (records do not outlive the run that wrote them).
    pub fn new(
        dir: impl AsRef<Path>,
        n_shards: usize,
        hot_budget_bytes: usize,
    ) -> crate::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .map_err(|e| anyhow::anyhow!("create spill dir {}: {e}", dir.display()))?;
        let tier = Arc::new(SpillTier {
            dir,
            index: Mutex::new(HashMap::new()),
            spill_loads: AtomicU64::new(0),
        });
        let hook_tier = Arc::clone(&tier);
        let hot = ShardedMemStore::new(n_shards, Some(hot_budget_bytes))
            .with_evict_hook(Box::new(move |client, state| hook_tier.write(client, state)));
        Ok(DiskSpillStore { hot, tier })
    }
}

impl StateStore for DiskSpillStore {
    fn take(&self, client: ClientId) -> crate::Result<Option<ClientState>> {
        if let Some(state) = self.hot.take(client)? {
            return Ok(Some(state));
        }
        self.tier.load(client)
    }

    fn put(&self, client: ClientId, state: ClientState) -> crate::Result<()> {
        // A fresh hot copy supersedes any stale spill record.
        self.tier.remove(client);
        self.hot.put(client, state)
    }

    fn remove(&self, client: ClientId) -> crate::Result<()> {
        self.hot.remove(client)?;
        self.tier.remove(client);
        Ok(())
    }

    fn epoch(&self, client: ClientId) -> crate::Result<Option<StateEpoch>> {
        if let Some(e) = self.hot.epoch(client)? {
            return Ok(Some(e));
        }
        Ok(self.tier.index.lock().unwrap().get(&client).map(|m| m.epoch))
    }

    fn stats(&self) -> StoreStats {
        let mut s = self.hot.stats();
        let index = self.tier.index.lock().unwrap();
        s.spilled_clients = index.len();
        s.spilled_bytes = index.values().map(|m| m.bytes).sum();
        s.spill_loads = self.tier.spill_loads.load(Ordering::Relaxed);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::state::CodecState;

    fn warm_state(seed: u32, n: usize, rounds: u32) -> ClientState {
        let mut cs = ClientState::cold();
        cs.codec.ensure(2);
        for r in 0..rounds {
            let recon: Vec<f32> =
                (0..n).map(|i| ((seed + r) as f32 * 0.1) + i as f32 * 0.01 - 1.0).collect();
            cs.codec.layers[0].absorb(&recon);
            cs.codec.layers[0].memory = recon.iter().map(|x| x.abs() * 0.5).collect();
            cs.codec.layers[0].pred = 3; // pred=auto shaped this layer
            // eb=rel1e-2 shaped this layer (ErrorBound::state_bits).
            cs.codec.layers[0].eb = 0x3c23d70a;
            cs.codec.layers[1].absorb(&recon[..n / 2]);
            cs.epoch.advance(cs.codec.fingerprint());
        }
        cs
    }

    #[test]
    fn planes_roundtrip() {
        let v = vec![0.0f32, -1.5, f32::MIN_POSITIVE, 3.25e10, -0.0];
        assert_eq!(join_planes(&split_planes(&v)).unwrap().len(), v.len());
        for (a, b) in v.iter().zip(join_planes(&split_planes(&v)).unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(join_planes(&[1, 2, 3]).is_err());
    }

    #[test]
    fn spill_record_roundtrips_exactly() {
        let cs = warm_state(7, 200, 3);
        let rec = encode_client_state(&cs, Backend::default()).unwrap();
        assert_eq!(peek_spill_epoch(&rec).unwrap(), cs.epoch);
        let back = decode_client_state(&rec).unwrap();
        assert_eq!(back.epoch, cs.epoch);
        assert_eq!(back.codec.fingerprint(), cs.codec.fingerprint());
        // Derived views were elided yet recomputed bit-exactly; the
        // predictor tag and error-bound bits travel in the record.
        for (a, b) in cs.codec.layers.iter().zip(&back.codec.layers) {
            assert_eq!(a.prev_sign, b.prev_sign);
            assert_eq!(a.prev_abs, b.prev_abs);
            assert_eq!(a.prev_prev_abs, b.prev_prev_abs);
            assert_eq!(a.pred, b.pred);
            assert_eq!(a.eb, b.eb);
        }
        assert_eq!(back.codec.layers[0].pred, 3);
        assert_eq!(back.codec.layers[0].eb, 0x3c23d70a);
    }

    #[test]
    fn spill_record_is_compact() {
        // Elision + planes + zstd must beat naive raw f32 dumping of all
        // five views.
        let cs = warm_state(3, 4000, 2);
        let naive = cs.byte_size();
        let rec = encode_client_state(&cs, Backend::default()).unwrap();
        assert!(rec.len() < naive, "record {} vs naive {naive}", rec.len());
    }

    #[test]
    fn corrupt_spill_record_errors() {
        let cs = warm_state(1, 64, 1);
        let mut rec = encode_client_state(&cs, Backend::default()).unwrap();
        let last = rec.len() - 1;
        rec[last] ^= 0xFF;
        assert!(decode_client_state(&rec).is_err());
        assert!(decode_client_state(&[1, 2, 3]).is_err());
        assert!(peek_spill_epoch(&[9; 16]).is_err());
        // Records from older layouts fail the magic check outright
        // instead of misparsing field offsets: v1 ("FGS1", no predictor
        // tag) and v2 ("FGS2", no per-layer error-bound bits).
        for old_magic in [b"FGS1", b"FGS2"] {
            let mut old = encode_client_state(&cs, Backend::default()).unwrap();
            old[..4].copy_from_slice(old_magic);
            assert!(decode_client_state(&old).is_err());
            assert!(peek_spill_epoch(&old).is_err());
        }
    }

    #[test]
    fn mem_store_take_put_epoch() {
        let store = ShardedMemStore::new(4, None);
        assert!(store.take(5).unwrap().is_none());
        let cs = warm_state(5, 100, 2);
        let fp = cs.epoch;
        store.put(5, cs).unwrap();
        assert_eq!(store.epoch(5).unwrap(), Some(fp));
        assert_eq!(store.stats().resident_clients, 1);
        let got = store.take(5).unwrap().unwrap();
        assert_eq!(got.epoch, fp);
        assert_eq!(store.stats().resident_clients, 0);
        assert_eq!(store.stats().resident_bytes, 0);
    }

    #[test]
    fn mem_store_evicts_lru_under_budget() {
        let one = warm_state(0, 100, 1).byte_size();
        // Room for ~3 states in one shard.
        let store = ShardedMemStore::new(1, Some(one * 3 + one / 2));
        for id in 0..5u32 {
            store.put(id, warm_state(id, 100, 1)).unwrap();
        }
        let s = store.stats();
        assert!(s.resident_clients <= 3, "{} resident", s.resident_clients);
        assert!(s.resident_bytes <= one * 3 + one / 2);
        assert!(s.evictions >= 2);
        // LRU: the oldest puts (0, 1) are gone, the newest survive.
        assert!(store.epoch(0).unwrap().is_none());
        assert!(store.epoch(4).unwrap().is_some());
        // Touching an old survivor by re-putting protects it.
        let touched = store.take(2).unwrap().unwrap();
        store.put(2, touched).unwrap();
        store.put(9, warm_state(9, 100, 1)).unwrap();
        assert!(store.epoch(2).unwrap().is_some());
    }

    #[test]
    fn mem_store_keeps_oversized_single_state() {
        let store = ShardedMemStore::new(1, Some(8));
        store.put(1, warm_state(1, 100, 1)).unwrap();
        assert_eq!(store.stats().resident_clients, 1, "sole state must not thrash");
    }

    #[test]
    fn disk_store_spills_and_reloads_exactly() {
        let dir = std::env::temp_dir().join(format!("fedgec_spill_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let one = warm_state(0, 100, 2).byte_size();
        let store = DiskSpillStore::new(&dir, 1, one * 2).unwrap();
        let fps: Vec<StateEpoch> =
            (0..6u32).map(|id| warm_state(id, 100, 2).epoch).collect();
        for id in 0..6u32 {
            store.put(id, warm_state(id, 100, 2)).unwrap();
        }
        let s = store.stats();
        assert!(s.spilled_clients >= 3, "spilled {}", s.spilled_clients);
        assert!(s.resident_bytes <= one * 2 + one / 2);
        // Epoch peeks work from both tiers; reload is exact.
        for id in 0..6u32 {
            assert_eq!(store.epoch(id).unwrap(), Some(fps[id as usize]), "client {id}");
            let back = store.take(id).unwrap().unwrap_or_else(|| panic!("client {id}"));
            assert_eq!(back.epoch, fps[id as usize]);
            assert_eq!(back.codec.fingerprint(), fps[id as usize].fingerprint);
            store.put(id, back).unwrap();
        }
        assert!(store.stats().spill_loads >= 3);
        // remove() clears both tiers.
        for id in 0..6u32 {
            store.remove(id).unwrap();
        }
        let s = store.stats();
        assert_eq!(s.resident_clients + s.spilled_clients, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
