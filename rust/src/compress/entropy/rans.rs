//! From-scratch **N-way interleaved rANS** coder over quantization codes
//! — the asymmetric-numeral-system sibling of the canonical Huffman stage
//! (in the spirit of orz's entropy backend), built for the skewed,
//! near-geometric code distributions the gradient-aware predictor emits.
//!
//! Invariants (see DESIGN.md §7 and §12):
//!
//! * **Static table**: per-stream symbol frequencies normalized to sum
//!   exactly [`SCALE`] (= 1 << 12), every present symbol keeping
//!   frequency ≥ 1, so sub-bit code lengths are representable where
//!   Huffman must spend a whole bit.
//! * **32-bit state, byte renormalization**: each lane's state `x` stays
//!   in `[RANS_L, 256 · RANS_L)`; the encoder emits low bytes while
//!   `x ≥ ((RANS_L >> SCALE_BITS) << 8) · freq`, the decoder refills
//!   while `x < RANS_L`. All arithmetic fits u32 (checked in tests).
//! * **N-way interleave** (N ∈ {2, 4, 8}): symbol `i` goes to lane
//!   `i mod N`, giving the CPU N independent dependency chains. The
//!   encoder walks the stream backwards pushing bytes into a scratch
//!   buffer that is reversed once at the end; the decoder walks forwards,
//!   so its byte reads replay the encoder's pushes in exact reverse order
//!   and all lanes share one byte stream. Lanes are flushed in order
//!   N−1 .. 0 (LSB-first), so after the reversal the stream opens with
//!   lane 0's state big-endian, then lane 1's, and so on.
//! * Decoding must return every lane to exactly [`RANS_L`] — a free
//!   integrity check on the whole stream.
//!
//! Each lane width is its **own wire format** with its own mode byte
//! ([`MODE_RANS`] = 2-way, the frozen legacy format; [`MODE_RANS4`];
//! [`MODE_RANS8`]) — the widths are not bit-compatible with each other,
//! so a stream always decodes with the interleave it was encoded with.
//!
//! Serialized form (same layout for every width; only the mode byte and
//! the number of flushed states differ):
//!
//! ```text
//! u8 mode | u32 count | u32 n_syms | n_syms × (i32 sym, u16 freq)
//!         | u32 stream_len | stream (opens with N big-endian states)
//! ```

use crate::compress::kernels;
use crate::compress::quant::{code_histogram, FAST_RADIUS};
use std::collections::HashMap;

/// log2 of the frequency-normalization total.
pub const SCALE_BITS: u32 = 12;
/// Normalized frequencies sum to exactly this.
pub const SCALE: u32 = 1 << SCALE_BITS;
/// Lower bound of the normalized state interval.
pub const RANS_L: u32 = 1 << 23;
/// Alphabets larger than this cannot be normalized (each symbol needs
/// frequency ≥ 1); the caller falls back to Huffman/raw.
pub const MAX_SYMS: usize = SCALE as usize;
/// Leading mode byte of a serialized 2-way rANS stream (the frozen
/// legacy format — `ec=rans` golden bytes).
pub const MODE_RANS: u8 = 2;
/// Leading mode byte of a 4-way interleaved stream (`ec=rans4`).
pub const MODE_RANS4: u8 = 3;
/// Leading mode byte of an 8-way interleaved stream (`ec=rans8`).
pub const MODE_RANS8: u8 = 4;

/// Mode byte for an `N`-way stream. Compile-time error surface: any
/// monomorphization outside {2, 4, 8} panics in const evaluation.
const fn mode_for_lanes(n: usize) -> u8 {
    match n {
        2 => MODE_RANS,
        4 => MODE_RANS4,
        8 => MODE_RANS8,
        _ => panic!("unsupported rANS lane width"),
    }
}

/// Normalize histogram counts to sum exactly [`SCALE`], each ≥ 1.
/// Requires `hist.len() <= MAX_SYMS` and a nonzero total.
fn normalize_freqs(hist: &[(i32, u64)], total: u64) -> Vec<u32> {
    let k = hist.len();
    debug_assert!(k >= 1 && k <= MAX_SYMS && total > 0);
    let mut freqs: Vec<u32> = hist
        .iter()
        .map(|&(_, c)| ((c as u128 * SCALE as u128 / total as u128) as u32).max(1))
        .collect();
    let mut sum: i64 = freqs.iter().map(|&f| f as i64).sum();
    if sum != SCALE as i64 {
        // Settle the rounding drift on the most frequent symbols, where a
        // ±1 slot costs the least precision. Cycling the index list
        // terminates: while sum > SCALE (≥ k), some frequency exceeds 1.
        let mut idx: Vec<usize> = (0..k).collect();
        idx.sort_by(|&a, &b| hist[b].1.cmp(&hist[a].1).then(a.cmp(&b)));
        let mut i = 0usize;
        while sum > SCALE as i64 {
            let j = idx[i % k];
            if freqs[j] > 1 {
                freqs[j] -= 1;
                sum -= 1;
            }
            i += 1;
        }
        let mut i = 0usize;
        while sum < SCALE as i64 {
            freqs[idx[i % k]] += 1;
            sum += 1;
            i += 1;
        }
    }
    freqs
}

/// Encode a code stream against its own histogram (as produced by
/// [`code_histogram`] **from these same codes** — a mismatched histogram
/// panics, which is why this stays crate-internal) in the frozen 2-way
/// format. Returns `None` when rANS cannot apply (empty stream or
/// alphabet too large for the normalization).
pub(crate) fn encode_with_hist(codes: &[i32], hist: &[(i32, u64)]) -> Option<Vec<u8>> {
    encode_lanes::<2>(codes, hist)
}

/// [`encode_with_hist`] at a runtime-chosen lane width (2, 4 or 8) —
/// the per-width registry coders funnel through here.
pub(crate) fn encode_with_hist_lanes(
    codes: &[i32],
    hist: &[(i32, u64)],
    lanes: usize,
) -> Option<Vec<u8>> {
    match lanes {
        2 => encode_lanes::<2>(codes, hist),
        4 => encode_lanes::<4>(codes, hist),
        8 => encode_lanes::<8>(codes, hist),
        _ => None,
    }
}

/// The `N`-way encoder core. One generic body serves every width — the
/// `N = 2` monomorphization is byte-identical to the legacy 2-way coder
/// (the frozen golden-bytes test pins it).
fn encode_lanes<const N: usize>(codes: &[i32], hist: &[(i32, u64)]) -> Option<Vec<u8>> {
    let n_syms = hist.len();
    if codes.is_empty() || n_syms == 0 || n_syms > MAX_SYMS {
        return None;
    }
    let total: u64 = hist.iter().map(|&(_, c)| c).sum();
    let freqs = normalize_freqs(hist, total);
    let mut starts = vec![0u32; n_syms];
    let mut acc = 0u32;
    for (i, &f) in freqs.iter().enumerate() {
        starts[i] = acc;
        acc += f;
    }
    // Symbol -> table-index lookup: flat array fast path + HashMap overflow.
    let flat_len = (2 * FAST_RADIUS + 1) as usize;
    let mut flat_idx = vec![u32::MAX; flat_len];
    let mut overflow: HashMap<i32, u32> = HashMap::new();
    for (i, &(sym, _)) in hist.iter().enumerate() {
        if (-FAST_RADIUS..=FAST_RADIUS).contains(&sym) {
            flat_idx[(sym + FAST_RADIUS) as usize] = i as u32;
        } else {
            overflow.insert(sym, i as u32);
        }
    }
    // Backward pass: lane i mod N, bytes pushed LSB-first then globally
    // reversed (see module docs).
    let mut lanes = [RANS_L; N];
    let mut rev: Vec<u8> = Vec::with_capacity(codes.len() / 2 + 4 * N + 8);
    if kernels::scalar_kernels() {
        for i in (0..codes.len()).rev() {
            let c = codes[i];
            let si = if (-FAST_RADIUS..=FAST_RADIUS).contains(&c) {
                flat_idx[(c + FAST_RADIUS) as usize] as usize
            } else {
                overflow[&c] as usize
            };
            let f = freqs[si];
            let x = &mut lanes[i % N];
            let x_max = ((RANS_L >> SCALE_BITS) << 8) * f;
            while *x >= x_max {
                rev.push(*x as u8);
                *x >>= 8;
            }
            *x = ((*x / f) << SCALE_BITS) + (*x % f) + starts[si];
        }
    } else {
        for i in (0..codes.len()).rev() {
            // SAFETY: `i < codes.len()` by the loop range.
            let c = unsafe { *codes.get_unchecked(i) };
            let si = if (-FAST_RADIUS..=FAST_RADIUS).contains(&c) {
                // SAFETY: the range check puts `c + FAST_RADIUS` in
                // `[0, 2 * FAST_RADIUS]` and `flat_idx.len()` is exactly
                // `2 * FAST_RADIUS + 1`.
                unsafe { *flat_idx.get_unchecked((c + FAST_RADIUS) as usize) as usize }
            } else {
                overflow[&c] as usize
            };
            if si == u32::MAX as usize {
                // Cold: a symbol missing from the histogram violates the
                // crate-internal contract — keep the loud panic of the
                // checked path rather than indexing out of bounds.
                panic!("rANS: symbol {c} not in histogram");
            }
            // SAFETY: `si` was written into `flat_idx`/`overflow` by the
            // enumerate loop above, so `si < n_syms == freqs.len() ==
            // starts.len()` (the sentinel case panicked just before).
            let (f, start) = unsafe { (*freqs.get_unchecked(si), *starts.get_unchecked(si)) };
            // `i % N` with N a power of two compiles to a mask; lanes is a
            // fixed-size array so this index is `< N` by construction.
            let x = &mut lanes[i % N];
            let x_max = ((RANS_L >> SCALE_BITS) << 8) * f;
            while *x >= x_max {
                rev.push(*x as u8);
                *x >>= 8;
            }
            *x = ((*x / f) << SCALE_BITS) + (*x % f) + start;
        }
    }
    for l in (0..N).rev() {
        let x = lanes[l];
        rev.push(x as u8);
        rev.push((x >> 8) as u8);
        rev.push((x >> 16) as u8);
        rev.push((x >> 24) as u8);
    }
    rev.reverse();
    let mut out = Vec::with_capacity(1 + 12 + n_syms * 6 + rev.len());
    out.push(mode_for_lanes(N));
    out.extend_from_slice(&(codes.len() as u32).to_le_bytes());
    out.extend_from_slice(&(n_syms as u32).to_le_bytes());
    for (i, &(sym, _)) in hist.iter().enumerate() {
        out.extend_from_slice(&sym.to_le_bytes());
        out.extend_from_slice(&(freqs[i] as u16).to_le_bytes());
    }
    out.extend_from_slice(&(rev.len() as u32).to_le_bytes());
    out.extend_from_slice(&rev);
    Some(out)
}

/// Encode straight from codes (histogram computed internally), 2-way.
pub fn encode_to_bytes(codes: &[i32]) -> Option<Vec<u8>> {
    encode_with_hist(codes, &code_histogram(codes))
}

/// Decode a serialized rANS stream of any lane width, returning
/// (codes, bytes consumed).
///
/// Unbounded form for callers decoding their own encodings; untrusted
/// streams should go through [`decode_bounded`] — a full-`SCALE`
/// single-symbol table decodes symbols without consuming stream bytes,
/// so `count` alone must not size the output.
pub fn decode_from_bytes(buf: &[u8]) -> anyhow::Result<(Vec<i32>, usize)> {
    decode_bounded(buf, u32::MAX as usize)
}

/// [`decode_from_bytes`] with a caller-known cap on the symbol count
/// (e.g. the layer's `numel` from the already-parsed blob header).
/// Streams declaring more symbols are rejected before any work. The
/// leading mode byte selects the interleave width the stream was
/// encoded with.
pub fn decode_bounded(buf: &[u8], max_count: usize) -> anyhow::Result<(Vec<i32>, usize)> {
    match buf.first() {
        Some(&MODE_RANS) => decode_lanes::<2>(buf, max_count),
        Some(&MODE_RANS4) => decode_lanes::<4>(buf, max_count),
        Some(&MODE_RANS8) => decode_lanes::<8>(buf, max_count),
        _ => anyhow::bail!("not a rANS stream"),
    }
}

/// The `N`-way decoder core.
fn decode_lanes<const N: usize>(buf: &[u8], max_count: usize) -> anyhow::Result<(Vec<i32>, usize)> {
    use anyhow::bail;
    if buf.first() != Some(&mode_for_lanes(N)) {
        bail!("rANS stream mode does not match the {N}-way decoder");
    }
    let mut pos = 1usize;
    let rd_u32 = |buf: &[u8], pos: &mut usize| -> anyhow::Result<u32> {
        if *pos + 4 > buf.len() {
            anyhow::bail!("truncated rANS stream");
        }
        let v = u32::from_le_bytes(buf[*pos..*pos + 4].try_into().unwrap());
        *pos += 4;
        Ok(v)
    };
    let count = rd_u32(buf, &mut pos)? as usize;
    if count > max_count {
        bail!("rANS stream declares {count} symbols, expected at most {max_count}");
    }
    let n_syms = rd_u32(buf, &mut pos)? as usize;
    if n_syms == 0 || n_syms > MAX_SYMS {
        bail!("rANS alphabet size {n_syms} out of range");
    }
    if pos + n_syms * 6 > buf.len() {
        bail!("truncated rANS table");
    }
    let mut syms = Vec::with_capacity(n_syms);
    let mut freqs = Vec::with_capacity(n_syms);
    let mut sum = 0u32;
    for _ in 0..n_syms {
        let sym = i32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap());
        let f = u16::from_le_bytes(buf[pos + 4..pos + 6].try_into().unwrap()) as u32;
        pos += 6;
        if f == 0 {
            bail!("rANS table has zero frequency");
        }
        syms.push(sym);
        freqs.push(f);
        sum += f;
    }
    if sum != SCALE {
        bail!("rANS frequencies sum to {sum}, expected {SCALE}");
    }
    let stream_len = rd_u32(buf, &mut pos)? as usize;
    if pos + stream_len > buf.len() {
        bail!("truncated rANS payload");
    }
    let stream = &buf[pos..pos + stream_len];
    pos += stream_len;
    if count == 0 {
        return Ok((Vec::new(), pos));
    }
    if stream_len < 4 * N {
        bail!("rANS payload shorter than the state flush");
    }
    // slot -> table index, plus per-symbol interval starts.
    let mut starts = vec![0u32; n_syms];
    let mut slot_sym = vec![0u16; SCALE as usize];
    let mut acc = 0u32;
    for (i, &f) in freqs.iter().enumerate() {
        starts[i] = acc;
        for s in slot_sym.iter_mut().skip(acc as usize).take(f as usize) {
            *s = i as u16;
        }
        acc += f;
    }
    let mut lanes = [0u32; N];
    for (l, x) in lanes.iter_mut().enumerate() {
        *x = u32::from_be_bytes(stream[l * 4..l * 4 + 4].try_into().unwrap());
    }
    let mut sp = 4 * N;
    let mut out = Vec::with_capacity(count.min(1 << 22));
    if kernels::scalar_kernels() {
        for i in 0..count {
            let x = &mut lanes[i % N];
            let slot = *x & (SCALE - 1);
            let si = slot_sym[slot as usize] as usize;
            out.push(syms[si]);
            // u64 intermediate: corrupt initial states could otherwise
            // overflow the u32 multiply; valid states never do.
            let nx = freqs[si] as u64 * (*x >> SCALE_BITS) as u64 + (slot - starts[si]) as u64;
            *x = nx as u32;
            while *x < RANS_L {
                if sp >= stream.len() {
                    bail!("rANS stream underrun at symbol {i}");
                }
                *x = (*x << 8) | stream[sp] as u32;
                sp += 1;
            }
        }
    } else {
        for i in 0..count {
            let x = &mut lanes[i % N];
            let slot = *x & (SCALE - 1);
            // SAFETY: `slot = x & (SCALE - 1) < SCALE` and `slot_sym` has
            // exactly `SCALE` entries.
            let si = unsafe { *slot_sym.get_unchecked(slot as usize) } as usize;
            // SAFETY: every `slot_sym` entry was written as `i < n_syms`
            // in the table-build loop (`sum == SCALE` covers all slots),
            // and `syms`, `freqs`, `starts` all have length `n_syms`.
            let (sym, f, start) = unsafe {
                (
                    *syms.get_unchecked(si),
                    *freqs.get_unchecked(si),
                    *starts.get_unchecked(si),
                )
            };
            out.push(sym);
            // u64 intermediate: corrupt initial states could otherwise
            // overflow the u32 multiply; valid states never do.
            let nx = f as u64 * (*x >> SCALE_BITS) as u64 + (slot - start) as u64;
            *x = nx as u32;
            while *x < RANS_L {
                if sp >= stream.len() {
                    bail!("rANS stream underrun at symbol {i}");
                }
                // SAFETY: the bound check just above guarantees
                // `sp < stream.len()`.
                *x = (*x << 8) | unsafe { *stream.get_unchecked(sp) } as u32;
                sp += 1;
            }
        }
    }
    if lanes.iter().any(|&x| x != RANS_L) {
        bail!("rANS final-state mismatch (corrupt stream)");
    }
    Ok((out, pos))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::quant::ESCAPE_CODE;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn roundtrip(codes: &[i32]) -> Vec<u8> {
        let bytes = encode_to_bytes(codes).expect("encodable");
        let (got, used) = decode_from_bytes(&bytes).expect("decodable");
        assert_eq!(got, codes);
        assert_eq!(used, bytes.len());
        bytes
    }

    fn roundtrip_lanes(codes: &[i32], lanes: usize) -> Vec<u8> {
        let bytes = encode_with_hist_lanes(codes, &code_histogram(codes), lanes)
            .expect("encodable");
        let (got, used) = decode_from_bytes(&bytes).expect("decodable");
        assert_eq!(got, codes, "lanes={lanes}");
        assert_eq!(used, bytes.len(), "lanes={lanes}");
        bytes
    }

    #[test]
    fn golden_single_symbol_stream_is_frozen() {
        // [7, 7, 7, 7]: one symbol at frequency SCALE, both lanes park at
        // RANS_L untouched — the stream is exactly the two flushed states.
        let bytes = encode_to_bytes(&[7, 7, 7, 7]).unwrap();
        #[rustfmt::skip]
        let expect: Vec<u8> = vec![
            2,              // MODE_RANS
            4, 0, 0, 0,     // count
            1, 0, 0, 0,     // n_syms
            7, 0, 0, 0,     // symbol 7
            0, 16,          // freq 4096
            8, 0, 0, 0,     // stream length
            0, 128, 0, 0,   // lane 0 state, big-endian RANS_L
            0, 128, 0, 0,   // lane 1 state
        ];
        assert_eq!(bytes, expect);
        let (got, used) = decode_from_bytes(&bytes).unwrap();
        assert_eq!(got, vec![7, 7, 7, 7]);
        assert_eq!(used, bytes.len());
    }

    #[test]
    fn golden_wide_lane_streams_are_frozen() {
        // The rans4/rans8 twins of the frozen 2-way golden stream: same
        // header layout, their own mode byte, N parked states.
        for (lanes, mode) in [(4usize, MODE_RANS4), (8usize, MODE_RANS8)] {
            let bytes =
                encode_with_hist_lanes(&[7, 7, 7, 7], &code_histogram(&[7, 7, 7, 7]), lanes)
                    .unwrap();
            #[rustfmt::skip]
            let mut expect: Vec<u8> = vec![
                mode,           // MODE_RANS4 / MODE_RANS8
                4, 0, 0, 0,     // count
                1, 0, 0, 0,     // n_syms
                7, 0, 0, 0,     // symbol 7
                0, 16,          // freq 4096
                (4 * lanes) as u8, 0, 0, 0, // stream length
            ];
            for _ in 0..lanes {
                expect.extend_from_slice(&[0, 128, 0, 0]); // parked state
            }
            assert_eq!(bytes, expect, "lanes={lanes}");
            let (got, used) = decode_from_bytes(&bytes).unwrap();
            assert_eq!(got, vec![7, 7, 7, 7]);
            assert_eq!(used, bytes.len());
        }
    }

    #[test]
    fn roundtrip_adversarial_distributions() {
        let mut rng = Rng::new(7);
        // Single symbol.
        let single = vec![-3; 4097];
        roundtrip(&single);
        // Uniform over a power-of-two alphabet (Huffman's best case).
        let uniform: Vec<i32> = (0..8192).map(|i| i % 16).collect();
        roundtrip(&uniform);
        // Geometric (the predictor's typical residual shape).
        let geo: Vec<i32> = (0..20_000)
            .map(|_| {
                let mut v = 0i32;
                while rng.chance(0.6) {
                    v += 1;
                }
                if rng.chance(0.5) {
                    -v
                } else {
                    v
                }
            })
            .collect();
        let enc = roundtrip(&geo);
        assert!(enc.len() < geo.len(), "geometric should beat 1 byte/sym");
        // Escape-heavy: the ESCAPE_CODE marker mixed through.
        let esc: Vec<i32> = (0..5000)
            .map(|i| if i % 3 == 0 { ESCAPE_CODE } else { (i % 7) as i32 - 3 })
            .collect();
        roundtrip(&esc);
        // Odd lengths exercise the interleave parity.
        roundtrip(&[5]);
        roundtrip(&[5, -5, 5]);
    }

    #[test]
    fn wide_lanes_roundtrip_adversarial_distributions() {
        let mut rng = Rng::new(17);
        let geo: Vec<i32> = (0..20_000)
            .map(|_| {
                let mut v = 0i32;
                while rng.chance(0.6) {
                    v += 1;
                }
                v
            })
            .collect();
        let single = vec![-3; 4097];
        let uniform: Vec<i32> = (0..8192).map(|i| i % 16).collect();
        for lanes in [4usize, 8] {
            roundtrip_lanes(&single, lanes);
            roundtrip_lanes(&uniform, lanes);
            roundtrip_lanes(&geo, lanes);
            // Lengths around the lane count exercise every tail parity.
            for n in 1..=39 {
                let codes: Vec<i32> = (0..n).map(|i| (i % 5) as i32 - 2).collect();
                roundtrip_lanes(&codes, lanes);
            }
        }
    }

    #[test]
    fn lane_widths_are_distinct_wire_formats() {
        let codes: Vec<i32> = (0..999).map(|i| (i % 11) as i32 - 5).collect();
        let hist = code_histogram(&codes);
        let b2 = encode_with_hist_lanes(&codes, &hist, 2).unwrap();
        let b4 = encode_with_hist_lanes(&codes, &hist, 4).unwrap();
        let b8 = encode_with_hist_lanes(&codes, &hist, 8).unwrap();
        assert_eq!(b2[0], MODE_RANS);
        assert_eq!(b4[0], MODE_RANS4);
        assert_eq!(b8[0], MODE_RANS8);
        // Same header (count + table), different stream bytes: the widths
        // must never be confused for each other.
        assert_ne!(b2, b4);
        assert_ne!(b4, b8);
        // Unsupported widths decline instead of inventing a format.
        assert!(encode_with_hist_lanes(&codes, &hist, 3).is_none());
        // All decode through the same mode-dispatched entry point.
        for b in [&b2, &b4, &b8] {
            assert_eq!(decode_from_bytes(b).unwrap().0, codes);
        }
    }

    #[test]
    fn empty_and_oversized_alphabets_decline() {
        assert!(encode_to_bytes(&[]).is_none());
        let wide: Vec<i32> = (0..(MAX_SYMS as i32 + 1)).collect();
        assert!(encode_to_bytes(&wide).is_none());
        let exactly: Vec<i32> = (0..(MAX_SYMS as i32)).collect();
        roundtrip(&exactly);
    }

    #[test]
    fn normalization_sums_to_scale() {
        let mut rng = Rng::new(11);
        for _ in 0..200 {
            let k = 1 + rng.next_below(300);
            let hist: Vec<(i32, u64)> =
                (0..k).map(|i| (i as i32, 1 + rng.next_below(100_000) as u64)).collect();
            let total: u64 = hist.iter().map(|&(_, c)| c).sum();
            let freqs = normalize_freqs(&hist, total);
            assert_eq!(freqs.iter().map(|&f| f as u64).sum::<u64>(), SCALE as u64);
            assert!(freqs.iter().all(|&f| f >= 1));
        }
    }

    #[test]
    fn bounded_decode_rejects_inflated_count() {
        // A flipped count high byte on a single-symbol stream would
        // otherwise decode ~4e9 symbols without consuming a byte (the
        // lanes never renorm at freq == SCALE) — the bound must catch it.
        let mut bytes = encode_to_bytes(&[7, 7, 7, 7]).unwrap();
        assert!(decode_bounded(&bytes, 4).is_ok());
        assert!(decode_bounded(&bytes, 3).is_err());
        bytes[4] = 0xFF; // count = 4 | 0xFF000000
        assert!(decode_bounded(&bytes, 1 << 20).is_err());
    }

    #[test]
    fn corrupt_streams_error_not_panic() {
        for lanes in [2usize, 4, 8] {
            let codes = [1, 2, 3, 1, 2, 1, 1, 1, 0, 0, 0];
            let bytes = encode_with_hist_lanes(&codes, &code_histogram(&codes), lanes).unwrap();
            assert!(decode_from_bytes(&bytes[..bytes.len() - 3]).is_err());
            for i in 1..bytes.len() {
                let mut bad = bytes.clone();
                bad[i] ^= 0xFF;
                // Any outcome but a panic is acceptable; most flips are
                // caught by the table checks or the final-state invariant.
                let _ = decode_from_bytes(&bad);
            }
        }
        assert!(decode_from_bytes(&[]).is_err());
        assert!(decode_from_bytes(&[MODE_RANS]).is_err());
        assert!(decode_from_bytes(&[MODE_RANS4]).is_err());
        assert!(decode_from_bytes(&[MODE_RANS8]).is_err());
    }

    #[test]
    fn property_roundtrip_random_streams() {
        prop::check("rans roundtrip", 100, |rng| {
            let n = prop::arb_len(rng, 5000);
            let spread = 1 + rng.next_below(1000) as i32;
            let codes: Vec<i32> =
                (0..n).map(|_| rng.next_below(spread as usize * 2) as i32 - spread).collect();
            let lanes = [2usize, 4, 8][rng.next_below(3)];
            let bytes = encode_with_hist_lanes(&codes, &code_histogram(&codes), lanes)
                .ok_or("declined")?;
            let (got, used) = decode_from_bytes(&bytes).map_err(|e| e.to_string())?;
            if got != codes {
                return Err("mismatch".into());
            }
            if used != bytes.len() {
                return Err(format!("used {used} != len {}", bytes.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn scalar_and_fast_twins_agree_bytewise() {
        prop::check("rans scalar==fast", 60, |rng| {
            let n = prop::arb_len(rng, 4000);
            let spread = 1 + rng.next_below(500) as i32;
            let codes: Vec<i32> =
                (0..n).map(|_| rng.next_below(spread as usize * 2) as i32 - spread).collect();
            let hist = code_histogram(&codes);
            for lanes in [2usize, 4, 8] {
                let fast = encode_with_hist_lanes(&codes, &hist, lanes).ok_or("declined")?;
                let slow = kernels::with_scalar_kernels(|| {
                    encode_with_hist_lanes(&codes, &hist, lanes)
                })
                .ok_or("declined")?;
                if fast != slow {
                    return Err(format!("lanes={lanes}: encoded bytes diverge"));
                }
                let (df, _) = decode_from_bytes(&fast).map_err(|e| e.to_string())?;
                let ds = kernels::with_scalar_kernels(|| {
                    decode_from_bytes(&fast).map(|x| x.0)
                })
                .map_err(|e| e.to_string())?;
                if df != codes || ds != codes {
                    return Err(format!("lanes={lanes}: decode mismatch"));
                }
            }
            Ok(())
        });
    }
}
